//! Representation ingest-throughput comparison (Sec. II-B): the memory
//! write amplification of SITS/TOS shows up directly as update cost.
//!
//! Also sweeps the ingest batch size (1 / 64 / 4096) on the SAE-class and
//! ISC representations to quantify the batch-first API win, benchmarks
//! the frame-readout paths — including the dense vs. active-set sweep at
//! 1 % / 10 % / 100 % pixel activity on 346×260 and 640×480 — and dumps
//! the measurements to `BENCH_tsurface.json` (readout entries carry a
//! `pixels_per_sec` field) so CI can track the perf trajectory.

use tsisc::events::{Event, Polarity, Resolution};
use tsisc::isc::{IscArray, IscConfig};
use tsisc::tsurface::*;
use tsisc::util::bench::{bench, header, BenchResult};
use tsisc::util::grid::Grid;
use tsisc::util::rng::Pcg64;

/// One JSON line: every bench reports `meps` (items/s ÷ 1e6); frame
/// readouts, whose items are pixels, additionally report `pixels_per_sec`.
struct Entry {
    result: BenchResult,
    is_readout: bool,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn dump_json(entries: &[Entry], path: &str) {
    let mut s = String::from("{\n  \"benchmarks\": [\n");
    for (i, e) in entries.iter().enumerate() {
        let r = &e.result;
        let extra = if e.is_readout {
            format!(", \"pixels_per_sec\": {:.1}", r.throughput_per_sec())
        } else {
            String::new()
        };
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"meps\": {:.4}{}}}{}\n",
            json_escape(&r.name),
            r.mean_ns,
            r.throughput_per_sec() / 1e6,
            extra,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write(path, s) {
        eprintln!("(could not write {path}: {e})");
    } else {
        println!("wrote {path}");
    }
}

fn main() {
    header("bench_tsurface — representation ingest throughput");
    let res = Resolution::QVGA;
    let mut rng = Pcg64::new(7);
    let n = 10_000usize;
    let events: Vec<Event> = (0..n)
        .map(|k| {
            Event::new(
                1 + k as u64 * 3,
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                if rng.bool(0.5) { Polarity::On } else { Polarity::Off },
            )
        })
        .collect();
    let mut entries: Vec<Entry> = Vec::new();

    // --- Per-event ingest across every representation -------------------
    {
        let mut run_rep = |name: &str, mut rep: Box<dyn Representation>| {
            let r = bench(name, events.len() as f64, 100, 600, || {
                for e in &events {
                    rep.ingest(e);
                }
            });
            println!("{}  (writes/event {:.2})", r.report(), rep.writes_per_event());
            entries.push(Entry { result: r, is_readout: false });
        };
        run_rep("SAE", Box::new(Sae::new(res)));
        run_rep("ideal TS", Box::new(IdealTs::new(res, 24_000.0)));
        run_rep("quantized SAE (16b)", Box::new(QuantizedSae::new(res, 16, 24_000.0)));
        run_rep("EBBI", Box::new(Ebbi::new(res)));
        run_rep("event count (4b)", Box::new(EventCount::new(res, 4)));
        run_rep("SITS (r=3)", Box::new(Sits::new(res, 3)));
        run_rep("TOS (r=3)", Box::new(Tos::new(res, 3)));
        run_rep("TORE (k=3)", Box::new(Tore::new(res, 3, 100.0, 1e6)));
        run_rep("3DS-ISC", Box::new(IscTs::with_defaults(res)));
    }

    // --- Batch-size sweep: the batch-first API win -----------------------
    println!();
    for &bs in &[1usize, 64, 4_096] {
        let mut run_batched = |name: &str, mut rep: Box<dyn Representation>| {
            let r = bench(
                &format!("{name} ingest_batch bs={bs}"),
                events.len() as f64,
                100,
                600,
                || {
                    for chunk in events.chunks(bs) {
                        rep.ingest_batch(chunk);
                    }
                },
            );
            println!("{}", r.report());
            entries.push(Entry { result: r, is_readout: false });
        };
        run_batched("SAE", Box::new(Sae::new(res)));
        run_batched("3DS-ISC", Box::new(IscTs::with_defaults(res)));
    }

    // --- Zero-allocation frame readout -----------------------------------
    println!();
    {
        let mut rep = IscTs::with_defaults(res);
        rep.ingest_batch(&events);
        let mut buf = Grid::new(1, 1, 0.0f64);
        rep.frame_into(&mut buf, 40_000); // warmup reshape
        let mut t = 40_000u64;
        let r = bench("3DS-ISC frame_into (QVGA, reused buffer)",
                      res.pixels() as f64, 100, 600, || {
            t += 1_000;
            rep.frame_into(&mut buf, t);
            std::hint::black_box(buf.as_slice());
        });
        println!("{}", r.report());
        entries.push(Entry { result: r, is_readout: true });
    }

    // --- Frame-readout sweep: dense vs. active-set ------------------------
    // Activity = fraction of distinct pixels holding a live (in-horizon)
    // write at readout time. The active path must win big at low activity
    // and stay competitive at 100 %.
    println!();
    header("frame readout: dense vs active-set");
    for (label, w, h) in [("346x260", 346u16, 260u16), ("640x480", 640, 480)] {
        let sweep_res = Resolution::new(w, h);
        for &activity in &[0.01f64, 0.10, 1.00] {
            let mut arr = IscArray::new(sweep_res, IscConfig::default());
            let n_active = ((sweep_res.pixels() as f64 * activity).round() as usize).max(1);
            let stride = (sweep_res.pixels() / n_active).max(1);
            let writes: Vec<Event> = (0..n_active)
                .map(|k| {
                    let i = (k * stride) % sweep_res.pixels();
                    Event::new(
                        1_000 + (k % 512) as u64,
                        (i % w as usize) as u16,
                        (i / w as usize) as u16,
                        Polarity::On,
                    )
                })
                .collect();
            arr.write_batch(&writes);
            let t_q = 40_000u64; // well inside the ~102 ms memory horizon
            let act_pct = (activity * 100.0).round() as u32;

            let mut buf = Grid::new(1, 1, 0.0f64);
            arr.frame_merged_into(&mut buf, t_q); // warmup reshape
            let r = bench(
                &format!("ISC readout active {label} act={act_pct}%"),
                sweep_res.pixels() as f64,
                80,
                400,
                || {
                    arr.frame_merged_into(&mut buf, t_q);
                    std::hint::black_box(buf.as_slice());
                },
            );
            println!("{}", r.report());
            entries.push(Entry { result: r, is_readout: true });

            let mut dbuf = Grid::new(1, 1, 0.0f64);
            arr.frame_merged_dense_into(&mut dbuf, t_q);
            let rd = bench(
                &format!("ISC readout dense  {label} act={act_pct}%"),
                sweep_res.pixels() as f64,
                80,
                400,
                || {
                    arr.frame_merged_dense_into(&mut dbuf, t_q);
                    std::hint::black_box(dbuf.as_slice());
                },
            );
            println!("{}", rd.report());
            entries.push(Entry { result: rd, is_readout: true });
        }
    }

    dump_json(&entries, "BENCH_tsurface.json");
}
