//! Representation ingest-throughput comparison (Sec. II-B): the memory
//! write amplification of SITS/TOS shows up directly as update cost.
//!
//! Also sweeps the ingest batch size (1 / 64 / 4096) on the SAE-class and
//! ISC representations to quantify the batch-first API win, benchmarks
//! the allocation-free `frame_into` readout, and dumps the measurements
//! to `BENCH_tsurface.json` so CI can track the perf trajectory.

use tsisc::events::{Event, Polarity, Resolution};
use tsisc::tsurface::*;
use tsisc::util::bench::{bench, header, BenchResult};
use tsisc::util::grid::Grid;
use tsisc::util::rng::Pcg64;

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn dump_json(results: &[BenchResult], path: &str) {
    let mut s = String::from("{\n  \"benchmarks\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"meps\": {:.4}}}{}\n",
            json_escape(&r.name),
            r.mean_ns,
            r.throughput_per_sec() / 1e6,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write(path, s) {
        eprintln!("(could not write {path}: {e})");
    } else {
        println!("wrote {path}");
    }
}

fn main() {
    header("bench_tsurface — representation ingest throughput");
    let res = Resolution::QVGA;
    let mut rng = Pcg64::new(7);
    let n = 10_000usize;
    let events: Vec<Event> = (0..n)
        .map(|k| {
            Event::new(
                1 + k as u64 * 3,
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                if rng.bool(0.5) { Polarity::On } else { Polarity::Off },
            )
        })
        .collect();
    let mut results: Vec<BenchResult> = Vec::new();

    // --- Per-event ingest across every representation -------------------
    {
        let mut run_rep = |name: &str, mut rep: Box<dyn Representation>| {
            let r = bench(name, events.len() as f64, 100, 600, || {
                for e in &events {
                    rep.ingest(e);
                }
            });
            println!("{}  (writes/event {:.2})", r.report(), rep.writes_per_event());
            results.push(r);
        };
        run_rep("SAE", Box::new(Sae::new(res)));
        run_rep("ideal TS", Box::new(IdealTs::new(res, 24_000.0)));
        run_rep("quantized SAE (16b)", Box::new(QuantizedSae::new(res, 16, 24_000.0)));
        run_rep("EBBI", Box::new(Ebbi::new(res)));
        run_rep("event count (4b)", Box::new(EventCount::new(res, 4)));
        run_rep("SITS (r=3)", Box::new(Sits::new(res, 3)));
        run_rep("TOS (r=3)", Box::new(Tos::new(res, 3)));
        run_rep("TORE (k=3)", Box::new(Tore::new(res, 3, 100.0, 1e6)));
        run_rep("3DS-ISC", Box::new(IscTs::with_defaults(res)));
    }

    // --- Batch-size sweep: the batch-first API win -----------------------
    println!();
    for &bs in &[1usize, 64, 4_096] {
        let mut run_batched = |name: &str, mut rep: Box<dyn Representation>| {
            let r = bench(
                &format!("{name} ingest_batch bs={bs}"),
                events.len() as f64,
                100,
                600,
                || {
                    for chunk in events.chunks(bs) {
                        rep.ingest_batch(chunk);
                    }
                },
            );
            println!("{}", r.report());
            results.push(r);
        };
        run_batched("SAE", Box::new(Sae::new(res)));
        run_batched("3DS-ISC", Box::new(IscTs::with_defaults(res)));
    }

    // --- Zero-allocation frame readout -----------------------------------
    println!();
    {
        let mut rep = IscTs::with_defaults(res);
        rep.ingest_batch(&events);
        let mut buf = Grid::new(1, 1, 0.0f64);
        rep.frame_into(&mut buf, 40_000); // warmup reshape
        let mut t = 40_000u64;
        let r = bench("3DS-ISC frame_into (QVGA, reused buffer)",
                      res.pixels() as f64, 100, 600, || {
            t += 1_000;
            rep.frame_into(&mut buf, t);
            std::hint::black_box(buf.as_slice());
        });
        println!("{}", r.report());
        results.push(r);
    }

    dump_json(&results, "BENCH_tsurface.json");
}
