//! Representation update-throughput comparison (Sec. II-B): the memory
//! write amplification of SITS/TOS shows up directly as update cost.

use tsisc::events::{Event, Polarity, Resolution};
use tsisc::tsurface::*;
use tsisc::util::bench::{bench, header};
use tsisc::util::rng::Pcg64;

fn main() {
    header("bench_tsurface — representation update throughput");
    let res = Resolution::QVGA;
    let mut rng = Pcg64::new(7);
    let n = 10_000usize;
    let events: Vec<Event> = (0..n)
        .map(|k| {
            Event::new(
                1 + k as u64 * 3,
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                if rng.bool(0.5) { Polarity::On } else { Polarity::Off },
            )
        })
        .collect();

    fn run_rep(name: &str, mut rep: Box<dyn Representation>, events: &[Event]) {
        let r = bench(name, events.len() as f64, 100, 600, || {
            for e in events {
                rep.update(e);
            }
        });
        println!("{}  (writes/event {:.2})", r.report(), rep.writes_per_event());
    }

    run_rep("SAE", Box::new(Sae::new(res)), &events);
    run_rep("ideal TS", Box::new(IdealTs::new(res, 24_000.0)), &events);
    run_rep("quantized SAE (16b)", Box::new(QuantizedSae::new(res, 16, 24_000.0)), &events);
    run_rep("EBBI", Box::new(Ebbi::new(res)), &events);
    run_rep("event count (4b)", Box::new(EventCount::new(res, 4)), &events);
    run_rep("SITS (r=3)", Box::new(Sits::new(res, 3)), &events);
    run_rep("TOS (r=3)", Box::new(Tos::new(res, 3)), &events);
    run_rep("TORE (k=3)", Box::new(Tore::new(res, 3, 100.0, 1e6)), &events);
    run_rep("3DS-ISC", Box::new(IscTs::with_defaults(res)), &events);
}
