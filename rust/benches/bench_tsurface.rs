//! Representation ingest-throughput comparison (Sec. II-B): the memory
//! write amplification of SITS/TOS shows up directly as update cost.
//!
//! Also sweeps the ingest batch size (1 / 64 / 4096) on the SAE-class and
//! ISC representations to quantify the batch-first API win, benchmarks
//! the frame-readout paths — the dense vs. active-set sweep at
//! 1 % / 10 % / 100 % pixel activity on 346×260 and 640×480, the
//! row-parallel thread-count sweep (1/2/4/8 chunks × activity, reported
//! as `frames_per_sec`), and the dense-fallback α crossover sweep
//! (α ∈ {5, 10, 20, 40 %}, printing the measured crossover against the
//! configured `DENSE_FALLBACK_ALPHA`) — and dumps everything to
//! `BENCH_tsurface.json` so CI can track the perf trajectory.

use tsisc::events::{Event, Polarity, Resolution};
use tsisc::isc::{IscArray, IscConfig};
use tsisc::tsurface::*;
use tsisc::util::active::DENSE_FALLBACK_ALPHA;
use tsisc::util::bench::{bench, dump_json, header, JsonEntry};
use tsisc::util::grid::Grid;
use tsisc::util::rng::Pcg64;

/// Array with ~`activity`·pixels distinct live cells (even stride fill).
fn array_at_activity(res: Resolution, activity: f64) -> IscArray {
    let mut arr = IscArray::new(res, IscConfig::default());
    let n_active = ((res.pixels() as f64 * activity).round() as usize).max(1);
    let stride = (res.pixels() / n_active).max(1);
    let w = res.width as usize;
    let writes: Vec<Event> = (0..n_active)
        .map(|k| {
            let i = (k * stride) % res.pixels();
            Event::new(1_000 + (k % 512) as u64, (i % w) as u16, (i / w) as u16, Polarity::On)
        })
        .collect();
    arr.write_batch(&writes);
    arr
}

fn main() {
    header("bench_tsurface — representation ingest throughput");
    let res = Resolution::QVGA;
    let mut rng = Pcg64::new(7);
    let n = 10_000usize;
    let events: Vec<Event> = (0..n)
        .map(|k| {
            Event::new(
                1 + k as u64 * 3,
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                if rng.bool(0.5) { Polarity::On } else { Polarity::Off },
            )
        })
        .collect();
    let mut entries: Vec<JsonEntry> = Vec::new();

    // --- Per-event ingest across every representation -------------------
    {
        let mut run_rep = |name: &str, mut rep: Box<dyn Representation>| {
            let r = bench(name, events.len() as f64, 100, 600, || {
                for e in &events {
                    rep.ingest(e);
                }
            });
            println!("{}  (writes/event {:.2})", r.report(), rep.writes_per_event());
            entries.push(JsonEntry::plain(r));
        };
        run_rep("SAE", Box::new(Sae::new(res)));
        run_rep("ideal TS", Box::new(IdealTs::new(res, 24_000.0)));
        run_rep("quantized SAE (16b)", Box::new(QuantizedSae::new(res, 16, 24_000.0)));
        run_rep("EBBI", Box::new(Ebbi::new(res)));
        run_rep("event count (4b)", Box::new(EventCount::new(res, 4)));
        run_rep("SITS (r=3)", Box::new(Sits::new(res, 3)));
        run_rep("TOS (r=3)", Box::new(Tos::new(res, 3)));
        run_rep("TORE (k=3)", Box::new(Tore::new(res, 3, 100.0, 1e6)));
        run_rep("3DS-ISC", Box::new(IscTs::with_defaults(res)));
    }

    // --- Batch-size sweep: the batch-first API win -----------------------
    println!();
    for &bs in &[1usize, 64, 4_096] {
        let mut run_batched = |name: &str, mut rep: Box<dyn Representation>| {
            let r = bench(
                &format!("{name} ingest_batch bs={bs}"),
                events.len() as f64,
                100,
                600,
                || {
                    for chunk in events.chunks(bs) {
                        rep.ingest_batch(chunk);
                    }
                },
            );
            println!("{}", r.report());
            entries.push(JsonEntry::plain(r));
        };
        run_batched("SAE", Box::new(Sae::new(res)));
        run_batched("3DS-ISC", Box::new(IscTs::with_defaults(res)));
    }

    // --- Zero-allocation frame readout -----------------------------------
    println!();
    {
        let mut rep = IscTs::with_defaults(res);
        rep.ingest_batch(&events);
        let mut buf = Grid::new(1, 1, 0.0f64);
        rep.frame_into(&mut buf, 40_000); // warmup reshape
        let mut t = 40_000u64;
        let r = bench("3DS-ISC frame_into (QVGA, reused buffer)",
                      res.pixels() as f64, 100, 600, || {
            t += 1_000;
            rep.frame_into(&mut buf, t);
            std::hint::black_box(buf.as_slice());
        });
        println!("{}", r.report());
        let pps = r.throughput_per_sec();
        entries.push(JsonEntry::with(r, "pixels_per_sec", pps));
    }

    // --- Frame-readout sweep: dense vs. active-set ------------------------
    // Activity = fraction of distinct pixels holding a live (in-horizon)
    // write at readout time. The active path must win big at low activity
    // and stay competitive at 100 %.
    println!();
    header("frame readout: dense vs active-set (forced modes)");
    for (label, w, h) in [("346x260", 346u16, 260u16), ("640x480", 640, 480)] {
        let sweep_res = Resolution::new(w, h);
        for &activity in &[0.01f64, 0.10, 1.00] {
            let arr = array_at_activity(sweep_res, activity);
            let t_q = 40_000u64; // well inside the ~102 ms memory horizon
            let act_pct = (activity * 100.0).round() as u32;

            let mut buf = Grid::new(1, 1, 0.0f64);
            arr.frame_merged_active_into(&mut buf, t_q); // warmup reshape
            let r = bench(
                &format!("ISC readout active {label} act={act_pct}%"),
                sweep_res.pixels() as f64,
                80,
                400,
                || {
                    arr.frame_merged_active_into(&mut buf, t_q);
                    std::hint::black_box(buf.as_slice());
                },
            );
            println!("{}", r.report());
            let pps = r.throughput_per_sec();
            entries.push(JsonEntry::with(r, "pixels_per_sec", pps));

            let mut dbuf = Grid::new(1, 1, 0.0f64);
            arr.frame_merged_dense_into(&mut dbuf, t_q);
            let rd = bench(
                &format!("ISC readout dense  {label} act={act_pct}%"),
                sweep_res.pixels() as f64,
                80,
                400,
                || {
                    arr.frame_merged_dense_into(&mut dbuf, t_q);
                    std::hint::black_box(dbuf.as_slice());
                },
            );
            println!("{}", rd.report());
            let pps = rd.throughput_per_sec();
            entries.push(JsonEntry::with(rd, "pixels_per_sec", pps));
        }
    }

    // --- Row-parallel thread-count sweep ----------------------------------
    // 1/2/4/8 chunks × 1/10/100 % activity at 640×480 through the
    // explicit-chunk API (the auto path picks available_parallelism).
    // The acceptance figure: 8-thread 100 %-activity frames_per_sec ≥ 2×
    // the 1-thread figure from the same run.
    println!();
    header("frame readout: thread-count sweep (640x480)");
    let par_res = Resolution::new(640, 480);
    for &activity in &[0.01f64, 0.10, 1.00] {
        let arr = array_at_activity(par_res, activity);
        let act_pct = (activity * 100.0).round() as u32;
        for &threads in &[1usize, 2, 4, 8] {
            let mut buf = Grid::new(1, 1, 0.0f64);
            arr.frame_merged_into_chunks(&mut buf, 40_000, threads); // warmup
            let r = bench(
                &format!("ISC readout 640x480 act={act_pct}% threads={threads}"),
                1.0,
                80,
                400,
                || {
                    arr.frame_merged_into_chunks(&mut buf, 40_000, threads);
                    std::hint::black_box(buf.as_slice());
                },
            );
            let fps = r.throughput_per_sec();
            println!("{}  [{fps:.1} frames/s]", r.report());
            entries.push(JsonEntry::with(r, "frames_per_sec", fps));
        }
    }

    // --- Dense-fallback α crossover sweep ---------------------------------
    // Measure forced-active vs forced-dense at α ∈ {5, 10, 20, 40 %} and
    // report the smallest swept activity at which the dense scan wins —
    // the re-tuning signal for DENSE_FALLBACK_ALPHA.
    println!();
    header("dense-fallback crossover sweep (346x260)");
    let cross_res = Resolution::new(346, 260);
    let mut crossover: Option<f64> = None;
    for &alpha in &[0.05f64, 0.10, 0.20, 0.40] {
        let arr = array_at_activity(cross_res, alpha);
        let mut abuf = Grid::new(1, 1, 0.0f64);
        let mut dbuf = Grid::new(1, 1, 0.0f64);
        arr.frame_merged_active_into(&mut abuf, 40_000);
        arr.frame_merged_dense_into(&mut dbuf, 40_000);
        let pct = (alpha * 100.0).round() as u32;
        let ra = bench(&format!("crossover active act={pct}%"), 1.0, 60, 300, || {
            arr.frame_merged_active_into(&mut abuf, 40_000);
            std::hint::black_box(abuf.as_slice());
        });
        let rd = bench(&format!("crossover dense  act={pct}%"), 1.0, 60, 300, || {
            arr.frame_merged_dense_into(&mut dbuf, 40_000);
            std::hint::black_box(dbuf.as_slice());
        });
        let winner = if rd.mean_ns < ra.mean_ns { "dense" } else { "active" };
        println!("{}  [{winner} wins]", ra.report());
        println!("{}", rd.report());
        if rd.mean_ns < ra.mean_ns && crossover.is_none() {
            crossover = Some(alpha);
        }
        let fps = ra.throughput_per_sec();
        entries.push(JsonEntry::with(ra, "frames_per_sec", fps));
        let fps = rd.throughput_per_sec();
        entries.push(JsonEntry::with(rd, "frames_per_sec", fps));
    }
    match crossover {
        Some(a) => println!(
            "chosen dense-fallback threshold: α = {:.0}% (configured = {:.0}%)",
            a * 100.0,
            DENSE_FALLBACK_ALPHA * 100.0
        ),
        None => println!(
            "dense never won in the swept range; keep DENSE_FALLBACK_ALPHA = {:.0}%",
            DENSE_FALLBACK_ALPHA * 100.0
        ),
    }

    dump_json(&entries, "BENCH_tsurface.json");
}
