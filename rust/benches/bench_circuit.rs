//! Circuit-layer benchmarks: transient simulation, Monte-Carlo sampling
//! and double-exponential fitting (the Table I / Fig. 5 / Fig. 9 engines).

use tsisc::circuit::cell::CellSim;
use tsisc::circuit::montecarlo::{sample_cell, FittedBank, MismatchParams};
use tsisc::circuit::params::VDD;
use tsisc::circuit::LeakageMacro;
use tsisc::util::bench::{bench, header};
use tsisc::util::fit::fit_double_exp;
use tsisc::util::rng::Pcg64;

fn main() {
    header("bench_circuit — SPICE-substitute engines");
    let cell = CellSim::ll_nominal();

    let r = bench("v_at(30 ms) RK4 transient", 1.0, 100, 600, || {
        std::hint::black_box(cell.v_at(VDD, 30e-3));
    });
    println!("{}", r.report());

    let r = bench("64-sample transient (60 ms)", 64.0, 100, 600, || {
        std::hint::black_box(cell.transient(VDD, 60e-3, 64));
    });
    println!("{}", r.report());

    let nominal = LeakageMacro::ll_calibrated();
    let mm = MismatchParams::default();
    let mut rng = Pcg64::new(1);
    let r = bench("MC cell sample + probe", 1.0, 100, 600, || {
        let c = sample_cell(20e-15, &nominal, &mm, &mut rng);
        std::hint::black_box(c.v_at(VDD, 20e-3));
    });
    println!("{}", r.report());

    let (ts, vs) = cell.transient(VDD, 60e-3, 64);
    let r = bench("double-exp LM fit (64 pts)", 1.0, 100, 600, || {
        std::hint::black_box(fit_double_exp(&ts, &vs));
    });
    println!("{}", r.report());

    let r = bench("FittedBank::build(32)", 32.0, 200, 1_000, || {
        std::hint::black_box(FittedBank::build(20e-15, &mm, 32, 3));
    });
    println!("{}", r.report());
}
