//! Coordinator benchmarks: sharded-router throughput vs shard count and
//! batch size, end-to-end pipeline events/s (the paper's "throughput
//! limited by data transmission" argument, Sec. III-B, measured on the
//! software twin), and the dirty-band snapshot protocol (clean vs dirty
//! steady-state frame cost, reported as `frames_per_sec`). All
//! measurements are dumped to `BENCH_router.json` for the CI artifact.

use tsisc::coordinator::{run_pipeline, PipelineConfig, Router, RouterConfig};
use tsisc::events::{noise::ba_noise, Event, Polarity, Resolution};
use tsisc::util::bench::{bench, dump_json, header, JsonEntry};
use tsisc::util::grid::Grid;
use tsisc::util::rng::Pcg64;

fn main() {
    header("bench_router — event routing and pipeline throughput");
    let res = Resolution::QVGA;
    let mut rng = Pcg64::new(3);
    let n = 20_000usize;
    let events: Vec<Event> = (0..n)
        .map(|k| {
            Event::new(
                1 + k as u64,
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                Polarity::On,
            )
        })
        .collect();
    let mut entries: Vec<JsonEntry> = Vec::new();

    // Single-event route() (staged internally) vs explicit route_batch().
    for shards in [1usize, 2, 4, 8] {
        let mut router = Router::new(
            res,
            RouterConfig { n_shards: shards, ..RouterConfig::default() },
        );
        let r = bench(&format!("route 20k events, {shards} shards"), n as f64, 100, 600, || {
            for e in &events {
                router.route(*e);
            }
        });
        println!("{}", r.report());
        entries.push(JsonEntry::plain(r));
        router.shutdown();
    }

    println!();
    for &bs in &[1usize, 64, 4_096] {
        let mut router = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        let r = bench(&format!("route_batch 20k events, 4 shards, bs={bs}"), n as f64, 100, 600,
                      || {
            for chunk in events.chunks(bs) {
                router.route_batch(chunk);
            }
        });
        println!("{}", r.report());
        entries.push(JsonEntry::plain(r));
        router.shutdown();
    }

    // Dirty-band snapshots: steady-state frame cost when the stream is
    // idle (all bands skip), sparse (one band dirty) and fully dirty.
    // The clean case measures the pure composite-from-cache floor.
    println!();
    header("snapshot scatter-gather: dirty-band protocol (4 shards, QVGA)");
    // Three steady states: an idle stream re-snapshotting the same
    // instant (every band skipped — the pure composite-from-cache
    // floor), a sparse stream confined to one band (3 of 4 bands
    // skipped every frame), and a stream dirtying every band (the
    // no-skip baseline).
    let band_h = res.height / 4;
    for (label, dirty_bands) in
        [("clean (0 bands dirty)", 0u16), ("sparse (1 band dirty)", 1), ("all 4 bands dirty", 4)]
    {
        let mut router = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        let mut out = Grid::new(1, 1, 0.0f64);
        if dirty_bands == 0 {
            router.route_batch(&events); // live content everywhere
        }
        router.frame_into(&mut out, 30_000); // warm caches
        let mut t = 30_000u64;
        let mut k = 0u64;
        let r = bench(&format!("snapshot {label}"), 1.0, 100, 500, || {
            t += 1_000;
            for b in 0..dirty_bands {
                router.route(Event::new(t, (k % res.width as u64) as u16, b * band_h,
                                        Polarity::On));
                k += 1;
            }
            router.frame_into(&mut out, if dirty_bands == 0 { 30_000 } else { t });
            std::hint::black_box(out.as_slice());
        });
        let fps = r.throughput_per_sec();
        println!("{}  [{fps:.1} frames/s, {} band renders skipped]",
                 r.report(), router.bands_skipped_unchanged());
        entries.push(JsonEntry::with(r, "frames_per_sec", fps));
        router.shutdown();
    }

    // End-to-end pipeline (incl. frame scheduling) on a noise workload,
    // consumed as a stream (no slice copy anywhere in the pipeline).
    println!();
    let stream = ba_noise(res, 10.0, 0.2, 5);
    let r = bench("pipeline 0.2s @10Hz/px noise", stream.len() as f64, 200, 1_000, || {
        std::hint::black_box(run_pipeline(
            stream.iter().copied(),
            res,
            200_000,
            &PipelineConfig::default(),
        ));
    });
    println!("{}", r.report());
    entries.push(JsonEntry::plain(r));

    dump_json(&entries, "BENCH_router.json");
}
