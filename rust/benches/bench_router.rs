//! Coordinator benchmarks: sharded-router throughput vs shard count and
//! batch size, plus end-to-end pipeline events/s (the paper's "throughput
//! limited by data transmission" argument, Sec. III-B, measured on the
//! software twin).

use tsisc::coordinator::{run_pipeline, PipelineConfig, Router, RouterConfig};
use tsisc::events::{noise::ba_noise, Event, Polarity, Resolution};
use tsisc::util::bench::{bench, header};
use tsisc::util::rng::Pcg64;

fn main() {
    header("bench_router — event routing and pipeline throughput");
    let res = Resolution::QVGA;
    let mut rng = Pcg64::new(3);
    let n = 20_000usize;
    let events: Vec<Event> = (0..n)
        .map(|k| {
            Event::new(
                1 + k as u64,
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                Polarity::On,
            )
        })
        .collect();

    // Single-event route() (staged internally) vs explicit route_batch().
    for shards in [1usize, 2, 4, 8] {
        let mut router = Router::new(
            res,
            RouterConfig { n_shards: shards, ..RouterConfig::default() },
        );
        let r = bench(&format!("route 20k events, {shards} shards"), n as f64, 100, 600, || {
            for e in &events {
                router.route(*e);
            }
        });
        println!("{}", r.report());
        router.shutdown();
    }

    println!();
    for &bs in &[1usize, 64, 4_096] {
        let mut router = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        let r = bench(&format!("route_batch 20k events, 4 shards, bs={bs}"), n as f64, 100, 600,
                      || {
            for chunk in events.chunks(bs) {
                router.route_batch(chunk);
            }
        });
        println!("{}", r.report());
        router.shutdown();
    }

    // End-to-end pipeline (incl. frame scheduling) on a noise workload,
    // consumed as a stream (no slice copy anywhere in the pipeline).
    println!();
    let stream = ba_noise(res, 10.0, 0.2, 5);
    let r = bench("pipeline 0.2s @10Hz/px noise", stream.len() as f64, 200, 1_000, || {
        std::hint::black_box(run_pipeline(
            stream.iter().copied(),
            res,
            200_000,
            &PipelineConfig::default(),
        ));
    });
    println!("{}", r.report());
}
