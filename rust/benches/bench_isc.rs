//! ISC analog-array hot-path benchmarks: event write, comparator read,
//! patch query and full-frame readout (the L3 serving primitives).
//! Relates to Fig. 7/8 (per-event costs) and the §Perf targets.

use tsisc::events::{Event, Polarity, Resolution};
use tsisc::isc::{IscArray, IscConfig};
use tsisc::util::bench::{bench, header};
use tsisc::util::rng::Pcg64;

fn main() {
    header("bench_isc — analog array primitives (QVGA)");
    let res = Resolution::QVGA;
    let mut array = IscArray::new(res, IscConfig::default());
    let mut rng = Pcg64::new(1);

    // Pre-generate a batch of events.
    let n = 10_000usize;
    let events: Vec<Event> = (0..n)
        .map(|k| {
            Event::new(
                1 + k as u64 * 10,
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                Polarity::On,
            )
        })
        .collect();

    let mut t = 1u64;
    let r = bench("write 10k events", n as f64, 100, 700, || {
        for e in &events {
            let mut e2 = *e;
            e2.t = t;
            array.write(&e2);
            t += 10;
        }
    });
    println!("{}", r.report());

    let coords: Vec<(u16, u16)> = (0..n)
        .map(|_| (rng.below(res.width as u64) as u16, rng.below(res.height as u64) as u16))
        .collect();
    let r = bench("comparator read 10k cells", n as f64, 100, 700, || {
        let mut hits = 0u32;
        for &(x, y) in &coords {
            hits += array.compare(x, y, Polarity::On, t, 0.383) as u32;
        }
        std::hint::black_box(hits);
    });
    println!("{}", r.report());

    let cmp = array.comparator(0.383);
    let r = bench("compiled comparator 10k cells", n as f64, 100, 700, || {
        let mut hits = 0u32;
        for &(x, y) in &coords {
            hits += array.compare_with(&cmp, x, y, Polarity::On, t) as u32;
        }
        std::hint::black_box(hits);
    });
    println!("{}", r.report());

    let r = bench("7x7 patch read", 49.0, 100, 700, || {
        let mut s = 0.0;
        for dy in 0..7u16 {
            for dx in 0..7u16 {
                s += array.read(100 + dx, 100 + dy, Polarity::On, t);
            }
        }
        std::hint::black_box(s);
    });
    println!("{}", r.report());

    let r = bench("full QVGA frame readout", res.pixels() as f64, 100, 900, || {
        std::hint::black_box(array.frame_merged(t));
    });
    println!("{}", r.report());
}
