//! STCF throughput: decisions/s on ideal vs ISC backends — the per-event
//! hot path of the denoise application (Fig. 10 workloads).

use tsisc::denoise::{run_stcf, StcfBackend, StcfParams};
use tsisc::events::noise::contaminate;
use tsisc::events::scene::EdgeScene;
use tsisc::events::v2e::{convert, DvsParams};
use tsisc::events::Resolution;
use tsisc::isc::IscConfig;
use tsisc::util::bench::{bench, header};

fn main() {
    header("bench_denoise — STCF decision throughput");
    let res = Resolution::new(128, 96);
    let scene = EdgeScene::new(90.0, 21);
    let signal = convert(&scene, res, DvsParams::default(), 0.3);
    let events = contaminate(&signal, res, 5.0, 0.3, 17);
    println!("workload: {} events at 128x96", events.len());

    for r_patch in [1u16, 2, 3] {
        let prm = StcfParams { radius: r_patch, ..StcfParams::default() };
        let mut b = StcfBackend::ideal(res);
        let r = bench(
            &format!("ideal backend, r={r_patch}"),
            events.len() as f64,
            100,
            700,
            || {
                std::hint::black_box(run_stcf(&mut b, &events, &prm));
            },
        );
        println!("{}", r.report());
    }
    // Backend constructed once (bank build is setup, not hot path).
    let prm = StcfParams::default();
    let mut b = StcfBackend::isc(res, IscConfig::default(), prm.tau_tw_us);
    let r = bench("ISC backend (mismatched), r=3", events.len() as f64, 100, 700, || {
        std::hint::black_box(run_stcf(&mut b, &events, &prm));
    });
    println!("{}", r.report());
}
