//! STCF denoise benchmarks — the ingest half of the pipeline:
//!
//! * support-scan tier sweep: events/s for the bitmask-popcount,
//!   row-sliced and naive scans × radius {1, 2, 3} × backend activity
//!   {1, 10, 100 %} (the bitmask tier's win grows as activity falls —
//!   all-zero patch rows cost one word load);
//! * end-to-end score+ingest throughput on ideal and ISC backends;
//! * denoise-shard-count sweep: sharded STCF scoring
//!   ([`tsisc::denoise::StcfShardPool`]) events/s at 1/2/4/8 shards vs
//!   the serial reference.
//!
//! Dumps `BENCH_denoise.json` (via `util::bench::dump_json`) next to the
//! manifest; CI uploads it alongside the tsurface/router snapshots.

use tsisc::denoise::{
    run_stcf, support_count, support_count_naive, support_count_rows, ShardBackend, StcfBackend,
    StcfParams, StcfShardPool,
};
use tsisc::events::noise::contaminate;
use tsisc::events::scene::EdgeScene;
use tsisc::events::v2e::{convert, DvsParams};
use tsisc::events::{Event, LabeledEvent, Polarity, Resolution};
use tsisc::isc::IscConfig;
use tsisc::util::bench::{bench, dump_json, header, JsonEntry};

/// Populate `backend` so that ~`activity_pct` % of pixels hold a stamp
/// recent at `t_query` (the rest stay unwritten), and return the query
/// events + query time for the scan-only loops.
fn populate(
    backend: &mut StcfBackend,
    res: Resolution,
    activity_pct: usize,
    prm: &StcfParams,
) -> (Vec<Event>, u64) {
    let px = res.pixels();
    let writes = (px * activity_pct).div_ceil(100);
    let t_query = prm.tau_tw_us; // all writes land inside the window
    for k in 0..writes {
        // Low-discrepancy pixel walk: spreads activity over the sensor.
        let i = (k * 2_654_435_761) % px;
        let (x, y) = ((i % res.width as usize) as u16, (i / res.width as usize) as u16);
        let t = 1 + (k as u64 * (t_query - 2)) / writes.max(1) as u64;
        backend.ingest(&Event::new(t, x, y, Polarity::On), prm);
    }
    // Queries spread over the sensor (fixed count so events/s compare
    // across activity levels).
    let queries = (0..2_000usize)
        .map(|k| {
            let i = (k * 40_503 + 7) % px;
            let (x, y) = ((i % res.width as usize) as u16, (i / res.width as usize) as u16);
            Event::new(t_query, x, y, Polarity::On)
        })
        .collect();
    (queries, t_query)
}

fn main() {
    let mut json: Vec<JsonEntry> = Vec::new();
    let res = Resolution::new(128, 96);

    // --- Support-scan tier sweep: bitmask vs row-sliced vs naive ---------
    header("STCF support scan: bitmask vs row-sliced vs naive");
    for radius in [1u16, 2, 3] {
        for activity_pct in [1usize, 10, 100] {
            let prm = StcfParams { radius, ..StcfParams::default() };
            let mut b = StcfBackend::ideal(res);
            let (queries, _) = populate(&mut b, res, activity_pct, &prm);
            type Scan = fn(&StcfBackend, &Event, &StcfParams) -> u32;
            // `support_count` auto-dispatches to the bitmask tier here:
            // the backend's recency plane covers the default window.
            let tiers: [(&str, Scan); 3] = [
                ("bitmask", support_count),
                ("rows", support_count_rows),
                ("naive", support_count_naive),
            ];
            for (name, scan) in tiers {
                let r = bench(
                    &format!("scan {name:<7} r={radius} act={activity_pct:>3}%"),
                    queries.len() as f64,
                    40,
                    200,
                    || {
                        let mut acc = 0u32;
                        for q in &queries {
                            acc = acc.wrapping_add(scan(&b, q, &prm));
                        }
                        std::hint::black_box(acc);
                    },
                );
                println!("{}", r.report());
                let tput = r.throughput_per_sec();
                json.push(JsonEntry::with(r, "events_per_sec", tput));
            }
        }
    }

    // --- End-to-end score+ingest throughput ------------------------------
    header("STCF end-to-end score+ingest (Fig. 10 workload)");
    let scene = EdgeScene::new(90.0, 21);
    let signal = convert(&scene, res, DvsParams::default(), 0.3);
    let events = contaminate(&signal, res, 5.0, 0.3, 17);
    println!("workload: {} events at 128x96", events.len());
    let span = events.last().unwrap().ev.t + 1;
    let prm = StcfParams::default();
    {
        let mut b = StcfBackend::ideal(res);
        let r = bench("e2e ideal backend, r=3", events.len() as f64, 100, 500, || {
            std::hint::black_box(run_stcf(&mut b, &events, &prm));
        });
        println!("{}", r.report());
        let tput = r.throughput_per_sec();
        json.push(JsonEntry::with(r, "events_per_sec", tput));
    }
    {
        // Backend constructed once (bank build is setup, not hot path).
        let mut b = StcfBackend::isc(res, IscConfig::default(), prm.tau_tw_us);
        let r = bench("e2e ISC backend (mismatched), r=3", events.len() as f64, 100, 500, || {
            std::hint::black_box(run_stcf(&mut b, &events, &prm));
        });
        println!("{}", r.report());
        let tput = r.throughput_per_sec();
        json.push(JsonEntry::with(r, "events_per_sec", tput));
    }

    // --- Denoise-shard-count sweep ---------------------------------------
    header("sharded STCF scoring: events/s vs shard count");
    for shards in [1usize, 2, 4, 8] {
        let mut pool = StcfShardPool::new(res, shards, ShardBackend::Ideal, prm);
        // Each iteration replays the stream shifted forward by the span
        // so queries stay causal (at the stream head) — the shifted copy
        // costs O(n) against the O(n·patch) scoring it feeds.
        let mut offset = 0u64;
        let mut shifted: Vec<LabeledEvent> = events.clone();
        let mut scores: Vec<u32> = Vec::new();
        let r = bench(
            &format!("sharded scoring, {shards} shard(s)"),
            events.len() as f64,
            80,
            400,
            || {
                offset += span;
                for (dst, src) in shifted.iter_mut().zip(&events) {
                    *dst = *src;
                    dst.ev.t += offset;
                }
                for chunk in shifted.chunks(4_096) {
                    pool.score_batch(chunk, &mut scores);
                    std::hint::black_box(&scores);
                }
            },
        );
        println!("{}", r.report());
        let tput = r.throughput_per_sec();
        let mut entry = JsonEntry::with(r, "denoise_shards", shards as f64);
        entry.extra.push(("events_per_sec", tput));
        json.push(entry);
        pool.shutdown();
    }

    dump_json(&json, "BENCH_denoise.json");
}
