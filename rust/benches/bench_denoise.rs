//! STCF throughput: decisions/s on ideal vs ISC backends — the per-event
//! hot path of the denoise application (Fig. 10 workloads) — plus the
//! isolated support-scan microbenchmark comparing the row-sliced patch
//! walk against the naive per-(dx,dy) reference.

use tsisc::denoise::{run_stcf, support_count, support_count_naive, StcfBackend, StcfParams};
use tsisc::events::noise::contaminate;
use tsisc::events::scene::EdgeScene;
use tsisc::events::v2e::{convert, DvsParams};
use tsisc::events::Resolution;
use tsisc::isc::IscConfig;
use tsisc::util::bench::{bench, header};

fn main() {
    header("bench_denoise — STCF decision throughput");
    let res = Resolution::new(128, 96);
    let scene = EdgeScene::new(90.0, 21);
    let signal = convert(&scene, res, DvsParams::default(), 0.3);
    let events = contaminate(&signal, res, 5.0, 0.3, 17);
    println!("workload: {} events at 128x96", events.len());

    for r_patch in [1u16, 2, 3] {
        let prm = StcfParams { radius: r_patch, ..StcfParams::default() };
        let mut b = StcfBackend::ideal(res);
        let r = bench(
            &format!("ideal backend, r={r_patch}"),
            events.len() as f64,
            100,
            700,
            || {
                std::hint::black_box(run_stcf(&mut b, &events, &prm));
            },
        );
        println!("{}", r.report());
    }
    // Backend constructed once (bank build is setup, not hot path).
    let prm = StcfParams::default();
    let mut b = StcfBackend::isc(res, IscConfig::default(), prm.tau_tw_us);
    let r = bench("ISC backend (mismatched), r=3", events.len() as f64, 100, 700, || {
        std::hint::black_box(run_stcf(&mut b, &events, &prm));
    });
    println!("{}", r.report());

    // --- Support-scan microbenchmark: row-sliced vs naive ----------------
    // Pre-populated backends, scan-only (no ingestion in the loop), so
    // the patch-walk cost is isolated.
    header("STCF support scan: row-sliced vs naive reference");
    let queries: Vec<_> = events.iter().step_by(7).map(|le| le.ev).collect();
    let t_scan = events.last().unwrap().ev.t;
    for r_patch in [1u16, 3] {
        let prm = StcfParams { radius: r_patch, ..StcfParams::default() };
        let mut ideal = StcfBackend::ideal(res);
        let mut isc = StcfBackend::isc(res, IscConfig::default(), prm.tau_tw_us);
        for le in &events {
            ideal.ingest(&le.ev, &prm);
            isc.ingest(&le.ev, &prm);
        }
        for (name, backend) in [("ideal", &ideal), ("ISC", &isc)] {
            let rr = bench(
                &format!("support scan row-sliced {name} r={r_patch}"),
                queries.len() as f64,
                80,
                400,
                || {
                    for q in &queries {
                        let mut e = *q;
                        e.t = t_scan;
                        std::hint::black_box(support_count(backend, &e, &prm));
                    }
                },
            );
            println!("{}", rr.report());
            let rn = bench(
                &format!("support scan naive      {name} r={r_patch}"),
                queries.len() as f64,
                80,
                400,
                || {
                    for q in &queries {
                        let mut e = *q;
                        e.t = t_scan;
                        std::hint::black_box(support_count_naive(backend, &e, &prm));
                    }
                },
            );
            println!("{}", rn.report());
        }
    }
}
