//! Multi-tenant serve-layer benchmarks: the sessions × worker-pool-size
//! sweep (1/4/16 sessions × 2/4/8 workers) measuring **aggregate ingest
//! throughput** (events/s across the whole fleet) and **snapshot p99**
//! (on-demand frame latency under concurrent session load), plus one
//! denoised-fleet configuration.
//!
//! Dumps `BENCH_serve.json` (via `util::bench::dump_json`) next to the
//! manifest; CI uploads it alongside the tsurface/router/denoise
//! snapshots.

use std::time::Instant;
use tsisc::coordinator::{PipelineConfig, RouterConfig};
use tsisc::denoise::StcfParams;
use tsisc::events::scene::EdgeScene;
use tsisc::events::v2e::{convert, DvsParams};
use tsisc::events::{LabeledEvent, Resolution};
use tsisc::isc::IscConfig;
use tsisc::serve::{ServeConfig, SessionConfig, SessionManager};
use tsisc::util::bench::{bench, dump_json, header, JsonEntry};
use tsisc::util::stats::percentile;

/// One fleet configuration measured end to end.
#[allow(clippy::too_many_arguments)]
fn bench_fleet(
    json: &mut Vec<JsonEntry>,
    base: &[LabeledEvent],
    span: u64,
    res: Resolution,
    sessions: usize,
    workers: usize,
    stcf: Option<StcfParams>,
    label: &str,
) {
    let mut m = SessionManager::new(ServeConfig {
        workers,
        max_sessions: sessions,
        max_inflight_batches: 1 << 20, // throughput run: never reject
    });
    let sids: Vec<_> = (0..sessions)
        .map(|k| {
            m.open(SessionConfig {
                name: format!("bench-{k}"),
                res,
                // No window clock: frames are taken explicitly below so
                // the snapshot latency is measured, not amortized.
                t_end_us: 0,
                pipeline: PipelineConfig {
                    stcf,
                    denoise_shards: if stcf.is_some() { 2 } else { 0 },
                    router: RouterConfig {
                        isc: IscConfig { bank_size: 64, ..IscConfig::default() },
                        ..RouterConfig::default()
                    },
                    ..PipelineConfig::default()
                },
            })
            .expect("open bench session")
        })
        .collect();
    let mut offset = 0u64;
    let mut shifted: Vec<LabeledEvent> = base.to_vec();
    let mut snap_lat: Vec<f64> = Vec::new();
    let r = bench(label, (base.len() * sessions) as f64, 60, 300, || {
        // Causal replay: every iteration shifts the stream past the
        // previous snapshot time.
        offset += span;
        for (dst, src) in shifted.iter_mut().zip(base) {
            *dst = *src;
            dst.ev.t += offset;
        }
        // Interleave chunks across every session — the fleet serves all
        // cameras at once, not one after another.
        for chunk in shifted.chunks(2_048) {
            for sid in &sids {
                m.ingest_batch(*sid, chunk).expect("ingest");
            }
        }
        for sid in &sids {
            let t0 = Instant::now();
            std::hint::black_box(m.snapshot(*sid, offset + span).expect("snapshot"));
            snap_lat.push(t0.elapsed().as_secs_f64());
        }
    });
    println!("{}", r.report());
    let p99_ms = percentile(&snap_lat, 99.0) * 1e3;
    println!("    snapshot p99 {p99_ms:.3} ms over {} frames", snap_lat.len());
    let tput = r.throughput_per_sec();
    let mut entry = JsonEntry::with(r, "sessions", sessions as f64);
    entry.extra.push(("workers", workers as f64));
    entry.extra.push(("events_per_sec", tput));
    entry.extra.push(("snapshot_p99_ms", p99_ms));
    json.push(entry);
    m.shutdown();
}

fn main() {
    let mut json: Vec<JsonEntry> = Vec::new();
    let res = Resolution::new(64, 64);
    let scene = EdgeScene::new(90.0, 21);
    let base = convert(&scene, res, DvsParams::default(), 0.2);
    let span = base.last().expect("non-empty stream").ev.t + 1;
    println!("workload: {} events/session at 64x64", base.len());

    // --- sessions × workers sweep (raw ingest + snapshot) ----------------
    header("serve fleet: aggregate events/s and snapshot p99");
    for &sessions in &[1usize, 4, 16] {
        for &workers in &[2usize, 4, 8] {
            let label = format!("serve {sessions:>2} sessions x {workers} workers");
            bench_fleet(&mut json, &base, span, res, sessions, workers, None, &label);
        }
    }

    // --- denoised fleet ---------------------------------------------------
    header("serve fleet with sharded STCF");
    bench_fleet(
        &mut json,
        &base,
        span,
        res,
        4,
        4,
        Some(StcfParams::default()),
        "serve  4 sessions x 4 workers + stcf",
    );

    dump_json(&json, "BENCH_serve.json");
}
