//! Multi-tenant serve-layer benchmarks: the sessions × worker-pool-size
//! sweep (1/4/16 sessions × 2/4/8 workers) measuring **aggregate ingest
//! throughput** (events/s across the whole fleet) and **snapshot p99**
//! (on-demand frame latency under concurrent session load), plus one
//! denoised-fleet configuration and the **idle-fleet memory sweep**
//! (256 sessions at 1 %/10 %/100 % duty cycle) reporting
//! `resident_bytes_per_session` — the number that proves quiet
//! sessions cost O(bands) structs under lazy band materialization, not
//! O(H·W) arrays — and the **chaos sweep** (0 %/1 %/10 % of sessions
//! armed with seeded job-panic plans) reporting
//! `clean_session_p99_under_faults_us`, the bystander latency price of
//! panic isolation.
//!
//! Dumps `BENCH_serve.json` (via `util::bench::dump_json`) next to the
//! manifest; CI uploads it alongside the tsurface/router/denoise
//! snapshots and hard-fails if the idle-fleet, chaos, or per-stage
//! telemetry keys (`stage_{decode,score,route,render}_p99_us`,
//! `queue_wait_p99_us` — read off the fleet's observability plane)
//! are missing. Two runs diff with `cargo xtask bench-compare`.

use std::time::{Duration, Instant};
use tsisc::coordinator::{PipelineConfig, RouterConfig};
use tsisc::denoise::StcfParams;
use tsisc::events::scene::EdgeScene;
use tsisc::events::v2e::{convert, DvsParams};
use tsisc::events::{Event, LabeledEvent, Resolution};
use tsisc::isc::IscConfig;
use tsisc::serve::net::{ClientConfig, Hello, NetClient, NetConfig, NetServer};
use tsisc::serve::{SchedFaultKind, SchedFaultPlan, ServeConfig, SessionConfig, SessionManager};
use tsisc::util::bench::{bench, dump_json, header, JsonEntry};
use tsisc::util::stats::percentile;

/// One fleet configuration measured end to end.
#[allow(clippy::too_many_arguments)]
fn bench_fleet(
    json: &mut Vec<JsonEntry>,
    base: &[LabeledEvent],
    span: u64,
    res: Resolution,
    sessions: usize,
    workers: usize,
    stcf: Option<StcfParams>,
    label: &str,
) {
    let mut m = SessionManager::new(ServeConfig {
        workers,
        max_sessions: sessions,
        max_inflight_batches: 1 << 20, // throughput run: never reject
        ..ServeConfig::default()
    });
    let sids: Vec<_> = (0..sessions)
        .map(|k| {
            m.open(SessionConfig {
                name: format!("bench-{k}"),
                res,
                // No window clock: frames are taken explicitly below so
                // the snapshot latency is measured, not amortized.
                t_end_us: 0,
                pipeline: PipelineConfig {
                    stcf,
                    denoise_shards: if stcf.is_some() { 2 } else { 0 },
                    router: RouterConfig {
                        isc: IscConfig { bank_size: 64, ..IscConfig::default() },
                        ..RouterConfig::default()
                    },
                    ..PipelineConfig::default()
                },
            })
            .expect("open bench session")
        })
        .collect();
    let mut offset = 0u64;
    let mut shifted: Vec<LabeledEvent> = base.to_vec();
    let mut snap_lat: Vec<f64> = Vec::new();
    let r = bench(label, (base.len() * sessions) as f64, 60, 300, || {
        // Causal replay: every iteration shifts the stream past the
        // previous snapshot time.
        offset += span;
        for (dst, src) in shifted.iter_mut().zip(base) {
            *dst = *src;
            dst.ev.t += offset;
        }
        // Interleave chunks across every session — the fleet serves all
        // cameras at once, not one after another.
        for chunk in shifted.chunks(2_048) {
            for sid in &sids {
                m.ingest_batch(*sid, chunk).expect("ingest");
            }
        }
        for sid in &sids {
            let t0 = Instant::now();
            std::hint::black_box(m.snapshot(*sid, offset + span).expect("snapshot"));
            snap_lat.push(t0.elapsed().as_secs_f64());
        }
    });
    println!("{}", r.report());
    let p99_us = percentile(&snap_lat, 99.0) * 1e6;
    println!("    snapshot p99 {p99_us:.1} µs over {} frames", snap_lat.len());
    let tput = r.throughput_per_sec();
    let mut entry = JsonEntry::with(r, "sessions", sessions as f64);
    entry.extra.push(("workers", workers as f64));
    entry.extra.push(("events_per_sec", tput));
    entry.extra.push(("snapshot_p99_us", p99_us));
    // Per-stage p99s from the fleet's telemetry plane (bucket-upper
    // resolution; zeros under `telemetry-off`, but the keys — which CI
    // hard-requires — stay present).
    let obs = m.obs();
    entry.extra.push(("queue_wait_p99_us", obs.queue_wait.percentile(99.0) as f64));
    entry.extra.push(("stage_score_p99_us", obs.stage_score.percentile(99.0) as f64));
    entry.extra.push(("stage_route_p99_us", obs.stage_route.percentile(99.0) as f64));
    entry.extra.push(("stage_render_p99_us", obs.stage_render.percentile(99.0) as f64));
    json.push(entry);
    m.shutdown();
}

/// Idle-fleet memory sweep: open `sessions` sessions at a *large*
/// sensor resolution, drive only a `duty_pct` fraction of them with the
/// (64×64-bounded) workload, and report per-session resident bytes
/// alongside fleet throughput. Quiet sessions never materialize a band
/// array, so their footprint is the per-band `BandWriter` struct —
/// independent of the 640×480 session resolution (O(m+n), not O(H·W)).
fn bench_idle_fleet(
    json: &mut Vec<JsonEntry>,
    base: &[LabeledEvent],
    span: u64,
    sessions: usize,
    duty_pct: usize,
) {
    let res = Resolution::new(640, 480); // events land in the 64×64 corner
    let active = (sessions * duty_pct / 100).max(1);
    let mut m = SessionManager::new(ServeConfig {
        workers: 4,
        max_sessions: sessions,
        max_inflight_batches: 1 << 20, // throughput run: never reject
        ..ServeConfig::default()
    });
    let sids: Vec<_> = (0..sessions)
        .map(|k| {
            m.open(SessionConfig {
                name: format!("idle-{k}"),
                res,
                t_end_us: 0,
                pipeline: PipelineConfig {
                    stcf: None,
                    denoise_shards: 0,
                    router: RouterConfig {
                        isc: IscConfig { bank_size: 64, ..IscConfig::default() },
                        ..RouterConfig::default()
                    },
                    ..PipelineConfig::default()
                },
            })
            .expect("open idle session")
        })
        .collect();
    let mut offset = 0u64;
    let mut shifted: Vec<LabeledEvent> = base.to_vec();
    let label = format!("idle fleet {sessions} sessions @ {duty_pct:>3}% duty");
    let r = bench(&label, (base.len() * active) as f64, 30, 150, || {
        offset += span;
        for (dst, src) in shifted.iter_mut().zip(base) {
            *dst = *src;
            dst.ev.t += offset;
        }
        for chunk in shifted.chunks(2_048) {
            for sid in &sids[..active] {
                m.ingest_batch(*sid, chunk).expect("ingest");
            }
        }
        // Snapshots drain the queued writes, so the resident gauges are
        // settled when we read them below.
        for sid in &sids[..active] {
            std::hint::black_box(m.snapshot(*sid, offset + span).expect("snapshot"));
        }
    });
    println!("{}", r.report());
    let fleet = m.stats();
    let per_session = fleet.resident_bytes as f64 / sessions as f64;
    let quiet_bytes: usize = fleet
        .sessions
        .iter()
        .filter(|s| sids[active..].iter().any(|sid| sid.raw() == s.id))
        .map(|s| s.resident_bytes)
        .sum();
    let quiet_n = sessions - active;
    let per_quiet =
        if quiet_n > 0 { quiet_bytes as f64 / quiet_n as f64 } else { per_session };
    println!(
        "    resident: {:.1} KiB/session mean, {:.1} KiB per quiet session \
         ({active} of {sessions} sessions active)",
        per_session / 1024.0,
        per_quiet / 1024.0,
    );
    let tput = r.throughput_per_sec();
    let mut entry = JsonEntry::with(r, "sessions", sessions as f64);
    entry.extra.push(("duty_pct", duty_pct as f64));
    entry.extra.push(("events_per_sec", tput));
    entry.extra.push(("resident_bytes_per_session", per_session));
    entry.extra.push(("resident_bytes_per_quiet_session", per_quiet));
    json.push(entry);
    m.shutdown();
}

/// Chaos sweep: `faulty_pct`% of a 100-session fleet carries an armed
/// `JobPanic` plan (seeded, fires once, quarantines that session); the
/// metric is the **clean** sessions' snapshot p99 — the latency price
/// bystanders pay for sharing a fleet with crashing tenants. Panic
/// isolation at the job-body boundary means the price should be noise:
/// no worker dies, no queue wedges, quarantined sessions go quiet.
fn bench_chaos_fleet(
    json: &mut Vec<JsonEntry>,
    base: &[LabeledEvent],
    span: u64,
    res: Resolution,
    faulty_pct: usize,
) {
    let sessions = 100usize;
    let n_faulty = sessions * faulty_pct / 100;
    let mut m = SessionManager::new(ServeConfig {
        workers: 4,
        max_sessions: sessions,
        max_inflight_batches: 1 << 20, // throughput run: never reject
        ..ServeConfig::default()
    });
    let session_cfg = |k: usize| SessionConfig {
        name: format!("chaos-{k}"),
        res,
        t_end_us: 0, // no window clock: snapshots are timed explicitly
        pipeline: PipelineConfig {
            stcf: None,
            denoise_shards: 0,
            router: RouterConfig {
                isc: IscConfig { bank_size: 64, ..IscConfig::default() },
                ..RouterConfig::default()
            },
            ..PipelineConfig::default()
        },
    };
    let mut clean_sids = Vec::new();
    let mut faulty_sids = Vec::new();
    for k in 0..sessions {
        if k < n_faulty {
            let plan = SchedFaultPlan::from_seed(SchedFaultKind::JobPanic, 0xC4A0_5EED ^ k as u64);
            faulty_sids.push(m.open_with_fault(session_cfg(k), Some(plan)).expect("open faulty"));
        } else {
            clean_sids.push(m.open(session_cfg(k)).expect("open clean"));
        }
    }
    let mut offset = 0u64;
    let mut shifted: Vec<LabeledEvent> = base.to_vec();
    let mut snap_lat: Vec<f64> = Vec::new();
    let label = format!("chaos fleet {sessions} sessions @ {faulty_pct:>2}% faulty");
    let r = bench(&label, (base.len() * clean_sids.len()) as f64, 20, 100, || {
        offset += span;
        for (dst, src) in shifted.iter_mut().zip(base) {
            *dst = *src;
            dst.ev.t += offset;
        }
        for chunk in shifted.chunks(2_048) {
            for sid in &clean_sids {
                m.ingest_batch(*sid, chunk).expect("clean ingest never rejected");
            }
            // Faulty sessions keep sending until quarantine silences
            // them — the rejection path is part of the measured load.
            for sid in &faulty_sids {
                let _ = m.ingest_batch(*sid, chunk);
            }
        }
        for sid in &clean_sids {
            let t0 = Instant::now();
            std::hint::black_box(m.snapshot(*sid, offset + span).expect("clean snapshot"));
            snap_lat.push(t0.elapsed().as_secs_f64());
        }
    });
    println!("{}", r.report());
    let p99_us = percentile(&snap_lat, 99.0) * 1e6;
    // Sync point: a checkpoint rides every band FIFO behind the armed
    // jobs, so once it returns, every injected panic has fired and been
    // counted (quarantined bands just export nothing).
    for sid in &faulty_sids {
        let _ = m.checkpoint(*sid);
    }
    let sup = m.stats().supervisor;
    println!(
        "    clean snapshot p99 {p99_us:.1} µs with {} quarantined / {} panics caught / \
         {} respawns",
        sup.quarantines, sup.worker_panics, sup.worker_respawns,
    );
    assert_eq!(sup.quarantines, n_faulty as u64, "every armed plan quarantines its session");
    assert_eq!(sup.worker_respawns, 0, "caught panics must not kill workers");
    let tput = r.throughput_per_sec();
    let mut entry = JsonEntry::with(r, "sessions", sessions as f64);
    entry.extra.push(("faulty_pct", faulty_pct as f64));
    entry.extra.push(("events_per_sec", tput));
    entry.extra.push(("clean_session_p99_under_faults_us", p99_us));
    json.push(entry);
    m.shutdown();
}

/// Wire mode: the same workload shipped over loopback TCP through the
/// `serve::net` front door — AER-encoded BATCH frames in, a timed
/// SNAPSHOT_REQ round trip out. `wire_to_snapshot_p99_us` is the p99 of
/// request-to-frame latency *including* framing, CRC, socket hops and
/// the session flush — the end-to-end number a real camera client sees.
fn bench_wire(json: &mut Vec<JsonEntry>, base: &[LabeledEvent], span: u64, res: Resolution) {
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetConfig {
            serve: ServeConfig {
                workers: 4,
                max_sessions: 4,
                max_inflight_batches: 1 << 20,
                ..ServeConfig::default()
            },
            read_timeout: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(5),
            error_budget: 8,
            max_connections: 8,
            max_frame_bytes: 64 << 20,
            retry_after_ms: 1,
        },
    )
    .expect("bind loopback bench server");
    let mut client = NetClient::connect(server.local_addr(), ClientConfig::default())
        .expect("connect bench client");
    client
        .hello(&Hello {
            name: "bench-wire".into(),
            width: res.width,
            height: res.height,
            t_end_us: 0, // no window clock: snapshots are timed explicitly
            window_us: 50_000,
            batch_size: 4_096,
            n_shards: 4,
            denoise_shards: 0,
            stcf: false,
        })
        .expect("bench HELLO admitted");

    let evs_base: Vec<Event> = base.iter().map(|l| l.ev).collect();
    let mut shifted = evs_base.clone();
    let mut offset = 0u64;
    let mut snap_lat: Vec<f64> = Vec::new();
    let r = bench("serve wire: 1 camera over loopback TCP", base.len() as f64, 20, 100, || {
        offset += span;
        for (dst, src) in shifted.iter_mut().zip(&evs_base) {
            *dst = *src;
            dst.t += offset;
        }
        for chunk in shifted.chunks(2_048) {
            client.send_batch(chunk).expect("bench batch acked");
        }
        let t0 = Instant::now();
        std::hint::black_box(client.snapshot(offset + span).expect("bench snapshot"));
        snap_lat.push(t0.elapsed().as_secs_f64());
    });
    println!("{}", r.report());
    let p99_us = percentile(&snap_lat, 99.0) * 1e6;
    println!("    wire→snapshot p99 {p99_us:.1} µs over {} round trips", snap_lat.len());
    let tput = r.throughput_per_sec();
    let mut entry = JsonEntry::with(r, "sessions", 1.0);
    entry.extra.push(("wire", 1.0));
    entry.extra.push(("events_per_sec", tput));
    entry.extra.push(("wire_to_snapshot_p99_us", p99_us));
    // The decode stage only exists on the wire path (AER frames off the
    // socket), so its p99 is exported here rather than in bench_fleet.
    let obs = server.obs();
    entry.extra.push(("stage_decode_p99_us", obs.stage_decode.percentile(99.0) as f64));
    json.push(entry);

    client.bye().expect("bench BYE");
    let stats = server.shutdown();
    assert_eq!(
        stats.net.drain_accounting_mismatches, 0,
        "bench stream lost acked events: {:?}",
        stats.net
    );
}

fn main() {
    let mut json: Vec<JsonEntry> = Vec::new();
    let res = Resolution::new(64, 64);
    let scene = EdgeScene::new(90.0, 21);
    let base = convert(&scene, res, DvsParams::default(), 0.2);
    let span = base.last().expect("non-empty stream").ev.t + 1;
    println!("workload: {} events/session at 64x64", base.len());

    // --- sessions × workers sweep (raw ingest + snapshot) ----------------
    header("serve fleet: aggregate events/s and snapshot p99");
    for &sessions in &[1usize, 4, 16] {
        for &workers in &[2usize, 4, 8] {
            let label = format!("serve {sessions:>2} sessions x {workers} workers");
            bench_fleet(&mut json, &base, span, res, sessions, workers, None, &label);
        }
    }

    // --- denoised fleet ---------------------------------------------------
    header("serve fleet with sharded STCF");
    bench_fleet(
        &mut json,
        &base,
        span,
        res,
        4,
        4,
        Some(StcfParams::default()),
        "serve  4 sessions x 4 workers + stcf",
    );

    // --- idle-fleet memory sweep (lazy band materialization) --------------
    header("idle fleet: resident bytes per session vs duty cycle");
    for &duty in &[1usize, 10, 100] {
        bench_idle_fleet(&mut json, &base, span, 256, duty);
    }

    // --- chaos sweep (panic isolation overhead on bystanders) -------------
    header("serve fleet under chaos: clean-session p99 vs faulty share");
    for &faulty_pct in &[0usize, 1, 10] {
        bench_chaos_fleet(&mut json, &base, span, res, faulty_pct);
    }

    // --- wire mode (TCP front door, end-to-end) ---------------------------
    header("serve wire: loopback TCP ingest + snapshot round trip");
    bench_wire(&mut json, &base, span, res);

    dump_json(&json, "BENCH_serve.json");
}
