//! PJRT artifact execution latency: the L1/L2 kernels and the train step
//! as seen from the Rust hot path. Skips when artifacts are absent, and
//! reduces to a skip stub when built without the `pjrt` feature.

#[cfg(feature = "pjrt")]
use tsisc::events::{Event, Polarity};
#[cfg(feature = "pjrt")]
use tsisc::runtime::{artifacts_available, default_artifact_dir, KernelTs, Runtime};
#[cfg(feature = "pjrt")]
use tsisc::util::bench::{bench, header};

#[cfg(not(feature = "pjrt"))]
fn main() {
    println!("bench_runtime — SKIP: built without the `pjrt` feature");
}

#[cfg(feature = "pjrt")]
fn main() {
    header("bench_runtime — AOT artifact execution (PJRT CPU)");
    if !artifacts_available() {
        println!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(default_artifact_dir()).expect("runtime");
    let mut plane = KernelTs::new(20e-15, None, 1);
    plane.write(&Event::new(1, 10, 10, Polarity::On)).unwrap();
    let mut t = 1u64;
    plane.advance(&mut rt, t).unwrap();

    let r = bench("ts_update microbatch (QVGA)", 240.0 * 320.0, 300, 1_500, || {
        t += 1_000;
        plane.advance(&mut rt, t).unwrap();
    });
    println!("{}", r.report());

    let r = bench("ts_frame readout (QVGA)", 240.0 * 320.0, 300, 1_500, || {
        std::hint::black_box(plane.frame(&mut rt).unwrap());
    });
    println!("{}", r.report());

    let r = bench("stcf_count r=3 (QVGA)", 240.0 * 320.0, 300, 1_500, || {
        std::hint::black_box(plane.stcf_counts(&mut rt, 0.383).unwrap());
    });
    println!("{}", r.report());

    // Train step latency (B=64) — the e2e driver's inner loop.
    use tsisc::train::driver::{train_classifier, TrainConfig, BATCH, SIDE};
    use tsisc::train::frames::{Frame, FrameSet};
    let frames: Vec<Frame> = (0..BATCH)
        .map(|i| Frame { pixels: vec![0.1; SIDE * SIDE], label: i % 10, sample_id: i })
        .collect();
    let set = FrameSet { frames, n_classes: 10, n_samples: BATCH };
    let r = bench("classifier_train step (B=64)", BATCH as f64, 500, 3_000, || {
        let cfg = TrainConfig { steps: 1, lr: 0.01, seed: 1, log_every: 0 };
        std::hint::black_box(train_classifier(&mut rt, &set, &set, &cfg).unwrap());
    });
    println!("{}", r.report());
}
