//! Equivalence contracts of the activity-aware, transcendental-free
//! readout (PR 2) and the row-parallel / dirty-band readout (PR 3):
//!
//! * active-set `frame_into` ≡ dense `frame_dense_into` bit-for-bit on
//!   random event streams for `Sae`, `IdealTs` and `IscArray` (both
//!   polarity modes), including interleaved write/read, streams long
//!   enough to trigger the lazy active-list pruning, queries before any
//!   write (`t_us < t_write`) and never-written arrays;
//! * chunked (scoped-thread) rendering ≡ the single-thread render
//!   bit-for-bit for 1/2/8 chunks, including more chunks than rows and
//!   the α dense-fallback regime;
//! * the router's dirty-band composited snapshots ≡ a full re-render by
//!   a fresh identically-configured router, across random
//!   write/snapshot/write interleavings at causal query times;
//! * the row-sliced STCF support scan ≡ the naive (2r+1)² reference on
//!   both backends across radii, polarity modes and border events;
//! * the shared quantized decay LUT stays within the documented 50 µs
//!   quantization bound of the exact exponential.

use tsisc::coordinator::{Router, RouterConfig};
use tsisc::denoise::{support_count, support_count_naive, StcfBackend, StcfParams};
use tsisc::events::{Event, Polarity, Resolution};
use tsisc::isc::{IscArray, IscConfig};
use tsisc::tsurface::{EventSink, FrameSource, IdealTs, Sae};
use tsisc::util::check::{check, Gen};
use tsisc::util::decay::DecayLut;
use tsisc::util::grid::Grid;

/// Time-sorted random stream; `max_step_us` controls the total span (big
/// steps push pixels past the memory horizon and force pruning).
fn stream(g: &mut Gen, res: Resolution, n: usize, max_step_us: u64) -> Vec<Event> {
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            t += g.u64(1, max_step_us);
            Event::new(
                t,
                g.u64(0, res.width as u64 - 1) as u16,
                g.u64(0, res.height as u64 - 1) as u16,
                if g.bool(0.5) { Polarity::On } else { Polarity::Off },
            )
        })
        .collect()
}

fn assert_frames_equal(active: &Grid<f64>, dense: &Grid<f64>, ctx: &str) {
    assert_eq!(active, dense, "{ctx}: active-set readout != dense readout");
}

#[test]
fn isc_active_frame_equals_dense_on_random_streams() {
    check("isc active ≡ dense", 12, |g| {
        let res = Resolution::new(32, 24);
        let polarity_sensitive = g.bool(0.5);
        let mut a = IscArray::new(
            res,
            IscConfig {
                polarity_sensitive,
                seed: g.u64(0, u64::MAX / 2),
                bank_size: 64,
                ..IscConfig::default()
            },
        );
        // Interleave ingestion and readout; spans several horizons so the
        // write-path pruning fires mid-stream.
        let evs = stream(g, res, 3_000, 500);
        let mut active = Grid::new(1, 1, 0.0);
        let mut dense = Grid::new(1, 1, 0.0);
        for chunk in evs.chunks(611) {
            a.write_batch(chunk);
            let t = chunk.last().unwrap().t + g.u64(0, 30_000);
            a.frame_merged_into(&mut active, t);
            a.frame_merged_dense_into(&mut dense, t);
            assert_frames_equal(&active, &dense, "merged");
            a.frame_into(Polarity::On, &mut active, t);
            a.frame_dense_into(Polarity::On, &mut dense, t);
            assert_frames_equal(&active, &dense, "on-plane");
        }
        // Far past the horizon everything reads zero in both paths.
        let t_far = evs.last().unwrap().t + a.memory_horizon_us() + 1;
        a.frame_merged_into(&mut active, t_far);
        a.frame_merged_dense_into(&mut dense, t_far);
        assert_frames_equal(&active, &dense, "past-horizon");
        assert!(active.as_slice().iter().all(|&v| v == 0.0));
    });
}

#[test]
fn ideal_ts_and_sae_active_frame_equals_dense() {
    check("ideal-ts/sae active ≡ dense", 20, |g| {
        let res = Resolution::new(24, 18);
        let tau = g.f64(2_000.0, 60_000.0);
        let mut ts = IdealTs::new(res, tau);
        let mut sae = Sae::new(res);
        let evs = stream(g, res, 800, 700);
        let mut active = Grid::new(1, 1, 0.0);
        let mut dense = Grid::new(1, 1, 0.0);
        for chunk in evs.chunks(173) {
            ts.ingest_batch(chunk);
            sae.ingest_batch(chunk);
            let t = chunk.last().unwrap().t + g.u64(0, 100_000);
            ts.frame_into(&mut active, t);
            ts.frame_dense_into(&mut dense, t);
            assert_frames_equal(&active, &dense, "ideal-ts");
            sae.frame_into(&mut active, t);
            sae.frame_dense_into(&mut dense, t);
            assert_frames_equal(&active, &dense, "sae");
        }
    });
}

#[test]
fn chunked_readout_bit_for_bit_identical_across_chunk_counts() {
    // Row-parallel rendering must be a pure decomposition: for every
    // chunk count (1 / 2 / 8, and more chunks than rows) the frame is
    // bit-for-bit the single-thread frame, across activity levels
    // (sparse through the α dense-fallback regime) and polarity modes.
    check("parallel ≡ serial", 10, |g| {
        let h = g.usize(3, 20) as u16; // sometimes fewer rows than chunks
        let res = Resolution::new(28, h);
        let polarity_sensitive = g.bool(0.5);
        let mut arr = IscArray::new(
            res,
            IscConfig {
                polarity_sensitive,
                seed: g.u64(0, u64::MAX / 2),
                bank_size: 48,
                ..IscConfig::default()
            },
        );
        let mut sae = Sae::new(res);
        let mut ts = IdealTs::new(res, g.f64(3_000.0, 40_000.0));
        // Activity from a handful of pixels to full coverage.
        let n = g.usize(5, 1_500);
        let evs = stream(g, res, n, 300);
        arr.write_batch(&evs);
        sae.ingest_batch(&evs);
        ts.ingest_batch(&evs);
        let t = evs.last().unwrap().t + g.u64(0, 20_000);
        let (mut serial, mut chunked) = (Grid::new(1, 1, 0.0), Grid::new(1, 1, 0.0));
        for chunks in [2usize, 8, 100] {
            arr.frame_merged_into_chunks(&mut serial, t, 1);
            arr.frame_merged_into_chunks(&mut chunked, t, chunks);
            assert_eq!(serial, chunked, "isc merged, chunks={chunks}");
            arr.frame_into_chunks(Polarity::On, &mut serial, t, 1);
            arr.frame_into_chunks(Polarity::On, &mut chunked, t, chunks);
            assert_eq!(serial, chunked, "isc on-plane, chunks={chunks}");
            sae.frame_into_chunks(&mut serial, t, 1);
            sae.frame_into_chunks(&mut chunked, t, chunks);
            assert_eq!(serial, chunked, "sae, chunks={chunks}");
            ts.frame_into_chunks(&mut serial, t, 1);
            ts.frame_into_chunks(&mut chunked, t, chunks);
            assert_eq!(serial, chunked, "ideal-ts, chunks={chunks}");
        }
        // The chunked render also still matches the dense reference at
        // this causal query time (mode switch ⊥ chunking).
        arr.frame_merged_into_chunks(&mut chunked, t, 8);
        let mut dense = Grid::new(1, 1, 0.0);
        arr.frame_merged_dense_into(&mut dense, t);
        assert_eq!(chunked, dense, "chunked ≡ dense reference");
    });
}

#[test]
fn router_dirty_band_composite_equals_fresh_full_render() {
    // Random write / snapshot / write interleavings at causal,
    // non-decreasing query times: the incrementally-composited snapshot
    // (cached clean bands + partial dirty re-renders) must equal a full
    // render by a fresh identically-configured router replaying the
    // same prefix.
    check("router dirty-band ≡ fresh render", 4, |g| {
        let res = Resolution::new(16, 16);
        let cfg = RouterConfig {
            n_shards: g.usize(1, 5),
            queue_depth: 16,
            batch_size: g.usize(1, 64),
            isc: IscConfig {
                bank_size: 32,
                seed: g.u64(0, u64::MAX / 2),
                ..IscConfig::default()
            },
            ..RouterConfig::default()
        };
        let evs = stream(g, res, 500, 300);
        let chunk_len = g.usize(40, 160);
        let mut incremental = Router::new(res, cfg.clone());
        let mut at = 0u64;
        let mut routed = 0usize;
        for chunk in evs.chunks(chunk_len) {
            incremental.route_batch(chunk);
            routed += chunk.len();
            // Causal and non-decreasing; sometimes repeat the same time
            // to drive the dirty-row-watermark partial re-render path.
            if !g.bool(0.3) {
                at = at.max(chunk.last().unwrap().t + g.u64(0, 8_000));
            }
            at = at.max(chunk.last().unwrap().t);
            let composited = incremental.frame(at);
            let mut fresh = Router::new(res, cfg.clone());
            fresh.route_batch(&evs[..routed]);
            let full = fresh.frame(at);
            fresh.shutdown();
            assert_eq!(composited, full, "at={at} routed={routed}");
        }
        // A snapshot with no intervening writes must skip every band and
        // reproduce the previous frame exactly.
        let before = incremental.bands_skipped_unchanged();
        let again = incremental.frame(at);
        assert_eq!(
            incremental.bands_skipped_unchanged() - before,
            incremental.n_shards() as u64
        );
        let mut fresh = Router::new(res, cfg.clone());
        fresh.route_batch(&evs[..routed]);
        assert_eq!(again, fresh.frame(at));
        fresh.shutdown();
        incremental.shutdown();
    });
}

#[test]
fn query_before_any_write_reads_zero_everywhere() {
    // t_us < t_write: every cell was written after the query time, so
    // both readout paths must produce the all-zero frame.
    let res = Resolution::new(16, 12);
    let evs: Vec<Event> = (0..50u64)
        .map(|k| Event::new(10_000 + k, (k % 16) as u16, (k % 12) as u16, Polarity::On))
        .collect();

    let mut a = IscArray::new(res, IscConfig::default());
    a.write_batch(&evs);
    let mut active = Grid::new(1, 1, 0.0);
    let mut dense = Grid::new(1, 1, 0.0);
    a.frame_merged_into(&mut active, 500);
    a.frame_merged_dense_into(&mut dense, 500);
    assert_frames_equal(&active, &dense, "isc pre-write");
    assert!(active.as_slice().iter().all(|&v| v == 0.0));

    let mut ts = IdealTs::new(res, 24_000.0);
    ts.ingest_batch(&evs);
    ts.frame_into(&mut active, 500);
    ts.frame_dense_into(&mut dense, 500);
    assert_frames_equal(&active, &dense, "ideal-ts pre-write");
    assert!(active.as_slice().iter().all(|&v| v == 0.0));
}

#[test]
fn never_written_arrays_read_zero_in_both_paths() {
    let res = Resolution::new(8, 8);
    let a = IscArray::new(res, IscConfig::default());
    let ts = IdealTs::new(res, 24_000.0);
    let sae = Sae::new(res);
    let mut active = Grid::new(1, 1, 0.0);
    let mut dense = Grid::new(1, 1, 0.0);

    a.frame_merged_into(&mut active, 1_000_000);
    a.frame_merged_dense_into(&mut dense, 1_000_000);
    assert_frames_equal(&active, &dense, "isc unwritten");
    ts.frame_into(&mut active, 1_000_000);
    ts.frame_dense_into(&mut dense, 1_000_000);
    assert_frames_equal(&active, &dense, "ideal-ts unwritten");
    sae.frame_into(&mut active, 1_000_000);
    sae.frame_dense_into(&mut dense, 1_000_000);
    assert_frames_equal(&active, &dense, "sae unwritten");
    assert!(active.as_slice().iter().all(|&v| v == 0.0));
}

#[test]
fn row_sliced_stcf_equals_naive_ideal_backend() {
    check("stcf row ≡ naive (ideal)", 10, |g| {
        let res = Resolution::new(20, 16);
        let prm = StcfParams {
            radius: g.u64(1, 4) as u16,
            tau_tw_us: g.u64(500, 50_000),
            polarity_sensitive: g.bool(0.5),
            count_center: g.bool(0.5),
            ..StcfParams::default()
        };
        let mut b = StcfBackend::ideal(res);
        let mut evs = stream(g, res, 400, 600);
        // Force border coverage: corners and edge mid-points.
        let t_last = evs.last().unwrap().t;
        for (x, y) in [(0, 0), (19, 15), (0, 15), (19, 0), (10, 0), (0, 8)] {
            evs.push(Event::new(t_last + 10, x, y, Polarity::On));
        }
        for e in &evs {
            assert_eq!(
                support_count(&b, e, &prm),
                support_count_naive(&b, e, &prm),
                "r={} e={e:?}",
                prm.radius
            );
            b.ingest(e, &prm);
        }
    });
}

#[test]
fn row_sliced_stcf_equals_naive_isc_backend() {
    check("stcf row ≡ naive (isc)", 4, |g| {
        let res = Resolution::new(16, 16);
        let prm = StcfParams {
            radius: g.u64(1, 3) as u16,
            polarity_sensitive: g.bool(0.5),
            count_center: g.bool(0.5),
            ..StcfParams::default()
        };
        let cfg = IscConfig {
            polarity_sensitive: prm.polarity_sensitive,
            bank_size: 32,
            seed: g.u64(0, u64::MAX / 2),
            ..IscConfig::default()
        };
        let mut b = StcfBackend::isc(res, cfg, prm.tau_tw_us);
        let mut evs = stream(g, res, 300, 400);
        let t_last = evs.last().unwrap().t;
        for (x, y) in [(0, 0), (15, 15), (0, 15), (15, 0)] {
            evs.push(Event::new(t_last + 10, x, y, Polarity::Off));
        }
        for e in &evs {
            assert_eq!(
                support_count(&b, e, &prm),
                support_count_naive(&b, e, &prm),
                "r={} e={e:?}",
                prm.radius
            );
            b.ingest(e, &prm);
        }
    });
}

#[test]
fn shared_lut_error_within_documented_50us_bound() {
    // For e^{−Δt/τ} sampled every 50 µs, floor-binning over-reads by at
    // most step/τ (|d/dΔt| ≤ 1/τ); only the f32 table storage can
    // under-read, by ≤6e-8 relative.
    check("decay LUT 50µs bound", 30, |g| {
        let tau = g.f64(1_000.0, 100_000.0);
        let lut = DecayLut::exponential(tau);
        assert_eq!(lut.step_us(), 50, "documented quantization step");
        let bound = lut.step_us() as f64 / tau + 1e-6;
        for _ in 0..200 {
            let dt = g.u64(0, lut.horizon_us() - 1);
            let exact = (-(dt as f64) / tau).exp();
            let got = lut.eval(0, dt);
            assert!(got >= exact - 1e-6, "under-read at dt={dt}");
            assert!(got - exact <= bound, "dt={dt}: err {} > {bound}", got - exact);
        }
        // Past the horizon the LUT reads exactly 0 — the contract that
        // lets expired pixels leave the active set without changing any
        // frame.
        assert_eq!(lut.eval(0, lut.horizon_us()), 0.0);
    });
}

#[test]
fn ideal_ts_point_reads_match_frame_cells() {
    // The quantized point read and the frame path share one kernel.
    let res = Resolution::new(10, 10);
    let mut ts = IdealTs::new(res, 10_000.0);
    let evs: Vec<Event> = (0..60u64)
        .map(|k| Event::new(1 + k * 777, (k % 10) as u16, (k * 3 % 10) as u16, Polarity::On))
        .collect();
    ts.ingest_batch(&evs);
    let t = evs.last().unwrap().t + 4_321;
    let f = ts.frame(t);
    for x in 0..10u16 {
        for y in 0..10u16 {
            assert_eq!(*f.get(x as usize, y as usize), ts.value(x, y, t));
        }
    }
}
