//! Cross-representation contracts of the batch-first API redesign:
//!
//! * `ingest_batch` ≡ repeated `ingest` for every representation
//!   (frames, event counts and write accounting all identical);
//! * `frame_into` ≡ `frame` and performs zero heap allocations on a warm
//!   buffer (asserted via buffer-pointer stability);
//! * the ISC analog TS agrees with the ideal exponential TS within the
//!   paper's quantization/mismatch tolerance under the `frame_into` path.

use tsisc::events::{Event, Polarity, Resolution};
use tsisc::isc::IscConfig;
use tsisc::tsurface::*;
use tsisc::util::grid::Grid;
use tsisc::util::rng::Pcg64;

fn stream(res: Resolution, n: usize, seed: u64) -> Vec<Event> {
    let mut rng = Pcg64::new(seed);
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            t += 1 + rng.below(900);
            Event::new(
                t,
                rng.below(res.width as u64) as u16,
                rng.below(res.height as u64) as u16,
                if rng.bool(0.5) { Polarity::On } else { Polarity::Off },
            )
        })
        .collect()
}

/// Every representation under test, ×2 instances (single vs batched).
fn all_reps(res: Resolution) -> Vec<[Box<dyn Representation>; 2]> {
    fn pair(f: impl Fn() -> Box<dyn Representation>) -> [Box<dyn Representation>; 2] {
        [f(), f()]
    }
    vec![
        pair(|| Box::new(Sae::new(res))),
        pair(|| Box::new(IdealTs::new(res, 24_000.0))),
        pair(|| Box::new(QuantizedSae::new(res, 16, 24_000.0))),
        pair(|| Box::new(EventCount::new(res, 4))),
        pair(|| Box::new(Ebbi::new(res))),
        pair(|| Box::new(Sits::new(res, 3))),
        pair(|| Box::new(Tos::new(res, 3))),
        pair(|| Box::new(Tore::new(res, 3, 100.0, 1e6))),
        pair(|| Box::new(IscTs::with_defaults(res))),
    ]
}

#[test]
fn ingest_batch_equals_repeated_ingest_for_every_representation() {
    let res = Resolution::new(24, 20);
    let events = stream(res, 600, 11);
    let t_end = events.last().unwrap().t + 5_000;
    for [mut single, mut batched] in all_reps(res) {
        for e in &events {
            single.ingest(e);
        }
        // Uneven chunking exercises batch boundaries.
        for chunk in events.chunks(97) {
            batched.ingest_batch(chunk);
        }
        let name = single.name();
        assert_eq!(single.events_seen(), batched.events_seen(), "{name}: events_seen");
        assert_eq!(single.memory_writes(), batched.memory_writes(), "{name}: memory_writes");
        assert_eq!(single.frame(t_end), batched.frame(t_end), "{name}: frame mismatch");
    }
}

#[test]
fn frame_into_matches_frame_and_never_reallocates_warm_buffer() {
    let res = Resolution::new(24, 20);
    let events = stream(res, 400, 23);
    let t_end = events.last().unwrap().t;
    for [mut rep, _] in all_reps(res) {
        rep.ingest_batch(&events);
        let mut buf = Grid::new(1, 1, 0.0);
        rep.frame_into(&mut buf, t_end); // warmup: single reshape
        let ptr = buf.as_slice().as_ptr();
        for k in 1..=5u64 {
            let t = t_end + k * 7_000;
            rep.frame_into(&mut buf, t);
            assert_eq!(
                buf.as_slice().as_ptr(),
                ptr,
                "{}: warm frame_into reallocated",
                rep.name()
            );
            assert_eq!(buf, rep.frame(t), "{}: frame_into != frame", rep.name());
        }
    }
}

#[test]
fn isc_ts_tracks_ideal_ts_within_tolerance_via_frame_into() {
    // The paper's parity claim (Sec. IV): the analog TS reproduces the
    // ideal exponential TS up to the decay-LUT quantization (≤3.4 mV ≈
    // 0.5 % of V_dd) plus the <2 % cell-mismatch CV. Rank order must
    // match and written-pixel values must correlate tightly.
    let res = Resolution::new(16, 16);
    let mut hw = IscTs::with_defaults(res);
    let mut ideal = IdealTs::new(res, 24_000.0);
    let events = stream(res, 256, 5);
    hw.ingest_batch(&events);
    ideal.ingest_batch(&events);
    let t_end = events.last().unwrap().t + 1_000;

    let mut fh = Grid::new(1, 1, 0.0);
    let mut fi = Grid::new(1, 1, 0.0);
    hw.frame_into(&mut fh, t_end);
    ideal.frame_into(&mut fi, t_end);

    let argmax = |g: &Grid<f64>| {
        g.as_slice()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    assert_eq!(argmax(&fh), argmax(&fi), "brightest pixel rank disagrees");

    let (hs, is): (Vec<f64>, Vec<f64>) = fh
        .as_slice()
        .iter()
        .zip(fi.as_slice())
        .filter(|(a, b)| **a > 0.0 || **b > 0.0)
        .map(|(a, b)| (*a, *b))
        .unzip();
    assert!(!hs.is_empty());
    let (_, _, r2) = tsisc::util::stats::linreg(&hs, &is);
    assert!(r2 > 0.8, "hardware vs ideal TS r² = {r2}");

    // Fresh writes (small Δt, where the curves are pinned at V_reset)
    // must agree within the quantization + mismatch band.
    let last = events.last().unwrap();
    let vh = *fh.get(last.x as usize, last.y as usize);
    let vi = *fi.get(last.x as usize, last.y as usize);
    assert!((vh - vi).abs() < 0.05, "fresh-pixel disagreement: hw {vh} vs ideal {vi}");
}

#[test]
fn ideal_array_matches_ideal_ts_most_closely() {
    // Without mismatch, only the decay-shape difference and the readout
    // LUT quantization remain: agreement must tighten.
    let res = Resolution::new(12, 12);
    let events = stream(res, 200, 9);
    let t_end = events.last().unwrap().t + 1_000;
    let cfg = IscConfig { mismatch: None, ..IscConfig::default() };
    let mut hw = IscTs::new(res, cfg);
    let mut ideal = IdealTs::new(res, 24_000.0);
    hw.ingest_batch(&events);
    ideal.ingest_batch(&events);
    let fh = hw.frame(t_end);
    let fi = ideal.frame(t_end);
    let (hs, is): (Vec<f64>, Vec<f64>) = fh
        .as_slice()
        .iter()
        .zip(fi.as_slice())
        .filter(|(a, b)| **a > 0.0 || **b > 0.0)
        .map(|(a, b)| (*a, *b))
        .unzip();
    let (_, _, r2) = tsisc::util::stats::linreg(&hs, &is);
    assert!(r2 > 0.85, "ideal-array vs ideal TS r² = {r2}");
}
