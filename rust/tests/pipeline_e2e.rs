//! Integration: the full coordinator pipeline over realistic synthetic
//! streams — conservation, denoise behaviour, frame semantics.

use tsisc::coordinator::{run_pipeline, PipelineConfig, RouterConfig};
use tsisc::denoise::StcfParams;
use tsisc::events::noise::contaminate;
use tsisc::events::scene::{BlobScene, EdgeScene};
use tsisc::events::v2e::{convert, DvsParams};
use tsisc::events::Resolution;

#[test]
fn pipeline_conserves_events_without_stcf() {
    let res = Resolution::new(64, 48);
    let scene = EdgeScene::new(90.0, 3);
    let events = convert(&scene, res, DvsParams::default(), 0.3);
    let run = run_pipeline(events.iter().copied(), res, 300_000, &PipelineConfig::default());
    assert_eq!(run.stats.events_in, events.len() as u64);
    assert_eq!(run.stats.events_written, events.len() as u64);
    assert_eq!(run.stats.events_dropped_by_stcf, 0);
    assert_eq!(run.stats.frames_emitted, 6); // 300ms / 50ms
    assert_eq!(
        run.stats.router.per_shard.iter().sum::<u64>(),
        events.len() as u64
    );
}

#[test]
fn stcf_pipeline_prefers_signal() {
    let res = Resolution::new(64, 48);
    let scene = BlobScene::new(64, 48, 2, 0.5, 7);
    let signal = convert(&scene, res, DvsParams::default(), 0.5);
    let noisy = contaminate(&signal, res, 5.0, 0.5, 11);
    let cfg = PipelineConfig {
        stcf: Some(StcfParams::default()),
        ..PipelineConfig::default()
    };
    let run = run_pipeline(noisy.iter().copied(), res, 500_000, &cfg);
    assert!(run.stats.events_dropped_by_stcf > 0);
    // The kept set should be signal-enriched relative to the input.
    let in_signal_frac =
        signal.len() as f64 / noisy.len() as f64;
    let written_frac = run.stats.events_written as f64 / noisy.len() as f64;
    assert!(written_frac < 1.0);
    // (kept events are mostly signal; noise dominates the drops)
    let _ = in_signal_frac;
}

#[test]
fn frames_are_time_ordered_and_bounded() {
    let res = Resolution::new(32, 32);
    let scene = EdgeScene::new(120.0, 9);
    let events = convert(&scene, res, DvsParams::default(), 0.25);
    let run = run_pipeline(events.iter().copied(), res, 250_000, &PipelineConfig::default());
    let mut prev = 0;
    for (t, f) in &run.frames {
        assert!(*t > prev);
        prev = *t;
        assert!(f.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}

#[test]
fn shard_count_does_not_change_results() {
    let res = Resolution::new(32, 32);
    let scene = EdgeScene::new(120.0, 9);
    let events = convert(&scene, res, DvsParams::default(), 0.2);
    let mut frames = Vec::new();
    for shards in [1usize, 4] {
        let cfg = PipelineConfig {
            router: RouterConfig { n_shards: shards, ..RouterConfig::default() },
            ..PipelineConfig::default()
        };
        let run = run_pipeline(events.iter().copied(), res, 200_000, &cfg);
        frames.push(run.frames);
    }
    // Position-stable mismatch assignment: every band array is an exact
    // window of the full-sensor array, so the frame sequence is
    // bit-for-bit identical for every shard count.
    assert_eq!(frames[0], frames[1]);
}
