//! Equivalence contracts of the bitmask-accelerated and band-sharded
//! STCF (the parallel-ingest PR):
//!
//! * the three support-scan tiers — bitmask-popcount, row-sliced, naive
//!   patch scan — produce identical counts on random causal streams for
//!   both backends, across radii, polarity modes, `count_center` off,
//!   sensor borders, and expiry/ageing edges (long gaps that force
//!   epoch-bucket recycling in the recency plane);
//! * band-sharded scoring ([`StcfShardPool`]) ≡ the serial
//!   [`run_stcf`] bit-for-bit — scores and kept sets — including events
//!   on band borders and halo configurations where the patch radius
//!   exceeds the band height, for both backends at every shard count,
//!   **mismatch enabled**: position-stable assignment makes every band
//!   array an exact window of the full-sensor array;
//! * the coordinator pipeline emits identical frames whether the STCF
//!   scores inline or on the shard pool.

use tsisc::coordinator::{run_pipeline, PipelineConfig, RouterConfig};
use tsisc::denoise::{
    run_stcf, support_count, support_count_bitmask, support_count_naive, support_count_rows,
    ShardBackend, StcfBackend, StcfParams, StcfShardPool,
};
use tsisc::events::{Event, LabeledEvent, Polarity, Resolution};
use tsisc::isc::IscConfig;
use tsisc::util::check::{check, Gen};

/// Time-sorted random stream; `max_step_us` controls the gap sizes (big
/// steps cross recency epochs and expire support).
fn stream(g: &mut Gen, res: Resolution, n: usize, max_step_us: u64) -> Vec<Event> {
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            t += g.u64(1, max_step_us);
            Event::new(
                t,
                g.u64(0, res.width as u64 - 1) as u16,
                g.u64(0, res.height as u64 - 1) as u16,
                if g.bool(0.5) { Polarity::On } else { Polarity::Off },
            )
        })
        .collect()
}

fn labeled(evs: &[Event]) -> Vec<LabeledEvent> {
    evs.iter().map(|&ev| LabeledEvent { ev, is_signal: true }).collect()
}

/// Assert all three scan tiers agree on `e` against the current state
/// of `b` (bitmask must actually engage: the caller guarantees coverage).
fn assert_tiers_agree(b: &StcfBackend, e: &Event, prm: &StcfParams, ctx: &str) {
    let naive = support_count_naive(b, e, prm);
    assert_eq!(support_count_rows(b, e, prm), naive, "rows≠naive {ctx} e={e:?}");
    assert_eq!(
        support_count_bitmask(b, e, prm),
        Some(naive),
        "bitmask≠naive {ctx} e={e:?}"
    );
    assert_eq!(support_count(b, e, prm), naive, "auto≠naive {ctx} e={e:?}");
}

#[test]
fn scan_tiers_agree_ideal_backend_random_streams() {
    check("stcf bitmask ≡ rows ≡ naive (ideal)", 10, |g| {
        let res = Resolution::new(20, 16);
        let prm = StcfParams {
            radius: g.u64(1, 4) as u16,
            tau_tw_us: g.u64(500, 50_000),
            polarity_sensitive: g.bool(0.5),
            count_center: g.bool(0.5),
            ..StcfParams::default()
        };
        let mut b = StcfBackend::ideal_with_window(res, prm.tau_tw_us);
        // Gaps up to ~2 epochs: plenty of expiry + bucket recycling.
        let mut evs = stream(g, res, 400, prm.tau_tw_us / 2 + 10);
        // Force border coverage: corners and edge mid-points.
        let t_last = evs.last().unwrap().t;
        for (x, y) in [(0, 0), (19, 15), (0, 15), (19, 0), (10, 0), (0, 8)] {
            evs.push(Event::new(t_last + 10, x, y, Polarity::On));
        }
        let ctx = format!("r={} tau={}", prm.radius, prm.tau_tw_us);
        for e in &evs {
            assert_tiers_agree(&b, e, &prm, &ctx);
            b.ingest(e, &prm);
        }
    });
}

#[test]
fn scan_tiers_agree_isc_backend_random_streams() {
    check("stcf bitmask ≡ rows ≡ naive (isc)", 4, |g| {
        let res = Resolution::new(16, 16);
        let prm = StcfParams {
            radius: g.u64(1, 3) as u16,
            polarity_sensitive: g.bool(0.5),
            count_center: g.bool(0.5),
            ..StcfParams::default()
        };
        let cfg = IscConfig {
            polarity_sensitive: prm.polarity_sensitive,
            bank_size: 32,
            seed: g.u64(0, u64::MAX / 2),
            ..IscConfig::default()
        };
        let mut b = StcfBackend::isc(res, cfg, prm.tau_tw_us);
        let mut evs = stream(g, res, 300, 400);
        let t_last = evs.last().unwrap().t;
        for (x, y) in [(0, 0), (15, 15), (0, 15), (15, 0)] {
            evs.push(Event::new(t_last + 10, x, y, Polarity::Off));
        }
        for e in &evs {
            assert_tiers_agree(&b, e, &prm, "isc");
            b.ingest(e, &prm);
        }
    });
}

#[test]
fn scan_tiers_agree_across_expiry_and_ageing_edges() {
    // Deterministic ageing torture: gaps exactly at, just below and just
    // above τ_tw and the bitmask epoch width, plus bursts that recycle
    // epoch buckets while older support is still live.
    let res = Resolution::new(12, 12);
    for tau in [900u64, 3_000, 24_000] {
        let prm = StcfParams { tau_tw_us: tau, ..StcfParams::default() };
        let mut b = StcfBackend::ideal_with_window(res, tau);
        let mut t = 1u64;
        let mut evs: Vec<Event> = Vec::new();
        let gaps = [1u64, tau / 3, tau / 3 + 1, tau - 1, tau, tau + 1, 3 * tau, 5 * tau + 7];
        for (k, &gap) in gaps.iter().cycle().take(160).enumerate() {
            t += gap;
            evs.push(Event::new(t, (k % 12) as u16, ((k / 3) % 12) as u16, Polarity::On));
        }
        for e in &evs {
            assert_tiers_agree(&b, e, &prm, &format!("tau={tau}"));
            b.ingest(e, &prm);
        }
    }
}

#[test]
fn sharded_scoring_equals_serial_ideal_across_shard_counts() {
    check("sharded ≡ serial (ideal)", 6, |g| {
        let res = Resolution::new(20, 16);
        let prm = StcfParams {
            radius: g.u64(1, 4) as u16,
            polarity_sensitive: g.bool(0.5),
            count_center: g.bool(0.5),
            ..StcfParams::default()
        };
        let mut evs = stream(g, res, 350, 600);
        // Events exactly on band borders for every layout under test
        // (band heights 16, 8, 4, 2 ⇒ borders at multiples of 2).
        let t_last = evs.last().unwrap().t;
        for (k, y) in [0u16, 1, 2, 3, 7, 8, 9, 14, 15].iter().enumerate() {
            evs.push(Event::new(t_last + 10 + k as u64, 10, *y, Polarity::On));
        }
        let evs = labeled(&evs);
        let mut serial_b = StcfBackend::ideal(res);
        let serial = run_stcf(&mut serial_b, &evs, &prm);
        for shards in [1usize, 2, 4, 8] {
            let mut pool = StcfShardPool::new(res, shards, ShardBackend::Ideal, prm);
            let got = pool.run(&evs);
            assert_eq!(got.scored, serial.scored, "scores, shards={shards} r={}", prm.radius);
            assert_eq!(got.kept, serial.kept, "kept, shards={shards} r={}", prm.radius);
            pool.shutdown();
        }
    });
}

#[test]
fn sharded_scoring_equals_serial_isc() {
    // Position-stable mismatch assignment: band(+halo) arrays anchored
    // at their global origin are exact windows of the full-sensor
    // array, so sharded scoring is bit-for-bit ≡ serial for the default
    // mismatch-enabled config — and, trivially, for `mismatch: None`.
    let res = Resolution::new(16, 16);
    for base in [IscConfig::default(), IscConfig { mismatch: None, ..IscConfig::default() }] {
        for polarity_sensitive in [false, true] {
            let prm = StcfParams { polarity_sensitive, ..StcfParams::default() };
            let cfg = IscConfig { polarity_sensitive, ..base.clone() };
            let evs: Vec<LabeledEvent> = labeled(
                &(0..400u64)
                    .map(|k| {
                        Event::new(
                            1 + k * 230,
                            (k * 7 % 16) as u16,
                            (k * 3 % 16) as u16,
                            if k % 3 == 0 { Polarity::Off } else { Polarity::On },
                        )
                    })
                    .collect::<Vec<_>>(),
            );
            let mm = base.mismatch.is_some();
            let mut serial_b = StcfBackend::isc(res, cfg.clone(), prm.tau_tw_us);
            let serial = run_stcf(&mut serial_b, &evs, &prm);
            for shards in [2usize, 5, 8] {
                let mut pool =
                    StcfShardPool::new(res, shards, ShardBackend::Isc(cfg.clone()), prm);
                let got = pool.run(&evs);
                let ctx = format!("mm={mm} ps={polarity_sensitive} shards={shards}");
                assert_eq!(got.scored, serial.scored, "{ctx}");
                assert_eq!(got.kept, serial.kept, "{ctx}");
                let tallies = pool.shutdown();
                assert_eq!(
                    tallies.iter().map(|t| t.kept + t.dropped).sum::<u64>(),
                    evs.len() as u64
                );
            }
        }
    }
}

#[test]
fn radius_deeper_than_band_reaches_across_multiple_bands() {
    // 16 rows over 8 shards ⇒ bands of 2; radius 5 spans up to 5 bands
    // per side. The dispatcher must duplicate border events to every
    // shard whose halo contains them, or counts break at the seams.
    let res = Resolution::new(12, 16);
    let prm = StcfParams { radius: 5, ..StcfParams::default() };
    let evs: Vec<LabeledEvent> = labeled(
        &(0..300u64)
            .map(|k| {
                Event::new(1 + k * 170, (k * 5 % 12) as u16, (k * 11 % 16) as u16, Polarity::On)
            })
            .collect::<Vec<_>>(),
    );
    let mut serial_b = StcfBackend::ideal(res);
    let serial = run_stcf(&mut serial_b, &evs, &prm);
    let mut pool = StcfShardPool::new(res, 8, ShardBackend::Ideal, prm);
    let got = pool.run(&evs);
    assert_eq!(got.scored, serial.scored);
    assert_eq!(got.kept, serial.kept);
    let tallies = pool.shutdown();
    assert!(
        tallies.iter().map(|t| t.halo_ingests).sum::<u64>() > evs.len() as u64,
        "deep halos must duplicate most events to several shards"
    );
}

#[test]
fn pipeline_frames_identical_inline_vs_sharded_denoise() {
    // End-to-end: same frames whether the STCF runs inline on the
    // producer or fanned out over denoise shards — with the default
    // mismatch-enabled config, since position-stable assignment makes
    // keep decisions layout-independent.
    let res = Resolution::new(32, 32);
    let evs: Vec<LabeledEvent> = labeled(
        &(0..1_500u64)
            .map(|k| {
                Event::new(
                    1 + k * 80,
                    (k * 13 % 32) as u16,
                    ((k / 7) % 32) as u16,
                    if k % 4 == 0 { Polarity::Off } else { Polarity::On },
                )
            })
            .collect::<Vec<_>>(),
    );
    let mut all = Vec::new();
    for denoise_shards in [0usize, 3, 8] {
        let cfg = PipelineConfig {
            stcf: Some(StcfParams::default()),
            denoise_shards,
            batch_size: 200, // multiple flushes per window
            router: RouterConfig { isc: IscConfig::default(), ..RouterConfig::default() },
            ..PipelineConfig::default()
        };
        let r = run_pipeline(evs.iter().copied(), res, 120_000, &cfg);
        assert_eq!(r.stats.events_in, evs.len() as u64);
        let dn = r.stats.denoise.expect("stcf configured");
        assert_eq!(dn.inline_scoring, denoise_shards == 0);
        assert_eq!(
            dn.per_shard.iter().map(|t| t.dropped).sum::<u64>(),
            r.stats.events_dropped_by_stcf
        );
        all.push((denoise_shards, r.stats.events_written, r.frames));
    }
    for w in all.windows(2) {
        assert_eq!(w[0].1, w[1].1, "written: {} vs {} shards", w[0].0, w[1].0);
        assert_eq!(w[0].2, w[1].2, "frames: {} vs {} shards", w[0].0, w[1].0);
    }
}
