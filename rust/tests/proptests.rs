//! Cross-module property tests on coordinator and system invariants
//! (in-repo `util::check` harness — the offline proptest substitute).

use tsisc::coordinator::{MicroBatcher, Router, RouterConfig};
use tsisc::events::aer;
use tsisc::events::event::{merge_sorted, Event, LabeledEvent, Polarity, Resolution};
use tsisc::isc::{IscArray, IscConfig};
use tsisc::metrics::{roc, Scored};
use tsisc::tsurface::{EventSink, FrameSource, IdealTs, Sae};
use tsisc::util::check::{check, Gen};
use tsisc::util::grid::Grid;
use tsisc::util::image::resize_bilinear;
use tsisc::metrics::ssim;

fn random_events(g: &mut Gen, res: Resolution, max_n: usize) -> Vec<LabeledEvent> {
    let n = g.usize(0, max_n);
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            t += g.u64(1, 2_000);
            LabeledEvent {
                ev: Event::new(
                    t,
                    g.u64(0, res.width as u64 - 1) as u16,
                    g.u64(0, res.height as u64 - 1) as u16,
                    if g.bool(0.5) { Polarity::On } else { Polarity::Off },
                ),
                is_signal: g.bool(0.7),
            }
        })
        .collect()
}

#[test]
fn prop_aer_roundtrip_any_stream() {
    check("aer roundtrip integration", 100, |g| {
        let res = Resolution::new(64, 64);
        let evs: Vec<Event> = random_events(g, res, 150).iter().map(|l| l.ev).collect();
        let back = aer::decode(&aer::encode(&evs), res).expect("decode");
        assert_eq!(evs, back);
    });
}

/// Byte length of one AER record: canonical varint Δt + x u16 + y u16 +
/// polarity u8 (mirrors the encoder, used to find record boundaries).
fn aer_record_len(delta: u64) -> usize {
    let mut v = delta;
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n + 5
}

#[test]
fn prop_mutated_aer_never_panics_and_fails_typed() {
    // Robustness contract of the decoder against arbitrary corruption:
    // a mutated stream either decodes to *some* valid stream (a flipped
    // coordinate bit is still a coordinate — in-bounds, time-sorted) or
    // returns a typed `AerError`; it never panics and never yields an
    // out-of-range event. Records wholly before the first mutated byte
    // always decode identically, and the incremental decoder agrees
    // with the one-shot path byte for byte.
    check("aer mutation robustness", 300, |g| {
        let res = Resolution::new(48, 36);
        let evs: Vec<Event> = {
            let n = g.usize(1, 120);
            let mut t = 0u64;
            (0..n)
                .map(|_| {
                    t += g.u64(0, 3_000);
                    Event::new(
                        t,
                        g.u64(0, 47) as u16,
                        g.u64(0, 35) as u16,
                        if g.bool(0.5) { Polarity::On } else { Polarity::Off },
                    )
                })
                .collect()
        };
        let bytes = aer::encode(&evs);

        // Corrupt: 1–4 bit flips / byte stomps, possibly a truncation.
        let mut mutated = bytes.clone();
        let mut first_mut = mutated.len();
        for _ in 0..g.usize(1, 4) {
            if mutated.is_empty() {
                break;
            }
            match g.usize(0, 2) {
                0 => {
                    let i = g.usize(0, mutated.len() - 1);
                    mutated[i] ^= 1 << g.usize(0, 7);
                    first_mut = first_mut.min(i);
                }
                1 => {
                    let i = g.usize(0, mutated.len() - 1);
                    mutated[i] = g.u64(0, 255) as u8;
                    first_mut = first_mut.min(i);
                }
                _ => {
                    let cut = g.usize(0, mutated.len());
                    mutated.truncate(cut);
                    first_mut = first_mut.min(cut);
                }
            }
        }

        // One-shot and prefix-preserving decode paths.
        let oneshot = aer::decode(&mutated, res);
        let mut prefix = Vec::new();
        let prefix_err = aer::decode_into(&mutated, res, &mut prefix).err();

        // Whatever happened, the produced events are valid: in-bounds
        // and time-sorted — corruption is *typed*, never silent garbage.
        assert!(prefix
            .iter()
            .all(|e| (e.x as u32) < res.width && (e.y as u32) < res.height));
        assert!(prefix.windows(2).all(|w| w[0].t <= w[1].t));
        match (&oneshot, &prefix_err) {
            (Ok(full), None) => assert_eq!(full, &prefix),
            (Err(a), Some(b)) => assert_eq!(a, b, "decode and decode_into disagree on the error"),
            other => panic!("decode / decode_into disagree on success: {other:?}"),
        }

        // Records wholly before the first mutated byte decode exactly.
        let mut intact = 0usize;
        let mut end = 0usize;
        let mut last_t = 0u64;
        for e in &evs {
            end += aer_record_len(e.t - last_t);
            last_t = e.t;
            if end > first_mut {
                break;
            }
            intact += 1;
        }
        assert!(
            prefix.len() >= intact,
            "lost intact records: decoded {} of {intact} pre-mutation events",
            prefix.len()
        );
        assert_eq!(&prefix[..intact], &evs[..intact], "pre-mutation records changed");

        // The incremental decoder, fed arbitrary chunk splits of the
        // same corrupted bytes, reaches the same events and same error.
        let mut inc = aer::AerDecoder::new(res);
        let mut inc_out = Vec::new();
        let mut inc_err = None;
        let mut pos = 0usize;
        while pos < mutated.len() {
            let take = g.usize(1, 37).min(mutated.len() - pos);
            match inc.push(&mutated[pos..pos + take], &mut inc_out) {
                Ok(_) => pos += take,
                Err(e) => {
                    inc_err = Some(e);
                    break;
                }
            }
        }
        if inc_err.is_none() {
            inc_err = inc.finish().err();
        }
        assert_eq!(inc_out, prefix, "incremental prefix diverged from one-shot");
        assert_eq!(inc_err, prefix_err, "incremental error diverged from one-shot");
    });
}

#[test]
fn prop_merge_sorted_is_sorted_and_complete() {
    check("merge sorted", 100, |g| {
        let res = Resolution::new(16, 16);
        let a = random_events(g, res, 60);
        let b = random_events(g, res, 60);
        let m = merge_sorted(&a, &b);
        assert_eq!(m.len(), a.len() + b.len());
        assert!(m.windows(2).all(|w| w[0].ev.t <= w[1].ev.t));
    });
}

#[test]
fn prop_sae_equals_replay_max() {
    // SAE(x,y) must equal the max timestamp of events at (x,y).
    check("sae is last-event", 60, |g| {
        let res = Resolution::new(8, 8);
        let evs = random_events(g, res, 100);
        let mut sae = Sae::new(res);
        for le in &evs {
            sae.ingest(&le.ev);
        }
        for x in 0..8u16 {
            for y in 0..8u16 {
                let expect = evs
                    .iter()
                    .filter(|l| l.ev.x == x && l.ev.y == y)
                    .map(|l| l.ev.t.max(1))
                    .max()
                    .unwrap_or(0);
                assert_eq!(sae.last(x, y), expect);
            }
        }
    });
}

#[test]
fn prop_ideal_ts_bounded_and_monotone_between_writes() {
    check("ideal ts bounds", 60, |g| {
        let res = Resolution::new(8, 8);
        let evs = random_events(g, res, 50);
        let mut ts = IdealTs::new(res, g.f64(1_000.0, 100_000.0));
        for le in &evs {
            ts.ingest(&le.ev);
        }
        let t_end = evs.last().map(|e| e.ev.t).unwrap_or(0) + g.u64(0, 50_000);
        let f = ts.frame(t_end);
        assert!(f.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
    });
}

#[test]
fn prop_isc_frame_bounded_any_stream() {
    check("isc frame bounded", 30, |g| {
        let res = Resolution::new(12, 12);
        let evs = random_events(g, res, 80);
        let mut arr = IscArray::new(
            res,
            IscConfig { seed: g.u64(0, u64::MAX / 2), ..IscConfig::default() },
        );
        for le in &evs {
            arr.write(&le.ev);
        }
        let t_end = evs.last().map(|e| e.ev.t).unwrap_or(1) + g.u64(0, 100_000);
        let f = arr.frame_merged(t_end);
        assert!(f.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
    });
}

#[test]
fn prop_batcher_then_router_conserves() {
    check("batcher+router conservation", 30, |g| {
        let res = Resolution::new(16, 16);
        let evs = random_events(g, res, 120);
        let mut batcher = MicroBatcher::new(g.u64(100, 5_000));
        let mut router = Router::new(
            res,
            RouterConfig { n_shards: g.usize(1, 4), queue_depth: 64, ..RouterConfig::default() },
        );
        let mut batches = Vec::new();
        for le in &evs {
            batches.extend(batcher.push(*le));
        }
        batches.extend(batcher.flush(evs.last().map(|e| e.ev.t).unwrap_or(0) + 10_000));
        for b in &batches {
            for le in &b.events {
                router.route(le.ev);
            }
        }
        let stats = router.shutdown();
        assert_eq!(stats.events_routed, evs.len() as u64);
    });
}

#[test]
fn prop_roc_auc_in_unit_interval_and_flip_symmetric() {
    check("roc auc bounds", 100, |g| {
        let n = g.usize(2, 300);
        let mut scored: Vec<Scored> = (0..n)
            .map(|_| Scored { score: g.f64(-5.0, 5.0), is_signal: g.bool(0.5) })
            .collect();
        // Ensure both classes present.
        scored[0].is_signal = true;
        scored.push(Scored { score: g.f64(-5.0, 5.0), is_signal: false });
        let auc = roc(&scored).auc;
        assert!((0.0..=1.0).contains(&auc), "auc={auc}");
        // Flipping all scores mirrors the AUC.
        let flipped: Vec<Scored> =
            scored.iter().map(|s| Scored { score: -s.score, ..*s }).collect();
        let auc_f = roc(&flipped).auc;
        assert!((auc + auc_f - 1.0).abs() < 1e-9, "auc={auc} flipped={auc_f}");
    });
}

#[test]
fn prop_ssim_identity_and_bounds() {
    check("ssim identity", 40, |g| {
        let w = g.usize(8, 24);
        let h = g.usize(8, 24);
        let vals: Vec<f64> = (0..w * h).map(|_| g.f64(0.0, 1.0)).collect();
        let a = Grid::from_vec(w, h, vals);
        assert!((ssim(&a, &a) - 1.0).abs() < 1e-9);
        let b = a.map(|v| (v * 0.5 + 0.25).clamp(0.0, 1.0));
        let s = ssim(&a, &b);
        assert!((-1.0..=1.0).contains(&s));
    });
}

#[test]
fn prop_resize_preserves_bounds() {
    check("resize bounds", 60, |g| {
        let w = g.usize(2, 40);
        let h = g.usize(2, 40);
        let vals: Vec<f64> = (0..w * h).map(|_| g.f64(0.0, 1.0)).collect();
        let src = Grid::from_vec(w, h, vals);
        let dst = resize_bilinear(&src, g.usize(1, 50), g.usize(1, 50));
        let (lo, hi) = tsisc::util::stats::min_max(src.as_slice());
        for &v in dst.as_slice() {
            assert!(v >= lo - 1e-12 && v <= hi + 1e-12);
        }
    });
}

#[test]
fn prop_event_order_within_pixel_preserved_by_representation() {
    // Re-writing a pixel must never make it look older.
    check("rewrite freshens", 60, |g| {
        let res = Resolution::new(4, 4);
        let mut ts = IdealTs::new(res, 24_000.0);
        let x = g.u64(0, 3) as u16;
        let y = g.u64(0, 3) as u16;
        let t1 = g.u64(1, 1_000_000);
        let t2 = t1 + g.u64(1, 1_000_000);
        ts.ingest(&Event::new(t1, x, y, Polarity::On));
        let v1 = ts.value(x, y, t2);
        ts.ingest(&Event::new(t2, x, y, Polarity::On));
        let v2 = ts.value(x, y, t2);
        assert!(v2 >= v1);
    });
}
