//! Loom model checks for the scheduler core and the bounded channel.
//!
//! Built only under `RUSTFLAGS="--cfg loom"` (`make loom`), where the
//! `util::sync` facade resolves to loom's modeled primitives and loom
//! exhaustively explores the thread interleavings of each model. Because
//! `util::actor::ActorPool` *is* the serve scheduler's core (the serve
//! layer only adds the band job grammar on top), these models check the
//! production queue logic, not a re-implementation:
//!
//! * an actor is processed by at most one worker at a time (the
//!   at-most-once-scheduled invariant);
//! * jobs on one actor execute strictly in enqueue order (per-band FIFO);
//! * a held pool starts no job, and releasing the last hold drains
//!   everything (drain quiescence, no lost hold-release wakeup);
//! * `shutdown` drains queued jobs even while a hold is live;
//! * an enqueue against a parked worker always wakes it (no lost
//!   wakeup — loom's deadlock detection fails the model otherwise);
//! * the bounded channel neither loses nor duplicates values, preserves
//!   FIFO order, and never wedges a sender on a dropped receiver;
//! * a worker death reported to the [`DeathBoard`] is consumed by
//!   exactly one `wait_next` caller (at-most-once respawn per death),
//!   never lost, and `close` wakes every parked waiter — the supervisor
//!   thread can neither double-respawn nor hang at shutdown;
//! * two workers filing faults against one session observe exactly one
//!   quarantine *transition* on the [`FaultBoard`] (prior count 0), so
//!   the fleet counts quarantined sessions, not faults.
//!
//! Panic *containment* itself runs through the
//! `util::sync::catch_boundary` facade, whose loom variant executes the
//! closure inline (loom does not model unwinding); the panic paths are
//! exercised by the non-loom scheduler/session/chaos tests. What loom
//! checks here is the supervision hand-off *around* a death — the
//! `DeathBoard` and `FaultBoard` models below.
//!
//! Models stay tiny (≤ 2 workers, ≤ 3 jobs) on purpose: loom's state
//! space is exponential in threads × sync operations.

#![cfg(loom)]

use tsisc::serve::supervise::{FaultBoard, FaultJobKind, SessionFault};
use tsisc::util::actor::{ActorPool, DeathBoard};
use tsisc::util::sync::chan;
use tsisc::util::sync::{Arc, AtomicU64, AtomicUsize, Ordering};

/// Two workers racing over one actor with two queued jobs: the runner
/// asserts it is never concurrently active for the actor (at-most-once
/// scheduled ⇒ at most one worker owns the actor), and that job ids
/// arrive in enqueue order (per-actor FIFO) even when the two jobs are
/// executed by different workers.
#[test]
fn actor_never_runs_concurrently_and_stays_fifo() {
    loom::model(|| {
        let active = Arc::new(AtomicUsize::new(0));
        let last_seen = Arc::new(AtomicU64::new(0));
        let (active2, last2) = (active.clone(), last_seen.clone());
        let pool = ActorPool::new(2, move |job: u64, _slot: &mut ()| {
            let was = active2.fetch_add(1, Ordering::SeqCst);
            assert_eq!(was, 0, "two workers ran the same actor concurrently");
            let prev = last2.swap(job, Ordering::SeqCst);
            assert!(prev < job, "jobs reordered within one actor: {prev} then {job}");
            active2.fetch_sub(1, Ordering::SeqCst);
        });
        let a = pool.spawn_actor(());
        pool.enqueue(&a, 1);
        pool.enqueue(&a, 2);
        pool.shutdown();
        assert_eq!(last_seen.load(Ordering::SeqCst), 2, "a job was lost");
    });
}

/// A producer thread enqueues concurrently with the main thread: FIFO
/// holds per actor regardless of which thread enqueued first (each
/// thread's own jobs stay ordered; here each enqueues to its own actor
/// so the global order is unconstrained but per-actor order is exact).
#[test]
fn concurrent_producers_keep_their_own_actor_fifo() {
    loom::model(|| {
        let last_a = Arc::new(AtomicU64::new(0));
        let last_b = Arc::new(AtomicU64::new(100));
        let (la, lb) = (last_a.clone(), last_b.clone());
        // The pool object itself is shared with a plain std Arc: the
        // refcount is not the synchronization under test (everything
        // inside the pool is on loom primitives), and loom's join gives
        // the needed happens-before for the final drop.
        let pool = std::sync::Arc::new(ActorPool::new(1, move |job: u64, slot: &mut u8| {
            let last = if *slot == 0 { &la } else { &lb };
            let prev = last.swap(job, Ordering::SeqCst);
            assert!(prev < job, "per-actor FIFO broken: {prev} then {job}");
        }));
        let a = pool.spawn_actor(0u8);
        let b = pool.spawn_actor(1u8);
        let (pool2, b2) = (pool.clone(), b.clone());
        let producer = tsisc::util::sync::thread::spawn(move || {
            pool2.enqueue(&b2, 101);
            pool2.enqueue(&b2, 102);
        });
        pool.enqueue(&a, 1);
        pool.enqueue(&a, 2);
        producer.join().expect("join producer");
        match std::sync::Arc::try_unwrap(pool) {
            Ok(p) => p.shutdown(),
            Err(_) => panic!("pool still shared"),
        }
        assert_eq!(last_a.load(Ordering::SeqCst), 2);
        assert_eq!(last_b.load(Ordering::SeqCst), 102);
    });
}

/// Drain quiescence: while a hold is live no job starts, whatever the
/// interleaving; dropping the hold releases the drain and shutdown
/// observes every job executed (hold release can never lose a wakeup).
#[test]
fn hold_quiesces_then_release_drains() {
    loom::model(|| {
        let ran = Arc::new(AtomicUsize::new(0));
        let ran2 = ran.clone();
        let pool = ActorPool::new(1, move |_job: u8, _slot: &mut ()| {
            ran2.fetch_add(1, Ordering::SeqCst);
        });
        let a = pool.spawn_actor(());
        let hold = pool.hold();
        pool.enqueue(&a, 1);
        assert_eq!(ran.load(Ordering::SeqCst), 0, "held pool started a job");
        drop(hold);
        pool.shutdown();
        assert_eq!(ran.load(Ordering::SeqCst), 1, "release failed to drain");
    });
}

/// Shutdown must drain queued jobs even while a hold is still alive —
/// otherwise a crashed hold owner would wedge every close/drain reply.
#[test]
fn shutdown_drains_despite_live_hold() {
    loom::model(|| {
        let ran = Arc::new(AtomicUsize::new(0));
        let ran2 = ran.clone();
        let pool = ActorPool::new(1, move |_job: u8, _slot: &mut ()| {
            ran2.fetch_add(1, Ordering::SeqCst);
        });
        let a = pool.spawn_actor(());
        let _hold = pool.hold();
        pool.enqueue(&a, 1);
        pool.shutdown();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    });
}

/// No lost wakeup on worker park: the worker may already be parked in
/// the condvar when the producer enqueues; the job's reply must still
/// arrive. A lost wakeup deadlocks `recv` and loom's deadlock detection
/// fails the model.
#[test]
fn enqueue_always_wakes_a_parked_worker() {
    loom::model(|| {
        let (done_tx, done_rx) = chan::bounded::<u8>(1);
        let pool = ActorPool::new(1, move |job: u8, _slot: &mut ()| {
            done_tx.send(job).expect("reply");
        });
        let a = pool.spawn_actor(());
        pool.enqueue(&a, 7);
        assert_eq!(done_rx.recv(), Ok(7), "job never executed");
        pool.shutdown();
    });
}

/// Worker-death handoff: a single reported death is consumed by exactly
/// one `wait_next` caller (at-most-once respawn per death), whatever the
/// interleaving of the report, the close, and two racing consumers. Both
/// consumers seeing `Some` would mean a double respawn; both seeing
/// `None` would mean a lost death. A lost *wakeup* parks a consumer
/// forever and loom's deadlock detection fails the model.
#[test]
fn death_board_delivers_each_death_exactly_once() {
    loom::model(|| {
        let board = Arc::new(DeathBoard::new());
        let b = board.clone();
        let waiter = tsisc::util::sync::thread::spawn(move || b.wait_next());
        board.report(7);
        // Close keeps the already-reported death consumable; whichever
        // consumer misses it must observe the close as `None`.
        board.close();
        let mine = board.wait_next();
        let theirs = waiter.join().expect("join waiter");
        match (mine, theirs) {
            (Some(7), None) | (None, Some(7)) => {}
            other => panic!("death mis-delivered: {other:?}"),
        }
    });
}

/// `close` must wake a parked `wait_next` with `None` — otherwise the
/// supervisor thread would never exit at pool shutdown.
#[test]
fn death_board_close_wakes_parked_waiter() {
    loom::model(|| {
        let board = Arc::new(DeathBoard::new());
        let b = board.clone();
        let waiter = tsisc::util::sync::thread::spawn(move || b.wait_next());
        board.close();
        assert_eq!(waiter.join().expect("join waiter"), None);
    });
}

/// Quarantine handoff: two workers filing faults against the same
/// session concurrently observe exactly one quarantine *transition*
/// (`file` returning a prior count of 0), so `SupervisorStats` counts
/// quarantined sessions rather than faults, and both faults land on the
/// board.
#[test]
fn fault_board_has_exactly_one_quarantine_transition() {
    loom::model(|| {
        let board = Arc::new(FaultBoard::new());
        let b = board.clone();
        let filer = tsisc::util::sync::thread::spawn(move || {
            b.file(SessionFault {
                band: 0,
                job: FaultJobKind::Write,
                detail: String::new(),
                recent: Vec::new(),
            })
        });
        let prior_main = board.file(SessionFault {
            band: 1,
            job: FaultJobKind::Score,
            detail: String::new(),
            recent: Vec::new(),
        });
        let prior_filer = filer.join().expect("join filer");
        let transitions = u64::from(prior_main == 0) + u64::from(prior_filer == 0);
        assert_eq!(transitions, 1, "quarantine transition must fire exactly once");
        assert_eq!(board.count(), 2, "a filed fault was lost");
        assert!(board.is_quarantined());
    });
}

/// The bounded channel conserves values and preserves order across a
/// producer/consumer interleaving at capacity 1 (every send after the
/// first must block until the consumer drains a slot).
#[test]
fn chan_conserves_and_orders_at_capacity_one() {
    loom::model(|| {
        let (tx, rx) = chan::bounded::<u8>(1);
        let producer = tsisc::util::sync::thread::spawn(move || {
            for k in 1..=3u8 {
                tx.send(k).expect("send");
            }
        });
        for k in 1..=3u8 {
            assert_eq!(rx.recv(), Ok(k), "value lost or reordered");
        }
        assert_eq!(rx.recv(), Err(chan::RecvError), "disconnect not observed");
        producer.join().expect("join");
    });
}

/// Dropping the receiver must wake a sender parked on a full channel
/// with an error — a wedged sender here is a wedged shard thread.
#[test]
fn chan_receiver_drop_frees_blocked_sender() {
    loom::model(|| {
        let (tx, rx) = chan::bounded::<u8>(1);
        let producer = tsisc::util::sync::thread::spawn(move || {
            // First send fills the slot (or errs if rx already dropped);
            // the second must return — blocked-then-error or immediate
            // error — never hang.
            let _ = tx.send(1);
            assert!(tx.send(2).is_err(), "send must err once rx is gone");
        });
        drop(rx);
        producer.join().expect("join");
    });
}
