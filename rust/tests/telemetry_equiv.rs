//! Telemetry-plane contracts (PR 10):
//!
//! * **Conservation laws** under concurrent fleet load: every event a
//!   session admits is either routed to a band writer or dropped by
//!   STCF (`events_in == events_routed + events_dropped_by_stcf`), and
//!   the same balance is what the scrape text exports — the numbers an
//!   operator reads are the numbers the fleet actually moved;
//! * **one scrape covers everything**: a single `metrics_text()` body
//!   carries every registered counter/gauge/histogram (supervisor,
//!   net names excluded — no front door here), the per-stage p50/p99
//!   quantile lines, queue-wait, and the per-session labeled sections;
//! * **histogram laws**: merge is associative (bucket-wise addition)
//!   and percentile queries are bucket-exact against a sorted
//!   reference — `percentile(p) == bucket_upper(bucket_index(v_true))`;
//! * **flight recorder bound**: the per-session ring never exceeds
//!   [`FLIGHT_CAPACITY`](tsisc::serve::obs) samples and a quarantined
//!   session's [`SessionFault`] carries the tail;
//! * **`telemetry-off` equivalence**: this file compiles and passes
//!   under both feature configurations, and the frame-equality test
//!   asserts fleet output ≡ the standalone `run_pipeline` reference in
//!   whichever configuration is active — so a telemetry-on and a
//!   telemetry-off build provably serve bit-for-bit identical frames
//!   (both equal the same reference).

use tsisc::coordinator::{run_pipeline, PipelineConfig, RouterConfig};
use tsisc::denoise::StcfParams;
use tsisc::events::{Event, LabeledEvent, Polarity, Resolution};
use tsisc::isc::IscConfig;
use tsisc::serve::{
    FaultJobKind, FleetObs, SchedFaultKind, SchedFaultPlan, ServeConfig, SessionConfig,
    SessionManager, SessionObs,
};
#[cfg(not(feature = "telemetry-off"))]
use tsisc::util::telemetry::{bucket_index, bucket_upper, Histogram};

/// Deterministic time-sorted stream covering every row of `res`.
fn stream(res: Resolution, n: u64, step_us: u64, salt: u64) -> Vec<LabeledEvent> {
    (0..n)
        .map(|k| LabeledEvent {
            ev: Event::new(
                1 + k * step_us,
                ((k * 7 + salt) % res.width as u64) as u16,
                ((k * 5 + salt * 3) % res.height as u64) as u16,
                if (k + salt) % 3 == 0 { Polarity::Off } else { Polarity::On },
            ),
            is_signal: true,
        })
        .collect()
}

fn pipeline_cfg(stcf: bool) -> PipelineConfig {
    PipelineConfig {
        stcf: stcf.then(|| StcfParams { threshold: 1, ..StcfParams::default() }),
        denoise_shards: if stcf { 2 } else { 0 },
        batch_size: 64,
        router: RouterConfig {
            n_shards: 3,
            isc: IscConfig { bank_size: 48, ..IscConfig::default() },
            ..RouterConfig::default()
        },
        ..PipelineConfig::default()
    }
}

/// Pull `name{labels…} value` out of a scrape body (first match).
fn scrape_value(text: &str, key: &str) -> Option<f64> {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find(|l| l.starts_with(key))
        .and_then(|l| l.rsplit_once(' '))
        .and_then(|(_, v)| v.parse().ok())
}

#[test]
fn conservation_laws_hold_under_concurrent_load() {
    let res = Resolution::new(24, 18);
    let t_end = 120_000u64;
    let mut m = SessionManager::new(ServeConfig {
        workers: 4,
        max_sessions: 8,
        max_inflight_batches: 4_096,
        ..ServeConfig::default()
    });
    // Mixed fleet: STCF sessions drop events, plain sessions route all.
    let sids: Vec<_> = (0..6)
        .map(|k| {
            m.open(SessionConfig {
                name: format!("law-{k}"),
                res,
                t_end_us: t_end,
                pipeline: pipeline_cfg(k % 2 == 1),
            })
            .expect("open")
        })
        .collect();
    let streams: Vec<Vec<LabeledEvent>> =
        (0..6).map(|k| stream(res, 500, 230, k as u64)).collect();
    // Interleave uneven chunks so the worker pool runs every session's
    // jobs concurrently while the laws are accumulating.
    let mut heads = vec![0usize; 6];
    loop {
        let mut progressed = false;
        for (s, events) in streams.iter().enumerate() {
            let lo = heads[s];
            if lo >= events.len() {
                continue;
            }
            let hi = (lo + 41).min(events.len());
            m.ingest_batch(sids[s], &events[lo..hi]).expect("ingest");
            heads[s] = hi;
            progressed = true;
        }
        if !progressed {
            break;
        }
    }
    for sid in &sids {
        m.drain(*sid).expect("drain");
    }

    // Law 1, struct-level: per session and summed across the fleet.
    let stats = m.stats();
    let mut fleet_in = 0u64;
    for s in &stats.sessions {
        assert_eq!(
            s.events_in,
            s.events_routed + s.events_dropped_by_stcf,
            "conservation broken for {}: {s:?}",
            s.name
        );
        fleet_in += s.events_in;
    }
    assert_eq!(stats.events_in, fleet_in, "fleet events_in != sum of sessions");
    assert_eq!(fleet_in, 6 * 500, "every generated event was admitted");

    // Law 2, scrape-level: the exported text carries the same balance —
    // counters are always real, so this holds under `telemetry-off` too.
    let text = m.metrics_text();
    for s in &stats.sessions {
        let get = |metric: &str| {
            scrape_value(&text, &format!("{metric}{{session=\"{}\"}}", s.name))
                .unwrap_or_else(|| panic!("scrape lacks {metric} for {}", s.name))
        };
        let (ein, routed, dropped) = (
            get("session_events_in_total"),
            get("session_events_routed_total"),
            get("session_events_dropped_by_stcf_total"),
        );
        assert_eq!(ein as u64, s.events_in, "{}", s.name);
        assert_eq!(ein, routed + dropped, "scrape conservation for {}", s.name);
    }
    assert_eq!(
        scrape_value(&text, "events_in_total ").expect("fleet gauge") as u64,
        fleet_in
    );
    m.shutdown();
}

#[test]
fn one_scrape_covers_every_registered_metric_and_stage_quantiles() {
    let res = Resolution::new(16, 16);
    let mut m = SessionManager::new(ServeConfig {
        workers: 2,
        max_sessions: 2,
        max_inflight_batches: 256,
        ..ServeConfig::default()
    });
    let sid = m
        .open(SessionConfig {
            name: "scraped".into(),
            res,
            t_end_us: 100_000,
            pipeline: pipeline_cfg(true),
        })
        .expect("open");
    m.ingest_batch(sid, &stream(res, 300, 300, 3)).expect("ingest");
    m.drain(sid).expect("drain");
    // drain rendered through t_end; an equal-time on-demand snapshot is
    // causal (non-decreasing) and exercises the render/composite spans.
    m.snapshot(sid, 100_000).expect("snapshot");

    let text = m.metrics_text();
    // Every name in the registry appears — fleet stage histograms plus
    // the supervisor counters registered at manager construction.
    for name in m.obs().registry.names() {
        assert!(text.contains(&name), "scrape lacks registered metric `{name}`");
    }
    for must in [
        "quarantines_total",
        "job_panics_total",
        "checkpoints_taken_total",
        "uptime_us",
        "workers_total",
        "open_sessions_total",
        "resident_bytes",
        "degrade_tier_total",
        "worker_busy_ratio",
    ] {
        assert!(text.contains(must), "scrape lacks `{must}`");
    }
    // Per-stage p50/p99 + queue-wait quantile lines (the acceptance
    // criterion: one scrape returns them all).
    for h in [
        "queue_wait_us",
        "stage_decode_us",
        "stage_score_us",
        "stage_route_us",
        "stage_render_us",
        "stage_composite_us",
        "ingest_ack_us",
        "batch_e2e_us",
    ] {
        for q in ["0.5", "0.99"] {
            assert!(
                text.contains(&format!("{h}{{quantile=\"{q}\"}}")),
                "scrape lacks {h} p{q}"
            );
        }
    }
    // Per-session labeled section.
    assert!(text.contains("session_events_in_total{session=\"scraped\"}"));
    assert!(text.contains("session_queue_wait_us{quantile=\"0.99\",session=\"scraped\"}"));
    // Under telemetry-on the drained writes must have landed in the
    // stage histograms; under telemetry-off the lines render as zeros.
    if cfg!(not(feature = "telemetry-off")) {
        assert!(
            scrape_value(&text, "queue_wait_us_count").expect("count line") > 0.0,
            "no jobs recorded queue wait"
        );
        assert!(
            scrape_value(&text, "stage_route_us_count").expect("count line") > 0.0,
            "no write jobs recorded route service time"
        );
    }
    m.close(sid).expect("close");
    m.shutdown();
}

#[cfg(not(feature = "telemetry-off"))]
#[test]
fn histogram_merge_is_associative_and_bucket_exact() {
    // Deterministic pseudo-random samples spanning many buckets.
    let mut x = 0x2545_F491_4F6C_DD1Du64;
    let mut next = move || {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        x % 3_000_000 // 0 µs .. 3 s
    };
    let parts: Vec<Vec<u64>> =
        (0..3).map(|_| (0..500).map(|_| next()).collect()).collect();

    // Associativity: (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c), bucket for bucket.
    let fill = |vals: &[u64]| {
        let h = Histogram::new();
        for &v in vals {
            h.record(v);
        }
        h
    };
    let left = fill(&parts[0]);
    left.merge(&fill(&parts[1]));
    left.merge(&fill(&parts[2]));
    let bc = fill(&parts[1]);
    bc.merge(&fill(&parts[2]));
    let right = fill(&parts[0]);
    right.merge(&bc);
    assert_eq!(left.bucket_counts(), right.bucket_counts());
    assert_eq!(left.count(), right.count());
    assert_eq!(left.sum(), right.sum());

    // Bucket-exactness vs the sorted reference: nearest-rank value v
    // at each percentile maps to exactly bucket_upper(bucket_index(v)).
    let mut sorted: Vec<u64> = parts.iter().flatten().copied().collect();
    sorted.sort_unstable();
    let n = sorted.len() as f64;
    for p in [1.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
        let rank = ((p / 100.0 * n).ceil() as usize).clamp(1, sorted.len());
        let v_true = sorted[rank - 1];
        assert_eq!(
            left.percentile(p),
            bucket_upper(bucket_index(v_true)),
            "p{p}: true value {v_true}"
        );
    }
    // Sum/count survive exactly (they are not bucketized).
    assert_eq!(left.sum(), sorted.iter().sum::<u64>());
    assert_eq!(left.count(), sorted.len() as u64);
}

#[test]
fn flight_recorder_ring_never_exceeds_its_bound() {
    let obs = SessionObs::new(std::sync::Arc::new(FleetObs::new()));
    for k in 0..500u64 {
        obs.record_job(3, FaultJobKind::Write, k, k * 2);
    }
    let tail = obs.flight.tail();
    if cfg!(feature = "telemetry-off") {
        assert!(tail.is_empty(), "telemetry-off flight recorder must be silent");
    } else {
        assert_eq!(tail.len(), 64, "ring holds exactly its bound once saturated");
        // Oldest → newest, contiguous sequence numbers, newest last.
        for w in tail.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1, "tail out of order");
        }
        // seq is 1-based: 500 records ⇒ the newest sample is #500, and
        // it carries the last loop iteration's queue wait (k = 499).
        assert_eq!(tail.last().expect("nonempty").seq, 500);
        assert_eq!(tail.last().expect("nonempty").queue_wait_us, 499);
    }
}

#[test]
fn quarantined_session_fault_carries_the_flight_tail() {
    let res = Resolution::new(8, 8);
    let mut m = SessionManager::new(ServeConfig {
        workers: 2,
        max_sessions: 2,
        max_inflight_batches: 256,
        ..ServeConfig::default()
    });
    // One band (serial FIFO) + batch_size 8 ⇒ each 8-event ingest is
    // exactly one write job, in order. Fire the panic on job 4: jobs
    // 1–3 complete and flight-record first, deterministically.
    let cfg = PipelineConfig {
        stcf: None,
        denoise_shards: 0,
        batch_size: 8,
        window_us: 1 << 40, // no window clock ⇒ no interleaved renders
        router: RouterConfig {
            n_shards: 1,
            isc: IscConfig { bank_size: 48, ..IscConfig::default() },
            ..RouterConfig::default()
        },
        ..PipelineConfig::default()
    };
    let plan = SchedFaultPlan {
        kind: SchedFaultKind::JobPanic,
        fire_on_job: 4,
        stall_ms: 0,
        corrupt_salt: 0,
    };
    let sid = m
        .open_with_fault(
            SessionConfig {
                name: "doomed".into(),
                res,
                t_end_us: 1 << 41,
                pipeline: cfg,
            },
            Some(plan),
        )
        .expect("open armed session");
    let evs = stream(res, 8, 10, 0);
    for _ in 0..4 {
        // Later calls may already see Reject::Quarantined — fine.
        let _ = m.ingest_batch(sid, &evs);
    }
    // Sync point: a checkpoint rides the band FIFO behind the armed
    // jobs, so once it returns the panic has fired and been filed.
    let _ = m.checkpoint(sid);
    assert_eq!(m.stats().supervisor.quarantines, 1, "armed plan must quarantine");
    let faults = m.session_faults(sid).expect("faults listable");
    assert!(!faults.is_empty());
    let recent = &faults[0].recent;
    if cfg!(feature = "telemetry-off") {
        assert!(recent.is_empty(), "telemetry-off faults carry no flight tail");
    } else {
        assert_eq!(recent.len(), 3, "jobs 1-3 precede the job-4 panic: {recent:?}");
        assert!(recent.iter().all(|s| s.job == FaultJobKind::Write));
        for w in recent.windows(2) {
            assert!(w[1].seq > w[0].seq, "tail out of order: {recent:?}");
        }
    }
    m.shutdown();
}

#[test]
fn fleet_frames_match_reference_under_active_telemetry_config() {
    // The bit-for-bit guarantee across feature builds, by transitivity:
    // telemetry-on frames == run_pipeline reference (this test, default
    // build) and telemetry-off frames == the same reference (this test,
    // `--features telemetry-off` build) ⇒ on == off. The reference
    // itself has no telemetry plane at all.
    let t_end = 110_000u64;
    let mut m = SessionManager::new(ServeConfig {
        workers: 3,
        max_sessions: 4,
        max_inflight_batches: 1_024,
        ..ServeConfig::default()
    });
    for (k, stcf) in [(0usize, false), (1, true)] {
        let res = Resolution::new(24, 18);
        let events = stream(res, 400, 260, k as u64);
        let cfg = pipeline_cfg(stcf);
        let sid = m
            .open(SessionConfig {
                name: format!("equiv-{k}"),
                res,
                t_end_us: t_end,
                pipeline: cfg.clone(),
            })
            .expect("open");
        let mut frames = Vec::new();
        for chunk in events.chunks(53) {
            frames.extend(m.ingest_batch(sid, chunk).expect("ingest"));
        }
        frames.extend(m.drain(sid).expect("drain"));
        let reference = run_pipeline(events.iter().copied(), res, t_end, &cfg);
        assert_eq!(
            frames, reference.frames,
            "session {k} frames diverged from the pipeline reference \
             (telemetry-off={})",
            cfg!(feature = "telemetry-off"),
        );
        let report = m.close(sid).expect("close");
        assert_eq!(report.pipeline.events_in, reference.stats.events_in);
        assert_eq!(report.pipeline.events_written, reference.stats.events_written);
        assert_eq!(
            report.pipeline.events_dropped_by_stcf,
            reference.stats.events_dropped_by_stcf
        );
    }
    m.shutdown();
}
