//! PR 7 contracts: sparse session memory.
//!
//! 1. The set-associative cache backend ([`StcfBackend::Cache`]) scores
//!    `support_count` **bit-for-bit equal** to the dense backends for
//!    every event whose probed neighborhood survives in-cache — zero
//!    evictions certifies a whole stream.
//! 2. Lazy band materialization round-trips: a router whose bands
//!    demote after full expiry and rematerialize on the next write
//!    produces frames **identical** to an always-dense (unsharded,
//!    never-demoting) `IscArray` replaying the same causal stream.
//! 3. Never-written bands perform **zero render work** after their
//!    one-time zero fill (extends the PR 3 clean-snapshot assert to
//!    advancing query times), and quiet serve sessions' resident bytes
//!    are independent of sensor resolution and decay back to the cold
//!    constant once every write has expired.

use tsisc::coordinator::router::{BandWriter, Router};
use tsisc::coordinator::{PipelineConfig, RouterConfig};
use tsisc::denoise::{run_stcf, StcfBackend, StcfParams};
use tsisc::events::{Event, LabeledEvent, Polarity, Resolution};
use tsisc::isc::{IscArray, IscConfig};
use tsisc::serve::{ServeConfig, ServeStats, SessionConfig, SessionId, SessionManager};

/// Deterministic pseudo-random labeled stream covering the full sensor
/// (band borders included) with mixed polarity — same shape as the
/// serve_equiv generator so the two suites stress identical layouts.
fn stream(res: Resolution, n: u64, step_us: u64, salt: u64) -> Vec<LabeledEvent> {
    (0..n)
        .map(|k| {
            let x = ((k * 7 + salt * 13) % res.width as u64) as u16;
            let y = ((k * 11 + salt * 5) % res.height as u64) as u16;
            let p = if (k + salt) % 3 == 0 { Polarity::Off } else { Polarity::On };
            LabeledEvent {
                ev: Event::new(1 + k * step_us, x, y, p),
                is_signal: (k + salt) % 4 != 0,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// 1. Cache backend ≡ dense support counts while capacity is not exceeded.
// ---------------------------------------------------------------------------

#[test]
fn cache_scores_equal_dense_bit_for_bit_within_capacity() {
    let res = Resolution::new(32, 24);
    for polarity_sensitive in [false, true] {
        for salt in 0..4u64 {
            let evs = stream(res, 600, 180, salt);
            let prm = StcfParams { polarity_sensitive, ..StcfParams::default() };
            let mut dense = StcfBackend::ideal(res);
            // Capacity comfortably above the live pixel count: the whole
            // stream stays in-cache, so equivalence must be exact.
            let mut cache = StcfBackend::cache(res, 2 * res.pixels());
            let want = run_stcf(&mut dense, &evs, &prm);
            let got = run_stcf(&mut cache, &evs, &prm);
            assert_eq!(
                cache.cache_evictions(),
                Some(0),
                "capacity 2x pixels must never evict (salt {salt})"
            );
            assert_eq!(
                want.scored, got.scored,
                "support scores diverged (salt {salt}, polarity_sensitive {polarity_sensitive})"
            );
            assert_eq!(want.kept, got.kept, "keep/drop decisions diverged (salt {salt})");
        }
    }
}

#[test]
fn cache_matches_dense_across_band_borders_and_tight_threshold() {
    // Tall thin sensor: every row is one band border away from another
    // under 4-way sharding; radius 3 patches straddle them constantly.
    let res = Resolution::new(8, 64);
    let evs = stream(res, 800, 90, 9);
    let prm = StcfParams { threshold: 3, ..StcfParams::default() };
    let mut dense = StcfBackend::ideal(res);
    let mut cache = StcfBackend::cache(res, 4 * res.pixels());
    let want = run_stcf(&mut dense, &evs, &prm);
    let got = run_stcf(&mut cache, &evs, &prm);
    assert_eq!(cache.cache_evictions(), Some(0));
    assert_eq!(want.scored, got.scored);
    assert_eq!(want.kept, got.kept);
}

#[test]
fn cache_under_pressure_only_ever_undercounts() {
    // Deliberately starved cache: evictions must happen, and every
    // divergence from the dense score must be an undercount.
    let res = Resolution::new(32, 24);
    let evs = stream(res, 600, 180, 2);
    let prm = StcfParams::default();
    let mut dense = StcfBackend::ideal(res);
    let mut cache = StcfBackend::cache(res, 64);
    let want = run_stcf(&mut dense, &evs, &prm);
    let got = run_stcf(&mut cache, &evs, &prm);
    let evictions = cache.cache_evictions().expect("cache backend reports evictions");
    assert!(evictions > 0, "64-entry cache over a 768-pixel sensor must evict");
    for (k, (d, c)) in want.scored.iter().zip(&got.scored).enumerate() {
        assert!(
            c.score <= d.score,
            "event {k}: cache score {} exceeds dense score {} — overcounting breaks \
             the bounded-undercount guarantee",
            c.score,
            d.score
        );
    }
}

// ---------------------------------------------------------------------------
// 2. Demote / rematerialize round-trip ≡ always-dense frames.
// ---------------------------------------------------------------------------

#[test]
fn demoted_and_rematerialized_bands_match_always_dense_frames() {
    let res = Resolution::new(16, 12);
    let cfg = IscConfig { bank_size: 32, ..IscConfig::default() };
    let burst = |t0: u64, salt: u64| -> Vec<Event> {
        (0..60u64)
            .map(|k| {
                let p = if (k + salt) % 3 == 0 { Polarity::Off } else { Polarity::On };
                Event::new(
                    t0 + k * 100,
                    ((k * 7 + salt) % res.width as u64) as u16,
                    ((k * 5 + salt) % res.height as u64) as u16,
                    p,
                )
            })
            .collect()
    };
    // Lazy router vs an always-dense reference: one unsharded array that
    // never demotes, replaying the identical causal stream.
    let rcfg = RouterConfig { n_shards: 3, isc: cfg.clone(), ..RouterConfig::default() };
    let mut r = Router::new(res, rcfg);
    let mut dense = IscArray::new(res, cfg);

    let b1 = burst(1_000, 1);
    r.route_batch(&b1);
    dense.write_batch(&b1);
    assert_eq!(r.frame(10_000), dense.frame_merged(10_000), "hot frame");

    // Far past the memory horizon (~102 ms): every band reads all-zero,
    // demotes its array, and later snapshots compose from the cache.
    for &t in &[2_000_000u64, 4_000_000] {
        let f = r.frame(t);
        assert_eq!(f, dense.frame_merged(t), "expired frame at t={t}");
        assert!(f.as_slice().iter().all(|&v| v == 0.0), "expired frame must be zero");
    }

    // Rematerialization: new writes rebuild the band arrays from
    // scratch; position-stable mismatch assignment makes the rebuilt
    // frames bit-for-bit the never-demoted array's.
    let b2 = burst(5_000_000, 9);
    r.route_batch(&b2);
    dense.write_batch(&b2);
    assert_eq!(r.frame(5_100_000), dense.frame_merged(5_100_000), "rematerialized frame");
    r.shutdown();
}

#[test]
fn band_writer_demotes_and_rematerializes_identically() {
    // Single-band variant pinned at the BandWriter level: demote, then
    // verify the rematerialized band renders exactly as a writer that
    // never demoted (fresh writer fed only the second burst — a demoted
    // band *is* a fresh band, that is the contract).
    let res = Resolution::new(8, 8);
    let cfg = IscConfig::default();
    let mut w = BandWriter::for_band(res, &cfg, 8, 0, 1);
    let mut buf = tsisc::util::grid::Grid::new(0, 0, 0.0);

    let mut b1 = [Event::new(500, 3, 3, Polarity::On)];
    w.apply_batch(&mut b1);
    w.snapshot_into(&mut buf, 1_000, false);
    assert!(w.is_materialized());

    // All-zero render far past the horizon → demoted.
    w.snapshot_into(&mut buf, 3_000_000, true);
    assert!(!w.is_materialized(), "fully expired band must demote");

    // Rematerialize with a second burst and compare against a fresh
    // writer that only ever saw that burst.
    let b2 =
        [Event::new(4_000_000, 1, 2, Polarity::Off), Event::new(4_000_100, 2, 2, Polarity::On)];
    let (mut b2a, mut b2b) = (b2, b2);
    w.apply_batch(&mut b2a);
    let mut fresh = BandWriter::for_band(res, &cfg, 8, 0, 1);
    fresh.apply_batch(&mut b2b);
    let mut buf_fresh = tsisc::util::grid::Grid::new(0, 0, 0.0);
    w.snapshot_into(&mut buf, 4_001_000, true);
    fresh.snapshot_into(&mut buf_fresh, 4_001_000, false);
    assert_eq!(buf, buf_fresh, "rematerialized band must render as a fresh band");
}

// ---------------------------------------------------------------------------
// 3. Never-written bands: zero render work; quiet sessions: O(bands) bytes.
// ---------------------------------------------------------------------------

#[test]
fn never_written_bands_snapshot_with_zero_render_work() {
    let res = Resolution::new(16, 16);
    let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
    let n = r.n_shards() as u64;

    // First frame: one-time zero fill per cold band (no array is
    // materialized by reads — asserted at the BandWriter level above).
    let f1 = r.frame(1_000);
    assert!(f1.as_slice().iter().all(|&v| v == 0.0));
    let skips = r.bands_skipped_unchanged();

    // Every later frame at *any* time composes straight from the router
    // cache: no shard round-trip, zero render work. PR 3 asserted this
    // for repeated same-time snapshots; cold bands are empty-static, so
    // it now holds for advancing query times too.
    for (k, &t) in [5_000u64, 50_000, 10_000_000].iter().enumerate() {
        let f = r.frame(t);
        assert!(f.as_slice().iter().all(|&v| v == 0.0));
        assert_eq!(
            r.bands_skipped_unchanged() - skips,
            n * (k as u64 + 1),
            "all {n} never-written bands must skip at t={t}"
        );
    }
    r.shutdown();
}

fn resident(st: &ServeStats, sid: SessionId) -> usize {
    st.sessions
        .iter()
        .find(|s| s.id == sid.raw())
        .map(|s| s.resident_bytes)
        .expect("session present in stats")
}

/// Gauges settle asynchronously (the worker updates its slot's gauge
/// right after replying to the snapshot) — poll briefly instead of
/// racing the worker thread.
fn settle(mut cond: impl FnMut() -> bool) -> bool {
    for _ in 0..400 {
        if cond() {
            return true;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    false
}

#[test]
fn idle_session_resident_bytes_are_resolution_independent() {
    let mut m = SessionManager::new(ServeConfig {
        workers: 2,
        max_sessions: 4,
        max_inflight_batches: 64,
        ..ServeConfig::default()
    });
    let open = |m: &mut SessionManager, res: Resolution| {
        m.open(SessionConfig {
            name: format!("idle-{}x{}", res.width, res.height),
            res,
            t_end_us: 0,
            pipeline: PipelineConfig { stcf: None, denoise_shards: 0, ..PipelineConfig::default() },
        })
        .expect("open idle session")
    };
    let small = open(&mut m, Resolution::new(32, 32));
    let big = open(&mut m, Resolution::new(640, 480));
    let st = m.stats();
    let (sb, bb) = (resident(&st, small), resident(&st, big));
    assert!(sb > 0, "cold sessions still carry their band structs");
    assert_eq!(sb, bb, "cold sessions must not scale with resolution (O(bands), not O(H*W))");
    assert_eq!(st.resident_bytes, sb + bb, "fleet gauge is the per-session sum");
    m.shutdown();
}

#[test]
fn session_resident_bytes_decay_back_to_cold_after_expiry() {
    let mut m = SessionManager::new(ServeConfig {
        workers: 2,
        max_sessions: 2,
        max_inflight_batches: 64,
        ..ServeConfig::default()
    });
    let res = Resolution::new(32, 32);
    let sid = m
        .open(SessionConfig {
            name: "decay".into(),
            res,
            t_end_us: 0,
            pipeline: PipelineConfig { stcf: None, denoise_shards: 0, ..PipelineConfig::default() },
        })
        .expect("open session");
    let cold = resident(&m.stats(), sid);
    assert!(cold > 0);

    let evs = stream(res, 300, 100, 3);
    let t_head = evs.last().expect("non-empty").ev.t;
    m.ingest_batch(sid, &evs).expect("ingest");
    m.snapshot(sid, t_head).expect("hot snapshot");
    assert!(
        settle(|| resident(&m.stats(), sid) > cold),
        "materialized bands must raise the resident gauge above the cold constant"
    );

    // One snapshot far past the horizon renders every band empty and
    // demotes it; the gauge must return exactly to the cold constant.
    m.snapshot(sid, t_head + 3_000_000).expect("expired snapshot");
    assert!(
        settle(|| resident(&m.stats(), sid) == cold),
        "expired bands must demote back to the cold footprint (got {}, want {cold})",
        resident(&m.stats(), sid)
    );
    m.close(sid).expect("close");
    m.shutdown();
}
