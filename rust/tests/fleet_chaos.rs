//! Fleet chaos: seeded in-process fault injection at the scheduler
//! fault points (`tsisc::serve::supervise`), holding the whole fleet to
//! the supervision contract:
//!
//! * clean sessions sharing the fleet with faulty ones stay
//!   **bit-for-bit** equal to a standalone `pipeline::run` of the same
//!   stream — fault isolation never costs exactness;
//! * every injected fault lands in exactly one typed
//!   `SupervisorStats` bucket: injected panics ⇔ quarantined sessions,
//!   injected stalls never quarantine, injected checkpoint corruptions
//!   ⇔ CRC detections;
//! * a quarantined session restored from a checkpoint replays its
//!   stream to exact equality with a never-crashed run, and its fault
//!   board is cleared;
//! * the fleet never deadlocks: every API call returns, teardown
//!   drains, and a watchdog aborts the process if it ever wedges.
//!
//! The whole run derives from one seed (printed on entry; override with
//! `TSISC_CHAOS_SEED`, decimal or `0x…` hex) so any failure replays
//! exactly.

use std::time::Duration;

use tsisc::coordinator::{run_pipeline, PipelineConfig, RouterConfig};
use tsisc::denoise::StcfParams;
use tsisc::events::{Event, LabeledEvent, Polarity, Resolution};
use tsisc::isc::IscConfig;
use tsisc::serve::{
    CheckpointError, Reject, RestoreError, SchedFaultKind, SchedFaultPlan, ServeConfig,
    SessionConfig, SessionId, SessionManager,
};
use tsisc::util::grid::Grid;

/// Seed for the whole run; override with `TSISC_CHAOS_SEED` to replay.
/// Accepts decimal or `0x…` hex (underscores allowed in either).
fn chaos_seed() -> u64 {
    std::env::var("TSISC_CHAOS_SEED")
        .ok()
        .and_then(|raw| {
            let s = raw.trim().replace('_', "");
            match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16).ok(),
                None => s.parse().ok(),
            }
        })
        .unwrap_or(0xC4A0_5EED)
}

/// The no-deadlock property, enforced: if the fleet ever wedges, abort
/// the test binary with a diagnosis instead of hanging CI forever.
fn arm_watchdog(secs: u64) {
    std::thread::spawn(move || {
        std::thread::sleep(Duration::from_secs(secs));
        eprintln!("fleet_chaos watchdog: fleet deadlocked (> {secs}s); aborting");
        std::process::exit(101);
    });
}

/// Deterministic time-sorted stream covering every row of `res`.
fn stream(res: Resolution, n: u64, salt: u64) -> Vec<LabeledEvent> {
    (0..n)
        .map(|k| LabeledEvent {
            ev: Event::new(
                1 + k * 300,
                ((k * 7 + salt) % res.width as u64) as u16,
                ((k * 5 + salt * 3) % res.height as u64) as u16,
                if (k + salt) % 3 == 0 { Polarity::Off } else { Polarity::On },
            ),
            is_signal: true,
        })
        .collect()
}

/// Shape for the faulty sessions: small staging (many early write
/// flushes, so a 1-based `fire_on_job` ≤ 4 always has a job to land
/// on) and 4 bands so one band's fault leaves live neighbors.
fn chaos_pipeline() -> PipelineConfig {
    PipelineConfig {
        stcf: None,
        denoise_shards: 0,
        batch_size: 32,
        router: RouterConfig {
            n_shards: 4,
            isc: IscConfig { bank_size: 48, ..IscConfig::default() },
            ..RouterConfig::default()
        },
        ..PipelineConfig::default()
    }
}

/// Clean-bystander shape `k`: varied STCF stages (none / sharded /
/// sharded-default), band counts and batch sizes, all mismatch-enabled.
fn clean_pipeline(k: usize) -> PipelineConfig {
    let stcf = match k {
        0 => None,
        1 => Some(StcfParams { threshold: 1, ..StcfParams::default() }),
        _ => Some(StcfParams::default()),
    };
    PipelineConfig {
        stcf,
        denoise_shards: [0usize, 2, 3][k % 3],
        batch_size: [64usize, 97, 4_096][k % 3],
        router: RouterConfig {
            n_shards: 1 + k % 4,
            isc: IscConfig { bank_size: 48, ..IscConfig::default() },
            ..RouterConfig::default()
        },
        ..PipelineConfig::default()
    }
}

struct Feed {
    sid: SessionId,
    res: Resolution,
    pipeline: PipelineConfig,
    events: Vec<LabeledEvent>,
    head: usize,
    frames: Vec<(u64, Grid<f64>)>,
    quarantined: bool,
}

/// K faulty + M clean sessions on one fleet: two sessions per fault
/// kind (seed-derived plans over `SchedFaultKind::ALL`) interleaved
/// with three clean bystanders, fed round-robin in uneven chunks.
#[test]
fn seeded_fault_fleet_isolates_faults_and_keeps_clean_sessions_exact() {
    let seed = chaos_seed();
    println!("fleet_chaos seed: {seed:#x} (set TSISC_CHAOS_SEED to replay)");
    arm_watchdog(240);
    let t_end = 130_000u64; // 50 ms windows ⇒ frames at 50 ms and 100 ms

    let mut m = SessionManager::new(ServeConfig {
        workers: 3,
        max_sessions: 32,
        max_inflight_batches: 1 << 20,
        ..ServeConfig::default()
    });

    // Faulty sessions: indices 2k, 2k+1 carry SchedFaultKind::ALL[k].
    let mut feeds: Vec<Feed> = Vec::new();
    let mut birth_blobs: Vec<Option<Vec<u8>>> = Vec::new();
    for (i, kind) in SchedFaultKind::ALL.iter().flat_map(|&k| [k, k]).enumerate() {
        let plan = SchedFaultPlan::from_seed(kind, seed.wrapping_add(i as u64));
        let res = Resolution::new(16, 16);
        let pipeline = chaos_pipeline();
        let sid = m
            .open_with_fault(
                SessionConfig {
                    name: format!("faulty-{i}"),
                    res,
                    t_end_us: t_end,
                    pipeline: pipeline.clone(),
                },
                Some(plan),
            )
            .expect("open faulty session");
        // Birth checkpoint before any ingest: checkpoint jobs never
        // tick the armed-fault ordinal, so this is safe for panic and
        // stall plans — but a CheckpointCorrupt plan would burn its
        // (at-most-once) corruption here, so those skip it.
        birth_blobs.push(if kind == SchedFaultKind::CheckpointCorrupt {
            None
        } else {
            Some(m.checkpoint(sid).expect("birth checkpoint"))
        });
        feeds.push(Feed {
            sid,
            res,
            pipeline,
            events: stream(res, 300, 1_000 + i as u64),
            head: 0,
            frames: Vec::new(),
            quarantined: false,
        });
    }
    let n_faulty = feeds.len();

    // Clean bystanders with varied shapes (incl. sharded STCF).
    for k in 0..3usize {
        let res = [Resolution::new(24, 18), Resolution::new(16, 16), Resolution::new(32, 24)][k];
        let pipeline = clean_pipeline(k);
        let sid = m
            .open(SessionConfig {
                name: format!("clean-{k}"),
                res,
                t_end_us: t_end,
                pipeline: pipeline.clone(),
            })
            .expect("open clean session");
        birth_blobs.push(None);
        feeds.push(Feed {
            sid,
            res,
            pipeline,
            events: stream(res, 400, k as u64),
            head: 0,
            frames: Vec::new(),
            quarantined: false,
        });
    }

    // Round-robin feed in chunks of 37 (coprime to every batch size).
    // A panic session may flip to Quarantined mid-feed — that is the
    // contract, and feeding simply stops there; any other rejection is
    // a fleet bug.
    loop {
        let mut progressed = false;
        for f in feeds.iter_mut() {
            if f.quarantined || f.head >= f.events.len() {
                continue;
            }
            let hi = (f.head + 37).min(f.events.len());
            match m.ingest_batch(f.sid, &f.events[f.head..hi]) {
                Ok(new) => {
                    f.frames.extend(new);
                    f.head = hi;
                }
                Err(Reject::Quarantined { .. }) => f.quarantined = true,
                Err(e) => panic!("unexpected rejection under chaos: {e}"),
            }
            progressed = true;
        }
        if !progressed {
            break;
        }
    }

    // Stall, corrupt and clean sessions drain and match pipeline::run
    // bit-for-bit; only the two panic sessions may be quarantined.
    for (i, f) in feeds.iter_mut().enumerate() {
        let is_panic = i < 2;
        if is_panic {
            continue;
        }
        assert!(!f.quarantined, "session {i} quarantined without an injected panic");
        f.frames.extend(m.drain(f.sid).expect("drain non-panic session"));
        let reference = run_pipeline(f.events.iter().copied(), f.res, t_end, &f.pipeline);
        assert_eq!(
            f.frames, reference.frames,
            "session {i} diverged from pipeline::run under chaos"
        );
    }

    // Panic sessions: force a sync point so the gate observes the filed
    // fault, assert the typed quarantine, then restore from the birth
    // checkpoint and replay the whole stream to exact equality.
    for i in 0..2 {
        let (sid, res, events, pipeline) =
            (feeds[i].sid, feeds[i].res, feeds[i].events.clone(), feeds[i].pipeline.clone());
        let _ = m.drain(sid); // sync: waits on every band's FIFO (or already rejects)
        match m.ingest_batch(sid, &events[..1]) {
            Err(Reject::Quarantined { .. }) => {}
            r => panic!("panic session {i} must be quarantined, got {r:?}"),
        }
        let faults = m.session_faults(sid).expect("quarantined faults are listable");
        assert!(!faults.is_empty(), "quarantined session {i} lists no fault");
        assert!(
            faults[0].detail.contains("injected fault"),
            "fault detail lost the panic payload: {}",
            faults[0].detail
        );

        let birth = birth_blobs[i].as_ref().expect("panic sessions took a birth checkpoint");
        m.restore_in_place(sid, birth).expect("restore quarantined session");
        assert!(
            m.session_faults(sid).expect("faults listable").is_empty(),
            "restore must clear the fault board"
        );
        let mut frames = m.ingest_batch(sid, &events).expect("re-ingest after restore");
        frames.extend(m.drain(sid).expect("drain after restore"));
        let reference = run_pipeline(events.iter().copied(), res, t_end, &pipeline);
        assert_eq!(
            frames, reference.frames,
            "restored session {i} diverged from a never-crashed run"
        );
        let report = m.close(sid).expect("close restored session");
        assert_eq!(report.pipeline.events_in, reference.stats.events_in);
    }

    // Corrupt sessions: the armed fault flips one seeded bit of the
    // first checkpoint taken; the CRC guard must reject it as a typed
    // CrcMismatch (never a silent restore), after which a fresh
    // checkpoint (the fault fires at most once) restores cleanly.
    for i in 4..6 {
        let sid = feeds[i].sid;
        let blob = m.checkpoint(sid).expect("checkpoint corrupt session");
        match m.restore_in_place(sid, &blob) {
            Err(RestoreError::Checkpoint(CheckpointError::CrcMismatch)) => {}
            r => panic!("corrupted checkpoint must fail the CRC guard, got {r:?}"),
        }
        let clean_blob = m.checkpoint(sid).expect("second checkpoint");
        m.restore_in_place(sid, &clean_blob).expect("clean blob restores");
        m.close(sid).expect("close corrupt-plan session");
    }
    for i in (2..4).chain(n_faulty..feeds.len()) {
        m.close(feeds[i].sid).expect("close session");
    }

    // Every injected fault sits in exactly one typed bucket, and the
    // fleet itself stayed healthy: panics were caught at the job-body
    // boundary (no worker death, no respawn, no degraded flag).
    let st = m.shutdown();
    let sup = &st.supervisor;
    assert_eq!(sup.injected_panics, 2, "both panic plans must fire");
    assert_eq!(sup.quarantines, 2, "injected panics ⇔ quarantined sessions");
    assert_eq!(sup.worker_panics, 2, "each injected panic is caught exactly once");
    assert_eq!(sup.injected_stalls, 2, "both stall plans must fire");
    assert_eq!(sup.injected_checkpoint_corruptions, 2, "both corruption plans must fire");
    assert_eq!(
        sup.checkpoint_corruptions_detected, sup.injected_checkpoint_corruptions,
        "every injected corruption must be CRC-detected"
    );
    assert_eq!(sup.restores_completed, 4, "2 panic restores + 2 clean-blob restores");
    assert_eq!(sup.checkpoints_taken, 8, "4 birth + 2 corrupted + 2 clean");
    assert_eq!(sup.worker_respawns, 0, "caught panics must not kill workers");
    assert!(!sup.fleet_degraded, "restart budget untouched ⇒ never degraded");
    assert_eq!(sup.sessions_shed_overloaded, 0);
    assert_eq!(st.open_sessions, 0, "every session closed");
}

/// A stalled job ahead of a snapshot blows the (here: 1 µs) soft
/// deadline: the miss is counted, nothing quarantines, and the frames
/// stay bit-for-bit exact — stalls degrade latency, never results.
#[test]
fn stalled_snapshot_counts_a_deadline_miss_without_quarantine() {
    arm_watchdog(240);
    let mut sc = ServeConfig {
        workers: 1,
        max_sessions: 2,
        max_inflight_batches: 1 << 10,
        ..ServeConfig::default()
    };
    sc.supervisor.snapshot_deadline_us = 1;
    let mut m = SessionManager::new(sc);
    let res = Resolution::new(16, 16);
    let plan = SchedFaultPlan {
        kind: SchedFaultKind::JobStall,
        fire_on_job: 1,
        stall_ms: 5,
        corrupt_salt: 0,
    };
    let sid = m
        .open_with_fault(
            SessionConfig {
                name: "stall".into(),
                res,
                t_end_us: 10_000_000,
                pipeline: chaos_pipeline(),
            },
            Some(plan),
        )
        .expect("open stalled session");

    // The first job is an on-demand snapshot: the armed stall sleeps
    // 5 ms inside it, so its enqueue→completion latency must miss the
    // 1 µs deadline deterministically.
    let cold = m.snapshot(sid, 1_000).expect("snapshot under stall");
    assert_eq!(cold.as_slice().iter().copied().sum::<f64>(), 0.0, "cold snapshot is all zeros");

    let events = stream(res, 64, 7);
    let mut frames = m.ingest_batch(sid, &events).expect("ingest");
    frames.extend(m.drain(sid).expect("drain"));
    let reference = run_pipeline(events.iter().copied(), res, 10_000_000, &chaos_pipeline());
    assert_eq!(frames, reference.frames, "stall changed results, not just latency");

    let st = m.shutdown();
    assert_eq!(st.supervisor.injected_stalls, 1);
    assert_eq!(st.supervisor.quarantines, 0, "a stall must never quarantine");
    assert!(
        st.supervisor.deadline_misses >= 1,
        "a 5 ms stall inside a 1 µs-deadline snapshot must count a miss"
    );
}
