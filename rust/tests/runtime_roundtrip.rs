//! Integration: the AOT artifacts round-trip numerically through the PJRT
//! runtime — kernel outputs match the native Rust simulation, and the
//! train artifacts step without degenerating.
//!
//! Requires `make artifacts`; tests skip loudly when artifacts are absent.
//! The whole suite is compiled out without the `pjrt` feature.

#![cfg(feature = "pjrt")]

use tsisc::events::{Event, Polarity};
use tsisc::runtime::{artifacts_available, default_artifact_dir, KernelTs, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::new(default_artifact_dir()).expect("runtime"))
}

#[test]
fn ts_update_matches_native_isc_decay() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // Nominal (mismatch-free) kernel plane vs the calibrated cell decay.
    let mut plane = KernelTs::new(20e-15, None, 1);
    plane.write(&Event::new(1_000, 10, 20, Polarity::On)).unwrap();
    plane.advance(&mut rt, 1_000).unwrap();
    let v0 = plane.read(10, 20);
    assert!((v0 - 1.2).abs() < 0.05, "fresh write ≈ V_dd, got {v0}");

    // Advance 10 ms in 10 microbatches; compare against the paper's point.
    for k in 1..=10u64 {
        plane.advance(&mut rt, 1_000 + k * 1_000).unwrap();
    }
    let v10 = plane.read(10, 20);
    assert!((v10 - 0.72).abs() < 0.04, "V(10 ms) ≈ 0.72 V, got {v10}");

    // Untouched pixel stays at 0.
    assert_eq!(plane.read(0, 0), 0.0);
}

#[test]
fn ts_frame_normalized_and_consistent() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut plane = KernelTs::new(20e-15, None, 2);
    plane.write(&Event::new(500, 5, 5, Polarity::On)).unwrap();
    plane.write(&Event::new(500, 100, 200, Polarity::On)).unwrap();
    plane.advance(&mut rt, 500).unwrap();
    plane.advance(&mut rt, 20_500).unwrap();
    let f = plane.frame(&mut rt).unwrap();
    assert!(f.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
    // Frame = read/Vdd at every pixel.
    let direct = plane.read(5, 5) / 1.2;
    assert!((f.get(5, 5) - direct).abs() < 1e-5);
}

#[test]
fn stcf_count_artifact_matches_definition() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut plane = KernelTs::new(20e-15, None, 3);
    // Cluster of 3 fresh writes.
    for &(x, y) in &[(50u16, 50u16), (51, 50), (50, 51)] {
        plane.write(&Event::new(100, x, y, Polarity::On)).unwrap();
    }
    plane.advance(&mut rt, 100).unwrap();
    let counts = plane.stcf_counts(&mut rt, 0.383).unwrap();
    // Each cluster member sees the other two (r=3 patch, center excluded).
    assert_eq!(*counts.get(50, 50), 2.0);
    assert_eq!(*counts.get(51, 50), 2.0);
    // A neighbour inside the patch sees all three.
    assert_eq!(*counts.get(52, 51), 3.0);
    // Far away: zero.
    assert_eq!(*counts.get(200, 100), 0.0);
}

#[test]
fn classifier_train_step_reduces_loss_on_fixed_batch() {
    let Some(mut rt) = runtime_or_skip() else { return };
    use tsisc::train::driver::{train_classifier, TrainConfig, BATCH, SIDE};
    use tsisc::train::frames::{Frame, FrameSet};

    // Trivially separable two-class frames.
    let mut frames = Vec::new();
    for i in 0..BATCH * 2 {
        let c = i % 2;
        let mut px = vec![0.0f32; SIDE * SIDE];
        for y in 0..SIDE {
            for x in 0..SIDE {
                if (c == 0) == (x < SIDE / 2) {
                    px[y * SIDE + x] = 1.0;
                }
            }
        }
        frames.push(Frame { pixels: px, label: c, sample_id: i });
    }
    let set = FrameSet { frames, n_classes: 10, n_samples: BATCH * 2 };
    let cfg = TrainConfig { steps: 12, lr: 0.05, seed: 1, log_every: 1 };
    let res = train_classifier(&mut rt, &set, &set, &cfg).expect("train");
    let first = res.loss_curve.first().unwrap().1;
    assert!(
        res.final_loss < first * 0.8,
        "loss should drop: {first} -> {}",
        res.final_loss
    );
    assert!(res.frame_accuracy > 0.9, "separable task acc {}", res.frame_accuracy);
}

#[test]
fn recon_train_step_runs_and_improves() {
    let Some(mut rt) = runtime_or_skip() else { return };
    use tsisc::recon::{train_recon, Pair, ReconConfig, SIDE};

    // Smooth target, noisy input.
    let mut pairs = Vec::new();
    for i in 0..12 {
        let mut input = vec![0.0f32; SIDE * SIDE];
        let mut target = vec![0.0f32; SIDE * SIDE];
        for y in 0..SIDE {
            for x in 0..SIDE {
                let v = 0.5 + 0.4 * ((x as f32) / (4.0 + i as f32)).sin()
                    * ((y as f32) / 5.0).cos();
                target[y * SIDE + x] = v;
                input[y * SIDE + x] = v + 0.1 * ((x * 31 + y * 17 + i) % 7) as f32 / 7.0;
            }
        }
        pairs.push(Pair { input, target });
    }
    let cfg = ReconConfig { steps: 15, lr: 0.2, seed: 3, holdout_every: 4 };
    let res = train_recon(&mut rt, &pairs, &cfg).expect("recon train");
    let first = res.loss_curve.first().unwrap().1;
    assert!(res.final_loss < first, "loss {first} -> {}", res.final_loss);
    assert!(res.mean_ssim > 0.2, "ssim {}", res.mean_ssim);
    assert!(res.n_eval > 0);
}
