//! Chaos test for the TCP front door (`tsisc::serve::net`).
//!
//! One live server, a mixed fleet: clean cameras streaming real batches
//! over loopback TCP while one faulty camera per [`FaultKind`] attacks
//! the wire (truncation, bit flips, mid-frame stalls, abrupt
//! disconnects, duplicate frames). The contract under fire:
//!
//! * no panics anywhere (a panicking handler shows up in
//!   `NetStats::handler_panics` — asserted zero);
//! * every fault lands in its typed `NetStats` bucket;
//! * faulty sessions are **drained, not dropped** — their accounting
//!   balances (`drain_accounting_mismatches == 0`) and no session leaks
//!   past teardown;
//! * clean sessions stay **bit-for-bit identical** to a standalone
//!   `pipeline::run` of the same stream and config, faults or no faults.
//!
//! Deterministic given its seed: set `TSISC_CHAOS_SEED=<u64>` to replay
//! a failing run (the seed is printed on entry).

use std::net::SocketAddr;
use std::time::Duration;

use tsisc::coordinator::run_pipeline;
use tsisc::events::{Event, LabeledEvent, Polarity, Resolution};
use tsisc::serve::net::faults::{run_faulty_camera, FaultKind};
use tsisc::serve::net::{ClientConfig, Hello, NetClient, NetConfig, NetServer};
use tsisc::serve::ServeConfig;
use tsisc::util::grid::Grid;

/// Seed for the whole run; override with `TSISC_CHAOS_SEED` to replay.
/// Accepts decimal or `0x…` hex (underscores allowed in either).
fn chaos_seed() -> u64 {
    std::env::var("TSISC_CHAOS_SEED")
        .ok()
        .and_then(|raw| {
            let s = raw.trim().replace('_', "");
            match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16).ok(),
                None => s.parse().ok(),
            }
        })
        .unwrap_or(0xC4A0_5EED)
}

/// Server shape under test: small fleet, tight read deadline (so the
/// stall fault trips quickly), three-strike error budget, and a small
/// in-flight cap so clean cameras exercise backpressure retries too.
fn chaos_config() -> NetConfig {
    NetConfig {
        serve: ServeConfig {
            workers: 3,
            max_sessions: 16,
            max_inflight_batches: 4,
            ..ServeConfig::default()
        },
        read_timeout: Duration::from_millis(150),
        idle_timeout: Duration::from_secs(5),
        write_timeout: Duration::from_secs(2),
        error_budget: 3,
        max_connections: 32,
        max_frame_bytes: 16 << 20,
        retry_after_ms: 1,
    }
}

/// Stall length for the mid-frame stall fault — comfortably past the
/// server's 150 ms read deadline.
const STALL_MS: u64 = 600;
const T_END_US: u64 = 130_000;

/// Per-camera HELLO: mixed geometries and pipeline shapes (with and
/// without STCF, varied shard/batch choices) so the equivalence check
/// covers more than one code path.
fn clean_hello(k: usize) -> Hello {
    Hello {
        name: format!("clean-{k}"),
        width: [24u16, 32, 16][k % 3],
        height: [18u16, 24, 16][k % 3],
        t_end_us: T_END_US,
        window_us: 50_000,
        batch_size: [64u32, 97, 4_096][k % 3],
        n_shards: 1 + (k as u32 % 3),
        denoise_shards: [0u32, 2, 3][k % 3],
        stcf: k % 3 != 0,
    }
}

/// Deterministic time-sorted stream covering the sensor.
fn stream(w: u16, h: u16, n: u64, step_us: u64, salt: u64) -> Vec<Event> {
    (0..n)
        .map(|k| {
            Event::new(
                1 + k * step_us,
                ((k * 7 + salt) % w as u64) as u16,
                ((k * 5 + salt * 3) % h as u64) as u16,
                if (k + salt) % 3 == 0 { Polarity::Off } else { Polarity::On },
            )
        })
        .collect()
}

/// Drive one clean camera over the wire and return what the server sent
/// back: `(window frames, server frame total)`.
fn run_clean_camera(addr: SocketAddr, k: usize, seed: u64) -> (Vec<(u64, Grid<f64>)>, u64) {
    let hello = clean_hello(k);
    let events = stream(hello.width, hello.height, 400, 300, seed.wrapping_add(k as u64) % 97);
    let mut client = NetClient::connect(
        addr,
        ClientConfig {
            max_retries: 40,
            backoff_cap_ms: 20,
            seed: seed ^ k as u64,
            ..ClientConfig::default()
        },
    )
    .expect("clean camera connects");
    client.hello(&hello).expect("clean HELLO is admitted");
    for chunk in events.chunks(37) {
        client.send_batch(chunk).expect("clean batch is acked");
    }
    // Causal on-demand probe at the stream head: must succeed and must
    // not perturb the window-frame sequence (checked bit-for-bit below).
    let probe_at = events.last().expect("stream nonempty").t;
    let (at, probe) = client.snapshot(probe_at).expect("causal snapshot succeeds");
    assert_eq!(at, probe_at);
    assert_eq!(probe.width(), hello.width as usize);
    assert_eq!(probe.height(), hello.height as usize);
    client.bye().expect("clean BYE completes")
}

#[test]
fn chaos_mixed_fleet_holds_the_contract() {
    let seed = chaos_seed();
    println!("TSISC_CHAOS_SEED={seed}");
    let server = NetServer::bind("127.0.0.1:0", chaos_config()).expect("bind loopback");
    let addr = server.local_addr();

    let clean: Vec<_> = (0..3)
        .map(|k| std::thread::spawn(move || run_clean_camera(addr, k, seed)))
        .collect();
    let faulty: Vec<_> = FaultKind::ALL
        .iter()
        .enumerate()
        .map(|(i, &fault)| {
            std::thread::spawn(move || {
                run_faulty_camera(addr, fault, seed.wrapping_add(i as u64), STALL_MS)
            })
        })
        .collect();

    let mut wire_results = Vec::new();
    for (k, handle) in clean.into_iter().enumerate() {
        wire_results.push((k, handle.join().expect("clean camera thread must not panic")));
    }
    for handle in faulty {
        handle.join().expect("faulty camera thread must not panic");
    }
    let stats = server.shutdown();

    // Clean sessions: bit-for-bit ≡ a standalone pipeline::run of the
    // same stream under the config the HELLO mapped to.
    for (k, (frames, total)) in wire_results {
        let hello = clean_hello(k);
        let events = stream(hello.width, hello.height, 400, 300, seed.wrapping_add(k as u64) % 97);
        let res = Resolution::new(hello.width, hello.height);
        let labeled = events.iter().map(|&ev| LabeledEvent { ev, is_signal: true });
        let reference = run_pipeline(labeled, res, T_END_US, &hello.pipeline_config());
        assert_eq!(
            frames, reference.frames,
            "clean camera {k}: wire frames diverged from pipeline::run"
        );
        assert_eq!(total, reference.stats.frames_emitted, "clean camera {k} frame total");
        assert_eq!(frames.len() as u64, total, "clean camera {k} received ≠ emitted");
    }

    // Every fault kind landed in its typed bucket.
    let n = &stats.net;
    assert!(n.duplicate_batches >= 1, "duplicate fault uncounted: {n:?}");
    assert!(n.deadline_disconnects >= 1, "stall fault uncounted: {n:?}");
    assert!(n.abrupt_disconnects >= 2, "truncate+disconnect faults uncounted: {n:?}");
    assert!(n.checksum_errors >= 3, "bit-flip faults uncounted: {n:?}");
    assert!(n.budget_disconnects >= 1, "error budget never tripped: {n:?}");
    assert!(n.nacks_sent >= 5, "faults must be NACKed where a peer is still listening: {n:?}");

    // Drained, not dropped: every faulted session was drained through
    // close, its accounting balanced, and nothing leaked.
    assert!(n.sessions_drained_on_error >= 4, "faulted sessions must drain: {n:?}");
    assert_eq!(n.drain_accounting_mismatches, 0, "acked events went missing: {n:?}");
    assert_eq!(n.handler_panics, 0, "a connection handler panicked: {n:?}");
    assert_eq!(stats.open_sessions, 0, "sessions leaked past teardown");

    // Bookkeeping sanity: 8 cameras connected, 4 BYEs completed (three
    // clean + the duplicate-fault camera), every admitted HELLO opened.
    assert_eq!(n.connections_accepted, 8, "{n:?}");
    assert_eq!(n.sessions_opened, 8, "{n:?}");
    assert!(n.byes_completed >= 4, "{n:?}");
    assert!(n.batches_acked >= 3 * 11 + 5 * 2, "clean batches must all ack: {n:?}");
}

#[test]
fn overload_sheds_whole_connections_before_degrading_sessions() {
    let cfg = NetConfig {
        max_connections: 1,
        ..chaos_config()
    };
    let server = NetServer::bind("127.0.0.1:0", cfg).expect("bind loopback");
    let addr = server.local_addr();

    // First connection occupies the only slot.
    let mut first = NetClient::connect(addr, ClientConfig::default()).expect("first connects");
    first.hello(&clean_hello(0)).expect("first HELLO admitted");

    // Subsequent connections are shed whole: a SHED NACK at the door,
    // before HELLO — the admitted session's service level is untouched.
    let mut shed_seen = 0;
    for _ in 0..5 {
        let mut extra = match NetClient::connect(addr, ClientConfig::default()) {
            Ok(c) => c,
            Err(_) => continue, // raced the accept loop; connect refused is fine
        };
        match extra.hello(&clean_hello(1)) {
            Err(tsisc::serve::net::NetError::Nacked { code, .. }) => {
                assert_eq!(code, tsisc::serve::net::frame::code::SHED, "shed must use SHED");
                shed_seen += 1;
            }
            Err(_) => {} // connection dropped before the NACK arrived
            Ok(()) => panic!("over-cap connection was admitted"),
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(shed_seen >= 1, "no connection was shed with a typed NACK");

    // The admitted session still works end to end.
    let events = stream(24, 18, 100, 300, 1);
    for chunk in events.chunks(37) {
        first.send_batch(chunk).expect("admitted session keeps its service level");
    }
    let (_frames, _total) = first.bye().expect("admitted session closes cleanly");

    let stats = server.shutdown();
    assert!(stats.net.connections_shed >= 1, "{:?}", stats.net);
    assert_eq!(stats.net.sessions_opened, 1, "{:?}", stats.net);
    assert_eq!(stats.net.drain_accounting_mismatches, 0);
}

#[test]
fn server_shutdown_drains_live_sessions_without_losing_acked_batches() {
    let server = NetServer::bind("127.0.0.1:0", chaos_config()).expect("bind loopback");
    let addr = server.local_addr();

    // A camera sends acked batches and then goes quiet WITHOUT a BYE;
    // server shutdown must drain its session, not drop it.
    let hello = clean_hello(0);
    let events = stream(hello.width, hello.height, 200, 300, 7);
    let mut client = NetClient::connect(addr, ClientConfig::default()).expect("connect");
    client.hello(&hello).expect("admitted");
    for chunk in events.chunks(50) {
        client.send_batch(chunk).expect("acked");
    }

    let stats = server.shutdown();
    assert_eq!(stats.net.drain_accounting_mismatches, 0, "{:?}", stats.net);
    assert_eq!(stats.open_sessions, 0);
    assert_eq!(stats.net.events_ingested, 200, "{:?}", stats.net);
    assert_eq!(stats.net.handler_panics, 0);
}
