//! Contracts of the multi-tenant serve layer (`tsisc::serve`):
//!
//! * session frames ≡ standalone `pipeline::run` **bit-for-bit** across
//!   1/4/16 concurrent sessions with mixed resolutions, mixed pipeline
//!   shapes (inline and sharded STCF, varying band counts and batch
//!   sizes) and **mismatch-enabled** ISC backends — the position-stable
//!   assignment makes band placement irrelevant to results;
//! * bounded per-session queues: a held fleet rejects with
//!   `Reject::Backpressure` instead of buffering unboundedly, and
//!   recovers cleanly once released;
//! * `close` frees the session's bands on the fleet (the live-bands
//!   gauge drops to zero) and invalidates the id;
//! * causal on-demand snapshots never perturb the window frames.

use tsisc::coordinator::{run_pipeline, PipelineConfig, RouterConfig};
use tsisc::denoise::StcfParams;
use tsisc::events::{Event, LabeledEvent, Polarity, Resolution};
use tsisc::isc::IscConfig;
use tsisc::serve::{Reject, ServeConfig, SessionConfig, SessionManager};
use tsisc::util::grid::Grid;

/// Deterministic time-sorted stream covering every row of `res`.
fn stream(res: Resolution, n: u64, step_us: u64, salt: u64) -> Vec<LabeledEvent> {
    (0..n)
        .map(|k| LabeledEvent {
            ev: Event::new(
                1 + k * step_us,
                ((k * 7 + salt) % res.width as u64) as u16,
                ((k * 5 + salt * 3) % res.height as u64) as u16,
                if (k + salt) % 3 == 0 { Polarity::Off } else { Polarity::On },
            ),
            is_signal: true,
        })
        .collect()
}

/// Per-session pipeline shape `k`: varied band counts, batch sizes and
/// STCF stages, always with the default **mismatch-enabled** ISC config
/// (small bank so 16 sessions of band arrays build quickly).
fn pipeline_cfg(k: usize) -> PipelineConfig {
    let stcf = match k % 3 {
        0 => None,
        1 => Some(StcfParams { threshold: 1, ..StcfParams::default() }),
        _ => Some(StcfParams::default()),
    };
    PipelineConfig {
        stcf,
        denoise_shards: [0usize, 2, 3, 1][k % 4],
        batch_size: [64usize, 97, 4_096][k % 3],
        router: RouterConfig {
            n_shards: 1 + k % 4,
            isc: IscConfig { bank_size: 48, ..IscConfig::default() },
            ..RouterConfig::default()
        },
        ..PipelineConfig::default()
    }
}

fn resolution(k: usize) -> Resolution {
    [Resolution::new(24, 18), Resolution::new(32, 24), Resolution::new(16, 16)][k % 3]
}

#[test]
fn session_frames_equal_standalone_pipeline_bitforbit() {
    let t_end = 130_000u64; // 50 ms windows ⇒ frames at 50 ms and 100 ms
    for &n_sessions in &[1usize, 4, 16] {
        let mut m = SessionManager::new(ServeConfig {
            workers: 3,
            max_sessions: 32,
            max_inflight_batches: 4_096,
            ..ServeConfig::default()
        });
        let specs: Vec<(Resolution, Vec<LabeledEvent>, PipelineConfig)> = (0..n_sessions)
            .map(|k| {
                let res = resolution(k);
                (res, stream(res, 400, 300, k as u64), pipeline_cfg(k))
            })
            .collect();
        let sids: Vec<_> = specs
            .iter()
            .enumerate()
            .map(|(k, (res, _, cfg))| {
                m.open(SessionConfig {
                    name: format!("cam-{k}"),
                    res: *res,
                    t_end_us: t_end,
                    pipeline: cfg.clone(),
                })
                .unwrap()
            })
            .collect();
        // Worker threads are the pool's, never the sessions': the fleet
        // reports its fixed size no matter how many sessions are open.
        assert_eq!(m.stats().workers, 3);
        assert_eq!(m.stats().open_sessions, n_sessions);

        // Feed every stream concurrently, round-robin in uneven chunks
        // (coprime to every batch size, so staging boundaries and
        // ingest boundaries interleave freely).
        let mut frames: Vec<Vec<(u64, Grid<f64>)>> = vec![Vec::new(); n_sessions];
        let mut heads = vec![0usize; n_sessions];
        loop {
            let mut progressed = false;
            for (s, (_, events, _)) in specs.iter().enumerate() {
                let lo = heads[s];
                if lo >= events.len() {
                    continue;
                }
                let hi = (lo + 37).min(events.len());
                frames[s].extend(m.ingest_batch(sids[s], &events[lo..hi]).unwrap());
                heads[s] = hi;
                progressed = true;
            }
            if !progressed {
                break;
            }
        }
        for (s, sid) in sids.iter().enumerate() {
            frames[s].extend(m.drain(*sid).unwrap());
        }

        // Every session must match its own standalone pipeline run.
        for (s, (res, events, cfg)) in specs.iter().enumerate() {
            let reference = run_pipeline(events.iter().copied(), *res, t_end, cfg);
            assert_eq!(
                frames[s], reference.frames,
                "n_sessions={n_sessions} session={s} frames diverged from pipeline::run"
            );
            let report = m.close(sids[s]).unwrap();
            assert_eq!(report.pipeline.events_in, reference.stats.events_in);
            assert_eq!(report.pipeline.events_written, reference.stats.events_written);
            assert_eq!(
                report.pipeline.events_dropped_by_stcf,
                reference.stats.events_dropped_by_stcf
            );
            assert_eq!(report.pipeline.frames_emitted, reference.stats.frames_emitted);
            assert_eq!(
                report.pipeline.router.events_routed,
                reference.stats.router.events_routed
            );
            // Per-band accounting, not just the sum: both sides cut the
            // same bands and keep the same events, so the counts match
            // band for band.
            assert_eq!(
                report.pipeline.router.per_shard,
                reference.stats.router.per_shard,
                "session {s} per-band written counts"
            );
            match (&report.pipeline.denoise, &reference.stats.denoise) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.inline_scoring, b.inline_scoring, "session {s}");
                    assert_eq!(a.per_shard, b.per_shard, "session {s} denoise tallies");
                }
                (None, None) => {}
                other => panic!("denoise stats shape diverged: {other:?}"),
            }
        }
        assert_eq!(m.open_bands(), 0, "all sessions closed ⇒ no live bands");
        m.shutdown();
    }
}

#[test]
fn backpressure_rejects_instead_of_buffering() {
    let mut m = SessionManager::new(ServeConfig {
        workers: 1,
        max_sessions: 2,
        max_inflight_batches: 2,
        ..ServeConfig::default()
    });
    let res = Resolution::new(8, 8);
    let mut cfg = pipeline_cfg(0); // no STCF: ingest never waits on jobs
    cfg.batch_size = 8; // every 8-event call flushes
    cfg.window_us = 1 << 40; // no window crossing while held
    let sid = m
        .open(SessionConfig { name: "hot".into(), res, t_end_us: 1 << 41, pipeline: cfg })
        .unwrap();
    let hold = m.hold_workers();
    let evs = stream(res, 8, 10, 0);
    let (mut accepted, mut rejected) = (0u64, 0u64);
    for _ in 0..30 {
        match m.ingest_batch(sid, &evs) {
            Ok(_) => accepted += 1,
            Err(Reject::Backpressure { queued, max }) => {
                assert_eq!(max, 2);
                assert!(queued >= 2, "rejected below the bound: {queued}");
                rejected += 1;
            }
            Err(other) => panic!("unexpected rejection {other:?}"),
        }
    }
    assert!(rejected >= 25, "held fleet must reject almost everything: {rejected}");
    assert!(accepted >= 1);
    let st = m.stats();
    assert_eq!(st.rejected_batches, rejected);
    // The bound is the admission check plus at most one call's own
    // flush — nothing grows with the number of attempts.
    assert!(
        st.sessions[0].peak_queue_depth <= 2 + st.sessions[0].batches_shipped as usize,
        "queue grew unboundedly: {:?}",
        st.sessions[0]
    );
    drop(hold);
    // Released fleet drains; accepted events all land.
    let report = m.close(sid).unwrap();
    assert_eq!(report.pipeline.events_in, accepted * 8);
    assert_eq!(report.pipeline.events_written, accepted * 8);
    assert_eq!(report.stats.rejected_batches, rejected);
    m.shutdown();
}

#[test]
fn close_frees_bands_and_invalidates_the_id() {
    let mut m = SessionManager::new(ServeConfig {
        workers: 2,
        max_sessions: 4,
        max_inflight_batches: 64,
        ..ServeConfig::default()
    });
    let res = Resolution::new(16, 16);
    let mk = |k: usize| SessionConfig {
        name: format!("cam-{k}"),
        res,
        t_end_us: 100_000,
        pipeline: pipeline_cfg(1), // sharded STCF ⇒ scorer bands too
    };
    let a = m.open(mk(0)).unwrap();
    let b = m.open(mk(1)).unwrap();
    let bands_two = m.open_bands();
    assert!(bands_two > 0);
    m.ingest_batch(a, &stream(res, 200, 400, 1)).unwrap();
    m.ingest_batch(b, &stream(res, 200, 400, 2)).unwrap();
    m.drain(a).unwrap();
    m.close(a).unwrap();
    let bands_one = m.open_bands();
    assert!(bands_one < bands_two, "closing a session must free its bands");
    assert_eq!(m.session_count(), 1);
    assert_eq!(m.close(a).unwrap_err(), Reject::UnknownSession(a.raw()));
    assert!(m.snapshot(a, 200_000).is_err());
    m.close(b).unwrap();
    assert_eq!(m.open_bands(), 0);
    m.shutdown();
}

#[test]
fn close_with_staged_and_queued_batches_loses_nothing() {
    // Regression: `close` used to tear a session down without flushing
    // its staging batcher, silently discarding events that had already
    // been acknowledged to the caller. Close must behave like an
    // implicit flush: every ingested event reaches the band writers
    // before the final report is cut.
    let mut m = SessionManager::new(ServeConfig {
        workers: 2,
        max_sessions: 4,
        max_inflight_batches: 64,
        ..ServeConfig::default()
    });
    let res = Resolution::new(16, 16);

    // Session A: a huge batch size keeps everything *staged* (no write
    // batch ever shipped before close).
    let mut staged_cfg = pipeline_cfg(0); // no STCF
    staged_cfg.batch_size = 4_096;
    staged_cfg.window_us = 1 << 40; // no window boundary forces a flush
    let a = m
        .open(SessionConfig {
            name: "staged".into(),
            res,
            t_end_us: 1 << 41,
            pipeline: staged_cfg,
        })
        .unwrap();

    // Session B: a tiny batch size ships many write batches that may
    // still be *queued* on the fleet when close arrives.
    let mut queued_cfg = pipeline_cfg(0);
    queued_cfg.batch_size = 7;
    queued_cfg.window_us = 1 << 40;
    let b = m
        .open(SessionConfig {
            name: "queued".into(),
            res,
            t_end_us: 1 << 41,
            pipeline: queued_cfg,
        })
        .unwrap();

    m.ingest_batch(a, &stream(res, 333, 200, 9)).unwrap();
    m.ingest_batch(b, &stream(res, 320, 200, 4)).unwrap();

    // No drain, no snapshot: close straight away.
    for (sid, n, label) in [(a, 333u64, "staged"), (b, 320u64, "queued")] {
        let report = m.close(sid).unwrap();
        assert_eq!(report.pipeline.events_in, n, "{label}");
        assert_eq!(
            report.pipeline.events_written, n,
            "{label}: close discarded in-flight work"
        );
        assert_eq!(report.pipeline.events_dropped_by_stcf, 0, "{label}");
        // The accounting balance the net layer's drain check relies on.
        assert_eq!(
            report.pipeline.events_in,
            report.pipeline.events_written + report.pipeline.events_dropped_by_stcf,
            "{label}"
        );
    }
    m.shutdown();
}

#[test]
fn causal_on_demand_snapshots_do_not_perturb_window_frames() {
    let res = Resolution::new(24, 18);
    let events = stream(res, 300, 350, 5);
    let cfg = pipeline_cfg(2); // sharded STCF, mismatch enabled
    let t_end = 110_000u64;
    let reference = run_pipeline(events.iter().copied(), res, t_end, &cfg);

    let mut m = SessionManager::new(ServeConfig {
        workers: 2,
        max_sessions: 2,
        max_inflight_batches: 1_024,
        ..ServeConfig::default()
    });
    let sid = m
        .open(SessionConfig {
            name: "probed".into(),
            res,
            t_end_us: t_end,
            pipeline: cfg,
        })
        .unwrap();
    let mut frames = Vec::new();
    for chunk in events.chunks(50) {
        frames.extend(m.ingest_batch(sid, chunk).unwrap());
        // Causal probe at the stream head: flushes staged events and
        // renders, but must leave the window-frame sequence untouched.
        let probe_at = chunk.last().unwrap().ev.t;
        let probe = m.snapshot(sid, probe_at).unwrap();
        assert_eq!(probe.width(), res.width as usize);
    }
    frames.extend(m.drain(sid).unwrap());
    assert_eq!(frames, reference.frames);
    m.close(sid).unwrap();
    m.shutdown();
}
