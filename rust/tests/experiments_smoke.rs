//! Every experiment harness must run to completion at Quick effort and
//! produce a non-trivial report (table2/table3 need artifacts and are
//! exercised when present).

use tsisc::experiments::{Effort, ALL};
#[cfg(feature = "pjrt")]
use tsisc::experiments::find;
#[cfg(feature = "pjrt")]
use tsisc::runtime::artifacts_available;

#[test]
fn all_cheap_experiments_produce_reports() {
    for (name, f) in ALL {
        if matches!(*name, "table2" | "table3") {
            continue; // covered below (artifact-gated, slower)
        }
        let report = f(Effort::Quick);
        assert!(report.len() > 100, "{name} report too short:\n{report}");
        assert!(report.contains("==="), "{name} missing banner");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn table2_runs_when_artifacts_present() {
    if !artifacts_available() {
        eprintln!("SKIP table2: artifacts missing");
        return;
    }
    let report = find("table2").unwrap()(Effort::Quick);
    assert!(report.contains("syn-nmnist"), "{report}");
    assert!(report.contains("3DS-ISC"));
}

#[cfg(feature = "pjrt")]
#[test]
fn table3_runs_when_artifacts_present() {
    if !artifacts_available() {
        eprintln!("SKIP table3: artifacts missing");
        return;
    }
    let report = find("table3").unwrap()(Effort::Quick);
    assert!(report.contains("mean"), "{report}");
    assert!(report.contains("3D-ISC"));
}
