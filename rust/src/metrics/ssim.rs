//! Structural Similarity (SSIM) for the image-reconstruction evaluation
//! (paper Table III) plus PSNR/MSE helpers.
//!
//! Standard Wang et al. SSIM: 8×8 sliding window, C1=(0.01·L)², C2=(0.03·L)²
//! with dynamic range L = 1 (frames are normalized to [0, 1]).

use crate::util::grid::Grid;

const C1: f64 = 0.01 * 0.01;
const C2: f64 = 0.03 * 0.03;
const WIN: usize = 8;

/// Mean SSIM over all valid 8×8 windows (stride 1).
pub fn ssim(a: &Grid<f64>, b: &Grid<f64>) -> f64 {
    assert_eq!(a.width(), b.width());
    assert_eq!(a.height(), b.height());
    let (w, h) = (a.width(), a.height());
    assert!(w >= WIN && h >= WIN, "image smaller than SSIM window");

    // Integral images of x, y, x², y², xy for O(1) window sums.
    let ii = |f: &dyn Fn(usize, usize) -> f64| -> Vec<f64> {
        let mut s = vec![0.0; (w + 1) * (h + 1)];
        for y in 0..h {
            for x in 0..w {
                s[(y + 1) * (w + 1) + (x + 1)] = f(x, y)
                    + s[y * (w + 1) + (x + 1)]
                    + s[(y + 1) * (w + 1) + x]
                    - s[y * (w + 1) + x];
            }
        }
        s
    };
    let sx = ii(&|x, y| *a.get(x, y));
    let sy = ii(&|x, y| *b.get(x, y));
    let sxx = ii(&|x, y| a.get(x, y) * a.get(x, y));
    let syy = ii(&|x, y| b.get(x, y) * b.get(x, y));
    let sxy = ii(&|x, y| a.get(x, y) * b.get(x, y));
    let rect = |s: &[f64], x0: usize, y0: usize| -> f64 {
        let (x1, y1) = (x0 + WIN, y0 + WIN);
        s[y1 * (w + 1) + x1] - s[y0 * (w + 1) + x1] - s[y1 * (w + 1) + x0] + s[y0 * (w + 1) + x0]
    };

    let n = (WIN * WIN) as f64;
    let mut total = 0.0;
    let mut count = 0usize;
    for y0 in 0..=(h - WIN) {
        for x0 in 0..=(w - WIN) {
            let mx = rect(&sx, x0, y0) / n;
            let my = rect(&sy, x0, y0) / n;
            let vx = (rect(&sxx, x0, y0) / n - mx * mx).max(0.0);
            let vy = (rect(&syy, x0, y0) / n - my * my).max(0.0);
            let cov = rect(&sxy, x0, y0) / n - mx * my;
            let s = ((2.0 * mx * my + C1) * (2.0 * cov + C2))
                / ((mx * mx + my * my + C1) * (vx + vy + C2));
            total += s;
            count += 1;
        }
    }
    total / count as f64
}

/// Mean squared error between frames.
pub fn frame_mse(a: &Grid<f64>, b: &Grid<f64>) -> f64 {
    crate::util::stats::mse(a.as_slice(), b.as_slice())
}

/// PSNR (dB) for [0,1] frames.
pub fn psnr(a: &Grid<f64>, b: &Grid<f64>) -> f64 {
    let m = frame_mse(a, b);
    if m == 0.0 {
        f64::INFINITY
    } else {
        -10.0 * m.log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn noise_grid(w: usize, h: usize, seed: u64) -> Grid<f64> {
        let mut r = Pcg64::new(seed);
        Grid::from_fn(w, h, |_, _| r.f64())
    }

    #[test]
    fn identical_images_ssim_one() {
        let g = noise_grid(16, 16, 1);
        assert!((ssim(&g, &g) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_noise_ssim_low() {
        let a = noise_grid(32, 32, 1);
        let b = noise_grid(32, 32, 2);
        let s = ssim(&a, &b);
        assert!(s < 0.2, "ssim={s}");
    }

    #[test]
    fn mild_noise_beats_heavy_noise() {
        let base = noise_grid(32, 32, 3);
        let perturb = |seed: u64, amp: f64| {
            let mut r = Pcg64::new(seed);
            let noise: Vec<f64> = (0..32 * 32).map(|_| r.normal()).collect();
            Grid::from_fn(32, 32, |x, y| {
                (base.get(x, y) + amp * noise[y * 32 + x]).clamp(0.0, 1.0)
            })
        };
        let mild = perturb(4, 0.05);
        let heavy = perturb(5, 0.4);
        assert!(ssim(&base, &mild) > ssim(&base, &heavy));
    }

    #[test]
    fn ssim_symmetric() {
        let a = noise_grid(16, 16, 7);
        let b = noise_grid(16, 16, 8);
        assert!((ssim(&a, &b) - ssim(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn psnr_infinite_for_identical() {
        let g = noise_grid(8, 8, 9);
        assert!(psnr(&g, &g).is_infinite());
    }

    #[test]
    fn constant_offset_reduces_ssim_luminance() {
        let a = Grid::new(16, 16, 0.2);
        let b = Grid::new(16, 16, 0.8);
        let s = ssim(&a, &b);
        assert!(s < 0.9, "ssim={s}");
    }
}
