//! Evaluation metrics: ROC/AUC for denoising (Fig. 10d/12), SSIM for
//! reconstruction (Table III), and frame/video accuracy for classification
//! (Table II).

pub mod accuracy;
pub mod roc;
pub mod ssim;

pub use accuracy::{frame_and_video_accuracy, majority_vote, Confusion};
pub use roc::{roc, BinaryStats, Roc, RocPoint, Scored};
pub use ssim::{frame_mse, psnr, ssim};
