//! ROC / AUC for the denoise evaluation (paper Fig. 10d, Fig. 12).
//!
//! The STCF produces an integer support count per event; sweeping the
//! decision threshold over the count yields the ROC. Positives = signal
//! events kept, negatives = noise events kept.

/// One scored decision: the classifier score (higher = more signal-like)
/// and the ground-truth label.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    pub score: f64,
    pub is_signal: bool,
}

/// A single ROC operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RocPoint {
    /// False-positive rate: noise passed / total noise.
    pub fpr: f64,
    /// True-positive rate: signal passed / total signal.
    pub tpr: f64,
    /// Threshold that produced this point (score ≥ threshold ⇒ keep).
    pub threshold: f64,
}

/// Full ROC curve (sorted by ascending FPR) plus its AUC.
#[derive(Clone, Debug)]
pub struct Roc {
    pub points: Vec<RocPoint>,
    pub auc: f64,
}

/// Build the ROC by sweeping a threshold over all distinct scores.
pub fn roc(scored: &[Scored]) -> Roc {
    let n_pos = scored.iter().filter(|s| s.is_signal).count() as f64;
    let n_neg = scored.len() as f64 - n_pos;
    assert!(n_pos > 0.0 && n_neg > 0.0, "ROC needs both classes");

    // Sort descending by score; walk thresholds at each distinct score.
    let mut sorted: Vec<&Scored> = scored.iter().collect();
    sorted.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());

    let mut points = vec![RocPoint { fpr: 0.0, tpr: 0.0, threshold: f64::INFINITY }];
    let (mut tp, mut fp) = (0.0f64, 0.0f64);
    let mut i = 0;
    while i < sorted.len() {
        let s = sorted[i].score;
        // Consume the tie group.
        while i < sorted.len() && sorted[i].score == s {
            if sorted[i].is_signal {
                tp += 1.0;
            } else {
                fp += 1.0;
            }
            i += 1;
        }
        points.push(RocPoint { fpr: fp / n_neg, tpr: tp / n_pos, threshold: s });
    }
    // Trapezoidal AUC.
    let mut auc = 0.0;
    for w in points.windows(2) {
        auc += (w[1].fpr - w[0].fpr) * 0.5 * (w[0].tpr + w[1].tpr);
    }
    Roc { points, auc }
}

/// Accuracy-style summary at a fixed threshold.
#[derive(Clone, Copy, Debug)]
pub struct BinaryStats {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl BinaryStats {
    pub fn from_scored(scored: &[Scored], threshold: f64) -> Self {
        let mut s = BinaryStats { tp: 0, fp: 0, tn: 0, fn_: 0 };
        for x in scored {
            match (x.score >= threshold, x.is_signal) {
                (true, true) => s.tp += 1,
                (true, false) => s.fp += 1,
                (false, false) => s.tn += 1,
                (false, true) => s.fn_ += 1,
            }
        }
        s
    }

    pub fn tpr(&self) -> f64 {
        self.tp as f64 / (self.tp + self.fn_).max(1) as f64
    }

    pub fn fpr(&self) -> f64 {
        self.fp as f64 / (self.fp + self.tn).max(1) as f64
    }

    pub fn precision(&self) -> f64 {
        self.tp as f64 / (self.tp + self.fp).max(1) as f64
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.tpr();
        if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_classifier_auc_one() {
        let mut s = Vec::new();
        for k in 0..50 {
            s.push(Scored { score: 10.0 + k as f64, is_signal: true });
            s.push(Scored { score: -(k as f64), is_signal: false });
        }
        let r = roc(&s);
        assert!((r.auc - 1.0).abs() < 1e-12, "auc={}", r.auc);
    }

    #[test]
    fn random_classifier_auc_half() {
        let mut rng = crate::util::rng::Pcg64::new(3);
        let s: Vec<Scored> = (0..20_000)
            .map(|_| Scored { score: rng.f64(), is_signal: rng.bool(0.5) })
            .collect();
        let r = roc(&s);
        assert!((r.auc - 0.5).abs() < 0.02, "auc={}", r.auc);
    }

    #[test]
    fn inverted_classifier_auc_zero() {
        let s = vec![
            Scored { score: 0.0, is_signal: true },
            Scored { score: 1.0, is_signal: false },
        ];
        assert!(roc(&s).auc < 1e-12);
    }

    #[test]
    fn roc_endpoints() {
        let s = vec![
            Scored { score: 0.9, is_signal: true },
            Scored { score: 0.1, is_signal: false },
        ];
        let r = roc(&s);
        assert_eq!(r.points.first().unwrap().tpr, 0.0);
        assert_eq!(r.points.last().unwrap().tpr, 1.0);
        assert_eq!(r.points.last().unwrap().fpr, 1.0);
    }

    #[test]
    fn ties_handled_as_one_group() {
        // All same score: single diagonal step → AUC 0.5.
        let s = vec![
            Scored { score: 1.0, is_signal: true },
            Scored { score: 1.0, is_signal: false },
            Scored { score: 1.0, is_signal: true },
            Scored { score: 1.0, is_signal: false },
        ];
        let r = roc(&s);
        assert!((r.auc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn binary_stats_counts() {
        let s = vec![
            Scored { score: 1.0, is_signal: true },  // tp
            Scored { score: 1.0, is_signal: false }, // fp
            Scored { score: 0.0, is_signal: true },  // fn
            Scored { score: 0.0, is_signal: false }, // tn
        ];
        let b = BinaryStats::from_scored(&s, 0.5);
        assert_eq!((b.tp, b.fp, b.tn, b.fn_), (1, 1, 1, 1));
        assert_eq!(b.tpr(), 0.5);
        assert_eq!(b.fpr(), 0.5);
    }
}
