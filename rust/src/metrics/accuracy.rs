//! Classification metrics: frame accuracy, majority-vote video accuracy
//! (paper Sec. IV-D, [35], [57]) and confusion matrices.

/// Confusion matrix over `n` classes.
#[derive(Clone, Debug)]
pub struct Confusion {
    n: usize,
    /// counts[true][pred]
    counts: Vec<u64>,
}

impl Confusion {
    pub fn new(n_classes: usize) -> Self {
        assert!(n_classes > 0);
        Self { n: n_classes, counts: vec![0; n_classes * n_classes] }
    }

    pub fn record(&mut self, truth: usize, pred: usize) {
        assert!(truth < self.n && pred < self.n);
        self.counts[truth * self.n + pred] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn correct(&self) -> u64 {
        (0..self.n).map(|k| self.counts[k * self.n + k]).sum()
    }

    pub fn accuracy(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        self.correct() as f64 / t as f64
    }

    pub fn count(&self, truth: usize, pred: usize) -> u64 {
        self.counts[truth * self.n + pred]
    }

    /// Per-class recall.
    pub fn recall(&self, class: usize) -> f64 {
        let row: u64 = (0..self.n).map(|p| self.count(class, p)).sum();
        if row == 0 {
            return 0.0;
        }
        self.count(class, class) as f64 / row as f64
    }

    /// Render as a small text table.
    pub fn to_table(&self) -> String {
        let mut s = String::from("true\\pred");
        for p in 0..self.n {
            s.push_str(&format!("{p:>7}"));
        }
        s.push('\n');
        for t in 0..self.n {
            s.push_str(&format!("{t:>9}"));
            for p in 0..self.n {
                s.push_str(&format!("{:>7}", self.count(t, p)));
            }
            s.push('\n');
        }
        s
    }
}

/// Majority vote over per-frame predictions (video accuracy). Ties break
/// toward the smallest class index (deterministic).
pub fn majority_vote(frame_preds: &[usize], n_classes: usize) -> usize {
    assert!(!frame_preds.is_empty());
    let mut counts = vec![0u64; n_classes];
    for &p in frame_preds {
        counts[p] += 1;
    }
    counts
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
        .unwrap()
        .0
}

/// Frame + video accuracy from per-sample frame predictions.
/// `samples`: (true label, predictions for each frame of the sample).
pub fn frame_and_video_accuracy(
    samples: &[(usize, Vec<usize>)],
    n_classes: usize,
) -> (f64, f64) {
    let mut frame_conf = Confusion::new(n_classes);
    let mut video_conf = Confusion::new(n_classes);
    for (truth, preds) in samples {
        for &p in preds {
            frame_conf.record(*truth, p);
        }
        if !preds.is_empty() {
            video_conf.record(*truth, majority_vote(preds, n_classes));
        }
    }
    (frame_conf.accuracy(), video_conf.accuracy())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confusion_accuracy() {
        let mut c = Confusion::new(3);
        c.record(0, 0);
        c.record(1, 1);
        c.record(2, 0);
        c.record(2, 2);
        assert_eq!(c.total(), 4);
        assert_eq!(c.correct(), 3);
        assert!((c.accuracy() - 0.75).abs() < 1e-12);
        assert!((c.recall(2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn majority_vote_basic() {
        assert_eq!(majority_vote(&[1, 1, 2], 3), 1);
        assert_eq!(majority_vote(&[0], 3), 0);
    }

    #[test]
    fn majority_vote_tie_breaks_low() {
        assert_eq!(majority_vote(&[2, 1, 1, 2], 3), 1);
    }

    #[test]
    fn video_accuracy_exceeds_frame_when_votes_fix_errors() {
        // Sample of class 0 with frames [0,0,1]: frame acc 2/3, video 1/1.
        let samples = vec![(0usize, vec![0, 0, 1]), (1usize, vec![1, 1, 0])];
        let (fa, va) = frame_and_video_accuracy(&samples, 2);
        assert!((fa - 4.0 / 6.0).abs() < 1e-12);
        assert!((va - 1.0).abs() < 1e-12);
        assert!(va > fa);
    }

    #[test]
    fn table_renders() {
        let mut c = Confusion::new(2);
        c.record(0, 1);
        let t = c.to_table();
        assert!(t.contains("true\\pred"));
    }
}
