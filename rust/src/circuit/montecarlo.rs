//! Monte Carlo mismatch analysis (paper Fig. 5b) and the bridge from
//! circuit simulation to the array-level software model (paper Sec. IV-C).
//!
//! The paper runs 8 000 SPICE MC transients, fits each to the
//! double-exponential f(t) = A1·e^{−t/τ1} + A2·e^{−t/τ2} + b, and assigns
//! fitted parameter tuples to pixels of the software model. We do exactly
//! that: sample mismatched [`LeakageMacro`]s, simulate, fit with
//! [`crate::util::fit`], and hand the tuples to `isc::IscArray`.

use super::cell::{CellSim, LeakageMacro};
use super::params::VDD;
use crate::util::fit::{fit_double_exp, DoubleExp};
use crate::util::rng::Pcg64;
use crate::util::stats;

/// Mismatch magnitudes (σ of ln for lognormal factors). Calibrated so the
/// simulated CV of V_mem at Δt = 10/20/30 ms lands in the paper's band
/// (0.10 % / 0.39 % / 1.28 %, all < 2 %): the junction-floor path carries
/// the large area mismatch, which is what makes CV grow superlinearly.
#[derive(Clone, Copy, Debug)]
pub struct MismatchParams {
    /// σ_ln of the subthreshold conductance.
    pub sigma_g_slow: f64,
    /// σ_ln of the DIBL path.
    pub sigma_g_fast: f64,
    /// σ_ln of the junction floor (largest: area-dominated).
    pub sigma_i_j: f64,
    /// Relative σ of C_mem (MOMCAP matching, ~0.3 % at 20 fF).
    pub sigma_c: f64,
}

impl Default for MismatchParams {
    fn default() -> Self {
        Self { sigma_g_slow: 0.004, sigma_g_fast: 0.01, sigma_i_j: 0.5, sigma_c: 0.002 }
    }
}

/// One Monte Carlo instance of the cell.
pub fn sample_cell(
    c_nominal: f64,
    nominal: &LeakageMacro,
    mm: &MismatchParams,
    rng: &mut Pcg64,
) -> CellSim {
    let leak = nominal.scaled(
        rng.lognormal(1.0, mm.sigma_g_slow),
        rng.lognormal(1.0, mm.sigma_g_fast),
        rng.lognormal(1.0, mm.sigma_i_j),
    );
    let c = c_nominal * rng.normal_ms(1.0, mm.sigma_c).max(0.5);
    CellSim::new(c, leak)
}

/// Result of the Fig. 5b experiment: distribution of V_mem at a probe time.
#[derive(Clone, Debug)]
pub struct VmemDistribution {
    pub dt_s: f64,
    pub mean: f64,
    pub cv_percent: f64,
    pub samples: Vec<f64>,
}

/// Run `n` MC transients of a `c_nominal` LL cell and probe V_mem at each
/// `probe_times` (seconds after write). Mirrors Fig. 5b.
pub fn vmem_distributions(
    c_nominal: f64,
    mm: &MismatchParams,
    probe_times: &[f64],
    n: usize,
    seed: u64,
) -> Vec<VmemDistribution> {
    let nominal = LeakageMacro::ll_calibrated();
    let mut rng = Pcg64::with_stream(seed, 0x5b);
    let mut per_probe: Vec<Vec<f64>> = vec![Vec::with_capacity(n); probe_times.len()];
    for _ in 0..n {
        let cell = sample_cell(c_nominal, &nominal, mm, &mut rng);
        for (k, &t) in probe_times.iter().enumerate() {
            per_probe[k].push(cell.v_at(VDD, t));
        }
    }
    probe_times
        .iter()
        .zip(per_probe)
        .map(|(&dt_s, samples)| VmemDistribution {
            dt_s,
            mean: stats::mean(&samples),
            cv_percent: stats::cv_percent(&samples),
            samples,
        })
        .collect()
}

/// A bank of double-exponential fits of MC transients — the "8 000 fitted
/// MC runs" of the paper's software model. The ISC array samples pixel
/// parameters from this bank.
#[derive(Clone, Debug)]
pub struct FittedBank {
    pub fits: Vec<DoubleExp>,
    pub mean_fit_mse: f64,
}

impl FittedBank {
    /// Build a bank of `n` fitted mismatched cells at `c_nominal`.
    pub fn build(c_nominal: f64, mm: &MismatchParams, n: usize, seed: u64) -> Self {
        let nominal = LeakageMacro::ll_calibrated();
        let mut rng = Pcg64::with_stream(seed, 0xf1);
        let mut fits = Vec::with_capacity(n);
        let mut mses = Vec::with_capacity(n);
        // Fit horizon: past the memory window so the tail is constrained.
        let t_end = 60e-3 * (c_nominal / 20e-15);
        for _ in 0..n {
            let cell = sample_cell(c_nominal, &nominal, mm, &mut rng);
            let (ts, vs) = cell.transient(VDD, t_end, 64);
            let fit = fit_double_exp(&ts, &vs);
            // The array model requires a physical (monotone) discharge; the
            // unconstrained LM fit occasionally flips an amplitude sign to
            // shave residual. Fall back to a constrained single-τ tail fit.
            let params = if fit.params.is_monotone_decay() {
                fit.params
            } else {
                constrained_fallback(&ts, &vs)
            };
            fits.push(params);
            mses.push(fit.mse);
        }
        Self { fits, mean_fit_mse: stats::mean(&mses) }
    }

    /// Draw one pixel's parameters (uniform over the bank).
    pub fn draw(&self, rng: &mut Pcg64) -> DoubleExp {
        self.fits[rng.below(self.fits.len() as u64) as usize]
    }

    /// The nominal (mismatch-free) fit — used for "ideal hardware" ablations
    /// and for deriving comparator thresholds (Fig. 10b).
    pub fn nominal(c: f64) -> DoubleExp {
        let cell = CellSim::new(c, LeakageMacro::ll_calibrated());
        let t_end = 60e-3 * (c / 20e-15);
        let (ts, vs) = cell.transient(VDD, t_end, 96);
        let fit = fit_double_exp(&ts, &vs);
        if fit.params.is_monotone_decay() {
            fit.params
        } else {
            constrained_fallback(&ts, &vs)
        }
    }
}

/// Constrained fallback when the free fit is non-monotone: a two-point
/// double exponential with both amplitudes clamped non-negative, matched
/// to the head and tail of the transient.
fn constrained_fallback(ts: &[f64], vs: &[f64]) -> DoubleExp {
    let n = ts.len();
    let v0 = vs[0];
    // Tail τ from the last third (log-slope).
    let third = n - n / 3;
    let mut tau2 = 20e-3;
    let pts: Vec<(f64, f64)> = ts[third..]
        .iter()
        .zip(&vs[third..])
        .filter(|(_, &v)| v > 1e-9)
        .map(|(&t, &v)| (t, v.ln()))
        .collect();
    if pts.len() >= 2 {
        let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        let (_, slope, _) = crate::util::stats::linreg(&xs, &ys);
        if slope < 0.0 {
            tau2 = -1.0 / slope;
        }
    }
    // Amplitude of the slow part from a mid sample, the rest goes fast.
    let mid = n / 3;
    let a2 = (vs[mid] / (-ts[mid] / tau2).exp()).clamp(0.0, v0);
    let a1 = (v0 - a2).max(0.0);
    DoubleExp { a1, tau1: tau2 / 5.0, a2, tau2, b: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cv_band_matches_paper() {
        // Paper Fig. 5b: CV = 0.10 / 0.39 / 1.28 % at 10/20/30 ms, < 2 %.
        // Bands are generous (our mismatch model is a substitute), but the
        // ordering and <2 % bound are hard requirements.
        let d = vmem_distributions(
            20e-15,
            &MismatchParams::default(),
            &[10e-3, 20e-3, 30e-3],
            400,
            42,
        );
        assert!(d[0].cv_percent < d[1].cv_percent);
        assert!(d[1].cv_percent < d[2].cv_percent);
        for x in &d {
            assert!(x.cv_percent < 2.0, "CV at {} ms = {}", x.dt_s * 1e3, x.cv_percent);
        }
        // Means track the nominal calibration.
        assert!((d[0].mean - 0.72).abs() < 0.03);
        assert!((d[1].mean - 0.46).abs() < 0.03);
        assert!((d[2].mean - 0.30).abs() < 0.03);
    }

    #[test]
    fn fitted_bank_reconstructs_decay() {
        let bank = FittedBank::build(20e-15, &MismatchParams::default(), 32, 7);
        assert_eq!(bank.fits.len(), 32);
        // Fits should be excellent (paper Fig. 9: "very good fit").
        assert!(bank.mean_fit_mse < 1e-4, "mse={}", bank.mean_fit_mse);
        for f in &bank.fits {
            // v(0) ≈ VDD, and decayed values near nominal.
            assert!((f.v0() - VDD).abs() < 0.05, "v0={}", f.v0());
            assert!((f.eval(20e-3) - 0.46).abs() < 0.05);
        }
    }

    #[test]
    fn nominal_fit_matches_cell() {
        let f = FittedBank::nominal(20e-15);
        let cell = CellSim::ll_nominal();
        for &t in &[5e-3, 15e-3, 25e-3, 40e-3] {
            assert!(
                (f.eval(t) - cell.v_at(VDD, t)).abs() < 5e-3,
                "t={t}: fit {} cell {}",
                f.eval(t),
                cell.v_at(VDD, t)
            );
        }
    }

    #[test]
    fn draws_are_deterministic_per_seed() {
        let bank = FittedBank::build(20e-15, &MismatchParams::default(), 16, 3);
        let mut r1 = Pcg64::new(9);
        let mut r2 = Pcg64::new(9);
        for _ in 0..10 {
            assert_eq!(bank.draw(&mut r1), bank.draw(&mut r2));
        }
    }
}
