//! eDRAM storage-cell transient simulation (the SPICE substitute).
//!
//! The cell is a storage capacitor C_mem discharging through the off-state
//! leakage of its access switch. We integrate dV/dt = −I_leak(V)/C with RK4.
//! I_leak(V) is a three-component macro-model aggregating the device physics
//! of [`super::device`]:
//!
//! * `g_slow·V`                — ohmic-like subthreshold floor (dominates the tail),
//! * `g_fast·V·e^{(V−Vdd)/v0}` — DIBL-enhanced channel leakage, active only
//!                                near V_dd (produces the fast initial droop),
//! * `i_j·(1−e^{−V/V_T})`      — junction / GIDL floor, approximately a
//!                                constant current for V ≫ V_T (dominates the
//!                                very end of the decay and carries the large
//!                                area mismatch — this is what makes the
//!                                measured CV grow superlinearly with Δt as
//!                                in the paper's Fig. 5b).
//!
//! The nominal LL-switch model is *calibrated* so a 20 fF cell reproduces
//! the paper's SPICE means: V(10 ms)=0.72 V, V(20 ms)=0.46 V,
//! V(30 ms)=0.30 V and the Fig. 10(b) operating point V(24 ms)=0.383 V,
//! starting from V_reset = V_dd = 1.2 V.

use super::params::{C_MEM_NOMINAL, VDD, VT_THERMAL};
use std::sync::OnceLock;

/// Macro leakage model: total off-state current pulled from the storage node.
#[derive(Clone, Copy, Debug)]
pub struct LeakageMacro {
    /// Ohmic subthreshold conductance (S).
    pub g_slow: f64,
    /// DIBL-enhanced conductance active near V_dd (S).
    pub g_fast: f64,
    /// Voltage scale of the DIBL term (V).
    pub v0: f64,
    /// Junction/GIDL floor current (A).
    pub i_j: f64,
}

impl LeakageMacro {
    /// Total leakage current at storage voltage `v` ≥ 0.
    #[inline]
    pub fn current(&self, v: f64) -> f64 {
        if v <= 0.0 {
            return 0.0;
        }
        self.g_slow * v
            + self.g_fast * v * ((v - VDD) / self.v0).exp()
            + self.i_j * (1.0 - (-v / VT_THERMAL).exp())
    }

    /// Calibrated low-leakage (LL) switch model — see module docs.
    pub fn ll_calibrated() -> LeakageMacro {
        *LL_CAL.get_or_init(calibrate_ll)
    }

    /// Conventional transmission gate: ~20× the channel conductance and a
    /// stronger DIBL term (full V_ds across one device, thin oxide, body
    /// tied to rails). Discharges a 20 fF cell in ≈10 ms (paper Fig. 2d).
    pub fn tg() -> LeakageMacro {
        let ll = Self::ll_calibrated();
        LeakageMacro {
            g_slow: 8.0 * ll.g_slow,
            g_fast: 25.0 * ll.g_fast,
            v0: ll.v0 * 1.3,
            i_j: 12.0 * ll.i_j,
        }
    }

    /// Scale all leakage paths by a mismatch triple — used by Monte Carlo.
    pub fn scaled(&self, f_slow: f64, f_fast: f64, f_j: f64) -> LeakageMacro {
        LeakageMacro {
            g_slow: self.g_slow * f_slow,
            g_fast: self.g_fast * f_fast,
            v0: self.v0,
            i_j: self.i_j * f_j,
        }
    }
}

/// A storage cell: capacitor + leakage model.
#[derive(Clone, Copy, Debug)]
pub struct CellSim {
    pub c: f64,
    pub leak: LeakageMacro,
}

impl CellSim {
    pub fn new(c: f64, leak: LeakageMacro) -> Self {
        assert!(c > 0.0);
        Self { c, leak }
    }

    /// Nominal LL cell at the paper's 20 fF design point.
    pub fn ll_nominal() -> Self {
        Self::new(C_MEM_NOMINAL, LeakageMacro::ll_calibrated())
    }

    /// Voltage at time `t` seconds after a write to `v_init` (RK4, adaptive
    /// fixed-step: 4096 steps over the interval, plenty for these smooth
    /// decays — verified against 4× refinement in tests).
    pub fn v_at(&self, v_init: f64, t: f64) -> f64 {
        if t <= 0.0 {
            return v_init;
        }
        let steps = 4096usize;
        let dt = t / steps as f64;
        let mut v = v_init;
        for _ in 0..steps {
            v = self.rk4_step(v, dt);
            if v <= 0.0 {
                return 0.0;
            }
        }
        v
    }

    /// Full transient: `n` samples of (t, V) uniformly over [0, t_end].
    pub fn transient(&self, v_init: f64, t_end: f64, n: usize) -> (Vec<f64>, Vec<f64>) {
        assert!(n >= 2);
        let steps_per_sample = 64usize;
        let dt = t_end / ((n - 1) * steps_per_sample) as f64;
        let mut ts = Vec::with_capacity(n);
        let mut vs = Vec::with_capacity(n);
        let mut v = v_init;
        ts.push(0.0);
        vs.push(v);
        for k in 1..n {
            for _ in 0..steps_per_sample {
                v = self.rk4_step(v, dt).max(0.0);
            }
            ts.push(t_end * k as f64 / (n - 1) as f64);
            vs.push(v);
        }
        (ts, vs)
    }

    /// Time until the stored voltage decays below `v_floor` (the usable
    /// memory window), or `t_max` if it never does within the horizon.
    pub fn memory_window(&self, v_floor: f64, t_max: f64) -> f64 {
        // Bisection over v_at, which is monotone decreasing in t.
        if self.v_at(VDD, t_max) > v_floor {
            return t_max;
        }
        let (mut lo, mut hi) = (0.0f64, t_max);
        for _ in 0..48 {
            let mid = 0.5 * (lo + hi);
            if self.v_at(VDD, mid) > v_floor {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    #[inline]
    fn rk4_step(&self, v: f64, dt: f64) -> f64 {
        let f = |v: f64| -self.leak.current(v.max(0.0)) / self.c;
        let k1 = f(v);
        let k2 = f(v + 0.5 * dt * k1);
        let k3 = f(v + 0.5 * dt * k2);
        let k4 = f(v + dt * k3);
        v + dt / 6.0 * (k1 + 2.0 * k2 + 2.0 * k3 + k4)
    }
}

/// Minimum usable stored voltage: below this the comparator/readout can no
/// longer separate the value from ground noise; defines the memory window.
pub const V_FLOOR: f64 = 0.12;

static LL_CAL: OnceLock<LeakageMacro> = OnceLock::new();

/// Calibration targets: the paper's SPICE/MC means for the 20 fF LL cell.
pub const CAL_POINTS: [(f64, f64); 4] =
    [(10e-3, 0.72), (20e-3, 0.46), (24e-3, 0.383), (30e-3, 0.30)];

/// Coordinate-descent calibration of the LL macro model against
/// [`CAL_POINTS`]. Runs once per process (~50 ms), cached in a OnceLock.
fn calibrate_ll() -> LeakageMacro {
    // Analytic warm start: tail τ≈23.9 ms ⇒ g_slow = C/τ; the rest small.
    let c = C_MEM_NOMINAL;
    let mut m = LeakageMacro {
        g_slow: c / 23.9e-3,
        g_fast: 0.3 * c / 23.9e-3,
        v0: 0.18,
        i_j: 2e-15,
    };
    let err = |m: &LeakageMacro| -> f64 {
        let cell = CellSim::new(c, *m);
        CAL_POINTS
            .iter()
            .map(|&(t, v)| {
                let e = cell.v_at(VDD, t) - v;
                e * e
            })
            .sum()
    };
    let mut best = err(&m);
    // Multiplicative coordinate descent over the four parameters.
    let mut step = 0.35f64;
    for _round in 0..60 {
        let mut improved = false;
        for p in 0..4 {
            for dir in [1.0 + step, 1.0 / (1.0 + step)] {
                let mut cand = m;
                match p {
                    0 => cand.g_slow *= dir,
                    1 => cand.g_fast *= dir,
                    2 => cand.v0 = (cand.v0 * dir).clamp(0.02, 0.6),
                    _ => cand.i_j *= dir,
                }
                let e = err(&cand);
                if e < best {
                    best = e;
                    m = cand;
                    improved = true;
                }
            }
        }
        if !improved {
            step *= 0.5;
            if step < 1e-3 {
                break;
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_hits_paper_points() {
        let cell = CellSim::ll_nominal();
        for &(t, v) in &CAL_POINTS {
            let got = cell.v_at(VDD, t);
            assert!(
                (got - v).abs() < 0.02,
                "t={} ms: got {got:.3} V want {v} V",
                t * 1e3
            );
        }
    }

    #[test]
    fn decay_is_monotone() {
        let cell = CellSim::ll_nominal();
        let (_, vs) = cell.transient(VDD, 60e-3, 100);
        assert!(vs.windows(2).all(|w| w[1] <= w[0] + 1e-12));
        assert!((vs[0] - VDD).abs() < 1e-12);
    }

    #[test]
    fn rk4_converged_vs_refinement() {
        let cell = CellSim::ll_nominal();
        // Compare the 4096-step answer with a brute-force 65536-step Euler.
        let t = 30e-3;
        let v_rk = cell.v_at(VDD, t);
        let steps = 65536;
        let dt = t / steps as f64;
        let mut v = VDD;
        for _ in 0..steps {
            v -= dt * cell.leak.current(v) / cell.c;
        }
        assert!((v_rk - v).abs() < 1e-3, "rk={v_rk} euler={v}");
    }

    #[test]
    fn tg_discharges_in_10ms_ll_lasts_50ms() {
        // Paper Fig. 2d: TG dead by ~10 ms; LL window > 50 ms at 20 fF.
        let tg = CellSim::new(C_MEM_NOMINAL, LeakageMacro::tg());
        let ll = CellSim::ll_nominal();
        let w_tg = tg.memory_window(V_FLOOR, 0.2);
        let w_ll = ll.memory_window(V_FLOOR, 0.2);
        assert!(w_tg < 12e-3, "TG window {w_tg}");
        assert!(w_ll > 50e-3, "LL window {w_ll}");
    }

    #[test]
    fn fig5a_cmem_sweep_thresholds() {
        // Paper Fig. 5a: C_mem ≥ 10 fF needed for a ≥24 ms memory window.
        let leak = LeakageMacro::ll_calibrated();
        let window = |c_ff: f64| {
            CellSim::new(c_ff * 1e-15, leak).memory_window(V_FLOOR, 0.3)
        };
        assert!(window(5.0) < 24e-3, "5 fF window {}", window(5.0));
        assert!(window(10.0) >= 24e-3, "10 fF window {}", window(10.0));
        assert!(window(20.0) >= 45e-3, "20 fF window {}", window(20.0));
        // Monotone in C.
        assert!(window(40.0) > window(20.0));
    }

    #[test]
    fn fig10b_vtw_operating_points() {
        // Paper Fig. 10b: V_mem(24 ms) = 383 mV @20 fF and ≈172 mV @10 fF.
        let leak = LeakageMacro::ll_calibrated();
        let v20 = CellSim::new(20e-15, leak).v_at(VDD, 24e-3);
        let v10 = CellSim::new(10e-15, leak).v_at(VDD, 24e-3);
        assert!((v20 - 0.383).abs() < 0.02, "v20={v20}");
        assert!((v10 - 0.172).abs() < 0.06, "v10={v10}");
    }

    #[test]
    fn leakage_current_monotone_in_v() {
        let leak = LeakageMacro::ll_calibrated();
        let mut prev = 0.0;
        for k in 0..=24 {
            let i = leak.current(k as f64 * 0.05);
            assert!(i >= prev - 1e-30, "non-monotone at {k}");
            prev = i;
        }
    }

    #[test]
    fn memory_window_scales_with_c() {
        let leak = LeakageMacro::ll_calibrated();
        let w10 = CellSim::new(10e-15, leak).memory_window(V_FLOOR, 0.5);
        let w20 = CellSim::new(20e-15, leak).memory_window(V_FLOOR, 0.5);
        let ratio = w20 / w10;
        assert!((1.6..2.4).contains(&ratio), "ratio={ratio}");
    }
}
