//! Bitcell-technology comparison (paper Table I).
//!
//! The paper compares five prior eDRAM bitcells (1T1C [45], 3T [46],
//! 2T1C [47], 2T [48]) and the proposed 4T1C (2D) / 6T1C (3D) analog cells
//! on data type, pros/cons and — the quantitative row — leakage/retention.
//! Conventional gain cells use thin-oxide minimum devices and retain for
//! only ~100–500 µs; the LL-switch cells hold for tens of ms.

use super::cell::{CellSim, LeakageMacro, V_FLOOR};
use super::params::VDD;

/// The Table-I bitcell families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bitcell {
    /// Classic 1T1C with deep-trench capacitor [45].
    T1C1,
    /// 3T gain cell with boosted supplies [46].
    T3,
    /// 2T1C gain cell, no boosted supplies [47].
    T2C1,
    /// Asymmetric 2T gain cell [48].
    T2,
    /// Proposed analog cell, 2D crossbar variant (4T1C).
    T4C1_2D,
    /// Proposed analog cell, 3D per-pixel variant (6T1C).
    T6C1_3D,
}

impl Bitcell {
    pub const ALL: [Bitcell; 6] =
        [Bitcell::T1C1, Bitcell::T3, Bitcell::T2C1, Bitcell::T2, Bitcell::T4C1_2D, Bitcell::T6C1_3D];

    pub fn name(self) -> &'static str {
        match self {
            Bitcell::T1C1 => "1T1C [45]",
            Bitcell::T3 => "3T [46]",
            Bitcell::T2C1 => "2T1C [47]",
            Bitcell::T2 => "2T [48]",
            Bitcell::T4C1_2D => "2D 4T1C (ours)",
            Bitcell::T6C1_3D => "3D 6T1C (ours)",
        }
    }

    pub fn data_type(self) -> &'static str {
        match self {
            Bitcell::T4C1_2D | Bitcell::T6C1_3D => "Analog",
            _ => "Digital",
        }
    }

    /// Storage capacitance of each cell (deep trench for 1T1C, parasitic
    /// node cap for gain cells, the 20 fF MOMCAP for ours).
    pub fn capacitance(self) -> f64 {
        match self {
            Bitcell::T1C1 => 25e-15,
            Bitcell::T3 => 1.5e-15,
            Bitcell::T2C1 => 3e-15,
            Bitcell::T2 => 1.2e-15,
            Bitcell::T4C1_2D | Bitcell::T6C1_3D => 20e-15,
        }
    }

    /// Access-path leakage model. Thin-oxide single-device gain cells use
    /// the TG-class model scaled to their device sizes; the proposed cells
    /// use the calibrated LL switch.
    pub fn leakage(self) -> LeakageMacro {
        let tg = LeakageMacro::tg();
        match self {
            // Deep-trench 1T1C: moderate leakage, big C → ~300 µs retention.
            Bitcell::T1C1 => LeakageMacro { g_slow: 8.0 * tg.g_slow, g_fast: 8.0 * tg.g_fast, ..tg },
            // 3T gain cell: small node, strong leakage → ~100 µs.
            Bitcell::T3 => LeakageMacro { g_slow: 2.0 * tg.g_slow, g_fast: 2.0 * tg.g_fast, ..tg },
            Bitcell::T2C1 => tg,
            Bitcell::T2 => LeakageMacro { g_slow: 3.0 * tg.g_slow, g_fast: 3.0 * tg.g_fast, ..tg },
            Bitcell::T4C1_2D | Bitcell::T6C1_3D => LeakageMacro::ll_calibrated(),
        }
    }

    /// Simulated retention: time until the stored level decays to V_FLOOR.
    pub fn retention_s(self) -> f64 {
        CellSim::new(self.capacitance(), self.leakage()).memory_window(V_FLOOR, 0.5)
    }

    /// Decay curve for the Table-I leakage row: n samples over [0, t_end].
    pub fn decay_curve(self, t_end: f64, n: usize) -> (Vec<f64>, Vec<f64>) {
        CellSim::new(self.capacitance(), self.leakage()).transient(VDD, t_end, n)
    }

    /// Whether the cell suffers the half-selection issue (Table I cons row:
    /// every crossbar-addressed cell does; the 3D per-pixel cell does not).
    pub fn has_half_select(self) -> bool {
        !matches!(self, Bitcell::T6C1_3D)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conventional_cells_retain_sub_ms() {
        // Table I leakage row: 1T1C/3T/2T1C/2T all decay within ~500 µs.
        for cell in [Bitcell::T1C1, Bitcell::T3, Bitcell::T2C1, Bitcell::T2] {
            let r = cell.retention_s();
            assert!(
                (20e-6..1e-3).contains(&r),
                "{}: retention {r:.2e}",
                cell.name()
            );
        }
    }

    #[test]
    fn proposed_cells_retain_tens_of_ms() {
        for cell in [Bitcell::T4C1_2D, Bitcell::T6C1_3D] {
            let r = cell.retention_s();
            assert!(r > 45e-3, "{}: retention {r:.2e}", cell.name());
        }
    }

    #[test]
    fn only_3d_cell_is_free_of_half_select() {
        let free: Vec<_> = Bitcell::ALL.iter().filter(|c| !c.has_half_select()).collect();
        assert_eq!(free.len(), 1);
        assert_eq!(*free[0], Bitcell::T6C1_3D);
    }

    #[test]
    fn analog_vs_digital_rows() {
        assert_eq!(Bitcell::T6C1_3D.data_type(), "Analog");
        assert_eq!(Bitcell::T1C1.data_type(), "Digital");
    }

    #[test]
    fn decay_curves_start_at_vdd() {
        for cell in Bitcell::ALL {
            let (_, vs) = cell.decay_curve(1e-3, 16);
            assert!((vs[0] - VDD).abs() < 1e-9, "{}", cell.name());
        }
    }
}
