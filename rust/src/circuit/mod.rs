//! SPICE-substitute circuit layer.
//!
//! The paper's circuit evidence comes from Cadence/SPICE transient and
//! Monte Carlo simulation of a TSMC-65nm 6T-1C eDRAM cell. Offline we
//! rebuild that stack analytically:
//!
//! * [`device`] — transistor off-state leakage components (I_c/I_b/I_g) and
//!   the stacked-PMOS vs transmission-gate comparison (Fig. 2c),
//! * [`cell`] — RC transient simulation of the storage node, calibrated to
//!   the paper's measured decay points (Fig. 2d, Fig. 5a, Fig. 9),
//! * [`montecarlo`] — mismatch sampling, CV analysis (Fig. 5b) and the
//!   double-exponential fitted bank that drives the array model (Sec. IV-C),
//! * [`table1`] — the bitcell-family comparison (Table I).

pub mod cell;
pub mod device;
pub mod montecarlo;
pub mod params;
pub mod table1;
pub mod temperature;

pub use cell::{CellSim, LeakageMacro, V_FLOOR};
pub use montecarlo::{FittedBank, MismatchParams};
