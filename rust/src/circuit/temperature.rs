//! Temperature dependence of the eDRAM retention — the classic DRAM
//! non-ideality the paper's "non-ideal characteristics" analysis implies:
//! subthreshold and junction leakage grow exponentially with temperature
//! (roughly 2× per 8–10 °C), so the memory window shrinks and the
//! effective TS time constant drifts. This module extends the calibrated
//! cell model across temperature and quantifies the impact on the STCF
//! operating point (an ablation beyond the paper's room-temperature
//! results; see EXPERIMENTS.md §Ablations).

use super::cell::{CellSim, LeakageMacro, V_FLOOR};
use super::params::VDD;

/// Reference temperature of the calibration (°C).
pub const T_REF_C: f64 = 27.0;

/// Leakage doubling interval for the subthreshold path (°C). 65 nm
/// subthreshold slope ≈ 85–100 mV/dec and V_th temperature coefficient
/// ≈ −1 mV/°C give ≈8–10 °C per doubling; we use 9.
pub const SUBVT_DOUBLING_C: f64 = 9.0;

/// Junction/GIDL leakage doubling interval (°C): steeper, ≈7 °C.
pub const JUNCTION_DOUBLING_C: f64 = 7.0;

/// Scale the calibrated leakage model to temperature `t_c` (°C).
pub fn leakage_at(t_c: f64) -> LeakageMacro {
    let base = LeakageMacro::ll_calibrated();
    let dt = t_c - T_REF_C;
    let f_sub = 2f64.powf(dt / SUBVT_DOUBLING_C);
    let f_jun = 2f64.powf(dt / JUNCTION_DOUBLING_C);
    base.scaled(f_sub, f_sub, f_jun)
}

/// Cell at temperature.
pub fn cell_at(c_mem: f64, t_c: f64) -> CellSim {
    CellSim::new(c_mem, leakage_at(t_c))
}

/// Memory window at temperature (seconds).
pub fn memory_window_at(c_mem: f64, t_c: f64) -> f64 {
    cell_at(c_mem, t_c).memory_window(V_FLOOR, 0.5)
}

/// The comparator threshold V_tw that realizes a τ_tw window at
/// temperature `t_c` — how a temperature-compensated bias generator would
/// retune the STCF operating point (Fig. 10b at other corners).
pub fn vtw_for_window(c_mem: f64, tau_s: f64, t_c: f64) -> f64 {
    cell_at(c_mem, t_c).v_at(VDD, tau_s)
}

/// Effective time-constant drift: the time to decay to V(τ_ref @ 27 °C)
/// at temperature `t_c`, relative to τ_ref. 1.0 = no drift.
pub fn window_shrink_factor(c_mem: f64, tau_ref_s: f64, t_c: f64) -> f64 {
    let v_target = cell_at(c_mem, T_REF_C).v_at(VDD, tau_ref_s);
    let cell = cell_at(c_mem, t_c);
    // Bisection: time for the hot cell to reach the same voltage.
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    if cell.v_at(VDD, hi) > v_target {
        return 1.0; // colder than reference beyond horizon
    }
    for _ in 0..48 {
        let mid = 0.5 * (lo + hi);
        if cell.v_at(VDD, mid) > v_target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi) / tau_ref_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_shrinks_with_temperature() {
        let w27 = memory_window_at(20e-15, 27.0);
        let w55 = memory_window_at(20e-15, 55.0);
        let w85 = memory_window_at(20e-15, 85.0);
        assert!(w27 > w55 && w55 > w85, "{w27} {w55} {w85}");
        // ~2x leakage per ~9 °C ⇒ roughly 8x shorter window at +27 °C.
        let ratio = w27 / w55;
        assert!((4.0..20.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn cold_cell_retains_longer() {
        assert!(memory_window_at(20e-15, 0.0) > memory_window_at(20e-15, 27.0));
    }

    #[test]
    fn reference_temperature_matches_calibration() {
        let cell = cell_at(20e-15, T_REF_C);
        assert!((cell.v_at(VDD, 10e-3) - 0.72).abs() < 0.02);
    }

    #[test]
    fn vtw_retuning_compensates() {
        // At 55 °C the 24 ms window needs a lower comparator threshold.
        let v27 = vtw_for_window(20e-15, 24e-3, 27.0);
        let v55 = vtw_for_window(20e-15, 24e-3, 55.0);
        assert!(v55 < v27, "hot V_tw {v55} should be below {v27}");
        assert!(v55 > 0.0);
    }

    #[test]
    fn shrink_factor_monotone() {
        let f40 = window_shrink_factor(20e-15, 24e-3, 40.0);
        let f70 = window_shrink_factor(20e-15, 24e-3, 70.0);
        assert!(f40 < 1.0);
        assert!(f70 < f40);
    }
}
