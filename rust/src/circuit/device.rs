//! Transistor off-state leakage components (paper Sec. III-A / Fig. 2c).
//!
//! The paper classifies leakage into channel (I_c: subthreshold + DIBL),
//! body (I_b: junction + GIDL), and gate (I_g: tunneling) components and
//! argues the stacked-PMOS LL switch wins because stacking halves V_ds,
//! which suppresses I_c exponentially through DIBL, while the floating well
//! kills the M-node body path and thick oxide removes I_g. This module
//! implements those equations so the claim is *derived*, not asserted; the
//! cell simulator consumes the resulting I(V) curves.

use super::params::{VDD, VT_THERMAL};

/// Off-state leakage model of a single PMOS pass device.
#[derive(Clone, Copy, Debug)]
pub struct DeviceParams {
    /// Subthreshold slope factor n (typ. 1.3–1.5 at 65 nm).
    pub n: f64,
    /// DIBL coefficient η in volts of V_th shift per volt of V_ds.
    pub dibl: f64,
    /// Extrapolated subthreshold current at V_gs = V_th, V_ds = V_dd (A).
    pub i0: f64,
    /// |V_gs| - |V_th| margin in off state (negative = safely off).
    pub vgs_minus_vth: f64,
    /// Reverse junction saturation current of drain/source diodes (A).
    pub i_junction: f64,
    /// GIDL prefactor (A) — field-assisted tunnel leakage at the drain edge.
    pub i_gidl0: f64,
    /// Gate tunneling current density prefactor (A). ~0 for thick oxide.
    pub i_gate0: f64,
}

impl DeviceParams {
    /// Thin-oxide core PMOS used in a conventional transmission gate.
    pub fn tg_pmos() -> Self {
        Self {
            n: 1.4,
            dibl: 0.12,
            i0: 4e-12,
            vgs_minus_vth: -0.35,
            i_junction: 8e-16,
            i_gidl0: 3e-15,
            i_gate0: 5e-14, // thin oxide tunnels
        }
    }

    /// Thick-oxide PMOS used in the LL switch (I/O device: higher V_th,
    /// negligible gate tunneling).
    pub fn ll_pmos() -> Self {
        Self {
            n: 1.45,
            dibl: 0.09,
            i0: 1.2e-12,
            vgs_minus_vth: -0.55,
            i_junction: 2e-16,
            i_gidl0: 4e-16,
            i_gate0: 0.0,
        }
    }

    /// Channel (subthreshold) leakage at drain-source voltage `vds` ≥ 0.
    /// I_c = I0 · e^{(V_gs − V_th + η·V_ds)/(n·V_T)} · (1 − e^{−V_ds/V_T})
    pub fn i_channel(&self, vds: f64) -> f64 {
        let vds = vds.max(0.0);
        let exp_arg = (self.vgs_minus_vth + self.dibl * vds) / (self.n * VT_THERMAL);
        self.i0 * exp_arg.exp() * (1.0 - (-vds / VT_THERMAL).exp())
    }

    /// Body leakage: reverse junction + GIDL (grows with drain-body bias).
    pub fn i_body(&self, vdb: f64) -> f64 {
        let vdb = vdb.max(0.0);
        self.i_junction * (1.0 - (-vdb / VT_THERMAL).exp())
            + self.i_gidl0 * ((vdb / VDD).powi(2))
    }

    /// Gate leakage (tunneling), proportional to gate overdrive area term.
    pub fn i_gate(&self, vgb: f64) -> f64 {
        self.i_gate0 * (vgb.abs() / VDD).powi(2)
    }

    /// Total off-state leakage seen by the storage node at voltage `v`
    /// for a single device holding off `vds = v` (TG case).
    pub fn i_off_total(&self, vds: f64) -> f64 {
        self.i_channel(vds) + self.i_body(vds) + self.i_gate(vds)
    }
}

/// Leakage of the stacked two-PMOS LL switch holding off a storage node at
/// `v` against a bit line at 0 V. The stack splits the drop: device A sees
/// η_split·v, device B sees (1−η_split)·v; steady state is where the two
/// series currents match — we solve it by bisection on the mid-node.
pub fn ll_stack_leakage(dev: &DeviceParams, v: f64) -> f64 {
    if v <= 0.0 {
        return 0.0;
    }
    // Find mid-node voltage m ∈ [0, v] with i(dev, v−m) = i(dev, m).
    let (mut lo, mut hi) = (0.0f64, v);
    for _ in 0..60 {
        let m = 0.5 * (lo + hi);
        let i_top = dev.i_channel(v - m); // storage → mid
        let i_bot = dev.i_channel(m); // mid → bit line
        if i_top > i_bot {
            lo = m;
        } else {
            hi = m;
        }
    }
    let m = 0.5 * (lo + hi);
    // Series current + the storage-side body/gate components (the floating
    // well suppresses the body path — keep the residual junction term).
    dev.i_channel(v - m) + 0.1 * dev.i_body(v - m) + dev.i_gate(v - m)
}

/// Leakage of a conventional transmission gate holding off the same node
/// (full v_ds across one device pair; body tied to rails so the full body
/// path is active).
pub fn tg_leakage(dev: &DeviceParams, v: f64) -> f64 {
    if v <= 0.0 {
        return 0.0;
    }
    dev.i_off_total(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_leak_increases_with_vds() {
        let d = DeviceParams::tg_pmos();
        assert!(d.i_channel(1.2) > d.i_channel(0.6));
        assert!(d.i_channel(0.6) > d.i_channel(0.1));
        assert_eq!(d.i_channel(0.0), 0.0);
    }

    #[test]
    fn stacking_reduces_leakage() {
        // The paper's core circuit claim (Fig. 2c/d): the stacked LL switch
        // leaks far less than a TG at the same stored voltage.
        let tg = DeviceParams::tg_pmos();
        let ll = DeviceParams::ll_pmos();
        for &v in &[0.3, 0.6, 0.9, 1.2] {
            let i_tg = tg_leakage(&tg, v);
            let i_ll = ll_stack_leakage(&ll, v);
            assert!(
                i_ll < i_tg / 5.0,
                "v={v}: LL {i_ll:.3e} not ≪ TG {i_tg:.3e}"
            );
        }
    }

    #[test]
    fn stack_beats_single_device_of_same_kind() {
        // Isolate the stacking effect itself: same device, stacked vs single.
        let ll = DeviceParams::ll_pmos();
        for &v in &[0.6, 1.2] {
            assert!(ll_stack_leakage(&ll, v) < ll.i_off_total(v));
        }
    }

    #[test]
    fn thick_oxide_kills_gate_leak() {
        let ll = DeviceParams::ll_pmos();
        assert_eq!(ll.i_gate(1.2), 0.0);
        let tg = DeviceParams::tg_pmos();
        assert!(tg.i_gate(1.2) > 0.0);
    }

    #[test]
    fn leakage_positive_and_finite() {
        let d = DeviceParams::ll_pmos();
        for k in 0..=24 {
            let v = k as f64 * 0.05;
            let i = ll_stack_leakage(&d, v);
            assert!(i.is_finite() && i >= 0.0);
        }
    }
}
