//! Process and design constants for the 65 nm circuit models.
//!
//! Sources: values quoted directly in the paper (cell size, C_mem, Cu-Cu
//! parasitics, SRAM energies) plus standard 65 nm numbers for wire
//! capacitance and logic energy. Each constant cites where it came from so
//! the architecture model (Fig. 7 / Fig. 8) is auditable line by line.

/// Supply voltage. The paper's decay plots span 0→1.2 V and the MC means
/// (0.72/0.46/0.30 V at 10/20/30 ms) are consistent with V_reset = 1.2 V.
pub const VDD: f64 = 1.2;

/// Thermal voltage kT/q at 300 K.
pub const VT_THERMAL: f64 = 0.02585;

/// Nominal storage capacitor: the M4–M7 interdigitated MOMCAP reaches
/// ≈20 fF in the 4.8 µm × 3.9 µm cell footprint (paper Fig. 4f).
pub const C_MEM_NOMINAL: f64 = 20e-15;

/// ISC cell footprint (paper Fig. 4f): 4.8 µm × 3.9 µm ≈ 18.7 µm², quoted
/// as ≈20 µm² in the text.
pub const CELL_WIDTH_UM: f64 = 4.8;
pub const CELL_HEIGHT_UM: f64 = 3.9;
pub const CELL_AREA_UM2: f64 = CELL_WIDTH_UM * CELL_HEIGHT_UM;

/// MOMCAP density for the M4–M7 interdigitated stack: 20 fF over the cell
/// footprint ⇒ ≈1.07 fF/µm².
pub const MOMCAP_DENSITY_F_PER_UM2: f64 = C_MEM_NOMINAL / CELL_AREA_UM2;

/// Cu-Cu bond parasitics, per [29] (quoted in paper Sec. IV-B):
/// 0.5 fF and 0.2 Ω per bond; transit latency ≈0.08 ns.
pub const CUCU_CAP: f64 = 0.5e-15;
pub const CUCU_RES: f64 = 0.2;
pub const CUCU_DELAY_S: f64 = 0.08e-9;

/// Event write pulse width (paper: both architectures show ~5 ns event
/// write latency).
pub const WRITE_PULSE_S: f64 = 5e-9;

/// LL switch on-resistance during a write. The stacked thick-oxide PMOS
/// pair in the low-resistance state; R_on·C_mem ≈ 0.4 ns ≪ 5 ns pulse, so
/// writes complete within the pulse.
pub const R_ON_LL: f64 = 20e3;

/// Conventional transmission-gate on-resistance (smaller devices).
pub const R_ON_TG: f64 = 5e3;

/// 65 nm metal wire capacitance per µm (M3-level route, typical 0.2 fF/µm).
pub const WIRE_CAP_PER_UM: f64 = 0.2e-15;

/// 65 nm wire resistance per µm (minimum-width intermediate metal).
pub const WIRE_RES_PER_UM: f64 = 1.0;

/// Energy per 2-input gate toggle in 65 nm logic at 1.2 V (≈2 fF switched
/// node ⇒ CV² ≈ 3 fJ); used for encoder/decoder dynamic energy.
pub const GATE_TOGGLE_ENERGY: f64 = 3e-15;

/// Static leakage per logic gate at 65 nm GP, ≈5 nA·V (subthreshold) ⇒ 6 nW.
pub const GATE_LEAK_W: f64 = 6e-9;

/// SRAM write energy per bit for the in-memory design of [53]:
/// 5.1 pJ/bit (paper Sec. IV-B).
pub const SRAM53_WRITE_E_PER_BIT: f64 = 5.1e-12;

/// SRAM static leakage per bit-cell for [53]: 350 pA at 1 V.
pub const SRAM53_LEAK_A_PER_BIT: f64 = 350e-12;
pub const SRAM53_VDD: f64 = 1.0;

/// [26]: 35 mW static for a 346×260×18 b array; 2.4 nJ per 7×7-pixel
/// access; write ≈ 1.5× read (paper's conservative choice).
pub const SRAM26_STATIC_W: f64 = 35e-3;
pub const SRAM26_ARRAY_BITS: f64 = 346.0 * 260.0 * 18.0;
pub const SRAM26_ACCESS_7X7_E: f64 = 2.4e-9;
pub const SRAM26_WRITE_READ_RATIO: f64 = 1.5;

/// 6T SRAM bit-cell area in 65 nm with array overhead (sense amps, WL
/// drivers): the paper's area ratios (3.1× / 2.2× vs our 18.7 µm² cell)
/// imply 16-bit footprints of ≈58/41 µm² ⇒ 3.6 / 2.6 µm² per bit.
pub const SRAM53_AREA_PER_BIT_UM2: f64 = 3.6;
pub const SRAM26_AREA_PER_BIT_UM2: f64 = 2.6;

/// Timestamp precision assumed for the SRAM comparisons (Sec. II-B: n_T ≥ 16).
pub const TIMESTAMP_BITS: u32 = 16;

/// Representative modern-DVS aggregate event rate used for all dynamic
/// power numbers (paper Sec. IV-B): 100 Meps.
pub const EVENT_RATE_EPS: f64 = 100e6;

/// Algorithmic retention requirement (paper Sec. IV-A, citing [51]):
/// the STCF time window needs ≥ 24 ms of memory.
pub const REQUIRED_WINDOW_S: f64 = 24e-3;

/// Comparator V_tw for τ_tw = 24 ms (paper Fig. 10b): 383 mV at 20 fF,
/// 172 mV at 10 fF.
pub const VTW_20FF: f64 = 0.383;
pub const VTW_10FF: f64 = 0.172;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momcap_density_consistent() {
        // 20 fF over 18.72 µm² ⇒ ~1.07 fF/µm², within MOM stack ballpark.
        let d = MOMCAP_DENSITY_F_PER_UM2 * 1e15; // fF/µm²
        assert!((1.0..1.2).contains(&d), "density={d}");
    }

    #[test]
    fn write_completes_within_pulse() {
        // 5 RC time constants fit in the 5 ns pulse.
        assert!(5.0 * R_ON_LL * C_MEM_NOMINAL < WRITE_PULSE_S);
    }

    #[test]
    fn cell_smaller_than_typical_dvs_pixel() {
        // Paper: ≈20 µm² is smaller than most existing DVS pixels
        // (e.g. DAVIS240 18.5 µm pitch ⇒ 342 µm²).
        assert!(CELL_AREA_UM2 < 30.0);
    }
}
