//! Image-reconstruction driver (paper Sec. IV-E / Table III).
//!
//! Synthetic DAVIS recordings provide paired (events, APS frame)
//! supervision. For each representation under comparison, TS frames at
//! APS timestamps become UNet-lite inputs; the Rust driver runs the AOT
//! `recon_train` artifact and scores held-out frames with SSIM.
//!
//! Comparator note (DESIGN.md §1): E2VID's pretrained recurrent network is
//! unavailable offline; the paper's three-way comparison structure is kept
//! by training the *same* decoder on three inputs — the 3DS-ISC analog TS,
//! TORE volumes, and event-count frames (the E2VID-slot baseline).

use crate::events::davis::Recording;
use crate::events::Event;
use crate::metrics::ssim;
use crate::runtime::pjrt::{lit_f32, lit_scalar, to_vec_f32, Runtime};
use crate::train::frames::SurfaceKind;
use crate::tsurface::{EventSink, FrameSource};
use crate::util::grid::Grid;
use crate::util::image::resize_bilinear;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Result};

/// Fixed by the lowered artifact.
pub const BATCH: usize = 8;
pub const SIDE: usize = 64;

/// One paired training example.
#[derive(Clone, Debug)]
pub struct Pair {
    pub input: Vec<f32>,  // SIDE×SIDE TS frame
    pub target: Vec<f32>, // SIDE×SIDE APS frame
}

/// Build (TS frame, APS frame) pairs from a recording using `kind`: the
/// events between consecutive APS timestamps are ingested as one batch,
/// and the TS frame is rendered into a reused buffer (`frame_into`).
pub fn build_pairs(rec: &Recording, kind: &SurfaceKind) -> Vec<Pair> {
    let mut rep = kind.build(rec.res);
    let mut pairs = Vec::with_capacity(rec.frames.len());
    let mut staged: Vec<Event> = Vec::new();
    let mut ts_buf = Grid::new(1, 1, 0.0f64);
    let mut ev_i = 0usize;
    for (t_frame, aps) in &rec.frames {
        staged.clear();
        while ev_i < rec.events.len() && rec.events[ev_i].ev.t <= *t_frame {
            staged.push(rec.events[ev_i].ev);
            ev_i += 1;
        }
        rep.ingest_batch(&staged);
        rep.frame_into(&mut ts_buf, *t_frame);
        let ts = resize_bilinear(&ts_buf, SIDE, SIDE);
        let target = resize_bilinear(aps, SIDE, SIDE);
        pairs.push(Pair {
            input: ts.as_slice().iter().map(|&v| v as f32).collect(),
            target: target.as_slice().iter().map(|&v| v as f32).collect(),
        });
        rep.reset_window();
    }
    pairs
}

/// Training options.
#[derive(Clone, Debug)]
pub struct ReconConfig {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Hold out every k-th pair for evaluation.
    pub holdout_every: usize,
}

impl Default for ReconConfig {
    fn default() -> Self {
        Self { steps: 120, lr: 0.15, seed: 7, holdout_every: 4 }
    }
}

/// Result: loss curve and SSIM on held-out frames.
#[derive(Clone, Debug)]
pub struct ReconResult {
    pub loss_curve: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub mean_ssim: f64,
    pub n_eval: usize,
}

/// Train UNet-lite on pairs and evaluate SSIM on the holdout.
pub fn train_recon(rt: &mut Runtime, pairs: &[Pair], cfg: &ReconConfig) -> Result<ReconResult> {
    if pairs.len() < 2 {
        return Err(anyhow!("need at least 2 pairs"));
    }
    let k = cfg.holdout_every.max(2);
    let (train, eval): (Vec<&Pair>, Vec<&Pair>) = {
        let mut tr = Vec::new();
        let mut ev = Vec::new();
        for (i, p) in pairs.iter().enumerate() {
            if i % k == k - 1 {
                ev.push(p);
            } else {
                tr.push(p);
            }
        }
        (tr, ev)
    };
    let mut params = rt.load_params("recon_params")?;
    let n_params = params.len();
    let mut moms: Vec<xla::Literal> = params
        .iter()
        .map(|p| {
            let shape = p.array_shape()?;
            let n: usize = shape.dims().iter().map(|&d| d as usize).product();
            lit_f32(&vec![0.0; n], shape.dims())
        })
        .collect::<Result<Vec<_>>>()?;

    let mut rng = Pcg64::with_stream(cfg.seed, 0x43c);
    let mut loss_curve = Vec::new();
    let mut final_loss = f32::NAN;
    let dims = [BATCH as i64, 1, SIDE as i64, SIDE as i64];
    for step in 0..cfg.steps {
        let mut xs = Vec::with_capacity(BATCH * SIDE * SIDE);
        let mut ys = Vec::with_capacity(BATCH * SIDE * SIDE);
        for _ in 0..BATCH {
            let p = train[rng.below(train.len() as u64) as usize];
            xs.extend_from_slice(&p.input);
            ys.extend_from_slice(&p.target);
        }
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 * n_params + 3);
        inputs.append(&mut params);
        inputs.append(&mut moms);
        inputs.push(lit_f32(&xs, &dims)?);
        inputs.push(lit_f32(&ys, &dims)?);
        inputs.push(lit_scalar(cfg.lr));
        let exe = rt.load("recon_train")?;
        let mut out = exe.run(&inputs)?;
        let loss_lit = out.pop().unwrap();
        final_loss = loss_lit.get_first_element::<f32>()?;
        moms = out.split_off(n_params);
        params = out;
        if step % 20 == 0 || step + 1 == cfg.steps {
            loss_curve.push((step, final_loss));
        }
    }

    // Evaluation: reconstruct holdout frames and score SSIM.
    let mut ssims = Vec::new();
    let mut i = 0;
    while i < eval.len() {
        let mut xs = Vec::with_capacity(BATCH * SIDE * SIDE);
        let n_real = (eval.len() - i).min(BATCH);
        for kk in 0..BATCH {
            xs.extend_from_slice(&eval[(i + kk).min(eval.len() - 1)].input);
        }
        let mut inputs: Vec<xla::Literal> = params
            .iter()
            .map(|p| {
                let shape = p.array_shape()?;
                lit_f32(&p.to_vec::<f32>()?, shape.dims())
            })
            .collect::<Result<Vec<_>>>()?;
        inputs.push(lit_f32(&xs, &dims)?);
        let exe = rt.load("recon_fwd")?;
        let out = exe.run(&inputs)?;
        let yhat = to_vec_f32(&out[0])?;
        for kk in 0..n_real {
            let rec_frame = Grid::from_vec(
                SIDE,
                SIDE,
                yhat[kk * SIDE * SIDE..(kk + 1) * SIDE * SIDE]
                    .iter()
                    .map(|&v| v as f64)
                    .collect(),
            );
            let target = Grid::from_vec(
                SIDE,
                SIDE,
                eval[i + kk].target.iter().map(|&v| v as f64).collect(),
            );
            ssims.push(ssim(&rec_frame, &target));
        }
        i += n_real;
    }
    Ok(ReconResult {
        loss_curve,
        final_loss,
        mean_ssim: crate::util::stats::mean(&ssims),
        n_eval: ssims.len(),
    })
}
