//! Table I: bitcell-family comparison — data type, retention (simulated
//! leakage row) and half-select susceptibility.

use super::Effort;
use crate::circuit::table1::Bitcell;

pub fn run(_effort: Effort) -> String {
    let mut s = super::banner("Table I — eDRAM bitcell comparison");
    s.push_str(&format!(
        "{:<16} {:>8} {:>9} {:>14} {:>12}\n",
        "cell", "type", "C (fF)", "retention", "half-select"
    ));
    for cell in Bitcell::ALL {
        let r = cell.retention_s();
        let ret = if r >= 1e-3 {
            format!("{:.1} ms", r * 1e3)
        } else {
            format!("{:.0} µs", r * 1e6)
        };
        s.push_str(&format!(
            "{:<16} {:>8} {:>9.1} {:>14} {:>12}\n",
            cell.name(),
            cell.data_type(),
            cell.capacitance() * 1e15,
            ret,
            if cell.has_half_select() { "yes" } else { "no" },
        ));
    }
    s.push_str(
        "\npaper: conventional gain cells decay within ~250-500 µs; the\n\
         proposed LL-switch cells hold tens of ms; only the 3D 6T1C cell\n\
         is free of the half-select hazard.\n",
    );
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_contains_all_cells() {
        let r = super::run(super::Effort::Quick);
        for name in ["1T1C", "3T", "2T1C", "2D 4T1C", "3D 6T1C"] {
            assert!(r.contains(name), "missing {name}");
        }
    }
}
