//! Fig. 7: 3D vs 2D architecture comparison at QVGA/100 Meps — power,
//! area, delay with component breakdowns. Paper headline: 69× power,
//! 1.9× area, 2.2× delay.

use super::Effort;
use crate::arch::arch3d::Workload;
use crate::arch::{arch2d, arch3d, ArchReport, ArrayGeometry};
use crate::events::Resolution;

pub fn run(_effort: Effort) -> String {
    let g = ArrayGeometry::new(Resolution::QVGA);
    let w = Workload::default();
    let r2 = arch2d::report(&g, &w);
    let r3 = arch3d::report(&g, &w);

    let mut s = super::banner("Fig. 7 — 3D vs 2D architecture (QVGA, 100 Meps)");
    s.push_str("--- 2D baseline power ---\n");
    s.push_str(&r2.power.to_table(1e6, "µW"));
    s.push_str("--- 3DS-ISC power ---\n");
    s.push_str(&r3.power.to_table(1e6, "µW"));
    s.push_str("--- 2D baseline area ---\n");
    s.push_str(&r2.area.to_table(1e-6, "mm²"));
    s.push_str("--- 3DS-ISC area ---\n");
    s.push_str(&r3.area.to_table(1e-6, "mm²"));
    s.push_str("--- 2D baseline delay ---\n");
    s.push_str(&r2.delay.to_table(1e9, "ns"));
    s.push_str("--- 3DS-ISC delay ---\n");
    s.push_str(&r3.delay.to_table(1e9, "ns"));

    let (p, a, d) = ArchReport::ratios(&r2, &r3);
    s.push_str(&format!(
        "\nheadline ratios (2D / 3D):   power {p:.1}x   area {a:.2}x   delay {d:.2}x\n\
         paper:                       power 69x     area 1.9x    delay 2.2x\n\
         2D power breakdown: encoder/decoder {:.1} % (paper 53.8 %), \
         buffers {:.1} % (paper 45.5 %)\n",
        r2.power.share_percent("encoder/decoder"),
        r2.power.share_percent("line buffers"),
    ));
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_prints_ratios() {
        let r = super::run(super::Effort::Quick);
        assert!(r.contains("headline ratios"));
        assert!(r.contains("encoder/decoder"));
    }
}
