//! Ablations beyond the paper's figures — the design-choice studies
//! DESIGN.md calls out:
//!
//! * `tau` — STCF window τ_tw sweep: AUC vs window (why 24 ms).
//! * `cmem` — C_mem sweep through the *application* (denoise AUC), not
//!   just the circuit window (extends Fig. 10d's 10/20 fF pair).
//! * `mismatch` — how much cell-to-cell variability the STCF tolerates
//!   (extends Fig. 5b: CV < 2 % is comfortable, but where is the cliff?).
//! * `temperature` — retention vs temperature and the V_tw retuning that
//!   recovers the 24 ms window (circuit/temperature.rs).
//! * `overflow` — the quantized-SAE wraparound artifact vs counter width
//!   (the hazard of Sec. II-B, quantified).

use super::Effort;
use crate::circuit::montecarlo::FittedBank;
use crate::circuit::temperature;
use crate::circuit::MismatchParams;
use crate::denoise::{run_stcf, StcfBackend, StcfParams};
use crate::events::noise::contaminate;
use crate::events::scene::BlobScene;
use crate::events::v2e::{convert, DvsParams};
use crate::events::{LabeledEvent, Resolution};
use crate::isc::IscConfig;
use crate::metrics::roc;
use crate::tsurface::{ingest_labeled, FrameSource, QuantizedSae};

fn stream(res: Resolution, dur: f64) -> Vec<LabeledEvent> {
    let scene = BlobScene::new(res.width, res.height, 3, dur, 7);
    let signal = convert(&scene, res, DvsParams::default(), dur);
    contaminate(&signal, res, 5.0, dur, 19)
}

fn auc_with(events: &[LabeledEvent], res: Resolution, cfg: IscConfig, prm: &StcfParams) -> f64 {
    let mut b = StcfBackend::isc(res, cfg, prm.tau_tw_us);
    let r = run_stcf(&mut b, events, prm);
    roc(&r.scored).auc
}

pub fn run(effort: Effort) -> String {
    let side = effort.scale(48, 80) as u16;
    let dur = effort.scale_f(0.5, 1.5);
    let res = Resolution::new(side, side);
    let events = stream(res, dur);

    let mut s = super::banner("Ablations — design-choice sweeps");
    s.push_str(&format!("(hotel-bar-like stream, {} events, {side}x{side})\n", events.len()));

    // --- τ_tw sweep -----------------------------------------------------
    s.push_str("\n[tau] STCF window sweep (ISC 20 fF):\n");
    for tau_ms in [6u64, 12, 24, 48] {
        let prm = StcfParams { tau_tw_us: tau_ms * 1_000, ..StcfParams::default() };
        let auc = auc_with(&events, res, IscConfig::default(), &prm);
        s.push_str(&format!("  τ_tw = {tau_ms:>3} ms → AUC {auc:.3}\n"));
    }

    // --- C_mem sweep through the application -----------------------------
    s.push_str("\n[cmem] capacitor sweep at τ_tw = 24 ms:\n");
    for c_ff in [5.0, 10.0, 20.0, 40.0] {
        let cfg = IscConfig { c_mem: c_ff * 1e-15, ..IscConfig::default() };
        let auc = auc_with(&events, res, cfg, &StcfParams::default());
        s.push_str(&format!("  C_mem = {c_ff:>4.0} fF → AUC {auc:.3}\n"));
    }
    s.push_str("  (5 fF: V(24 ms) sits below the comparator floor, so the effective\n   window collapses to ~13 ms — the Fig. 5a constraint)\n");

    // --- mismatch severity ----------------------------------------------
    s.push_str("\n[mismatch] variability tolerance (scale x nominal σ):\n");
    for scale in [0.0, 1.0, 4.0, 10.0] {
        let mm = MismatchParams::default();
        let scaled = MismatchParams {
            sigma_g_slow: mm.sigma_g_slow * scale,
            sigma_g_fast: mm.sigma_g_fast * scale,
            sigma_i_j: mm.sigma_i_j * scale,
            sigma_c: mm.sigma_c * scale,
        };
        let cfg = IscConfig {
            mismatch: if scale == 0.0 { None } else { Some(scaled) },
            ..IscConfig::default()
        };
        let auc = auc_with(&events, res, cfg, &StcfParams::default());
        s.push_str(&format!("  {scale:>4.0}x σ → AUC {auc:.3}\n"));
    }

    // --- temperature ------------------------------------------------------
    s.push_str("\n[temperature] retention + V_tw retuning (20 fF):\n");
    for t_c in [0.0, 27.0, 55.0, 85.0] {
        let w = temperature::memory_window_at(20e-15, t_c);
        let vtw = temperature::vtw_for_window(20e-15, 24e-3, t_c);
        s.push_str(&format!(
            "  {t_c:>4.0} °C: window {:>7.1} ms, V_tw(24 ms) = {:>6.3} V\n",
            w * 1e3,
            vtw
        ));
    }

    // --- timestamp overflow -----------------------------------------------
    s.push_str("\n[overflow] quantized-SAE wraparound error vs counter width:\n");
    let horizon_us = (dur * 1e6) as u64;
    for bits in [12u32, 16, 20, 24] {
        let mut q = QuantizedSae::new(res, bits, 24_000.0);
        let mut ideal = crate::tsurface::IdealTs::new(res, 24_000.0);
        ingest_labeled(&mut q, &events, 4_096);
        ingest_labeled(&mut ideal, &events, 4_096);
        let fq = q.frame(horizon_us);
        let fi = ideal.frame(horizon_us);
        let err = crate::metrics::frame_mse(&fq, &fi).sqrt();
        let wrap_ms = crate::arch::sram::timestamp_wrap_period_s(bits, 1.0) * 1e3;
        s.push_str(&format!(
            "  {bits:>3} b (wraps every {wrap_ms:>9.1} ms): TS RMSE vs ideal = {err:.4}\n"
        ));
    }
    s.push_str("  (the analog array never wraps — its error is the <2 % mismatch CV)\n");

    // Nominal decay reference for context.
    let f = FittedBank::nominal(20e-15);
    s.push_str(&format!(
        "\nnominal cell: τ_fast {:.1} ms, τ_slow {:.1} ms (double-exp fit)\n",
        f.tau1 * 1e3,
        f.tau2 * 1e3
    ));
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn ablation_report_has_all_sections() {
        let r = super::run(super::Effort::Quick);
        for sec in ["[tau]", "[cmem]", "[mismatch]", "[temperature]", "[overflow]"] {
            assert!(r.contains(sec), "missing {sec}\n{r}");
        }
    }

    #[test]
    fn overflow_error_decreases_with_bits() {
        let r = super::run(super::Effort::Quick);
        let errs: Vec<f64> = r
            .lines()
            .filter(|l| l.contains("TS RMSE vs ideal"))
            .map(|l| l.split("= ").nth(1).unwrap().trim().parse().unwrap())
            .collect();
        assert_eq!(errs.len(), 4);
        assert!(errs[0] >= errs[3], "12b err {} < 24b err {}", errs[0], errs[3]);
    }
}
