//! Sec. II-B: representation resource comparison — memory footprint and
//! memory-writes-per-event across all implemented 2D representations
//! (the argument for why SAE/TS-class surfaces suit low-energy hardware
//! while SITS/TOS do not).

use super::Effort;
use crate::events::scene::EdgeScene;
use crate::events::v2e::{convert, DvsParams};
use crate::events::Resolution;
use crate::tsurface::*;

pub fn run(effort: Effort) -> String {
    let side = effort.scale(48, 96) as u16;
    let dur = effort.scale_f(0.3, 1.0);
    let res = Resolution::new(side, side);
    let events = convert(&EdgeScene::new(120.0, 5), res, DvsParams::default(), dur);

    let mut reps: Vec<Box<dyn Representation>> = vec![
        Box::new(Ebbi::new(res)),
        Box::new(EventCount::new(res, 4)),
        Box::new(Sae::new(res)),
        Box::new(IdealTs::new(res, 24_000.0)),
        Box::new(QuantizedSae::new(res, 16, 24_000.0)),
        Box::new(Sits::new(res, 3)),
        Box::new(Tos::new(res, 3)),
        Box::new(Tore::new(res, 3, 100.0, 1e6)),
        Box::new(IscTs::with_defaults(res)),
    ];
    for rep in reps.iter_mut() {
        ingest_labeled(rep.as_mut(), &events, 4_096);
    }

    let mut s = super::banner("Sec. II-B — representation resource comparison");
    s.push_str(&format!(
        "({} events, {side}x{side})\n{:<16} {:>14} {:>16}\n",
        events.len(),
        "representation",
        "bits/pixel",
        "writes/event"
    ));
    for rep in &reps {
        s.push_str(&format!(
            "{:<16} {:>14.1} {:>16.2}\n",
            rep.name(),
            rep.memory_bits() as f64 / res.pixels() as f64,
            rep.writes_per_event()
        ));
    }
    s.push_str(
        "\npaper: SAE-class surfaces need 1 write/event; SITS/TOS need\n\
         ~25-50x more, making them hostile to low-energy hardware. TORE\n\
         needs ≥96 b/pixel (≈16x the ISC cell's effective storage).\n",
    );
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn write_amplification_ordering() {
        let r = super::run(super::Effort::Quick);
        assert!(r.contains("SITS"));
        assert!(r.contains("3DS-ISC"));
        // SITS writes/event must exceed SAE's.
        let get = |name: &str| -> f64 {
            r.lines()
                .find(|l| l.starts_with(name))
                .unwrap()
                .split_whitespace()
                .last()
                .unwrap()
                .parse()
                .unwrap()
        };
        assert!(get("SITS") > 5.0 * get("SAE"));
    }
}
