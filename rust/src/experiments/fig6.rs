//! Fig. 6: array-level visualization — SAE timestamps (a) vs the analog
//! V_mem time-surface (b) for the same event sequence. Emits ASCII art
//! and optional PGM dumps.

use super::Effort;
use crate::events::scene::BlobScene;
use crate::events::v2e::{convert, DvsParams};
use crate::events::Resolution;
use crate::isc::{IscArray, IscConfig};
use crate::tsurface::{EventSink, FrameSource, Sae};

fn ascii(g: &crate::util::grid::Grid<f64>) -> String {
    let ramp = b" .:-=+*#%@";
    let (lo, hi) = crate::util::stats::min_max(g.as_slice());
    let span = (hi - lo).max(1e-12);
    let mut s = String::new();
    // Downsample to ≤64 columns for terminal display.
    let step = (g.width() / 64).max(1);
    for y in (0..g.height()).step_by(step) {
        for x in (0..g.width()).step_by(step) {
            let v = (g.get(x, y) - lo) / span;
            let idx = ((v * (ramp.len() - 1) as f64).round() as usize).min(ramp.len() - 1);
            s.push(ramp[idx] as char);
        }
        s.push('\n');
    }
    s
}

pub fn run(effort: Effort) -> String {
    let side = effort.scale(48, 128) as u16;
    let dur = effort.scale_f(0.3, 0.8);
    let res = Resolution::new(side, side);
    let scene = BlobScene::new(side, side, 2, dur, 11);
    let events = convert(&scene, res, DvsParams::default(), dur);
    let t_end = (dur * 1e6) as u64;

    let mut sae = Sae::new(res);
    let mut isc = IscArray::new(res, IscConfig::default());
    // Bounded staging: both sinks share one ≤4096-event raw-event buffer
    // instead of duplicating the whole stream.
    let mut staged = Vec::with_capacity(4_096.min(events.len()));
    for part in events.chunks(4_096) {
        staged.clear();
        staged.extend(part.iter().map(|le| le.ev));
        sae.ingest_batch(&staged);
        isc.write_batch(&staged);
    }

    let mut s = super::banner("Fig. 6 — SAE timestamps vs analog V_mem TS");
    s.push_str(&format!("({} events over {:.1} s at {side}x{side})\n", events.len(), dur));
    s.push_str("\n(a) SAE raw timestamps (normalized):\n");
    s.push_str(&ascii(&sae.frame(t_end)));
    s.push_str("\n(b) ISC analog V_mem (normalized, with cell variability):\n");
    s.push_str(&ascii(&isc.frame_merged(t_end)));
    s.push_str(
        "\npaper: the latest events read near V_reset (bright), older ones\n\
         decay toward 0 — the analog plane is a self-normalizing TS.\n",
    );

    // Also dump PGMs next to the binary for visual inspection.
    let _ = std::fs::write("fig6_sae.pgm", sae.frame(t_end).to_pgm());
    let _ = std::fs::write("fig6_isc.pgm", isc.frame_merged(t_end).to_pgm());
    s.push_str("(wrote fig6_sae.pgm / fig6_isc.pgm)\n");
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_renders_both_panels() {
        let r = super::run(super::Effort::Quick);
        assert!(r.contains("(a) SAE"));
        assert!(r.contains("(b) ISC"));
        let _ = std::fs::remove_file("fig6_sae.pgm");
        let _ = std::fs::remove_file("fig6_isc.pgm");
    }
}
