//! Fig. 2(d): V_mem decay of the LL switch vs a conventional transmission
//! gate at C_mem = 20 fF.

use super::Effort;
use crate::circuit::cell::{CellSim, LeakageMacro, V_FLOOR};
use crate::circuit::params::{C_MEM_NOMINAL, VDD};

pub fn run(effort: Effort) -> String {
    let n = effort.scale(9, 25);
    let mut s = super::banner("Fig. 2d — LL switch vs transmission gate decay (20 fF)");
    let ll = CellSim::new(C_MEM_NOMINAL, LeakageMacro::ll_calibrated());
    let tg = CellSim::new(C_MEM_NOMINAL, LeakageMacro::tg());
    let (t_ll, v_ll) = ll.transient(VDD, 60e-3, n);
    let (_, v_tg) = tg.transient(VDD, 60e-3, n);
    s.push_str(&format!("{:>9} {:>10} {:>10}\n", "t (ms)", "LL (V)", "TG (V)"));
    for i in 0..n {
        s.push_str(&format!(
            "{:>9.1} {:>10.3} {:>10.3}\n",
            t_ll[i] * 1e3,
            v_ll[i],
            v_tg[i]
        ));
    }
    let w_ll = ll.memory_window(V_FLOOR, 0.2);
    let w_tg = tg.memory_window(V_FLOOR, 0.2);
    s.push_str(&format!(
        "\nmemory window (V > {V_FLOOR} V): LL = {:.1} ms, TG = {:.1} ms\n\
         paper: LL extends the effective retention to >50 ms; the TG\n\
         charge is completely dissipated in ~10 ms.\n",
        w_ll * 1e3,
        w_tg * 1e3
    ));
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_shows_windows() {
        let r = super::run(super::Effort::Quick);
        assert!(r.contains("memory window"));
        assert!(r.contains("LL (V)"));
    }
}
