//! Experiment harnesses — one module per table/figure in the paper's
//! evaluation (see DESIGN.md §3 for the index). Each harness regenerates
//! the rows/series the paper reports and prints paper-vs-measured.
//!
//! Run via the CLI: `tsisc exp <id>` where `<id>` ∈
//! {table1, fig2d, fig4, fig5, fig6, fig7, fig8, fig9, fig10, fig12,
//!  table2, table3, sec2b, all}.

pub mod ablations;
pub mod fig10;
pub mod fig2d;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod sec2b;
pub mod table1;
#[cfg(feature = "pjrt")]
pub mod table2;
#[cfg(feature = "pjrt")]
pub mod table3;

/// Fallback for Table II when the crate is built without the `pjrt`
/// feature: same registry id, but the harness reports itself skipped.
#[cfg(not(feature = "pjrt"))]
pub mod table2 {
    use super::Effort;

    /// Print the skip banner (the real harness needs the `pjrt` feature).
    pub fn run(_effort: Effort) -> String {
        super::banner("Table II — classification accuracy (frame/video)")
            + "SKIPPED: built without the `pjrt` feature — rebuild with \
               `cargo build --features pjrt` and run `make artifacts`.\n"
    }
}

/// Fallback for Table III when the crate is built without the `pjrt`
/// feature: same registry id, but the harness reports itself skipped.
#[cfg(not(feature = "pjrt"))]
pub mod table3 {
    use super::Effort;

    /// Print the skip banner (the real harness needs the `pjrt` feature).
    pub fn run(_effort: Effort) -> String {
        super::banner("Table III — reconstruction SSIM (DAVIS-like sequences)")
            + "SKIPPED: built without the `pjrt` feature — rebuild with \
               `cargo build --features pjrt` and run `make artifacts`.\n"
    }
}

/// Effort level: `Quick` shrinks workloads for smoke tests/CI; `Full`
/// reproduces at the scales recorded in EXPERIMENTS.md.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Effort {
    Quick,
    Full,
}

impl Effort {
    pub fn scale(self, quick: usize, full: usize) -> usize {
        match self {
            Effort::Quick => quick,
            Effort::Full => full,
        }
    }

    pub fn scale_f(self, quick: f64, full: f64) -> f64 {
        match self {
            Effort::Quick => quick,
            Effort::Full => full,
        }
    }
}

/// Registry of all experiments.
pub const ALL: &[(&str, fn(Effort) -> String)] = &[
    ("table1", table1::run),
    ("fig2d", fig2d::run),
    ("fig4", fig4::run),
    ("fig5", fig5::run),
    ("fig6", fig6::run),
    ("fig7", fig7::run),
    ("fig8", fig8::run),
    ("fig9", fig9::run),
    ("fig10", fig10::run),
    ("fig12", fig10::run_fig12),
    ("sec2b", sec2b::run),
    ("ablations", ablations::run),
    ("table2", table2::run),
    ("table3", table3::run),
];

/// Look up an experiment by id.
pub fn find(id: &str) -> Option<fn(Effort) -> String> {
    ALL.iter().find(|(n, _)| *n == id).map(|(_, f)| *f)
}

/// Render a header banner for a report.
pub fn banner(title: &str) -> String {
    format!("\n=== {title} ===\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique() {
        let mut names: Vec<&str> = ALL.iter().map(|(n, _)| *n).collect();
        let len = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), len);
    }

    #[test]
    fn find_known_and_unknown() {
        assert!(find("fig7").is_some());
        assert!(find("nope").is_none());
    }
}
