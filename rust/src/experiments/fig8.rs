//! Fig. 8: ISC analog array vs 16-bit SRAM timestamp storage ([53], [26])
//! — storage array only. Paper: 1600× / 6761× power, 3.1× / 2.2× area,
//! plus the timestamp-overflow hazard the analog array avoids.

use super::Effort;
use crate::arch::arch3d::Workload;
use crate::arch::sram::{self, SramDesign};
use crate::arch::ArrayGeometry;
use crate::events::Resolution;

pub fn run(_effort: Effort) -> String {
    let g = ArrayGeometry::new(Resolution::QVGA);
    let w = Workload::default();
    let p_isc = sram::isc_array_power(&g, &w);
    let a_isc = sram::isc_array_area(&g);

    let mut s = super::banner("Fig. 8 — ISC analog array vs SRAM timestamp storage");
    s.push_str("--- ISC analog array (storage only) ---\n");
    s.push_str(&p_isc.to_table(1e6, "µW"));
    s.push_str(&format!("  area: {:.3} mm²\n\n", a_isc * 1e-6));

    for (design, paper_p, paper_a) in [
        (SramDesign::Bose53, 1600.0, 3.1),
        (SramDesign::Rios26, 6761.0, 2.2),
    ] {
        let p = sram::power(design, &g, &w);
        let a = sram::area(design, &g);
        s.push_str(&format!("--- {} ---\n", design.name()));
        s.push_str(&p.to_table(1e3, "mW"));
        s.push_str(&format!(
            "  area: {:.3} mm²\n  power ratio vs ISC: {:.0}x (paper {paper_p:.0}x)\n  \
             area ratio vs ISC:  {:.2}x (paper {paper_a}x)\n\n",
            a * 1e-6,
            p.total() / p_isc.total(),
            a / a_isc,
        ));
    }
    s.push_str(&format!(
        "timestamp overflow: a 16-bit µs counter wraps every {:.1} ms —\n\
         the analog array self-normalizes and never wraps.\n",
        sram::timestamp_wrap_period_s(16, 1.0) * 1e3
    ));
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_covers_both_designs() {
        let r = super::run(super::Effort::Quick);
        assert!(r.contains("[53]"));
        assert!(r.contains("[26]"));
        assert!(r.contains("power ratio"));
    }
}
