//! Fig. 5: (a) V_mem decay vs C_mem — the ≥24 ms memory-window
//! requirement picks C_mem ≥ 10 fF; (b) Monte-Carlo V_mem distributions
//! at Δt = 10/20/30 ms for the 20 fF cell.

use super::Effort;
use crate::circuit::cell::{CellSim, LeakageMacro, V_FLOOR};
use crate::circuit::montecarlo::{vmem_distributions, MismatchParams};
use crate::circuit::params::{REQUIRED_WINDOW_S, VDD};

pub fn run(effort: Effort) -> String {
    let mut s = super::banner("Fig. 5a — memory window vs C_mem");
    let leak = LeakageMacro::ll_calibrated();
    s.push_str(&format!("{:>8} {:>14} {:>10}\n", "C (fF)", "window (ms)", "≥24 ms?"));
    for c_ff in [5.0, 10.0, 20.0, 40.0] {
        let w = CellSim::new(c_ff * 1e-15, leak).memory_window(V_FLOOR, 0.5);
        s.push_str(&format!(
            "{:>8.0} {:>14.1} {:>10}\n",
            c_ff,
            w * 1e3,
            if w >= REQUIRED_WINDOW_S { "yes" } else { "no" }
        ));
    }
    s.push_str("paper: C_mem ≥ 10 fF needed for the ≥24 ms STCF window.\n");

    s.push_str(&super::banner(
        "Fig. 5b — Monte-Carlo V_mem at Δt = 10/20/30 ms (20 fF)",
    ));
    let n = effort.scale(300, 8_000);
    let d = vmem_distributions(
        20e-15,
        &MismatchParams::default(),
        &[10e-3, 20e-3, 30e-3],
        n,
        42,
    );
    s.push_str(&format!(
        "{:>9} {:>10} {:>9} | paper: µ, CV\n",
        "Δt (ms)", "µ (V)", "CV (%)"
    ));
    let paper = [(0.72, 0.10), (0.46, 0.39), (0.30, 1.28)];
    for (dist, (pm, pcv)) in d.iter().zip(paper) {
        s.push_str(&format!(
            "{:>9.0} {:>10.3} {:>9.2} | {:.2} V, {:.2} %\n",
            dist.dt_s * 1e3,
            dist.mean,
            dist.cv_percent,
            pm,
            pcv
        ));
    }
    s.push_str(&format!(
        "(n = {n} MC samples; V_reset = {VDD} V; all CV < 2 % as required)\n"
    ));
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_has_both_panels() {
        let r = super::run(super::Effort::Quick);
        assert!(r.contains("Fig. 5a"));
        assert!(r.contains("Fig. 5b"));
        assert!(r.contains("CV"));
    }
}
