//! Table II: classification accuracy (frame / video) across the four
//! synthetic dataset families, comparing CNN inputs built from the ideal
//! software TS vs the 3DS-ISC analog TS (the paper's parity claim), plus
//! cheaper baselines (EBBI, event-count).
//!
//! Needs `make artifacts` (the classifier train/fwd artifacts).

use super::Effort;
use crate::events::dataset::{generate, Family, GenOptions};
use crate::isc::IscConfig;
use crate::runtime::{artifacts_available, default_artifact_dir, Runtime};
use crate::train::driver::{train_classifier, TrainConfig};
use crate::train::frames::{dataset_frames, SurfaceKind};

pub fn run(effort: Effort) -> String {
    let mut s = super::banner("Table II — classification accuracy (frame/video)");
    if !artifacts_available() {
        s.push_str("SKIPPED: artifacts missing — run `make artifacts` first.\n");
        return s;
    }
    let mut rt = Runtime::new(default_artifact_dir()).expect("runtime");

    let families: &[Family] = match effort {
        Effort::Quick => &[Family::NMnist],
        Effort::Full => &[Family::NMnist, Family::Shapes, Family::CifarDvs, Family::Gesture],
    };
    let opts = GenOptions {
        train_per_class: effort.scale(10, 24),
        test_per_class: effort.scale(4, 10),
        duration_s: 0.15,
        noise_hz: 1.0,
        seed: 7,
    };
    let train_cfg = TrainConfig {
        steps: effort.scale(60, 250),
        lr: 0.03,
        seed: 42,
        log_every: 0,
    };
    // Quick: just the parity pair; Full adds the cheap baselines.
    let mut kinds: Vec<(String, SurfaceKind)> = vec![
        ("ideal-TS".into(), SurfaceKind::Ideal { tau_us: 24_000.0 }),
        ("3DS-ISC".into(), SurfaceKind::Isc(IscConfig::default())),
    ];
    // The cheap baselines are covered by `tsisc train --surface count|ebbi`
    // (kept out of the sweep to bound the full run to ~20 min on 1 core).
    let _ = &mut kinds;

    s.push_str(&format!(
        "{:<14} {:<13} {:>8} {:>8}   (train steps = {})\n",
        "dataset", "input", "frame", "video", train_cfg.steps
    ));
    for &fam in families {
        let ds = generate(fam, opts);
        for (name, kind) in &kinds {
            let (train, test) = dataset_frames(&ds, kind, 50_000, 32);
            let r = train_classifier(&mut rt, &train, &test, &train_cfg).expect("train");
            s.push_str(&format!(
                "{:<14} {:<13} {:>8.2} {:>8.2}\n",
                ds.name, name, r.frame_accuracy, r.video_accuracy
            ));
        }
    }
    s.push_str(
        "\npaper (frame/video): N-MNIST .99/.99, N-Caltech101 .82/.85,\n\
         CIFAR10-DVS .72/.78, DVS128-Gesture .91/.97. Shape requirements:\n\
         (1) 3DS-ISC ≈ ideal-TS (hardware parity), (2) video ≥ frame\n\
         accuracy, (3) TS-class inputs ≥ count/binary inputs.\n",
    );
    s
}

#[cfg(test)]
mod tests {
    // Covered by the experiments_smoke integration test (needs artifacts).
}
