//! Fig. 4: the half-select hazard in a 2D crossbar — ΔV vs Δt scatter
//! (4c) and the distribution of first half-select times on hotel-bar-like
//! and driving-like streams (4d).

use super::Effort;
use crate::arch::arch2d::{hs_discharge_factor, simulate_half_select, wbl_coupling_bump};
use crate::circuit::montecarlo::FittedBank;
use crate::events::scene::{BlobScene, EdgeScene};
use crate::events::v2e::{convert, DvsParams};
use crate::events::Resolution;
use crate::util::stats::{histogram, mean, percentile};

pub fn run(effort: Effort) -> String {
    let mut s = super::banner("Fig. 4 — half-select analysis (2D crossbar)");
    s.push_str(&format!(
        "row-discharge survival factor per half-select pulse: {:.2e}\n\
         WBL coupling bump (blue case): {:.1} mV (non-cumulative)\n\n",
        hs_discharge_factor(),
        wbl_coupling_bump() * 1e3
    ));

    let side = effort.scale(48, 96) as u16;
    let dur = effort.scale_f(0.3, 1.0);
    let res = Resolution::new(side, side);
    let decay = FittedBank::nominal(20e-15);

    for (name, events) in [
        (
            "hotel-bar",
            convert(&BlobScene::new(side, side, 3, dur, 7), res, DvsParams::default(), dur),
        ),
        ("driving", convert(&EdgeScene::new(90.0, 21), res, DvsParams::default(), dur)),
    ] {
        let stats = simulate_half_select(&events, res, &decay, 5);
        s.push_str(&format!(
            "--- {name}: {} events, {} half-select hits ---\n",
            events.len(),
            stats.dv_vs_dt.len()
        ));

        // Fig 4c: ΔV binned by Δt.
        s.push_str("  ΔV vs Δt (Fig. 4c):\n");
        for (lo, hi) in [(0.0, 2e-3), (2e-3, 8e-3), (8e-3, 20e-3), (20e-3, 60e-3)] {
            let vals: Vec<f64> = stats
                .dv_vs_dt
                .iter()
                .filter(|(dt, _)| *dt >= lo && *dt < hi)
                .map(|(_, dv)| *dv)
                .collect();
            if !vals.is_empty() {
                s.push_str(&format!(
                    "    Δt ∈ [{:>4.0}, {:>4.0}) ms: mean ΔV = {:.3} V  (n={})\n",
                    lo * 1e3,
                    hi * 1e3,
                    mean(&vals),
                    vals.len()
                ));
            }
        }

        // Fig 4d: first half-select time distribution.
        if !stats.first_hs_times.is_empty() {
            let med = percentile(&stats.first_hs_times, 50.0);
            let p90 = percentile(&stats.first_hs_times, 90.0);
            let h = histogram(&stats.first_hs_times, 0.0, 20e-3, 10);
            s.push_str(&format!(
                "  first half-select after write (Fig. 4d): median {:.2} ms, p90 {:.2} ms\n \
                  histogram 0-20 ms (2 ms bins): {:?}\n",
                med * 1e3,
                p90 * 1e3,
                h
            ));
        }
        s.push_str(&format!(
            "  end-of-stream TS RMSE vs ideal: {:.3} V; disturbed cells: {:.1} %\n\n",
            stats.ts_rmse,
            stats.disturbed_fraction * 100.0
        ));
    }
    s.push_str(
        "paper: earlier half-selects cause larger ΔV; first half-selects\n\
         occur within ms on both datasets, corrupting the stored TS — the\n\
         3D per-pixel (Cu-Cu) organization eliminates the hazard entirely.\n",
    );
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_covers_both_scenes() {
        let r = super::run(super::Effort::Quick);
        assert!(r.contains("hotel-bar"));
        assert!(r.contains("driving"));
        assert!(r.contains("Fig. 4c"));
    }

    #[test]
    fn dv_decreases_with_dt_in_report() {
        // Parse the binned means for the driving scene and check ordering.
        let r = super::run(super::Effort::Quick);
        let means: Vec<f64> = r
            .lines()
            .filter(|l| l.contains("mean ΔV"))
            .map(|l| {
                l.split("mean ΔV = ").nth(1).unwrap().split(' ').next().unwrap()
                    .parse::<f64>().unwrap()
            })
            .collect();
        assert!(!means.is_empty());
        // First bin (earliest) should exceed the last bin in each scene.
        // (means come in scene order; just check global max is an early bin)
        let max_idx = means
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(max_idx <= means.len() / 2, "largest ΔV should be an early bin");
    }
}
