//! Table III: image reconstruction SSIM over the seven synthetic DAVIS
//! sequences — the same UNet-lite decoder trained on three inputs:
//! 3DS-ISC analog TS, TORE volumes, and event-count frames (standing in
//! for the E2VID slot; see DESIGN.md §1 for the substitution note).

use super::Effort;
use crate::events::davis::{record_all, Recording};
use crate::events::Resolution;
use crate::isc::IscConfig;
use crate::recon::{build_pairs, train_recon, ReconConfig};
use crate::runtime::{artifacts_available, default_artifact_dir, Runtime};
use crate::train::frames::SurfaceKind;
use crate::util::stats::mean;

pub fn run(effort: Effort) -> String {
    let mut s = super::banner("Table III — reconstruction SSIM (DAVIS-like sequences)");
    if !artifacts_available() {
        s.push_str("SKIPPED: artifacts missing — run `make artifacts` first.\n");
        return s;
    }
    let mut rt = Runtime::new(default_artifact_dir()).expect("runtime");

    let res = Resolution::new(64, 64);
    let dur = effort.scale_f(0.6, 2.0);
    let fps = 30.0;
    let recs: Vec<Recording> = record_all(res, dur, fps, 13);
    let recs: Vec<&Recording> = match effort {
        Effort::Quick => recs.iter().take(2).collect(),
        Effort::Full => recs.iter().collect(),
    };

    let cfg = ReconConfig {
        steps: effort.scale(40, 150),
        lr: 0.15,
        seed: 7,
        holdout_every: 4,
    };
    let kinds: Vec<(&str, SurfaceKind)> = vec![
        ("evcount", SurfaceKind::Count { bits: 4 }),
        ("TORE", SurfaceKind::Tore { k: 3 }),
        ("3D-ISC", SurfaceKind::Isc(IscConfig::default())),
    ];

    s.push_str(&format!(
        "{:<16} {:>10} {:>10} {:>10}   (train steps = {})\n",
        "sequence", "evcount", "TORE", "3D-ISC", cfg.steps
    ));
    let mut per_kind: Vec<Vec<f64>> = vec![Vec::new(); kinds.len()];
    for rec in &recs {
        let mut row = format!("{:<16}", rec.name);
        for (k, (_, kind)) in kinds.iter().enumerate() {
            let pairs = build_pairs(rec, kind);
            let r = train_recon(&mut rt, &pairs, &cfg).expect("recon");
            row.push_str(&format!(" {:>10.3}", r.mean_ssim));
            per_kind[k].push(r.mean_ssim);
        }
        s.push_str(&row);
        s.push('\n');
    }
    s.push_str(&format!(
        "{:<16} {:>10.3} {:>10.3} {:>10.3}\n",
        "mean",
        mean(&per_kind[0]),
        mean(&per_kind[1]),
        mean(&per_kind[2])
    ));
    s.push_str(
        "\npaper means: E2VID 0.56, TORE 0.55, 3D-ISC 0.62 (3D-ISC best).\n\
         Shape requirement: the analog-TS input should be competitive with\n\
         or better than the alternatives under the same decoder.\n",
    );
    s
}
