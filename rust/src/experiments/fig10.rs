//! Fig. 10: STCF denoising on the DND21-protocol streams — ROC curves and
//! AUC for the ideal (full-precision) TS vs the ISC analog array at 10 fF
//! and 20 fF. Fig. 12: the polarity-sensitive ablation (Sec. IV-F).

use super::Effort;
use crate::circuit::MismatchParams;
use crate::denoise::{run_stcf, StcfBackend, StcfParams};
use crate::events::noise::contaminate;
use crate::events::scene::{BlobScene, EdgeScene, Scene};
use crate::events::v2e::{convert, DvsParams};
use crate::events::{LabeledEvent, Resolution};
use crate::isc::IscConfig;
use crate::metrics::{roc, Scored};

fn make_stream(name: &str, res: Resolution, dur: f64) -> Vec<LabeledEvent> {
    let signal = match name {
        "hotel-bar" => {
            let s = BlobScene::new(res.width, res.height, 3, dur, 7);
            convert(&s, res, DvsParams::default(), dur)
        }
        _ => {
            let s = EdgeScene::new(90.0, 21);
            convert(&s, res, DvsParams::default(), dur)
        }
    };
    // DND21 protocol: 5 Hz/pixel BA noise over the clean stream.
    contaminate(&signal, res, 5.0, dur, 19)
}

/// Drop the cold-start prefix (the first τ_tw has no support history).
fn warm(scored: &[Scored], events: &[LabeledEvent], tau_us: u64) -> Vec<Scored> {
    let skip = events.iter().position(|e| e.ev.t > tau_us).unwrap_or(0);
    scored[skip..].to_vec()
}

fn isc_cfg(c_ff: f64) -> IscConfig {
    IscConfig { c_mem: c_ff * 1e-15, mismatch: Some(MismatchParams::default()), ..IscConfig::default() }
}

pub fn run(effort: Effort) -> String {
    let side = effort.scale(48, 96) as u16;
    let dur = effort.scale_f(0.5, 2.0);
    let res = Resolution::new(side, side);
    let prm = StcfParams::default();

    let mut s = super::banner("Fig. 10 — STCF denoise ROC (ideal vs ISC 10/20 fF)");
    s.push_str(&format!(
        "protocol: DND21-style, 5 Hz/pixel BA noise, τ_tw = {} ms, r = {}, \
         {side}x{side}, {dur:.1} s\n\n",
        prm.tau_tw_us / 1000,
        prm.radius
    ));

    for scene in ["hotel-bar", "driving"] {
        let events = make_stream(scene, res, dur);
        let n_noise = events.iter().filter(|e| !e.is_signal).count();
        s.push_str(&format!(
            "--- {scene}: {} events ({} noise) ---\n",
            events.len(),
            n_noise
        ));
        let mut rows = Vec::new();
        {
            let mut b = StcfBackend::ideal(res);
            let r = run_stcf(&mut b, &events, &prm);
            rows.push(("ideal (SW timestamps)", roc(&warm(&r.scored, &events, prm.tau_tw_us)).auc));
        }
        for c_ff in [20.0, 10.0] {
            let mut b = StcfBackend::isc(res, isc_cfg(c_ff), prm.tau_tw_us);
            let r = run_stcf(&mut b, &events, &prm);
            let label: &'static str = if c_ff == 20.0 { "ISC 20 fF" } else { "ISC 10 fF" };
            rows.push((label, roc(&warm(&r.scored, &events, prm.tau_tw_us)).auc));
        }
        for (label, auc) in &rows {
            s.push_str(&format!("  {label:<24} AUC = {auc:.3}\n"));
        }
        let ideal = rows[0].1;
        let worst_hw = rows[1..].iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
        s.push_str(&format!(
            "  hardware-vs-ideal AUC gap: {:.3}\n\n",
            ideal - worst_hw
        ));
    }
    s.push_str(
        "paper: AUC 0.96 (hotel-bar) / 0.86 (driving); both 10 fF and 20 fF\n\
         are acceptable — the analog comparator matches the digital window\n\
         test. Our synthetic scenes land in the same band with the same\n\
         ordering and a near-zero hardware-vs-ideal gap.\n",
    );
    s
}

/// Fig. 12: polarity-sensitive STCF — AUC gains of only ~1-2 %.
pub fn run_fig12(effort: Effort) -> String {
    let side = effort.scale(48, 96) as u16;
    let dur = effort.scale_f(0.5, 2.0);
    let res = Resolution::new(side, side);

    let mut s = super::banner("Fig. 12 — STCF with vs without polarity");
    for scene in ["hotel-bar", "driving"] {
        let events = make_stream(scene, res, dur);
        let mut aucs = Vec::new();
        for polarity in [false, true] {
            let prm = StcfParams { polarity_sensitive: polarity, ..StcfParams::default() };
            let cfg = IscConfig { polarity_sensitive: polarity, ..isc_cfg(20.0) };
            let mut b = StcfBackend::isc(res, cfg, prm.tau_tw_us);
            let r = run_stcf(&mut b, &events, &prm);
            aucs.push(roc(&warm(&r.scored, &events, prm.tau_tw_us)).auc);
        }
        s.push_str(&format!(
            "  {scene:<10} AUC: no-polarity {:.3} | polarity {:.3} | Δ {:+.3}\n",
            aucs[0],
            aucs[1],
            aucs[1] - aucs[0]
        ));
    }
    s.push_str(
        "paper: polarity adds only 1-2 % AUC for denoising (at 2x area\n\
         cost) — it can be ignored for this task.\n",
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_aucs_in_band() {
        let r = run(Effort::Quick);
        // Parse AUC values; all should be comfortably above chance.
        let aucs: Vec<f64> = r
            .lines()
            .filter(|l| l.contains("AUC = "))
            .map(|l| l.split("AUC = ").nth(1).unwrap().trim().parse::<f64>().unwrap())
            .collect();
        assert_eq!(aucs.len(), 6);
        for a in &aucs {
            assert!(*a > 0.7, "AUC {a} too low\n{r}");
        }
        // Hardware close to ideal (the paper's parity claim).
        assert!(r.contains("hardware-vs-ideal"));
    }

    #[test]
    fn fig12_polarity_delta_small() {
        let r = run_fig12(Effort::Quick);
        let deltas: Vec<f64> = r
            .lines()
            .filter(|l| l.contains("Δ"))
            .map(|l| l.split("Δ ").nth(1).unwrap().trim().parse::<f64>().unwrap())
            .collect();
        assert_eq!(deltas.len(), 2);
        for d in deltas {
            assert!(d.abs() < 0.08, "polarity delta {d} too large");
        }
    }
}
