//! Fig. 9: double-exponential fit of the simulated V_mem decay — the
//! bridge from circuit simulation to the software model. Reports the
//! fitted parameters and MSE ("very good fit" in the paper).

use super::Effort;
use crate::circuit::cell::CellSim;
use crate::circuit::params::VDD;
use crate::util::fit::fit_double_exp;

pub fn run(effort: Effort) -> String {
    let n = effort.scale(64, 256);
    let cell = CellSim::ll_nominal();
    let (ts, vs) = cell.transient(VDD, 60e-3, n);
    let fit = fit_double_exp(&ts, &vs);
    let p = fit.params;

    let mut s = super::banner("Fig. 9 — double-exponential fit of V_mem(t)");
    s.push_str(&format!(
        "f(t) = A1·exp(-t/τ1) + A2·exp(-t/τ2) + b\n\
         A1 = {:.4} V   τ1 = {:.2} ms\n\
         A2 = {:.4} V   τ2 = {:.2} ms\n\
         b  = {:.4} V\n\
         fit MSE = {:.3e} V²  over {n} samples (0-60 ms)\n",
        p.a1,
        p.tau1 * 1e3,
        p.a2,
        p.tau2 * 1e3,
        p.b,
        fit.mse
    ));
    s.push_str(&format!("{:>8} {:>10} {:>10} {:>10}\n", "t (ms)", "sim (V)", "fit (V)", "err (mV)"));
    for k in (0..n).step_by((n / 8).max(1)) {
        let f = p.eval(ts[k]);
        s.push_str(&format!(
            "{:>8.1} {:>10.4} {:>10.4} {:>10.2}\n",
            ts[k] * 1e3,
            vs[k],
            f,
            (vs[k] - f) * 1e3
        ));
    }
    s.push_str("paper: MSE between simulated V_mem and the fit indicates a very good fit.\n");
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn fit_quality_reported() {
        let r = super::run(super::Effort::Quick);
        assert!(r.contains("fit MSE"));
        // Extract the MSE and check it is small.
        let mse: f64 = r
            .lines()
            .find(|l| l.contains("fit MSE"))
            .unwrap()
            .split("= ")
            .nth(1)
            .unwrap()
            .split(' ')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(mse < 1e-4, "mse={mse}");
    }
}
