//! Address-Event-Representation (AER) wire format.
//!
//! In the paper's 2D baseline every event leaves the sensor die through an
//! AER encoder, crosses a bus, and is decoded on the memory die (Fig. 3a).
//! This module implements that interchange: a compact binary encoding with
//! timestamp delta compression (the standard AER-DAT style trick), used by
//! the coordinator's transport layer, the architecture model (toggled wire
//! bits for the energy estimate), and the TCP front door in `serve::net`.
//!
//! The decoder is strict: a record must be complete, its coordinates must
//! lie inside the declared geometry, and its varint Δt must be *canonical*
//! (the unique shortest encoding). Overlong varints are how a corrupted or
//! adversarial stream smuggles ambiguity past a delta decoder, so they are
//! a typed error, not a tolerated alias. [`AerDecoder`] is the incremental
//! form used on the wire path: bytes arrive in arbitrary read-sized chunks
//! and a record split across chunks is carried in a bounded stash — never
//! copied wholesale, never re-parsed from the start.

use super::event::{Event, Polarity, Resolution};

/// Longest possible record: a 10-byte varint Δt + 2×u16 coords + 1 polarity
/// byte. The incremental decoder's partial-record stash never exceeds this.
pub const MAX_RECORD_BYTES: usize = 15;

/// Errors produced when decoding a corrupt AER byte stream.
#[derive(Debug, PartialEq, Eq)]
pub enum AerError {
    /// Stream ended inside a record.
    Truncated,
    /// Coordinate outside the declared geometry.
    OutOfRange { x: u16, y: u16 },
    /// Timestamp delta overflowed the accumulator.
    TimestampOverflow,
    /// Varint Δt was not the canonical shortest encoding.
    NonCanonical,
}

impl std::fmt::Display for AerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AerError::Truncated => write!(f, "AER stream truncated mid-record"),
            AerError::OutOfRange { x, y } => write!(f, "AER coordinate ({x},{y}) out of range"),
            AerError::TimestampOverflow => write!(f, "AER timestamp accumulator overflow"),
            AerError::NonCanonical => write!(f, "AER varint delta is not canonical (overlong)"),
        }
    }
}

impl std::error::Error for AerError {}

/// Encode events (must be time-sorted) into the wire format:
/// per record: varint Δt (µs) | u16 x | u16 y | u8 polarity.
pub fn encode(events: &[Event]) -> Vec<u8> {
    let mut out = Vec::with_capacity(events.len() * 6);
    let mut last_t = 0u64;
    for e in events {
        debug_assert!(e.t >= last_t, "events must be sorted for AER encoding");
        write_varint(&mut out, e.t - last_t);
        out.extend_from_slice(&e.x.to_le_bytes());
        out.extend_from_slice(&e.y.to_le_bytes());
        out.push(match e.p {
            Polarity::On => 1,
            Polarity::Off => 0,
        });
        last_t = e.t;
    }
    out
}

/// Decode a byte stream produced by [`encode`], validating geometry.
pub fn decode(bytes: &[u8], res: Resolution) -> Result<Vec<Event>, AerError> {
    let mut events = Vec::new();
    decode_into(bytes, res, &mut events)?;
    Ok(events)
}

/// Decode into a caller-owned buffer so hot paths reuse allocations.
///
/// Appends to `out` (it is *not* cleared). On error, `out` holds the valid
/// prefix of records decoded before the corruption — callers that want
/// all-or-nothing semantics (like [`decode`]) discard it; the net ingest
/// path uses the prefix property to account partially-decoded frames.
pub fn decode_into(bytes: &[u8], res: Resolution, out: &mut Vec<Event>) -> Result<(), AerError> {
    let mut dec = AerDecoder::new(res);
    dec.push(bytes, out)?;
    dec.finish()
}

/// Incremental, resumable AER decoder.
///
/// Feed byte chunks with [`push`](AerDecoder::push) as they arrive off a
/// socket; complete records are appended to the output immediately and a
/// record split across chunk boundaries is carried in a stash bounded by
/// [`MAX_RECORD_BYTES`] — the next `push` completes it without re-parsing
/// or buffering the whole frame. Call [`finish`](AerDecoder::finish) at
/// end-of-stream to reject a dangling partial record, and
/// [`reset`](AerDecoder::reset) to reuse the decoder for an independent
/// stream (timestamps restart from zero).
#[derive(Debug)]
pub struct AerDecoder {
    res: Resolution,
    t: u64,
    stash: [u8; MAX_RECORD_BYTES],
    stash_len: usize,
}

impl AerDecoder {
    /// New decoder for streams using the given geometry.
    pub fn new(res: Resolution) -> Self {
        Self { res, t: 0, stash: [0; MAX_RECORD_BYTES], stash_len: 0 }
    }

    /// Forget all stream state (timestamp accumulator and partial record).
    pub fn reset(&mut self) {
        self.t = 0;
        self.stash_len = 0;
    }

    /// Bytes of a partial record carried over from the previous chunk.
    pub fn pending(&self) -> usize {
        self.stash_len
    }

    /// Decode one chunk, appending complete records to `out`.
    ///
    /// Returns the number of events appended. After any error the decoder
    /// is reset; the bytes already appended to `out` remain valid (they
    /// are the stream prefix that decoded cleanly before the corruption).
    pub fn push(&mut self, mut bytes: &[u8], out: &mut Vec<Event>) -> Result<usize, AerError> {
        let n0 = out.len();
        // Complete a carried partial record first: copy just enough new
        // bytes into the bounded stash to finish it.
        while self.stash_len > 0 && !bytes.is_empty() {
            let take = (MAX_RECORD_BYTES - self.stash_len).min(bytes.len());
            self.stash[self.stash_len..self.stash_len + take].copy_from_slice(&bytes[..take]);
            match parse_record(&self.stash[..self.stash_len + take], self.t, self.res) {
                Err(e) => {
                    self.reset();
                    return Err(e);
                }
                Ok(Some((ev, used))) => {
                    debug_assert!(used > self.stash_len);
                    bytes = &bytes[used - self.stash_len..];
                    self.stash_len = 0;
                    self.t = ev.t;
                    out.push(ev);
                }
                Ok(None) => {
                    self.stash_len += take;
                    if self.stash_len == MAX_RECORD_BYTES {
                        // A record can never exceed MAX_RECORD_BYTES, so a
                        // full stash that still won't parse is corrupt.
                        self.reset();
                        return Err(AerError::NonCanonical);
                    }
                    return Ok(out.len() - n0);
                }
            }
        }
        // Fast path: parse straight out of the caller's chunk, zero-copy.
        loop {
            match parse_record(bytes, self.t, self.res) {
                Err(e) => {
                    self.reset();
                    return Err(e);
                }
                Ok(Some((ev, used))) => {
                    self.t = ev.t;
                    out.push(ev);
                    bytes = &bytes[used..];
                }
                Ok(None) => break,
            }
        }
        // Stash the bounded partial tail for the next chunk.
        debug_assert!(bytes.len() < MAX_RECORD_BYTES);
        self.stash[..bytes.len()].copy_from_slice(bytes);
        self.stash_len = bytes.len();
        Ok(out.len() - n0)
    }

    /// End-of-stream check: a dangling partial record is a truncation.
    pub fn finish(&mut self) -> Result<(), AerError> {
        if self.stash_len > 0 {
            self.reset();
            Err(AerError::Truncated)
        } else {
            Ok(())
        }
    }
}

/// Parse one record from the front of `buf`. `Ok(None)` means the buffer
/// ends inside the record (incomplete, not corrupt); incompleteness is only
/// ever reported for buffers shorter than [`MAX_RECORD_BYTES`].
fn parse_record(
    buf: &[u8],
    t_acc: u64,
    res: Resolution,
) -> Result<Option<(Event, usize)>, AerError> {
    let (dt, used) = match read_varint_canonical(buf)? {
        Some(v) => v,
        None => return Ok(None),
    };
    if buf.len() < used + 5 {
        return Ok(None);
    }
    let t = t_acc.checked_add(dt).ok_or(AerError::TimestampOverflow)?;
    let x = u16::from_le_bytes([buf[used], buf[used + 1]]);
    let y = u16::from_le_bytes([buf[used + 2], buf[used + 3]]);
    if !res.contains(x, y) {
        return Err(AerError::OutOfRange { x, y });
    }
    let p = if buf[used + 4] != 0 { Polarity::On } else { Polarity::Off };
    Ok(Some((Event { t, x, y, p }, used + 5)))
}

/// Number of address bits for one AER word at the given geometry — what the
/// 2D architecture's encoder must produce per event (row + column + polarity).
pub fn address_bits(res: Resolution) -> u32 {
    bits_for(res.width as u32 - 1) + bits_for(res.height as u32 - 1) + 1
}

fn bits_for(max_value: u32) -> u32 {
    32 - max_value.leading_zeros()
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Read a canonical LEB128 varint. `Ok(None)` = buffer ends mid-varint.
///
/// Rejections: an overlong encoding (a multi-byte varint whose final byte
/// is zero re-encodes shorter), a continuation past the 10th byte, and —
/// fixing a latent bug in the old reader, which silently *dropped* the high
/// bits of the 10th byte — any 10th byte carrying bits beyond 2^63.
fn read_varint_canonical(bytes: &[u8]) -> Result<Option<(u64, usize)>, AerError> {
    let mut v = 0u64;
    for (i, &b) in bytes.iter().enumerate() {
        if i == 9 {
            if b & 0x80 != 0 {
                return Err(AerError::NonCanonical);
            }
            if b > 1 {
                return Err(AerError::TimestampOverflow);
            }
        }
        v |= ((b & 0x7f) as u64) << (7 * i as u32);
        if b & 0x80 == 0 {
            if i > 0 && b == 0 {
                return Err(AerError::NonCanonical);
            }
            return Ok(Some((v, i + 1)));
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn roundtrip_simple() {
        let evs = vec![
            Event::new(0, 0, 0, Polarity::On),
            Event::new(10, 5, 7, Polarity::Off),
            Event::new(1_000_000, 319, 239, Polarity::On),
        ];
        let bytes = encode(&evs);
        let back = decode(&bytes, Resolution::QVGA).unwrap();
        assert_eq!(evs, back);
    }

    #[test]
    fn decode_rejects_out_of_range() {
        let evs = vec![Event::new(0, 500, 0, Polarity::On)];
        let bytes = encode(&evs);
        assert_eq!(
            decode(&bytes, Resolution::QVGA),
            Err(AerError::OutOfRange { x: 500, y: 0 })
        );
    }

    #[test]
    fn decode_rejects_truncation() {
        let evs = vec![Event::new(12345, 1, 2, Polarity::On)];
        let mut bytes = encode(&evs);
        bytes.pop();
        assert_eq!(decode(&bytes, Resolution::QVGA), Err(AerError::Truncated));
    }

    #[test]
    fn decode_rejects_overlong_varint() {
        // Δt = 0 encoded in two bytes (0x80 0x00) instead of one (0x00).
        let bytes = [0x80, 0x00, 1, 0, 2, 0, 1];
        assert_eq!(decode(&bytes, Resolution::QVGA), Err(AerError::NonCanonical));
    }

    #[test]
    fn decode_rejects_varint_past_ten_bytes() {
        // Eleven continuation bytes: rejected, never silently truncated.
        let bytes = [0xff; 16];
        assert_eq!(decode(&bytes, Resolution::QVGA), Err(AerError::NonCanonical));
    }

    #[test]
    fn decode_rejects_tenth_byte_overflow_bits() {
        // Nine continuation bytes then 0x02: bit 64, dropped by the old
        // reader, now a typed overflow.
        let mut bytes = vec![0x80u8; 9];
        bytes.push(0x02);
        bytes.extend_from_slice(&[1, 0, 2, 0, 1]);
        assert_eq!(decode(&bytes, Resolution::QVGA), Err(AerError::TimestampOverflow));
    }

    #[test]
    fn decode_accepts_full_width_delta() {
        // u64::MAX is a legal (canonical, 10-byte) first delta.
        let evs = vec![Event::new(u64::MAX, 3, 4, Polarity::Off)];
        assert_eq!(decode(&encode(&evs), Resolution::QVGA).unwrap(), evs);
    }

    #[test]
    fn decode_into_appends_and_reuses() {
        let a = vec![Event::new(5, 1, 1, Polarity::On)];
        let b = vec![Event::new(9, 2, 2, Polarity::Off)];
        let mut out = Vec::new();
        decode_into(&encode(&a), Resolution::QVGA, &mut out).unwrap();
        decode_into(&encode(&b), Resolution::QVGA, &mut out).unwrap();
        assert_eq!(out, vec![a[0], b[0]]);
    }

    #[test]
    fn decode_into_keeps_valid_prefix_on_error() {
        let evs = vec![
            Event::new(10, 1, 2, Polarity::On),
            Event::new(20, 3, 4, Polarity::Off),
        ];
        let mut bytes = encode(&evs);
        bytes.pop(); // truncate inside the second record
        let mut out = Vec::new();
        assert_eq!(
            decode_into(&bytes, Resolution::QVGA, &mut out),
            Err(AerError::Truncated)
        );
        assert_eq!(out, vec![evs[0]]);
    }

    #[test]
    fn incremental_decoder_matches_oneshot_at_every_split() {
        let evs: Vec<Event> = (0..40)
            .map(|i| {
                Event::new(i as u64 * 1_000_003, (i % 64) as u16, (i % 48) as u16, Polarity::On)
            })
            .collect();
        let bytes = encode(&evs);
        for split in 0..=bytes.len() {
            let mut dec = AerDecoder::new(Resolution::QVGA);
            let mut out = Vec::new();
            dec.push(&bytes[..split], &mut out).unwrap();
            dec.push(&bytes[split..], &mut out).unwrap();
            dec.finish().unwrap();
            assert_eq!(out, evs, "split at {split}");
        }
    }

    #[test]
    fn incremental_decoder_byte_at_a_time() {
        let evs = vec![
            Event::new(0, 0, 0, Polarity::On),
            Event::new(1 << 40, 319, 239, Polarity::Off),
        ];
        let bytes = encode(&evs);
        let mut dec = AerDecoder::new(Resolution::QVGA);
        let mut out = Vec::new();
        for b in &bytes {
            dec.push(std::slice::from_ref(b), &mut out).unwrap();
            assert!(dec.pending() < MAX_RECORD_BYTES);
        }
        dec.finish().unwrap();
        assert_eq!(out, evs);
    }

    #[test]
    fn incremental_decoder_finish_flags_partial() {
        let bytes = encode(&[Event::new(7, 1, 1, Polarity::On)]);
        let mut dec = AerDecoder::new(Resolution::QVGA);
        let mut out = Vec::new();
        dec.push(&bytes[..bytes.len() - 1], &mut out).unwrap();
        assert!(dec.pending() > 0);
        assert_eq!(dec.finish(), Err(AerError::Truncated));
        // finish() resets: the decoder is reusable afterwards.
        dec.push(&bytes, &mut out).unwrap();
        dec.finish().unwrap();
    }

    #[test]
    fn address_bits_qvga() {
        // 9 bits column (0..319) + 8 bits row (0..239) + 1 polarity = 18.
        assert_eq!(address_bits(Resolution::QVGA), 18);
        assert_eq!(address_bits(Resolution::NMNIST), 13); // 6+6+1
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX / 2, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let (back, used) = read_varint_canonical(&buf).unwrap().unwrap();
            assert_eq!(back, v);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        check("aer roundtrip", 200, |g| {
            let n = g.usize(0, 200);
            let mut t = 0u64;
            let evs: Vec<Event> = (0..n)
                .map(|_| {
                    t += g.u64(0, 10_000);
                    Event::new(
                        t,
                        g.u64(0, 319) as u16,
                        g.u64(0, 239) as u16,
                        if g.bool(0.5) { Polarity::On } else { Polarity::Off },
                    )
                })
                .collect();
            let back = decode(&encode(&evs), Resolution::QVGA).unwrap();
            assert_eq!(evs, back);
        });
    }

    #[test]
    fn prop_chunked_decode_matches_oneshot() {
        check("aer chunked decode", 100, |g| {
            let n = g.usize(1, 120);
            let mut t = 0u64;
            let evs: Vec<Event> = (0..n)
                .map(|_| {
                    t += g.u64(0, 1 << 20);
                    Event::new(t, g.u64(0, 319) as u16, g.u64(0, 239) as u16, Polarity::On)
                })
                .collect();
            let bytes = encode(&evs);
            let mut dec = AerDecoder::new(Resolution::QVGA);
            let mut out = Vec::new();
            let mut pos = 0usize;
            while pos < bytes.len() {
                let end = (pos + g.usize(1, 17)).min(bytes.len());
                dec.push(&bytes[pos..end], &mut out).unwrap();
                pos = end;
            }
            dec.finish().unwrap();
            assert_eq!(out, evs);
        });
    }
}
