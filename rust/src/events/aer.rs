//! Address-Event-Representation (AER) wire format.
//!
//! In the paper's 2D baseline every event leaves the sensor die through an
//! AER encoder, crosses a bus, and is decoded on the memory die (Fig. 3a).
//! This module implements that interchange: a compact binary encoding with
//! timestamp delta compression (the standard AER-DAT style trick), used by
//! the coordinator's transport layer and by the architecture model to count
//! toggled wire bits for the energy estimate.

use super::event::{Event, Polarity, Resolution};

/// Errors produced when decoding a corrupt AER byte stream.
#[derive(Debug, PartialEq, Eq)]
pub enum AerError {
    /// Stream ended inside a record.
    Truncated,
    /// Coordinate outside the declared geometry.
    OutOfRange { x: u16, y: u16 },
    /// Timestamp delta overflowed the accumulator.
    TimestampOverflow,
}

impl std::fmt::Display for AerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AerError::Truncated => write!(f, "AER stream truncated mid-record"),
            AerError::OutOfRange { x, y } => write!(f, "AER coordinate ({x},{y}) out of range"),
            AerError::TimestampOverflow => write!(f, "AER timestamp accumulator overflow"),
        }
    }
}

impl std::error::Error for AerError {}

/// Encode events (must be time-sorted) into the wire format:
/// per record: varint Δt (µs) | u16 x | u16 y | u8 polarity.
pub fn encode(events: &[Event]) -> Vec<u8> {
    let mut out = Vec::with_capacity(events.len() * 6);
    let mut last_t = 0u64;
    for e in events {
        debug_assert!(e.t >= last_t, "events must be sorted for AER encoding");
        write_varint(&mut out, e.t - last_t);
        out.extend_from_slice(&e.x.to_le_bytes());
        out.extend_from_slice(&e.y.to_le_bytes());
        out.push(match e.p {
            Polarity::On => 1,
            Polarity::Off => 0,
        });
        last_t = e.t;
    }
    out
}

/// Decode a byte stream produced by [`encode`], validating geometry.
pub fn decode(bytes: &[u8], res: Resolution) -> Result<Vec<Event>, AerError> {
    let mut events = Vec::new();
    let mut pos = 0usize;
    let mut t = 0u64;
    while pos < bytes.len() {
        let (dt, used) = read_varint(&bytes[pos..]).ok_or(AerError::Truncated)?;
        pos += used;
        t = t.checked_add(dt).ok_or(AerError::TimestampOverflow)?;
        if pos + 5 > bytes.len() {
            return Err(AerError::Truncated);
        }
        let x = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]);
        let y = u16::from_le_bytes([bytes[pos + 2], bytes[pos + 3]]);
        let p = if bytes[pos + 4] != 0 { Polarity::On } else { Polarity::Off };
        pos += 5;
        if !res.contains(x, y) {
            return Err(AerError::OutOfRange { x, y });
        }
        events.push(Event { t, x, y, p });
    }
    Ok(events)
}

/// Number of address bits for one AER word at the given geometry — what the
/// 2D architecture's encoder must produce per event (row + column + polarity).
pub fn address_bits(res: Resolution) -> u32 {
    bits_for(res.width as u32 - 1) + bits_for(res.height as u32 - 1) + 1
}

fn bits_for(max_value: u32) -> u32 {
    32 - max_value.leading_zeros()
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(bytes: &[u8]) -> Option<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &b) in bytes.iter().enumerate() {
        if shift >= 64 {
            return None;
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn roundtrip_simple() {
        let evs = vec![
            Event::new(0, 0, 0, Polarity::On),
            Event::new(10, 5, 7, Polarity::Off),
            Event::new(1_000_000, 319, 239, Polarity::On),
        ];
        let bytes = encode(&evs);
        let back = decode(&bytes, Resolution::QVGA).unwrap();
        assert_eq!(evs, back);
    }

    #[test]
    fn decode_rejects_out_of_range() {
        let evs = vec![Event::new(0, 500, 0, Polarity::On)];
        let bytes = encode(&evs);
        assert_eq!(
            decode(&bytes, Resolution::QVGA),
            Err(AerError::OutOfRange { x: 500, y: 0 })
        );
    }

    #[test]
    fn decode_rejects_truncation() {
        let evs = vec![Event::new(12345, 1, 2, Polarity::On)];
        let mut bytes = encode(&evs);
        bytes.pop();
        assert_eq!(decode(&bytes, Resolution::QVGA), Err(AerError::Truncated));
    }

    #[test]
    fn address_bits_qvga() {
        // 9 bits column (0..319) + 8 bits row (0..239) + 1 polarity = 18.
        assert_eq!(address_bits(Resolution::QVGA), 18);
        assert_eq!(address_bits(Resolution::NMNIST), 13); // 6+6+1
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX / 2] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let (back, used) = read_varint(&buf).unwrap();
            assert_eq!(back, v);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        check("aer roundtrip", 200, |g| {
            let n = g.usize(0, 200);
            let mut t = 0u64;
            let evs: Vec<Event> = (0..n)
                .map(|_| {
                    t += g.u64(0, 10_000);
                    Event::new(
                        t,
                        g.u64(0, 319) as u16,
                        g.u64(0, 239) as u16,
                        if g.bool(0.5) { Polarity::On } else { Polarity::Off },
                    )
                })
                .collect();
            let back = decode(&encode(&evs), Resolution::QVGA).unwrap();
            assert_eq!(evs, back);
        });
    }
}
