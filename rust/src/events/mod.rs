//! Event-camera data layer: core AER types, the v2e-style converter,
//! synthetic scenes/datasets, noise injection and stream windowing.
//!
//! Everything downstream (ISC array, denoiser, classifier pipeline,
//! architecture models) consumes the [`event::Event`] /
//! [`event::LabeledEvent`] types defined here.

pub mod aer;
pub mod dataset;
pub mod davis;
pub mod event;
pub mod noise;
pub mod raster;
pub mod replay;
pub mod scene;
pub mod stream;
pub mod v2e;

pub use event::{ClockPolicy, Event, LabeledEvent, Polarity, Resolution};
