//! Synthetic DAVIS recorder — paired events + APS frames.
//!
//! The image-reconstruction task (paper Sec. IV-E) trains a UNet on TS
//! frames with DAVIS240C APS frames as supervision. Offline we substitute a
//! synthetic DAVIS: the same latent scene renders both the event stream
//! (via v2e) and ground-truth grayscale frames, so the pairing is exact.
//! Seven "sequences" mirror the paper's motion taxonomy (Table III).

use super::event::{LabeledEvent, Resolution};
use super::scene::{Scene, TextureMotion, TextureScene};
use super::v2e::{convert, DvsParams};
use crate::util::grid::Grid;

/// One synthetic DAVIS recording: events plus APS frames at fixed times.
#[derive(Clone, Debug)]
pub struct Recording {
    pub name: &'static str,
    pub res: Resolution,
    pub events: Vec<LabeledEvent>,
    /// (timestamp µs, grayscale frame in [0,1]).
    pub frames: Vec<(u64, Grid<f64>)>,
}

/// The seven synthetic sequences standing in for the DAVIS240C set used in
/// Table III. Motion parameters are chosen to span the same difficulty
/// range (slow translation → fast mixed motion).
pub const SEQUENCES: [(&str, TextureMotion); 7] = [
    ("boxes_6dof", TextureMotion::Mixed { vx: 55.0, vy: 25.0, omega: 2.0 }),
    ("calibration", TextureMotion::Translate { vx: 18.0, vy: 6.0 }),
    ("dynamic_6dof", TextureMotion::Mixed { vx: 30.0, vy: 30.0, omega: 1.2 }),
    ("office_zigzag", TextureMotion::Translate { vx: 35.0, vy: -20.0 }),
    ("poster_6dof", TextureMotion::Mixed { vx: 45.0, vy: 10.0, omega: 0.8 }),
    ("shapes_6dof", TextureMotion::Rotate { omega: 2.5 }),
    ("slider_depth", TextureMotion::Translate { vx: 60.0, vy: 0.0 }),
];

/// Record one synthetic sequence.
///
/// `fps` APS frames over `duration_s`; events from the default DVS model.
pub fn record(
    name: &'static str,
    motion: TextureMotion,
    res: Resolution,
    duration_s: f64,
    fps: f64,
    seed: u64,
) -> Recording {
    let scene = TextureScene::new(res.width, res.height, motion, seed);
    let events = convert(&scene, res, DvsParams::default(), duration_s);
    let n_frames = (duration_s * fps).floor() as usize;
    let mut frames = Vec::with_capacity(n_frames);
    for k in 1..=n_frames {
        let t_s = k as f64 / fps;
        frames.push(((t_s * 1e6) as u64, render_frame(&scene, res, t_s)));
    }
    Recording { name, res, events, frames }
}

/// Record all seven sequences at the given geometry.
pub fn record_all(res: Resolution, duration_s: f64, fps: f64, seed: u64) -> Vec<Recording> {
    SEQUENCES
        .iter()
        .enumerate()
        .map(|(i, &(name, motion))| record(name, motion, res, duration_s, fps, seed + i as u64))
        .collect()
}

/// Render the APS view: linear intensity normalized into [0, 1].
fn render_frame(scene: &dyn Scene, res: Resolution, t_s: f64) -> Grid<f64> {
    let mut g = Grid::from_fn(res.width as usize, res.height as usize, |x, y| {
        scene.intensity(x as f64, y as f64, t_s)
    });
    let (lo, hi) = crate::util::stats::min_max(g.as_slice());
    let scale = if hi > lo { 1.0 / (hi - lo) } else { 0.0 };
    for v in g.as_mut_slice() {
        *v = (*v - lo) * scale;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_has_paired_data() {
        let rec = record("test", TextureMotion::Translate { vx: 40.0, vy: 0.0 },
                         Resolution::new(32, 32), 0.2, 20.0, 1);
        assert_eq!(rec.frames.len(), 4);
        assert!(!rec.events.is_empty());
        // Frames normalized to [0,1].
        for (_, f) in &rec.frames {
            for &v in f.as_slice() {
                assert!((0.0..=1.0).contains(&v));
            }
        }
        // Frame timestamps inside the recording span.
        for (t, _) in &rec.frames {
            assert!(*t <= 200_000);
        }
    }

    #[test]
    fn all_sequences_record() {
        // 0.25 s is long enough for even the slow "calibration" motion to
        // cross the contrast threshold at this tiny debug geometry.
        let recs = record_all(Resolution::new(24, 24), 0.25, 20.0, 3);
        assert_eq!(recs.len(), 7);
        for r in &recs {
            assert!(!r.events.is_empty(), "{} has no events", r.name);
            assert_eq!(r.frames.len(), 5);
        }
    }

    #[test]
    fn faster_motion_more_events() {
        let slow = record("slow", TextureMotion::Translate { vx: 10.0, vy: 0.0 },
                          Resolution::new(32, 32), 0.2, 10.0, 5);
        let fast = record("fast", TextureMotion::Translate { vx: 80.0, vy: 0.0 },
                          Resolution::new(32, 32), 0.2, 10.0, 5);
        assert!(
            fast.events.len() > slow.events.len(),
            "fast={} slow={}",
            fast.events.len(),
            slow.events.len()
        );
    }
}
