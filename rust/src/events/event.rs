//! Core event-camera data types.
//!
//! A DVS event is the tuple (x, y, t, p) of Eq. (1) in the paper: pixel
//! coordinates, a microsecond timestamp, and the polarity of the brightness
//! change. The simulator additionally tracks per-event ground truth
//! (signal vs injected noise) so denoising ROC curves (Fig. 10d) can be
//! computed exactly.

/// Polarity of the temporal-contrast change that triggered the event.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Polarity {
    /// Brightness increase (ON event).
    On,
    /// Brightness decrease (OFF event).
    Off,
}

impl Polarity {
    /// Index form used for per-polarity storage planes (ON=1, OFF=0).
    #[inline]
    pub fn index(self) -> usize {
        match self {
            Polarity::Off => 0,
            Polarity::On => 1,
        }
    }

    #[inline]
    pub fn from_index(i: usize) -> Self {
        if i == 0 { Polarity::Off } else { Polarity::On }
    }

    /// Signed value (+1 / -1) for accumulation representations.
    #[inline]
    pub fn sign(self) -> i8 {
        match self {
            Polarity::Off => -1,
            Polarity::On => 1,
        }
    }
}

/// What an ingest stage does with an event whose timestamp runs
/// *backwards* (below the stream's watermark — the highest timestamp
/// seen so far). Real AER links reorder under load and host clocks
/// step; every ingest boundary (pipeline run, serve session, replay
/// interleave) applies one of these policies explicitly instead of
/// silently corrupting the time-surface decay math. Equal timestamps
/// are never affected — only strictly decreasing ones.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClockPolicy {
    /// Raise the event's timestamp to the watermark and ingest it
    /// (order preserved, relative timing within the glitch lost). The
    /// default: keeps every event and keeps time monotone.
    #[default]
    Clamp,
    /// Drop the event entirely (counted, never ingested).
    Reject,
}

/// One Address-Event-Representation event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Timestamp in microseconds since stream start (DVS convention).
    pub t: u64,
    /// Column, 0-based.
    pub x: u16,
    /// Row, 0-based.
    pub y: u16,
    /// Contrast polarity.
    pub p: Polarity,
}

impl Event {
    pub fn new(t: u64, x: u16, y: u16, p: Polarity) -> Self {
        Self { t, x, y, p }
    }

    /// Timestamp in seconds.
    #[inline]
    pub fn t_sec(&self) -> f64 {
        self.t as f64 * 1e-6
    }
}

/// An event plus its ground-truth provenance label. The label never reaches
/// any algorithm under test — it is used only by the metrics layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LabeledEvent {
    pub ev: Event,
    /// True if this event came from the scene (signal), false if it was
    /// injected background-activity noise.
    pub is_signal: bool,
}

/// Sensor geometry. QVGA (320×240) is the paper's evaluation resolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resolution {
    pub width: u16,
    pub height: u16,
}

impl Resolution {
    pub const QVGA: Resolution = Resolution { width: 320, height: 240 };
    /// DAVIS240C, used by the image-reconstruction task.
    pub const DAVIS240: Resolution = Resolution { width: 240, height: 180 };
    /// DAVIS346, used by the DND21 denoise recordings.
    pub const DAVIS346: Resolution = Resolution { width: 346, height: 260 };
    /// N-MNIST native sensor window.
    pub const NMNIST: Resolution = Resolution { width: 34, height: 34 };

    pub fn new(width: u16, height: u16) -> Self {
        assert!(width > 0 && height > 0);
        Self { width, height }
    }

    #[inline]
    pub fn pixels(&self) -> usize {
        self.width as usize * self.height as usize
    }

    #[inline]
    pub fn contains(&self, x: u16, y: u16) -> bool {
        x < self.width && y < self.height
    }

    /// Flat row-major pixel index.
    #[inline]
    pub fn index(&self, x: u16, y: u16) -> usize {
        debug_assert!(self.contains(x, y));
        y as usize * self.width as usize + x as usize
    }
}

/// Sort events by timestamp, stably (ties keep generation order, which
/// matches the AER arbiter's fairness in hardware).
pub fn sort_events(events: &mut [Event]) {
    events.sort_by_key(|e| e.t);
}

/// Merge two already-sorted event streams into one sorted stream.
pub fn merge_sorted(a: &[LabeledEvent], b: &[LabeledEvent]) -> Vec<LabeledEvent> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i].ev.t <= b[j].ev.t {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polarity_roundtrip() {
        assert_eq!(Polarity::from_index(Polarity::On.index()), Polarity::On);
        assert_eq!(Polarity::from_index(Polarity::Off.index()), Polarity::Off);
        assert_eq!(Polarity::On.sign(), 1);
        assert_eq!(Polarity::Off.sign(), -1);
    }

    #[test]
    fn resolution_indexing() {
        let r = Resolution::QVGA;
        assert_eq!(r.pixels(), 76_800);
        assert_eq!(r.index(0, 0), 0);
        assert_eq!(r.index(319, 239), 76_799);
        assert!(r.contains(319, 239));
        assert!(!r.contains(320, 0));
    }

    #[test]
    fn merge_sorted_interleaves() {
        let mk = |t| LabeledEvent { ev: Event::new(t, 0, 0, Polarity::On), is_signal: true };
        let a = vec![mk(1), mk(5), mk(9)];
        let b = vec![mk(2), mk(5), mk(10)];
        let m = merge_sorted(&a, &b);
        let ts: Vec<u64> = m.iter().map(|e| e.ev.t).collect();
        assert_eq!(ts, vec![1, 2, 5, 5, 9, 10]);
    }

    #[test]
    fn t_sec_scaling() {
        let e = Event::new(1_500_000, 1, 2, Polarity::Off);
        assert!((e.t_sec() - 1.5).abs() < 1e-12);
    }
}
