//! Event-stream slicing and windowing helpers.
//!
//! The classification pipeline cuts streams into fixed 50 ms windows
//! (paper Sec. IV-D); the reconstruction pipeline cuts at APS frame
//! timestamps (Sec. IV-E). Both are implemented here over sorted slices.

use super::event::LabeledEvent;

/// Iterator of consecutive fixed-duration windows over a sorted stream.
/// Each item is (window_start_us, window_end_us, &[events in window)).
pub struct Windows<'a> {
    events: &'a [LabeledEvent],
    window_us: u64,
    end_us: u64,
    cursor: usize,
    t: u64,
}

/// Cut `events` (sorted) into `window_us` windows covering [0, end_us).
pub fn windows(events: &[LabeledEvent], window_us: u64, end_us: u64) -> Windows<'_> {
    assert!(window_us > 0);
    Windows { events, window_us, end_us, cursor: 0, t: 0 }
}

impl<'a> Iterator for Windows<'a> {
    type Item = (u64, u64, &'a [LabeledEvent]);

    fn next(&mut self) -> Option<Self::Item> {
        if self.t >= self.end_us {
            return None;
        }
        let start = self.t;
        let end = (self.t + self.window_us).min(self.end_us);
        let lo = self.cursor;
        let mut hi = lo;
        while hi < self.events.len() && self.events[hi].ev.t < end {
            hi += 1;
        }
        self.cursor = hi;
        self.t = end;
        Some((start, end, &self.events[lo..hi]))
    }
}

/// Slice events into intervals ending at each cut timestamp: for cuts
/// `[t1, t2, ...]` yields the events in [prev, t_i). Used for APS-aligned
/// segmentation in the reconstruction task.
pub fn slices_at<'a>(
    events: &'a [LabeledEvent],
    cuts: &[u64],
) -> Vec<(u64, &'a [LabeledEvent])> {
    let mut out = Vec::with_capacity(cuts.len());
    let mut lo = 0usize;
    let mut _prev = 0u64;
    for &c in cuts {
        let mut hi = lo;
        while hi < events.len() && events[hi].ev.t < c {
            hi += 1;
        }
        out.push((c, &events[lo..hi]));
        lo = hi;
        _prev = c;
    }
    out
}

/// Event-rate series: events per second in consecutive bins (diagnostics
/// and the architecture model's activity input).
pub fn rate_series(events: &[LabeledEvent], bin_us: u64, end_us: u64) -> Vec<f64> {
    let mut out = Vec::new();
    for (_s, _e, w) in windows(events, bin_us, end_us) {
        out.push(w.len() as f64 / (bin_us as f64 * 1e-6));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::event::{Event, Polarity};

    fn ev(t: u64) -> LabeledEvent {
        LabeledEvent { ev: Event::new(t, 0, 0, Polarity::On), is_signal: true }
    }

    #[test]
    fn windows_partition_exactly() {
        let evs: Vec<LabeledEvent> = [5, 10, 49_999, 50_000, 99_999, 150_000].iter()
            .map(|&t| ev(t)).collect();
        let ws: Vec<_> = windows(&evs, 50_000, 200_000).collect();
        assert_eq!(ws.len(), 4);
        assert_eq!(ws[0].2.len(), 3); // 5, 10, 49999
        assert_eq!(ws[1].2.len(), 2); // 50000, 99999
        assert_eq!(ws[2].2.len(), 0);
        assert_eq!(ws[3].2.len(), 1); // 150000
        let total: usize = ws.iter().map(|w| w.2.len()).sum();
        assert_eq!(total, evs.len());
    }

    #[test]
    fn windows_cover_range_without_events() {
        let ws: Vec<_> = windows(&[], 10_000, 35_000).collect();
        assert_eq!(ws.len(), 4);
        assert_eq!(ws[3].0, 30_000);
        assert_eq!(ws[3].1, 35_000);
    }

    #[test]
    fn slices_at_cuts() {
        let evs: Vec<LabeledEvent> = [10, 20, 30, 40].iter().map(|&t| ev(t)).collect();
        let s = slices_at(&evs, &[25, 45]);
        assert_eq!(s[0].1.len(), 2);
        assert_eq!(s[1].1.len(), 2);
    }

    #[test]
    fn rate_series_counts() {
        let evs: Vec<LabeledEvent> = (0..100).map(|k| ev(k * 1_000)).collect();
        let r = rate_series(&evs, 50_000, 100_000);
        assert_eq!(r.len(), 2);
        assert!((r[0] - 1_000.0).abs() < 1e-9); // 50 events / 50 ms
        assert!((r[1] - 1_000.0).abs() < 1e-9);
    }
}
