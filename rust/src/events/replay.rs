//! Event-stream persistence and multi-stream replay: save/load labeled
//! recordings so experiment workloads can be frozen, shared and
//! replayed byte-identically, and interleave many labeled streams into
//! one deterministic multi-camera feed (the serve-layer workload).
//!
//! Two persistence formats:
//! * binary `.aer` — the [`super::aer`] wire format plus a label bitmap
//!   and a small header (geometry, duration);
//! * text `.csv` — `t,x,y,p,label` rows for quick inspection/plotting.
//!
//! Multi-stream replay ([`interleave`]): each [`StreamSpec`] carries its
//! own resolution and a playback `rate` (timestamps divided by it), and
//! the merged iterator yields [`TaggedEvent`]s in deterministic
//! (replay time, stream index) order — the fixture the `serve` CLI and
//! `bench_serve` feed to concurrent sessions.

use super::aer;
use super::event::{ClockPolicy, Event, LabeledEvent, Polarity, Resolution};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"TSISCAER";
const VERSION: u8 = 1;

/// A saved recording.
#[derive(Clone, Debug, PartialEq)]
pub struct Recording {
    pub res: Resolution,
    pub duration_us: u64,
    pub events: Vec<LabeledEvent>,
}

/// Serialize to the binary container.
pub fn to_bytes(rec: &Recording) -> Vec<u8> {
    let events: Vec<Event> = rec.events.iter().map(|l| l.ev).collect();
    let payload = aer::encode(&events);
    let mut out = Vec::with_capacity(payload.len() + rec.events.len() / 8 + 64);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&rec.res.width.to_le_bytes());
    out.extend_from_slice(&rec.res.height.to_le_bytes());
    out.extend_from_slice(&rec.duration_us.to_le_bytes());
    out.extend_from_slice(&(rec.events.len() as u64).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    // Label bitmap (1 = signal).
    let mut bitmap = vec![0u8; rec.events.len().div_ceil(8)];
    for (i, le) in rec.events.iter().enumerate() {
        if le.is_signal {
            bitmap[i / 8] |= 1 << (i % 8);
        }
    }
    out.extend_from_slice(&bitmap);
    out
}

/// Deserialize from the binary container.
pub fn from_bytes(bytes: &[u8]) -> Result<Recording, String> {
    let need = |n: usize, pos: usize| -> Result<(), String> {
        if pos + n > bytes.len() {
            Err(format!("truncated at offset {pos}"))
        } else {
            Ok(())
        }
    };
    need(MAGIC.len() + 1, 0)?;
    if &bytes[..8] != MAGIC {
        return Err("bad magic".into());
    }
    if bytes[8] != VERSION {
        return Err(format!("unsupported version {}", bytes[8]));
    }
    let mut pos = 9;
    let rd_u16 = |pos: &mut usize| -> u16 {
        let v = u16::from_le_bytes([bytes[*pos], bytes[*pos + 1]]);
        *pos += 2;
        v
    };
    need(2 + 2 + 8 + 8 + 8, pos)?;
    let w = rd_u16(&mut pos);
    let h = rd_u16(&mut pos);
    let rd_u64 = |pos: &mut usize| -> u64 {
        let mut b = [0u8; 8];
        b.copy_from_slice(&bytes[*pos..*pos + 8]);
        *pos += 8;
        u64::from_le_bytes(b)
    };
    let duration_us = rd_u64(&mut pos);
    let n_events = rd_u64(&mut pos) as usize;
    let payload_len = rd_u64(&mut pos) as usize;
    need(payload_len, pos)?;
    let res = Resolution::new(w, h);
    let events = aer::decode(&bytes[pos..pos + payload_len], res)
        .map_err(|e| format!("payload: {e}"))?;
    pos += payload_len;
    if events.len() != n_events {
        return Err(format!("event count mismatch: {} vs {}", events.len(), n_events));
    }
    let bm_len = n_events.div_ceil(8);
    need(bm_len, pos)?;
    let bitmap = &bytes[pos..pos + bm_len];
    let labeled = events
        .into_iter()
        .enumerate()
        .map(|(i, ev)| LabeledEvent { ev, is_signal: bitmap[i / 8] & (1 << (i % 8)) != 0 })
        .collect();
    Ok(Recording { res, duration_us, events: labeled })
}

/// Save to a file (binary container).
pub fn save(rec: &Recording, path: impl AsRef<Path>) -> std::io::Result<()> {
    std::fs::File::create(path)?.write_all(&to_bytes(rec))
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<Recording, String> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())
        .map_err(|e| e.to_string())?
        .read_to_end(&mut bytes)
        .map_err(|e| e.to_string())?;
    from_bytes(&bytes)
}

/// Export as CSV (`t_us,x,y,polarity,is_signal`).
pub fn to_csv(rec: &Recording) -> String {
    let mut s = String::from("t_us,x,y,polarity,is_signal\n");
    for le in &rec.events {
        s.push_str(&format!(
            "{},{},{},{},{}\n",
            le.ev.t,
            le.ev.x,
            le.ev.y,
            match le.ev.p {
                Polarity::On => 1,
                Polarity::Off => 0,
            },
            le.is_signal as u8
        ));
    }
    s
}

/// Parse the CSV form back.
pub fn from_csv(text: &str, res: Resolution, duration_us: u64) -> Result<Recording, String> {
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate().skip(1) {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split(',').collect();
        if cols.len() != 5 {
            return Err(format!("line {}: expected 5 columns", i + 1));
        }
        let parse = |s: &str| s.trim().parse::<u64>().map_err(|e| format!("line {}: {e}", i + 1));
        let t = parse(cols[0])?;
        let x = parse(cols[1])? as u16;
        let y = parse(cols[2])? as u16;
        if !res.contains(x, y) {
            return Err(format!("line {}: ({x},{y}) out of range", i + 1));
        }
        let p = if parse(cols[3])? != 0 { Polarity::On } else { Polarity::Off };
        let is_signal = parse(cols[4])? != 0;
        events.push(LabeledEvent { ev: Event::new(t, x, y, p), is_signal });
    }
    Ok(Recording { res, duration_us, events })
}

/// One labeled stream of an interleaved multi-camera replay.
#[derive(Clone, Debug)]
pub struct StreamSpec {
    /// Display label (scene name, file stem, …).
    pub name: String,
    pub res: Resolution,
    /// Time-sorted labeled events in the stream's own clock.
    pub events: Vec<LabeledEvent>,
    /// Playback rate: replay timestamps are the stream's divided by
    /// this factor ([`scale_time`]), so 2.0 replays at twice real-time
    /// speed. Must be > 0.
    pub rate: f64,
}

impl StreamSpec {
    /// A stream replayed at real-time speed.
    pub fn new(name: impl Into<String>, res: Resolution, events: Vec<LabeledEvent>) -> Self {
        Self { name: name.into(), res, events, rate: 1.0 }
    }

    /// End of the stream on the replay clock (exclusive; 0 when empty).
    pub fn replay_end_us(&self) -> u64 {
        self.events.last().map(|le| scale_time(le.ev.t, self.rate) + 1).unwrap_or(0)
    }
}

/// An event of one stream of a multi-stream replay, on the shared
/// replay clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaggedEvent {
    /// Index into the [`interleave`] input slice.
    pub stream: usize,
    /// The event with its timestamp rescaled to the replay clock.
    pub le: LabeledEvent,
}

/// Replay timestamp of stream time `t` under rate scaling (monotone in
/// `t`, so per-stream order is preserved; clamped to ≥ 1 because 0 is
/// the never-written sentinel throughout the stack).
#[inline]
pub fn scale_time(t: u64, rate: f64) -> u64 {
    ((t as f64 / rate).round() as u64).max(1)
}

/// Deterministically interleave labeled streams into one replay-ordered
/// feed: a lazy k-way merge by (scaled timestamp, stream index), so
/// equal-time events always replay in stream-index order and the merge
/// is reproducible run-to-run and platform-to-platform. The output
/// preserves every stream as an in-order subsequence.
///
/// Inputs are *expected* time-sorted, but a stream whose clock runs
/// backwards (recording glitch, merge bug) is handled explicitly
/// rather than breaking the merge order: this constructor applies
/// [`ClockPolicy::Clamp`] — see [`interleave_with_policy`] to choose,
/// and [`MultiReplay::nonmonotonic`] to observe how often it fired.
pub fn interleave(streams: &[StreamSpec]) -> MultiReplay<'_> {
    interleave_with_policy(streams, ClockPolicy::Clamp)
}

/// [`interleave`] with an explicit non-monotonic-timestamp policy:
/// `Clamp` raises a backwards event to its stream's replay watermark
/// (keeping the global merge nondecreasing), `Reject` drops it. Equal
/// timestamps (duplicates) always pass. Every clamped or dropped event
/// is counted in [`MultiReplay::nonmonotonic`].
pub fn interleave_with_policy(streams: &[StreamSpec], policy: ClockPolicy) -> MultiReplay<'_> {
    MultiReplay {
        streams,
        heads: vec![0; streams.len()],
        last_t: vec![0; streams.len()],
        policy,
        nonmonotonic: 0,
    }
}

/// Iterator returned by [`interleave`] / [`interleave_with_policy`].
pub struct MultiReplay<'a> {
    streams: &'a [StreamSpec],
    heads: Vec<usize>,
    /// Per-stream replay-clock watermark (highest emitted time).
    last_t: Vec<u64>,
    policy: ClockPolicy,
    nonmonotonic: u64,
}

impl MultiReplay<'_> {
    /// Events so far whose scaled timestamp ran backwards within their
    /// own stream and were clamped or rejected per the policy.
    pub fn nonmonotonic(&self) -> u64 {
        self.nonmonotonic
    }
}

impl Iterator for MultiReplay<'_> {
    type Item = TaggedEvent;

    fn next(&mut self) -> Option<TaggedEvent> {
        // Linear head scan: stream counts are small (a camera fleet,
        // not a data center), so this beats heap bookkeeping.
        let mut best: Option<(u64, usize)> = None;
        for s in 0..self.streams.len() {
            let spec = &self.streams[s];
            let head_t = loop {
                let Some(le) = spec.events.get(self.heads[s]) else { break None };
                let t = scale_time(le.ev.t, spec.rate);
                if t < self.last_t[s] {
                    // Backwards within its stream (duplicates pass: `<`).
                    match self.policy {
                        ClockPolicy::Clamp => break Some(self.last_t[s]),
                        ClockPolicy::Reject => {
                            self.nonmonotonic += 1;
                            self.heads[s] += 1;
                            continue;
                        }
                    }
                }
                break Some(t);
            };
            if let Some(t) = head_t {
                // Strict < keeps the lowest stream index on time ties.
                match best {
                    Some((bt, _)) if t >= bt => {}
                    _ => best = Some((t, s)),
                }
            }
        }
        let (t, s) = best?;
        let mut le = self.streams[s].events[self.heads[s]];
        if scale_time(le.ev.t, self.streams[s].rate) < self.last_t[s] {
            // Count the clamp only on emission, so re-scans of a pending
            // head don't inflate the counter.
            self.nonmonotonic += 1;
        }
        self.heads[s] += 1;
        self.last_t[s] = t;
        le.ev.t = t;
        Some(TaggedEvent { stream: s, le })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn sample_rec() -> Recording {
        Recording {
            res: Resolution::new(32, 24),
            duration_us: 100_000,
            events: vec![
                LabeledEvent { ev: Event::new(10, 1, 2, Polarity::On), is_signal: true },
                LabeledEvent { ev: Event::new(500, 31, 23, Polarity::Off), is_signal: false },
                LabeledEvent { ev: Event::new(99_999, 0, 0, Polarity::On), is_signal: true },
            ],
        }
    }

    #[test]
    fn binary_roundtrip() {
        let rec = sample_rec();
        let back = from_bytes(&to_bytes(&rec)).unwrap();
        assert_eq!(rec, back);
    }

    #[test]
    fn csv_roundtrip() {
        let rec = sample_rec();
        let back = from_csv(&to_csv(&rec), rec.res, rec.duration_us).unwrap();
        assert_eq!(rec, back);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let mut b = to_bytes(&sample_rec());
        b[0] = b'X';
        assert!(from_bytes(&b).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let b = to_bytes(&sample_rec());
        for cut in [4usize, 12, b.len() - 1] {
            assert!(from_bytes(&b[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn file_roundtrip() {
        let rec = sample_rec();
        let path = std::env::temp_dir().join("tsisc_replay_test.aer");
        save(&rec, &path).unwrap();
        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(rec, back);
    }

    fn spec(name: &str, rate: f64, ts: &[u64]) -> StreamSpec {
        StreamSpec {
            name: name.into(),
            res: Resolution::new(8, 8),
            events: ts
                .iter()
                .map(|&t| LabeledEvent { ev: Event::new(t, 1, 2, Polarity::On), is_signal: true })
                .collect(),
            rate,
        }
    }

    #[test]
    fn interleave_merges_by_time_with_stream_index_ties() {
        let streams = [spec("a", 1.0, &[10, 30, 30]), spec("b", 1.0, &[10, 20, 40])];
        let got: Vec<(usize, u64)> =
            interleave(&streams).map(|te| (te.stream, te.le.ev.t)).collect();
        // Equal times replay lowest-stream-first; each stream stays an
        // in-order subsequence.
        assert_eq!(got, vec![(0, 10), (1, 10), (1, 20), (0, 30), (0, 30), (1, 40)]);
    }

    #[test]
    fn interleave_rate_scales_timestamps() {
        let streams = [spec("fast", 2.0, &[100, 200]), spec("slow", 0.5, &[100])];
        let got: Vec<(usize, u64)> =
            interleave(&streams).map(|te| (te.stream, te.le.ev.t)).collect();
        // rate 2 halves timestamps, rate 0.5 doubles them.
        assert_eq!(got, vec![(0, 50), (0, 100), (1, 200)]);
        assert_eq!(streams[0].replay_end_us(), 101);
        assert_eq!(scale_time(1, 4.0), 1, "scaled times never hit the 0 sentinel");
    }

    #[test]
    fn interleave_is_deterministic_and_complete() {
        let streams =
            [spec("a", 1.0, &[5, 9, 13]), spec("b", 1.3, &[1, 7]), spec("c", 0.7, &[2, 3, 4])];
        let a: Vec<TaggedEvent> = interleave(&streams).collect();
        let b: Vec<TaggedEvent> = interleave(&streams).collect();
        assert_eq!(a, b);
        assert_eq!(a.len(), 8, "every event of every stream replays exactly once");
        // Globally nondecreasing on the replay clock.
        assert!(a.windows(2).all(|w| w[0].le.ev.t <= w[1].le.ev.t));
        // Empty input terminates immediately.
        assert_eq!(interleave(&[]).count(), 0);
    }

    #[test]
    fn interleave_clamps_backwards_timestamps_by_default() {
        // Stream a glitches backwards (30 → 12 → 35); stream b is clean.
        let streams = [spec("a", 1.0, &[10, 30, 12, 35]), spec("b", 1.0, &[20])];
        let mut it = interleave(&streams);
        let got: Vec<(usize, u64)> = it.by_ref().map(|te| (te.stream, te.le.ev.t)).collect();
        // 12 is clamped up to 30; the merge stays globally nondecreasing
        // and every event survives.
        assert_eq!(got, vec![(0, 10), (1, 20), (0, 30), (0, 30), (0, 35)]);
        assert_eq!(it.nonmonotonic(), 1);
    }

    #[test]
    fn interleave_reject_policy_drops_backwards_timestamps() {
        let streams = [spec("a", 1.0, &[10, 30, 12, 35]), spec("b", 1.0, &[20])];
        let mut it = interleave_with_policy(&streams, ClockPolicy::Reject);
        let got: Vec<(usize, u64)> = it.by_ref().map(|te| (te.stream, te.le.ev.t)).collect();
        assert_eq!(got, vec![(0, 10), (1, 20), (0, 30), (0, 35)]);
        assert_eq!(it.nonmonotonic(), 1);
    }

    #[test]
    fn interleave_duplicate_timestamps_pass_under_both_policies() {
        for policy in [ClockPolicy::Clamp, ClockPolicy::Reject] {
            let streams = [spec("a", 1.0, &[10, 10, 10])];
            let mut it = interleave_with_policy(&streams, policy);
            let got: Vec<u64> = it.by_ref().map(|te| te.le.ev.t).collect();
            assert_eq!(got, vec![10, 10, 10], "{policy:?}");
            assert_eq!(it.nonmonotonic(), 0, "duplicates are not backwards ({policy:?})");
        }
    }

    #[test]
    fn prop_roundtrip_random_recordings() {
        check("replay roundtrip", 60, |g| {
            let res = Resolution::new(16, 16);
            let n = g.usize(0, 100);
            let mut t = 0u64;
            let events: Vec<LabeledEvent> = (0..n)
                .map(|_| {
                    t += g.u64(0, 5_000);
                    LabeledEvent {
                        ev: Event::new(
                            t,
                            g.u64(0, 15) as u16,
                            g.u64(0, 15) as u16,
                            if g.bool(0.5) { Polarity::On } else { Polarity::Off },
                        ),
                        is_signal: g.bool(0.5),
                    }
                })
                .collect();
            let rec = Recording { res, duration_us: t + 1, events };
            assert_eq!(from_bytes(&to_bytes(&rec)).unwrap(), rec);
        });
    }
}
