//! Video-to-events conversion (v2e-style temporal-contrast model).
//!
//! The paper's "driving" DND21 sequence was produced by the v2e tool [56]:
//! each pixel integrates log intensity and emits an event whenever the
//! change since its last event crosses the contrast threshold. We implement
//! the same model: per-pixel log-intensity memory, separate ON/OFF
//! thresholds, a refractory period, and sub-step timestamp interpolation —
//! multiple events are emitted for large steps, as in the reference tool.

use super::event::{Event, LabeledEvent, Polarity, Resolution};
use super::scene::Scene;
use crate::util::rng::Pcg64;

/// DVS pixel model parameters.
#[derive(Clone, Copy, Debug)]
pub struct DvsParams {
    /// ON contrast threshold in log-intensity units (typ. 0.2–0.4).
    pub theta_on: f64,
    /// OFF contrast threshold (positive magnitude).
    pub theta_off: f64,
    /// Per-pixel threshold mismatch σ (absolute, log-intensity units).
    /// Real DVS front-ends show σ ≈ 0.03–0.05; this desynchronizes event
    /// bursts the way real sensors do (v2e [56] models the same effect).
    pub theta_sigma: f64,
    /// Pixel refractory period in µs — the minimum inter-event spacing the
    /// front-end allows at one pixel.
    pub refractory_us: u64,
    /// Sampling period of the latent video in µs. Events inside a step are
    /// linearly interpolated in time.
    pub dt_us: u64,
    /// Seed for the per-pixel mismatch map.
    pub mismatch_seed: u64,
}

impl Default for DvsParams {
    fn default() -> Self {
        Self {
            theta_on: 0.25,
            theta_off: 0.25,
            theta_sigma: 0.04,
            refractory_us: 100,
            dt_us: 1_000,
            mismatch_seed: 0xd5,
        }
    }
}

/// Convert a scene to a labeled signal-event stream over [0, duration_s].
///
/// Events are produced in nondecreasing timestamp order. All events from the
/// converter are labeled `is_signal = true`; noise is injected separately by
/// [`super::noise`].
pub fn convert(
    scene: &dyn Scene,
    res: Resolution,
    params: DvsParams,
    duration_s: f64,
) -> Vec<LabeledEvent> {
    let w = res.width as usize;
    let h = res.height as usize;
    let n = w * h;
    let steps = (duration_s * 1e6 / params.dt_us as f64).round() as u64;

    // Per-pixel state: log intensity at the last emitted event (the DVS
    // "memorized" level), last event time for the refractory check, and the
    // mismatched per-pixel thresholds.
    let mut mem = vec![0.0f64; n];
    let mut last_ev = vec![0u64; n];
    let mut rng = Pcg64::with_stream(params.mismatch_seed, 0x7e);
    let th_on: Vec<f64> = (0..n)
        .map(|_| (params.theta_on + params.theta_sigma * rng.normal()).max(0.05))
        .collect();
    let th_off: Vec<f64> = (0..n)
        .map(|_| (params.theta_off + params.theta_sigma * rng.normal()).max(0.05))
        .collect();
    for y in 0..h {
        for x in 0..w {
            mem[y * w + x] = scene.intensity(x as f64, y as f64, 0.0).ln();
        }
    }

    // Events within a step are collected then sorted by interpolated
    // timestamp, keeping the global stream ordered.
    let mut out: Vec<LabeledEvent> = Vec::new();
    let mut step_buf: Vec<Event> = Vec::new();
    let mut prev_log = mem.clone();

    for s in 1..=steps {
        let t_us = s * params.dt_us;
        let t_s = t_us as f64 * 1e-6;
        let t_prev_us = (s - 1) * params.dt_us;
        step_buf.clear();
        for y in 0..h {
            for x in 0..w {
                let i = y * w + x;
                let l = scene.intensity(x as f64, y as f64, t_s).ln();
                let l_prev = prev_log[i];
                prev_log[i] = l;
                // Emit one event per full threshold crossing relative to the
                // memorized level, walking the level toward the new value.
                loop {
                    let d = l - mem[i];
                    let (theta, pol) = if d >= th_on[i] {
                        (th_on[i], Polarity::On)
                    } else if d <= -th_off[i] {
                        (th_off[i], Polarity::Off)
                    } else {
                        break;
                    };
                    // Interpolated crossing time inside the step: fraction of
                    // the step's total log change consumed so far.
                    let total = (l - l_prev).abs().max(1e-12);
                    let crossed = match pol {
                        Polarity::On => mem[i] + theta - l_prev,
                        Polarity::Off => l_prev - (mem[i] - theta),
                    };
                    let frac = (crossed / total).clamp(0.0, 1.0);
                    let te = t_prev_us + (frac * params.dt_us as f64) as u64;
                    mem[i] += match pol {
                        Polarity::On => theta,
                        Polarity::Off => -theta,
                    };
                    // Refractory: drop the event but keep the level update
                    // (the front-end resets its reference at the diff amp).
                    if last_ev[i] == 0 || te >= last_ev[i] + params.refractory_us {
                        last_ev[i] = te.max(1); // t=0 reserved for "never"
                        step_buf.push(Event::new(te.max(1), x as u16, y as u16, pol));
                    }
                }
            }
        }
        step_buf.sort_by_key(|e| e.t);
        out.extend(step_buf.iter().map(|&ev| LabeledEvent { ev, is_signal: true }));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::scene::{EdgeScene, Scene};

    /// Deterministic ramp scene for threshold math checks.
    struct Ramp {
        rate: f64, // log-intensity per second
    }
    impl Scene for Ramp {
        fn intensity(&self, _x: f64, _y: f64, t: f64) -> f64 {
            (self.rate * t).exp()
        }
        fn name(&self) -> &'static str {
            "ramp"
        }
    }

    #[test]
    fn ramp_event_rate_matches_threshold() {
        // log I rises at 2.0/s; θ_on = 0.25 → 8 ON events per pixel per s.
        let res = Resolution::new(4, 4);
        let params = DvsParams {
            theta_on: 0.25,
            theta_off: 0.25,
            theta_sigma: 0.0,
            refractory_us: 0,
            dt_us: 1000,
            ..DvsParams::default()
        };
        let evs = convert(&Ramp { rate: 2.0 }, res, params, 1.0);
        let per_pixel = evs.len() as f64 / 16.0;
        assert!((per_pixel - 8.0).abs() <= 1.0, "per_pixel={per_pixel}");
        assert!(evs.iter().all(|e| e.ev.p == Polarity::On));
    }

    #[test]
    fn falling_ramp_gives_off_events() {
        let res = Resolution::new(2, 2);
        let evs = convert(&Ramp { rate: -2.0 }, res, DvsParams::default(), 0.5);
        assert!(!evs.is_empty());
        assert!(evs.iter().all(|e| e.ev.p == Polarity::Off));
    }

    #[test]
    fn stream_is_time_sorted() {
        let scene = EdgeScene::new(200.0, 5);
        let evs = convert(&scene, Resolution::new(32, 24), DvsParams::default(), 0.2);
        assert!(!evs.is_empty());
        assert!(evs.windows(2).all(|w| w[0].ev.t <= w[1].ev.t));
    }

    #[test]
    fn refractory_limits_rate() {
        let res = Resolution::new(2, 2);
        let fast = DvsParams { refractory_us: 0, ..DvsParams::default() };
        let slow = DvsParams { refractory_us: 300_000, ..DvsParams::default() };
        let scene = Ramp { rate: 6.0 };
        let n_fast = convert(&scene, res, fast, 1.0).len();
        let n_slow = convert(&scene, res, slow, 1.0).len();
        assert!(n_slow < n_fast, "refractory should drop events: {n_slow} vs {n_fast}");
        // ≥300 ms spacing → at most 4 events per pixel in 1 s.
        assert!(n_slow <= 4 * 4, "n_slow={n_slow}");
    }

    #[test]
    fn static_scene_is_silent() {
        struct Flat;
        impl Scene for Flat {
            fn intensity(&self, _: f64, _: f64, _: f64) -> f64 {
                0.5
            }
            fn name(&self) -> &'static str {
                "flat"
            }
        }
        let evs = convert(&Flat, Resolution::new(8, 8), DvsParams::default(), 0.5);
        assert!(evs.is_empty());
    }

    #[test]
    fn events_within_bounds_and_labeled_signal() {
        let scene = EdgeScene::new(150.0, 9);
        let res = Resolution::new(24, 16);
        let evs = convert(&scene, res, DvsParams::default(), 0.1);
        for e in &evs {
            assert!(res.contains(e.ev.x, e.ev.y));
            assert!(e.is_signal);
            assert!(e.ev.t > 0);
        }
    }
}
