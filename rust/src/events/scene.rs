//! Latent-intensity scene models.
//!
//! Every synthetic dataset in this reproduction is produced the same way the
//! paper's "driving" DND21 sequence was produced: a latent intensity video is
//! converted to events by a v2e-style temporal-contrast model
//! (`events::v2e`). A scene is simply a deterministic function
//! `intensity(x, y, t) -> linear intensity in (0, 1]`, so event statistics
//! follow from scene motion exactly as in a real DVS.

use crate::util::rng::Pcg64;

/// A time-varying latent intensity field. Implementations must be
/// deterministic in (x, y, t) so the converter can sample them freely.
pub trait Scene {
    /// Linear intensity at pixel center (x, y) at time `t` seconds.
    /// Must be strictly positive (log-intensity is taken downstream).
    fn intensity(&self, x: f64, y: f64, t: f64) -> f64;

    /// Human-readable name used in reports.
    fn name(&self) -> &'static str;
}

/// "hotel-bar"-like scene: a static background with a few wandering
/// blob-shaped foreground objects (people moving through an otherwise
/// stationary view from a fixed camera). Sparse events.
pub struct BlobScene {
    blobs: Vec<Blob>,
    background: f64,
}

struct Blob {
    /// Piecewise-linear waypoint path: (t, x, y) knots.
    path: Vec<(f64, f64, f64)>,
    radius: f64,
    brightness: f64,
}

impl BlobScene {
    /// `n_blobs` wanderers over a `width`×`height` field for `duration` s.
    /// Blob size and wander scale with the geometry so foreground coverage
    /// stays at the sparse (~10 %) level of a real static-camera scene.
    pub fn new(width: u16, height: u16, n_blobs: usize, duration: f64, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0xb10b);
        let w = width as f64;
        let h = height as f64;
        let mut blobs = Vec::with_capacity(n_blobs);
        for _ in 0..n_blobs {
            // Random waypoints every ~0.5 s; blobs move at walking pace
            // (a body-width or so per half second).
            let n_way = (duration / 0.5).ceil() as usize + 2;
            let mut path = Vec::with_capacity(n_way);
            let mut x = rng.range_f64(0.0, w);
            let mut y = rng.range_f64(0.0, h);
            for k in 0..n_way {
                path.push((k as f64 * 0.5, x, y));
                x = (x + rng.range_f64(-w / 4.0, w / 4.0)).clamp(0.0, w);
                y = (y + rng.range_f64(-h / 10.0, h / 10.0)).clamp(0.0, h);
            }
            blobs.push(Blob {
                path,
                radius: rng.range_f64(0.05 * w, 0.10 * w),
                brightness: rng.range_f64(0.35, 0.8),
            });
        }
        Self { blobs, background: 0.15 }
    }
}

impl Blob {
    fn position(&self, t: f64) -> (f64, f64) {
        let last = self.path.len() - 1;
        if t <= self.path[0].0 {
            return (self.path[0].1, self.path[0].2);
        }
        if t >= self.path[last].0 {
            return (self.path[last].1, self.path[last].2);
        }
        // Linear interpolation between surrounding knots.
        let i = self.path.partition_point(|k| k.0 <= t) - 1;
        let (t0, x0, y0) = self.path[i];
        let (t1, x1, y1) = self.path[i + 1];
        let f = (t - t0) / (t1 - t0);
        (x0 + f * (x1 - x0), y0 + f * (y1 - y0))
    }
}

impl Scene for BlobScene {
    fn intensity(&self, x: f64, y: f64, t: f64) -> f64 {
        let mut v = self.background;
        for b in &self.blobs {
            let (bx, by) = b.position(t);
            let (rx, ry) = (x - bx, y - by);
            let d = (rx * rx + ry * ry).sqrt();
            // Sharp-edged body (sigmoid silhouette, ~1 px transition) with
            // body-fixed internal texture (clothing folds / limbs): real
            // foreground objects produce dense simultaneous bursts along
            // their contours, which is what gives the STCF its support.
            let silhouette = 1.0 / (1.0 + ((d - b.radius) / 0.6).exp());
            let tex = 1.0 + 0.35 * (rx * 1.1).sin() * (ry * 0.9).cos();
            v += b.brightness * tex * silhouette;
        }
        v.max(1e-3)
    }

    fn name(&self) -> &'static str {
        "hotelbar-like"
    }
}

/// "driving"-like scene: the whole field translates (global ego-motion past
/// vertical structure: poles, lamp posts, lane markings). Thin bright bars
/// over a darker background: each bar's leading edge fires ON events and
/// its trailing edge OFF events a bar-width later — the mixed-polarity
/// local statistics of real driving footage.
pub struct EdgeScene {
    /// Horizontal speed in pixels/second.
    speed: f64,
    /// Thin bars: (spacing px, phase px, bar width px, amplitude).
    bars: Vec<(f64, f64, f64, f64)>,
}

impl EdgeScene {
    pub fn new(speed_px_per_s: f64, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0xed9e);
        let mut bars = Vec::new();
        // A few well-separated thin structures: most of the frame is quiet
        // background, activity concentrates at the bars.
        for _ in 0..2 {
            bars.push((
                rng.range_f64(30.0, 90.0),
                rng.range_f64(0.0, 90.0),
                rng.range_f64(1.5, 3.0),
                rng.range_f64(0.25, 0.5),
            ));
        }
        Self { speed: speed_px_per_s, bars }
    }
}

impl Scene for EdgeScene {
    fn intensity(&self, x: f64, y: f64, t: f64) -> f64 {
        let xs = x - self.speed * t;
        let mut v = 0.25;
        for &(spacing, phase, width, amp) in &self.bars {
            // Distance to the nearest bar center (periodic).
            let u = (xs - phase).rem_euclid(spacing);
            let d = u.min(spacing - u);
            // Smooth thin bar profile (~1 px transition).
            v += amp / (1.0 + ((d - width / 2.0) / 0.5).exp());
        }
        // Mild vertical shading so rows are not identical.
        v += 0.04 * (y * 0.05).sin();
        v.max(1e-3)
    }

    fn name(&self) -> &'static str {
        "driving-like"
    }
}

/// A small binary glyph raster moved along a saccade path — the N-MNIST
/// generation protocol (three saccades over a static glyph).
pub struct GlyphScene {
    glyph: crate::util::grid::Grid<f64>,
    /// Piecewise-linear (t, dx, dy) offsets of the glyph origin.
    saccades: Vec<(f64, f64, f64)>,
    background: f64,
}

impl GlyphScene {
    /// `glyph` is an intensity raster; the saccade path mimics the tri-phase
    /// N-MNIST camera motion over `duration` seconds.
    pub fn new(glyph: crate::util::grid::Grid<f64>, duration: f64, amplitude: f64) -> Self {
        // Triangle path: right-down, left-down, up-back — as in the N-MNIST
        // recording rig. Offsets relative to center.
        let d3 = duration / 3.0;
        let a = amplitude;
        let saccades = vec![
            (0.0, 0.0, 0.0),
            (d3, a, a * 0.5),
            (2.0 * d3, -a, a * 0.5),
            (duration, 0.0, -a),
        ];
        Self { glyph, saccades, background: 0.08 }
    }

    fn offset(&self, t: f64) -> (f64, f64) {
        let last = self.saccades.len() - 1;
        if t <= self.saccades[0].0 {
            return (self.saccades[0].1, self.saccades[0].2);
        }
        if t >= self.saccades[last].0 {
            return (self.saccades[last].1, self.saccades[last].2);
        }
        let i = self.saccades.partition_point(|k| k.0 <= t) - 1;
        let (t0, x0, y0) = self.saccades[i];
        let (t1, x1, y1) = self.saccades[i + 1];
        let f = (t - t0) / (t1 - t0);
        (x0 + f * (x1 - x0), y0 + f * (y1 - y0))
    }

    /// Bilinear sample of the glyph raster at fractional coordinates.
    fn sample(&self, gx: f64, gy: f64) -> f64 {
        let (w, h) = (self.glyph.width() as f64, self.glyph.height() as f64);
        if gx < 0.0 || gy < 0.0 || gx >= w - 1.0 || gy >= h - 1.0 {
            return 0.0;
        }
        let (x0, y0) = (gx.floor() as usize, gy.floor() as usize);
        let (fx, fy) = (gx - x0 as f64, gy - y0 as f64);
        let g = |x: usize, y: usize| *self.glyph.get(x, y);
        g(x0, y0) * (1.0 - fx) * (1.0 - fy)
            + g(x0 + 1, y0) * fx * (1.0 - fy)
            + g(x0, y0 + 1) * (1.0 - fx) * fy
            + g(x0 + 1, y0 + 1) * fx * fy
    }
}

impl Scene for GlyphScene {
    fn intensity(&self, x: f64, y: f64, t: f64) -> f64 {
        let (dx, dy) = self.offset(t);
        (self.background + 0.8 * self.sample(x - dx, y - dy)).max(1e-3)
    }

    fn name(&self) -> &'static str {
        "glyph-saccade"
    }
}

/// Smooth moving texture with paired ground-truth frames — the DAVIS240C
/// substitute for the reconstruction task: a sum-of-sinusoids texture under
/// rigid translation + slow rotation, so every pixel sees contrast changes.
pub struct TextureScene {
    comps: Vec<(f64, f64, f64, f64)>, // (kx, ky, phase, amp)
    vx: f64,
    vy: f64,
    omega: f64,
    cx: f64,
    cy: f64,
}

impl TextureScene {
    pub fn new(width: u16, height: u16, motion: TextureMotion, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0x7e47);
        let mut comps = Vec::new();
        for _ in 0..8 {
            let lambda = rng.range_f64(8.0, 48.0);
            let theta = rng.range_f64(0.0, std::f64::consts::TAU);
            let k = std::f64::consts::TAU / lambda;
            comps.push((
                k * theta.cos(),
                k * theta.sin(),
                rng.range_f64(0.0, std::f64::consts::TAU),
                rng.range_f64(0.09, 0.22),
            ));
        }
        let (vx, vy, omega) = match motion {
            TextureMotion::Translate { vx, vy } => (vx, vy, 0.0),
            TextureMotion::Rotate { omega } => (0.0, 0.0, omega),
            TextureMotion::Mixed { vx, vy, omega } => (vx, vy, omega),
        };
        Self { comps, vx, vy, omega, cx: width as f64 / 2.0, cy: height as f64 / 2.0 }
    }
}

/// Motion pattern of a [`TextureScene`] — mirrors the DAVIS240C sequence
/// taxonomy (translation-dominant vs rotation-dominant vs 6-DoF-like mixes).
#[derive(Clone, Copy, Debug)]
pub enum TextureMotion {
    Translate { vx: f64, vy: f64 },
    Rotate { omega: f64 },
    Mixed { vx: f64, vy: f64, omega: f64 },
}

impl Scene for TextureScene {
    fn intensity(&self, x: f64, y: f64, t: f64) -> f64 {
        // Rigid motion: rotate about center then translate.
        let (s, c) = (self.omega * t).sin_cos();
        let (rx, ry) = (x - self.cx, y - self.cy);
        let xr = c * rx + s * ry + self.cx - self.vx * t;
        let yr = -s * rx + c * ry + self.cy - self.vy * t;
        let mut v = 0.45;
        for &(kx, ky, phase, amp) in &self.comps {
            v += amp * (kx * xr + ky * yr + phase).sin();
        }
        v.max(1e-3)
    }

    fn name(&self) -> &'static str {
        "texture"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::grid::Grid;

    #[test]
    fn blob_scene_positive_and_moving() {
        let s = BlobScene::new(64, 48, 3, 2.0, 1);
        let mut changed = false;
        for t in [0.0, 0.5, 1.0] {
            for &(x, y) in &[(5.0, 5.0), (30.0, 20.0)] {
                assert!(s.intensity(x, y, t) > 0.0);
            }
        }
        let v0 = s.intensity(30.0, 20.0, 0.0);
        for k in 1..20 {
            if (s.intensity(30.0, 20.0, k as f64 * 0.1) - v0).abs() > 1e-3 {
                changed = true;
            }
        }
        assert!(changed, "blobs should move");
    }

    #[test]
    fn edge_scene_translates() {
        let s = EdgeScene::new(100.0, 2);
        // intensity(x, t) == intensity(x + v·dt, t + dt) up to the static
        // vertical shading term.
        let a = s.intensity(50.0, 10.0, 0.0);
        let b = s.intensity(50.0 + 100.0 * 0.1, 10.0, 0.1);
        assert!((a - b).abs() < 1e-9, "pure translation expected: {a} vs {b}");
    }

    #[test]
    fn glyph_scene_bilinear_inside_only() {
        let mut g = Grid::new(8, 8, 0.0);
        g.set(4, 4, 1.0);
        let s = GlyphScene::new(g, 0.3, 4.0);
        assert!(s.intensity(4.0, 4.0, 0.0) > s.intensity(0.0, 0.0, 0.0));
        // Far outside the raster → background only.
        assert!((s.intensity(100.0, 100.0, 0.0) - 0.08).abs() < 1e-9);
    }

    #[test]
    fn texture_scene_rigid_translation() {
        let s = TextureScene::new(64, 64, TextureMotion::Translate { vx: 30.0, vy: 0.0 }, 3);
        let a = s.intensity(20.0, 20.0, 0.0);
        let b = s.intensity(20.0 + 30.0 * 0.05, 20.0, 0.05);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn scenes_strictly_positive() {
        let scenes: Vec<Box<dyn Scene>> = vec![
            Box::new(BlobScene::new(32, 32, 2, 1.0, 7)),
            Box::new(EdgeScene::new(50.0, 7)),
            Box::new(TextureScene::new(32, 32, TextureMotion::Rotate { omega: 1.0 }, 7)),
        ];
        for s in &scenes {
            for ix in 0..8 {
                for iy in 0..8 {
                    for it in 0..4 {
                        let v = s.intensity(ix as f64 * 4.0, iy as f64 * 4.0, it as f64 * 0.2);
                        assert!(v > 0.0, "{} produced non-positive intensity", s.name());
                    }
                }
            }
        }
    }
}
