//! Synthetic event-camera classification datasets.
//!
//! Offline substitutes for the four Table-II datasets. Each sample is an
//! event stream produced by the same v2e converter used everywhere else, so
//! the temporal statistics (saccade-locked bursts, polarity structure,
//! motion-dependent rates) are genuine even though the imagery is synthetic:
//!
//! * `SynNMnist`   — 10 digit glyphs under tri-saccade motion (N-MNIST rig).
//! * `SynShapes`   — 8 shape classes with scale/rotation jitter
//!                   (N-Caltech101 stand-in).
//! * `SynCifarDvs` — shapes over moving textured background (harder,
//!                   CIFAR10-DVS stand-in).
//! * `SynGesture`  — 6 global-motion classes (DVS128-Gesture stand-in).

use super::event::{LabeledEvent, Resolution};
use super::raster::{digit_glyph, shape_glyph, ShapeClass};
use super::scene::{GlyphScene, Scene, TextureMotion, TextureScene};
use super::v2e::{convert, DvsParams};
use crate::util::grid::Grid;
use crate::util::rng::Pcg64;

/// One classification sample: an event stream plus its class label.
#[derive(Clone, Debug)]
pub struct Sample {
    pub events: Vec<LabeledEvent>,
    pub label: usize,
    /// Stream duration in µs (frames are cut from [0, duration_us]).
    pub duration_us: u64,
}

/// A complete train/test split.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: &'static str,
    pub res: Resolution,
    pub n_classes: usize,
    pub train: Vec<Sample>,
    pub test: Vec<Sample>,
}

/// Dataset family selector (the Table II columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    NMnist,
    Shapes,
    CifarDvs,
    Gesture,
}

impl Family {
    pub fn name(self) -> &'static str {
        match self {
            Family::NMnist => "syn-nmnist",
            Family::Shapes => "syn-shapes",
            Family::CifarDvs => "syn-cifardvs",
            Family::Gesture => "syn-gesture",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "syn-nmnist" | "nmnist" => Some(Family::NMnist),
            "syn-shapes" | "shapes" => Some(Family::Shapes),
            "syn-cifardvs" | "cifardvs" => Some(Family::CifarDvs),
            "syn-gesture" | "gesture" => Some(Family::Gesture),
            _ => None,
        }
    }
}

/// Generation options. Defaults are sized for the 1-core CI budget; the
/// e2e example scales them up.
#[derive(Clone, Copy, Debug)]
pub struct GenOptions {
    pub train_per_class: usize,
    pub test_per_class: usize,
    /// Stream duration per sample, seconds.
    pub duration_s: f64,
    /// BA noise rate folded into every sample (events are still labeled).
    pub noise_hz: f64,
    pub seed: u64,
}

impl Default for GenOptions {
    fn default() -> Self {
        Self { train_per_class: 24, test_per_class: 8, duration_s: 0.15, noise_hz: 1.0, seed: 7 }
    }
}

/// Generate a dataset of the given family.
pub fn generate(family: Family, opts: GenOptions) -> Dataset {
    match family {
        Family::NMnist => gen_nmnist(opts),
        Family::Shapes => gen_shapes(opts),
        Family::CifarDvs => gen_cifardvs(opts),
        Family::Gesture => gen_gesture(opts),
    }
}

fn make_sample(
    scene: &dyn Scene,
    res: Resolution,
    label: usize,
    opts: &GenOptions,
    seed: u64,
) -> Sample {
    let params = DvsParams::default();
    let signal = convert(scene, res, params, opts.duration_s);
    let events = if opts.noise_hz > 0.0 {
        super::noise::contaminate(&signal, res, opts.noise_hz, opts.duration_s, seed)
    } else {
        signal
    };
    Sample { events, label, duration_us: (opts.duration_s * 1e6) as u64 }
}

fn gen_nmnist(opts: GenOptions) -> Dataset {
    let res = Resolution::NMNIST;
    let mut rng = Pcg64::with_stream(opts.seed, 0x01);
    let mut gen_split = |per_class: usize, salt: u64| -> Vec<Sample> {
        let mut out = Vec::new();
        for d in 0..10u8 {
            for k in 0..per_class {
                // Jitter: glyph size and saccade amplitude vary per sample.
                let size = rng.range_u64(20, 26) as usize;
                let amp = rng.range_f64(3.0, 6.0);
                let mut glyph = digit_glyph(d, size);
                jitter_translate(&mut glyph, &mut rng, res);
                let scene = GlyphScene::new(glyph, opts.duration_s, amp);
                out.push(make_sample(
                    &scene,
                    res,
                    d as usize,
                    &opts,
                    opts.seed ^ salt ^ (d as u64) << 8 ^ k as u64,
                ));
            }
        }
        out
    };
    let train = gen_split(opts.train_per_class, 0x1111);
    let test = gen_split(opts.test_per_class, 0x2222);
    Dataset { name: Family::NMnist.name(), res, n_classes: 10, train, test }
}

fn gen_shapes(opts: GenOptions) -> Dataset {
    let res = Resolution::new(48, 48);
    let mut rng = Pcg64::with_stream(opts.seed, 0x02);
    let mut gen_split = |per_class: usize, salt: u64| -> Vec<Sample> {
        let mut out = Vec::new();
        for class in ShapeClass::ALL {
            for k in 0..per_class {
                let rot = rng.range_f64(0.0, std::f64::consts::TAU);
                let scale = rng.range_f64(0.7, 1.0);
                let mut glyph = shape_glyph(class, 36, rot, scale);
                jitter_translate(&mut glyph, &mut rng, res);
                let amp = rng.range_f64(3.0, 7.0);
                let scene = GlyphScene::new(glyph, opts.duration_s, amp);
                out.push(make_sample(
                    &scene,
                    res,
                    class.label(),
                    &opts,
                    opts.seed ^ salt ^ (class.label() as u64) << 8 ^ k as u64,
                ));
            }
        }
        out
    };
    let train = gen_split(opts.train_per_class, 0x3333);
    let test = gen_split(opts.test_per_class, 0x4444);
    Dataset { name: Family::Shapes.name(), res, n_classes: 8, train, test }
}

/// CIFAR10-DVS stand-in: shapes over a moving texture → clutter makes it
/// the hardest family, mirroring the accuracy ordering in Table II.
fn gen_cifardvs(opts: GenOptions) -> Dataset {
    let res = Resolution::new(48, 48);
    let mut rng = Pcg64::with_stream(opts.seed, 0x03);
    // Use 6 of the shape classes over cluttered background.
    let classes = &ShapeClass::ALL[..6];
    let mut gen_split = |per_class: usize, salt: u64| -> Vec<Sample> {
        let mut out = Vec::new();
        for (li, &class) in classes.iter().enumerate() {
            for k in 0..per_class {
                let rot = rng.range_f64(0.0, std::f64::consts::TAU);
                let glyph = shape_glyph(class, 32, rot, rng.range_f64(0.75, 1.0));
                let scene = ClutteredScene {
                    glyph: GlyphScene::new(glyph, opts.duration_s, rng.range_f64(4.0, 7.0)),
                    texture: TextureScene::new(
                        res.width,
                        res.height,
                        TextureMotion::Translate {
                            vx: rng.range_f64(-25.0, 25.0),
                            vy: rng.range_f64(-8.0, 8.0),
                        },
                        opts.seed ^ salt ^ k as u64,
                    ),
                };
                out.push(make_sample(
                    &scene,
                    res,
                    li,
                    &opts,
                    opts.seed ^ salt ^ (li as u64) << 8 ^ k as u64,
                ));
            }
        }
        out
    };
    let train = gen_split(opts.train_per_class, 0x5555);
    let test = gen_split(opts.test_per_class, 0x6666);
    Dataset { name: Family::CifarDvs.name(), res, n_classes: 6, train, test }
}

/// Gesture stand-in: 6 global-motion classes over a textured field.
fn gen_gesture(opts: GenOptions) -> Dataset {
    let res = Resolution::new(48, 48);
    let mut rng = Pcg64::with_stream(opts.seed, 0x04);
    let motions: [fn(&mut Pcg64) -> TextureMotion; 6] = [
        |r| TextureMotion::Translate { vx: r.range_f64(40.0, 70.0), vy: 0.0 },
        |r| TextureMotion::Translate { vx: -r.range_f64(40.0, 70.0), vy: 0.0 },
        |r| TextureMotion::Translate { vx: 0.0, vy: r.range_f64(40.0, 70.0) },
        |r| TextureMotion::Translate { vx: 0.0, vy: -r.range_f64(40.0, 70.0) },
        |r| TextureMotion::Rotate { omega: r.range_f64(2.0, 4.0) },
        |r| TextureMotion::Rotate { omega: -r.range_f64(2.0, 4.0) },
    ];
    let mut gen_split = |per_class: usize, salt: u64| -> Vec<Sample> {
        let mut out = Vec::new();
        for (li, mk) in motions.iter().enumerate() {
            for k in 0..per_class {
                let motion = mk(&mut rng);
                let scene = TextureScene::new(
                    res.width,
                    res.height,
                    motion,
                    opts.seed ^ salt ^ (li as u64) << 16 ^ k as u64,
                );
                out.push(make_sample(
                    &scene,
                    res,
                    li,
                    &opts,
                    opts.seed ^ salt ^ (li as u64) << 8 ^ k as u64,
                ));
            }
        }
        out
    };
    let train = gen_split(opts.train_per_class, 0x7777);
    let test = gen_split(opts.test_per_class, 0x8888);
    Dataset { name: Family::Gesture.name(), res, n_classes: 6, train, test }
}

/// Glyph over moving texture (CIFAR10-DVS-style clutter).
struct ClutteredScene {
    glyph: GlyphScene,
    texture: TextureScene,
}

impl Scene for ClutteredScene {
    fn intensity(&self, x: f64, y: f64, t: f64) -> f64 {
        0.6 * self.glyph.intensity(x, y, t) + 0.4 * self.texture.intensity(x, y, t)
    }
    fn name(&self) -> &'static str {
        "cluttered-glyph"
    }
}

/// Re-center a glyph raster inside the sensor with random translation so
/// samples of a class are not pixel-aligned.
fn jitter_translate(glyph: &mut Grid<f64>, rng: &mut Pcg64, res: Resolution) {
    let max_dx = (res.width as usize).saturating_sub(glyph.width());
    let max_dy = (res.height as usize).saturating_sub(glyph.height());
    let dx = if max_dx > 0 { rng.below(max_dx as u64 + 1) as usize } else { 0 };
    let dy = if max_dy > 0 { rng.below(max_dy as u64 + 1) as usize } else { 0 };
    let mut out = Grid::new(res.width as usize, res.height as usize, 0.0);
    for (x, y, &v) in glyph.iter_coords() {
        if v > 0.0 {
            out.set(x + dx, y + dy, v);
        }
    }
    *glyph = out;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> GenOptions {
        GenOptions { train_per_class: 2, test_per_class: 1, duration_s: 0.08, noise_hz: 1.0, seed: 5 }
    }

    #[test]
    fn nmnist_shape_and_labels() {
        let ds = generate(Family::NMnist, tiny_opts());
        assert_eq!(ds.n_classes, 10);
        assert_eq!(ds.train.len(), 20);
        assert_eq!(ds.test.len(), 10);
        for s in ds.train.iter().chain(&ds.test) {
            assert!(s.label < 10);
            assert!(!s.events.is_empty(), "sample has no events");
            assert!(s.events.windows(2).all(|w| w[0].ev.t <= w[1].ev.t));
        }
    }

    #[test]
    fn all_families_generate() {
        for fam in [Family::Shapes, Family::CifarDvs, Family::Gesture] {
            let ds = generate(fam, tiny_opts());
            assert!(!ds.train.is_empty());
            assert!(ds.train.iter().all(|s| !s.events.is_empty()), "{}", ds.name);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(Family::NMnist, tiny_opts());
        let b = generate(Family::NMnist, tiny_opts());
        assert_eq!(a.train[0].events.len(), b.train[0].events.len());
        assert_eq!(a.train[0].events.first(), b.train[0].events.first());
    }

    #[test]
    fn family_name_roundtrip() {
        for fam in [Family::NMnist, Family::Shapes, Family::CifarDvs, Family::Gesture] {
            assert_eq!(Family::from_name(fam.name()), Some(fam));
        }
        assert_eq!(Family::from_name("bogus"), None);
    }

    #[test]
    fn events_within_sensor_bounds() {
        let ds = generate(Family::Gesture, tiny_opts());
        for s in &ds.train {
            for e in &s.events {
                assert!(ds.res.contains(e.ev.x, e.ev.y));
            }
        }
    }
}
