//! Background-activity (BA) noise injection.
//!
//! The DND21 denoise benchmark [51] adds shot/leak noise at a fixed
//! per-pixel rate (the paper uses 5 Hz/pixel) to a clean recording; the
//! denoiser is then scored against the known signal/noise labels. This
//! module reproduces that protocol: homogeneous Poisson noise per pixel,
//! uniform polarity, merged into the labeled signal stream.

use super::event::{merge_sorted, Event, LabeledEvent, Polarity, Resolution};
use crate::util::rng::Pcg64;

/// Generate BA noise events at `rate_hz` per pixel over [0, duration_s],
/// labeled `is_signal = false`, sorted by timestamp.
pub fn ba_noise(
    res: Resolution,
    rate_hz: f64,
    duration_s: f64,
    seed: u64,
) -> Vec<LabeledEvent> {
    assert!(rate_hz >= 0.0);
    let mut rng = Pcg64::with_stream(seed, 0x0153);
    let mut out = Vec::new();
    if rate_hz == 0.0 {
        return out;
    }
    // Superposition of per-pixel Poisson processes == one Poisson process at
    // aggregate rate with uniformly random pixel assignment. O(total events)
    // instead of O(pixels) bookkeeping.
    let total_rate = rate_hz * res.pixels() as f64;
    let mut t = 0.0f64;
    loop {
        t += rng.exponential(total_rate);
        if t >= duration_s {
            break;
        }
        let x = rng.below(res.width as u64) as u16;
        let y = rng.below(res.height as u64) as u16;
        let p = if rng.bool(0.5) { Polarity::On } else { Polarity::Off };
        out.push(LabeledEvent {
            ev: Event::new((t * 1e6) as u64 + 1, x, y, p),
            is_signal: false,
        });
    }
    out
}

/// Mix a clean signal stream with BA noise at `rate_hz`/pixel (DND21
/// protocol). Both inputs must be sorted; the output is sorted.
pub fn contaminate(
    signal: &[LabeledEvent],
    res: Resolution,
    rate_hz: f64,
    duration_s: f64,
    seed: u64,
) -> Vec<LabeledEvent> {
    let noise = ba_noise(res, rate_hz, duration_s, seed);
    merge_sorted(signal, &noise)
}

/// Hot-pixel noise: a handful of pixels firing at an elevated rate — a
/// failure mode the STCF must also reject (used by robustness tests).
pub fn hot_pixels(
    res: Resolution,
    n_hot: usize,
    rate_hz: f64,
    duration_s: f64,
    seed: u64,
) -> Vec<LabeledEvent> {
    let mut rng = Pcg64::with_stream(seed, 0x4077);
    let mut out = Vec::new();
    let mut events: Vec<LabeledEvent> = Vec::new();
    for _ in 0..n_hot {
        let x = rng.below(res.width as u64) as u16;
        let y = rng.below(res.height as u64) as u16;
        let mut t = 0.0f64;
        loop {
            t += rng.exponential(rate_hz);
            if t >= duration_s {
                break;
            }
            events.push(LabeledEvent {
                ev: Event::new((t * 1e6) as u64 + 1, x, y, Polarity::On),
                is_signal: false,
            });
        }
    }
    events.sort_by_key(|e| e.ev.t);
    out.extend(events);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_rate_matches_lambda() {
        let res = Resolution::new(64, 48);
        let evs = ba_noise(res, 5.0, 2.0, 42);
        let expected = 5.0 * res.pixels() as f64 * 2.0;
        let got = evs.len() as f64;
        assert!(
            (got - expected).abs() < 4.0 * expected.sqrt(),
            "expected≈{expected} got={got}"
        );
    }

    #[test]
    fn noise_sorted_and_labeled() {
        let evs = ba_noise(Resolution::new(16, 16), 20.0, 1.0, 7);
        assert!(evs.windows(2).all(|w| w[0].ev.t <= w[1].ev.t));
        assert!(evs.iter().all(|e| !e.is_signal));
    }

    #[test]
    fn zero_rate_is_empty() {
        assert!(ba_noise(Resolution::QVGA, 0.0, 1.0, 1).is_empty());
    }

    #[test]
    fn contaminate_preserves_both_populations() {
        let res = Resolution::new(8, 8);
        let signal = vec![
            LabeledEvent { ev: Event::new(100, 1, 1, Polarity::On), is_signal: true },
            LabeledEvent { ev: Event::new(500_000, 2, 2, Polarity::Off), is_signal: true },
        ];
        let mixed = contaminate(&signal, res, 10.0, 1.0, 3);
        let n_sig = mixed.iter().filter(|e| e.is_signal).count();
        let n_noise = mixed.iter().filter(|e| !e.is_signal).count();
        assert_eq!(n_sig, 2);
        assert!(n_noise > 0);
        assert!(mixed.windows(2).all(|w| w[0].ev.t <= w[1].ev.t));
    }

    #[test]
    fn polarity_roughly_balanced() {
        let evs = ba_noise(Resolution::new(32, 32), 50.0, 1.0, 9);
        let on = evs.iter().filter(|e| e.ev.p == Polarity::On).count() as f64;
        let frac = on / evs.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "ON fraction {frac}");
    }

    #[test]
    fn hot_pixels_concentrated() {
        let evs = hot_pixels(Resolution::new(32, 32), 3, 1000.0, 0.5, 11);
        let mut coords: Vec<(u16, u16)> = evs.iter().map(|e| (e.ev.x, e.ev.y)).collect();
        coords.sort_unstable();
        coords.dedup();
        assert!(coords.len() <= 3);
        assert!(evs.len() > 1000);
    }
}
