//! Tiny software rasterizer used to procedurally draw dataset glyphs
//! (digits, shapes) — the offline substitute for downloading N-MNIST /
//! N-Caltech101 / CIFAR10-DVS source images.

use crate::util::grid::Grid;

/// Anti-aliased-ish line segment: stamps a disc of radius `w` along the way.
pub fn draw_line(g: &mut Grid<f64>, x0: f64, y0: f64, x1: f64, y1: f64, w: f64, v: f64) {
    let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt().max(1e-9);
    let steps = (len * 2.0).ceil() as usize + 1;
    for s in 0..=steps {
        let f = s as f64 / steps as f64;
        stamp_disc(g, x0 + f * (x1 - x0), y0 + f * (y1 - y0), w, v);
    }
}

/// Circle outline.
pub fn draw_circle(g: &mut Grid<f64>, cx: f64, cy: f64, r: f64, w: f64, v: f64) {
    let steps = (std::f64::consts::TAU * r * 2.0).ceil() as usize + 8;
    for s in 0..steps {
        let a = std::f64::consts::TAU * s as f64 / steps as f64;
        stamp_disc(g, cx + r * a.cos(), cy + r * a.sin(), w, v);
    }
}

/// Filled disc.
pub fn fill_disc(g: &mut Grid<f64>, cx: f64, cy: f64, r: f64, v: f64) {
    let (w, h) = (g.width() as i64, g.height() as i64);
    for y in ((cy - r).floor() as i64).max(0)..=((cy + r).ceil() as i64).min(h - 1) {
        for x in ((cx - r).floor() as i64).max(0)..=((cx + r).ceil() as i64).min(w - 1) {
            let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
            if d2 <= r * r {
                let cur = *g.get(x as usize, y as usize);
                g.set(x as usize, y as usize, cur.max(v));
            }
        }
    }
}

/// Axis-aligned rectangle outline.
pub fn draw_rect(g: &mut Grid<f64>, x0: f64, y0: f64, x1: f64, y1: f64, w: f64, v: f64) {
    draw_line(g, x0, y0, x1, y0, w, v);
    draw_line(g, x1, y0, x1, y1, w, v);
    draw_line(g, x1, y1, x0, y1, w, v);
    draw_line(g, x0, y1, x0, y0, w, v);
}

fn stamp_disc(g: &mut Grid<f64>, cx: f64, cy: f64, r: f64, v: f64) {
    let (w, h) = (g.width() as i64, g.height() as i64);
    let rr = r.max(0.5);
    for y in ((cy - rr).floor() as i64).max(0)..=((cy + rr).ceil() as i64).min(h - 1) {
        for x in ((cx - rr).floor() as i64).max(0)..=((cx + rr).ceil() as i64).min(w - 1) {
            let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
            if d2 <= rr * rr {
                let cur = *g.get(x as usize, y as usize);
                g.set(x as usize, y as usize, cur.max(v));
            }
        }
    }
}

/// Draw digit `d` (0–9) into a fresh `size`×`size` raster. Strokes follow a
/// 7-segment-plus-diagonals skeleton, normalized to the raster size.
pub fn digit_glyph(d: u8, size: usize) -> Grid<f64> {
    assert!(d <= 9);
    let mut g = Grid::new(size, size, 0.0);
    let s = size as f64;
    // Canonical segment endpoints in a unit box with margins.
    let (l, r_, t, m, b) = (0.25 * s, 0.75 * s, 0.15 * s, 0.5 * s, 0.85 * s);
    let w = (s * 0.06).max(0.8);
    let mut seg = |x0: f64, y0: f64, x1: f64, y1: f64| draw_line(&mut g, x0, y0, x1, y1, w, 1.0);
    match d {
        0 => {
            seg(l, t, r_, t);
            seg(r_, t, r_, b);
            seg(r_, b, l, b);
            seg(l, b, l, t);
            seg(l, b, r_, t); // slash distinguishes from 'O'
        }
        1 => {
            seg((l + r_) / 2.0, t, (l + r_) / 2.0, b);
            seg(l, b, r_, b);
            seg((l + r_) / 2.0, t, l, t + 0.15 * s);
        }
        2 => {
            seg(l, t, r_, t);
            seg(r_, t, r_, m);
            seg(r_, m, l, b);
            seg(l, b, r_, b);
        }
        3 => {
            seg(l, t, r_, t);
            seg(r_, t, r_, b);
            seg(l, m, r_, m);
            seg(l, b, r_, b);
        }
        4 => {
            seg(l, t, l, m);
            seg(l, m, r_, m);
            seg(r_, t, r_, b);
        }
        5 => {
            seg(r_, t, l, t);
            seg(l, t, l, m);
            seg(l, m, r_, m);
            seg(r_, m, r_, b);
            seg(r_, b, l, b);
        }
        6 => {
            seg(r_, t, l, m);
            seg(l, m, l, b);
            seg(l, b, r_, b);
            seg(r_, b, r_, m);
            seg(r_, m, l, m);
        }
        7 => {
            seg(l, t, r_, t);
            seg(r_, t, (l + r_) / 2.0, b);
        }
        8 => {
            seg(l, t, r_, t);
            seg(l, t, l, b);
            seg(r_, t, r_, b);
            seg(l, m, r_, m);
            seg(l, b, r_, b);
        }
        9 => {
            seg(r_, m, l, m);
            seg(l, m, l, t);
            seg(l, t, r_, t);
            seg(r_, t, r_, b);
            seg(r_, b, l, b);
        }
        _ => unreachable!(),
    }
    g
}

/// Shape classes for the Caltech-like synthetic dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShapeClass {
    Circle,
    Square,
    Triangle,
    Cross,
    Star,
    Ring,
    HBars,
    VBars,
}

impl ShapeClass {
    pub const ALL: [ShapeClass; 8] = [
        ShapeClass::Circle,
        ShapeClass::Square,
        ShapeClass::Triangle,
        ShapeClass::Cross,
        ShapeClass::Star,
        ShapeClass::Ring,
        ShapeClass::HBars,
        ShapeClass::VBars,
    ];

    pub fn label(self) -> usize {
        Self::ALL.iter().position(|&s| s == self).unwrap()
    }
}

/// Draw a shape glyph with scale/rotation jitter (`rot` radians,
/// `scale` ∈ (0, 1] of the raster).
pub fn shape_glyph(class: ShapeClass, size: usize, rot: f64, scale: f64) -> Grid<f64> {
    let mut g = Grid::new(size, size, 0.0);
    let c = size as f64 / 2.0;
    let r = c * 0.7 * scale;
    let w = (size as f64 * 0.05).max(0.8);
    let pt = |a: f64, rad: f64| (c + rad * (a + rot).cos(), c + rad * (a + rot).sin());
    match class {
        ShapeClass::Circle => draw_circle(&mut g, c, c, r, w, 1.0),
        ShapeClass::Ring => {
            draw_circle(&mut g, c, c, r, w, 1.0);
            draw_circle(&mut g, c, c, r * 0.5, w, 1.0);
        }
        ShapeClass::Square => {
            let pts: Vec<(f64, f64)> =
                (0..4).map(|k| pt(std::f64::consts::FRAC_PI_4 + k as f64 * std::f64::consts::FRAC_PI_2, r)).collect();
            for k in 0..4 {
                let (x0, y0) = pts[k];
                let (x1, y1) = pts[(k + 1) % 4];
                draw_line(&mut g, x0, y0, x1, y1, w, 1.0);
            }
        }
        ShapeClass::Triangle => {
            let pts: Vec<(f64, f64)> =
                (0..3).map(|k| pt(-std::f64::consts::FRAC_PI_2 + k as f64 * std::f64::consts::TAU / 3.0, r)).collect();
            for k in 0..3 {
                let (x0, y0) = pts[k];
                let (x1, y1) = pts[(k + 1) % 3];
                draw_line(&mut g, x0, y0, x1, y1, w, 1.0);
            }
        }
        ShapeClass::Cross => {
            let (x0, y0) = pt(0.0, r);
            let (x1, y1) = pt(std::f64::consts::PI, r);
            draw_line(&mut g, x0, y0, x1, y1, w, 1.0);
            let (x0, y0) = pt(std::f64::consts::FRAC_PI_2, r);
            let (x1, y1) = pt(-std::f64::consts::FRAC_PI_2, r);
            draw_line(&mut g, x0, y0, x1, y1, w, 1.0);
        }
        ShapeClass::Star => {
            for k in 0..5 {
                let a0 = -std::f64::consts::FRAC_PI_2 + k as f64 * std::f64::consts::TAU / 5.0;
                let a1 = -std::f64::consts::FRAC_PI_2 + ((k + 2) % 5) as f64 * std::f64::consts::TAU / 5.0;
                let (x0, y0) = pt(a0, r);
                let (x1, y1) = pt(a1, r);
                draw_line(&mut g, x0, y0, x1, y1, w, 1.0);
            }
        }
        ShapeClass::HBars => {
            for k in 0..3 {
                let y = c - r + k as f64 * r;
                draw_line(&mut g, c - r, y, c + r, y, w, 1.0);
            }
        }
        ShapeClass::VBars => {
            for k in 0..3 {
                let x = c - r + k as f64 * r;
                draw_line(&mut g, x, c - r, x, c + r, w, 1.0);
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ink(g: &Grid<f64>) -> f64 {
        g.as_slice().iter().sum()
    }

    #[test]
    fn all_digits_draw_something() {
        for d in 0..=9u8 {
            let g = digit_glyph(d, 24);
            assert!(ink(&g) > 5.0, "digit {d} nearly empty");
        }
    }

    #[test]
    fn digits_are_distinct() {
        let gs: Vec<Grid<f64>> = (0..=9u8).map(|d| digit_glyph(d, 24)).collect();
        for i in 0..10 {
            for j in i + 1..10 {
                let diff: f64 = gs[i]
                    .as_slice()
                    .iter()
                    .zip(gs[j].as_slice())
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                assert!(diff > 3.0, "digits {i} and {j} too similar (diff={diff})");
            }
        }
    }

    #[test]
    fn shapes_draw_and_differ() {
        let gs: Vec<Grid<f64>> =
            ShapeClass::ALL.iter().map(|&c| shape_glyph(c, 32, 0.0, 1.0)).collect();
        for (k, g) in gs.iter().enumerate() {
            assert!(ink(g) > 5.0, "shape {k} nearly empty");
        }
        for i in 0..gs.len() {
            for j in i + 1..gs.len() {
                let diff: f64 = gs[i]
                    .as_slice()
                    .iter()
                    .zip(gs[j].as_slice())
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                assert!(diff > 3.0, "shapes {i}/{j} too similar");
            }
        }
    }

    #[test]
    fn rotation_moves_ink() {
        let a = shape_glyph(ShapeClass::Triangle, 32, 0.0, 1.0);
        let b = shape_glyph(ShapeClass::Triangle, 32, 1.0, 1.0);
        let diff: f64 =
            a.as_slice().iter().zip(b.as_slice()).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0);
    }

    #[test]
    fn values_bounded() {
        let g = digit_glyph(8, 24);
        assert!(g.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
