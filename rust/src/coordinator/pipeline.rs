//! The end-to-end event pipeline: source → (optional STCF denoise) →
//! sharded ISC writes → windowed frame readout.
//!
//! This is the serving loop of the system: events stream in, the analog
//! plane absorbs them, and every `window_us` a time-surface frame is
//! snapshotted for the downstream CV consumer (classifier / reconstructor
//! running on the PJRT artifacts). Stages communicate over bounded
//! channels, so a slow consumer backpressures the source instead of
//! buffering unboundedly.

use super::router::{Router, RouterConfig, RouterStats};
use crate::denoise::{run_stcf, StcfBackend, StcfParams};
use crate::events::{LabeledEvent, Resolution};
use crate::util::grid::Grid;
use std::time::Instant;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Frame readout period (paper Sec. IV-D: 50 ms windows).
    pub window_us: u64,
    /// Run the STCF in front of the array (None = raw stream).
    pub stcf: Option<StcfParams>,
    pub router: RouterConfig,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { window_us: 50_000, stcf: None, router: RouterConfig::default() }
    }
}

/// Pipeline result: frames plus run statistics.
pub struct PipelineRun {
    /// (frame timestamp µs, normalized TS frame).
    pub frames: Vec<(u64, Grid<f64>)>,
    pub stats: PipelineStats,
}

#[derive(Clone, Debug)]
pub struct PipelineStats {
    pub events_in: u64,
    pub events_written: u64,
    pub events_dropped_by_stcf: u64,
    pub frames_emitted: u64,
    pub wall_seconds: f64,
    pub router: RouterStats,
    /// Throughput in events/second of wall time.
    pub events_per_second: f64,
}

/// Run the pipeline over a sorted labeled stream covering [0, t_end_us).
pub fn run(
    events: &[LabeledEvent],
    res: Resolution,
    t_end_us: u64,
    cfg: &PipelineConfig,
) -> PipelineRun {
    let start = Instant::now();
    let events_in = events.len() as u64;

    // Stage 1: denoise (optional). The STCF is causal and cheap relative to
    // everything downstream, so it runs inline ahead of the router.
    let (kept, dropped): (Vec<LabeledEvent>, u64) = match &cfg.stcf {
        Some(prm) => {
            let mut backend = StcfBackend::isc(res, cfg.router.isc.clone(), prm.tau_tw_us);
            let r = run_stcf(&mut backend, events, prm);
            let d = events.len() as u64 - r.kept.len() as u64;
            (r.kept, d)
        }
        None => (events.to_vec(), 0),
    };

    // Stage 2+3: route writes, snapshot frames at window boundaries.
    let mut router = Router::new(res, cfg.router.clone());
    let mut frames = Vec::new();
    let mut next_frame = cfg.window_us;
    for le in &kept {
        while le.ev.t > next_frame && next_frame <= t_end_us {
            frames.push((next_frame, router.frame(next_frame)));
            next_frame += cfg.window_us;
        }
        router.route(le.ev);
    }
    while next_frame <= t_end_us {
        frames.push((next_frame, router.frame(next_frame)));
        next_frame += cfg.window_us;
    }

    let events_written = router.events_routed();
    let router_stats = router.shutdown();
    let wall = start.elapsed().as_secs_f64();
    PipelineRun {
        frames: frames.clone(),
        stats: PipelineStats {
            events_in,
            events_written,
            events_dropped_by_stcf: dropped,
            frames_emitted: frames.len() as u64,
            wall_seconds: wall,
            events_per_second: if wall > 0.0 { events_in as f64 / wall } else { 0.0 },
            router: router_stats,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::event::{Event, Polarity};

    fn stream(n: u64, res: Resolution) -> Vec<LabeledEvent> {
        (0..n)
            .map(|k| LabeledEvent {
                ev: Event::new(
                    1 + k * 1_000,
                    (k % res.width as u64) as u16,
                    (k % res.height as u64) as u16,
                    Polarity::On,
                ),
                is_signal: true,
            })
            .collect()
    }

    #[test]
    fn emits_expected_frame_count() {
        let res = Resolution::new(16, 16);
        let evs = stream(100, res); // covers 0..100ms
        let run = run(&evs, res, 100_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 2); // 50ms windows
        assert_eq!(run.stats.frames_emitted, 2);
        assert_eq!(run.stats.events_in, 100);
        assert_eq!(run.stats.events_written, 100);
    }

    #[test]
    fn stcf_stage_drops_noise() {
        let res = Resolution::new(16, 16);
        // Isolated events (all far apart in space) → STCF drops them all.
        let evs: Vec<LabeledEvent> = (0..20)
            .map(|k| LabeledEvent {
                ev: Event::new(1 + k * 2_000, ((k * 7) % 16) as u16, ((k * 5) % 16) as u16,
                               Polarity::On),
                is_signal: false,
            })
            .collect();
        let cfg = PipelineConfig {
            stcf: Some(StcfParams { threshold: 2, ..StcfParams::default() }),
            ..PipelineConfig::default()
        };
        let run = run(&evs, res, 50_000, &cfg);
        assert!(run.stats.events_dropped_by_stcf > 10,
                "dropped {}", run.stats.events_dropped_by_stcf);
    }

    #[test]
    fn frames_reflect_recent_writes() {
        let res = Resolution::new(8, 8);
        let evs = vec![LabeledEvent {
            ev: Event::new(49_000, 4, 4, Polarity::On),
            is_signal: true,
        }];
        let run = run(&evs, res, 50_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 1);
        let f = &run.frames[0].1;
        assert!(*f.get(4, 4) > 0.9, "fresh write should be bright");
        assert_eq!(*f.get(0, 0), 0.0);
    }

    #[test]
    fn empty_stream_still_emits_frames() {
        let res = Resolution::new(8, 8);
        let run = run(&[], res, 150_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 3);
        assert!(run.frames.iter().all(|(_, f)| f.as_slice().iter().all(|&v| v == 0.0)));
    }
}
