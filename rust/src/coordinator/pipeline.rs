//! The end-to-end event pipeline: source → (optional STCF denoise, band-
//! sharded) → sharded ISC writes → windowed frame readout.
//!
//! This is the serving loop of the system: events stream in, the analog
//! plane absorbs them, and every `window_us` a time-surface frame is
//! snapshotted for the downstream CV consumer (classifier / reconstructor
//! running on the PJRT artifacts).
//!
//! The pipeline is **streaming and batch-first**: it consumes any
//! `IntoIterator<Item = LabeledEvent>` (a replayed recording, a lazy
//! generator, `events.iter().copied()` over a slice) and never
//! materializes the stream — the only buffering is a bounded staging
//! batch of at most `batch_size` events between flushes. Stages
//! communicate over bounded channels, so a slow consumer backpressures
//! the source instead of buffering unboundedly.
//!
//! The STCF stage scores on its own worker shards
//! ([`crate::denoise::sharded`], `denoise_shards` > 0): each staged
//! batch fans out to band-owning scorers (with halo-row duplication at
//! band borders), and the kept events come back in stream order to feed
//! [`Router::route_batch`]. Set `denoise_shards: 0` to score inline on
//! the producer thread (the pre-sharding behaviour — same decisions,
//! one core). [`PipelineStats`] reports per-stage wall time
//! ([`StageWall`]) and the per-shard kept/dropped tallies
//! ([`DenoiseStats`]).

use super::router::{Router, RouterConfig, RouterStats};
use crate::denoise::sharded::{ShardBackend, ShardTally, StcfShardPool};
use crate::denoise::{support_count, StcfBackend, StcfParams};
use crate::events::{ClockPolicy, Event, LabeledEvent, Resolution};
use crate::util::grid::Grid;
use std::time::Instant;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Frame readout period (paper Sec. IV-D: 50 ms windows).
    pub window_us: u64,
    /// Run the STCF in front of the array (None = raw stream).
    pub stcf: Option<StcfParams>,
    /// Denoise worker shards for the STCF stage (ignored when `stcf` is
    /// None). 0 scores inline on the producer thread. Every layout —
    /// inline or any shard count — produces bit-for-bit identical
    /// keep/drop decisions: band-local arrays anchor their
    /// position-stable mismatch maps at the band origin, making each an
    /// exact window of the full-sensor array.
    pub denoise_shards: usize,
    /// Events staged between flushes — the ingest batch size and the
    /// pipeline's only stream buffering.
    pub batch_size: usize,
    /// What to do with events whose timestamps run backwards (below the
    /// stream watermark): clamp them up to the watermark (default) or
    /// reject them outright. Either way the count lands in
    /// [`PipelineStats::events_nonmonotonic`] — a non-monotonic source
    /// is never silently fed to the decay math.
    pub clock_policy: ClockPolicy,
    pub router: RouterConfig,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            window_us: 50_000,
            stcf: None,
            denoise_shards: 4,
            batch_size: 4_096,
            clock_policy: ClockPolicy::default(),
            router: RouterConfig::default(),
        }
    }
}

/// Pipeline result: frames plus run statistics.
pub struct PipelineRun {
    /// (frame timestamp µs, normalized TS frame).
    pub frames: Vec<(u64, Grid<f64>)>,
    pub stats: PipelineStats,
}

/// Producer-side wall time spent in each pipeline stage (the stages a
/// single run iteration passes through; router shards and denoise
/// shards additionally overlap work on their own threads).
#[derive(Clone, Debug, Default)]
pub struct StageWall {
    /// STCF scoring + filtering (fan-out/fan-in for sharded scoring).
    pub denoise_seconds: f64,
    /// `Router::route_batch` staging + shipping.
    pub route_seconds: f64,
    /// Frame snapshots (`Router::frame`, dirty-band protocol included).
    pub snapshot_seconds: f64,
}

/// Denoise-stage outcome counters.
#[derive(Clone, Debug)]
pub struct DenoiseStats {
    /// True when scoring ran inline on the producer (`denoise_shards: 0`).
    pub inline_scoring: bool,
    /// Per-shard kept/dropped/halo tallies (a single entry for inline
    /// scoring, with `halo_ingests` = 0).
    pub per_shard: Vec<ShardTally>,
}

/// End-to-end accounting for one pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineStats {
    pub events_in: u64,
    pub events_written: u64,
    pub events_dropped_by_stcf: u64,
    /// Events that arrived with a timestamp below the stream watermark
    /// and were clamped or rejected per [`PipelineConfig::clock_policy`].
    /// (Rejected events are excluded from `events_in`, so the
    /// in = written + dropped balance always holds.)
    pub events_nonmonotonic: u64,
    pub frames_emitted: u64,
    /// High-water mark of the staging batch — bounded by `batch_size`,
    /// which is the pipeline's no-full-stream-copy guarantee.
    pub peak_batch_len: usize,
    pub wall_seconds: f64,
    /// Per-stage producer wall time (denoise / route / snapshot).
    pub stage_wall: StageWall,
    /// Denoise-stage tallies (None when the STCF is disabled).
    pub denoise: Option<DenoiseStats>,
    /// Router statistics, including the dirty-band snapshot counters:
    /// `router.snapshots_served` (= `frames_emitted`) and
    /// `router.bands_skipped_unchanged` (band renders the dirty-band
    /// protocol avoided — the observable win on sparse streams).
    pub router: RouterStats,
    /// Throughput in events/second of wall time.
    pub events_per_second: f64,
}

/// The STCF stage in one of its two homes: inline on the producer, or
/// fanned out to the band-sharded scorer pool.
enum DenoiseStage {
    Inline { backend: StcfBackend, prm: StcfParams, tally: ShardTally },
    Sharded { pool: StcfShardPool, scores: Vec<u32> },
}

impl DenoiseStage {
    fn new(res: Resolution, cfg: &PipelineConfig, prm: StcfParams) -> Self {
        if cfg.denoise_shards == 0 {
            let backend = StcfBackend::isc(res, cfg.router.isc.clone(), prm.tau_tw_us);
            DenoiseStage::Inline { backend, prm, tally: ShardTally::default() }
        } else {
            let backend = ShardBackend::Isc(cfg.router.isc.clone());
            let pool = StcfShardPool::new(res, cfg.denoise_shards, backend, prm);
            DenoiseStage::Sharded { pool, scores: Vec::new() }
        }
    }

    /// Score `batch` (causal score-then-write order) and append the
    /// events passing the keep threshold to `kept` in stream order.
    fn filter(&mut self, batch: &[LabeledEvent], kept: &mut Vec<LabeledEvent>) {
        match self {
            DenoiseStage::Inline { backend, prm, tally } => {
                for le in batch {
                    let s = support_count(backend, &le.ev, prm);
                    backend.ingest(&le.ev, prm);
                    tally.scored += 1;
                    if s >= prm.threshold {
                        tally.kept += 1;
                        kept.push(*le);
                    } else {
                        tally.dropped += 1;
                    }
                }
            }
            DenoiseStage::Sharded { pool, scores } => pool.filter_batch(batch, scores, kept),
        }
    }

    fn finish(self) -> DenoiseStats {
        match self {
            DenoiseStage::Inline { tally, .. } => {
                DenoiseStats { inline_scoring: true, per_shard: vec![tally] }
            }
            DenoiseStage::Sharded { pool, .. } => {
                DenoiseStats { inline_scoring: false, per_shard: pool.shutdown() }
            }
        }
    }
}

/// Push the staged batch through the denoise stage (when configured)
/// and route the survivors. Returns the number of events dropped.
fn flush_staged(
    pre: &mut Vec<LabeledEvent>,
    stage: &mut Option<DenoiseStage>,
    kept: &mut Vec<LabeledEvent>,
    route_buf: &mut Vec<Event>,
    router: &mut Router,
    wall: &mut StageWall,
) -> u64 {
    if pre.is_empty() {
        return 0;
    }
    route_buf.clear();
    let mut dropped = 0u64;
    match stage {
        Some(st) => {
            let t0 = Instant::now();
            kept.clear();
            st.filter(pre, kept);
            wall.denoise_seconds += t0.elapsed().as_secs_f64();
            dropped = (pre.len() - kept.len()) as u64;
            route_buf.extend(kept.iter().map(|le| le.ev));
        }
        None => route_buf.extend(pre.iter().map(|le| le.ev)),
    }
    pre.clear();
    let t0 = Instant::now();
    router.route_batch(route_buf);
    wall.route_seconds += t0.elapsed().as_secs_f64();
    dropped
}

/// Run the pipeline over a sorted labeled event source covering
/// [0, t_end_us). Slice holders pass `events.iter().copied()`; anything
/// streaming (replay readers, generators) is consumed without a copy.
pub fn run<I>(events: I, res: Resolution, t_end_us: u64, cfg: &PipelineConfig) -> PipelineRun
where
    I: IntoIterator<Item = LabeledEvent>,
{
    let start = Instant::now();
    let batch_size = cfg.batch_size.max(1);

    // Optional STCF stage: scored in causal score-then-write order per
    // staged batch, inline or on the denoise shard pool.
    let mut stage: Option<DenoiseStage> = cfg.stcf.map(|prm| DenoiseStage::new(res, cfg, prm));

    let mut router = Router::new(res, cfg.router.clone());
    let mut frames: Vec<(u64, Grid<f64>)> = Vec::new();
    let mut pre: Vec<LabeledEvent> = Vec::with_capacity(batch_size);
    let mut kept: Vec<LabeledEvent> = Vec::with_capacity(batch_size);
    let mut route_buf: Vec<Event> = Vec::with_capacity(batch_size);
    let mut wall = StageWall::default();
    let mut next_frame = cfg.window_us;
    let mut events_in = 0u64;
    let mut dropped = 0u64;
    let mut nonmonotonic = 0u64;
    let mut last_t = 0u64;
    let mut peak_batch_len = 0usize;

    for le in events {
        let mut le = le;
        if le.ev.t < last_t {
            // Backwards clock (duplicates pass: `<`, not `<=`). Reject
            // skips the event before `events_in`, keeping the
            // in = written + dropped balance intact.
            nonmonotonic += 1;
            match cfg.clock_policy {
                ClockPolicy::Clamp => le.ev.t = last_t,
                ClockPolicy::Reject => continue,
            }
        }
        last_t = le.ev.t;
        events_in += 1;
        // Snapshot every window boundary the stream has passed; staged
        // events are flushed through denoise + routing first, so each
        // frame observes exactly the events that precede it.
        while le.ev.t > next_frame && next_frame <= t_end_us {
            peak_batch_len = peak_batch_len.max(pre.len());
            dropped += flush_staged(
                &mut pre,
                &mut stage,
                &mut kept,
                &mut route_buf,
                &mut router,
                &mut wall,
            );
            let t0 = Instant::now();
            let frame = router.frame(next_frame);
            wall.snapshot_seconds += t0.elapsed().as_secs_f64();
            frames.push((next_frame, frame));
            next_frame += cfg.window_us;
        }
        pre.push(le);
        if pre.len() >= batch_size {
            peak_batch_len = peak_batch_len.max(pre.len());
            dropped += flush_staged(
                &mut pre,
                &mut stage,
                &mut kept,
                &mut route_buf,
                &mut router,
                &mut wall,
            );
        }
    }
    peak_batch_len = peak_batch_len.max(pre.len());
    dropped +=
        flush_staged(&mut pre, &mut stage, &mut kept, &mut route_buf, &mut router, &mut wall);
    while next_frame <= t_end_us {
        let t0 = Instant::now();
        let frame = router.frame(next_frame);
        wall.snapshot_seconds += t0.elapsed().as_secs_f64();
        frames.push((next_frame, frame));
        next_frame += cfg.window_us;
    }

    let events_written = router.events_routed();
    let denoise = stage.map(DenoiseStage::finish);
    let router_stats = router.shutdown();
    let wall_s = start.elapsed().as_secs_f64();
    let stats = PipelineStats {
        events_in,
        events_written,
        events_dropped_by_stcf: dropped,
        events_nonmonotonic: nonmonotonic,
        frames_emitted: frames.len() as u64,
        peak_batch_len,
        wall_seconds: wall_s,
        stage_wall: wall,
        denoise,
        events_per_second: if wall_s > 0.0 { events_in as f64 / wall_s } else { 0.0 },
        router: router_stats,
    };
    PipelineRun { frames, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::event::{Event, Polarity};
    use crate::isc::IscConfig;

    fn stream(n: u64, res: Resolution) -> Vec<LabeledEvent> {
        (0..n)
            .map(|k| LabeledEvent {
                ev: Event::new(
                    1 + k * 1_000,
                    (k % res.width as u64) as u16,
                    (k % res.height as u64) as u16,
                    Polarity::On,
                ),
                is_signal: true,
            })
            .collect()
    }

    #[test]
    fn emits_expected_frame_count() {
        let res = Resolution::new(16, 16);
        let evs = stream(100, res); // covers 0..100ms
        let run = run(evs.iter().copied(), res, 100_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 2); // 50ms windows
        assert_eq!(run.stats.frames_emitted, 2);
        assert_eq!(run.stats.events_in, 100);
        assert_eq!(run.stats.events_written, 100);
        assert!(run.stats.denoise.is_none(), "no STCF configured");
    }

    #[test]
    fn consumes_lazy_iterator_without_materializing() {
        // The source here is a pure generator: no backing Vec exists, so
        // the old `events.to_vec()` copy is impossible by construction.
        // Buffering is bounded by batch_size (asserted via the high-water
        // mark).
        let res = Resolution::new(16, 16);
        let n = 10_000u64;
        let cfg = PipelineConfig { batch_size: 256, ..PipelineConfig::default() };
        let source = (0..n).map(|k| LabeledEvent {
            ev: Event::new(1 + k * 10, (k % 16) as u16, (k % 16) as u16, Polarity::On),
            is_signal: true,
        });
        let run = run(source, res, 100_000, &cfg);
        assert_eq!(run.stats.events_in, n);
        assert_eq!(run.stats.events_written, n);
        assert!(
            run.stats.peak_batch_len <= 256,
            "staging exceeded batch_size: {}",
            run.stats.peak_batch_len
        );
    }

    #[test]
    fn stcf_stage_drops_noise() {
        let res = Resolution::new(16, 16);
        // Isolated events (all far apart in space) → STCF drops them all.
        let evs: Vec<LabeledEvent> = (0..20)
            .map(|k| LabeledEvent {
                ev: Event::new(1 + k * 2_000, ((k * 7) % 16) as u16, ((k * 5) % 16) as u16,
                               Polarity::On),
                is_signal: false,
            })
            .collect();
        let cfg = PipelineConfig {
            stcf: Some(StcfParams { threshold: 2, ..StcfParams::default() }),
            ..PipelineConfig::default()
        };
        let run = run(evs.iter().copied(), res, 50_000, &cfg);
        assert!(run.stats.events_dropped_by_stcf > 10,
                "dropped {}", run.stats.events_dropped_by_stcf);
        // The denoise tallies reconcile with the drop counter.
        let dn = run.stats.denoise.as_ref().expect("STCF configured");
        assert!(!dn.inline_scoring);
        assert_eq!(
            dn.per_shard.iter().map(|t| t.dropped).sum::<u64>(),
            run.stats.events_dropped_by_stcf
        );
        assert_eq!(dn.per_shard.iter().map(|t| t.scored).sum::<u64>(), 20);
    }

    #[test]
    fn inline_and_sharded_denoise_agree_across_layouts() {
        // Position-stable mismatch assignment: every denoise backend
        // (inline full-res, sharded band+halo) holds the exact same
        // per-pixel cells over its region, so the keep decisions — and
        // therefore every routed write and frame — are bit-for-bit
        // identical across shard counts, mismatch enabled and all.
        let res = Resolution::new(32, 24);
        let evs: Vec<LabeledEvent> = (0..600u64)
            .map(|k| LabeledEvent {
                ev: Event::new(
                    1 + k * 150,
                    (k * 3 % 32) as u16,
                    (k * 7 % 24) as u16,
                    Polarity::On,
                ),
                is_signal: true,
            })
            .collect();
        let mut all = Vec::new();
        for denoise_shards in [0usize, 1, 4] {
            let cfg = PipelineConfig {
                stcf: Some(StcfParams::default()),
                denoise_shards,
                router: RouterConfig { isc: IscConfig::default(), ..RouterConfig::default() },
                ..PipelineConfig::default()
            };
            let r = run(evs.iter().copied(), res, 90_000, &cfg);
            all.push((denoise_shards, r.stats.events_written, r.frames));
        }
        for w in all.windows(2) {
            assert_eq!(w[0].1, w[1].1, "kept counts differ: {} vs {} shards", w[0].0, w[1].0);
            assert_eq!(w[0].2, w[1].2, "frames differ: {} vs {} shards", w[0].0, w[1].0);
        }
    }

    #[test]
    fn stage_wall_times_are_recorded() {
        let res = Resolution::new(16, 16);
        let evs = stream(300, res);
        let cfg = PipelineConfig {
            stcf: Some(StcfParams::default()),
            ..PipelineConfig::default()
        };
        let r = run(evs.iter().copied(), res, 300_000, &cfg);
        let w = &r.stats.stage_wall;
        assert!(w.denoise_seconds > 0.0);
        assert!(w.snapshot_seconds > 0.0);
        // Route time can be arbitrarily small but never negative; the
        // three stage timers are all bounded by the total wall clock.
        assert!(w.route_seconds >= 0.0);
        let sum = w.denoise_seconds + w.route_seconds + w.snapshot_seconds;
        assert!(sum <= r.stats.wall_seconds + 1e-9, "{sum} vs {}", r.stats.wall_seconds);
    }

    #[test]
    fn frames_reflect_recent_writes() {
        let res = Resolution::new(8, 8);
        let evs = vec![LabeledEvent {
            ev: Event::new(49_000, 4, 4, Polarity::On),
            is_signal: true,
        }];
        let run = run(evs.iter().copied(), res, 50_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 1);
        let f = &run.frames[0].1;
        assert!(*f.get(4, 4) > 0.9, "fresh write should be bright");
        assert_eq!(*f.get(0, 0), 0.0);
    }

    #[test]
    fn empty_stream_still_emits_frames() {
        let res = Resolution::new(8, 8);
        let run = run(std::iter::empty(), res, 150_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 3);
        assert!(run.frames.iter().all(|(_, f)| f.as_slice().iter().all(|&v| v == 0.0)));
        // Dirty-band protocol on an empty stream: the first snapshot
        // renders every (empty) band; the later ones skip them all.
        let st = &run.stats.router;
        assert_eq!(st.snapshots_served, 3);
        assert_eq!(st.bands_skipped_unchanged, 2 * st.per_shard.len() as u64);
    }

    #[test]
    fn sparse_stream_skips_untouched_bands() {
        // All activity confined to one row: after the first window, every
        // never-written band is provably static and must stop costing a
        // shard round-trip while the frames stay exact.
        let res = Resolution::new(16, 16);
        let evs: Vec<LabeledEvent> = (0..200u64)
            .map(|k| LabeledEvent {
                ev: Event::new(1 + k * 900, (k % 16) as u16, 5, Polarity::On),
                is_signal: true,
            })
            .collect();
        let run = run(evs.iter().copied(), res, 180_000, &PipelineConfig::default());
        let st = &run.stats.router;
        assert_eq!(st.snapshots_served, run.stats.frames_emitted);
        assert!(
            st.bands_skipped_unchanged > 0,
            "clean bands must be skipped: {st:?}"
        );
    }

    #[test]
    fn clamp_policy_ingests_backwards_events_at_the_watermark() {
        let res = Resolution::new(8, 8);
        let mk = |t| LabeledEvent { ev: Event::new(t, 1, 1, Polarity::On), is_signal: true };
        // 1000, 500 (backwards), 1000 (duplicate — passes), 2000.
        let evs = vec![mk(1_000), mk(500), mk(1_000), mk(2_000)];
        let cfg = PipelineConfig::default();
        assert_eq!(cfg.clock_policy, crate::events::ClockPolicy::Clamp);
        let r = run(evs.iter().copied(), res, 50_000, &cfg);
        assert_eq!(r.stats.events_in, 4, "clamped events are ingested");
        assert_eq!(r.stats.events_written, 4);
        assert_eq!(r.stats.events_nonmonotonic, 1, "only the strict decrease counts");
    }

    #[test]
    fn reject_policy_drops_backwards_events_before_accounting() {
        let res = Resolution::new(8, 8);
        let mk = |t| LabeledEvent { ev: Event::new(t, 1, 1, Polarity::On), is_signal: true };
        let evs = vec![mk(1_000), mk(500), mk(1_000), mk(2_000)];
        let cfg = PipelineConfig {
            clock_policy: crate::events::ClockPolicy::Reject,
            ..PipelineConfig::default()
        };
        let r = run(evs.iter().copied(), res, 50_000, &cfg);
        assert_eq!(r.stats.events_in, 3, "rejected event never enters the accounting");
        assert_eq!(r.stats.events_written, 3);
        assert_eq!(r.stats.events_nonmonotonic, 1);
    }

    #[test]
    fn batch_size_does_not_change_frames() {
        let res = Resolution::new(16, 16);
        let evs = stream(400, res);
        let mut all = Vec::new();
        for bs in [1usize, 64, 4_096] {
            let cfg = PipelineConfig { batch_size: bs, ..PipelineConfig::default() };
            all.push(run(evs.iter().copied(), res, 400_000, &cfg).frames);
        }
        assert_eq!(all[0], all[1]);
        assert_eq!(all[1], all[2]);
    }
}
