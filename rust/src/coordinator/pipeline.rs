//! The end-to-end event pipeline: source → (optional STCF denoise) →
//! sharded ISC writes → windowed frame readout.
//!
//! This is the serving loop of the system: events stream in, the analog
//! plane absorbs them, and every `window_us` a time-surface frame is
//! snapshotted for the downstream CV consumer (classifier / reconstructor
//! running on the PJRT artifacts).
//!
//! The pipeline is **streaming and batch-first**: it consumes any
//! `IntoIterator<Item = LabeledEvent>` (a replayed recording, a lazy
//! generator, `events.iter().copied()` over a slice) and never
//! materializes the stream — the only buffering is a bounded staging
//! batch of at most `batch_size` events between router flushes, and the
//! STCF (causal and cheap relative to everything downstream) filters
//! events inline as they pass. Stages communicate over bounded channels,
//! so a slow consumer backpressures the source instead of buffering
//! unboundedly.

use super::router::{Router, RouterConfig, RouterStats};
use crate::denoise::{support_count, StcfBackend, StcfParams};
use crate::events::{Event, LabeledEvent, Resolution};
use crate::util::grid::Grid;
use std::time::Instant;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Frame readout period (paper Sec. IV-D: 50 ms windows).
    pub window_us: u64,
    /// Run the STCF in front of the array (None = raw stream).
    pub stcf: Option<StcfParams>,
    /// Events staged between router flushes — the ingest batch size and
    /// the pipeline's only stream buffering.
    pub batch_size: usize,
    pub router: RouterConfig,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { window_us: 50_000, stcf: None, batch_size: 4_096, router: RouterConfig::default() }
    }
}

/// Pipeline result: frames plus run statistics.
pub struct PipelineRun {
    /// (frame timestamp µs, normalized TS frame).
    pub frames: Vec<(u64, Grid<f64>)>,
    pub stats: PipelineStats,
}

#[derive(Clone, Debug)]
pub struct PipelineStats {
    pub events_in: u64,
    pub events_written: u64,
    pub events_dropped_by_stcf: u64,
    pub frames_emitted: u64,
    /// High-water mark of the staging batch — bounded by `batch_size`,
    /// which is the pipeline's no-full-stream-copy guarantee.
    pub peak_batch_len: usize,
    pub wall_seconds: f64,
    /// Router statistics, including the dirty-band snapshot counters:
    /// `router.snapshots_served` (= `frames_emitted`) and
    /// `router.bands_skipped_unchanged` (band renders the dirty-band
    /// protocol avoided — the observable win on sparse streams).
    pub router: RouterStats,
    /// Throughput in events/second of wall time.
    pub events_per_second: f64,
}

/// Run the pipeline over a sorted labeled event source covering
/// [0, t_end_us). Slice holders pass `events.iter().copied()`; anything
/// streaming (replay readers, generators) is consumed without a copy.
pub fn run<I>(events: I, res: Resolution, t_end_us: u64, cfg: &PipelineConfig) -> PipelineRun
where
    I: IntoIterator<Item = LabeledEvent>,
{
    let start = Instant::now();
    let batch_size = cfg.batch_size.max(1);

    // Optional STCF stage, applied inline per event (score against the
    // current surface, then write — the filter is causal by construction).
    let mut stcf: Option<(StcfBackend, StcfParams)> = cfg.stcf.as_ref().map(|prm| {
        (StcfBackend::isc(res, cfg.router.isc.clone(), prm.tau_tw_us), *prm)
    });

    let mut router = Router::new(res, cfg.router.clone());
    let mut frames: Vec<(u64, Grid<f64>)> = Vec::new();
    let mut batch: Vec<Event> = Vec::with_capacity(batch_size);
    let mut next_frame = cfg.window_us;
    let mut events_in = 0u64;
    let mut dropped = 0u64;
    let mut peak_batch_len = 0usize;

    for le in events {
        events_in += 1;
        // Snapshot every window boundary the stream has passed; staged
        // writes are flushed by `Router::frame` so each frame observes
        // exactly the events that precede it.
        while le.ev.t > next_frame && next_frame <= t_end_us {
            peak_batch_len = peak_batch_len.max(batch.len());
            router.route_batch(&batch);
            batch.clear();
            frames.push((next_frame, router.frame(next_frame)));
            next_frame += cfg.window_us;
        }
        if let Some((backend, prm)) = stcf.as_mut() {
            let s = support_count(backend, &le.ev, prm);
            backend.ingest(&le.ev, prm);
            if s < prm.threshold {
                dropped += 1;
                continue;
            }
        }
        batch.push(le.ev);
        if batch.len() >= batch_size {
            peak_batch_len = peak_batch_len.max(batch.len());
            router.route_batch(&batch);
            batch.clear();
        }
    }
    peak_batch_len = peak_batch_len.max(batch.len());
    router.route_batch(&batch);
    batch.clear();
    while next_frame <= t_end_us {
        frames.push((next_frame, router.frame(next_frame)));
        next_frame += cfg.window_us;
    }

    let events_written = router.events_routed();
    let router_stats = router.shutdown();
    let wall = start.elapsed().as_secs_f64();
    let stats = PipelineStats {
        events_in,
        events_written,
        events_dropped_by_stcf: dropped,
        frames_emitted: frames.len() as u64,
        peak_batch_len,
        wall_seconds: wall,
        events_per_second: if wall > 0.0 { events_in as f64 / wall } else { 0.0 },
        router: router_stats,
    };
    PipelineRun { frames, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::event::{Event, Polarity};

    fn stream(n: u64, res: Resolution) -> Vec<LabeledEvent> {
        (0..n)
            .map(|k| LabeledEvent {
                ev: Event::new(
                    1 + k * 1_000,
                    (k % res.width as u64) as u16,
                    (k % res.height as u64) as u16,
                    Polarity::On,
                ),
                is_signal: true,
            })
            .collect()
    }

    #[test]
    fn emits_expected_frame_count() {
        let res = Resolution::new(16, 16);
        let evs = stream(100, res); // covers 0..100ms
        let run = run(evs.iter().copied(), res, 100_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 2); // 50ms windows
        assert_eq!(run.stats.frames_emitted, 2);
        assert_eq!(run.stats.events_in, 100);
        assert_eq!(run.stats.events_written, 100);
    }

    #[test]
    fn consumes_lazy_iterator_without_materializing() {
        // The source here is a pure generator: no backing Vec exists, so
        // the old `events.to_vec()` copy is impossible by construction.
        // Buffering is bounded by batch_size (asserted via the high-water
        // mark).
        let res = Resolution::new(16, 16);
        let n = 10_000u64;
        let cfg = PipelineConfig { batch_size: 256, ..PipelineConfig::default() };
        let source = (0..n).map(|k| LabeledEvent {
            ev: Event::new(1 + k * 10, (k % 16) as u16, (k % 16) as u16, Polarity::On),
            is_signal: true,
        });
        let run = run(source, res, 100_000, &cfg);
        assert_eq!(run.stats.events_in, n);
        assert_eq!(run.stats.events_written, n);
        assert!(
            run.stats.peak_batch_len <= 256,
            "staging exceeded batch_size: {}",
            run.stats.peak_batch_len
        );
    }

    #[test]
    fn stcf_stage_drops_noise() {
        let res = Resolution::new(16, 16);
        // Isolated events (all far apart in space) → STCF drops them all.
        let evs: Vec<LabeledEvent> = (0..20)
            .map(|k| LabeledEvent {
                ev: Event::new(1 + k * 2_000, ((k * 7) % 16) as u16, ((k * 5) % 16) as u16,
                               Polarity::On),
                is_signal: false,
            })
            .collect();
        let cfg = PipelineConfig {
            stcf: Some(StcfParams { threshold: 2, ..StcfParams::default() }),
            ..PipelineConfig::default()
        };
        let run = run(evs.iter().copied(), res, 50_000, &cfg);
        assert!(run.stats.events_dropped_by_stcf > 10,
                "dropped {}", run.stats.events_dropped_by_stcf);
    }

    #[test]
    fn frames_reflect_recent_writes() {
        let res = Resolution::new(8, 8);
        let evs = vec![LabeledEvent {
            ev: Event::new(49_000, 4, 4, Polarity::On),
            is_signal: true,
        }];
        let run = run(evs.iter().copied(), res, 50_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 1);
        let f = &run.frames[0].1;
        assert!(*f.get(4, 4) > 0.9, "fresh write should be bright");
        assert_eq!(*f.get(0, 0), 0.0);
    }

    #[test]
    fn empty_stream_still_emits_frames() {
        let res = Resolution::new(8, 8);
        let run = run(std::iter::empty(), res, 150_000, &PipelineConfig::default());
        assert_eq!(run.frames.len(), 3);
        assert!(run.frames.iter().all(|(_, f)| f.as_slice().iter().all(|&v| v == 0.0)));
        // Dirty-band protocol on an empty stream: the first snapshot
        // renders every (empty) band; the later ones skip them all.
        let st = &run.stats.router;
        assert_eq!(st.snapshots_served, 3);
        assert_eq!(st.bands_skipped_unchanged, 2 * st.per_shard.len() as u64);
    }

    #[test]
    fn sparse_stream_skips_untouched_bands() {
        // All activity confined to one row: after the first window, every
        // never-written band is provably static and must stop costing a
        // shard round-trip while the frames stay exact.
        let res = Resolution::new(16, 16);
        let evs: Vec<LabeledEvent> = (0..200u64)
            .map(|k| LabeledEvent {
                ev: Event::new(1 + k * 900, (k % 16) as u16, 5, Polarity::On),
                is_signal: true,
            })
            .collect();
        let run = run(evs.iter().copied(), res, 180_000, &PipelineConfig::default());
        let st = &run.stats.router;
        assert_eq!(st.snapshots_served, run.stats.frames_emitted);
        assert!(
            st.bands_skipped_unchanged > 0,
            "clean bands must be skipped: {st:?}"
        );
    }

    #[test]
    fn batch_size_does_not_change_frames() {
        let res = Resolution::new(16, 16);
        let evs = stream(400, res);
        let mut all = Vec::new();
        for bs in [1usize, 64, 4_096] {
            let cfg = PipelineConfig { batch_size: bs, ..PipelineConfig::default() };
            all.push(run(evs.iter().copied(), res, 400_000, &cfg).frames);
        }
        assert_eq!(all[0], all[1]);
        assert_eq!(all[1], all[2]);
    }
}
