//! L3 coordination: the batch-first serving layer between event sources
//! and the sharded ISC plane.
//!
//! * [`batcher`] — groups a sorted stream into fixed-Δt microbatches;
//!   [`batcher::batches`] does it lazily over any event iterator.
//! * [`router`] — partitions the plane into horizontal bands owned by
//!   worker threads, routes **batches** of writes (per-shard staging +
//!   sort-free run coalescing), applies backpressure through bounded
//!   queues, and scatter-gathers frame snapshots into reused buffers.
//! * [`pipeline`] — the end-to-end loop: an
//!   `IntoIterator<Item = LabeledEvent>` source → optional band-sharded
//!   STCF → batched shard writes → windowed `frame_into` readout.
//!   Streaming by construction: the full event stream is never
//!   materialized or cloned; buffering is bounded by
//!   `PipelineConfig::batch_size`.
//!
//! ## Pipeline stages
//!
//! Every stage after the producer runs on its own threads; both shard
//! pools cut the sensor into the same horizontal bands
//! ([`crate::util::parallel::band_layout`]):
//!
//! ```text
//!            staged batch           kept events (stream order)
//! producer ──────────────► STCF denoise shards ─────────────► Router
//!  (source    ≤batch_size   [band + r halo rows each;           │ WriteBatch
//!   iterator)               score-then-write, popcount-         ▼ per band
//!                           gated support scans]           ISC write shards
//!                                                               │ Snapshot /
//!                                                               ▼ Unchanged
//!                           frames (every window_us) ◄── dirty-band composite
//! ```
//!
//! With `denoise_shards: 0` the STCF scores inline on the producer (one
//! core, same decisions). `PipelineStats::stage_wall` reports where the
//! producer's time went; `PipelineStats::denoise` carries the per-shard
//! kept/dropped/halo tallies.
//!
//! This module serves **one** stream with dedicated thread teams. To
//! host many concurrent streams on a shared fixed-size worker fleet —
//! with admission control and fair scheduling — use the
//! [`crate::serve`] session layer, which drives the same band cores
//! ([`router::BandWriter`], the denoise pool's band scorers) as queued
//! jobs and emits bit-for-bit identical frames.
//!
//! **Migration note** (old → new API): `pipeline::run(&[LabeledEvent],…)`
//! → `pipeline::run(events.iter().copied(), …)` (or any lazy source);
//! `Router::route` still exists for single events but stages internally —
//! bulk producers should call `Router::route_batch`; `Router::frame`
//! gained an allocation-free `Router::frame_into` sibling.

// Coordination code must surface failures as expects with context,
// never bare unwraps (tests are exempt).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod batcher;
pub mod pipeline;
pub mod router;

pub use batcher::{batches, Batches, MicroBatch, MicroBatcher};
pub use pipeline::{
    run as run_pipeline, DenoiseStats, PipelineConfig, PipelineRun, PipelineStats, StageWall,
};
pub use router::{Router, RouterConfig, RouterStats};
