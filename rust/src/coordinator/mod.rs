//! L3 coordination: microbatching, the sharded event router with
//! backpressure, and the end-to-end event→frame pipeline.

pub mod batcher;
pub mod pipeline;
pub mod router;

pub use batcher::{MicroBatch, MicroBatcher};
pub use pipeline::{run as run_pipeline, PipelineConfig, PipelineRun, PipelineStats};
pub use router::{Router, RouterConfig, RouterStats};
