//! Sharded event router: the L3 coordination core.
//!
//! The ISC plane is partitioned into horizontal bands, each owned by a
//! worker thread with its own analog-array state (mirroring how a tiled
//! hardware readout partitions the sensor). The router dispatches writes
//! by row **in batches**: incoming events are staged per shard and
//! shipped as one `WriteBatch` message when a batch fills (or before any
//! snapshot/shutdown), so a 100 Meps-class stream costs one channel
//! round-trip per few thousand events instead of one per event.
//! [`Router::route_batch`] additionally coalesces sort-free runs of
//! consecutive events that land in the same band, so shard-local cells
//! are staged with one contiguous `extend_from_slice` per run.
//!
//! Backpressure still propagates through bounded queues (`queue_depth`
//! counts in-flight *batches* per shard), and scatter-gather frame
//! snapshots recycle their band buffers: each `Snapshot` request carries
//! a buffer the shard fills and returns, so a steady-state serving loop
//! performs zero per-frame allocations (see [`Router::frame_into`]).
//! Because each shard renders its band via the array's activity-aware
//! `frame_merged_into`, snapshot cost scales with the band's *active*
//! pixels, not its area — the per-band inheritance of the O(active)
//! readout (see [`crate::isc`] module docs).
//! std::thread + sync_channel (tokio is not available offline; bounded
//! mpsc gives the same backpressure semantics deterministically).

use crate::events::{Event, Resolution};
use crate::isc::{IscArray, IscConfig};
use crate::util::grid::Grid;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Worker shards (horizontal bands).
    pub n_shards: usize,
    /// Bounded queue depth per shard (in batches) — the backpressure knob.
    pub queue_depth: usize,
    /// Events staged per shard before a batch is shipped.
    pub batch_size: usize,
    /// Array config cloned per shard (seeds are derived per shard).
    pub isc: IscConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { n_shards: 4, queue_depth: 64, batch_size: 4_096, isc: IscConfig::default() }
    }
}

enum ShardMsg {
    /// A staged batch of writes; `y` is still in sensor coordinates.
    WriteBatch(Vec<Event>),
    /// Render the band's merged frame at `at_us` directly into `buf` and
    /// send it back (the buffer cycles shard → router → shard).
    Snapshot { at_us: u64, buf: Grid<f64>, reply: SyncSender<(usize, Grid<f64>)> },
    Stop,
}

/// Post-shutdown statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterStats {
    pub events_routed: u64,
    pub per_shard: Vec<u64>,
    /// Batch messages shipped across all shards (events_routed / batches
    /// is the effective coalescing factor).
    pub batches_shipped: u64,
}

/// The sharded router.
pub struct Router {
    senders: Vec<SyncSender<ShardMsg>>,
    handles: Vec<JoinHandle<u64>>,
    res: Resolution,
    band_h: usize,
    batch_size: usize,
    /// Per-shard staging buffers awaiting a full batch.
    staging: Vec<Vec<Event>>,
    /// Recycled band buffers for frame snapshots (shard → router → shard).
    snap_bufs: Vec<Grid<f64>>,
    events_routed: u64,
    batches_shipped: u64,
}

impl Router {
    pub fn new(res: Resolution, cfg: RouterConfig) -> Self {
        let requested = cfg.n_shards.max(1).min(res.height as usize);
        let band_h = (res.height as usize).div_ceil(requested);
        // Recompute the effective shard count so no shard owns zero rows
        // (e.g. 8 rows over 6 requested shards → bands of 2 → 4 shards).
        let n = (res.height as usize).div_ceil(band_h);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx): (SyncSender<ShardMsg>, Receiver<ShardMsg>) =
                sync_channel(cfg.queue_depth.max(1));
            let rows = band_h.min(res.height as usize - shard * band_h);
            let band_res = Resolution::new(res.width, rows as u16);
            let mut isc_cfg = cfg.isc.clone();
            isc_cfg.seed = isc_cfg.seed.wrapping_add(shard as u64 * 0x9e37_79b9);
            let y0 = (shard * band_h) as u16;
            handles.push(std::thread::spawn(move || {
                let mut array = IscArray::new(band_res, isc_cfg);
                let mut processed = 0u64;
                for msg in rx {
                    match msg {
                        ShardMsg::WriteBatch(mut batch) => {
                            for e in &mut batch {
                                e.y -= y0;
                            }
                            array.write_batch(&batch);
                            processed += batch.len() as u64;
                        }
                        ShardMsg::Snapshot { at_us, mut buf, reply } => {
                            array.frame_merged_into(&mut buf, at_us);
                            let _ = reply.send((y0 as usize, buf));
                        }
                        ShardMsg::Stop => break,
                    }
                }
                processed
            }));
            senders.push(tx);
        }
        Self {
            staging: (0..n).map(|_| Vec::with_capacity(cfg.batch_size.max(1))).collect(),
            snap_bufs: vec![Grid::new(1, 1, 0.0); n],
            senders,
            handles,
            res,
            band_h,
            batch_size: cfg.batch_size.max(1),
            events_routed: 0,
            batches_shipped: 0,
        }
    }

    #[inline]
    fn shard_for(&self, y: u16) -> usize {
        (y as usize / self.band_h).min(self.senders.len() - 1)
    }

    /// Route one event write. The event is staged; a full batch blocks on
    /// the target shard's bounded queue (backpressure propagates to the
    /// producer). Staged events become visible to snapshots at the next
    /// [`Router::flush`] / [`Router::frame`] / [`Router::shutdown`].
    pub fn route(&mut self, e: Event) {
        debug_assert!(self.res.contains(e.x, e.y));
        let s = self.shard_for(e.y);
        self.staging[s].push(e);
        if self.staging[s].len() >= self.batch_size {
            self.flush_shard(s);
        }
        self.events_routed += 1;
    }

    /// Route a time-sorted batch. Consecutive events falling in the same
    /// band are coalesced into one contiguous staging append (sort-free
    /// run coalescing) — event streams are spatially coherent, so runs
    /// are long and the per-event shard lookup mostly disappears.
    pub fn route_batch(&mut self, events: &[Event]) {
        let mut i = 0usize;
        while i < events.len() {
            debug_assert!(self.res.contains(events[i].x, events[i].y));
            let s = self.shard_for(events[i].y);
            let mut j = i + 1;
            while j < events.len() && self.shard_for(events[j].y) == s {
                debug_assert!(self.res.contains(events[j].x, events[j].y));
                j += 1;
            }
            self.staging[s].extend_from_slice(&events[i..j]);
            if self.staging[s].len() >= self.batch_size {
                self.flush_shard(s);
            }
            i = j;
        }
        self.events_routed += events.len() as u64;
    }

    fn flush_shard(&mut self, s: usize) {
        if self.staging[s].is_empty() {
            return;
        }
        let replacement = Vec::with_capacity(self.batch_size);
        let batch = std::mem::replace(&mut self.staging[s], replacement);
        self.senders[s].send(ShardMsg::WriteBatch(batch)).expect("shard died");
        self.batches_shipped += 1;
    }

    /// Ship all staged events to their shards.
    pub fn flush(&mut self) {
        for s in 0..self.senders.len() {
            self.flush_shard(s);
        }
    }

    /// Scatter-gather a full frame snapshot at `at_us` (allocating
    /// convenience wrapper around [`Router::frame_into`]).
    pub fn frame(&mut self, at_us: u64) -> Grid<f64> {
        let mut g = Grid::new(self.res.width as usize, self.res.height as usize, 0.0);
        self.frame_into(&mut g, at_us);
        g
    }

    /// Scatter-gather a frame snapshot into a caller-owned grid. Staged
    /// writes are flushed first so the snapshot observes every routed
    /// event. Band buffers are recycled between calls: after the first
    /// frame, the readout path performs zero heap allocations.
    pub fn frame_into(&mut self, out: &mut Grid<f64>, at_us: u64) {
        self.flush();
        let w = self.res.width as usize;
        out.ensure_shape(w, self.res.height as usize, 0.0);
        let (tx, rx) = sync_channel(self.senders.len());
        for s in &self.senders {
            let buf = self.snap_bufs.pop().unwrap_or_else(|| Grid::new(1, 1, 0.0));
            s.send(ShardMsg::Snapshot { at_us, buf, reply: tx.clone() })
                .expect("shard died");
        }
        drop(tx);
        let slice = out.as_mut_slice();
        for (y0, band) in rx.iter().take(self.senders.len()) {
            let rows = band.height();
            slice[y0 * w..(y0 + rows) * w].copy_from_slice(band.as_slice());
            self.snap_bufs.push(band);
        }
    }

    pub fn events_routed(&self) -> u64 {
        self.events_routed
    }

    pub fn n_shards(&self) -> usize {
        self.senders.len()
    }

    /// Stop all shards and collect statistics.
    pub fn shutdown(mut self) -> RouterStats {
        self.flush();
        for s in &self.senders {
            let _ = s.send(ShardMsg::Stop);
        }
        let per_shard: Vec<u64> =
            self.handles.drain(..).map(|h| h.join().expect("join")).collect();
        RouterStats {
            events_routed: self.events_routed,
            per_shard,
            batches_shipped: self.batches_shipped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;
    use crate::util::check::check;

    #[test]
    fn routes_and_counts() {
        let res = Resolution::new(16, 16);
        let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        for y in 0..16u16 {
            r.route(Event::new(1_000 + y as u64, 3, y, Polarity::On));
        }
        assert_eq!(r.events_routed(), 16);
        let stats = r.shutdown();
        assert_eq!(stats.per_shard.iter().sum::<u64>(), 16);
        // Even row spread → even shard loads.
        assert!(stats.per_shard.iter().all(|&c| c == 4), "{:?}", stats.per_shard);
    }

    #[test]
    fn batch_routing_coalesces_messages() {
        let res = Resolution::new(8, 8);
        let mut r = Router::new(
            res,
            RouterConfig { n_shards: 2, batch_size: 4_096, ..RouterConfig::default() },
        );
        // 100 events in two spatially coherent runs → far fewer batches.
        let events: Vec<Event> = (0..100u64)
            .map(|k| Event::new(1 + k, (k % 8) as u16, if k < 50 { 1 } else { 6 }, Polarity::On))
            .collect();
        r.route_batch(&events);
        let stats = r.shutdown();
        assert_eq!(stats.events_routed, 100);
        assert_eq!(stats.per_shard, vec![50, 50]);
        assert!(stats.batches_shipped <= 2, "batches {}", stats.batches_shipped);
    }

    #[test]
    fn route_batch_equals_single_routes() {
        let res = Resolution::new(12, 12);
        let cfg = RouterConfig { n_shards: 3, queue_depth: 16, ..RouterConfig::default() };
        let events: Vec<Event> = (0..60u64)
            .map(|k| Event::new(1_000 + k * 250, (k % 12) as u16, ((k * 5) % 12) as u16,
                                Polarity::On))
            .collect();
        let mut single = Router::new(res, cfg.clone());
        for e in &events {
            single.route(*e);
        }
        let mut batched = Router::new(res, cfg);
        batched.route_batch(&events);
        let fa = single.frame(20_000);
        let fb = batched.frame(20_000);
        assert_eq!(fa, fb);
        single.shutdown();
        batched.shutdown();
    }

    #[test]
    fn frame_matches_unsharded_array() {
        let res = Resolution::new(12, 12);
        let cfg = IscConfig::default();
        let mut router = Router::new(
            res,
            RouterConfig { n_shards: 3, queue_depth: 64, isc: cfg.clone(),
                           ..RouterConfig::default() },
        );
        let mut single = IscArray::new(res, cfg);
        let events: Vec<Event> = (0..40)
            .map(|k| Event::new(1_000 + k * 500, (k % 12) as u16, (k % 12) as u16, Polarity::On))
            .collect();
        router.route_batch(&events);
        single.write_batch(&events);
        let fr = router.frame(25_000);
        let fs = single.frame_merged(25_000);
        // Same write pattern, same nominal bank ⇒ same brightness ordering;
        // mismatch maps differ per shard seed, so compare written-pixel sets
        // and value proximity.
        for (x, y, &v) in fr.iter_coords() {
            let vs = *fs.get(x, y);
            assert_eq!(v > 0.0, vs > 0.0, "write-set mismatch at ({x},{y})");
            if v > 0.0 {
                assert!((v - vs).abs() < 0.05, "({x},{y}): {v} vs {vs}");
            }
        }
        router.shutdown();
    }

    #[test]
    fn uneven_heights_covered() {
        // 10 rows over 4 shards: bands of 3,3,3,1.
        let res = Resolution::new(4, 10);
        let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        for y in 0..10u16 {
            r.route(Event::new(1_000, 0, y, Polarity::On));
        }
        let f = r.frame(1_000);
        for y in 0..10 {
            assert!(*f.get(0, y) > 0.5, "row {y} missing");
        }
        r.shutdown();
    }

    #[test]
    fn frame_into_reuses_buffers() {
        let res = Resolution::new(8, 8);
        let mut r = Router::new(res, RouterConfig { n_shards: 2, ..RouterConfig::default() });
        let mut out = Grid::new(1, 1, 0.0);
        r.frame_into(&mut out, 1_000); // warmup: reshapes + first band bufs
        let ptr = out.as_slice().as_ptr();
        for k in 0..5u64 {
            r.route(Event::new(2_000 + k, (k % 8) as u16, (k % 8) as u16, Polarity::On));
            r.frame_into(&mut out, 3_000 + k);
            assert_eq!(out.as_slice().as_ptr(), ptr, "warm frame_into must not reallocate");
        }
        assert!(out.as_slice().iter().any(|&v| v > 0.0));
        r.shutdown();
    }

    #[test]
    fn prop_router_preserves_event_count() {
        check("router count conservation", 20, |g| {
            let res = Resolution::new(8, 8);
            let n_shards = g.usize(1, 6);
            let batch_size = g.usize(1, 32);
            let mut r = Router::new(
                res,
                RouterConfig { n_shards, queue_depth: 16, batch_size,
                               ..RouterConfig::default() },
            );
            let n = g.usize(0, 100);
            let mut t = 0u64;
            for _ in 0..n {
                t += g.u64(1, 100);
                r.route(Event::new(
                    t,
                    g.u64(0, 7) as u16,
                    g.u64(0, 7) as u16,
                    Polarity::On,
                ));
            }
            let stats = r.shutdown();
            assert_eq!(stats.events_routed, n as u64);
            assert_eq!(stats.per_shard.iter().sum::<u64>(), n as u64);
        });
    }
}
