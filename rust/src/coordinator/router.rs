//! Sharded event router: the L3 coordination core.
//!
//! The ISC plane is partitioned into horizontal bands, each owned by a
//! worker thread with its own analog-array state (mirroring how a tiled
//! hardware readout partitions the sensor). The router dispatches writes
//! by row **in batches**: incoming events are staged per shard and
//! shipped as one `WriteBatch` message when a batch fills (or before any
//! snapshot/shutdown), so a 100 Meps-class stream costs one channel
//! round-trip per few thousand events instead of one per event.
//! [`Router::route_batch`] additionally coalesces sort-free runs of
//! consecutive events that land in the same band, so shard-local cells
//! are staged with one contiguous `extend_from_slice` per run.
//!
//! Backpressure still propagates through bounded queues (`queue_depth`
//! counts in-flight *batches* per shard), and scatter-gather frame
//! snapshots recycle their band buffers: each `Snapshot` request carries
//! the shard's own previous band buffer, which the shard refreshes and
//! returns, so a steady-state serving loop performs no per-frame buffer
//! allocations (see [`Router::frame_into`]).
//! Threads + the bounded channel come from the loom-switchable
//! [`crate::util::sync`] facade (tokio is not available offline;
//! bounded mpsc gives the same backpressure semantics
//! deterministically, and under `--cfg loom` the very same shard
//! channel is model-checked).
//!
//! ## Dirty-band snapshots (PR 3)
//!
//! Snapshots are incremental. The router keeps each shard's last
//! rendered band ([`BandCache`]) plus a per-shard dirty bit (set when a
//! write batch ships); shards track their own dirty state and per-row
//! dirty watermarks since their last reply. A snapshot then costs, per
//! band:
//!
//! | Band state | Work |
//! |---|---|
//! | clean + cached at the same `at_us` | **skipped entirely** (no shard round-trip, composite from cache) |
//! | clean + provably all-zero (every write expired) | **skipped entirely** for any later `at_us` |
//! | clean but shard must confirm | `Unchanged` reply, zero render work |
//! | dirty at the cached `at_us` | partial re-render of the dirty row span — O(dirty rows) |
//! | dirty at a new `at_us` | band render (activity-aware + row-parallel, see [`crate::isc`]) |
//!
//! Steady-state snapshot cost is therefore O(dirty) render work plus
//! the unavoidable composite memcpy, instead of O(H·W) renders; a
//! sparse stream whose activity sits in a few bands skips most shard
//! round-trips outright ([`RouterStats::bands_skipped_unchanged`]
//! counts both skip flavors). The shard render itself stays bit-for-bit
//! what a full re-render would produce, provided snapshot times are
//! causal (non-decreasing and ≥ the routed event times — the same
//! contract as the activity-aware readout, see [`crate::util::active`]).
//!
//! ## Lazy band materialization (PR 7)
//!
//! A band allocates **no analog-array state until its first write**:
//! [`BandWriter`] starts cold (config only), materializes its
//! [`IscArray`] on the first non-empty batch, and **demotes back to
//! cold** once a snapshot finds every written cell expired past the
//! memory horizon ([`IscArray::fully_expired_at`]). Cold bands answer
//! snapshots with a one-time zero fill that the dirty-band cache then
//! composites for free, so a session whose activity touches a few bands
//! holds O(active bands) resident bytes — not O(H·W) — and an idle
//! session's memory decays back toward a small constant. Demotion is
//! exact: a band only demotes when its frame is provably zero forever
//! absent new writes, and the position-stable mismatch assignment makes
//! a rematerialized array bit-for-bit identical to the one torn down.
//!
//! ## Band-job core (serve PR)
//!
//! The per-shard state machine — band array, dirty watermarks, the
//! snapshot decision tree above — lives in [`BandWriter`], which the
//! shard thread loop merely drives. The multi-tenant session layer
//! ([`crate::serve`]) schedules the same struct as queued jobs on a
//! shared worker pool, so a session's band state evolves exactly as a
//! dedicated router's would. Band arrays are anchored at their global
//! origin rows ([`IscConfig::origin_y`]): with the position-stable
//! mismatch assignment, routed frames are bit-for-bit identical to an
//! unsharded array for **every** shard layout, mismatch included (the
//! PR 4 per-shard-seed caveat is gone).

use crate::events::{Event, Polarity, Resolution};
use crate::isc::{IscArray, IscConfig};
use crate::util::grid::Grid;
use crate::util::sync::chan::{bounded, Sender};
use crate::util::sync::thread::{self, JoinHandle};

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Worker shards (horizontal bands).
    pub n_shards: usize,
    /// Bounded queue depth per shard (in batches) — the backpressure knob.
    pub queue_depth: usize,
    /// Events staged per shard before a batch is shipped.
    pub batch_size: usize,
    /// Array config cloned per shard. Each band array is anchored at its
    /// global origin row, so the position-stable mismatch assignment
    /// makes every band an exact window of the full-sensor array —
    /// routed frames are bit-for-bit independent of the shard count.
    pub isc: IscConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { n_shards: 4, queue_depth: 64, batch_size: 4_096, isc: IscConfig::default() }
    }
}

enum ShardMsg {
    /// A staged batch of writes; `y` is still in sensor coordinates.
    WriteBatch(Vec<Event>),
    /// Render the band's merged frame at `at_us` into `buf` and send it
    /// back (the buffer cycles shard → router → shard) — or, when the
    /// band provably cannot have changed, return the buffer untouched
    /// with `rendered: false` (an `Unchanged` reply). `cache_valid`
    /// promises `buf` still holds this shard's previous reply.
    Snapshot { at_us: u64, buf: Grid<f64>, cache_valid: bool, reply: Sender<SnapReply> },
    Stop,
}

/// A shard's answer to [`ShardMsg::Snapshot`].
struct SnapReply {
    shard: usize,
    buf: Grid<f64>,
    /// false = the band was clean and `buf` still holds the previous
    /// render (zero render work was performed).
    rendered: bool,
    /// See [`BandCache::empty_static`].
    empty_static: bool,
}

/// Router-side cached state of one shard's band between snapshots.
struct BandCache {
    /// The shard's last rendered band (None only while in flight).
    buf: Option<Grid<f64>>,
    /// Query time of the cached render.
    at_us: u64,
    /// The cache holds a band this shard actually rendered (false until
    /// the first snapshot reply arrives).
    valid: bool,
    /// The cached band is all-zero and stays all-zero at any later query
    /// time absent new writes (every routed write had already expired at
    /// `at_us` — passive decay is monotone, so zero stays zero).
    empty_static: bool,
}

/// One write shard's band-local core: the band's analog array plus the
/// dirty-band snapshot state. The router's shard threads and the serve
/// scheduler's band jobs ([`crate::serve`]) both drive this struct —
/// extracting it is what lets a multi-tenant session replay the exact
/// per-band write/render sequence a dedicated router would run, so
/// session frames are bit-for-bit identical to a standalone pipeline.
pub struct BandWriter {
    /// The band's resolution (kept for cold-band zero fills and
    /// rematerialization).
    band_res: Resolution,
    /// Band-anchored array config, kept so a demoted band can
    /// rematerialize an identical array on its next write.
    cfg: IscConfig,
    /// The band's analog array — `None` while the band is **cold**:
    /// never written, or demoted after every write expired past the
    /// memory horizon. Cold bands hold no plane allocation at all.
    array: Option<IscArray>,
    /// Global sensor row of the band's row 0.
    y0: u16,
    /// Row-chunk count for full band renders (1 = render inline on the
    /// calling thread; the serve scheduler always passes 1 so worker
    /// threads stay bounded by the pool size).
    render_chunks: usize,
    /// Query time of the previous snapshot reply (None before the first).
    last_at: Option<u64>,
    /// Writes arrived since the previous snapshot reply.
    dirty: bool,
    /// Band-local dirty row watermarks since the previous reply.
    dirty_rows: Option<(usize, usize)>,
    /// See [`BandCache::empty_static`].
    empty_static: bool,
    processed: u64,
}

/// Outcome of [`BandWriter::snapshot_into`].
pub struct BandSnapshot {
    /// False = the band was clean and the buffer still holds the
    /// previous render (zero render work was performed).
    pub rendered: bool,
    /// See [`BandCache::empty_static`].
    pub empty_static: bool,
}

impl BandWriter {
    /// The writer for band `shard` of the `band_layout(height, …)`
    /// partition of `res`: rows `shard·band_h ..`. The band's array is
    /// anchored at its global origin ([`IscConfig::origin_y`]), so its
    /// position-stable mismatch map is an exact window of the
    /// full-sensor array's and band partitioning never perturbs values.
    pub fn for_band(
        res: Resolution,
        isc: &IscConfig,
        band_h: usize,
        shard: usize,
        render_chunks: usize,
    ) -> Self {
        let rows = band_h.min(res.height as usize - shard * band_h);
        let band_res = Resolution::new(res.width, rows as u16);
        let y0 = (shard * band_h) as u16;
        let mut cfg = isc.clone();
        cfg.origin_y = isc.origin_y + y0;
        Self {
            band_res,
            cfg,
            // Cold until the first write: no plane allocation, no
            // Monte-Carlo bank fit.
            array: None,
            y0,
            render_chunks: render_chunks.max(1),
            last_at: None,
            dirty: false,
            dirty_rows: None,
            empty_static: false,
            processed: 0,
        }
    }

    /// Apply one write batch. Events arrive in sensor coordinates and
    /// are shifted into the band in place; the dirty flag and row
    /// watermarks advance so the next snapshot can re-render only what
    /// changed. A cold band materializes its array on the first
    /// non-empty batch (the only place allocation happens).
    pub fn apply_batch(&mut self, batch: &mut [Event]) {
        if batch.is_empty() {
            return;
        }
        for e in batch.iter_mut() {
            e.y -= self.y0;
            let yl = e.y as usize;
            self.dirty_rows = Some(match self.dirty_rows {
                None => (yl, yl),
                Some((lo, hi)) => (lo.min(yl), hi.max(yl)),
            });
        }
        self.dirty = true;
        self.array
            .get_or_insert_with(|| IscArray::new(self.band_res, self.cfg.clone()))
            .write_batch(batch);
        self.processed += batch.len() as u64;
    }

    /// Render the band's merged frame at `at_us` into `buf` — or, when
    /// the band provably cannot have changed, leave `buf` untouched and
    /// report `rendered: false`. `cache_valid` promises `buf` still
    /// holds this band's previous reply. Clean bands at the cached
    /// query time (or provably all-zero ones at any later time) cost
    /// nothing; dirty bands at the cached time re-render only the dirty
    /// row span.
    pub fn snapshot_into(
        &mut self,
        buf: &mut Grid<f64>,
        at_us: u64,
        cache_valid: bool,
    ) -> BandSnapshot {
        let cached = cache_valid && self.last_at.is_some();
        let Some(array) = self.array.as_mut() else {
            // Cold band: identically zero at every causal query time. A
            // valid cached reply from this writer is necessarily
            // all-zero (bands only demote once empty-static), so a
            // cached buffer composites as-is; otherwise one zero fill —
            // no array, no render work either way.
            let unchanged = cached && !self.dirty && self.empty_static;
            if !unchanged {
                let (w, h) = (self.band_res.width as usize, self.band_res.height as usize);
                buf.ensure_shape(w, h, 0.0);
                buf.as_mut_slice().fill(0.0);
                self.empty_static = true;
            }
            self.last_at = Some(at_us);
            self.dirty = false;
            self.dirty_rows = None;
            return BandSnapshot { rendered: !unchanged, empty_static: true };
        };
        // Clean band: the cached render is still exact at the same query
        // time, or at any later one when it was all-zero with no pending
        // decay (every write already expired — see
        // [`BandCache::empty_static`]).
        let unchanged = cached
            && !self.dirty
            && match self.last_at {
                Some(last) => last == at_us || (self.empty_static && at_us >= last),
                None => false,
            };
        if !unchanged {
            if cached && self.dirty && self.last_at == Some(at_us) {
                // Same query time: only rows written since the cached
                // render can differ. O(dirty rows) via the watermarks.
                let (lo, hi) = self.dirty_rows.unwrap_or((0, 0));
                array.frame_merged_rows_into(buf, at_us, lo..hi + 1);
            } else {
                array.frame_merged_into_chunks(buf, at_us, self.render_chunks);
            }
            let empty = buf.as_slice().iter().all(|&v| v == 0.0);
            self.empty_static = empty && array.clock_us() <= at_us;
        }
        // Demote once every written cell is strictly past the memory
        // horizon: the band reads zero forever absent new writes, and
        // the position-stable assignment makes a rematerialized array
        // bit-for-bit identical — so freeing the planes is observably
        // free. (`fully_expired_at` is conservative at exactly the
        // horizon, so a band may stay hot one extra snapshot.)
        let demote = self.empty_static && array.fully_expired_at(at_us);
        if demote {
            self.array = None;
        }
        self.last_at = Some(at_us);
        self.dirty = false;
        self.dirty_rows = None;
        BandSnapshot { rendered: !unchanged, empty_static: self.empty_static }
    }

    /// Events written into the band so far (across materializations —
    /// the counter survives demotion).
    pub fn events_written(&self) -> u64 {
        self.processed
    }

    /// Whether the band currently holds a materialized analog array
    /// (false while cold: never written, or demoted after full expiry).
    pub fn is_materialized(&self) -> bool {
        self.array.is_some()
    }

    /// Approximate resident bytes: the struct plus the materialized
    /// band array, if any — a cold band costs only the struct itself,
    /// independent of the sensor resolution.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.array.as_ref().map_or(0, IscArray::approx_bytes)
    }

    /// Export the band's restorable state for a `serve::supervise`
    /// checkpoint: appends every written stamp in **band-local**
    /// coordinates (`plane` 0 = OFF / polarity-insensitive, 1 = ON) and
    /// returns the events-processed counter. A cold band appends
    /// nothing — its state is exactly the counter.
    pub fn export_state(&self, stamps: &mut Vec<(u8, u16, u16, u64)>) -> u64 {
        if let Some(array) = &self.array {
            array.for_each_stamp(|pi, x, y, t| stamps.push((pi as u8, x, y, t)));
        }
        self.processed
    }

    /// Rebuild the band from an [`BandWriter::export_state`] checkpoint:
    /// replay the stamps (sorted ascending by time here, so the clock
    /// and recency planes see a monotone stream) into a freshly
    /// materialized array and restore the processed counter. The
    /// restored writer holds no cached-reply state (`last_at` cleared),
    /// so its first snapshot performs one full render; the rendered
    /// values are bit-for-bit identical to the never-crashed writer at
    /// every causal query time (position-stable parameter assignment +
    /// stamp-complete array state).
    pub fn restore_state(&mut self, processed: u64, stamps: &[(u8, u16, u16, u64)]) {
        self.array = None;
        self.last_at = None;
        self.dirty = false;
        self.dirty_rows = None;
        self.empty_static = false;
        self.processed = processed;
        if stamps.is_empty() {
            return;
        }
        let mut batch: Vec<Event> = stamps
            .iter()
            .map(|&(plane, x, y, t)| {
                let p = if plane == 1 { Polarity::On } else { Polarity::Off };
                Event::new(t, x, y, p)
            })
            .collect();
        batch.sort_unstable_by_key(|e| e.t);
        self.array
            .get_or_insert_with(|| IscArray::new(self.band_res, self.cfg.clone()))
            .write_batch(&batch);
    }
}

/// Post-shutdown statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterStats {
    pub events_routed: u64,
    pub per_shard: Vec<u64>,
    /// Batch messages shipped across all shards (events_routed / batches
    /// is the effective coalescing factor).
    pub batches_shipped: u64,
    /// Frame snapshots served (`frame`/`frame_into` calls).
    pub snapshots_served: u64,
    /// Band renders avoided by the dirty-band protocol: clean bands
    /// composited from the router cache, whether skipped without a shard
    /// round-trip or acknowledged `Unchanged` by the shard.
    pub bands_skipped_unchanged: u64,
}

/// The sharded router.
pub struct Router {
    senders: Vec<Sender<ShardMsg>>,
    handles: Vec<JoinHandle<u64>>,
    res: Resolution,
    band_h: usize,
    batch_size: usize,
    /// Per-shard staging buffers awaiting a full batch.
    staging: Vec<Vec<Event>>,
    /// Per-shard cached band from the previous snapshot (dirty-band
    /// compositing; the buffers cycle shard → router → shard).
    caches: Vec<BandCache>,
    /// Shards that received a write batch since their band was cached.
    shard_dirty: Vec<bool>,
    events_routed: u64,
    batches_shipped: u64,
    snapshots_served: u64,
    bands_skipped_unchanged: u64,
}

impl Router {
    /// Start `cfg.n_shards` band worker threads over `res` (see
    /// [`crate::util::parallel::band_layout`] for the effective count).
    pub fn new(res: Resolution, cfg: RouterConfig) -> Self {
        // Shared band math (`util::parallel::band_layout`): no shard owns
        // zero rows, and the STCF denoise shards cut identical bands.
        let (band_h, n) = crate::util::parallel::band_layout(res.height as usize, cfg.n_shards);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx) = bounded::<ShardMsg>(cfg.queue_depth.max(1));
            let rows = band_h.min(res.height as usize - shard * band_h);
            let band_pixels = res.width as usize * rows;
            let isc_cfg = cfg.isc.clone();
            // All shards render their bands concurrently, so each band's
            // in-shard row parallelism gets its share of the cores —
            // without this cap a snapshot would spawn up to
            // n_shards × available_parallelism transient threads.
            let render_chunks = {
                use crate::util::parallel::{auto_chunks, available_threads};
                auto_chunks(band_pixels).min((available_threads() / n).max(1))
            };
            handles.push(thread::spawn(move || {
                // The band-job core (shared with the serve scheduler,
                // which drives the same struct from pooled workers).
                let mut w = BandWriter::for_band(res, &isc_cfg, band_h, shard, render_chunks);
                for msg in rx {
                    match msg {
                        ShardMsg::WriteBatch(mut batch) => w.apply_batch(&mut batch),
                        ShardMsg::Snapshot { at_us, mut buf, cache_valid, reply } => {
                            let out = w.snapshot_into(&mut buf, at_us, cache_valid);
                            let _ = reply.send(SnapReply {
                                shard,
                                buf,
                                rendered: out.rendered,
                                empty_static: out.empty_static,
                            });
                        }
                        ShardMsg::Stop => break,
                    }
                }
                w.events_written()
            }));
            senders.push(tx);
        }
        Self {
            staging: (0..n).map(|_| Vec::with_capacity(cfg.batch_size.max(1))).collect(),
            caches: (0..n)
                .map(|_| BandCache {
                    buf: Some(Grid::new(1, 1, 0.0)),
                    at_us: 0,
                    valid: false,
                    empty_static: false,
                })
                .collect(),
            shard_dirty: vec![false; n],
            senders,
            handles,
            res,
            band_h,
            batch_size: cfg.batch_size.max(1),
            events_routed: 0,
            batches_shipped: 0,
            snapshots_served: 0,
            bands_skipped_unchanged: 0,
        }
    }

    #[inline]
    fn shard_for(&self, y: u16) -> usize {
        (y as usize / self.band_h).min(self.senders.len() - 1)
    }

    /// Route one event write. The event is staged; a full batch blocks on
    /// the target shard's bounded queue (backpressure propagates to the
    /// producer). Staged events become visible to snapshots at the next
    /// [`Router::flush`] / [`Router::frame`] / [`Router::shutdown`].
    pub fn route(&mut self, e: Event) {
        debug_assert!(self.res.contains(e.x, e.y));
        let s = self.shard_for(e.y);
        self.staging[s].push(e);
        if self.staging[s].len() >= self.batch_size {
            self.flush_shard(s);
        }
        self.events_routed += 1;
    }

    /// Route a time-sorted batch. Consecutive events falling in the same
    /// band are coalesced into one contiguous staging append (sort-free
    /// run coalescing) — event streams are spatially coherent, so runs
    /// are long and the per-event shard lookup mostly disappears.
    pub fn route_batch(&mut self, events: &[Event]) {
        let mut i = 0usize;
        while i < events.len() {
            debug_assert!(self.res.contains(events[i].x, events[i].y));
            let s = self.shard_for(events[i].y);
            let mut j = i + 1;
            while j < events.len() && self.shard_for(events[j].y) == s {
                debug_assert!(self.res.contains(events[j].x, events[j].y));
                j += 1;
            }
            self.staging[s].extend_from_slice(&events[i..j]);
            if self.staging[s].len() >= self.batch_size {
                self.flush_shard(s);
            }
            i = j;
        }
        self.events_routed += events.len() as u64;
    }

    fn flush_shard(&mut self, s: usize) {
        if self.staging[s].is_empty() {
            return;
        }
        let replacement = Vec::with_capacity(self.batch_size);
        let batch = std::mem::replace(&mut self.staging[s], replacement);
        self.senders[s].send(ShardMsg::WriteBatch(batch)).expect("shard died");
        self.batches_shipped += 1;
        // The shard's cached band no longer reflects every routed write.
        self.shard_dirty[s] = true;
    }

    /// Ship all staged events to their shards.
    pub fn flush(&mut self) {
        for s in 0..self.senders.len() {
            self.flush_shard(s);
        }
    }

    /// Scatter-gather a full frame snapshot at `at_us` (allocating
    /// convenience wrapper around [`Router::frame_into`]).
    pub fn frame(&mut self, at_us: u64) -> Grid<f64> {
        let mut g = Grid::new(self.res.width as usize, self.res.height as usize, 0.0);
        self.frame_into(&mut g, at_us);
        g
    }

    /// Scatter-gather a frame snapshot into a caller-owned grid. Staged
    /// writes are flushed first so the snapshot observes every routed
    /// event. Dirty-band protocol: clean bands whose cached render is
    /// provably still exact are composited straight from the router
    /// cache (no shard round-trip); the rest are requested concurrently,
    /// and shards that find themselves clean reply `Unchanged` without
    /// rendering. Band buffers are recycled per shard, so after the
    /// first frame the readout path performs no buffer allocations.
    pub fn frame_into(&mut self, out: &mut Grid<f64>, at_us: u64) {
        self.flush();
        self.snapshots_served += 1;
        let w = self.res.width as usize;
        out.ensure_shape(w, self.res.height as usize, 0.0);
        let n = self.senders.len();
        let (tx, rx) = bounded::<SnapReply>(n);
        let mut in_flight = 0usize;
        for s in 0..n {
            let cache = &mut self.caches[s];
            // Skip the round-trip when the cached band is provably still
            // exact: same query time, or an all-zero band whose every
            // write had already expired (decay is monotone — zero stays
            // zero at any later time absent new writes).
            let skip = cache.valid
                && !self.shard_dirty[s]
                && (cache.at_us == at_us || (cache.empty_static && at_us >= cache.at_us));
            if skip {
                cache.at_us = at_us;
                self.bands_skipped_unchanged += 1;
                continue;
            }
            let buf = cache.buf.take().expect("band buffer in flight");
            let msg =
                ShardMsg::Snapshot { at_us, buf, cache_valid: cache.valid, reply: tx.clone() };
            self.senders[s].send(msg).expect("shard died");
            in_flight += 1;
        }
        drop(tx);
        // Shards render their bands concurrently (row-parallel inside the
        // larger ones); replies land in completion order.
        for r in rx.iter().take(in_flight) {
            if !r.rendered {
                self.bands_skipped_unchanged += 1;
            }
            let cache = &mut self.caches[r.shard];
            cache.buf = Some(r.buf);
            cache.at_us = at_us;
            cache.valid = true;
            cache.empty_static = r.empty_static;
            self.shard_dirty[r.shard] = false;
        }
        // Composite every band — refreshed or cached — into the frame.
        let slice = out.as_mut_slice();
        for (s, cache) in self.caches.iter().enumerate() {
            let band = cache.buf.as_ref().expect("band buffer returned");
            let y0 = s * self.band_h;
            slice[y0 * w..y0 * w + band.len()].copy_from_slice(band.as_slice());
        }
    }

    /// Events routed so far (staged or shipped).
    pub fn events_routed(&self) -> u64 {
        self.events_routed
    }

    /// Frame snapshots served so far.
    pub fn snapshots_served(&self) -> u64 {
        self.snapshots_served
    }

    /// Band renders avoided so far by the dirty-band protocol (cache
    /// skips + shard `Unchanged` replies).
    pub fn bands_skipped_unchanged(&self) -> u64 {
        self.bands_skipped_unchanged
    }

    /// Effective shard count (≤ requested; see `band_layout`).
    pub fn n_shards(&self) -> usize {
        self.senders.len()
    }

    /// Stop all shards and collect statistics.
    pub fn shutdown(mut self) -> RouterStats {
        self.flush();
        for s in &self.senders {
            let _ = s.send(ShardMsg::Stop);
        }
        let per_shard: Vec<u64> =
            self.handles.drain(..).map(|h| h.join().expect("join")).collect();
        RouterStats {
            events_routed: self.events_routed,
            per_shard,
            batches_shipped: self.batches_shipped,
            snapshots_served: self.snapshots_served,
            bands_skipped_unchanged: self.bands_skipped_unchanged,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;
    use crate::util::check::check;

    #[test]
    fn routes_and_counts() {
        let res = Resolution::new(16, 16);
        let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        for y in 0..16u16 {
            r.route(Event::new(1_000 + y as u64, 3, y, Polarity::On));
        }
        assert_eq!(r.events_routed(), 16);
        let stats = r.shutdown();
        assert_eq!(stats.per_shard.iter().sum::<u64>(), 16);
        // Even row spread → even shard loads.
        assert!(stats.per_shard.iter().all(|&c| c == 4), "{:?}", stats.per_shard);
    }

    #[test]
    fn batch_routing_coalesces_messages() {
        let res = Resolution::new(8, 8);
        let mut r = Router::new(
            res,
            RouterConfig { n_shards: 2, batch_size: 4_096, ..RouterConfig::default() },
        );
        // 100 events in two spatially coherent runs → far fewer batches.
        let events: Vec<Event> = (0..100u64)
            .map(|k| Event::new(1 + k, (k % 8) as u16, if k < 50 { 1 } else { 6 }, Polarity::On))
            .collect();
        r.route_batch(&events);
        let stats = r.shutdown();
        assert_eq!(stats.events_routed, 100);
        assert_eq!(stats.per_shard, vec![50, 50]);
        assert!(stats.batches_shipped <= 2, "batches {}", stats.batches_shipped);
    }

    #[test]
    fn route_batch_equals_single_routes() {
        let res = Resolution::new(12, 12);
        let cfg = RouterConfig { n_shards: 3, queue_depth: 16, ..RouterConfig::default() };
        let events: Vec<Event> = (0..60u64)
            .map(|k| Event::new(1_000 + k * 250, (k % 12) as u16, ((k * 5) % 12) as u16,
                                Polarity::On))
            .collect();
        let mut single = Router::new(res, cfg.clone());
        for e in &events {
            single.route(*e);
        }
        let mut batched = Router::new(res, cfg);
        batched.route_batch(&events);
        let fa = single.frame(20_000);
        let fb = batched.frame(20_000);
        assert_eq!(fa, fb);
        single.shutdown();
        batched.shutdown();
    }

    #[test]
    fn frame_matches_unsharded_array() {
        let res = Resolution::new(12, 12);
        let cfg = IscConfig::default();
        let mut router = Router::new(
            res,
            RouterConfig { n_shards: 3, queue_depth: 64, isc: cfg.clone(),
                           ..RouterConfig::default() },
        );
        let mut single = IscArray::new(res, cfg);
        let events: Vec<Event> = (0..40)
            .map(|k| Event::new(1_000 + k * 500, (k % 12) as u16, (k % 12) as u16, Polarity::On))
            .collect();
        router.route_batch(&events);
        single.write_batch(&events);
        let fr = router.frame(25_000);
        let fs = single.frame_merged(25_000);
        // Position-stable mismatch assignment: every band array is an
        // exact window of the full-sensor array, so the composited frame
        // is bit-for-bit the unsharded one — mismatch enabled (the
        // default config) and all.
        assert_eq!(fr, fs);
        router.shutdown();
    }

    #[test]
    fn frames_identical_across_shard_counts_with_mismatch() {
        // The unconditional sharded ≡ serial guarantee: the default
        // (mismatch-enabled) config must produce identical frames for
        // every band layout.
        let res = Resolution::new(12, 10);
        let events: Vec<Event> = (0..80)
            .map(|k| Event::new(1_000 + k * 350, (k % 12) as u16, ((k * 3) % 10) as u16,
                                Polarity::On))
            .collect();
        let mut reference: Option<Grid<f64>> = None;
        for n_shards in [1usize, 3, 4, 10] {
            let mut r = Router::new(res, RouterConfig { n_shards, ..RouterConfig::default() });
            r.route_batch(&events);
            let f = r.frame(40_000);
            if let Some(want) = &reference {
                assert_eq!(&f, want, "n_shards={n_shards}");
            } else {
                reference = Some(f);
            }
            r.shutdown();
        }
    }

    #[test]
    fn uneven_heights_covered() {
        // 10 rows over 4 shards: bands of 3,3,3,1.
        let res = Resolution::new(4, 10);
        let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        for y in 0..10u16 {
            r.route(Event::new(1_000, 0, y, Polarity::On));
        }
        let f = r.frame(1_000);
        for y in 0..10 {
            assert!(*f.get(0, y) > 0.5, "row {y} missing");
        }
        r.shutdown();
    }

    #[test]
    fn frame_into_reuses_buffers() {
        let res = Resolution::new(8, 8);
        let mut r = Router::new(res, RouterConfig { n_shards: 2, ..RouterConfig::default() });
        let mut out = Grid::new(1, 1, 0.0);
        r.frame_into(&mut out, 1_000); // warmup: reshapes + first band bufs
        let ptr = out.as_slice().as_ptr();
        for k in 0..5u64 {
            r.route(Event::new(2_000 + k, (k % 8) as u16, (k % 8) as u16, Polarity::On));
            r.frame_into(&mut out, 3_000 + k);
            assert_eq!(out.as_slice().as_ptr(), ptr, "warm frame_into must not reallocate");
        }
        assert!(out.as_slice().iter().any(|&v| v > 0.0));
        r.shutdown();
    }

    #[test]
    fn snapshot_without_writes_performs_zero_band_renders() {
        let res = Resolution::new(16, 16);
        let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        for y in 0..16u16 {
            r.route(Event::new(1_000 + y as u64, 3, y, Polarity::On));
        }
        let f1 = r.frame(5_000);
        let skips_before = r.bands_skipped_unchanged();
        // Same query time, no intervening writes: every band must be
        // composited from cache with zero shard render work.
        let f2 = r.frame(5_000);
        assert_eq!(f1, f2, "composited snapshot must equal the rendered one");
        assert_eq!(
            r.bands_skipped_unchanged() - skips_before,
            r.n_shards() as u64,
            "all bands clean ⇒ all skipped"
        );
        assert_eq!(r.snapshots_served(), 2);
        let stats = r.shutdown();
        assert_eq!(stats.snapshots_served, 2);
        assert!(stats.bands_skipped_unchanged >= stats.per_shard.len() as u64);
    }

    #[test]
    fn empty_bands_stay_skipped_as_time_advances() {
        // Activity confined to one band: after the first snapshot the
        // untouched bands are provably all-zero at every later time and
        // must never cost a shard round-trip again.
        let res = Resolution::new(8, 8);
        let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        r.route(Event::new(1_000, 2, 0, Polarity::On)); // band 0 only
        r.frame(2_000);
        let skips0 = r.bands_skipped_unchanged();
        r.frame(30_000);
        // Bands 1..3 are empty-static; band 0 re-renders (decay advanced).
        assert_eq!(r.bands_skipped_unchanged() - skips0, 3);
        r.shutdown();
    }

    #[test]
    fn cold_bands_hold_no_array_and_demote_after_expiry() {
        let res = Resolution::new(8, 8);
        let cfg = IscConfig::default();
        // Band 1 of a band_h=2 partition: global rows 2..4.
        let mut w = BandWriter::for_band(res, &cfg, 2, 1, 1);
        assert!(!w.is_materialized(), "fresh band must be cold");
        let cold_bytes = w.approx_bytes();
        assert_eq!(cold_bytes, std::mem::size_of::<BandWriter>());

        // Snapshot of a never-written band: zeros, no materialization.
        let mut buf = Grid::new(1, 1, 0.0);
        let s = w.snapshot_into(&mut buf, 1_000, false);
        assert!(s.empty_static);
        assert!(buf.as_slice().iter().all(|&v| v == 0.0));
        assert!(!w.is_materialized(), "snapshot must not materialize");
        // Composited from cache from now on: zero work, not rendered.
        let s = w.snapshot_into(&mut buf, 2_000, true);
        assert!(!s.rendered);

        // First write materializes; the frame shows it.
        let mut batch = vec![Event::new(2_000, 1, 2, Polarity::On)];
        w.apply_batch(&mut batch);
        assert!(w.is_materialized());
        assert!(w.approx_bytes() > cold_bytes);
        let s = w.snapshot_into(&mut buf, 2_000, true);
        assert!(s.rendered && !s.empty_static);
        assert!(buf.as_slice().iter().any(|&v| v > 0.0));

        // Far past the memory horizon the frame empties and the band
        // demotes back to cold — resident bytes decay to the constant.
        let s = w.snapshot_into(&mut buf, 2_000 + 10_000_000, true);
        assert!(s.rendered && s.empty_static);
        assert!(!w.is_materialized(), "expired band must demote");
        assert_eq!(w.approx_bytes(), cold_bytes);
        assert_eq!(w.events_written(), 1, "counter survives demotion");

        // Rematerialize on the next write: frames stay exact (the
        // round-trip equivalence proper lives in tests/sparse_equiv.rs).
        let mut batch = vec![Event::new(20_000_000, 3, 3, Polarity::On)];
        w.apply_batch(&mut batch);
        assert!(w.is_materialized());
        let s = w.snapshot_into(&mut buf, 20_000_000, true);
        assert!(s.rendered);
        assert!(buf.as_slice().iter().any(|&v| v > 0.0));
    }

    #[test]
    fn dirty_band_composite_equals_full_rerender_across_interleavings() {
        let res = Resolution::new(12, 12);
        let cfg = RouterConfig {
            n_shards: 3,
            queue_depth: 16,
            isc: IscConfig { bank_size: 32, ..IscConfig::default() },
            ..RouterConfig::default()
        };
        // Spatially clustered bursts (8 events per row, rows 0/3/6/9/…)
        // so individual chunks leave some bands untouched — the skip,
        // re-render and composite-from-cache paths all get exercised.
        let events: Vec<Event> = (0..90u64)
            .map(|k| {
                let y = ((k / 8) * 3 % 12) as u16;
                Event::new(1_000 + k * 400, (k % 12) as u16, y, Polarity::On)
            })
            .collect();
        let mut incremental = Router::new(res, cfg.clone());
        for (i, chunk) in events.chunks(15).enumerate() {
            incremental.route_batch(chunk);
            // Causal, non-decreasing snapshot times.
            let at = chunk.last().unwrap().t + 200 * (i as u64 % 3);
            let composited = incremental.frame(at);
            // Reference: a fresh identically-configured router replaying
            // the same prefix renders everything from scratch.
            let mut fresh = Router::new(res, cfg.clone());
            fresh.route_batch(&events[..(i + 1) * 15]);
            assert_eq!(composited, fresh.frame(at), "step {i}");
            fresh.shutdown();
        }
        incremental.shutdown();
    }

    #[test]
    fn same_time_dirty_rows_rerender_partially_and_exactly() {
        let res = Resolution::new(8, 8);
        let cfg = RouterConfig { n_shards: 2, ..RouterConfig::default() };
        let mut r = Router::new(res, cfg.clone());
        let warm: Vec<Event> = (0..20u64)
            .map(|k| Event::new(1_000 + k * 100, (k % 8) as u16, (k % 8) as u16, Polarity::On))
            .collect();
        r.route_batch(&warm);
        let at = 10_000u64;
        let f1 = r.frame(at);
        // New causal writes into one band, snapshot at the SAME time:
        // the shard takes the dirty-row-watermark partial render path.
        let dirty: Vec<Event> = (0..6u64)
            .map(|k| Event::new(5_000 + k, k as u16, 1, Polarity::On))
            .collect();
        r.route_batch(&dirty);
        let f2 = r.frame(at);
        let mut fresh = Router::new(res, cfg);
        fresh.route_batch(&warm);
        fresh.route_batch(&dirty);
        assert_eq!(f2, fresh.frame(at), "partial re-render must equal a full one");
        assert_ne!(f1, f2, "the dirty writes must be visible");
        fresh.shutdown();
        r.shutdown();
    }

    #[test]
    fn prop_router_preserves_event_count() {
        check("router count conservation", 20, |g| {
            let res = Resolution::new(8, 8);
            let n_shards = g.usize(1, 6);
            let batch_size = g.usize(1, 32);
            let mut r = Router::new(
                res,
                RouterConfig { n_shards, queue_depth: 16, batch_size,
                               ..RouterConfig::default() },
            );
            let n = g.usize(0, 100);
            let mut t = 0u64;
            for _ in 0..n {
                t += g.u64(1, 100);
                r.route(Event::new(
                    t,
                    g.u64(0, 7) as u16,
                    g.u64(0, 7) as u16,
                    Polarity::On,
                ));
            }
            let stats = r.shutdown();
            assert_eq!(stats.events_routed, n as u64);
            assert_eq!(stats.per_shard.iter().sum::<u64>(), n as u64);
        });
    }
}
