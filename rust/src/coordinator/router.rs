//! Sharded event router: the L3 coordination core.
//!
//! The ISC plane is partitioned into horizontal bands, each owned by a
//! worker thread with its own analog-array state (mirroring how a tiled
//! hardware readout partitions the sensor). The router dispatches writes
//! by row, applies backpressure through bounded queues, and performs
//! scatter-gather frame snapshots. std::thread + sync_channel (tokio is
//! not available offline; bounded mpsc gives the same backpressure
//! semantics deterministically).

use crate::events::{Event, Resolution};
use crate::isc::{IscArray, IscConfig};
use crate::util::grid::Grid;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Worker shards (horizontal bands).
    pub n_shards: usize,
    /// Bounded queue depth per shard — the backpressure knob.
    pub queue_depth: usize,
    /// Array config cloned per shard (seeds are derived per shard).
    pub isc: IscConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { n_shards: 4, queue_depth: 4_096, isc: IscConfig::default() }
    }
}

enum ShardMsg {
    Write(Event),
    Snapshot { at_us: u64, reply: SyncSender<(usize, Vec<f64>)> },
    Stop,
}

/// Post-shutdown statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterStats {
    pub events_routed: u64,
    pub per_shard: Vec<u64>,
}

/// The sharded router.
pub struct Router {
    senders: Vec<SyncSender<ShardMsg>>,
    handles: Vec<JoinHandle<u64>>,
    res: Resolution,
    band_h: usize,
    events_routed: u64,
}

impl Router {
    pub fn new(res: Resolution, cfg: RouterConfig) -> Self {
        let requested = cfg.n_shards.max(1).min(res.height as usize);
        let band_h = (res.height as usize).div_ceil(requested);
        // Recompute the effective shard count so no shard owns zero rows
        // (e.g. 8 rows over 6 requested shards → bands of 2 → 4 shards).
        let n = (res.height as usize).div_ceil(band_h);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx): (SyncSender<ShardMsg>, Receiver<ShardMsg>) =
                sync_channel(cfg.queue_depth);
            let rows = band_h.min(res.height as usize - shard * band_h);
            let band_res = Resolution::new(res.width, rows as u16);
            let mut isc_cfg = cfg.isc.clone();
            isc_cfg.seed = isc_cfg.seed.wrapping_add(shard as u64 * 0x9e37_79b9);
            let y0 = (shard * band_h) as u16;
            handles.push(std::thread::spawn(move || {
                let mut array = IscArray::new(band_res, isc_cfg);
                let mut processed = 0u64;
                for msg in rx {
                    match msg {
                        ShardMsg::Write(mut e) => {
                            e.y -= y0;
                            array.write(&e);
                            processed += 1;
                        }
                        ShardMsg::Snapshot { at_us, reply } => {
                            let frame = array.frame_merged(at_us);
                            let _ = reply.send((y0 as usize, frame.as_slice().to_vec()));
                        }
                        ShardMsg::Stop => break,
                    }
                }
                processed
            }));
            senders.push(tx);
        }
        Self { senders, handles, res, band_h, events_routed: 0 }
    }

    #[inline]
    fn shard_for(&self, y: u16) -> usize {
        (y as usize / self.band_h).min(self.senders.len() - 1)
    }

    /// Route one event write. Blocks when the target shard's queue is full
    /// (backpressure propagates to the producer).
    pub fn route(&mut self, e: Event) {
        debug_assert!(self.res.contains(e.x, e.y));
        let s = self.shard_for(e.y);
        self.senders[s].send(ShardMsg::Write(e)).expect("shard died");
        self.events_routed += 1;
    }

    /// Scatter-gather a full frame snapshot at `at_us`.
    pub fn frame(&self, at_us: u64) -> Grid<f64> {
        let (tx, rx) = sync_channel(self.senders.len());
        for s in &self.senders {
            s.send(ShardMsg::Snapshot { at_us, reply: tx.clone() })
                .expect("shard died");
        }
        drop(tx);
        let w = self.res.width as usize;
        let h = self.res.height as usize;
        let mut out = vec![0.0f64; w * h];
        for (y0, band) in rx.iter().take(self.senders.len()) {
            let rows = band.len() / w;
            out[y0 * w..(y0 + rows) * w].copy_from_slice(&band);
        }
        Grid::from_vec(w, h, out)
    }

    pub fn events_routed(&self) -> u64 {
        self.events_routed
    }

    pub fn n_shards(&self) -> usize {
        self.senders.len()
    }

    /// Stop all shards and collect statistics.
    pub fn shutdown(self) -> RouterStats {
        for s in &self.senders {
            let _ = s.send(ShardMsg::Stop);
        }
        let per_shard: Vec<u64> =
            self.handles.into_iter().map(|h| h.join().expect("join")).collect();
        RouterStats { events_routed: self.events_routed, per_shard }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;
    use crate::util::check::check;

    #[test]
    fn routes_and_counts() {
        let res = Resolution::new(16, 16);
        let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        for y in 0..16u16 {
            r.route(Event::new(1_000 + y as u64, 3, y, Polarity::On));
        }
        assert_eq!(r.events_routed(), 16);
        let stats = r.shutdown();
        assert_eq!(stats.per_shard.iter().sum::<u64>(), 16);
        // Even row spread → even shard loads.
        assert!(stats.per_shard.iter().all(|&c| c == 4), "{:?}", stats.per_shard);
    }

    #[test]
    fn frame_matches_unsharded_array() {
        let res = Resolution::new(12, 12);
        let cfg = IscConfig::default();
        let mut router = Router::new(
            res,
            RouterConfig { n_shards: 3, queue_depth: 64, isc: cfg.clone() },
        );
        let mut single = IscArray::new(res, cfg);
        let events: Vec<Event> = (0..40)
            .map(|k| Event::new(1_000 + k * 500, (k % 12) as u16, (k % 12) as u16, Polarity::On))
            .collect();
        for e in &events {
            router.route(*e);
            single.write(e);
        }
        let fr = router.frame(25_000);
        let fs = single.frame_merged(25_000);
        // Same write pattern, same nominal bank ⇒ same brightness ordering;
        // mismatch maps differ per shard seed, so compare written-pixel sets
        // and value proximity.
        for (x, y, &v) in fr.iter_coords() {
            let vs = *fs.get(x, y);
            assert_eq!(v > 0.0, vs > 0.0, "write-set mismatch at ({x},{y})");
            if v > 0.0 {
                assert!((v - vs).abs() < 0.05, "({x},{y}): {v} vs {vs}");
            }
        }
        router.shutdown();
    }

    #[test]
    fn uneven_heights_covered() {
        // 10 rows over 4 shards: bands of 3,3,3,1.
        let res = Resolution::new(4, 10);
        let mut r = Router::new(res, RouterConfig { n_shards: 4, ..RouterConfig::default() });
        for y in 0..10u16 {
            r.route(Event::new(1_000, 0, y, Polarity::On));
        }
        let f = r.frame(1_000);
        for y in 0..10 {
            assert!(*f.get(0, y) > 0.5, "row {y} missing");
        }
        r.shutdown();
    }

    #[test]
    fn prop_router_preserves_event_count() {
        check("router count conservation", 20, |g| {
            let res = Resolution::new(8, 8);
            let n_shards = g.usize(1, 6);
            let mut r = Router::new(
                res,
                RouterConfig { n_shards, queue_depth: 16, ..RouterConfig::default() },
            );
            let n = g.usize(0, 100);
            let mut t = 0u64;
            for _ in 0..n {
                t += g.u64(1, 100);
                r.route(Event::new(
                    t,
                    g.u64(0, 7) as u16,
                    g.u64(0, 7) as u16,
                    Polarity::On,
                ));
            }
            let stats = r.shutdown();
            assert_eq!(stats.events_routed, n as u64);
            assert_eq!(stats.per_shard.iter().sum::<u64>(), n as u64);
        });
    }
}
