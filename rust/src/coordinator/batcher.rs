//! Microbatcher: groups the sorted event stream into fixed-Δt batches.
//!
//! The kernel-backed plane (`runtime::KernelTs`) advances once per
//! microbatch (decay is elementwise over the plane), so the batcher is
//! what turns a 100 Meps-class stream into a bounded number of kernel
//! launches. Native-array consumers use it too for scheduling regularity.

use crate::events::LabeledEvent;

/// A closed microbatch covering (t_start, t_end].
#[derive(Clone, Debug)]
pub struct MicroBatch {
    pub t_start_us: u64,
    pub t_end_us: u64,
    pub events: Vec<LabeledEvent>,
}

/// Fixed-interval batcher. Feed sorted events; closed batches pop out.
pub struct MicroBatcher {
    dt_us: u64,
    t_next: u64,
    current: Vec<LabeledEvent>,
    batches_emitted: u64,
    events_in: u64,
}

impl MicroBatcher {
    /// `dt_us` — microbatch duration (e.g. 1 000 µs).
    pub fn new(dt_us: u64) -> Self {
        assert!(dt_us > 0);
        Self { dt_us, t_next: dt_us, current: Vec::new(), batches_emitted: 0, events_in: 0 }
    }

    /// Push one event (must be ≥ all previous events' timestamps). Returns
    /// the batches closed by this event's arrival (possibly several empty
    /// ones if the stream had a gap — the plane still needs decay steps).
    pub fn push(&mut self, e: LabeledEvent) -> Vec<MicroBatch> {
        self.events_in += 1;
        let mut closed = Vec::new();
        while e.ev.t > self.t_next {
            closed.push(self.close_current());
        }
        self.current.push(e);
        closed
    }

    /// Flush: close all batches up to and including `t_end_us`.
    pub fn flush(&mut self, t_end_us: u64) -> Vec<MicroBatch> {
        let mut closed = Vec::new();
        while self.t_next <= t_end_us {
            closed.push(self.close_current());
        }
        if !self.current.is_empty() {
            closed.push(self.close_current());
        }
        closed
    }

    fn close_current(&mut self) -> MicroBatch {
        let b = MicroBatch {
            t_start_us: self.t_next - self.dt_us,
            t_end_us: self.t_next,
            events: std::mem::take(&mut self.current),
        };
        self.t_next += self.dt_us;
        self.batches_emitted += 1;
        b
    }

    /// Closed batches emitted so far.
    pub fn batches_emitted(&self) -> u64 {
        self.batches_emitted
    }

    /// Events pushed so far.
    pub fn events_in(&self) -> u64 {
        self.events_in
    }
}

/// Streaming adapter: turn any sorted labeled-event source into an
/// iterator of closed microbatches covering (0, t_end_us]. Only one open
/// batch is buffered at a time, so an arbitrarily long replay/generator
/// stream is batched in O(batch) memory.
pub struct Batches<I: Iterator<Item = LabeledEvent>> {
    inner: I,
    batcher: MicroBatcher,
    t_end_us: u64,
    ready: std::collections::VecDeque<MicroBatch>,
    flushed: bool,
}

/// Batch `events` (sorted) into `dt_us` microbatches covering
/// (0, t_end_us]; see [`Batches`].
pub fn batches<I>(events: I, dt_us: u64, t_end_us: u64) -> Batches<I::IntoIter>
where
    I: IntoIterator<Item = LabeledEvent>,
{
    Batches {
        inner: events.into_iter(),
        batcher: MicroBatcher::new(dt_us),
        t_end_us,
        ready: std::collections::VecDeque::new(),
        flushed: false,
    }
}

impl<I: Iterator<Item = LabeledEvent>> Iterator for Batches<I> {
    type Item = MicroBatch;

    fn next(&mut self) -> Option<MicroBatch> {
        loop {
            if let Some(b) = self.ready.pop_front() {
                return Some(b);
            }
            if self.flushed {
                return None;
            }
            match self.inner.next() {
                Some(le) => self.ready.extend(self.batcher.push(le)),
                None => {
                    self.ready.extend(self.batcher.flush(self.t_end_us));
                    self.flushed = true;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::event::{Event, Polarity};
    use crate::util::check::check;

    fn le(t: u64) -> LabeledEvent {
        LabeledEvent { ev: Event::new(t, 0, 0, Polarity::On), is_signal: true }
    }

    #[test]
    fn batches_partition_stream() {
        let mut b = MicroBatcher::new(1_000);
        let mut out = Vec::new();
        for &t in &[100, 900, 1_500, 4_200] {
            out.extend(b.push(le(t)));
        }
        out.extend(b.flush(5_000));
        // Batches: (0,1000]={100,900}, (1000,2000]={1500}, (2000,3000]={},
        // (3000,4000]={}, (4000,5000]={4200}
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].events.len(), 2);
        assert_eq!(out[1].events.len(), 1);
        assert!(out[2].events.is_empty());
        assert!(out[3].events.is_empty());
        assert_eq!(out[4].events.len(), 1);
        let total: usize = out.iter().map(|x| x.events.len()).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn gap_produces_empty_batches() {
        let mut b = MicroBatcher::new(1_000);
        let closed = b.push(le(10_500));
        assert_eq!(closed.len(), 10);
        assert!(closed.iter().all(|c| c.events.is_empty()));
    }

    #[test]
    fn batch_boundaries_are_half_open() {
        let mut b = MicroBatcher::new(1_000);
        // t = 1000 belongs to the first batch (t_start, t_end].
        let closed = b.push(le(1_000));
        assert!(closed.is_empty());
        let all = b.flush(1_000);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].events.len(), 1);
    }

    #[test]
    fn streaming_batches_match_push_flush() {
        let times = [100u64, 900, 1_500, 4_200];
        let evs: Vec<LabeledEvent> = times.iter().map(|&t| le(t)).collect();
        let streamed: Vec<MicroBatch> = batches(evs.iter().copied(), 1_000, 5_000).collect();
        let mut b = MicroBatcher::new(1_000);
        let mut pushed = Vec::new();
        for &t in &times {
            pushed.extend(b.push(le(t)));
        }
        pushed.extend(b.flush(5_000));
        assert_eq!(streamed.len(), pushed.len());
        for (s, p) in streamed.iter().zip(&pushed) {
            assert_eq!(s.t_start_us, p.t_start_us);
            assert_eq!(s.t_end_us, p.t_end_us);
            assert_eq!(s.events.len(), p.events.len());
        }
    }

    #[test]
    fn streaming_batches_from_generator() {
        // A lazy source: no Vec behind the iterator.
        let n = 50u64;
        let out: Vec<MicroBatch> =
            batches((0..n).map(|k| le(1 + k * 100)), 1_000, 5_000).collect();
        let total: usize = out.iter().map(|b| b.events.len()).sum();
        assert_eq!(total, n as usize);
        assert!(out.windows(2).all(|w| w[0].t_end_us == w[1].t_start_us));
    }

    #[test]
    fn prop_no_events_lost_or_reordered() {
        check("batcher conservation", 100, |g| {
            let dt = g.u64(10, 5_000);
            let mut b = MicroBatcher::new(dt);
            let n = g.usize(0, 200);
            let mut t = 0u64;
            let mut times = Vec::new();
            let mut out = Vec::new();
            for _ in 0..n {
                t += g.u64(0, 3_000);
                times.push(t);
                out.extend(b.push(le(t)));
            }
            out.extend(b.flush(t + dt));
            // Every event lands in exactly one batch, in order, and within
            // the batch's bounds.
            let recovered: Vec<u64> = out
                .iter()
                .flat_map(|mb| mb.events.iter().map(|e| e.ev.t))
                .collect();
            assert_eq!(recovered, times);
            for mb in &out {
                for e in &mb.events {
                    assert!(e.ev.t > mb.t_start_us || e.ev.t == 0 || mb.t_start_us == 0);
                    assert!(e.ev.t <= mb.t_end_us);
                }
            }
        });
    }
}
