//! Minimal command-line parsing (offline substitute for `clap`).
//!
//! Supports `program <subcommand> [positional...] [--flag] [--key value]
//! [--key=value]`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, Option<String>>,
}

/// Is `s` a flag token (as opposed to a value)? `--anything` is a flag;
/// a single-dash token is a flag unless it is a negative number
/// (`-0.5`, `-12`, `-5e3`, `-inf`), so `--bias -0.5` parses as
/// key/value while `--a -v` leaves `a` valueless.
fn is_flag_token(s: &str) -> bool {
    if s.starts_with("--") {
        return true;
    }
    match s.strip_prefix('-') {
        // Bare "-" is a conventional stdin placeholder, not a flag.
        Some("") | None => false,
        // f64 parsing accepts every numeric form we hand out via
        // `get_parsed` (ints, floats, exponents, ±inf/NaN).
        Some(_) => s.parse::<f64>().is_err(),
    }
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key=value` binds inline (the value may itself start
                // with a dash or contain further `=`s); otherwise
                // `--key value` unless the next token is another flag/end.
                if let Some((key, value)) = name.split_once('=') {
                    out.flags.insert(key.to_string(), Some(value.to_string()));
                    continue;
                }
                let value = match iter.peek() {
                    Some(v) if !is_flag_token(v) => Some(iter.next().unwrap()),
                    _ => None,
                };
                out.flags.insert(name.to_string(), value);
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// From std::env.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Boolean flag presence.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// String value of `--name value`.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.as_deref())
    }

    /// Typed value with default.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("exp fig7 extra");
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig7", "extra"]);
    }

    #[test]
    fn flags_with_and_without_values() {
        let a = parse("run --full --steps 200 --name foo");
        assert!(a.flag("full"));
        assert_eq!(a.get("steps"), Some("200"));
        assert_eq!(a.get_parsed("steps", 0usize), 200);
        assert_eq!(a.get("name"), Some("foo"));
        assert!(!a.flag("absent"));
        assert_eq!(a.get_parsed("absent", 7u32), 7);
    }

    #[test]
    fn flag_followed_by_flag_has_no_value() {
        let a = parse("x --a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("a"), None);
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse("run --bias -0.5 --offset -12 --frac -.25");
        assert_eq!(a.get("bias"), Some("-0.5"));
        assert_eq!(a.get_parsed("bias", 0.0f64), -0.5);
        assert_eq!(a.get_parsed("offset", 0i64), -12);
        assert_eq!(a.get_parsed("frac", 0.0f64), -0.25);
    }

    #[test]
    fn exponent_and_special_float_values_bind() {
        let a = parse("run --rate -5e3 --floor -inf");
        assert_eq!(a.get_parsed("rate", 0.0f64), -5e3);
        assert_eq!(a.get_parsed("floor", 0.0f64), f64::NEG_INFINITY);
    }

    #[test]
    fn short_flag_like_token_is_not_swallowed_as_value() {
        // "-v" is not a number, so "--a -v" must not bind it to a; it is
        // parsed as a (future) short flag would be — i.e. a is valueless.
        let a = parse("x --a -v");
        assert!(a.flag("a"));
        assert_eq!(a.get("a"), None);
    }

    #[test]
    fn bare_dash_is_a_value() {
        let a = parse("x --input -");
        assert_eq!(a.get("input"), Some("-"));
    }

    #[test]
    fn equals_form_binds_inline() {
        let a = parse("serve --listen=127.0.0.1:7400 --workers=8 --bias=-0.5 --empty= --x -v");
        assert_eq!(a.get("listen"), Some("127.0.0.1:7400"));
        assert_eq!(a.get_parsed("workers", 0usize), 8);
        // Dash-leading and empty values bind too — `=` is unambiguous.
        assert_eq!(a.get_parsed("bias", 0.0f64), -0.5);
        assert_eq!(a.get("empty"), Some(""));
        // Only the first `=` splits; the rest stays in the value.
        let b = parse("x --kv=a=b");
        assert_eq!(b.get("kv"), Some("a=b"));
        // The equals form never swallows the next token.
        assert!(a.flag("x"));
        assert_eq!(a.get("x"), None);
    }
}
