//! `tsisc` — the 3DS-ISC coordinator binary.
//!
//! Subcommands:
//!   exp <id|all> [--full]     regenerate a paper table/figure (DESIGN.md §3)
//!   pipeline [--events N]     run the event→frame serving pipeline and
//!                             print throughput/latency stats
//!   serve [--sessions M]      replay M independent camera streams through
//!                             the multi-tenant session layer and print the
//!                             fleet summary
//!   serve --listen ADDR       run the TCP front door over the fleet and
//!                             print the net summary on shutdown
//!   camera --connect ADDR     stream one synthetic camera over TCP to a
//!                             running `serve --listen` front door
//!   top --connect ADDR        scrape a running front door's telemetry
//!                             (wire STATS) and print the fleet summary
//!   train [--family F]        train the classifier on a synthetic dataset
//!                             through the AOT artifacts (needs `make artifacts`)
//!   info                      runtime/platform diagnostics

use tsisc::cli::Args;
use tsisc::experiments::{self, Effort};

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("exp") => cmd_exp(&args),
        Some("pipeline") => cmd_pipeline(&args),
        Some("serve") => cmd_serve(&args),
        Some("camera") => cmd_camera(&args),
        Some("top") => cmd_top(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(),
        _ => {
            eprint!("{}", USAGE);
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
tsisc — 3D Stack In-Sensor-Computing reproduction

USAGE:
  tsisc exp <id|all> [--full]    regenerate a paper table/figure
                                 ids: table1 fig2d fig4 fig5 fig6 fig7 fig8
                                      fig9 fig10 fig12 sec2b table2 table3
  tsisc pipeline [--duration S] [--stcf] [--shards K] [--denoise-shards K]
                 [--batch-size N]
  tsisc serve [--sessions M] [--duration S] [--workers N] [--stcf]
              [--shards K] [--denoise-shards K] [--batch-size N]
              [--max-inflight B] [--chunk N]
  tsisc serve --listen HOST:PORT [--duration S] [--workers N]
              [--max-sessions M] [--max-connections C] [--max-inflight B]
              [--read-timeout-ms T] [--idle-timeout-ms T] [--error-budget N]
              [--metrics HOST:PORT] [--json-stats PATH] [--json-every S]
  tsisc top --connect HOST:PORT [--raw]
  tsisc camera --connect HOST:PORT [--duration S] [--width W] [--height H]
               [--window-ms T] [--stcf] [--shards K] [--denoise-shards K]
               [--batch-size N] [--chunk N] [--name S] [--seed N]
  tsisc train [--family nmnist|shapes|cifardvs|gesture] [--steps N]
              [--surface isc|ideal|count|ebbi] [--per-class N]
  tsisc info
";

fn effort(args: &Args) -> Effort {
    if args.flag("full") {
        Effort::Full
    } else {
        Effort::Quick
    }
}

fn cmd_exp(args: &Args) -> i32 {
    let Some(id) = args.positional.first() else {
        eprintln!("exp: missing id (or 'all')");
        return 2;
    };
    let eff = effort(args);
    if id == "all" {
        for (name, f) in experiments::ALL {
            eprintln!("[running {name}...]");
            print!("{}", f(eff));
        }
        return 0;
    }
    match experiments::find(id) {
        Some(f) => {
            print!("{}", f(eff));
            0
        }
        None => {
            eprintln!("unknown experiment '{id}'");
            2
        }
    }
}

fn cmd_pipeline(args: &Args) -> i32 {
    use tsisc::coordinator::{run_pipeline, PipelineConfig, RouterConfig};
    use tsisc::denoise::StcfParams;
    use tsisc::events::{noise::contaminate, scene::EdgeScene, v2e, Resolution};

    let res = Resolution::QVGA;
    let dur = args.get_parsed("duration", 0.5f64);
    let shards = args.get_parsed("shards", 4usize);
    let denoise_shards = args.get_parsed("denoise-shards", 4usize);
    eprintln!("generating driving-like stream at QVGA for {dur} s ...");
    let scene = EdgeScene::new(120.0, 21);
    let signal = v2e::convert(&scene, res, v2e::DvsParams::default(), dur);
    let events = contaminate(&signal, res, 5.0, dur, 17);
    eprintln!("{} events ({} signal)", events.len(), signal.len());

    let cfg = PipelineConfig {
        stcf: if args.flag("stcf") { Some(StcfParams::default()) } else { None },
        denoise_shards,
        batch_size: args.get_parsed("batch-size", 4_096usize),
        router: RouterConfig { n_shards: shards, ..RouterConfig::default() },
        ..PipelineConfig::default()
    };
    let run = run_pipeline(events.iter().copied(), res, (dur * 1e6) as u64, &cfg);
    let st = &run.stats;
    println!(
        "pipeline: {} events in, {} written, {} dropped by STCF\n\
         frames: {} ({} ms windows)\n\
         snapshots: {} served, {} band renders skipped (dirty-band protocol)\n\
         stage wall: denoise {:.3} s, route {:.3} s, snapshot {:.3} s\n\
         wall: {:.3} s  throughput: {:.2} Meps  shards: {:?}",
        st.events_in,
        st.events_written,
        st.events_dropped_by_stcf,
        st.frames_emitted,
        cfg.window_us / 1000,
        st.router.snapshots_served,
        st.router.bands_skipped_unchanged,
        st.stage_wall.denoise_seconds,
        st.stage_wall.route_seconds,
        st.stage_wall.snapshot_seconds,
        st.wall_seconds,
        st.events_per_second / 1e6,
        st.router.per_shard,
    );
    if let Some(dn) = &st.denoise {
        let kept: Vec<u64> = dn.per_shard.iter().map(|t| t.kept).collect();
        let dropped: Vec<u64> = dn.per_shard.iter().map(|t| t.dropped).collect();
        let halo: u64 = dn.per_shard.iter().map(|t| t.halo_ingests).sum();
        println!(
            "denoise: {} kept {kept:?}, dropped {dropped:?}, {halo} halo ingests",
            if dn.inline_scoring { "inline," } else { "sharded," },
        );
    }
    0
}

/// Replay M independent camera streams (mixed scenes, resolutions and
/// playback rates) concurrently through the multi-tenant session layer
/// and print the fleet summary. With `--listen ADDR` the streams come
/// over TCP instead (see [`cmd_serve_listen`]).
fn cmd_serve(args: &Args) -> i32 {
    if let Some(addr) = args.get("listen") {
        return cmd_serve_listen(addr, args);
    }
    use tsisc::coordinator::{PipelineConfig, RouterConfig};
    use tsisc::denoise::StcfParams;
    use tsisc::events::noise::contaminate;
    use tsisc::events::replay::{interleave, scale_time, StreamSpec};
    use tsisc::events::scene::{BlobScene, EdgeScene, Scene, TextureMotion, TextureScene};
    use tsisc::events::{v2e, LabeledEvent, Resolution};
    use tsisc::serve::{Reject, ServeConfig, SessionConfig, SessionManager};

    let n_sessions = args.get_parsed("sessions", 4usize).max(1);
    let dur = args.get_parsed("duration", 0.3f64);
    let chunk = args.get_parsed("chunk", 2_048usize).max(1);
    let serve_cfg = ServeConfig {
        workers: args.get_parsed("workers", ServeConfig::default().workers),
        max_sessions: n_sessions.max(ServeConfig::default().max_sessions),
        max_inflight_batches: args.get_parsed("max-inflight", 64usize),
        ..ServeConfig::default()
    };

    // Mixed fleet workload: per session a different scene family,
    // resolution and playback rate.
    eprintln!("generating {n_sessions} streams ({dur} s each) ...");
    let streams: Vec<StreamSpec> = (0..n_sessions)
        .map(|k| {
            let seed = 21 + k as u64;
            let (res, name, scene): (Resolution, String, Box<dyn Scene>) = match k % 3 {
                0 => (
                    Resolution::new(160, 120),
                    format!("driving-{k}"),
                    Box::new(EdgeScene::new(120.0, seed)),
                ),
                1 => (
                    Resolution::new(128, 96),
                    format!("hotelbar-{k}"),
                    Box::new(BlobScene::new(128, 96, 3, dur, seed)),
                ),
                _ => (
                    Resolution::new(96, 96),
                    format!("texture-{k}"),
                    Box::new(TextureScene::new(
                        96,
                        96,
                        TextureMotion::Mixed { vx: 40.0, vy: 10.0, omega: 0.6 },
                        seed,
                    )),
                ),
            };
            let signal = v2e::convert(scene.as_ref(), res, v2e::DvsParams::default(), dur);
            let events = contaminate(&signal, res, 5.0, dur, seed ^ 0x5e);
            let rate = [1.0, 2.0, 0.5][k % 3];
            StreamSpec { name, res, events, rate }
        })
        .collect();
    let total_events: usize = streams.iter().map(|s| s.events.len()).sum();
    eprintln!("{total_events} events across {n_sessions} streams");

    let mut manager = SessionManager::new(serve_cfg);
    let mut sids = Vec::with_capacity(n_sessions);
    for spec in &streams {
        let cfg = SessionConfig {
            name: spec.name.clone(),
            res: spec.res,
            t_end_us: scale_time((dur * 1e6) as u64, spec.rate),
            pipeline: PipelineConfig {
                stcf: args.flag("stcf").then(StcfParams::default),
                denoise_shards: args.get_parsed("denoise-shards", 4usize),
                batch_size: args.get_parsed("batch-size", 4_096usize),
                router: RouterConfig {
                    n_shards: args.get_parsed("shards", 4usize),
                    ..RouterConfig::default()
                },
                ..PipelineConfig::default()
            },
        };
        sids.push(manager.open(cfg).expect("open session"));
    }

    // One interleaved multi-camera feed, chunked per stream.
    let start = std::time::Instant::now();
    let mut buffers: Vec<Vec<LabeledEvent>> = vec![Vec::with_capacity(chunk); n_sessions];
    let mut frames = vec![0usize; n_sessions];
    let mut dropped_by_backpressure = 0u64;
    // Ship one stream's buffered chunk; returns (frames emitted, events
    // dropped by admission control).
    let feed = |manager: &mut SessionManager,
                sid: tsisc::serve::SessionId,
                buf: &mut Vec<LabeledEvent>|
     -> (usize, u64) {
        let out = match manager.ingest_batch(sid, buf) {
            Ok(fs) => (fs.len(), 0),
            Err(Reject::Backpressure { .. }) => (0, buf.len() as u64),
            Err(e) => panic!("ingest: {e}"),
        };
        buf.clear();
        out
    };
    for te in interleave(&streams) {
        buffers[te.stream].push(te.le);
        if buffers[te.stream].len() >= chunk {
            let mut buf = std::mem::take(&mut buffers[te.stream]);
            let (f, d) = feed(&mut manager, sids[te.stream], &mut buf);
            frames[te.stream] += f;
            dropped_by_backpressure += d;
            buffers[te.stream] = buf;
        }
    }
    for s in 0..n_sessions {
        let mut buf = std::mem::take(&mut buffers[s]);
        if !buf.is_empty() {
            let (f, d) = feed(&mut manager, sids[s], &mut buf);
            frames[s] += f;
            dropped_by_backpressure += d;
        }
        frames[s] += manager.drain(sids[s]).expect("drain").len();
    }
    let wall = start.elapsed().as_secs_f64();

    let fleet = manager.stats();
    println!(
        "serve fleet: {} sessions on {} workers — {} events in {:.3} s ({:.2} Meps aggregate)",
        fleet.open_sessions,
        fleet.workers,
        fleet.events_in,
        wall,
        fleet.events_in as f64 / wall.max(1e-9) / 1e6,
    );
    println!(
        "jobs executed: {}  rejected batches: {}  events dropped by backpressure: {}",
        fleet.jobs_executed, fleet.rejected_batches, dropped_by_backpressure,
    );
    println!(
        "resident memory: {:.2} MiB across {} sessions ({:.1} KiB/session mean — \
         activity-proportional under lazy band materialization)",
        fleet.resident_bytes as f64 / (1024.0 * 1024.0),
        fleet.open_sessions,
        fleet.resident_bytes as f64 / fleet.open_sessions.max(1) as f64 / 1024.0,
    );
    for (k, sid) in sids.iter().enumerate() {
        let resident = fleet
            .sessions
            .iter()
            .find(|s| s.id == sid.raw())
            .map_or(0, |s| s.resident_bytes);
        let report = manager.close(*sid).expect("close");
        let st = &report.stats;
        let p = &report.pipeline;
        println!(
            "  {:<12} {:>4}x{:<4} rate {:<3} | {:>7} in, {:>7} written, {:>6} dropped | \
             {} frames | ack p50 {:.0} µs p99 {:.0} µs | peak queue {} | {:.1} KiB resident",
            st.name,
            st.res.width,
            st.res.height,
            streams[k].rate,
            p.events_in,
            p.events_written,
            p.events_dropped_by_stcf,
            frames[k],
            st.ingest_ack_p50_us,
            st.ingest_ack_p99_us,
            st.peak_queue_depth,
            resident as f64 / 1024.0,
        );
    }
    let final_stats = manager.shutdown();
    assert_eq!(final_stats.open_bands, 0, "all bands freed at shutdown");
    0
}

/// Run the TCP front door (`serve::net`): bind `--listen ADDR`, accept
/// camera connections for `--duration` seconds, then drain every live
/// session and print the net summary. Exit code reflects the robustness
/// contract: any drain-accounting mismatch or leaked session fails.
fn cmd_serve_listen(addr: &str, args: &Args) -> i32 {
    use std::time::Duration;
    use tsisc::serve::net::{NetConfig, NetServer};
    use tsisc::serve::ServeConfig;

    let dur = args.get_parsed("duration", 10.0f64).clamp(0.1, 3_600.0);
    let defaults = NetConfig::default();
    let serve_defaults = ServeConfig::default();
    let cfg = NetConfig {
        serve: ServeConfig {
            workers: args.get_parsed("workers", serve_defaults.workers).max(1),
            max_sessions: args.get_parsed("max-sessions", serve_defaults.max_sessions).max(1),
            max_inflight_batches: args
                .get_parsed("max-inflight", serve_defaults.max_inflight_batches)
                .max(1),
            ..ServeConfig::default()
        },
        read_timeout: Duration::from_millis(
            args.get_parsed("read-timeout-ms", defaults.read_timeout.as_millis() as u64),
        ),
        idle_timeout: Duration::from_millis(
            args.get_parsed("idle-timeout-ms", defaults.idle_timeout.as_millis() as u64),
        ),
        error_budget: args.get_parsed("error-budget", defaults.error_budget).max(1),
        max_connections: args.get_parsed("max-connections", defaults.max_connections).max(1),
        ..defaults
    };
    let server = match NetServer::bind(addr, cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: bind {addr}: {e}");
            return 1;
        }
    };
    eprintln!(
        "listening on {} for {dur} s — connect cameras with \
         `tsisc camera --connect {}`",
        server.local_addr(),
        server.local_addr(),
    );
    // Export surfaces: --metrics serves the fleet scrape over HTTP;
    // --json-stats writes a periodic JSON snapshot (bench-JSON shape,
    // diffable with `cargo run -p xtask -- bench-compare`).
    let metrics = match args.get("metrics") {
        Some(maddr) => match server.spawn_metrics(maddr) {
            Ok(m) => {
                eprintln!("metrics scrape at http://{}/", m.local_addr());
                Some(m)
            }
            Err(e) => {
                eprintln!("serve: metrics bind {maddr}: {e}");
                return 1;
            }
        },
        None => None,
    };
    let mut json = args.get("json-stats").map(|path| {
        tsisc::serve::ObsJsonWriter::new(path, args.get_parsed("json-every", 5u64).max(1))
    });
    let t0 = std::time::Instant::now();
    while t0.elapsed().as_secs_f64() < dur {
        std::thread::sleep(Duration::from_millis(200));
        if let Some(w) = json.as_mut() {
            server.tick_json(w);
        }
    }
    if let Some(w) = json.as_mut() {
        server.tick_json(w);
    }
    drop(metrics);
    eprintln!("duration elapsed — draining live sessions ...");
    let stats = server.shutdown();
    print_net_summary(&stats);
    let clean = stats.net.drain_accounting_mismatches == 0
        && stats.net.handler_panics == 0
        && stats.open_sessions == 0;
    i32::from(!clean)
}

/// Print the front door's counters grouped the way the chaos harness
/// asserts them: admission, traffic, recoverable faults, disconnects.
fn print_net_summary(stats: &tsisc::serve::ServeStats) {
    let n = &stats.net;
    println!(
        "net: {} connections accepted, {} shed | {} sessions opened, \
         {} HELLOs refused, {} clean BYEs",
        n.connections_accepted,
        n.connections_shed,
        n.sessions_opened,
        n.hellos_rejected,
        n.byes_completed,
    );
    println!(
        "traffic: {} batches acked, {} events in, {} frames out, {} NACKs",
        n.batches_acked, n.events_ingested, n.frames_sent, n.nacks_sent,
    );
    println!(
        "faults: {} bad frame, {} checksum, {} decode, {} protocol, \
         {} duplicate, {} backpressure",
        n.bad_frames,
        n.checksum_errors,
        n.decode_errors,
        n.protocol_errors,
        n.duplicate_batches,
        n.backpressure_nacks,
    );
    println!(
        "disconnects: {} deadline, {} budget, {} abrupt | {} sessions drained \
         on error, {} accounting mismatches, {} handler panics",
        n.deadline_disconnects,
        n.budget_disconnects,
        n.abrupt_disconnects,
        n.sessions_drained_on_error,
        n.drain_accounting_mismatches,
        n.handler_panics,
    );
}

/// One synthetic camera over TCP: HELLO, stream AER-encoded batches,
/// one causal snapshot round trip, then BYE — printing what actually
/// came back over the wire.
fn cmd_camera(args: &Args) -> i32 {
    use tsisc::events::scene::EdgeScene;
    use tsisc::events::{v2e, Event, Resolution};
    use tsisc::serve::net::{ClientConfig, Hello, NetClient, NetError};

    let Some(addr) = args.get("connect") else {
        eprintln!("camera: missing --connect HOST:PORT");
        return 2;
    };
    let dur = args.get_parsed("duration", 0.3f64).clamp(0.01, 3_600.0);
    let width: u16 = args.get_parsed("width", 64u16).max(1);
    let height: u16 = args.get_parsed("height", 64u16).max(1);
    let chunk = args.get_parsed("chunk", 2_048usize).max(1);
    let res = Resolution::new(width, height);
    eprintln!("generating a {dur} s edge scene at {width}x{height} ...");
    let seed = args.get_parsed("seed", 21u64);
    let labeled =
        v2e::convert(&EdgeScene::new(120.0, seed), res, v2e::DvsParams::default(), dur);
    let events: Vec<Event> = labeled.iter().map(|l| l.ev).collect();
    eprintln!("{} events to stream in chunks of {chunk}", events.len());

    let hello = Hello {
        name: args.get("name").unwrap_or("camera").to_string(),
        width,
        height,
        t_end_us: (dur * 1e6) as u64,
        window_us: args.get_parsed("window-ms", 50u64).max(1) * 1_000,
        batch_size: args.get_parsed("batch-size", 4_096u32).max(1),
        n_shards: args.get_parsed("shards", 4u32).max(1),
        denoise_shards: args.get_parsed("denoise-shards", 0u32),
        stcf: args.flag("stcf"),
    };
    let stream = || -> Result<(), NetError> {
        let mut client = NetClient::connect(addr, ClientConfig::default())?;
        client.hello(&hello)?;
        for c in events.chunks(chunk) {
            client.send_batch(c)?;
        }
        if let Some(last) = events.last() {
            let (at, frame) = client.snapshot(last.t)?;
            let active = frame.iter_coords().filter(|(_, _, v)| **v != 0.0).count();
            println!(
                "snapshot at {at} µs: {}x{} frame, {active} active pixels",
                frame.width(),
                frame.height(),
            );
        }
        let (frames, emitted) = client.bye()?;
        println!(
            "server emitted {emitted} window frames; {} received over the wire",
            frames.len(),
        );
        Ok(())
    };
    match stream() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("camera: {e}");
            1
        }
    }
}

/// `tsisc top`: one wire `STATS` scrape of a running front door,
/// rendered as a fleet summary — per-stage p50/p99, worker utilization,
/// degrade tier, then a per-session table. `--raw` dumps the
/// Prometheus-style text untouched (what `--metrics` serves over HTTP).
fn cmd_top(args: &Args) -> i32 {
    use tsisc::serve::net::{ClientConfig, NetClient};

    let Some(addr) = args.get("connect") else {
        eprintln!("top: missing --connect HOST:PORT");
        return 2;
    };
    let text = match NetClient::connect(addr, ClientConfig::default())
        .and_then(|mut c| c.stats())
    {
        Ok(t) => t,
        Err(e) => {
            eprintln!("top: {addr}: {e}");
            return 1;
        }
    };
    if args.flag("raw") {
        print!("{text}");
        return 0;
    }
    let scrape = Scrape::parse(&text);
    print_top(&scrape);
    0
}

/// A parsed scrape: `name{labels} value` lines keyed verbatim (comment
/// lines skipped). Shared by `tsisc top`'s summary and table renderers.
struct Scrape {
    values: std::collections::BTreeMap<String, f64>,
}

impl Scrape {
    fn parse(text: &str) -> Self {
        let mut values = std::collections::BTreeMap::new();
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((key, val)) = line.rsplit_once(' ') {
                if let Ok(v) = val.parse::<f64>() {
                    values.insert(key.to_string(), v);
                }
            }
        }
        Scrape { values }
    }

    fn get(&self, key: &str) -> f64 {
        self.values.get(key).copied().unwrap_or(0.0)
    }

    /// p50/p99 of a histogram by bare name, optional session label.
    fn quantiles(&self, name: &str, session: Option<&str>) -> (f64, f64) {
        let labels = session.map_or(String::new(), |s| format!(",session=\"{s}\""));
        (
            self.get(&format!("{name}{{quantile=\"0.5\"{labels}}}")),
            self.get(&format!("{name}{{quantile=\"0.99\"{labels}}}")),
        )
    }

    /// Session names, recovered from the per-session labeled lines.
    fn sessions(&self) -> Vec<String> {
        self.values
            .keys()
            .filter_map(|k| {
                k.strip_prefix("session_events_in_total{session=\"")
                    .and_then(|rest| rest.strip_suffix("\"}"))
                    .map(str::to_string)
            })
            .collect()
    }
}

fn print_top(s: &Scrape) {
    let tier = match s.get("degrade_tier_total") as u8 {
        0 => "nominal",
        1 => "defer-cold",
        2 => "serve-stale",
        _ => "shed",
    };
    println!(
        "fleet: {} sessions on {} workers | uptime {:.1} s | busy {:.1}% | \
         degrade {tier} | resident {:.2} MiB",
        s.get("open_sessions_total"),
        s.get("workers_total"),
        s.get("uptime_us") / 1e6,
        s.get("worker_busy_ratio") * 100.0,
        s.get("resident_bytes") / (1024.0 * 1024.0),
    );
    println!(
        "jobs executed {} | events in {} | rejected batches {} | ready depth {} | \
         quarantines {}",
        s.get("jobs_executed_total"),
        s.get("events_in_total"),
        s.get("rejected_batches_total"),
        s.get("ready_depth_total"),
        s.get("quarantines_total"),
    );
    println!("stage p50/p99 µs:");
    for (label, name) in [
        ("decode", "stage_decode_us"),
        ("score", "stage_score_us"),
        ("route", "stage_route_us"),
        ("render", "stage_render_us"),
        ("composite", "stage_composite_us"),
        ("queue wait", "queue_wait_us"),
        ("ingest ack", "ingest_ack_us"),
        ("batch e2e", "batch_e2e_us"),
    ] {
        let (p50, p99) = s.quantiles(name, None);
        println!("  {label:<10} {p50:>8.0} / {p99:<8.0}");
    }
    let sessions = s.sessions();
    if sessions.is_empty() {
        return;
    }
    println!(
        "{:<16} {:>10} {:>10} {:>8}  {:>15}  {:>15}  {:>10}",
        "session", "in", "routed", "dropped", "queue p50/p99", "e2e p50/p99", "resident"
    );
    for name in &sessions {
        let block = format!("{{session=\"{name}\"}}");
        let (qw50, qw99) = s.quantiles("session_queue_wait_us", Some(name));
        let (e50, e99) = s.quantiles("session_batch_e2e_us", Some(name));
        println!(
            "{:<16} {:>10} {:>10} {:>8}  {:>7.0}/{:<7.0}  {:>7.0}/{:<7.0}  {:>8.1}Ki",
            name,
            s.get(&format!("session_events_in_total{block}")),
            s.get(&format!("session_events_routed_total{block}")),
            s.get(&format!("session_events_dropped_by_stcf_total{block}")),
            qw50,
            qw99,
            e50,
            e99,
            s.get(&format!("session_resident_bytes{block}")) / 1024.0,
        );
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> i32 {
    eprintln!(
        "train: built without the `pjrt` feature — rebuild with \
         `cargo build --features pjrt` (and run `make artifacts`)"
    );
    1
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> i32 {
    use tsisc::events::dataset::{generate, Family, GenOptions};
    use tsisc::isc::IscConfig;
    use tsisc::runtime::{artifacts_available, default_artifact_dir, Runtime};
    use tsisc::train::driver::{train_classifier, TrainConfig};
    use tsisc::train::frames::{dataset_frames, SurfaceKind};

    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return 1;
    }
    let family = Family::from_name(args.get("family").unwrap_or("nmnist"))
        .unwrap_or(Family::NMnist);
    let surface = match args.get("surface").unwrap_or("isc") {
        "ideal" => SurfaceKind::Ideal { tau_us: 24_000.0 },
        "count" => SurfaceKind::Count { bits: 4 },
        "ebbi" => SurfaceKind::Binary,
        _ => SurfaceKind::Isc(IscConfig::default()),
    };
    let opts = GenOptions {
        train_per_class: args.get_parsed("per-class", 24usize),
        test_per_class: args.get_parsed("test-per-class", 8usize),
        duration_s: 0.15,
        noise_hz: 1.0,
        seed: args.get_parsed("seed", 7u64),
    };
    eprintln!("generating {} dataset ...", family.name());
    let ds = generate(family, opts);
    eprintln!("building {} frames ...", surface.name());
    let (train, test) = dataset_frames(&ds, &surface, 50_000, 32);
    eprintln!("train frames: {}  test frames: {}", train.frames.len(), test.frames.len());

    let mut rt = Runtime::new(default_artifact_dir()).expect("runtime");
    let cfg = TrainConfig {
        steps: args.get_parsed("steps", 150usize),
        lr: args.get_parsed("lr", 0.03f32),
        seed: 42,
        log_every: args.get_parsed("log-every", 10usize),
    };
    match train_classifier(&mut rt, &train, &test, &cfg) {
        Ok(r) => {
            for (step, loss) in &r.loss_curve {
                println!("step {step:>5}  loss {loss:.4}");
            }
            println!(
                "final loss {:.4}  frame acc {:.3}  video acc {:.3}",
                r.final_loss, r.frame_accuracy, r.video_accuracy
            );
            0
        }
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    use tsisc::runtime::{artifacts_available, default_artifact_dir};
    println!("tsisc {} — 3DS-ISC reproduction", env!("CARGO_PKG_VERSION"));
    println!("artifact dir: {:?}", default_artifact_dir());
    println!("artifacts present: {}", artifacts_available());
    #[cfg(feature = "pjrt")]
    if artifacts_available() {
        match tsisc::runtime::Runtime::new(default_artifact_dir()) {
            Ok(rt) => println!("PJRT platform: {}", rt.platform()),
            Err(e) => println!("PJRT init failed: {e:#}"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT platform: unavailable (built without the `pjrt` feature)");
    0
}
