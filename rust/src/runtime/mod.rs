//! The PJRT runtime layer: artifact loading/execution ([`pjrt`]) and the
//! kernel-backed time-surface state machine ([`surfaces`]). Python never
//! runs here — artifacts were lowered once by `make artifacts`.
//!
//! Execution requires the `pjrt` cargo feature (pulls in the `xla`
//! crate); without it only the artifact-location helpers below build, and
//! artifact-backed experiments report themselves as skipped.

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod surfaces;

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};
#[cfg(feature = "pjrt")]
pub use surfaces::KernelTs;

/// Default artifact directory, resolvable from the repo root or target/.
pub fn default_artifact_dir() -> std::path::PathBuf {
    // Prefer $TSISC_ARTIFACTS, then ./artifacts relative to cwd, then the
    // crate manifest dir (useful under `cargo test`).
    if let Ok(d) = std::env::var("TSISC_ARTIFACTS") {
        return d.into();
    }
    let cwd = std::path::PathBuf::from("artifacts");
    if cwd.is_dir() {
        return cwd;
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when the AOT artifacts exist (tests use this to skip gracefully
/// with a loud message instead of failing when `make artifacts` hasn't run).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.txt").is_file()
}
