//! Device-side time-surface state machine over the AOT kernels.
//!
//! `KernelTs` drives the L1 Pallas artifacts (`ts_update`, `ts_frame`,
//! `stcf_count`) from the Rust hot path: the analog plane state (v1, v2)
//! plus the per-pixel mismatch maps live as host mirrors, each microbatch
//! becomes one `ts_update` execution, frame readouts one `ts_frame`, and
//! STCF support maps one `stcf_count`. This is the artifact-backed twin of
//! the native `isc::IscArray` (used for A/B verification and for feeding
//! the CNN pipeline from the exact kernels that would run on TPU).

use super::pjrt::{lit_f32, lit_pred, lit_scalar, to_vec_f32, Runtime};
use crate::circuit::montecarlo::FittedBank;
use crate::circuit::MismatchParams;
use crate::events::{Event, Resolution};
use crate::util::grid::Grid;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Result};

/// Geometry the artifacts were lowered at (see python/compile/aot.py).
pub const KERNEL_H: usize = 240;
pub const KERNEL_W: usize = 320;

/// Kernel-backed analog plane at the fixed artifact geometry.
pub struct KernelTs {
    v1: Vec<f32>,
    v2: Vec<f32>,
    a1: Vec<f32>,
    a2: Vec<f32>,
    tau1: Vec<f32>,
    tau2: Vec<f32>,
    /// Events accumulated since the last advance (mask plane).
    pending: Vec<bool>,
    /// Plane time in µs (state is valid as of this instant).
    t_us: u64,
    res: Resolution,
}

impl KernelTs {
    /// Build with per-pixel parameters sampled from the Monte-Carlo fitted
    /// bank (same procedure as `IscArray`).
    pub fn new(c_mem: f64, mismatch: Option<MismatchParams>, seed: u64) -> Self {
        let n = KERNEL_H * KERNEL_W;
        let bank = match mismatch {
            Some(mm) => FittedBank::build(c_mem, &mm, 512, seed).fits,
            None => vec![FittedBank::nominal(c_mem)],
        };
        let mut rng = Pcg64::with_stream(seed, 0x6e);
        let mut a1 = Vec::with_capacity(n);
        let mut a2 = Vec::with_capacity(n);
        let mut t1 = Vec::with_capacity(n);
        let mut t2 = Vec::with_capacity(n);
        for _ in 0..n {
            let f = bank[rng.below(bank.len() as u64) as usize];
            a1.push(f.a1 as f32);
            a2.push((f.a2 + f.b) as f32); // fold the (small) offset into A2
            t1.push(f.tau1 as f32);
            t2.push(f.tau2 as f32);
        }
        Self {
            v1: vec![0.0; n],
            v2: vec![0.0; n],
            a1,
            a2,
            tau1: t1,
            tau2: t2,
            pending: vec![false; n],
            t_us: 0,
            res: Resolution::new(KERNEL_W as u16, KERNEL_H as u16),
        }
    }

    pub fn resolution(&self) -> Resolution {
        self.res
    }

    pub fn time_us(&self) -> u64 {
        self.t_us
    }

    /// Queue an event write (applied by the next [`advance`]).
    pub fn write(&mut self, e: &Event) -> Result<()> {
        if !self.res.contains(e.x, e.y) {
            return Err(anyhow!("event ({}, {}) outside kernel geometry", e.x, e.y));
        }
        self.pending[e.y as usize * KERNEL_W + e.x as usize] = true;
        Ok(())
    }

    /// Advance the plane to `t_us` via one `ts_update` execution: decay all
    /// cells by Δt then apply the pending write mask.
    pub fn advance(&mut self, rt: &mut Runtime, t_us: u64) -> Result<()> {
        let dt = (t_us.saturating_sub(self.t_us)) as f32 * 1e-6;
        let dims = [KERNEL_H as i64, KERNEL_W as i64];
        let exe = rt.load("ts_update")?;
        let out = exe.run(&[
            lit_f32(&self.v1, &dims)?,
            lit_f32(&self.v2, &dims)?,
            lit_pred(&self.pending, &dims)?,
            lit_f32(&self.a1, &dims)?,
            lit_f32(&self.a2, &dims)?,
            lit_f32(&self.tau1, &dims)?,
            lit_f32(&self.tau2, &dims)?,
            lit_scalar(dt),
        ])?;
        if out.len() != 2 {
            return Err(anyhow!("ts_update returned {} outputs", out.len()));
        }
        self.v1 = to_vec_f32(&out[0])?;
        self.v2 = to_vec_f32(&out[1])?;
        self.pending.iter_mut().for_each(|m| *m = false);
        self.t_us = t_us;
        Ok(())
    }

    /// Normalized [0,1] frame via the `ts_frame` artifact.
    pub fn frame(&self, rt: &mut Runtime) -> Result<Grid<f64>> {
        let dims = [KERNEL_H as i64, KERNEL_W as i64];
        let exe = rt.load("ts_frame")?;
        let out = exe.run(&[lit_f32(&self.v1, &dims)?, lit_f32(&self.v2, &dims)?])?;
        let data = to_vec_f32(&out[0])?;
        Ok(Grid::from_vec(KERNEL_W, KERNEL_H, data.into_iter().map(|v| v as f64).collect()))
    }

    /// STCF support counts via the `stcf_count` artifact (r = 3 baked).
    pub fn stcf_counts(&self, rt: &mut Runtime, v_tw: f32) -> Result<Grid<f64>> {
        let dims = [KERNEL_H as i64, KERNEL_W as i64];
        let v: Vec<f32> = self.v1.iter().zip(&self.v2).map(|(a, b)| a + b).collect();
        let exe = rt.load("stcf_count")?;
        let out = exe.run(&[lit_f32(&v, &dims)?, lit_scalar(v_tw)])?;
        let data = to_vec_f32(&out[0])?;
        Ok(Grid::from_vec(KERNEL_W, KERNEL_H, data.into_iter().map(|v| v as f64).collect()))
    }

    /// Direct surface read (host mirror), volts.
    pub fn read(&self, x: u16, y: u16) -> f64 {
        let i = y as usize * KERNEL_W + x as usize;
        (self.v1[i] + self.v2[i]) as f64
    }
}
