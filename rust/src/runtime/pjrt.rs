//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! This is the only place the `xla` crate is touched. Pattern (see
//! /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. All artifacts
//! were lowered with `return_tuple=True`, so every execution returns one
//! tuple literal which we decompose.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded, compiled artifact.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of '{}'", self.name))?;
        Ok(out.to_tuple()?)
    }

    /// Execute with device-resident buffers (hot loop path: params never
    /// leave the device between steps); returns output buffers, still
    /// device-resident, after splitting the tuple.
    pub fn run_b(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let result = self
            .exe
            .execute_b::<xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing artifact '{}' (buffers)", self.name))?;
        let mut row = result.into_iter().next().ok_or_else(|| anyhow!("no replica output"))?;
        if row.len() == 1 {
            // Single tuple output: fetch as literal and re-upload parts is
            // wasteful; the CPU plugin untuples automatically when the
            // root is a tuple, so row.len()>1 is the common case. Fall
            // back to literal decomposition when it doesn't.
            let lit = row.remove(0).to_literal_sync()?;
            return Err(anyhow!(
                "artifact '{}' returned a packed tuple ({} elements) in buffer mode; \
                 use run() instead",
                self.name,
                lit.to_tuple()?.len()
            ));
        }
        Ok(row)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// The runtime: one PJRT CPU client plus a registry of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a runtime over an artifact directory (usually `artifacts/`).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(anyhow!(
                "artifact directory {:?} missing — run `make artifacts` first",
                dir
            ));
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir, cache: HashMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact by file stem (e.g. "classifier_train"),
    /// memoized for the life of the runtime.
    pub fn load(&mut self, stem: &str) -> Result<&Executable> {
        if !self.cache.contains_key(stem) {
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {:?}", path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{stem}'"))?;
            self.cache.insert(stem.to_string(), Executable { name: stem.to_string(), exe });
        }
        Ok(&self.cache[stem])
    }

    /// Load an `.npz` parameter archive as ordered literals (keys p000…).
    pub fn load_params(&self, stem: &str) -> Result<Vec<xla::Literal>> {
        use xla::FromRawBytes;
        let path = self.dir.join(format!("{stem}.npz"));
        let mut named = xla::Literal::read_npz(&path, &())
            .with_context(|| format!("reading {:?}", path))?;
        named.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(named.into_iter().map(|(_, l)| l).collect())
    }

    /// Upload a literal to the device.
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }
}

// ---------------------------------------------------------------------
// Literal construction helpers (f32 host bridges)
// ---------------------------------------------------------------------

/// Row-major f32 literal of the given dims.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("lit_f32: {} elements for dims {:?}", data.len(), dims));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Boolean (PRED) literal.
pub fn lit_pred(data: &[bool], dims: &[i64]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = data.iter().map(|&b| b as u8).collect();
    let lit = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::Pred,
        &dims.iter().map(|&d| d as usize).collect::<Vec<_>>(),
        &bytes,
    )?;
    Ok(lit)
}

/// i32 literal.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract f32 data from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
