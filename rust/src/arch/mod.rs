//! Architecture-level models: the 2D-vs-3D comparison (Fig. 7), the
//! half-select analysis (Fig. 4) and the SRAM baselines (Fig. 8).
//!
//! All numbers derive from the constants in [`crate::circuit::params`]
//! (quoted from the paper and its references) plus standard 65 nm wire and
//! gate figures — see each module for the component derivations.

pub mod arch2d;
pub mod arch3d;
pub mod geometry;
pub mod report;
pub mod sram;

pub use geometry::ArrayGeometry;
pub use report::{ArchReport, Breakdown};
