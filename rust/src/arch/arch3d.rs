//! 3D stacked architecture model (paper Fig. 3b / Fig. 7).
//!
//! In 3DS-ISC every DVS pixel drives its eDRAM cell directly through a
//! Cu-Cu bond: no AER encoder, no decoders, no long word/bit lines. The
//! power/area/delay model therefore contains only the ISC array itself,
//! the bond parasitics and the frame-readout periphery.

use super::geometry::ArrayGeometry;
use super::report::{ArchReport, Breakdown};
use crate::circuit::cell::LeakageMacro;
use crate::circuit::params::*;

/// Per-event energy of the in-pixel write path beyond the storage cap:
/// the WBL stub, the inverter generating the WWL pulse and the pulse
/// shaping — all local to one cell in the 3D organization (≈25 fJ, a few
/// gate-loads at 1.2 V).
pub const IN_PIXEL_WRITE_E: f64 = 25e-15;

/// Read energy per cell per frame scan: source-follower settle on a short
/// column stub (analog-pixel style readout).
pub const READ_E_PER_CELL: f64 = 50e-15;

/// Column readout amplifier area per column (µm²).
pub const COL_AMP_AREA_UM2: f64 = 30.0;

/// Operating point for the architecture comparison.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Aggregate event rate (events/s). Paper uses 100 Meps.
    pub event_rate: f64,
    /// Full-frame readout rate for downstream CV (frames/s).
    pub frame_rate: f64,
}

impl Default for Workload {
    fn default() -> Self {
        Self { event_rate: EVENT_RATE_EPS, frame_rate: 20.0 }
    }
}

/// Average static leakage power of one ISC cell: leakage current drawn at
/// the mid-decay stored level (cells spend most time between writes).
pub fn cell_static_power() -> f64 {
    let leak = LeakageMacro::ll_calibrated();
    // Average over the usable decay range [V_FLOOR, VDD]; a flat average of
    // the current at a few representative levels is accurate to a few %
    // against the time-weighted integral for these gentle curves.
    let levels = [0.9 * VDD, 0.6 * VDD, 0.35 * VDD];
    let i_avg: f64 = levels.iter().map(|&v| leak.current(v)).sum::<f64>() / levels.len() as f64;
    i_avg * VDD
}

/// Build the 3D architecture report for geometry `g` under workload `w`.
pub fn report(g: &ArrayGeometry, w: &Workload) -> ArchReport {
    let cells = g.cells() as f64;

    // ---- power ---------------------------------------------------------
    let mut power = Breakdown::new();
    // Event writes: storage cap swing + local pulse circuitry.
    let e_write = C_MEM_NOMINAL * VDD * VDD + IN_PIXEL_WRITE_E;
    power.add("isc-array write", e_write * w.event_rate);
    // Cu-Cu bond charge per event.
    power.add("cu-cu bond", CUCU_CAP * VDD * VDD * w.event_rate);
    // Cell leakage (static).
    power.add("isc-array static", cells * cell_static_power());
    // Frame readout scans.
    power.add("readout", cells * READ_E_PER_CELL * w.frame_rate);

    // ---- area ----------------------------------------------------------
    let mut area = Breakdown::new();
    // Stacked: sensor sits above the ISC array — one footprint.
    area.add("stacked array footprint", g.core_area_um2());
    // Cu-Cu bonds land on in-cell pads (no extra footprint); keep a 1 %
    // keep-out allowance for the bond ring.
    area.add("bond keep-out", 0.01 * g.core_area_um2());
    area.add("readout periphery", g.res.width as f64 * COL_AMP_AREA_UM2);

    // ---- delay (per-event write path) -----------------------------------
    let mut delay = Breakdown::new();
    delay.add("event write", WRITE_PULSE_S);
    delay.add("cu-cu bond", CUCU_DELAY_S);

    ArchReport { name: "3DS-ISC", power, area, delay }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Resolution;

    #[test]
    fn power_is_microwatt_scale() {
        // Paper Fig. 8: the ISC analog array at QVGA/100 Meps sits three
        // orders of magnitude below SRAM's mW — i.e. a few µW.
        let r = report(&ArrayGeometry::new(Resolution::QVGA), &Workload::default());
        let p = r.power.total();
        assert!((2e-6..12e-6).contains(&p), "total power {p:.3e} W");
    }

    #[test]
    fn write_energy_dominated_by_array() {
        let r = report(&ArrayGeometry::new(Resolution::QVGA), &Workload::default());
        assert!(r.power.share_percent("isc-array write") > 50.0);
        // Cu-Cu bond cost is minor (the paper's core 3D argument).
        assert!(r.power.share_percent("cu-cu bond") < 5.0);
    }

    #[test]
    fn delay_near_write_pulse() {
        let r = report(&ArrayGeometry::new(Resolution::QVGA), &Workload::default());
        let d = r.delay.total();
        assert!((d - 5.08e-9).abs() < 0.1e-9, "delay {d:.3e}");
    }

    #[test]
    fn static_power_subnanowatt_per_cell() {
        let p = cell_static_power();
        assert!((0.1e-12..5e-12).contains(&p), "cell static {p:.3e} W");
    }

    #[test]
    fn area_close_to_single_array() {
        let g = ArrayGeometry::new(Resolution::QVGA);
        let r = report(&g, &Workload::default());
        let ratio = r.area.total() / g.core_area_um2();
        assert!((1.0..1.05).contains(&ratio), "area overhead ratio {ratio}");
    }
}
