//! Array geometry: wire lengths, loads and block counts for the
//! architecture-level energy/area/delay models.

use crate::circuit::params::{CELL_HEIGHT_UM, CELL_WIDTH_UM};
use crate::events::Resolution;

/// Physical geometry of an ISC array at a given sensor resolution.
#[derive(Clone, Copy, Debug)]
pub struct ArrayGeometry {
    pub res: Resolution,
    /// Cell pitch (µm).
    pub cell_w_um: f64,
    pub cell_h_um: f64,
}

impl ArrayGeometry {
    pub fn new(res: Resolution) -> Self {
        Self { res, cell_w_um: CELL_WIDTH_UM, cell_h_um: CELL_HEIGHT_UM }
    }

    /// Number of cells.
    pub fn cells(&self) -> usize {
        self.res.pixels()
    }

    /// Array core area in µm².
    pub fn core_area_um2(&self) -> f64 {
        self.cells() as f64 * self.cell_w_um * self.cell_h_um
    }

    /// Length of one write word line (runs across a row; µm).
    pub fn wwl_length_um(&self) -> f64 {
        self.res.width as f64 * self.cell_w_um
    }

    /// Length of one write bit line (runs down a column; µm).
    pub fn wbl_length_um(&self) -> f64 {
        self.res.height as f64 * self.cell_h_um
    }

    /// Row/column address bits the 2D periphery must decode.
    pub fn row_addr_bits(&self) -> u32 {
        (usize::BITS - (self.res.height as usize - 1).leading_zeros()).max(1)
    }

    pub fn col_addr_bits(&self) -> u32 {
        (usize::BITS - (self.res.width as usize - 1).leading_zeros()).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qvga_geometry() {
        let g = ArrayGeometry::new(Resolution::QVGA);
        assert_eq!(g.cells(), 76_800);
        // 320 × 4.8 µm = 1 536 µm WWL; 240 × 3.9 = 936 µm WBL.
        assert!((g.wwl_length_um() - 1536.0).abs() < 1e-9);
        assert!((g.wbl_length_um() - 936.0).abs() < 1e-9);
        // ≈1.44 mm² core.
        assert!((g.core_area_um2() * 1e-6 - 1.438).abs() < 0.01);
        assert_eq!(g.row_addr_bits(), 8);
        assert_eq!(g.col_addr_bits(), 9);
    }
}
