//! SRAM timestamp-storage baselines (paper Fig. 8, Sec. II-C.2, IV-B).
//!
//! Two published designs store the SAE as 16-bit digital timestamps in
//! SRAM; the paper compares its ISC analog array against both, storage
//! array only:
//!
//! * **[53]** Bose et al., in-memory binary image filtering: 5.1 pJ/bit
//!   write, 350 pA/bit static at 1 V.
//! * **[26]** Rios-Navarro et al., within-camera MLP denoising: 35 mW
//!   static for a 346×260×18 b array, 2.4 nJ per 7×7-pixel access,
//!   write ≈ 1.5× read.

use super::arch3d::Workload;
use super::geometry::ArrayGeometry;
use super::report::Breakdown;
use crate::circuit::params::*;

/// Which published SRAM design to model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SramDesign {
    /// Bose et al. [53].
    Bose53,
    /// Rios-Navarro et al. [26].
    Rios26,
}

impl SramDesign {
    pub fn name(self) -> &'static str {
        match self {
            SramDesign::Bose53 => "16b SRAM [53]",
            SramDesign::Rios26 => "16b SRAM [26]",
        }
    }
}

/// Storage-array power breakdown for a design holding `TIMESTAMP_BITS`-bit
/// timestamps at geometry `g` under workload `w`.
pub fn power(design: SramDesign, g: &ArrayGeometry, w: &Workload) -> Breakdown {
    let bits = g.cells() as f64 * TIMESTAMP_BITS as f64;
    let mut b = Breakdown::new();
    match design {
        SramDesign::Bose53 => {
            // Dynamic: every event writes one 16-bit word.
            b.add(
                "write dynamic",
                TIMESTAMP_BITS as f64 * SRAM53_WRITE_E_PER_BIT * w.event_rate,
            );
            b.add("static leakage", bits * SRAM53_LEAK_A_PER_BIT * SRAM53_VDD);
        }
        SramDesign::Rios26 => {
            // Static scales with bit count from the published 346×260×18 array.
            b.add("static leakage", SRAM26_STATIC_W * bits / SRAM26_ARRAY_BITS);
            // Dynamic: one 16-bit word written per event; derived from the
            // published 7×7-patch access energy (49 pixels, 18 b each) with
            // the 1.5× write/read factor. ≈ 0.072 nJ/event, the figure the
            // paper quotes.
            let e_per_bit = SRAM26_ACCESS_7X7_E / (49.0 * 18.0);
            let e_write = e_per_bit * SRAM26_WRITE_READ_RATIO * TIMESTAMP_BITS as f64;
            b.add("write dynamic", e_write * w.event_rate);
        }
    }
    b
}

/// Storage-array area (µm²) for the design at geometry `g`.
pub fn area(design: SramDesign, g: &ArrayGeometry) -> f64 {
    let per_bit = match design {
        SramDesign::Bose53 => SRAM53_AREA_PER_BIT_UM2,
        SramDesign::Rios26 => SRAM26_AREA_PER_BIT_UM2,
    };
    g.cells() as f64 * TIMESTAMP_BITS as f64 * per_bit
}

/// ISC analog array, storage only (for the Fig. 8 comparison): write energy
/// + bond + cell leakage; no periphery.
pub fn isc_array_power(g: &ArrayGeometry, w: &Workload) -> Breakdown {
    let mut b = Breakdown::new();
    let e_write =
        C_MEM_NOMINAL * VDD * VDD + super::arch3d::IN_PIXEL_WRITE_E + CUCU_CAP * VDD * VDD;
    b.add("write dynamic", e_write * w.event_rate);
    b.add("static leakage", g.cells() as f64 * super::arch3d::cell_static_power());
    b
}

/// ISC array area (µm²).
pub fn isc_array_area(g: &ArrayGeometry) -> f64 {
    g.core_area_um2()
}

/// The timestamp-overflow hazard (paper Sec. II-B / IV-B): a `bits`-wide
/// µs counter wraps after 2^bits µs. Returns the wrap period in seconds —
/// SRAM designs hit this; the analog array's self-normalization does not.
pub fn timestamp_wrap_period_s(bits: u32, tick_us: f64) -> f64 {
    (2f64.powi(bits as i32) * tick_us) * 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Resolution;

    fn qvga() -> ArrayGeometry {
        ArrayGeometry::new(Resolution::QVGA)
    }

    #[test]
    fn fig8_power_ratios() {
        // Paper: ISC vs [53] = 1600×, vs [26] = 6761× ("three orders of
        // magnitude"). Shape requirement: both ≥ 1000×, [26] > [53].
        let w = Workload::default();
        let p_isc = isc_array_power(&qvga(), &w).total();
        let p53 = power(SramDesign::Bose53, &qvga(), &w).total();
        let p26 = power(SramDesign::Rios26, &qvga(), &w).total();
        let r53 = p53 / p_isc;
        let r26 = p26 / p_isc;
        assert!((1000.0..2500.0).contains(&r53), "[53] ratio {r53}");
        assert!((4000.0..9000.0).contains(&r26), "[26] ratio {r26}");
        assert!(r26 > r53);
    }

    #[test]
    fn fig8_area_ratios() {
        // Paper: [53] 3.1×, [26] 2.2× the ISC array area.
        let a_isc = isc_array_area(&qvga());
        let r53 = area(SramDesign::Bose53, &qvga()) / a_isc;
        let r26 = area(SramDesign::Rios26, &qvga()) / a_isc;
        assert!((2.7..3.5).contains(&r53), "[53] area ratio {r53}");
        assert!((1.9..2.5).contains(&r26), "[26] area ratio {r26}");
    }

    #[test]
    fn rios_write_energy_matches_quoted() {
        // The paper quotes 0.072 nJ/event write for [26]; our derivation
        // from the published access numbers should reproduce it.
        let e_per_bit = SRAM26_ACCESS_7X7_E / (49.0 * 18.0);
        let e_write = e_per_bit * SRAM26_WRITE_READ_RATIO * TIMESTAMP_BITS as f64;
        assert!(
            (e_write - 0.072e-9).abs() < 0.01e-9,
            "write energy {e_write:.3e} J/event"
        );
    }

    #[test]
    fn sram26_static_large() {
        // [26]'s dominant cost is the 35 mW-class static leakage.
        let p = power(SramDesign::Rios26, &qvga(), &Workload::default());
        assert!(p.share_percent("static leakage") > 70.0);
        assert!(p.total() > 20e-3);
    }

    #[test]
    fn overflow_period_finite_for_sram() {
        // 16-bit µs timestamps wrap every 65.5 ms — mid-recording for any
        // real sequence (the hazard the analog array avoids by design).
        let wrap = timestamp_wrap_period_s(16, 1.0);
        assert!((0.06..0.07).contains(&wrap));
    }
}
