//! Component-resolved power/area/delay reports (the Fig. 7 data structure).

/// One named component contribution.
#[derive(Clone, Debug)]
pub struct Component {
    pub name: &'static str,
    pub value: f64,
}

/// A breakdown of a metric into components (power in W, area in µm²,
/// delay in s — the unit is the report's business).
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    pub parts: Vec<Component>,
}

impl Breakdown {
    pub fn new() -> Self {
        Self { parts: Vec::new() }
    }

    pub fn add(&mut self, name: &'static str, value: f64) -> &mut Self {
        assert!(value >= 0.0, "negative component {name}: {value}");
        self.parts.push(Component { name, value });
        self
    }

    pub fn total(&self) -> f64 {
        self.parts.iter().map(|c| c.value).sum()
    }

    /// Percentage share of component `name` (0 if absent).
    pub fn share_percent(&self, name: &str) -> f64 {
        let t = self.total();
        if t == 0.0 {
            return 0.0;
        }
        100.0 * self.parts.iter().filter(|c| c.name == name).map(|c| c.value).sum::<f64>() / t
    }

    /// Render as an aligned text table with values scaled by `unit` and
    /// suffixed `unit_name` (e.g. 1e6, "µW").
    pub fn to_table(&self, unit: f64, unit_name: &str) -> String {
        let mut s = String::new();
        let width = self.parts.iter().map(|c| c.name.len()).max().unwrap_or(8).max(8);
        for c in &self.parts {
            s.push_str(&format!(
                "  {:<width$}  {:>12.4} {}  ({:5.1} %)\n",
                c.name,
                c.value * unit,
                unit_name,
                self.share_percent(c.name),
                width = width
            ));
        }
        s.push_str(&format!(
            "  {:<width$}  {:>12.4} {}\n",
            "TOTAL",
            self.total() * unit,
            unit_name,
            width = width
        ));
        s
    }
}

/// Full architecture report: the three Fig. 7 metrics with breakdowns.
#[derive(Clone, Debug)]
pub struct ArchReport {
    pub name: &'static str,
    pub power: Breakdown,
    pub area: Breakdown,
    pub delay: Breakdown,
}

impl ArchReport {
    /// Ratios (other/self) for the three metrics — the paper's headline
    /// "69× / 1.9× / 2.2×" comparison is `ratios(&arch2d, &arch3d)`.
    pub fn ratios(a: &ArchReport, b: &ArchReport) -> (f64, f64, f64) {
        (
            a.power.total() / b.power.total(),
            a.area.total() / b.area.total(),
            a.delay.total() / b.delay.total(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_totals_and_shares() {
        let mut b = Breakdown::new();
        b.add("x", 3.0).add("y", 1.0);
        assert_eq!(b.total(), 4.0);
        assert_eq!(b.share_percent("x"), 75.0);
        assert_eq!(b.share_percent("missing"), 0.0);
    }

    #[test]
    fn table_renders() {
        let mut b = Breakdown::new();
        b.add("component", 2e-6);
        let t = b.to_table(1e6, "µW");
        assert!(t.contains("component"));
        assert!(t.contains("TOTAL"));
        assert!(t.contains("2.0000 µW"));
    }

    #[test]
    #[should_panic(expected = "negative component")]
    fn rejects_negative() {
        Breakdown::new().add("bad", -1.0);
    }
}
