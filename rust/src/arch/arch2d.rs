//! Conventional 2D architecture model and the half-select simulation
//! (paper Fig. 3a, Fig. 4, Fig. 7).
//!
//! The 2D organization keeps the same eDRAM ISC array but addresses it as
//! a crossbar: every event passes through an AER encoder, row/column
//! decoders and buffers that drive word/bit lines spanning the full array.
//! Those components dominate power (the paper's breakdown: 53.8 %
//! encoder/decoder, 45.5 % buffers) and add ~6 ns of latency. The crossbar
//! also introduces the half-select hazard analyzed in Fig. 4.

use super::arch3d::{Workload, COL_AMP_AREA_UM2, IN_PIXEL_WRITE_E, READ_E_PER_CELL};
use super::geometry::ArrayGeometry;
use super::report::{ArchReport, Breakdown};
use crate::circuit::params::*;
use crate::events::{LabeledEvent, Resolution};
use crate::util::fit::DoubleExp;
use crate::util::rng::Pcg64;

// ---------------------------------------------------------------------
// Energy / area / delay model constants (65 nm, documented derivations)
// ---------------------------------------------------------------------

/// Gate load each cell presents to its word/bit line (fF): the LL-switch
/// gate through the write inverter.
pub const CELL_LINE_LOAD: f64 = 0.8e-15;

/// Driver-chain overhead multiplier for the line buffers (tapered inverter
/// chain dissipates ≈30 % on top of the final load).
pub const DRIVER_OVERHEAD: f64 = 1.3;

/// Equivalent toggled gates per address bit in the AER encoder + row/col
/// decoder + handshake path (arbiter tree levels, pre-decoders, word-line
/// gating). 34 gate-toggles/bit × 3 fJ ≈ 0.1 pJ/bit.
pub const ENCDEC_GATES_PER_BIT: f64 = 34.0;

/// Static gate count of the 2D periphery (arbiters, decoders, handshake
/// FSMs) for leakage accounting.
pub const ENCDEC_STATIC_GATES: f64 = 3_000.0;

/// Latency components added by the 2D path (paper: encoder/decoder and
/// handshaking overhead ≈ 46.4 % of the 11 ns total).
pub const T_ENCODER: f64 = 2.3e-9;
pub const T_DECODER: f64 = 1.3e-9;
pub const T_HANDSHAKE: f64 = 1.5e-9;

/// Mean arbitration wait in the AER row/column arbiter tree at the
/// 100 Meps operating point (queueing on simultaneous requests).
pub const T_ARBITRATION: f64 = 0.9e-9;

/// NAND2-equivalent gate area at 65 nm (µm²).
pub const GATE_AREA_UM2: f64 = 1.5;

/// Line-buffer area per driven line (µm²): tapered driver sized for ~1 pF.
pub const BUFFER_AREA_UM2: f64 = 30.0;

/// Capacitance of one WWL (runs across a row) including cell loads.
pub fn wwl_cap(g: &ArrayGeometry) -> f64 {
    g.wwl_length_um() * WIRE_CAP_PER_UM + g.res.width as f64 * CELL_LINE_LOAD
}

/// Capacitance of one WBL (runs down a column) including cell loads.
pub fn wbl_cap(g: &ArrayGeometry) -> f64 {
    g.wbl_length_um() * WIRE_CAP_PER_UM + g.res.height as f64 * CELL_LINE_LOAD
}

/// Build the 2D architecture report.
pub fn report(g: &ArrayGeometry, w: &Workload) -> ArchReport {
    let cells = g.cells() as f64;
    let addr_bits = (g.row_addr_bits() + g.col_addr_bits() + 1) as f64; // +1 polarity

    // ---- power ---------------------------------------------------------
    let mut power = Breakdown::new();
    let e_write = C_MEM_NOMINAL * VDD * VDD + IN_PIXEL_WRITE_E;
    power.add("isc-array write", e_write * w.event_rate);
    power.add("isc-array static", cells * super::arch3d::cell_static_power());
    // Line buffers: every event charges one full WWL and one full WBL.
    let e_lines = (wwl_cap(g) + wbl_cap(g)) * VDD * VDD * DRIVER_OVERHEAD;
    power.add("line buffers", e_lines * w.event_rate);
    // AER encoder + decoders + handshake.
    let e_encdec = addr_bits * ENCDEC_GATES_PER_BIT * GATE_TOGGLE_ENERGY;
    power.add("encoder/decoder", e_encdec * w.event_rate + ENCDEC_STATIC_GATES * GATE_LEAK_W);
    power.add("readout", cells * READ_E_PER_CELL * w.frame_rate);

    // ---- area ----------------------------------------------------------
    let mut area = Breakdown::new();
    // Side-by-side: the sensor array and the ISC array each need their own
    // footprint on the single die (vs one stacked footprint in 3D).
    area.add("sensor array", g.core_area_um2());
    area.add("isc array", g.core_area_um2());
    let n_lines = (g.res.width + g.res.height) as f64;
    area.add("line buffers", n_lines * BUFFER_AREA_UM2);
    area.add("encoder/decoder", ENCDEC_STATIC_GATES * GATE_AREA_UM2);
    area.add("readout periphery", g.res.width as f64 * COL_AMP_AREA_UM2);

    // ---- delay ----------------------------------------------------------
    let mut delay = Breakdown::new();
    delay.add("event write", WRITE_PULSE_S);
    delay.add("encoder", T_ENCODER);
    delay.add("decoder", T_DECODER);
    delay.add("handshake", T_HANDSHAKE);
    delay.add("arbitration wait", T_ARBITRATION);
    // Distributed-RC flight time of the word line (0.4·R·C Elmore).
    let t_wire = 0.4
        * (g.wwl_length_um() * WIRE_RES_PER_UM)
        * (g.wwl_length_um() * WIRE_CAP_PER_UM);
    delay.add("line flight", t_wire);

    ArchReport { name: "2D baseline", power, area, delay }
}

// ---------------------------------------------------------------------
// Half-select simulation (Fig. 4)
// ---------------------------------------------------------------------

/// Outcome of simulating an event stream through the 2D crossbar,
/// tracking half-select disturbances against the ideal (3D) array.
#[derive(Clone, Debug)]
pub struct HalfSelectStats {
    /// (Δt since the cell's own write, ΔV disturbance) for each half-select
    /// hit on a recently-written cell — the Fig. 4c scatter.
    pub dv_vs_dt: Vec<(f64, f64)>,
    /// First half-select time after each write (seconds) — Fig. 4d.
    pub first_hs_times: Vec<f64>,
    /// RMS error of the disturbed time-surface vs the ideal one, evaluated
    /// at the end of the stream over all written cells.
    pub ts_rmse: f64,
    /// Fraction of cells whose stored value was disturbed at least once.
    pub disturbed_fraction: f64,
}

/// Row-discharge model: when a row's WWL activates for a write, every other
/// cell on the row sees its LL switch turn on against a grounded bit line
/// for the pulse duration and loses charge with time constant R_on·C_mem.
pub fn hs_discharge_factor() -> f64 {
    (-WRITE_PULSE_S / (R_ON_LL * C_MEM_NOMINAL)).exp()
}

/// Capacitive coupling bump for WBL-selected (WWL-inactive) cells (Fig. 4a
/// blue case): ΔV = C_gd/(C_gd+C_mem)·V_dd with C_gd ≈ the Cu-Cu-scale
/// overlap cap. Small (tens of mV) and non-cumulative (it rides on the
/// stored value during the pulse only); we track it as a bounded jitter.
pub fn wbl_coupling_bump() -> f64 {
    let c_gd = 0.5e-15;
    c_gd / (c_gd + C_MEM_NOMINAL) * VDD
}

/// Simulate the crossbar on `events` (sorted). `decay` is the nominal cell
/// decay; `jitter_seed` adds per-hit comparator-scale measurement noise.
pub fn simulate_half_select(
    events: &[LabeledEvent],
    res: Resolution,
    decay: &DoubleExp,
    jitter_seed: u64,
) -> HalfSelectStats {
    let n = res.pixels();
    // Per-cell state: last write time (µs, 0 = never) and the multiplicative
    // survival factor applied by half-select discharges since that write.
    let mut t_write = vec![0u64; n];
    let mut survival = vec![1.0f64; n];
    let mut first_hs: Vec<f64> = Vec::new();
    let mut had_hs_since_write = vec![false; n];
    let mut disturbed = vec![false; n];
    let mut dv_vs_dt = Vec::new();
    let mut rng = Pcg64::with_stream(jitter_seed, 0x45);
    let alpha = hs_discharge_factor();

    // Row index → columns of recently written cells (for the row sweep we
    // just walk the whole row; resolutions here are small enough).
    for le in events {
        let e = le.ev;
        let (ex, ey) = (e.x as usize, e.y as usize);
        // 1) The write itself: full select.
        let i = ey * res.width as usize + ex;
        t_write[i] = e.t.max(1);
        survival[i] = 1.0;
        had_hs_since_write[i] = false;
        // 2) Green half-select: all other cells in the active row leak
        //    through their ON switch for the pulse duration.
        for x in 0..res.width as usize {
            if x == ex {
                continue;
            }
            let j = ey * res.width as usize + x;
            if t_write[j] == 0 {
                continue;
            }
            let dt = (e.t.saturating_sub(t_write[j])) as f64 * 1e-6;
            let v_before = survival[j] * decay.eval(dt);
            survival[j] *= alpha;
            let dv = v_before * (1.0 - alpha) + rng.normal_ms(0.0, 1e-4);
            if !had_hs_since_write[j] {
                had_hs_since_write[j] = true;
                first_hs.push(dt);
            }
            disturbed[j] = true;
            // Subsample the scatter to keep memory bounded.
            if dv_vs_dt.len() < 200_000 {
                dv_vs_dt.push((dt, dv.max(0.0)));
            }
        }
        // 3) Blue half-select (same column, WWL off): coupling bump only —
        //    bounded, non-cumulative; modeled as no stored-state change.
    }

    // Final TS error vs the ideal (no half-select) array.
    let t_end = events.last().map(|e| e.ev.t).unwrap_or(0);
    let mut se = 0.0;
    let mut cnt = 0usize;
    for i in 0..n {
        if t_write[i] == 0 {
            continue;
        }
        let dt = (t_end - t_write[i]) as f64 * 1e-6;
        let ideal = decay.eval(dt);
        let actual = survival[i] * ideal;
        se += (ideal - actual) * (ideal - actual);
        cnt += 1;
    }
    let disturbed_cnt = disturbed.iter().filter(|&&d| d).count();
    HalfSelectStats {
        dv_vs_dt,
        first_hs_times: first_hs,
        ts_rmse: if cnt > 0 { (se / cnt as f64).sqrt() } else { 0.0 },
        disturbed_fraction: disturbed_cnt as f64 / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::montecarlo::FittedBank;
    use crate::events::event::{Event, Polarity};

    fn mk(t: u64, x: u16, y: u16) -> LabeledEvent {
        LabeledEvent { ev: Event::new(t, x, y, Polarity::On), is_signal: true }
    }

    #[test]
    fn hs_discharge_is_severe() {
        // 5 ns pulse over R_on·C = 0.4 ns ⇒ the held charge is essentially
        // gone after one same-row write (the paper's "substantial decrease").
        assert!(hs_discharge_factor() < 1e-5);
    }

    #[test]
    fn coupling_bump_small() {
        let dv = wbl_coupling_bump();
        assert!((0.01..0.05).contains(&dv), "bump {dv}");
    }

    #[test]
    fn earlier_half_select_larger_dv() {
        // Fig. 4c: ΔV decreases with Δt (the earlier the half-select after a
        // write, the more voltage there is to lose).
        let decay = FittedBank::nominal(20e-15);
        let res = Resolution::new(8, 4);
        // Write cell (0,0), then trigger same-row writes at two delays.
        let evs = vec![mk(1, 0, 0), mk(2_001, 3, 0), mk(1, 1, 1), mk(25_001, 4, 1)];
        let stats = simulate_half_select(&evs, res, &decay, 1);
        // Two half-select hits recorded (one per victim).
        let hit_early = stats.dv_vs_dt.iter().find(|(dt, _)| *dt < 0.01).unwrap();
        let hit_late = stats.dv_vs_dt.iter().find(|(dt, _)| *dt > 0.02).unwrap();
        assert!(
            hit_early.1 > hit_late.1,
            "early ΔV {} should exceed late ΔV {}",
            hit_early.1,
            hit_late.1
        );
    }

    #[test]
    fn no_same_row_traffic_no_disturbance() {
        let decay = FittedBank::nominal(20e-15);
        let res = Resolution::new(4, 4);
        // All writes on distinct rows → no half-select.
        let evs = vec![mk(1, 0, 0), mk(100, 1, 1), mk(200, 2, 2)];
        let stats = simulate_half_select(&evs, res, &decay, 2);
        assert!(stats.first_hs_times.is_empty());
        assert!(stats.ts_rmse < 1e-9);
        assert_eq!(stats.disturbed_fraction, 0.0);
    }

    #[test]
    fn dense_rows_disturb_ts() {
        let decay = FittedBank::nominal(20e-15);
        let res = Resolution::new(16, 2);
        let mut evs = Vec::new();
        for k in 0..64u64 {
            evs.push(mk(1 + k * 500, (k % 16) as u16, 0));
        }
        let stats = simulate_half_select(&evs, res, &decay, 3);
        assert!(stats.ts_rmse > 0.05, "rmse={}", stats.ts_rmse);
        assert!(!stats.first_hs_times.is_empty());
    }

    #[test]
    fn fig7_report_breakdown_shape() {
        // Paper Fig. 7c: encoder/decoder ≈ 53.8 %, buffers ≈ 45.5 % of 2D
        // power; our component model must land in those neighbourhoods.
        let g = ArrayGeometry::new(Resolution::QVGA);
        let r = report(&g, &Workload::default());
        let enc = r.power.share_percent("encoder/decoder");
        let buf = r.power.share_percent("line buffers");
        assert!((40.0..65.0).contains(&enc), "enc/dec share {enc}");
        assert!((35.0..55.0).contains(&buf), "buffer share {buf}");
        // Array is a small fraction in 2D (as in the paper).
        assert!(r.power.share_percent("isc-array write") < 5.0);
    }

    #[test]
    fn fig7_headline_ratios() {
        // Paper: 69× power, 1.9× area, 2.2× delay (2D/3D). The shape
        // requirement: same order, right neighbourhood.
        let g = ArrayGeometry::new(Resolution::QVGA);
        let w = Workload::default();
        let r2 = report(&g, &w);
        let r3 = super::super::arch3d::report(&g, &w);
        let (p, a, d) = ArchReport::ratios(&r2, &r3);
        assert!((50.0..95.0).contains(&p), "power ratio {p}");
        assert!((1.7..2.2).contains(&a), "area ratio {a}");
        assert!((2.0..2.4).contains(&d), "delay ratio {d}");
    }
}
