//! Dataset → time-surface frame conversion for the classifier pipeline.

use crate::events::dataset::{Dataset, Sample};
use crate::events::Event;
use crate::isc::IscConfig;
use crate::tsurface::{
    Ebbi, EventCount, EventSink, FrameSource, IdealTs, IscTs, QuantizedSae, Representation, Tore,
};
use crate::util::grid::Grid;
use crate::util::image::resize_bilinear;

/// Which representation produces the CNN input frames — the Table II
/// comparison axis (ideal software TS vs the analog hardware TS vs the
/// cheaper/costlier baselines).
#[derive(Clone, Debug)]
pub enum SurfaceKind {
    /// The 3DS-ISC analog array with mismatch (the paper's system).
    Isc(IscConfig),
    /// Ideal exponential TS from full-precision timestamps (τ µs).
    Ideal { tau_us: f64 },
    /// SAE in n-bit counters with wraparound (digital SRAM baseline).
    Quantized { bits: u32, tau_us: f64 },
    /// Event-count image (n_C-bit).
    Count { bits: u32 },
    /// Binary image.
    Binary,
    /// TORE volume collapsed to one channel (FIFO depth k).
    Tore { k: usize },
}

impl SurfaceKind {
    pub fn name(&self) -> &'static str {
        match self {
            SurfaceKind::Isc(_) => "3DS-ISC",
            SurfaceKind::Ideal { .. } => "ideal-TS",
            SurfaceKind::Quantized { .. } => "quantized-SAE",
            SurfaceKind::Count { .. } => "event-count",
            SurfaceKind::Binary => "EBBI",
            SurfaceKind::Tore { .. } => "TORE",
        }
    }

    /// Instantiate the representation behind this kind (also used by the
    /// reconstruction driver — one registry for every frame consumer).
    pub fn build(&self, res: crate::events::Resolution) -> Box<dyn Representation> {
        match self {
            SurfaceKind::Isc(cfg) => Box::new(IscTs::new(res, cfg.clone())),
            SurfaceKind::Ideal { tau_us } => Box::new(IdealTs::new(res, *tau_us)),
            SurfaceKind::Quantized { bits, tau_us } => {
                Box::new(QuantizedSae::new(res, *bits, *tau_us))
            }
            SurfaceKind::Count { bits } => Box::new(EventCount::new(res, *bits)),
            SurfaceKind::Binary => Box::new(Ebbi::new(res)),
            SurfaceKind::Tore { k } => Box::new(Tore::new(res, *k, 100.0, 1e6)),
        }
    }
}

/// One CNN input frame with provenance.
#[derive(Clone, Debug)]
pub struct Frame {
    /// Flattened 32×32 f32 input.
    pub pixels: Vec<f32>,
    pub label: usize,
    /// Index of the originating sample (for majority-vote video accuracy).
    pub sample_id: usize,
}

/// A frame dataset split.
#[derive(Clone, Debug, Default)]
pub struct FrameSet {
    pub frames: Vec<Frame>,
    pub n_classes: usize,
    pub n_samples: usize,
}

/// Cut every sample into `window_us` windows and render one frame per
/// window through `kind`'s representation, resized to `side`×`side`.
pub fn build_frames(
    samples: &[Sample],
    res: crate::events::Resolution,
    n_classes: usize,
    kind: &SurfaceKind,
    window_us: u64,
    side: usize,
) -> FrameSet {
    let mut out = FrameSet { frames: Vec::new(), n_classes, n_samples: samples.len() };
    // Reused across samples/windows: the staged event batch and the
    // full-resolution frame buffer (zero steady-state allocations on the
    // ingest/readout path).
    let mut staged: Vec<Event> = Vec::new();
    let mut frame_buf = Grid::new(1, 1, 0.0f64);
    for (sid, s) in samples.iter().enumerate() {
        let mut rep = kind.build(res);
        let mut t_next = window_us;
        let mut emit = |rep: &mut Box<dyn Representation>,
                        staged: &mut Vec<Event>,
                        frame_buf: &mut Grid<f64>,
                        t: u64,
                        frames: &mut Vec<Frame>| {
            rep.ingest_batch(staged);
            staged.clear();
            rep.frame_into(frame_buf, t);
            let small = resize_bilinear(frame_buf, side, side);
            frames.push(Frame {
                pixels: small.as_slice().iter().map(|&v| v as f32).collect(),
                label: s.label,
                sample_id: sid,
            });
            rep.reset_window();
        };
        for le in &s.events {
            while le.ev.t > t_next && t_next <= s.duration_us {
                emit(&mut rep, &mut staged, &mut frame_buf, t_next, &mut out.frames);
                t_next += window_us;
            }
            staged.push(le.ev);
        }
        rep.ingest_batch(&staged);
        staged.clear();
        while t_next <= s.duration_us {
            emit(&mut rep, &mut staged, &mut frame_buf, t_next, &mut out.frames);
            t_next += window_us;
        }
    }
    out
}

/// Convenience: frames for both splits of a generated dataset.
pub fn dataset_frames(
    ds: &Dataset,
    kind: &SurfaceKind,
    window_us: u64,
    side: usize,
) -> (FrameSet, FrameSet) {
    (
        build_frames(&ds.train, ds.res, ds.n_classes, kind, window_us, side),
        build_frames(&ds.test, ds.res, ds.n_classes, kind, window_us, side),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::dataset::{generate, Family, GenOptions};

    fn tiny() -> crate::events::dataset::Dataset {
        generate(
            Family::NMnist,
            GenOptions {
                train_per_class: 1,
                test_per_class: 1,
                duration_s: 0.1,
                noise_hz: 0.0,
                seed: 3,
            },
        )
    }

    #[test]
    fn frames_per_sample_match_windows() {
        let ds = tiny();
        let fs = build_frames(&ds.train, ds.res, 10, &SurfaceKind::Ideal { tau_us: 24_000.0 },
                              50_000, 32);
        // 100 ms / 50 ms = 2 frames per sample, 10 samples.
        assert_eq!(fs.frames.len(), 20);
        assert!(fs.frames.iter().all(|f| f.pixels.len() == 32 * 32));
        assert!(fs.frames.iter().all(|f| f.label < 10));
    }

    #[test]
    fn isc_and_ideal_frames_correlate() {
        let ds = tiny();
        let a = build_frames(&ds.train, ds.res, 10,
                             &SurfaceKind::Isc(crate::isc::IscConfig::default()), 50_000, 32);
        let b = build_frames(&ds.train, ds.res, 10,
                             &SurfaceKind::Ideal { tau_us: 24_000.0 }, 50_000, 32);
        assert_eq!(a.frames.len(), b.frames.len());
        // Averaged over all frames, the two inputs should be highly
        // correlated — the paper's core parity claim at the input level.
        let xs: Vec<f64> = a.frames.iter().flat_map(|f| f.pixels.iter().map(|&v| v as f64)).collect();
        let ys: Vec<f64> = b.frames.iter().flat_map(|f| f.pixels.iter().map(|&v| v as f64)).collect();
        let (_, _, r2) = crate::util::stats::linreg(&xs, &ys);
        assert!(r2 > 0.7, "ISC vs ideal frame r² = {r2}");
    }

    #[test]
    fn frame_values_bounded() {
        let ds = tiny();
        for kind in [
            SurfaceKind::Binary,
            SurfaceKind::Count { bits: 4 },
            SurfaceKind::Tore { k: 3 },
            SurfaceKind::Quantized { bits: 16, tau_us: 24_000.0 },
        ] {
            let fs = build_frames(&ds.test, ds.res, 10, &kind, 50_000, 32);
            for f in &fs.frames {
                for &v in &f.pixels {
                    assert!((0.0..=1.0 + 1e-6).contains(&(v as f64)), "{}", kind.name());
                }
            }
        }
    }
}
