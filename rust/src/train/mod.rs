//! Classification training driver (paper Sec. IV-D / Table II).
//!
//! Dataset event streams → time-surface frames (from a configurable
//! representation: the ISC analog array, the ideal TS, quantized SAE,
//! event count, TORE…) → 32×32 inputs → the AOT `classifier_train`
//! artifact executed in a loop by this Rust driver. Python never runs.

#[cfg(feature = "pjrt")]
pub mod driver;
pub mod frames;

#[cfg(feature = "pjrt")]
pub use driver::{train_classifier, TrainConfig, TrainResult};
pub use frames::{build_frames, FrameSet, SurfaceKind};
