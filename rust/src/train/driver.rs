//! The classifier training loop over the AOT `classifier_train` artifact.
//!
//! The driver shuffles frames into fixed-size batches, executes the
//! train-step artifact (params and momenta round-trip as literals; only
//! the scalar loss is inspected per step), logs the loss curve, and
//! evaluates frame + majority-vote video accuracy with `classifier_fwd`.

use super::frames::FrameSet;
use crate::metrics::frame_and_video_accuracy;
use crate::runtime::pjrt::{lit_f32, lit_i32, lit_scalar, to_vec_f32, Runtime};
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Result};

/// Fixed by the lowered artifact (python/compile/model.py).
pub const BATCH: usize = 64;
pub const SIDE: usize = 32;
pub const N_CLASSES: usize = 10;

/// Training options.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Print a loss line every `log_every` steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 150, lr: 0.03, seed: 42, log_every: 25 }
    }
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// (step, loss) — the logged loss curve.
    pub loss_curve: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub frame_accuracy: f64,
    pub video_accuracy: f64,
    pub steps: usize,
}

/// Train the classifier on `train` frames, evaluate on `test` frames.
pub fn train_classifier(
    rt: &mut Runtime,
    train: &FrameSet,
    test: &FrameSet,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    if train.frames.is_empty() {
        return Err(anyhow!("no training frames"));
    }
    let mut params = rt.load_params("classifier_params")?;
    let n_params = params.len();
    let mut moms: Vec<xla::Literal> = params
        .iter()
        .map(|p| zeros_like(p))
        .collect::<Result<Vec<_>>>()?;

    let mut rng = Pcg64::with_stream(cfg.seed, 0x7a41);
    let mut order: Vec<usize> = (0..train.frames.len()).collect();
    let mut cursor = order.len(); // force shuffle on first use
    let mut loss_curve = Vec::new();
    let mut final_loss = f32::NAN;

    for step in 0..cfg.steps {
        // Assemble the next batch (reshuffle each epoch).
        let mut xs = Vec::with_capacity(BATCH * SIDE * SIDE);
        let mut ys = Vec::with_capacity(BATCH);
        for _ in 0..BATCH {
            if cursor >= order.len() {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            let f = &train.frames[order[cursor]];
            cursor += 1;
            xs.extend_from_slice(&f.pixels);
            ys.push(f.label as i32);
        }
        let x = lit_f32(&xs, &[BATCH as i64, 1, SIDE as i64, SIDE as i64])?;
        let y = lit_i32(&ys, &[BATCH as i64])?;
        // Cosine decay with a short linear warmup: SGD+momentum at a fixed
        // lr is unstable on some dataset/surface combinations; the schedule
        // is driver-side state (lr is an input of the AOT train step).
        let warmup = (cfg.steps / 20).max(1);
        let lr_now = if step < warmup {
            cfg.lr * (step + 1) as f32 / warmup as f32
        } else {
            let f = (step - warmup) as f32 / (cfg.steps - warmup).max(1) as f32;
            let cos = 0.5 * (1.0 + (std::f32::consts::PI * f).cos());
            cfg.lr * (0.1 + 0.9 * cos)
        };

        // One artifact execution: (p.., m.., x, y, lr) -> (p'.., m'.., loss).
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 * n_params + 3);
        inputs.append(&mut params);
        inputs.append(&mut moms);
        inputs.push(x);
        inputs.push(y);
        inputs.push(lit_scalar(lr_now));
        let exe = rt.load("classifier_train")?;
        let mut out = exe.run(&inputs)?;
        if out.len() != 2 * n_params + 1 {
            return Err(anyhow!("train artifact returned {} outputs", out.len()));
        }
        let loss_lit = out.pop().unwrap();
        final_loss = loss_lit.get_first_element::<f32>()?;
        moms = out.split_off(n_params);
        params = out;

        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            loss_curve.push((step, final_loss));
        }
    }

    // ---- evaluation ------------------------------------------------------
    let (frame_accuracy, video_accuracy) = evaluate(rt, &params, test)?;
    Ok(TrainResult {
        loss_curve,
        final_loss,
        frame_accuracy,
        video_accuracy,
        steps: cfg.steps,
    })
}

/// Frame + video accuracy of `params` on a frame set.
pub fn evaluate(
    rt: &mut Runtime,
    params: &[xla::Literal],
    test: &FrameSet,
) -> Result<(f64, f64)> {
    if test.frames.is_empty() {
        return Ok((0.0, 0.0));
    }
    let mut preds = vec![0usize; test.frames.len()];
    let mut i = 0;
    while i < test.frames.len() {
        let mut xs = Vec::with_capacity(BATCH * SIDE * SIDE);
        let n_real = (test.frames.len() - i).min(BATCH);
        for k in 0..BATCH {
            let f = &test.frames[(i + k).min(test.frames.len() - 1)];
            xs.extend_from_slice(&f.pixels);
        }
        let x = lit_f32(&xs, &[BATCH as i64, 1, SIDE as i64, SIDE as i64])?;
        let mut inputs: Vec<xla::Literal> = params
            .iter()
            .map(clone_literal)
            .collect::<Result<Vec<_>>>()?;
        inputs.push(x);
        let exe = rt.load("classifier_fwd")?;
        let out = exe.run(&inputs)?;
        let logits = to_vec_f32(&out[0])?;
        for k in 0..n_real {
            let row = &logits[k * N_CLASSES..(k + 1) * N_CLASSES];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            preds[i + k] = arg;
        }
        i += n_real;
    }
    // Group by sample for video accuracy.
    let mut by_sample: Vec<(usize, Vec<usize>)> = Vec::new();
    for _ in 0..test.n_samples {
        by_sample.push((usize::MAX, Vec::new()));
    }
    for (f, &p) in test.frames.iter().zip(&preds) {
        by_sample[f.sample_id].0 = f.label;
        by_sample[f.sample_id].1.push(p);
    }
    by_sample.retain(|(l, v)| *l != usize::MAX && !v.is_empty());
    Ok(frame_and_video_accuracy(&by_sample, N_CLASSES))
}

fn zeros_like(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    let n: usize = shape.dims().iter().map(|&d| d as usize).product();
    lit_f32(&vec![0.0; n], shape.dims())
}

fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    let data = l.to_vec::<f32>()?;
    lit_f32(&data, shape.dims())
}
