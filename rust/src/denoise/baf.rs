//! Background-Activity Filter (BAF) baseline [Delbruck 2008-style]:
//! keep an event iff *any* 8-neighbour fired within τ. The classic cheap
//! denoiser the STCF improves upon — included as the comparison baseline
//! for the denoise experiments.

use crate::events::{LabeledEvent, Resolution};
use crate::metrics::Scored;
use crate::tsurface::sae::Sae;
use crate::tsurface::EventSink;

/// BAF parameters.
#[derive(Clone, Copy, Debug)]
pub struct BafParams {
    pub tau_us: u64,
}

impl Default for BafParams {
    fn default() -> Self {
        Self { tau_us: 24_000 }
    }
}

/// Run the BAF; score = 1 if any 8-neighbour is recent, else 0 (we also
/// expose the most-recent-neighbour age inverted as a soft score so a ROC
/// can be traced).
pub fn run(events: &[LabeledEvent], res: Resolution, prm: &BafParams) -> Vec<Scored> {
    let mut sae = Sae::new(res);
    let mut out = Vec::with_capacity(events.len());
    for le in events {
        let e = le.ev;
        let (ex, ey) = (e.x as i64, e.y as i64);
        let mut best_age = u64::MAX;
        for dy in -1..=1i64 {
            for dx in -1..=1i64 {
                if dx == 0 && dy == 0 {
                    continue;
                }
                let (x, y) = (ex + dx, ey + dy);
                if x < 0 || y < 0 || x >= res.width as i64 || y >= res.height as i64 {
                    continue;
                }
                let tw = sae.last(x as u16, y as u16);
                if tw != 0 && e.t >= tw {
                    best_age = best_age.min(e.t - tw);
                }
            }
        }
        // Soft score: recency of the freshest neighbour within τ (0 if none).
        let score = if best_age <= prm.tau_us {
            1.0 - best_age as f64 / prm.tau_us as f64
        } else {
            0.0
        };
        out.push(Scored { score, is_signal: le.is_signal });
        sae.ingest(&e);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::event::{Event, Polarity};
    use crate::metrics::roc;

    fn le(t: u64, x: u16, y: u16, sig: bool) -> LabeledEvent {
        LabeledEvent { ev: Event::new(t, x, y, Polarity::On), is_signal: sig }
    }

    #[test]
    fn isolated_event_scores_zero() {
        let res = Resolution::new(8, 8);
        let s = run(&[le(100, 4, 4, false)], res, &BafParams::default());
        assert_eq!(s[0].score, 0.0);
    }

    #[test]
    fn neighbour_recency_raises_score() {
        let res = Resolution::new(8, 8);
        let s = run(
            &[le(100, 4, 4, true), le(200, 5, 4, true), le(30_000, 3, 4, true)],
            res,
            &BafParams::default(),
        );
        assert!(s[1].score > 0.9); // 100 µs old neighbour
        assert!(s[2].score < s[1].score); // 29.9 ms old neighbour
    }

    #[test]
    fn both_filters_discriminate_at_protocol_noise() {
        // At the DND21 protocol's 5 Hz/pixel both filters separate signal
        // from noise clearly. (At pathological noise densities the STCF's
        // 24 ms count saturates while BAF's recency score degrades more
        // gracefully — covered by the Fig. 10 sweep harness, not asserted
        // here.)
        let res = Resolution::new(48, 48);
        let scene = crate::events::scene::EdgeScene::new(90.0, 21);
        let signal = crate::events::v2e::convert(
            &scene,
            res,
            crate::events::v2e::DvsParams::default(),
            0.5,
        );
        let noisy = crate::events::noise::contaminate(&signal, res, 5.0, 0.5, 17);
        let auc_baf = roc(&run(&noisy, res, &BafParams::default())).auc;
        let mut b = crate::denoise::stcf::StcfBackend::ideal(res);
        let r = crate::denoise::stcf::run(
            &mut b,
            &noisy,
            &crate::denoise::stcf::StcfParams::default(),
        );
        let auc_stcf = roc(&r.scored).auc;
        assert!(auc_baf > 0.65, "BAF AUC {auc_baf}");
        assert!(auc_stcf > 0.65, "STCF AUC {auc_stcf}");
    }
}
