//! Event-stream denoising: the STCF (paper Sec. IV-C) over ideal and
//! ISC-analog backends, plus the BAF baseline.
//!
//! ## Support-scan complexity (per scored event, patch (2r+1)²)
//!
//! | scan | per patch row | typical cost | memory | where |
//! |---|---|---|---|---|
//! | naive patch scan | 2r+1 indexed point reads (2D index math + bounds checks each) | O((2r+1)²) always | O(H·W) dense surface | [`support_count_naive`] — reference |
//! | row-sliced | one contiguous stamp/param slice walk | O((2r+1)²) but bounds-free, cache-linear | O(H·W) dense surface | [`support_count_rows`] |
//! | bitmask-popcount | 1–2 window words × live epoch buckets (≤ 4) `u64` loads, then exact confirmation of set-bit runs only | O((2r+1) · buckets) word loads + O(recent) confirms — all-zero rows cost no stamp reads | O(H·W) + H·W/8 bits × buckets | [`support_count_bitmask`] via [`crate::util::bitplane::RecencyPlane`] |
//! | hashed probe walk | 2r+1 set-associative probes | O((2r+1)²) hashed probes — no dense surface at all | **O(capacity)**, resolution-independent ([`StcfBackend::Cache`]) | [`crate::util::sparse::SparseRecencyStore`] — bit-for-bit ≡ dense while the probed neighborhood survives in-cache; evictions only ever *undercount* |
//!
//! [`support_count`] picks the bitmask tier whenever the backend's
//! recency plane covers the query window and falls back to the
//! row-sliced scan otherwise; all tiers are bit-for-bit equivalent on
//! causal (stream-head) queries — `tests/stcf_equiv.rs` asserts it.
//!
//! Scoring itself parallelizes across horizontal bands with replicated
//! halo rows ([`sharded::StcfShardPool`]): end-to-end denoised
//! throughput scales with cores while keeping the serial filter's exact
//! scores — bit-for-bit for both backends, since ISC band arrays are
//! exact mismatch windows of the full-sensor array (position-stable
//! assignment, [`crate::isc::param_index_at`]).

pub mod baf;
pub mod sharded;
pub mod stcf;

pub use sharded::{stage_items, BandScorer, ScoreItem, ShardBackend, ShardTally, StcfShardPool};
pub use stcf::{
    run as run_stcf, support_count, support_count_bitmask, support_count_naive,
    support_count_rows, StcfBackend, StcfParams, StcfRun,
};
