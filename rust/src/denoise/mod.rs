//! Event-stream denoising: the STCF (paper Sec. IV-C) over ideal and
//! ISC-analog backends, plus the BAF baseline.

pub mod baf;
pub mod stcf;

pub use stcf::{
    run as run_stcf, support_count, support_count_naive, StcfBackend, StcfParams, StcfRun,
};
