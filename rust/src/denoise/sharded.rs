//! Band-sharded STCF scoring: the denoise stage as a worker pool.
//!
//! The serial [`super::stcf::run`] scores every event on the caller's
//! thread — the last serial hot path of a denoised pipeline once writes
//! (router shards) and readout (row-parallel rendering) scale with
//! cores. This module moves scoring onto worker shards, each owning a
//! horizontal band of the sensor exactly as the write router cuts it
//! ([`crate::util::parallel::band_layout`]).
//!
//! ## Halo replication
//!
//! A support patch of radius `r` centred in one band can reach up to
//! `r` rows into the neighbouring bands, so each shard's backend covers
//! its band **plus `r` replicated halo rows** on each side. The
//! dispatcher sends every event to the shard that owns its row (a
//! `Score` item) and *duplicates* it to every shard whose halo region
//! contains the row (`Halo` items — write-only ingests, never scored).
//! Each shard therefore sees, in stream order, exactly the events whose
//! row intersects its extended region, and processes them in the same
//! causal score-then-write order as the serial filter: a `Score` item
//! is scored against the shard surface *before* it (or any later event)
//! is written. Scores are consequently **bit-for-bit identical** to the
//! serial reference for the ideal backend and for mismatch-free ISC
//! configs; with cell mismatch enabled, per-shard mismatch maps differ
//! from a single full-sensor array (the same caveat as the write
//! router's per-shard seeds).
//!
//! Batches are scored synchronously: [`StcfShardPool::score_batch`]
//! fans a time-sorted batch out, the shards score their slices
//! concurrently, and the reply merge restores input order — so the
//! caller (the coordinator pipeline) keeps its frame-boundary
//! bookkeeping unchanged while the patch scans run on every core.

use super::stcf::{support_count, StcfBackend, StcfParams, StcfRun};
use crate::events::{Event, LabeledEvent, Resolution};
use crate::isc::IscConfig;
use crate::metrics::Scored;
use crate::util::parallel::band_layout;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

/// How each denoise shard builds its band(+halo) backend.
#[derive(Clone, Debug)]
pub enum ShardBackend {
    /// Full-precision SAE planes — sharded scoring is bit-for-bit ≡ the
    /// serial ideal backend.
    Ideal,
    /// ISC analog arrays (per-shard seeds derived as in the write
    /// router). Bit-for-bit ≡ serial when `mismatch` is `None`; with
    /// mismatch the per-shard maps differ by construction.
    Isc(IscConfig),
}

/// Per-shard outcome counters, returned by [`StcfShardPool::shutdown`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardTally {
    /// Events this shard owned and scored.
    pub scored: u64,
    /// Scored events at or above the keep threshold.
    pub kept: u64,
    /// Scored events below the keep threshold.
    pub dropped: u64,
    /// Write-only halo ingests (duplicates of border events owned by a
    /// neighbouring shard).
    pub halo_ingests: u64,
}

/// One time-ordered work item for a shard.
enum Item {
    /// Score this event (index into the dispatched batch), then ingest it.
    Score(u32, Event),
    /// Ingest only: a halo duplicate owned by another shard.
    Halo(Event),
}

enum Job {
    Batch(Vec<Item>),
    Stop,
}

struct Reply {
    scores: Vec<(u32, u32)>,
}

/// The denoise shard pool. Construct once, feed time-sorted batches
/// through [`StcfShardPool::score_batch`] / [`StcfShardPool::filter_batch`],
/// then [`StcfShardPool::shutdown`] for the tallies.
pub struct StcfShardPool {
    senders: Vec<SyncSender<Job>>,
    handles: Vec<JoinHandle<ShardTally>>,
    reply_rx: Receiver<Reply>,
    res: Resolution,
    prm: StcfParams,
    band_h: usize,
    radius: usize,
    /// Per-shard item lists for the dispatch in progress (shipped whole
    /// to the shard, so each dispatch hands its allocation over).
    staging: Vec<Vec<Item>>,
}

impl StcfShardPool {
    /// Pool of (at most) `n_shards` denoise workers over `res`, each
    /// backed per `backend`. The shard bands match
    /// [`crate::util::parallel::band_layout`]; each backend additionally
    /// covers `prm.radius` halo rows per side.
    pub fn new(res: Resolution, n_shards: usize, backend: ShardBackend, prm: StcfParams) -> Self {
        let h = res.height as usize;
        let (band_h, n) = band_layout(h, n_shards);
        let radius = prm.radius as usize;
        let (reply_tx, reply_rx) = sync_channel::<Reply>(n);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx): (SyncSender<Job>, Receiver<Job>) = sync_channel(2);
            let band_start = shard * band_h;
            let band_end = (band_start + band_h).min(h) - 1;
            let lo = band_start.saturating_sub(radius);
            let hi = (band_end + radius).min(h - 1);
            let local = Resolution::new(res.width, (hi - lo + 1) as u16);
            let backend = backend.clone();
            let reply = reply_tx.clone();
            handles.push(std::thread::spawn(move || {
                // Built on the worker so heavyweight setup (the ISC
                // Monte-Carlo bank fit) also runs in parallel.
                let mut b = match backend {
                    ShardBackend::Ideal => StcfBackend::ideal_with_window(local, prm.tau_tw_us),
                    ShardBackend::Isc(mut cfg) => {
                        cfg.seed = crate::util::parallel::shard_seed(cfg.seed, shard);
                        StcfBackend::isc(local, cfg, prm.tau_tw_us)
                    }
                };
                let mut tally = ShardTally::default();
                for job in rx {
                    let items = match job {
                        Job::Batch(items) => items,
                        Job::Stop => break,
                    };
                    let mut scores = Vec::new();
                    for item in &items {
                        match item {
                            Item::Score(idx, ev) => {
                                let mut e = *ev;
                                e.y -= lo as u16;
                                let s = support_count(&b, &e, &prm);
                                scores.push((*idx, s));
                                b.ingest(&e, &prm);
                                tally.scored += 1;
                                if s >= prm.threshold {
                                    tally.kept += 1;
                                } else {
                                    tally.dropped += 1;
                                }
                            }
                            Item::Halo(ev) => {
                                let mut e = *ev;
                                e.y -= lo as u16;
                                b.ingest(&e, &prm);
                                tally.halo_ingests += 1;
                            }
                        }
                    }
                    if reply.send(Reply { scores }).is_err() {
                        break; // pool dropped mid-batch
                    }
                }
                tally
            }));
            senders.push(tx);
        }
        // The pool holds no reply sender of its own: once every worker
        // clone is gone, `reply_rx.recv()` reports the death instead of
        // blocking forever.
        drop(reply_tx);
        Self {
            senders,
            handles,
            reply_rx,
            res,
            prm,
            band_h,
            radius,
            staging: (0..n).map(|_| Vec::new()).collect(),
        }
    }

    /// Effective shard count (≤ requested; see `band_layout`).
    pub fn n_shards(&self) -> usize {
        self.senders.len()
    }

    /// The filter parameters every shard scores with.
    pub fn params(&self) -> &StcfParams {
        &self.prm
    }

    #[inline]
    fn shard_for(&self, y: usize) -> usize {
        (y / self.band_h).min(self.senders.len() - 1)
    }

    /// Score a time-sorted batch of on-sensor events. `scores` is
    /// cleared and filled with one support count per event, in input
    /// order — identical to calling [`support_count`] +
    /// [`StcfBackend::ingest`] serially over the whole stream (see the
    /// module docs for the backend caveats). Blocks until every shard
    /// has finished its slice.
    pub fn score_batch(&mut self, batch: &[LabeledEvent], scores: &mut Vec<u32>) {
        scores.clear();
        scores.resize(batch.len(), 0);
        let h = self.res.height as usize;
        for (k, le) in batch.iter().enumerate() {
            let e = &le.ev;
            debug_assert!(self.res.contains(e.x, e.y), "off-sensor event {e:?}");
            let y = e.y as usize;
            let own = self.shard_for(y);
            let s_min = self.shard_for(y.saturating_sub(self.radius));
            let s_max = self.shard_for((y + self.radius).min(h - 1));
            for s in s_min..=s_max {
                if s == own {
                    self.staging[s].push(Item::Score(k as u32, *e));
                } else {
                    self.staging[s].push(Item::Halo(*e));
                }
            }
        }
        let mut in_flight = 0usize;
        for s in 0..self.senders.len() {
            if self.staging[s].is_empty() {
                continue;
            }
            let items = std::mem::take(&mut self.staging[s]);
            self.senders[s].send(Job::Batch(items)).expect("denoise shard died");
            in_flight += 1;
        }
        for _ in 0..in_flight {
            let r = self.reply_rx.recv().expect("denoise shard died");
            for &(idx, s) in &r.scores {
                scores[idx as usize] = s;
            }
        }
    }

    /// Score `batch` and append the events passing the keep threshold to
    /// `kept` in input order. `scores` is scratch (reused across calls).
    pub fn filter_batch(
        &mut self,
        batch: &[LabeledEvent],
        scores: &mut Vec<u32>,
        kept: &mut Vec<LabeledEvent>,
    ) {
        self.score_batch(batch, scores);
        for (le, &s) in batch.iter().zip(scores.iter()) {
            if s >= self.prm.threshold {
                kept.push(*le);
            }
        }
    }

    /// Convenience mirror of the serial [`super::stcf::run`]: score a
    /// whole sorted stream (in pool-sized batches — the split does not
    /// change any score) and return the same [`StcfRun`] shape.
    pub fn run(&mut self, events: &[LabeledEvent]) -> StcfRun {
        let mut scores = Vec::new();
        let mut scored = Vec::with_capacity(events.len());
        let mut kept = Vec::new();
        for chunk in events.chunks(4_096) {
            self.score_batch(chunk, &mut scores);
            for (le, &s) in chunk.iter().zip(&scores) {
                scored.push(Scored { score: s as f64, is_signal: le.is_signal });
                if s >= self.prm.threshold {
                    kept.push(*le);
                }
            }
        }
        StcfRun { scored, kept }
    }

    /// Stop all shards and collect their tallies (index = shard).
    pub fn shutdown(mut self) -> Vec<ShardTally> {
        for s in &self.senders {
            let _ = s.send(Job::Stop);
        }
        self.handles.drain(..).map(|h| h.join().expect("join denoise shard")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::denoise::stcf;
    use crate::events::Polarity;

    fn le(t: u64, x: u16, y: u16) -> LabeledEvent {
        LabeledEvent { ev: Event::new(t, x, y, Polarity::On), is_signal: true }
    }

    /// Deterministic stream that hits every row, including band borders.
    fn stream(res: Resolution, n: u64) -> Vec<LabeledEvent> {
        (0..n)
            .map(|k| {
                le(
                    1 + k * 211,
                    (k * 7 % res.width as u64) as u16,
                    (k * 5 % res.height as u64) as u16,
                )
            })
            .collect()
    }

    #[test]
    fn sharded_ideal_equals_serial_for_every_shard_count() {
        let res = Resolution::new(24, 16);
        let evs = stream(res, 400);
        let prm = StcfParams::default();
        let mut serial_b = StcfBackend::ideal(res);
        let serial = stcf::run(&mut serial_b, &evs, &prm);
        for shards in [1usize, 2, 4, 8] {
            let mut pool = StcfShardPool::new(res, shards, ShardBackend::Ideal, prm);
            let got = pool.run(&evs);
            assert_eq!(got.scored, serial.scored, "shards={shards}");
            assert_eq!(got.kept, serial.kept, "shards={shards}");
            let tallies = pool.shutdown();
            assert_eq!(tallies.iter().map(|t| t.scored).sum::<u64>(), evs.len() as u64);
            assert_eq!(
                tallies.iter().map(|t| t.kept).sum::<u64>(),
                serial.kept.len() as u64,
                "shards={shards}"
            );
        }
    }

    #[test]
    fn halo_rows_are_replicated_not_scored() {
        let res = Resolution::new(8, 8);
        let prm = StcfParams::default(); // radius 3 > band_h 2: deep halos
        let mut pool = StcfShardPool::new(res, 4, ShardBackend::Ideal, prm);
        let evs = stream(res, 120);
        pool.run(&evs);
        let tallies = pool.shutdown();
        assert_eq!(tallies.len(), 4);
        // Every event is scored exactly once pool-wide...
        assert_eq!(tallies.iter().map(|t| t.scored).sum::<u64>(), 120);
        // ...and border events are additionally halo-ingested elsewhere.
        assert!(tallies.iter().map(|t| t.halo_ingests).sum::<u64>() > 0);
    }

    #[test]
    fn batch_split_does_not_change_scores() {
        let res = Resolution::new(16, 12);
        let evs = stream(res, 300);
        let prm = StcfParams::default();
        let mut a = StcfShardPool::new(res, 3, ShardBackend::Ideal, prm);
        let whole = a.run(&evs);
        let mut b = StcfShardPool::new(res, 3, ShardBackend::Ideal, prm);
        let mut scores = Vec::new();
        let mut got = Vec::new();
        for chunk in evs.chunks(17) {
            b.score_batch(chunk, &mut scores);
            got.extend(scores.iter().map(|&s| s as f64));
        }
        let want: Vec<f64> = whole.scored.iter().map(|s| s.score).collect();
        assert_eq!(got, want);
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn filter_batch_applies_threshold_in_order() {
        let res = Resolution::new(16, 8);
        let evs = stream(res, 200);
        let prm = StcfParams::default();
        let mut pool = StcfShardPool::new(res, 2, ShardBackend::Ideal, prm);
        let (mut scores, mut kept) = (Vec::new(), Vec::new());
        pool.filter_batch(&evs, &mut scores, &mut kept);
        let mut serial_b = StcfBackend::ideal(res);
        let serial = stcf::run(&mut serial_b, &evs, &prm);
        assert_eq!(kept, serial.kept);
        pool.shutdown();
    }
}
