//! Band-sharded STCF scoring: the denoise stage as a worker pool.
//!
//! The serial [`super::stcf::run`] scores every event on the caller's
//! thread — the last serial hot path of a denoised pipeline once writes
//! (router shards) and readout (row-parallel rendering) scale with
//! cores. This module moves scoring onto worker shards, each owning a
//! horizontal band of the sensor exactly as the write router cuts it
//! ([`crate::util::parallel::band_layout`]).
//!
//! ## Halo replication
//!
//! A support patch of radius `r` centred in one band can reach up to
//! `r` rows into the neighbouring bands, so each shard's backend covers
//! its band **plus `r` replicated halo rows** on each side. The
//! dispatcher sends every event to the shard that owns its row (a
//! `Score` item) and *duplicates* it to every shard whose halo region
//! contains the row (`Halo` items — write-only ingests, never scored).
//! Each shard therefore sees, in stream order, exactly the events whose
//! row intersects its extended region, and processes them in the same
//! causal score-then-write order as the serial filter: a `Score` item
//! is scored against the shard surface *before* it (or any later event)
//! is written. Scores are consequently **bit-for-bit identical** to the
//! serial reference for both backends — ISC band arrays anchor their
//! position-stable mismatch maps at the band-plus-halo origin
//! ([`crate::isc::IscConfig::origin_y`]), so each is an exact window of
//! the full-sensor array and shard layout can never perturb a decision.
//!
//! The per-shard core — backend, halo offset, causal score-then-write
//! loop, tallies — lives in [`BandScorer`]; the pool's worker threads
//! merely drive it, and the serve session layer ([`crate::serve`])
//! schedules the same struct as queued jobs on its shared worker pool.
//! [`stage_items`] is the matching dispatch: both layers fan a batch
//! out with identical Score/Halo duplication.
//!
//! Batches are scored synchronously: [`StcfShardPool::score_batch`]
//! fans a time-sorted batch out, the shards score their slices
//! concurrently, and the reply merge restores input order — so the
//! caller (the coordinator pipeline) keeps its frame-boundary
//! bookkeeping unchanged while the patch scans run on every core.

use super::stcf::{support_count, StcfBackend, StcfParams, StcfRun};
use crate::events::{Event, LabeledEvent, Resolution};
use crate::isc::IscConfig;
use crate::metrics::Scored;
use crate::util::parallel::band_layout;
use crate::util::sync::chan::{bounded, Receiver, Sender};
use crate::util::sync::thread::{self, JoinHandle};

/// How each denoise shard builds its band(+halo) backend.
#[derive(Clone, Debug)]
pub enum ShardBackend {
    /// Full-precision SAE planes — sharded scoring is bit-for-bit ≡ the
    /// serial ideal backend.
    Ideal,
    /// ISC analog arrays, anchored at each band's global origin row so
    /// the position-stable mismatch map is an exact window of the
    /// full-sensor array — bit-for-bit ≡ serial for every shard count,
    /// mismatch included.
    Isc(IscConfig),
}

/// Per-shard outcome counters, returned by [`StcfShardPool::shutdown`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardTally {
    /// Events this shard owned and scored.
    pub scored: u64,
    /// Scored events at or above the keep threshold.
    pub kept: u64,
    /// Scored events below the keep threshold.
    pub dropped: u64,
    /// Write-only halo ingests (duplicates of border events owned by a
    /// neighbouring shard).
    pub halo_ingests: u64,
}

/// One time-ordered work item for a scorer band.
pub enum ScoreItem {
    /// Score this event (index into the dispatched batch), then ingest it.
    Score(u32, Event),
    /// Ingest only: a halo duplicate owned by another band.
    Halo(Event),
}

/// Fan a time-sorted batch out to per-band item lists: each event
/// becomes a [`ScoreItem::Score`] for the band owning its row and a
/// [`ScoreItem::Halo`] for every band whose halo region contains it
/// (generalized to radii deeper than the band height). The pool's
/// dispatcher and the serve session layer share this function, so both
/// produce identical item sequences. `staging` must hold `n_bands`
/// lists (appended to, not cleared).
pub fn stage_items(
    res: Resolution,
    band_h: usize,
    n_bands: usize,
    radius: usize,
    batch: &[LabeledEvent],
    staging: &mut [Vec<ScoreItem>],
) {
    debug_assert_eq!(staging.len(), n_bands);
    let h = res.height as usize;
    let band_for = |y: usize| (y / band_h).min(n_bands - 1);
    for (k, le) in batch.iter().enumerate() {
        let e = &le.ev;
        debug_assert!(res.contains(e.x, e.y), "off-sensor event {e:?}");
        let y = e.y as usize;
        let own = band_for(y);
        let s_min = band_for(y.saturating_sub(radius));
        let s_max = band_for((y + radius).min(h - 1));
        for s in s_min..=s_max {
            if s == own {
                staging[s].push(ScoreItem::Score(k as u32, *e));
            } else {
                staging[s].push(ScoreItem::Halo(*e));
            }
        }
    }
}

/// One denoise shard's band-local core: the band(+halo) backend plus
/// the causal score-then-write loop and its tallies. The pool's worker
/// threads and the serve scheduler's band jobs both drive this struct.
pub struct BandScorer {
    backend: StcfBackend,
    prm: StcfParams,
    /// Global sensor row of the backend's row 0 (halo included).
    lo: u16,
    tally: ShardTally,
}

impl BandScorer {
    /// The scorer for band `shard` of the `band_layout(height, …)`
    /// partition of `res`, covering `prm.radius` halo rows per side.
    /// ISC backends anchor their mismatch window at the global region
    /// origin, making them exact windows of the full-sensor array.
    pub fn for_band(
        res: Resolution,
        backend: &ShardBackend,
        prm: StcfParams,
        band_h: usize,
        shard: usize,
    ) -> Self {
        let h = res.height as usize;
        let radius = prm.radius as usize;
        let band_start = shard * band_h;
        let band_end = (band_start + band_h).min(h) - 1;
        let lo = band_start.saturating_sub(radius);
        let hi = (band_end + radius).min(h - 1);
        let local = Resolution::new(res.width, (hi - lo + 1) as u16);
        let b = match backend {
            ShardBackend::Ideal => StcfBackend::ideal_with_window(local, prm.tau_tw_us),
            ShardBackend::Isc(cfg) => {
                let mut cfg = cfg.clone();
                cfg.origin_y += lo as u16;
                StcfBackend::isc(local, cfg, prm.tau_tw_us)
            }
        };
        Self { backend: b, prm, lo: lo as u16, tally: ShardTally::default() }
    }

    /// Process one time-ordered item list — score-then-write causally —
    /// appending `(batch index, support)` pairs for owned events to
    /// `scores`.
    pub fn process(&mut self, items: &[ScoreItem], scores: &mut Vec<(u32, u32)>) {
        for item in items {
            match item {
                ScoreItem::Score(idx, ev) => {
                    let mut e = *ev;
                    e.y -= self.lo;
                    let s = support_count(&self.backend, &e, &self.prm);
                    scores.push((*idx, s));
                    self.backend.ingest(&e, &self.prm);
                    self.tally.scored += 1;
                    if s >= self.prm.threshold {
                        self.tally.kept += 1;
                    } else {
                        self.tally.dropped += 1;
                    }
                }
                ScoreItem::Halo(ev) => {
                    let mut e = *ev;
                    e.y -= self.lo;
                    self.backend.ingest(&e, &self.prm);
                    self.tally.halo_ingests += 1;
                }
            }
        }
    }

    /// The shard's outcome counters so far.
    pub fn tally(&self) -> &ShardTally {
        &self.tally
    }

    /// Approximate resident bytes of the shard's backend surfaces —
    /// one leaf of the serve layer's `resident_bytes` gauge.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.backend.approx_bytes()
    }

    /// Export the scorer's restorable state for a `serve::supervise`
    /// checkpoint: appends every backend stamp in **region-local**
    /// coordinates (band + halo; `plane` 0 = OFF / polarity-insensitive,
    /// 1 = ON) and returns a copy of the outcome tallies.
    pub fn export_state(&self, stamps: &mut Vec<(u8, u16, u16, u64)>) -> ShardTally {
        self.backend.for_each_stamp(|plane, x, y, t| stamps.push((plane, x, y, t)));
        self.tally.clone()
    }

    /// Rebuild the scorer from an [`BandScorer::export_state`]
    /// checkpoint: replay the stamps (sorted ascending by time here, so
    /// the backend's clock and recency planes see a monotone stream)
    /// into the backend of a freshly constructed scorer and restore the
    /// tallies. Every subsequent [`support_count`] answer — and so every
    /// keep/drop decision — is bit-for-bit identical to the
    /// never-crashed scorer's.
    pub fn restore_state(&mut self, tally: ShardTally, stamps: &[(u8, u16, u16, u64)]) {
        let mut ordered: Vec<(u8, u16, u16, u64)> = stamps.to_vec();
        ordered.sort_unstable_by_key(|&(_, _, _, t)| t);
        for (plane, x, y, t) in ordered {
            self.backend.restore_stamp(plane, x, y, t);
        }
        self.tally = tally;
    }
}

enum Job {
    Batch(Vec<ScoreItem>),
    Stop,
}

struct Reply {
    scores: Vec<(u32, u32)>,
}

/// The denoise shard pool. Construct once, feed time-sorted batches
/// through [`StcfShardPool::score_batch`] / [`StcfShardPool::filter_batch`],
/// then [`StcfShardPool::shutdown`] for the tallies.
pub struct StcfShardPool {
    senders: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<ShardTally>>,
    reply_rx: Receiver<Reply>,
    res: Resolution,
    prm: StcfParams,
    band_h: usize,
    radius: usize,
    /// Per-shard item lists for the dispatch in progress (shipped whole
    /// to the shard, so each dispatch hands its allocation over).
    staging: Vec<Vec<ScoreItem>>,
}

impl StcfShardPool {
    /// Pool of (at most) `n_shards` denoise workers over `res`, each
    /// backed per `backend`. The shard bands match
    /// [`crate::util::parallel::band_layout`]; each backend additionally
    /// covers `prm.radius` halo rows per side.
    pub fn new(res: Resolution, n_shards: usize, backend: ShardBackend, prm: StcfParams) -> Self {
        let h = res.height as usize;
        let (band_h, n) = band_layout(h, n_shards);
        let radius = prm.radius as usize;
        let (reply_tx, reply_rx) = bounded::<Reply>(n);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx) = bounded::<Job>(2);
            let backend = backend.clone();
            let reply = reply_tx.clone();
            handles.push(thread::spawn(move || {
                // Built on the worker so heavyweight setup (the ISC
                // Monte-Carlo bank fit) also runs in parallel.
                let mut scorer = BandScorer::for_band(res, &backend, prm, band_h, shard);
                for job in rx {
                    let items = match job {
                        Job::Batch(items) => items,
                        Job::Stop => break,
                    };
                    let mut scores = Vec::new();
                    scorer.process(&items, &mut scores);
                    if reply.send(Reply { scores }).is_err() {
                        break; // pool dropped mid-batch
                    }
                }
                scorer.tally
            }));
            senders.push(tx);
        }
        // The pool holds no reply sender of its own: once every worker
        // clone is gone, `reply_rx.recv()` reports the death instead of
        // blocking forever.
        drop(reply_tx);
        Self {
            senders,
            handles,
            reply_rx,
            res,
            prm,
            band_h,
            radius,
            staging: (0..n).map(|_| Vec::new()).collect(),
        }
    }

    /// Effective shard count (≤ requested; see `band_layout`).
    pub fn n_shards(&self) -> usize {
        self.senders.len()
    }

    /// The filter parameters every shard scores with.
    pub fn params(&self) -> &StcfParams {
        &self.prm
    }

    /// Score a time-sorted batch of on-sensor events. `scores` is
    /// cleared and filled with one support count per event, in input
    /// order — bit-for-bit identical to calling [`support_count`] +
    /// [`StcfBackend::ingest`] serially over the whole stream, for both
    /// backends and any shard count. Blocks until every shard has
    /// finished its slice.
    pub fn score_batch(&mut self, batch: &[LabeledEvent], scores: &mut Vec<u32>) {
        scores.clear();
        scores.resize(batch.len(), 0);
        let n = self.senders.len();
        stage_items(self.res, self.band_h, n, self.radius, batch, &mut self.staging);
        let mut in_flight = 0usize;
        for s in 0..self.senders.len() {
            if self.staging[s].is_empty() {
                continue;
            }
            let items = std::mem::take(&mut self.staging[s]);
            self.senders[s].send(Job::Batch(items)).expect("denoise shard died");
            in_flight += 1;
        }
        for _ in 0..in_flight {
            let r = self.reply_rx.recv().expect("denoise shard died");
            for &(idx, s) in &r.scores {
                scores[idx as usize] = s;
            }
        }
    }

    /// Score `batch` and append the events passing the keep threshold to
    /// `kept` in input order. `scores` is scratch (reused across calls).
    pub fn filter_batch(
        &mut self,
        batch: &[LabeledEvent],
        scores: &mut Vec<u32>,
        kept: &mut Vec<LabeledEvent>,
    ) {
        self.score_batch(batch, scores);
        for (le, &s) in batch.iter().zip(scores.iter()) {
            if s >= self.prm.threshold {
                kept.push(*le);
            }
        }
    }

    /// Convenience mirror of the serial [`super::stcf::run`]: score a
    /// whole sorted stream (in pool-sized batches — the split does not
    /// change any score) and return the same [`StcfRun`] shape.
    pub fn run(&mut self, events: &[LabeledEvent]) -> StcfRun {
        let mut scores = Vec::new();
        let mut scored = Vec::with_capacity(events.len());
        let mut kept = Vec::new();
        for chunk in events.chunks(4_096) {
            self.score_batch(chunk, &mut scores);
            for (le, &s) in chunk.iter().zip(&scores) {
                scored.push(Scored { score: s as f64, is_signal: le.is_signal });
                if s >= self.prm.threshold {
                    kept.push(*le);
                }
            }
        }
        StcfRun { scored, kept }
    }

    /// Stop all shards and collect their tallies (index = shard).
    pub fn shutdown(mut self) -> Vec<ShardTally> {
        for s in &self.senders {
            let _ = s.send(Job::Stop);
        }
        self.handles.drain(..).map(|h| h.join().expect("join denoise shard")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::denoise::stcf;
    use crate::events::Polarity;

    fn le(t: u64, x: u16, y: u16) -> LabeledEvent {
        LabeledEvent { ev: Event::new(t, x, y, Polarity::On), is_signal: true }
    }

    /// Deterministic stream that hits every row, including band borders.
    fn stream(res: Resolution, n: u64) -> Vec<LabeledEvent> {
        (0..n)
            .map(|k| {
                le(
                    1 + k * 211,
                    (k * 7 % res.width as u64) as u16,
                    (k * 5 % res.height as u64) as u16,
                )
            })
            .collect()
    }

    #[test]
    fn sharded_ideal_equals_serial_for_every_shard_count() {
        let res = Resolution::new(24, 16);
        let evs = stream(res, 400);
        let prm = StcfParams::default();
        let mut serial_b = StcfBackend::ideal(res);
        let serial = stcf::run(&mut serial_b, &evs, &prm);
        for shards in [1usize, 2, 4, 8] {
            let mut pool = StcfShardPool::new(res, shards, ShardBackend::Ideal, prm);
            let got = pool.run(&evs);
            assert_eq!(got.scored, serial.scored, "shards={shards}");
            assert_eq!(got.kept, serial.kept, "shards={shards}");
            let tallies = pool.shutdown();
            assert_eq!(tallies.iter().map(|t| t.scored).sum::<u64>(), evs.len() as u64);
            assert_eq!(
                tallies.iter().map(|t| t.kept).sum::<u64>(),
                serial.kept.len() as u64,
                "shards={shards}"
            );
        }
    }

    #[test]
    fn halo_rows_are_replicated_not_scored() {
        let res = Resolution::new(8, 8);
        let prm = StcfParams::default(); // radius 3 > band_h 2: deep halos
        let mut pool = StcfShardPool::new(res, 4, ShardBackend::Ideal, prm);
        let evs = stream(res, 120);
        pool.run(&evs);
        let tallies = pool.shutdown();
        assert_eq!(tallies.len(), 4);
        // Every event is scored exactly once pool-wide...
        assert_eq!(tallies.iter().map(|t| t.scored).sum::<u64>(), 120);
        // ...and border events are additionally halo-ingested elsewhere.
        assert!(tallies.iter().map(|t| t.halo_ingests).sum::<u64>() > 0);
    }

    #[test]
    fn batch_split_does_not_change_scores() {
        let res = Resolution::new(16, 12);
        let evs = stream(res, 300);
        let prm = StcfParams::default();
        let mut a = StcfShardPool::new(res, 3, ShardBackend::Ideal, prm);
        let whole = a.run(&evs);
        let mut b = StcfShardPool::new(res, 3, ShardBackend::Ideal, prm);
        let mut scores = Vec::new();
        let mut got = Vec::new();
        for chunk in evs.chunks(17) {
            b.score_batch(chunk, &mut scores);
            got.extend(scores.iter().map(|&s| s as f64));
        }
        let want: Vec<f64> = whole.scored.iter().map(|s| s.score).collect();
        assert_eq!(got, want);
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn filter_batch_applies_threshold_in_order() {
        let res = Resolution::new(16, 8);
        let evs = stream(res, 200);
        let prm = StcfParams::default();
        let mut pool = StcfShardPool::new(res, 2, ShardBackend::Ideal, prm);
        let (mut scores, mut kept) = (Vec::new(), Vec::new());
        pool.filter_batch(&evs, &mut scores, &mut kept);
        let mut serial_b = StcfBackend::ideal(res);
        let serial = stcf::run(&mut serial_b, &evs, &prm);
        assert_eq!(kept, serial.kept);
        pool.shutdown();
    }
}
