//! Spatio-Temporal Correlation Filter (STCF) denoising [51] on both the
//! ideal (full-precision timestamp) surface and the ISC analog array
//! (paper Sec. IV-C, Fig. 10).
//!
//! For each incoming event, count the *support*: neighbours inside the
//! (2r+1)² patch whose last event lies within the correlation window
//! τ_tw. Signal events ride moving structure and collect support; BA noise
//! does not. The hardware realization replaces the timestamp comparison
//! `t − T(u) ≤ τ_tw` with a single analog comparator `V_mem ≥ V_tw`
//! (Fig. 10b) — the entire point of the self-normalizing analog TS.
//!
//! The support query runs at three tiers (fastest applicable wins; all
//! three produce identical counts — see `tests/stcf_equiv.rs` and the
//! complexity table in [`crate::denoise`]):
//!
//! 1. **bitmask** ([`support_count_bitmask`]) — popcount the per-row
//!    recency bitmask words over the masked patch window
//!    ([`crate::util::bitplane::RecencyPlane`]), skip all-zero rows
//!    outright, and confirm only the set-bit runs against the exact
//!    timestamp/comparator test;
//! 2. **row-sliced** ([`support_count_rows`]) — one contiguous slice
//!    walk per patch row with the compiled [`Comparator`] integer-age
//!    test;
//! 3. **naive** ([`support_count_naive`]) — per-(dx, dy) point reads,
//!    the reference.
//!
//! The sparse [`StcfBackend::Cache`] has no dense rows or bitmask plane
//! to scan: every tier resolves to the per-pixel probe walk — O((2r+1)²)
//! hashed probes against the O(m) [`crate::util::sparse`] store, with
//! the eviction-undercount bound documented on the variant.
//!
//! The bitmask tier inherits the causality contract of the recency
//! plane: counts are exact for queries at or ahead of the stream head
//! (score-then-write over a time-sorted stream — precisely how
//! [`run`] and the coordinator pipeline drive it).

use crate::circuit::montecarlo::FittedBank;
use crate::events::{Event, LabeledEvent, Polarity, Resolution};
use crate::isc::array::Comparator;
use crate::isc::{IscArray, IscConfig};
use crate::metrics::Scored;
use crate::tsurface::sae::Sae;
use crate::tsurface::EventSink;
use crate::util::grid::patch_bounds;
use crate::util::sparse::{pixel_key, SparseRecencyStore};

/// STCF parameters.
#[derive(Clone, Copy, Debug)]
pub struct StcfParams {
    /// Patch radius r (support patch is (2r+1)²).
    pub radius: u16,
    /// Correlation window τ_tw in µs (paper: 24 ms).
    pub tau_tw_us: u64,
    /// Keep threshold: support ≥ th ⇒ signal.
    pub threshold: u32,
    /// Count only same-polarity support (paper Sec. IV-F).
    pub polarity_sensitive: bool,
    /// Count the event's own pixel history as (temporal) support.
    pub count_center: bool,
}

impl Default for StcfParams {
    fn default() -> Self {
        Self {
            radius: 3,
            tau_tw_us: 24_000,
            threshold: 2,
            polarity_sensitive: false,
            count_center: true,
        }
    }
}

/// Default set associativity of the sparse cache backend: deep enough
/// that a (2r+1)² patch of simultaneously-hot pixels rarely collides
/// into one set, shallow enough that a probe stays a short linear scan.
pub const CACHE_DEFAULT_WAYS: usize = 8;

/// Which surface backs the support query.
pub enum StcfBackend {
    /// Full-precision timestamps (the paper's "ideal" software curve).
    /// `planes[0]` serves polarity-insensitive queries and the OFF
    /// polarity; `planes[1]` (the ON plane) is allocated lazily on the
    /// first polarity-sensitive ON ingest, so the default
    /// (`polarity_sensitive: false`) configuration pays for one plane.
    Ideal {
        planes: Vec<Sae>,
        /// Recency window baked into each plane's bitmask (lazily
        /// created planes inherit it).
        window_us: u64,
    },
    /// The simulated analog array with a comparator at `v_tw` volts.
    /// `cmp` is the compiled fixed-threshold comparator (integer-age test;
    /// see `IscArray::comparator`).
    Isc { array: IscArray, v_tw: f64, cmp: Comparator },
    /// Set-associative sparse recency store
    /// ([`crate::util::sparse::SparseRecencyStore`]): O(m) memory in the
    /// number of cached entries instead of O(H·W), scoring each support
    /// query with O((2r+1)²) hashed probes. Semantics mirror the
    /// [`StcfBackend::Ideal`] timestamp test, so counts are **bit-for-bit
    /// equal to the dense backends for every event whose (2r+1)²
    /// neighborhood survives in-cache** (`tests/sparse_equiv.rs` proves
    /// it; zero [`SparseRecencyStore::evictions`] certifies a whole
    /// stream). Under capacity pressure the store evicts the **oldest**
    /// entry of the victim's set, so a miss only ever hides activity at
    /// least as old as everything the set retained — the support count
    /// can undercount, never overcount, and only by events older than
    /// the retained minimum (the cache-like filter's bounded-undercount
    /// guarantee, Zhao et al. arXiv 2410.12423).
    Cache { res: Resolution, store: SparseRecencyStore },
}

impl StcfBackend {
    /// Ideal backend at resolution `res`. The recency bitmask is sized
    /// for the default correlation window ([`StcfParams::default`]);
    /// queries with a larger τ_tw fall back to the row-sliced scan —
    /// use [`StcfBackend::ideal_with_window`] to cover them.
    pub fn ideal(res: Resolution) -> Self {
        Self::ideal_with_window(res, StcfParams::default().tau_tw_us)
    }

    /// Ideal backend whose recency bitmask covers windows up to
    /// `window_us`.
    pub fn ideal_with_window(res: Resolution, window_us: u64) -> Self {
        StcfBackend::Ideal { planes: vec![Sae::with_recency(res, window_us)], window_us }
    }

    /// ISC backend with the comparator threshold derived from the nominal
    /// decay: V_tw = V_nominal(τ_tw) — how the designer picks V_tw
    /// (paper Fig. 10b: 383 mV for 24 ms at 20 fF).
    pub fn isc(res: Resolution, cfg: IscConfig, tau_tw_us: u64) -> Self {
        // A real comparator cannot resolve thresholds below the noise/offset
        // floor — exactly why Fig. 5a rules out C_mem < 10 fF for a 24 ms
        // window (V(24 ms) would sit under the floor).
        let v_tw = FittedBank::nominal(cfg.c_mem)
            .eval(tau_tw_us as f64 * 1e-6)
            .max(crate::circuit::V_FLOOR);
        Self::isc_with_vtw(res, cfg, v_tw)
    }

    /// ISC backend with an explicit comparator voltage. The backing
    /// array always maintains its recency bitmask (the bitmask support
    /// tier reads it); pure write/readout arrays leave it off.
    pub fn isc_with_vtw(res: Resolution, cfg: IscConfig, v_tw: f64) -> Self {
        let array = IscArray::new(res, IscConfig { recency_bitmask: true, ..cfg });
        let cmp = array.comparator(v_tw);
        StcfBackend::Isc { array, v_tw, cmp }
    }

    /// Sparse cache backend holding at least `min_entries` recency
    /// entries in sets of [`CACHE_DEFAULT_WAYS`] ways — O(m) memory
    /// independent of `res` (the resolution is kept only for patch
    /// clamping). See [`StcfBackend::Cache`] for the equivalence and
    /// eviction-undercount guarantees.
    pub fn cache(res: Resolution, min_entries: usize) -> Self {
        Self::cache_with_ways(res, min_entries, CACHE_DEFAULT_WAYS)
    }

    /// [`StcfBackend::cache`] with an explicit set associativity.
    pub fn cache_with_ways(res: Resolution, min_entries: usize, ways: usize) -> Self {
        StcfBackend::Cache { res, store: SparseRecencyStore::new(min_entries, ways) }
    }

    fn res(&self) -> Resolution {
        match self {
            StcfBackend::Ideal { planes, .. } => planes[0].resolution(),
            StcfBackend::Isc { array, .. } => array.resolution(),
            StcfBackend::Cache { res, .. } => *res,
        }
    }

    /// Number of allocated SAE planes (ideal backend; diagnostics for
    /// the lazy-allocation contract).
    pub fn ideal_planes(&self) -> usize {
        match self {
            StcfBackend::Ideal { planes, .. } => planes.len(),
            StcfBackend::Isc { .. } | StcfBackend::Cache { .. } => 0,
        }
    }

    /// Entries displaced from the sparse store so far (cache backend;
    /// 0 certifies every count so far was bit-for-bit ≡ dense). `None`
    /// for the dense backends, which never evict.
    pub fn cache_evictions(&self) -> Option<u64> {
        match self {
            StcfBackend::Cache { store, .. } => Some(store.evictions()),
            _ => None,
        }
    }

    /// Resident bytes of the backing surface — one leaf of the serve
    /// layer's `resident_bytes` gauge. O(H·W) for the dense backends,
    /// O(capacity) for the cache backend.
    pub fn approx_bytes(&self) -> usize {
        match self {
            StcfBackend::Ideal { planes, .. } => {
                planes.iter().map(|s| s.approx_bytes()).sum::<usize>()
            }
            StcfBackend::Isc { array, cmp, .. } => array.approx_bytes() + cmp.approx_bytes(),
            StcfBackend::Cache { store, .. } => store.approx_bytes(),
        }
    }

    /// Does pixel (x, y) [plane p] hold a correlated (recent) event at t?
    #[inline]
    fn supported(&self, x: u16, y: u16, p: Polarity, t: u64, prm: &StcfParams) -> bool {
        match self {
            StcfBackend::Ideal { planes, .. } => {
                let idx = if prm.polarity_sensitive { p.index() } else { 0 };
                match planes.get(idx) {
                    None => false, // plane never ingested — nothing recent
                    Some(s) => {
                        let tw = s.last(x, y);
                        tw != 0 && t >= tw && t - tw <= prm.tau_tw_us
                    }
                }
            }
            StcfBackend::Isc { array, cmp, .. } => array.compare_with(cmp, x, y, p, t),
            StcfBackend::Cache { store, .. } => {
                let plane = if prm.polarity_sensitive { p.index() } else { 0 };
                match store.last(pixel_key(plane as u8, x, y)) {
                    Some(tw) => t >= tw && t - tw <= prm.tau_tw_us,
                    None => false, // never written, or evicted (older than the set's retained minimum)
                }
            }
        }
    }

    /// Record an event on the backing surface (after scoring it — the
    /// filter is causal). Public so streaming consumers (the coordinator
    /// pipeline) can interleave scoring and ingestion without
    /// materializing a kept-event vector. The ideal backend allocates
    /// its second (ON) plane here on the first polarity-sensitive ON
    /// ingest.
    #[inline]
    pub fn ingest(&mut self, e: &Event, prm: &StcfParams) {
        match self {
            StcfBackend::Ideal { planes, window_us } => {
                let idx = if prm.polarity_sensitive { e.p.index() } else { 0 };
                if planes.len() <= idx {
                    let res = planes[0].resolution();
                    planes.push(Sae::with_recency(res, *window_us));
                }
                planes[idx].ingest(e);
            }
            StcfBackend::Isc { array, .. } => array.write(e),
            StcfBackend::Cache { store, .. } => {
                let plane = if prm.polarity_sensitive { e.p.index() } else { 0 };
                store.mark(pixel_key(plane as u8, e.x, e.y), e.t);
            }
        }
    }

    /// Visit every stamp held by the backing surface as
    /// `f(plane, x, y, t)` — the checkpoint export walk of
    /// `serve::supervise`. `plane` is the storage plane index (0 =
    /// polarity-insensitive / OFF, 1 = ON). Feeding the tuples back
    /// through [`StcfBackend::restore_stamp`] in ascending-`t` order on
    /// a freshly constructed backend of the same shape reproduces every
    /// [`support_count`] answer.
    pub fn for_each_stamp(&self, mut f: impl FnMut(u8, u16, u16, u64)) {
        match self {
            StcfBackend::Ideal { planes, .. } => {
                for (pi, s) in planes.iter().enumerate() {
                    s.for_each_stamp(|x, y, t| f(pi as u8, x, y, t));
                }
            }
            StcfBackend::Isc { array, .. } => {
                array.for_each_stamp(|pi, x, y, t| f(pi as u8, x, y, t));
            }
            StcfBackend::Cache { store, .. } => store.for_each_entry(|key, t| {
                let plane = (key >> 32) as u8;
                let y = ((key >> 16) & 0xFFFF) as u16;
                let x = (key & 0xFFFF) as u16;
                f(plane, x, y, t);
            }),
        }
    }

    /// Replay one stamp exported by [`StcfBackend::for_each_stamp`]:
    /// plane 1 replays as an ON write (allocating the lazy ON plane
    /// where the backend has one), every other plane as OFF. Stamps are
    /// already `max(1)`-clamped on the original write, so replay in
    /// ascending-`t` order is a fixed point of the export.
    pub fn restore_stamp(&mut self, plane: u8, x: u16, y: u16, t: u64) {
        let p = if plane == 1 { Polarity::On } else { Polarity::Off };
        match self {
            StcfBackend::Ideal { planes, window_us } => {
                let idx = plane as usize;
                while planes.len() <= idx {
                    let res = planes[0].resolution();
                    planes.push(Sae::with_recency(res, *window_us));
                }
                planes[idx].ingest(&Event::new(t, x, y, p));
            }
            StcfBackend::Isc { array, .. } => array.write(&Event::new(t, x, y, p)),
            StcfBackend::Cache { store, .. } => store.mark(pixel_key(plane, x, y), t),
        }
    }
}

/// Support count for event `e` (center optional via `count_center`):
/// the bitmask-accelerated scan when the backend's recency plane covers
/// the query window, else the row-sliced scan. Identical counts either
/// way (causal queries; see the module docs).
pub fn support_count(backend: &StcfBackend, e: &Event, prm: &StcfParams) -> u32 {
    match support_count_bitmask(backend, e, prm) {
        Some(n) => n,
        None => support_count_rows(backend, e, prm),
    }
}

/// Bitmask-accelerated support scan: popcount the masked recency words
/// per patch row (all-zero rows cost one or two word loads and nothing
/// else), then confirm each set-bit run with the exact row-sliced
/// timestamp/comparator test — the bitmask is a conservative superset,
/// so the confirmed count is bit-for-bit the exact one.
///
/// Returns `None` when the fast path does not apply (off-sensor event,
/// no recency plane, a query window the plane does not cover, or the
/// sparse cache backend, which has no bitmask plane by design) — the
/// caller falls back to [`support_count_rows`].
pub fn support_count_bitmask(backend: &StcfBackend, e: &Event, prm: &StcfParams) -> Option<u32> {
    let res = backend.res();
    if !res.contains(e.x, e.y) {
        return None; // stray off-sensor event: the clamped reference scans handle it
    }
    let r = prm.radius as usize;
    let (x0, x1) = patch_bounds(e.x as usize, r, res.width as usize);
    let (y0, y1) = patch_bounds(e.y as usize, r, res.height as usize);
    let (x0, x1) = (x0 as u16, x1 as u16);
    let mut n = 0u32;
    match backend {
        StcfBackend::Ideal { planes, .. } => {
            let idx = if prm.polarity_sensitive { e.p.index() } else { 0 };
            let Some(s) = planes.get(idx) else {
                return Some(0); // plane never ingested — zero support by definition
            };
            let rp = s.recency()?;
            if !rp.covers(prm.tau_tw_us) {
                return None;
            }
            for y in y0..=y1 {
                rp.for_each_possibly_recent_run(y, x0, x1, e.t, |run| {
                    n += s.count_recent_in_row(
                        y as u16,
                        run.start as u16,
                        (run.end - 1) as u16,
                        e.t,
                        prm.tau_tw_us,
                    );
                });
            }
        }
        StcfBackend::Isc { array, cmp, .. } => {
            let rp = array.recency_plane(e.p)?;
            if !rp.covers(cmp.max_dt_us()) {
                return None;
            }
            for y in y0..=y1 {
                rp.for_each_possibly_recent_run(y, x0, x1, e.t, |run| {
                    n += array.count_recent_in_row(
                        cmp,
                        e.p,
                        y as u16,
                        run.start as u16,
                        (run.end - 1) as u16,
                        e.t,
                    );
                });
            }
        }
        StcfBackend::Cache { .. } => return None, // probe tier only
    }
    if !prm.count_center && backend.supported(e.x, e.y, e.p, e.t, prm) {
        // Saturating: on a causal query a supported center always has its
        // bit set (so n ≥ 1), but a non-causal query can lose the bit to
        // bucket recycling while the exact point test still passes —
        // bound that contract violation at 0 instead of wrapping.
        n = n.saturating_sub(1);
    }
    Some(n)
}

/// Row-sliced support scan: the (2r+1)² patch is clamped to the sensor
/// once, then each patch row is counted over one contiguous memory slice
/// ([`Sae::count_recent_in_row`] / [`IscArray::count_recent_in_row`]) —
/// no per-element 2D index math or bounds checks in the inner loop. The
/// center pixel is included by the row scan and subtracted afterwards
/// when `count_center` is off. Produces exactly the same counts as
/// [`support_count_naive`]. The sparse cache backend has no contiguous
/// rows to slice — it delegates to the per-pixel probe walk (that *is*
/// its O(window)-probes cost model).
pub fn support_count_rows(backend: &StcfBackend, e: &Event, prm: &StcfParams) -> u32 {
    let res = backend.res();
    if !res.contains(e.x, e.y) || matches!(backend, StcfBackend::Cache { .. }) {
        // Stray off-sensor event (clamped bounds would invert), or the
        // cache backend: both take the reference probe walk.
        return support_count_naive(backend, e, prm);
    }
    let r = prm.radius as usize;
    let (x0, x1) = patch_bounds(e.x as usize, r, res.width as usize);
    let (y0, y1) = patch_bounds(e.y as usize, r, res.height as usize);
    let (x0, x1) = (x0 as u16, x1 as u16);
    let mut n = 0u32;
    match backend {
        StcfBackend::Ideal { planes, .. } => {
            let idx = if prm.polarity_sensitive { e.p.index() } else { 0 };
            let Some(s) = planes.get(idx) else {
                return 0; // plane never ingested — zero support by definition
            };
            for y in y0..=y1 {
                n += s.count_recent_in_row(y as u16, x0, x1, e.t, prm.tau_tw_us);
            }
        }
        StcfBackend::Isc { array, cmp, .. } => {
            for y in y0..=y1 {
                n += array.count_recent_in_row(cmp, e.p, y as u16, x0, x1, e.t);
            }
        }
        StcfBackend::Cache { .. } => unreachable!("cache backend delegated to the probe walk"),
    }
    if !prm.count_center && backend.supported(e.x, e.y, e.p, e.t, prm) {
        n -= 1;
    }
    n
}

/// Reference implementation: per-(dx, dy) point reads over the patch.
/// Kept for the equivalence tests and the support-scan benchmark; hot
/// paths use [`support_count`].
pub fn support_count_naive(backend: &StcfBackend, e: &Event, prm: &StcfParams) -> u32 {
    let res = backend.res();
    let r = prm.radius as i64;
    let (ex, ey) = (e.x as i64, e.y as i64);
    let mut n = 0u32;
    for dy in -r..=r {
        for dx in -r..=r {
            if dx == 0 && dy == 0 && !prm.count_center {
                continue;
            }
            let (x, y) = (ex + dx, ey + dy);
            if x < 0 || y < 0 || x >= res.width as i64 || y >= res.height as i64 {
                continue;
            }
            if backend.supported(x as u16, y as u16, e.p, e.t, prm) {
                n += 1;
            }
        }
    }
    n
}

/// Result of filtering a stream.
#[derive(Clone, Debug)]
pub struct StcfRun {
    /// Per-event (support score, ground truth) — feed to `metrics::roc`.
    pub scored: Vec<Scored>,
    /// Events kept at `params.threshold`.
    pub kept: Vec<LabeledEvent>,
}

/// Run the STCF over a sorted labeled stream: score every event against
/// the *current* surface, then write it. For streaming consumption
/// without materializing `kept`, interleave [`support_count`] and
/// [`StcfBackend::ingest`] directly (see `coordinator::pipeline`); to
/// score on worker threads, use [`crate::denoise::sharded`].
pub fn run(backend: &mut StcfBackend, events: &[LabeledEvent], prm: &StcfParams) -> StcfRun {
    let mut scored = Vec::with_capacity(events.len());
    let mut kept = Vec::new();
    for le in events {
        let s = support_count(backend, &le.ev, prm);
        scored.push(Scored { score: s as f64, is_signal: le.is_signal });
        if s >= prm.threshold {
            kept.push(*le);
        }
        backend.ingest(&le.ev, prm);
    }
    StcfRun { scored, kept }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::event::Event;
    use crate::metrics::roc;

    fn le(t: u64, x: u16, y: u16, sig: bool) -> LabeledEvent {
        LabeledEvent { ev: Event::new(t, x, y, Polarity::On), is_signal: sig }
    }

    #[test]
    fn clustered_events_gain_support() {
        let res = Resolution::new(16, 16);
        let mut b = StcfBackend::ideal(res);
        let prm = StcfParams::default();
        // Three neighbours fire, then the test event.
        let stream =
            vec![le(100, 5, 5, true), le(200, 6, 5, true), le(300, 5, 6, true),
                 le(400, 6, 6, true)];
        let run = run(&mut b, &stream, &prm);
        // Last event sees 3 supporting neighbours.
        assert_eq!(run.scored[3].score, 3.0);
        // First event saw nothing.
        assert_eq!(run.scored[0].score, 0.0);
    }

    #[test]
    fn stale_support_expires_ideal() {
        let res = Resolution::new(8, 8);
        let mut b = StcfBackend::ideal(res);
        let prm = StcfParams { tau_tw_us: 1_000, ..StcfParams::default() };
        let stream = vec![le(100, 3, 3, true), le(5_000, 4, 3, true)];
        let r = run(&mut b, &stream, &prm);
        assert_eq!(r.scored[1].score, 0.0, "support older than τ_tw must not count");
    }

    #[test]
    fn isc_backend_matches_ideal_on_clean_cases() {
        // The paper's claim: the analog comparator reproduces the digital
        // time-window test. Compare decisions on a moderate stream.
        let res = Resolution::new(24, 24);
        let prm = StcfParams::default();
        let scene = crate::events::scene::EdgeScene::new(150.0, 11);
        let signal = crate::events::v2e::convert(
            &scene,
            res,
            crate::events::v2e::DvsParams::default(),
            0.12,
        );
        let noisy = crate::events::noise::contaminate(&signal, res, 5.0, 0.12, 3);

        let mut ideal = StcfBackend::ideal(res);
        let run_i = run(&mut ideal, &noisy, &prm);
        let mut isc = StcfBackend::isc(res, IscConfig::default(), prm.tau_tw_us);
        let run_h = run(&mut isc, &noisy, &prm);

        let agree = run_i
            .scored
            .iter()
            .zip(&run_h.scored)
            .filter(|(a, b)| (a.score >= prm.threshold as f64) == (b.score >= prm.threshold as f64))
            .count() as f64
            / run_i.scored.len() as f64;
        assert!(agree > 0.93, "ideal/ISC decision agreement {agree}");
    }

    #[test]
    fn stcf_separates_signal_from_noise() {
        // AUC on a noisy edge scene must be clearly above chance — the
        // Fig. 10d sanity requirement.
        let res = Resolution::new(32, 32);
        let scene = crate::events::scene::EdgeScene::new(200.0, 5);
        let signal = crate::events::v2e::convert(
            &scene,
            res,
            crate::events::v2e::DvsParams::default(),
            0.15,
        );
        let noisy = crate::events::noise::contaminate(&signal, res, 5.0, 0.15, 9);
        let mut b = StcfBackend::isc(res, IscConfig::default(), 24_000);
        let r = run(&mut b, &noisy, &StcfParams::default());
        // Small scene + cold start (the first τ_tw has no support history)
        // depress the smoke-test AUC; the full Fig. 10 harness warms up and
        // reaches the paper's 0.86–0.96 band.
        let auc = roc(&r.scored).auc;
        assert!(auc > 0.65, "AUC {auc}");
    }

    #[test]
    fn polarity_sensitive_counts_same_polarity_only() {
        let res = Resolution::new(8, 8);
        let prm = StcfParams { polarity_sensitive: true, ..StcfParams::default() };
        let mut b = StcfBackend::ideal(res);
        let stream = vec![
            LabeledEvent { ev: Event::new(100, 3, 3, Polarity::Off), is_signal: true },
            LabeledEvent { ev: Event::new(200, 4, 3, Polarity::On), is_signal: true },
        ];
        let r = run(&mut b, &stream, &prm);
        // The ON event's only neighbour is OFF → zero support.
        assert_eq!(r.scored[1].score, 0.0);
    }

    #[test]
    fn second_ideal_plane_is_allocated_lazily() {
        let res = Resolution::new(8, 8);
        let mut b = StcfBackend::ideal(res);
        assert_eq!(b.ideal_planes(), 1, "default config holds one plane");
        // Polarity-insensitive traffic of both polarities stays on one
        // plane (the memory-halving default).
        let prm = StcfParams::default();
        b.ingest(&Event::new(100, 1, 1, Polarity::On), &prm);
        b.ingest(&Event::new(200, 2, 1, Polarity::Off), &prm);
        assert_eq!(b.ideal_planes(), 1);
        // Polarity-sensitive OFF traffic also lives on plane 0...
        let ps = StcfParams { polarity_sensitive: true, ..StcfParams::default() };
        b.ingest(&Event::new(300, 3, 1, Polarity::Off), &ps);
        assert_eq!(b.ideal_planes(), 1);
        // ...and a query against the absent ON plane reads zero support
        // on every scan tier.
        let probe = Event::new(400, 3, 1, Polarity::On);
        assert_eq!(support_count(&b, &probe, &ps), 0);
        assert_eq!(support_count_rows(&b, &probe, &ps), 0);
        assert_eq!(support_count_naive(&b, &probe, &ps), 0);
        // The first polarity-sensitive ON ingest materializes plane 1.
        b.ingest(&probe, &ps);
        assert_eq!(b.ideal_planes(), 2);
        assert_eq!(support_count(&b, &Event::new(500, 4, 1, Polarity::On), &ps), 1);
    }

    #[test]
    fn all_three_scan_tiers_agree() {
        let res = Resolution::new(16, 12);
        let evs: Vec<LabeledEvent> = (0..120u64)
            .map(|k| {
                LabeledEvent {
                    ev: Event::new(
                        100 + k * 300,
                        (k * 7 % 16) as u16,
                        (k * 5 % 12) as u16,
                        if k % 2 == 0 { Polarity::On } else { Polarity::Off },
                    ),
                    is_signal: true,
                }
            })
            .collect();
        for polarity_sensitive in [false, true] {
            for count_center in [false, true] {
                let prm = StcfParams {
                    radius: 3,
                    polarity_sensitive,
                    count_center,
                    ..StcfParams::default()
                };
                let mut b = StcfBackend::ideal(res);
                for le in &evs {
                    let naive = support_count_naive(&b, &le.ev, &prm);
                    assert_eq!(
                        support_count_rows(&b, &le.ev, &prm),
                        naive,
                        "rows: ps={polarity_sensitive} cc={count_center} e={:?}",
                        le.ev
                    );
                    assert_eq!(
                        support_count_bitmask(&b, &le.ev, &prm),
                        Some(naive),
                        "bitmask: ps={polarity_sensitive} cc={count_center} e={:?}",
                        le.ev
                    );
                    assert_eq!(support_count(&b, &le.ev, &prm), naive);
                    b.ingest(&le.ev, &prm);
                }
            }
        }
    }

    #[test]
    fn uncovered_window_falls_back_to_rows() {
        // Query window wider than the bitmask guarantee: the fast path
        // must decline, and the auto dispatch must still be exact.
        let res = Resolution::new(12, 12);
        let mut b = StcfBackend::ideal_with_window(res, 1_000);
        let prm = StcfParams { tau_tw_us: 50_000, ..StcfParams::default() };
        let mut t = 0u64;
        for k in 0..60u64 {
            t += 400;
            let e = Event::new(t, (k % 12) as u16, (k * 5 % 12) as u16, Polarity::On);
            assert_eq!(support_count_bitmask(&b, &e, &prm), None);
            assert_eq!(support_count(&b, &e, &prm), support_count_naive(&b, &e, &prm));
            b.ingest(&e, &prm);
        }
    }

    #[test]
    fn cache_backend_matches_ideal_without_evictions() {
        // Capacity comfortably above the distinct-pixel working set: the
        // cache must track the ideal backend bit for bit on every tier
        // dispatch, for both polarity modes.
        let res = Resolution::new(16, 12);
        let evs: Vec<Event> = (0..200u64)
            .map(|k| {
                Event::new(
                    100 + k * 250,
                    (k * 7 % 16) as u16,
                    (k * 5 % 12) as u16,
                    if k % 2 == 0 { Polarity::On } else { Polarity::Off },
                )
            })
            .collect();
        for polarity_sensitive in [false, true] {
            for count_center in [false, true] {
                let prm =
                    StcfParams { polarity_sensitive, count_center, ..StcfParams::default() };
                let mut ideal = StcfBackend::ideal(res);
                let mut cache = StcfBackend::cache(res, 2 * res.pixels());
                for e in &evs {
                    assert_eq!(
                        support_count(&cache, e, &prm),
                        support_count(&ideal, e, &prm),
                        "ps={polarity_sensitive} cc={count_center} e={e:?}"
                    );
                    assert_eq!(support_count_bitmask(&cache, e, &prm), None);
                    ideal.ingest(e, &prm);
                    cache.ingest(e, &prm);
                }
                assert_eq!(cache.cache_evictions(), Some(0), "working set must fit");
                assert_eq!(ideal.cache_evictions(), None);
            }
        }
    }

    #[test]
    fn cache_backend_only_undercounts_under_pressure() {
        // Tiny cache, big working set: counts may drop support (evicted
        // entries) but must never invent it.
        let res = Resolution::new(32, 32);
        let prm = StcfParams::default();
        let mut ideal = StcfBackend::ideal(res);
        let mut cache = StcfBackend::cache_with_ways(res, 32, 2);
        for k in 0..600u64 {
            let e = Event::new(
                100 + k * 40,
                (k * 11 % 32) as u16,
                (k * 17 % 32) as u16,
                Polarity::On,
            );
            let (c, i) = (support_count(&cache, &e, &prm), support_count(&ideal, &e, &prm));
            assert!(c <= i, "cache overcounted: {c} > {i} at {e:?}");
            ideal.ingest(&e, &prm);
            cache.ingest(&e, &prm);
        }
        assert!(cache.cache_evictions().is_some_and(|n| n > 0), "pressure must evict");
    }

    #[test]
    fn cache_backend_memory_is_resolution_independent() {
        let small = StcfBackend::cache(Resolution::new(16, 16), 1_024);
        let large = StcfBackend::cache(Resolution::new(1280, 720), 1_024);
        assert_eq!(small.approx_bytes(), large.approx_bytes());
        let dense = StcfBackend::ideal(Resolution::new(1280, 720));
        assert!(large.approx_bytes() < dense.approx_bytes() / 10);
    }

    #[test]
    fn threshold_gates_kept_set() {
        let res = Resolution::new(8, 8);
        let mut b = StcfBackend::ideal(res);
        let prm = StcfParams { threshold: 1, ..StcfParams::default() };
        let stream = vec![le(100, 3, 3, false), le(200, 4, 3, true)];
        let r = run(&mut b, &stream, &prm);
        assert_eq!(r.kept.len(), 1); // only the supported second event
        assert!(r.kept[0].is_signal);
    }
}
