//! Run-configuration files: a TOML-subset parser (offline substitute for
//! `serde` + `toml`) supporting `[sections]`, `key = value` with string,
//! number and boolean values, and `#` comments. Used by the CLI's
//! `--config` option so experiment sweeps are reproducible from files.

use std::collections::HashMap;

/// Parsed configuration: section → key → raw value string.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    sections: HashMap<String, HashMap<String, String>>,
}

/// Parse error with line information.
#[derive(Debug, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or(ParseError {
                    line: i + 1,
                    message: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let mut val = v.trim().to_string();
                // Strip matching quotes.
                if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                    val = val[1..val.len() - 1].to_string();
                }
                if key.is_empty() {
                    return Err(ParseError { line: i + 1, message: "empty key".into() });
                }
                cfg.sections.entry(section.clone()).or_default().insert(key, val);
            } else {
                return Err(ParseError {
                    line: i + 1,
                    message: format!("expected `key = value`, got '{line}'"),
                });
            }
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Self, Box<dyn std::error::Error>> {
        Ok(Self::parse(&std::fs::read_to_string(path)?)?)
    }

    /// Raw string lookup: `section.key` (empty section = top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_parsed<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> T {
        self.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(
            "top = 1\n\
             [train]\n\
             steps = 200     # comment\n\
             lr = 0.03\n\
             name = \"nmnist\"\n\
             full = true\n",
        )
        .unwrap();
        assert_eq!(cfg.get("", "top"), Some("1"));
        assert_eq!(cfg.get_parsed("train", "steps", 0usize), 200);
        assert!((cfg.get_parsed("train", "lr", 0.0f64) - 0.03).abs() < 1e-12);
        assert_eq!(cfg.get("train", "name"), Some("nmnist"));
        assert!(cfg.get_parsed("train", "full", false));
    }

    #[test]
    fn defaults_for_missing() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.get("x", "y"), None);
        assert_eq!(cfg.get_parsed("x", "y", 9u32), 9);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("not a kv").is_err());
        assert!(Config::parse("[unterminated").is_err());
        let e = Config::parse("ok = 1\nbad line").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg = Config::parse("# header\n\n  # indented\nk = v\n").unwrap();
        assert_eq!(cfg.get("", "k"), Some("v"));
    }
}
