//! The multi-tenant session layer: many concurrent camera streams over
//! one shared, fixed-size worker fleet.
//!
//! Everything below L3 — router write shards, the STCF shard pool,
//! dirty-band snapshots — assumed one sensor stream owning dedicated
//! thread teams, so N cameras would have cost N×(denoise_shards +
//! write_shards) threads with no admission control. This module
//! multiplexes instead: each [`SessionManager`] session keeps its own
//! *state* (band arrays, STCF surfaces, window clock, staging batcher)
//! but shares the fleet's *threads*, with every unit of work queued as
//! a (session, band)-tagged job.
//!
//! ## Stages and queues
//!
//! Mirroring the [`crate::coordinator`] stage diagram, with thread
//! teams replaced by queues on one pool:
//!
//! ```text
//!  session A ──ingest_batch──► staging (≤batch_size) ──┐ Score jobs (A, band)
//!  session B ──ingest_batch──► staging (≤batch_size) ──┤   + kept events
//!      ⋮                                               ▼
//!                                     ┌────────────────────────────┐
//!        admission control:           │  global ready queue        │
//!        max_sessions,                │  round-robin over every    │
//!        max_inflight_batches         │  (session, band) actor —   │
//!        reject-with-reason           │  one job per turn          │
//!                                     └──────┬─────────────────────┘
//!                                            │ workers (fixed pool)
//!                    ┌───────────────────────┼──────────────────────┐
//!                    ▼                       ▼                      ▼
//!            BandScorer job           BandWriter job          Snapshot job
//!            (score-then-write,       (write_batch +          (dirty-band
//!             halo ingests)            dirty watermark)        render / skip)
//!                    │ scores                                       │ band buf
//!                    ▼                                              ▼
//!            session staging ──► Write jobs per band ──► window frame composite
//! ```
//!
//! Per-band FIFO order makes a band's snapshot observe every write
//! queued before it; the round-robin ready queue gives fairness — a hot
//! camera only lengthens its own queue, never another session's turn.
//!
//! ## Supervision
//!
//! Every job body runs under a panic boundary on the worker
//! ([`crate::util::sync::catch_boundary`]); a caught panic files a
//! typed [`SessionFault`] and **quarantines** only the owning session —
//! the worker, the pool and every other session keep running, and a
//! worker that dies anyway is respawned by a supervisor thread under a
//! restart budget ([`SupervisionConfig`]):
//!
//! ```text
//!                       ┌ supervisor thread (respawn budget N per window,
//!                       │  exhausted → fleet `degraded` flag)
//!                       ▼
//!   workers ──job──► catch_boundary ──panic──► FaultBoard(session) ──► quarantined:
//!      │                                        │                      ingest/snapshot/
//!      │ ok                                     │ band freed           drain reject;
//!      ▼                                        ▼                      close/checkpoint
//!   reply as usual                     SupervisorStats buckets         still work
//! ```
//!
//! [`SessionManager::checkpoint`] serializes a session's full band
//! state into a CRC-guarded versioned blob;
//! [`SessionManager::restore_in_place`] (or
//! [`SessionManager::restore`], migrating to a fresh session) replays
//! it bit-for-bit and lifts the quarantine. Under overload
//! ([`SupervisorConfig`] pressure thresholds over ready-queue depth ×
//! resident bytes) on-demand snapshots degrade through typed tiers
//! ([`DegradeTier`]): defer provably event-free cold bands → serve
//! stale dirty-band caches (STALE-flagged on the wire) → shed new
//! sessions. Window frames are never degraded; exactness holds at every
//! tier. The chaos harness (`tests/fleet_chaos.rs`, seeded via
//! `TSISC_CHAOS_SEED`) injects panics, stalls and checkpoint corruption
//! at the scheduler fault points and holds the fleet to all of it.
//!
//! ## Per-batch complexity vs fleet size
//!
//! With S open sessions, B bands per session, W workers, n events per
//! batch and (2r+1)² STCF patches:
//!
//! | Operation | Producer side | Fleet side | Scaling | Resident memory |
//! |---|---|---|---|---|
//! | `ingest_batch` (no STCF) | O(n) stage + O(touched bands) job enqueues | O(n) writes | independent of S | first write materializes a band (lazy) — state is O(written bands), not O(H·W) |
//! | `ingest_batch` (sharded STCF) | O(n·(1 + halo dup)) item staging + reply merge | O(n·(2r+1)²) scoring across ≤ min(B, W) workers | per-session latency grows ∝ active sessions (fair share), fleet throughput bounded by W | dense scorer surfaces O(H·W); [`crate::denoise::StcfBackend::Cache`] holds O(capacity) entries instead |
//! | window frame | O(B) skip checks + composite memcpy | O(dirty) render work (dirty-band protocol) | clean bands cost no job at all | band buffers recycled; bands expired past the memory horizon **demote back to cold** |
//! | `open`/`close` | O(B) actor setup / teardown jobs | cold band structs (open — no plane allocation, no bank fit until first write), frees arrays (close) | bands gauge drops on close | open ≈ O(B) structs; idle sessions decay toward that constant |
//! | admission check | O(1) atomic read | — | rejects instead of buffering | — |
//!
//! Worker threads are bounded by [`ServeConfig::workers`] — never by
//! session count: band renders run with `render_chunks = 1` and
//! sessions spawn nothing. Per-session and fleet `resident_bytes`
//! gauges ([`SessionStats`]/[`ServeStats`]) keep the memory column
//! honest: the fleet workers re-measure a band after every job, so the
//! gauge tracks materialization, growth, demotion and close with no
//! producer round-trips.
//!
//! ## Exactness
//!
//! A session's frames are **bit-for-bit identical** to a standalone
//! [`crate::coordinator::pipeline::run`] of the same stream and config,
//! including mismatch-enabled ISC backends — the band jobs drive the
//! very structs the dedicated router/pool threads drive
//! ([`crate::coordinator::router::BandWriter`],
//! [`crate::denoise::sharded::BandScorer`]), and the position-stable
//! mismatch assignment ([`crate::isc::param_index_at`]) makes every
//! band array an exact window of the full-sensor array regardless of
//! how sessions land on the fleet. `tests/serve_equiv.rs` asserts it
//! across 1/4/16 concurrent sessions with mixed resolutions.
//!
//! The scheduling core itself (ready queue, at-most-once actor
//! scheduling, hold gate) is the generic [`crate::util::actor`] pool,
//! model-checked under loom — see `tests/loom_sched.rs`.
//!
//! ## The network front door
//!
//! [`net`] puts this fleet behind a TCP listener — the AER bus of the
//! paper's Fig. 3a stretched over a socket. Connection lifecycle maps
//! 1:1 onto session lifecycle; every failure mode is a typed, counted
//! rejection ([`NetStats`]), and a faulted connection's session is
//! always drained through `drain`/`close`, never dropped:
//!
//! ```text
//!   camera ──TCP──► listener ──► framer (len+crc frames,      ──► session jobs
//!   clients        (accept cap:   incremental AER decode,          (ingest_batch /
//!     ⋮             shed whole    deadlines, decode-error          snapshot /
//!   faulty ──TCP──► conns first)  budget, seq dedup)               drain+close)
//!                        │             │ ACK / NACK(code, retry-after) / FRAME
//!                        ▼             ▼
//!                     NetStats    back to the client (backoff + jitter on NACK)
//! ```
//!
//! ## The telemetry plane
//!
//! [`obs`] is the measurement layer over all of the above (built on
//! [`crate::util::telemetry`]): every scheduler job records queue-wait
//! vs service time into per-session **and** fleet log2 histograms (µs),
//! each pipeline stage (decode → score → route → render → composite)
//! gets a span, a bounded per-session flight recorder captures the last
//! jobs before a quarantine (dumped into [`SessionFault::recent`]), and
//! one Prometheus-style scrape body is served from three surfaces:
//!
//! ```text
//!   job done ──► SessionObs ──double-record──► FleetObs(Registry)
//!                  │ flight ring                 │ render_fleet_text
//!                  ▼                             ├──► STATS_REQ/STATS (wire)
//!   quarantine ──► SessionFault.recent           ├──► --metrics ADDR (HTTP)
//!                                                └──► ObsJsonWriter (bench JSON)
//! ```
//!
//! Histograms/spans/flight recorder compile out under `telemetry-off`;
//! counters stay (they double as functional state). Frames are
//! bit-for-bit identical either way (`tests/telemetry_equiv.rs`).

// Serving code must surface failures as typed rejects or expects with
// context, never bare unwraps (tests are exempt).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod net;
pub mod obs;
mod scheduler;
pub mod session;
pub mod stats;
pub mod supervise;

pub use crate::util::actor::SupervisionConfig;
pub use obs::{FleetObs, FlightSample, MetricsServer, ObsJsonWriter, SessionObs};
pub use scheduler::HoldGuard;
pub use session::{Reject, RestoreError, ServeConfig, SessionConfig, SessionId, SessionManager};
pub use stats::{NetStats, ServeStats, SessionReport, SessionStats, SupervisorStats};
pub use supervise::{
    CheckpointError, DegradeTier, FaultJobKind, SchedFaultKind, SchedFaultPlan, SessionFault,
    SupervisorConfig,
};
