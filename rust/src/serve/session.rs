//! Multi-tenant camera sessions over the shared worker fleet.
//!
//! A [`SessionManager`] hosts many concurrent sessions — each with its
//! own resolution, ISC config, STCF stage and window clock — on one
//! fixed [`scheduler`](super::scheduler) worker pool. Per session it
//! reproduces the coordinator pipeline's streaming semantics **exactly**
//! (same staging batcher, same band layout, same causal
//! score-then-write order, same dirty-band snapshot protocol), so the
//! frames a session emits are bit-for-bit identical to a standalone
//! [`crate::coordinator::pipeline::run`] of the same stream and config
//! — verified in `tests/serve_equiv.rs` across 1/4/16 concurrent
//! sessions with mismatch-enabled ISC backends.
//!
//! Admission control: `open` rejects past [`ServeConfig::max_sessions`];
//! `ingest_batch` rejects (with [`Reject::Backpressure`]) while the
//! session's in-flight write batches sit at
//! [`ServeConfig::max_inflight_batches`] — queues stay bounded instead
//! of buffering a hot camera unboundedly. Within the bound, a batch is
//! accepted in full; the per-call overshoot is at most one write job
//! per touched band per internal flush.

use super::scheduler::{
    BandActor, BandState, CloseDone, HoldGuard, Job, ScoreDone, SnapDone, WorkerPool,
};
use super::stats::{latency_percentiles_ms, ServeStats, SessionReport, SessionStats};
use crate::coordinator::router::BandWriter;
use crate::coordinator::{DenoiseStats, PipelineConfig, PipelineStats, RouterStats, StageWall};
use crate::denoise::sharded::{stage_items, BandScorer, ScoreItem, ShardBackend, ShardTally};
use crate::denoise::{support_count, StcfBackend, StcfParams};
use crate::events::{Event, LabeledEvent, Resolution};
use crate::util::grid::Grid;
use crate::util::parallel::band_layout;
use crate::util::sync::chan::bounded;
use crate::util::sync::{Arc, AtomicUsize, Ordering};
use std::collections::BTreeMap;
use std::time::Instant;

/// Opaque session handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(u64);

impl SessionId {
    /// The raw id (stable for the manager's lifetime).
    pub fn raw(self) -> u64 {
        self.0
    }
}

impl std::fmt::Display for SessionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Why the manager refused a request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reject {
    /// `open` at the [`ServeConfig::max_sessions`] ceiling.
    TooManySessions { open: usize, max: usize },
    /// `ingest_batch` while the session's queued write batches sit at
    /// [`ServeConfig::max_inflight_batches`]. Retry after the fleet
    /// drains; nothing from the rejected batch was ingested.
    Backpressure { queued: usize, max: usize },
    /// Unknown (or already closed) session id.
    UnknownSession(u64),
}

impl Reject {
    /// Stable wire code for this rejection, carried verbatim in the net
    /// front door's NACK frames (`serve::net`). These values are part of
    /// the wire protocol: never renumber, only append. Codes ≥ 10 are
    /// reserved for net-layer (framing/deadline) rejections — see
    /// `serve::net::frame::code`.
    pub fn code(&self) -> u16 {
        match self {
            Reject::TooManySessions { .. } => 1,
            Reject::Backpressure { .. } => 2,
            Reject::UnknownSession(_) => 3,
        }
    }
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::TooManySessions { open, max } => {
                write!(f, "session limit reached: {open} open of fleet cap {max}")
            }
            Reject::Backpressure { queued, max } => {
                write!(
                    f,
                    "backpressure: {queued} of {max} allowed write batches in flight; \
                     retry after the fleet drains"
                )
            }
            Reject::UnknownSession(id) => write!(f, "unknown session s{id}"),
        }
    }
}

impl std::error::Error for Reject {}

/// Fleet configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Fixed worker-thread count shared by every session (≥ 1).
    pub workers: usize,
    /// Admission ceiling on concurrently open sessions.
    pub max_sessions: usize,
    /// Per-session bound on queued write batches — the backpressure
    /// knob: `ingest_batch` rejects instead of buffering past it.
    pub max_inflight_batches: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: crate::util::parallel::available_threads(),
            max_sessions: 64,
            max_inflight_batches: 64,
        }
    }
}

/// Per-session configuration: the stream's geometry and end time plus
/// the exact pipeline shape a standalone run would use.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Display label for fleet summaries.
    pub name: String,
    pub res: Resolution,
    /// Stream end time: window frames are emitted for every boundary
    /// ≤ `t_end_us`, exactly as `pipeline::run(events, res, t_end_us, …)`.
    pub t_end_us: u64,
    /// Window period, STCF stage, band counts, batch size and ISC
    /// config — the same struct `pipeline::run` takes. Results are
    /// interpreted identically; two knobs are moot for queueing only:
    /// `router.queue_depth` (serve bounds queues per session via
    /// [`ServeConfig::max_inflight_batches`]) and `router.batch_size`
    /// (serve ships one write job per touched band per staged flush —
    /// message boundaries never change band state, so frames are
    /// unaffected).
    pub pipeline: PipelineConfig,
}

/// The inline STCF stage (`denoise_shards: 0`): scored on the calling
/// thread, mirroring the pipeline's inline path decision-for-decision.
struct InlineStage {
    backend: StcfBackend,
    prm: StcfParams,
    tally: ShardTally,
}

/// Router-side cached band state (the dirty-band snapshot protocol,
/// mirroring `coordinator::router::BandCache`).
struct BandCache {
    buf: Option<Grid<f64>>,
    at_us: u64,
    valid: bool,
    /// The cached band is all-zero and stays all-zero at any later query
    /// time absent new writes (see the router's dirty-band docs).
    empty_static: bool,
}

/// One open session's state (producer-side; band state lives on the
/// fleet's actors).
struct Session {
    id: SessionId,
    cfg: SessionConfig,
    write_actors: Vec<Arc<BandActor>>,
    /// Sharded STCF bands (empty when the STCF is off or inline).
    score_actors: Vec<Arc<BandActor>>,
    inline: Option<InlineStage>,
    band_h: usize,
    score_band_h: usize,
    score_radius: usize,
    caches: Vec<BandCache>,
    band_dirty: Vec<bool>,
    inflight: Arc<AtomicUsize>,
    /// Resident bytes of the session's band states, maintained by the
    /// fleet's workers as jobs complete (materialization, growth,
    /// demotion, close — see `scheduler::sync_resident`).
    resident: Arc<AtomicUsize>,
    // Streaming state (the pipeline's producer loop, verbatim).
    pre: Vec<LabeledEvent>,
    kept: Vec<LabeledEvent>,
    scores: Vec<u32>,
    score_staging: Vec<Vec<ScoreItem>>,
    route_staging: Vec<Vec<Event>>,
    next_frame: u64,
    // Counters.
    events_in: u64,
    events_routed: u64,
    dropped: u64,
    peak_batch_len: usize,
    batches_shipped: u64,
    snapshots_served: u64,
    bands_skipped_unchanged: u64,
    frames_emitted: u64,
    rejected_batches: u64,
    peak_queue_depth: usize,
    /// Ring of per-`ingest_batch` wall latencies (bounded so long-lived
    /// sessions don't grow without limit).
    batch_latency_s: Vec<f64>,
    latency_cursor: usize,
    stage_wall: StageWall,
    opened: Instant,
}

/// Latency samples kept per session (ring buffer).
const LATENCY_SAMPLES: usize = 16_384;

impl Session {
    /// The pipeline producer loop body for one event (staging + window
    /// clock), emitting window frames into `frames`.
    fn push(&mut self, pool: &WorkerPool, le: LabeledEvent, frames: &mut Vec<(u64, Grid<f64>)>) {
        debug_assert!(
            self.cfg.res.contains(le.ev.x, le.ev.y),
            "off-sensor event {:?} for {}x{} session",
            le.ev,
            self.cfg.res.width,
            self.cfg.res.height
        );
        self.events_in += 1;
        let window = self.cfg.pipeline.window_us;
        while le.ev.t > self.next_frame && self.next_frame <= self.cfg.t_end_us {
            self.flush(pool);
            let at = self.next_frame;
            let frame = self.snapshot_frame(pool, at);
            self.frames_emitted += 1;
            frames.push((at, frame));
            self.next_frame += window;
        }
        self.pre.push(le);
        if self.pre.len() >= self.cfg.pipeline.batch_size.max(1) {
            self.flush(pool);
        }
    }

    /// Push the staged batch through the STCF stage (when configured)
    /// and ship the survivors to the band writers.
    fn flush(&mut self, pool: &WorkerPool) {
        self.peak_batch_len = self.peak_batch_len.max(self.pre.len());
        if self.pre.is_empty() {
            return;
        }
        if self.cfg.pipeline.stcf.is_some() {
            let t0 = Instant::now();
            self.kept.clear();
            if let Some(st) = &mut self.inline {
                for le in &self.pre {
                    let s = support_count(&st.backend, &le.ev, &st.prm);
                    st.backend.ingest(&le.ev, &st.prm);
                    st.tally.scored += 1;
                    if s >= st.prm.threshold {
                        st.tally.kept += 1;
                        self.kept.push(*le);
                    } else {
                        st.tally.dropped += 1;
                    }
                }
            } else {
                self.score_sharded(pool);
            }
            self.stage_wall.denoise_seconds += t0.elapsed().as_secs_f64();
            self.dropped += (self.pre.len() - self.kept.len()) as u64;
            let t0 = Instant::now();
            self.route(pool, true);
            self.stage_wall.route_seconds += t0.elapsed().as_secs_f64();
        } else {
            let t0 = Instant::now();
            self.route(pool, false);
            self.stage_wall.route_seconds += t0.elapsed().as_secs_f64();
        }
        self.pre.clear();
    }

    /// Fan `pre` out to the scorer bands (identical item construction
    /// to `StcfShardPool::score_batch`), wait for the per-band replies,
    /// and fill `kept` threshold-gated in input order.
    fn score_sharded(&mut self, pool: &WorkerPool) {
        let n = self.score_actors.len();
        stage_items(
            self.cfg.res,
            self.score_band_h,
            n,
            self.score_radius,
            &self.pre,
            &mut self.score_staging,
        );
        let (tx, rx) = bounded::<ScoreDone>(n);
        let mut in_flight = 0usize;
        for b in 0..n {
            if self.score_staging[b].is_empty() {
                continue;
            }
            let items = std::mem::take(&mut self.score_staging[b]);
            pool.enqueue(&self.score_actors[b], Job::Score { items, reply: tx.clone() });
            in_flight += 1;
        }
        drop(tx);
        self.scores.clear();
        self.scores.resize(self.pre.len(), 0);
        for done in rx.iter().take(in_flight) {
            for (idx, s) in done.scores {
                self.scores[idx as usize] = s;
            }
        }
        let threshold = self.cfg.pipeline.stcf.expect("sharded scoring needs stcf").threshold;
        for (le, &s) in self.pre.iter().zip(&self.scores) {
            if s >= threshold {
                self.kept.push(*le);
            }
        }
    }

    /// Ship `kept` (or raw `pre`) to the band writers: one write job per
    /// touched band, coalesced over consecutive same-band runs exactly
    /// like `Router::route_batch` staging.
    fn route(&mut self, pool: &WorkerPool, from_kept: bool) {
        let events: &[LabeledEvent] = if from_kept { &self.kept } else { &self.pre };
        let band_h = self.band_h;
        let n = self.write_actors.len();
        let mut i = 0usize;
        while i < events.len() {
            let s = (events[i].ev.y as usize / band_h).min(n - 1);
            let mut j = i + 1;
            while j < events.len() && (events[j].ev.y as usize / band_h).min(n - 1) == s {
                j += 1;
            }
            self.route_staging[s].extend(events[i..j].iter().map(|le| le.ev));
            i = j;
        }
        for s in 0..n {
            if self.route_staging[s].is_empty() {
                continue;
            }
            let batch = std::mem::take(&mut self.route_staging[s]);
            self.events_routed += batch.len() as u64;
            // The in-flight gauge bumps before the job is visible to any
            // worker, so admission control never undercounts.
            self.inflight.fetch_add(1, Ordering::SeqCst);
            pool.enqueue(&self.write_actors[s], Job::Write(batch));
            self.batches_shipped += 1;
            self.band_dirty[s] = true;
        }
        self.peak_queue_depth = self.peak_queue_depth.max(self.inflight.load(Ordering::SeqCst));
    }

    /// Scatter-gather one frame at `at_us` — `Router::frame_into`'s
    /// dirty-band protocol over the fleet: provably-clean bands
    /// composite from the session cache with no job at all, the rest
    /// snapshot behind their pending writes in band-FIFO order.
    fn snapshot_frame(&mut self, pool: &WorkerPool, at_us: u64) -> Grid<f64> {
        let t0 = Instant::now();
        self.snapshots_served += 1;
        let w = self.cfg.res.width as usize;
        let mut out = Grid::new(w, self.cfg.res.height as usize, 0.0f64);
        let n = self.write_actors.len();
        let (tx, rx) = bounded::<SnapDone>(n);
        let mut in_flight = 0usize;
        for s in 0..n {
            let cache = &mut self.caches[s];
            let skip = cache.valid
                && !self.band_dirty[s]
                && (cache.at_us == at_us || (cache.empty_static && at_us >= cache.at_us));
            if skip {
                cache.at_us = at_us;
                self.bands_skipped_unchanged += 1;
                continue;
            }
            let buf = cache.buf.take().expect("band buffer in flight");
            let job = Job::Snapshot {
                at_us,
                buf,
                cache_valid: cache.valid,
                band: s,
                reply: tx.clone(),
            };
            pool.enqueue(&self.write_actors[s], job);
            in_flight += 1;
        }
        drop(tx);
        for r in rx.iter().take(in_flight) {
            if !r.rendered {
                self.bands_skipped_unchanged += 1;
            }
            let cache = &mut self.caches[r.band];
            cache.buf = Some(r.buf);
            cache.at_us = at_us;
            cache.valid = true;
            cache.empty_static = r.empty_static;
            self.band_dirty[r.band] = false;
        }
        let slice = out.as_mut_slice();
        for (s, cache) in self.caches.iter().enumerate() {
            let band = cache.buf.as_ref().expect("band buffer returned");
            let y0 = s * self.band_h;
            slice[y0 * w..y0 * w + band.len()].copy_from_slice(band.as_slice());
        }
        self.stage_wall.snapshot_seconds += t0.elapsed().as_secs_f64();
        out
    }

    fn live_stats(&self) -> SessionStats {
        let (p50, p99) = latency_percentiles_ms(&self.batch_latency_s);
        SessionStats {
            id: self.id.raw(),
            name: self.cfg.name.clone(),
            res: self.cfg.res,
            events_in: self.events_in,
            events_routed: self.events_routed,
            events_dropped_by_stcf: self.dropped,
            frames_emitted: self.frames_emitted,
            snapshots_served: self.snapshots_served,
            bands_skipped_unchanged: self.bands_skipped_unchanged,
            batches_shipped: self.batches_shipped,
            queue_depth: self.inflight.load(Ordering::SeqCst),
            peak_queue_depth: self.peak_queue_depth,
            rejected_batches: self.rejected_batches,
            batch_latency_p50_ms: p50,
            batch_latency_p99_ms: p99,
            resident_bytes: self.resident.load(Ordering::SeqCst),
        }
    }
}

/// The multi-tenant session manager (see the module docs).
pub struct SessionManager {
    cfg: ServeConfig,
    pool: WorkerPool,
    sessions: BTreeMap<u64, Session>,
    next_id: u64,
    open_bands: Arc<AtomicUsize>,
    /// Rejections + events of already-closed sessions (fleet totals).
    closed_rejected: u64,
    closed_events_in: u64,
}

impl SessionManager {
    /// Start a manager with a fresh fixed-size worker fleet.
    pub fn new(cfg: ServeConfig) -> Self {
        Self {
            pool: WorkerPool::new(cfg.workers),
            cfg,
            sessions: BTreeMap::new(),
            next_id: 0,
            open_bands: Arc::new(AtomicUsize::new(0)),
            closed_rejected: 0,
            closed_events_in: 0,
        }
    }

    /// Open a session: builds its band writers (and scorer bands when
    /// the STCF is sharded) as fleet actors. Rejects at the session
    /// ceiling.
    pub fn open(&mut self, cfg: SessionConfig) -> Result<SessionId, Reject> {
        if self.sessions.len() >= self.cfg.max_sessions {
            return Err(Reject::TooManySessions {
                open: self.sessions.len(),
                max: self.cfg.max_sessions,
            });
        }
        let id = SessionId(self.next_id);
        self.next_id += 1;
        let inflight = Arc::new(AtomicUsize::new(0));
        let resident = Arc::new(AtomicUsize::new(0));
        let height = cfg.res.height as usize;
        let (band_h, n_bands) = band_layout(height, cfg.pipeline.router.n_shards);
        let write_actors: Vec<Arc<BandActor>> = (0..n_bands)
            .map(|s| {
                // render_chunks = 1: the fleet's workers are the
                // parallelism; band renders must not spawn threads.
                let writer = BandWriter::for_band(cfg.res, &cfg.pipeline.router.isc, band_h, s, 1);
                self.pool.spawn_actor(
                    BandState::Writer(Box::new(writer)),
                    inflight.clone(),
                    self.open_bands.clone(),
                    resident.clone(),
                )
            })
            .collect();
        let sharded = cfg.pipeline.stcf.is_some() && cfg.pipeline.denoise_shards > 0;
        let (score_band_h, n_score) = if sharded {
            band_layout(height, cfg.pipeline.denoise_shards)
        } else {
            (height, 0)
        };
        let score_radius =
            cfg.pipeline.stcf.map(|prm| prm.radius as usize).unwrap_or(0);
        let score_actors: Vec<Arc<BandActor>> = (0..n_score)
            .map(|s| {
                let prm = cfg.pipeline.stcf.expect("sharded stage needs stcf");
                let backend = ShardBackend::Isc(cfg.pipeline.router.isc.clone());
                let scorer = BandScorer::for_band(cfg.res, &backend, prm, score_band_h, s);
                self.pool.spawn_actor(
                    BandState::Scorer(Box::new(scorer)),
                    inflight.clone(),
                    self.open_bands.clone(),
                    resident.clone(),
                )
            })
            .collect();
        let inline = match (&cfg.pipeline.stcf, sharded) {
            (Some(prm), false) => Some(InlineStage {
                backend: StcfBackend::isc(
                    cfg.res,
                    cfg.pipeline.router.isc.clone(),
                    prm.tau_tw_us,
                ),
                prm: *prm,
                tally: ShardTally::default(),
            }),
            _ => None,
        };
        let batch_size = cfg.pipeline.batch_size.max(1);
        let next_frame = cfg.pipeline.window_us;
        let session = Session {
            id,
            write_actors,
            score_actors,
            inline,
            band_h,
            score_band_h,
            score_radius,
            caches: (0..n_bands)
                .map(|_| BandCache {
                    buf: Some(Grid::new(1, 1, 0.0)),
                    at_us: 0,
                    valid: false,
                    empty_static: false,
                })
                .collect(),
            band_dirty: vec![false; n_bands],
            inflight,
            resident,
            pre: Vec::with_capacity(batch_size),
            kept: Vec::with_capacity(batch_size),
            scores: Vec::new(),
            score_staging: (0..n_score).map(|_| Vec::new()).collect(),
            route_staging: (0..n_bands).map(|_| Vec::new()).collect(),
            next_frame,
            events_in: 0,
            events_routed: 0,
            dropped: 0,
            peak_batch_len: 0,
            batches_shipped: 0,
            snapshots_served: 0,
            bands_skipped_unchanged: 0,
            frames_emitted: 0,
            rejected_batches: 0,
            peak_queue_depth: 0,
            batch_latency_s: Vec::new(),
            latency_cursor: 0,
            stage_wall: StageWall::default(),
            opened: Instant::now(),
            cfg,
        };
        self.sessions.insert(id.raw(), session);
        Ok(id)
    }

    /// Ingest a time-sorted labeled batch, returning any window frames
    /// the stream crossed. Rejected in full (nothing ingested) while the
    /// session's queued write batches sit at the in-flight bound.
    pub fn ingest_batch(
        &mut self,
        sid: SessionId,
        events: &[LabeledEvent],
    ) -> Result<Vec<(u64, Grid<f64>)>, Reject> {
        let s = self.sessions.get_mut(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        let queued = s.inflight.load(Ordering::SeqCst);
        if queued >= self.cfg.max_inflight_batches {
            s.rejected_batches += 1;
            return Err(Reject::Backpressure { queued, max: self.cfg.max_inflight_batches });
        }
        let t0 = Instant::now();
        let mut frames = Vec::new();
        for le in events {
            s.push(&self.pool, *le, &mut frames);
        }
        let dt = t0.elapsed().as_secs_f64();
        if s.batch_latency_s.len() < LATENCY_SAMPLES {
            s.batch_latency_s.push(dt);
        } else {
            s.batch_latency_s[s.latency_cursor] = dt;
            s.latency_cursor = (s.latency_cursor + 1) % LATENCY_SAMPLES;
        }
        Ok(frames)
    }

    /// On-demand frame at `at_us` (flushes staged events first, like
    /// `Router::frame`). Must be causal — non-decreasing and ≥ the
    /// session's ingested event times — the same contract as every
    /// snapshot in the stack; causal on-demand snapshots never perturb
    /// the window frames.
    pub fn snapshot(&mut self, sid: SessionId, at_us: u64) -> Result<Grid<f64>, Reject> {
        let s = self.sessions.get_mut(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        s.flush(&self.pool);
        Ok(s.snapshot_frame(&self.pool, at_us))
    }

    /// Flush staged events and emit every remaining window frame through
    /// `t_end_us` — the pipeline run's tail, so `ingest_batch` frames +
    /// `drain` frames together are exactly `pipeline::run`'s frame list.
    pub fn drain(&mut self, sid: SessionId) -> Result<Vec<(u64, Grid<f64>)>, Reject> {
        let s = self.sessions.get_mut(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        s.flush(&self.pool);
        let mut frames = Vec::new();
        while s.next_frame <= s.cfg.t_end_us {
            let at = s.next_frame;
            let frame = s.snapshot_frame(&self.pool, at);
            s.frames_emitted += 1;
            frames.push((at, frame));
            s.next_frame += s.cfg.pipeline.window_us;
        }
        Ok(frames)
    }

    /// Close a session: flushes its staged events, waits for its queued
    /// jobs, frees its bands on the fleet, and returns the final
    /// accounting (a full `PipelineStats` among it). Every event an
    /// `ingest_batch` call acknowledged is written before the final
    /// per-band counts are read: the flush ships staged events as write
    /// jobs and the `Close` jobs queue *behind* them on each band's FIFO
    /// mailbox, so in-flight writes are never silently discarded. (The
    /// remaining window frames through `t_end_us` are still only emitted
    /// by `drain` — call it first when the caller wants the frame tail.)
    pub fn close(&mut self, sid: SessionId) -> Result<SessionReport, Reject> {
        let mut s =
            self.sessions.remove(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        s.flush(&self.pool);
        let n_actors = s.write_actors.len() + s.score_actors.len();
        let (tx, rx) = bounded::<CloseDone>(n_actors);
        for (b, actor) in s.write_actors.iter().enumerate() {
            self.pool.enqueue(actor, Job::Close { band: b, reply: tx.clone() });
        }
        for (b, actor) in s.score_actors.iter().enumerate() {
            let band = s.write_actors.len() + b;
            self.pool.enqueue(actor, Job::Close { band, reply: tx.clone() });
        }
        drop(tx);
        let mut per_shard = vec![0u64; s.write_actors.len()];
        let mut tallies: Vec<(usize, ShardTally)> = Vec::new();
        for done in rx.iter().take(n_actors) {
            if let Some(t) = done.tally {
                tallies.push((done.band, t));
            } else if done.band < per_shard.len() {
                per_shard[done.band] = done.written;
            }
        }
        tallies.sort_by_key(|(b, _)| *b);
        let denoise = match (&s.cfg.pipeline.stcf, s.inline.take()) {
            (Some(_), Some(st)) => {
                Some(DenoiseStats { inline_scoring: true, per_shard: vec![st.tally] })
            }
            (Some(_), None) => Some(DenoiseStats {
                inline_scoring: false,
                per_shard: tallies.into_iter().map(|(_, t)| t).collect(),
            }),
            _ => None,
        };
        let wall = s.opened.elapsed().as_secs_f64();
        let stats = s.live_stats();
        let pipeline = PipelineStats {
            events_in: s.events_in,
            events_written: per_shard.iter().sum(),
            events_dropped_by_stcf: s.dropped,
            frames_emitted: s.frames_emitted,
            peak_batch_len: s.peak_batch_len,
            wall_seconds: wall,
            stage_wall: s.stage_wall.clone(),
            denoise,
            router: RouterStats {
                events_routed: s.events_routed,
                per_shard,
                batches_shipped: s.batches_shipped,
                snapshots_served: s.snapshots_served,
                bands_skipped_unchanged: s.bands_skipped_unchanged,
            },
            events_per_second: if wall > 0.0 { s.events_in as f64 / wall } else { 0.0 },
        };
        self.closed_rejected += s.rejected_batches;
        self.closed_events_in += s.events_in;
        Ok(SessionReport { stats, pipeline })
    }

    /// Live band states on the fleet (drops to 0 once every session is
    /// closed — "close frees its bands").
    pub fn open_bands(&self) -> usize {
        self.open_bands.load(Ordering::SeqCst)
    }

    /// Open session count.
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Pause the worker fleet until the guard drops (maintenance drains,
    /// deterministic backpressure tests). While held, write jobs queue
    /// but nothing executes — so `snapshot`/`drain`/`close` and sharded
    /// scoring, which wait on job replies, will block until release.
    pub fn hold_workers(&self) -> HoldGuard {
        self.pool.hold()
    }

    /// Fleet-wide statistics snapshot. `net` is zeroed here — the fleet
    /// doesn't know about sockets; `serve::net::NetServer::stats` fills
    /// it for wire-driven fleets.
    pub fn stats(&self) -> ServeStats {
        let sessions: Vec<SessionStats> =
            self.sessions.values().map(Session::live_stats).collect();
        ServeStats {
            net: Default::default(),
            workers: self.pool.workers(),
            open_sessions: sessions.len(),
            open_bands: self.open_bands(),
            jobs_executed: self.pool.jobs_executed(),
            ready_depth: self.pool.ready_depth(),
            rejected_batches: self.closed_rejected
                + sessions.iter().map(|s| s.rejected_batches).sum::<u64>(),
            events_in: self.closed_events_in
                + sessions.iter().map(|s| s.events_in).sum::<u64>(),
            resident_bytes: sessions.iter().map(|s| s.resident_bytes).sum(),
            sessions,
        }
    }

    /// Close every remaining session and stop the worker fleet,
    /// returning the final fleet statistics.
    pub fn shutdown(mut self) -> ServeStats {
        let ids: Vec<u64> = self.sessions.keys().copied().collect();
        for id in ids {
            let _ = self.close(SessionId(id));
        }
        let stats = self.stats();
        self.pool.shutdown();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn stream(n: u64, res: Resolution) -> Vec<LabeledEvent> {
        (0..n)
            .map(|k| LabeledEvent {
                ev: Event::new(
                    1 + k * 1_000,
                    (k % res.width as u64) as u16,
                    (k % res.height as u64) as u16,
                    Polarity::On,
                ),
                is_signal: true,
            })
            .collect()
    }

    fn session_cfg(res: Resolution, t_end_us: u64) -> SessionConfig {
        SessionConfig {
            name: "test".into(),
            res,
            t_end_us,
            pipeline: PipelineConfig::default(),
        }
    }

    #[test]
    fn open_ingest_drain_close_lifecycle() {
        let mut m = SessionManager::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let res = Resolution::new(16, 16);
        let sid = m.open(session_cfg(res, 100_000)).unwrap();
        assert_eq!(m.session_count(), 1);
        assert!(m.open_bands() > 0);
        let evs = stream(100, res); // covers 0..100 ms, 50 ms windows
        let mut frames = m.ingest_batch(sid, &evs).unwrap();
        frames.extend(m.drain(sid).unwrap());
        assert_eq!(frames.len(), 2);
        let report = m.close(sid).unwrap();
        assert_eq!(report.pipeline.events_in, 100);
        assert_eq!(report.pipeline.events_written, 100);
        assert_eq!(report.pipeline.frames_emitted, 2);
        assert_eq!(m.open_bands(), 0, "close must free every band");
        assert_eq!(m.session_count(), 0);
        assert!(matches!(m.ingest_batch(sid, &evs), Err(Reject::UnknownSession(_))));
        m.shutdown();
    }

    #[test]
    fn session_ceiling_rejects_with_reason() {
        let mut m = SessionManager::new(ServeConfig {
            workers: 1,
            max_sessions: 2,
            ..ServeConfig::default()
        });
        let res = Resolution::new(8, 8);
        m.open(session_cfg(res, 10_000)).unwrap();
        m.open(session_cfg(res, 10_000)).unwrap();
        match m.open(session_cfg(res, 10_000)) {
            Err(Reject::TooManySessions { open: 2, max: 2 }) => {}
            other => panic!("expected session-ceiling reject, got {other:?}"),
        }
        m.shutdown();
    }

    #[test]
    fn held_fleet_builds_bounded_queue_then_rejects() {
        let mut m = SessionManager::new(ServeConfig {
            workers: 2,
            max_sessions: 4,
            max_inflight_batches: 3,
        });
        let res = Resolution::new(8, 8);
        let mut cfg = session_cfg(res, 10_000_000);
        cfg.pipeline.batch_size = 4; // every call flushes
        cfg.pipeline.window_us = 100_000_000; // no window crossing
        let sid = m.open(cfg).unwrap();
        let hold = m.hold_workers();
        let evs = stream(4, res);
        let mut rejected = 0u64;
        for _ in 0..20 {
            match m.ingest_batch(sid, &evs) {
                Ok(_) => {}
                Err(Reject::Backpressure { queued, max }) => {
                    assert_eq!(max, 3);
                    assert!(queued >= 3);
                    rejected += 1;
                }
                Err(other) => panic!("unexpected reject {other:?}"),
            }
        }
        assert!(rejected > 0, "a held fleet must reject past the in-flight bound");
        let st = m.stats();
        assert_eq!(st.rejected_batches, rejected);
        // Queue stayed bounded: at most the admission bound plus one
        // call's own flush (≤ touched bands) ever sat in flight.
        assert!(
            st.sessions[0].peak_queue_depth
                <= 3 + st.sessions[0].batches_shipped as usize,
        );
        drop(hold);
        // Released fleet drains and the session closes cleanly.
        let report = m.close(sid).unwrap();
        assert_eq!(report.stats.rejected_batches, rejected);
        assert_eq!(report.pipeline.events_in, report.pipeline.events_written);
        m.shutdown();
    }

    #[test]
    fn reject_is_a_coded_error_with_numbered_reasons() {
        let cases = [
            (Reject::TooManySessions { open: 7, max: 8 }, 1u16, ["7", "8"]),
            (Reject::Backpressure { queued: 5, max: 6 }, 2, ["5", "6"]),
            (Reject::UnknownSession(42), 3, ["42", "s42"]),
        ];
        for (reject, code, needles) in cases {
            assert_eq!(reject.code(), code);
            let msg = reject.to_string();
            for n in needles {
                assert!(msg.contains(n), "Display {msg:?} must carry {n:?}");
            }
            // Usable as a boxed error (satellite: impl std::error::Error).
            let boxed: Box<dyn std::error::Error> = Box::new(reject);
            assert_eq!(boxed.to_string(), msg);
        }
    }

    #[test]
    fn close_flushes_staged_and_queued_batches() {
        // Regression: a session closed with events still staged in the
        // producer batcher AND write jobs still queued on the fleet must
        // account every acked event as written, not silently drop them.
        let mut m = SessionManager::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let res = Resolution::new(8, 8);
        let mut cfg = session_cfg(res, 10_000_000);
        cfg.pipeline.batch_size = 7; // 64 events: 9 flushed jobs + 1 staged
        cfg.pipeline.window_us = 100_000_000; // no window crossing
        let sid = m.open(cfg).unwrap();
        m.ingest_batch(sid, &stream(64, res)).unwrap();
        let report = m.close(sid).unwrap();
        assert_eq!(report.pipeline.events_in, 64);
        assert_eq!(report.pipeline.events_written, 64, "close must flush the staged tail");
        m.shutdown();
    }

    #[test]
    fn many_sessions_share_a_small_fixed_fleet() {
        // 6 sessions on 2 workers: everything completes, the fleet
        // reports 2 workers regardless of session count, and each
        // session's frames land independently.
        let mut m = SessionManager::new(ServeConfig {
            workers: 2,
            max_sessions: 8,
            ..ServeConfig::default()
        });
        let resolutions = [Resolution::new(16, 16), Resolution::new(8, 12)];
        let mut sids = Vec::new();
        for k in 0..6usize {
            let res = resolutions[k % 2];
            sids.push((m.open(session_cfg(res, 100_000)).unwrap(), res));
        }
        assert_eq!(m.stats().workers, 2);
        let mut emitted = vec![0usize; sids.len()];
        for (k, (sid, res)) in sids.iter().enumerate() {
            emitted[k] += m.ingest_batch(*sid, &stream(60, *res)).unwrap().len();
        }
        for (k, (sid, _)) in sids.iter().enumerate() {
            emitted[k] += m.drain(*sid).unwrap().len();
            assert_eq!(emitted[k], 2, "50 ms windows over 100 ms, session {k}");
        }
        let st = m.stats();
        assert_eq!(st.open_sessions, 6);
        assert!(st.jobs_executed > 0);
        let final_stats = m.shutdown();
        assert_eq!(final_stats.open_sessions, 0);
        assert_eq!(final_stats.open_bands, 0);
    }
}
