//! Multi-tenant camera sessions over the shared worker fleet.
//!
//! A [`SessionManager`] hosts many concurrent sessions — each with its
//! own resolution, ISC config, STCF stage and window clock — on one
//! fixed [`scheduler`](super::scheduler) worker pool. Per session it
//! reproduces the coordinator pipeline's streaming semantics **exactly**
//! (same staging batcher, same band layout, same causal
//! score-then-write order, same dirty-band snapshot protocol), so the
//! frames a session emits are bit-for-bit identical to a standalone
//! [`crate::coordinator::pipeline::run`] of the same stream and config
//! — verified in `tests/serve_equiv.rs` across 1/4/16 concurrent
//! sessions with mismatch-enabled ISC backends.
//!
//! Admission control: `open` rejects past [`ServeConfig::max_sessions`]
//! (and sheds under overload pressure, see below); `ingest_batch`
//! rejects (with [`Reject::Backpressure`]) while the session's in-flight
//! write batches sit at [`ServeConfig::max_inflight_batches`] — queues
//! stay bounded instead of buffering a hot camera unboundedly. Within
//! the bound, a batch is accepted in full; the per-call overshoot is at
//! most one write job per touched band per internal flush.
//!
//! ## Supervision (see [`super::supervise`])
//!
//! A job panic on the fleet quarantines the owning session: its bands
//! are freed, a typed [`SessionFault`] is filed, and every ingest /
//! snapshot / drain refuses with [`Reject::Quarantined`] until
//! [`SessionManager::restore_in_place`] replays a checkpoint. Healthy
//! sessions are unaffected — their exactness guarantees hold through a
//! neighbor's crash. [`SessionManager::checkpoint`] serializes a
//! session's full band state (CRC-guarded, versioned); a restored
//! session renders bit-for-bit identically to one that never crashed.
//! Under overload ([`SupervisorConfig`] pressure thresholds) on-demand
//! snapshots degrade through typed tiers — defer provably event-free
//! cold bands, serve stale dirty-band caches (flagged), shed new
//! sessions — while window frames stay exact at every tier.

use super::obs::{elapsed_us, render_fleet_text, FleetObs, SessionObs};
use super::scheduler::{
    BandActor, BandSeed, BandState, CheckpointDone, CloseDone, HoldGuard, Job, RestoreDone,
    ScoreDone, SnapDone, WorkerPool,
};
use super::stats::{latency_percentiles_us, ServeStats, SessionReport, SessionStats};
use super::supervise::{
    config_fingerprint, decode_checkpoint, encode_checkpoint, pressure, ArmedFault,
    BandCheckpoint, CheckpointError, DegradeTier, FaultBoard, SchedFaultPlan, SessionCheckpoint,
    SessionFault, SupervisorConfig, SupervisorCounters,
};
use crate::coordinator::router::BandWriter;
use crate::coordinator::{DenoiseStats, PipelineConfig, PipelineStats, RouterStats, StageWall};
use crate::denoise::sharded::{stage_items, BandScorer, ScoreItem, ShardBackend, ShardTally};
use crate::denoise::{support_count, StcfBackend, StcfParams};
use crate::events::{ClockPolicy, Event, LabeledEvent, Resolution};
use crate::util::grid::Grid;
use crate::util::parallel::band_layout;
use crate::util::sync::chan::bounded;
use crate::util::sync::{Arc, AtomicUsize, Ordering};
use std::collections::BTreeMap;
use std::time::Instant;

/// Combined band index the inline STCF stage checkpoints under (it is
/// producer-side state, not a band actor, but rides in the same
/// [`BandCheckpoint::Scorer`] record).
const INLINE_BAND: u16 = u16::MAX;

/// Opaque session handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(u64);

impl SessionId {
    /// The raw id (stable for the manager's lifetime).
    pub fn raw(self) -> u64 {
        self.0
    }
}

impl std::fmt::Display for SessionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Why the manager refused a request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reject {
    /// `open` at the [`ServeConfig::max_sessions`] ceiling.
    TooManySessions { open: usize, max: usize },
    /// `ingest_batch` while the session's queued write batches sit at
    /// [`ServeConfig::max_inflight_batches`]. Retry after the fleet
    /// drains; nothing from the rejected batch was ingested.
    Backpressure { queued: usize, max: usize },
    /// Unknown (or already closed) session id.
    UnknownSession(u64),
    /// `open` shed under fleet overload (degradation tier
    /// [`DegradeTier::Shed`] — see [`SupervisorConfig::shed_pressure`]).
    Overloaded {
        /// The fleet [`pressure`] reading that tripped the shed tier.
        pressure: u64,
    },
    /// The session is quarantined after a job panic; ingest/snapshot/
    /// drain refuse until a successful
    /// [`SessionManager::restore_in_place`]. (`close` still works — a
    /// faulted session never wedges its teardown.)
    Quarantined {
        /// The quarantined session's raw id.
        id: u64,
        /// Faults filed on its board so far.
        faults: u64,
    },
}

impl Reject {
    /// Stable wire code for this rejection, carried verbatim in the net
    /// front door's NACK frames (`serve::net`). These values are part of
    /// the wire protocol: never renumber, only append. Codes ≥ 10 are
    /// reserved for net-layer (framing/deadline) rejections — see
    /// `serve::net::frame::code`.
    pub fn code(&self) -> u16 {
        match self {
            Reject::TooManySessions { .. } => 1,
            Reject::Backpressure { .. } => 2,
            Reject::UnknownSession(_) => 3,
            Reject::Overloaded { .. } => 4,
            Reject::Quarantined { .. } => 5,
        }
    }
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::TooManySessions { open, max } => {
                write!(f, "session limit reached: {open} open of fleet cap {max}")
            }
            Reject::Backpressure { queued, max } => {
                write!(
                    f,
                    "backpressure: {queued} of {max} allowed write batches in flight; \
                     retry after the fleet drains"
                )
            }
            Reject::UnknownSession(id) => write!(f, "unknown session s{id}"),
            Reject::Overloaded { pressure } => {
                write!(f, "overloaded: fleet pressure {pressure} at the shed tier; retry later")
            }
            Reject::Quarantined { id, faults } => {
                write!(
                    f,
                    "session s{id} quarantined after {faults} fault(s); \
                     restore from a checkpoint to resume"
                )
            }
        }
    }
}

impl std::error::Error for Reject {}

/// Why a checkpoint restore failed: either the manager refused the
/// request (unknown session, admission) or the blob itself did
/// (corruption, version, config mismatch) — the blob errors are typed so
/// corruption is always *detected*, never applied.
#[derive(Clone, Debug, PartialEq)]
pub enum RestoreError {
    /// Admission-side refusal.
    Reject(Reject),
    /// Blob-side refusal.
    Checkpoint(CheckpointError),
}

impl std::fmt::Display for RestoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RestoreError::Reject(r) => write!(f, "restore refused: {r}"),
            RestoreError::Checkpoint(e) => write!(f, "restore refused: {e}"),
        }
    }
}

impl std::error::Error for RestoreError {}

impl From<Reject> for RestoreError {
    fn from(r: Reject) -> Self {
        RestoreError::Reject(r)
    }
}

impl From<CheckpointError> for RestoreError {
    fn from(e: CheckpointError) -> Self {
        RestoreError::Checkpoint(e)
    }
}

/// Fleet configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Fixed worker-thread count shared by every session (≥ 1).
    pub workers: usize,
    /// Admission ceiling on concurrently open sessions.
    pub max_sessions: usize,
    /// Per-session bound on queued write batches — the backpressure
    /// knob: `ingest_batch` rejects instead of buffering past it.
    pub max_inflight_batches: usize,
    /// Supervision policy: worker respawn budget, snapshot soft
    /// deadline, degradation-tier pressure thresholds. The default
    /// never degrades and never misses its (5 s) deadline in practice,
    /// so existing deployments are unaffected unless they opt in.
    pub supervisor: SupervisorConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: crate::util::parallel::available_threads(),
            max_sessions: 64,
            max_inflight_batches: 64,
            supervisor: SupervisorConfig::default(),
        }
    }
}

/// Per-session configuration: the stream's geometry and end time plus
/// the exact pipeline shape a standalone run would use.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Display label for fleet summaries.
    pub name: String,
    pub res: Resolution,
    /// Stream end time: window frames are emitted for every boundary
    /// ≤ `t_end_us`, exactly as `pipeline::run(events, res, t_end_us, …)`.
    pub t_end_us: u64,
    /// Window period, STCF stage, band counts, batch size and ISC
    /// config — the same struct `pipeline::run` takes. Results are
    /// interpreted identically; two knobs are moot for queueing only:
    /// `router.queue_depth` (serve bounds queues per session via
    /// [`ServeConfig::max_inflight_batches`]) and `router.batch_size`
    /// (serve ships one write job per touched band per staged flush —
    /// message boundaries never change band state, so frames are
    /// unaffected).
    pub pipeline: PipelineConfig,
}

/// The inline STCF stage (`denoise_shards: 0`): scored on the calling
/// thread, mirroring the pipeline's inline path decision-for-decision.
struct InlineStage {
    backend: StcfBackend,
    prm: StcfParams,
    tally: ShardTally,
}

/// Router-side cached band state (the dirty-band snapshot protocol,
/// mirroring `coordinator::router::BandCache`).
struct BandCache {
    buf: Option<Grid<f64>>,
    at_us: u64,
    valid: bool,
    /// The cached band is all-zero and stays all-zero at any later query
    /// time absent new writes (see the router's dirty-band docs).
    empty_static: bool,
}

/// One open session's state (producer-side; band state lives on the
/// fleet's actors).
struct Session {
    id: SessionId,
    cfg: SessionConfig,
    write_actors: Vec<Arc<BandActor>>,
    /// Sharded STCF bands (empty when the STCF is off or inline).
    score_actors: Vec<Arc<BandActor>>,
    inline: Option<InlineStage>,
    band_h: usize,
    score_band_h: usize,
    score_radius: usize,
    caches: Vec<BandCache>,
    band_dirty: Vec<bool>,
    inflight: Arc<AtomicUsize>,
    /// Resident bytes of the session's band states, maintained by the
    /// fleet's workers as jobs complete (materialization, growth,
    /// demotion, close — see `scheduler::sync_resident`).
    resident: Arc<AtomicUsize>,
    /// Quarantine board the fleet workers file caught panics on.
    faults: Arc<FaultBoard>,
    /// Chaos-injection plan armed at open (None in production).
    armed: Option<Arc<ArmedFault>>,
    /// Fleet supervision counters (shared with the manager and workers).
    counters: Arc<SupervisorCounters>,
    /// Soft snapshot deadline (µs), from the supervisor config.
    deadline_us: u64,
    /// Per-session observability: stage histograms, flight recorder —
    /// double-recording into the manager's [`FleetObs`]. Shared with the
    /// session's band actors, which tap every job at execute time.
    obs: Arc<SessionObs>,
    // Streaming state (the pipeline's producer loop, verbatim).
    pre: Vec<LabeledEvent>,
    kept: Vec<LabeledEvent>,
    scores: Vec<u32>,
    score_staging: Vec<Vec<ScoreItem>>,
    route_staging: Vec<Vec<Event>>,
    next_frame: u64,
    /// Clock-policy watermark: the highest event time ingested so far.
    last_t: u64,
    // Counters.
    events_in: u64,
    events_routed: u64,
    dropped: u64,
    /// Events arriving with `t` below the session watermark (clamped or
    /// rejected per [`ClockPolicy`]).
    nonmonotonic: u64,
    peak_batch_len: usize,
    batches_shipped: u64,
    snapshots_served: u64,
    bands_skipped_unchanged: u64,
    frames_emitted: u64,
    rejected_batches: u64,
    peak_queue_depth: usize,
    /// Ring of per-`ingest_batch` wall latencies (bounded so long-lived
    /// sessions don't grow without limit).
    batch_latency_s: Vec<f64>,
    latency_cursor: usize,
    stage_wall: StageWall,
    opened: Instant,
}

/// Latency samples kept per session (ring buffer).
const LATENCY_SAMPLES: usize = 16_384;

impl Session {
    /// The pipeline producer loop body for one event (clock policy,
    /// staging + window clock), emitting window frames into `frames`.
    fn push(&mut self, pool: &WorkerPool, le: LabeledEvent, frames: &mut Vec<(u64, Grid<f64>)>) {
        debug_assert!(
            self.cfg.res.contains(le.ev.x, le.ev.y),
            "off-sensor event {:?} for {}x{} session",
            le.ev,
            self.cfg.res.width,
            self.cfg.res.height
        );
        let mut le = le;
        if le.ev.t < self.last_t {
            // Backwards clock (duplicate timestamps pass: `<`, not `<=`).
            self.nonmonotonic += 1;
            match self.cfg.pipeline.clock_policy {
                ClockPolicy::Clamp => le.ev.t = self.last_t,
                // Rejected before `events_in` so accounting still
                // balances: events_in == written + dropped-by-STCF.
                ClockPolicy::Reject => return,
            }
        }
        self.last_t = le.ev.t;
        self.events_in += 1;
        let window = self.cfg.pipeline.window_us;
        while le.ev.t > self.next_frame && self.next_frame <= self.cfg.t_end_us {
            self.flush(pool);
            let at = self.next_frame;
            // Window frames are never degraded: exactness holds at every
            // overload tier.
            let (frame, _) = self.snapshot_frame(pool, at, DegradeTier::Nominal);
            self.frames_emitted += 1;
            frames.push((at, frame));
            self.next_frame += window;
        }
        self.pre.push(le);
        if self.pre.len() >= self.cfg.pipeline.batch_size.max(1) {
            self.flush(pool);
        }
    }

    /// Push the staged batch through the STCF stage (when configured)
    /// and ship the survivors to the band writers.
    fn flush(&mut self, pool: &WorkerPool) {
        self.peak_batch_len = self.peak_batch_len.max(self.pre.len());
        if self.pre.is_empty() {
            return;
        }
        if self.cfg.pipeline.stcf.is_some() {
            let t0 = Instant::now();
            self.kept.clear();
            if let Some(st) = &mut self.inline {
                for le in &self.pre {
                    let s = support_count(&st.backend, &le.ev, &st.prm);
                    st.backend.ingest(&le.ev, &st.prm);
                    st.tally.scored += 1;
                    if s >= st.prm.threshold {
                        st.tally.kept += 1;
                        self.kept.push(*le);
                    } else {
                        st.tally.dropped += 1;
                    }
                }
            } else {
                self.score_sharded(pool);
            }
            self.stage_wall.denoise_seconds += t0.elapsed().as_secs_f64();
            self.dropped += (self.pre.len() - self.kept.len()) as u64;
            let t0 = Instant::now();
            self.route(pool, true);
            self.stage_wall.route_seconds += t0.elapsed().as_secs_f64();
        } else {
            let t0 = Instant::now();
            self.route(pool, false);
            self.stage_wall.route_seconds += t0.elapsed().as_secs_f64();
        }
        self.pre.clear();
    }

    /// Fan `pre` out to the scorer bands (identical item construction
    /// to `StcfShardPool::score_batch`), wait for the per-band replies,
    /// and fill `kept` threshold-gated in input order.
    fn score_sharded(&mut self, pool: &WorkerPool) {
        let n = self.score_actors.len();
        stage_items(
            self.cfg.res,
            self.score_band_h,
            n,
            self.score_radius,
            &self.pre,
            &mut self.score_staging,
        );
        let (tx, rx) = bounded::<ScoreDone>(n);
        let mut in_flight = 0usize;
        for b in 0..n {
            if self.score_staging[b].is_empty() {
                continue;
            }
            let items = std::mem::take(&mut self.score_staging[b]);
            pool.enqueue(&self.score_actors[b], Job::Score { items, reply: tx.clone() });
            in_flight += 1;
        }
        drop(tx);
        self.scores.clear();
        self.scores.resize(self.pre.len(), 0);
        for done in rx.iter().take(in_flight) {
            for (idx, s) in done.scores {
                self.scores[idx as usize] = s;
            }
        }
        let threshold = self.cfg.pipeline.stcf.expect("sharded scoring needs stcf").threshold;
        for (le, &s) in self.pre.iter().zip(&self.scores) {
            if s >= threshold {
                self.kept.push(*le);
            }
        }
    }

    /// Ship `kept` (or raw `pre`) to the band writers: one write job per
    /// touched band, coalesced over consecutive same-band runs exactly
    /// like `Router::route_batch` staging.
    fn route(&mut self, pool: &WorkerPool, from_kept: bool) {
        let events: &[LabeledEvent] = if from_kept { &self.kept } else { &self.pre };
        let band_h = self.band_h;
        let n = self.write_actors.len();
        let mut i = 0usize;
        while i < events.len() {
            let s = (events[i].ev.y as usize / band_h).min(n - 1);
            let mut j = i + 1;
            while j < events.len() && (events[j].ev.y as usize / band_h).min(n - 1) == s {
                j += 1;
            }
            self.route_staging[s].extend(events[i..j].iter().map(|le| le.ev));
            i = j;
        }
        for s in 0..n {
            if self.route_staging[s].is_empty() {
                continue;
            }
            let batch = std::mem::take(&mut self.route_staging[s]);
            self.events_routed += batch.len() as u64;
            // The in-flight gauge bumps before the job is visible to any
            // worker, so admission control never undercounts.
            self.inflight.fetch_add(1, Ordering::SeqCst);
            pool.enqueue(&self.write_actors[s], Job::Write(batch));
            self.batches_shipped += 1;
            self.band_dirty[s] = true;
        }
        self.peak_queue_depth = self.peak_queue_depth.max(self.inflight.load(Ordering::SeqCst));
    }

    /// Scatter-gather one frame at `at_us` — `Router::frame_into`'s
    /// dirty-band protocol over the fleet: provably-clean bands
    /// composite from the session cache with no job at all, the rest
    /// snapshot behind their pending writes in band-FIFO order.
    ///
    /// `tier` applies the overload degradation ladder (on-demand
    /// snapshots only; window frames always pass `Nominal`): at
    /// [`DegradeTier::DeferCold`]+ provably event-free cold bands are
    /// served as zero fill without a job (lossless); at
    /// [`DegradeTier::ServeStale`]+ dirty bands with a previous render
    /// serve that cache unrendered and the returned `stale` flag is set.
    fn snapshot_frame(
        &mut self,
        pool: &WorkerPool,
        at_us: u64,
        tier: DegradeTier,
    ) -> (Grid<f64>, bool) {
        let t0 = Instant::now();
        self.snapshots_served += 1;
        let w = self.cfg.res.width as usize;
        let mut out = Grid::new(w, self.cfg.res.height as usize, 0.0f64);
        let n = self.write_actors.len();
        let (tx, rx) = bounded::<SnapDone>(n);
        let mut in_flight = 0usize;
        let mut stale = false;
        for s in 0..n {
            let cache = &mut self.caches[s];
            let skip = cache.valid
                && !self.band_dirty[s]
                && (cache.at_us == at_us || (cache.empty_static && at_us >= cache.at_us));
            if skip {
                cache.at_us = at_us;
                self.bands_skipped_unchanged += 1;
                continue;
            }
            if tier >= DegradeTier::DeferCold && !cache.valid && !self.band_dirty[s] {
                // Never materialized and no writes in flight: the band
                // is provably event-free, so its render is all zeros —
                // exactly the composite base. Deferring it is lossless.
                self.counters.deferred_cold_snapshots.inc();
                continue;
            }
            if tier >= DegradeTier::ServeStale && cache.valid && self.band_dirty[s] {
                // Serve the last render instead of queueing behind the
                // pending writes; the frame is marked stale. The band
                // stays dirty so a later (or Nominal) snapshot renders.
                stale = true;
                continue;
            }
            let buf = cache.buf.take().expect("band buffer in flight");
            let job = Job::Snapshot {
                at_us,
                buf,
                cache_valid: cache.valid,
                band: s,
                enqueued: Instant::now(),
                deadline_us: self.deadline_us,
                reply: tx.clone(),
            };
            pool.enqueue(&self.write_actors[s], job);
            in_flight += 1;
        }
        drop(tx);
        for r in rx.iter().take(in_flight) {
            if !r.rendered {
                self.bands_skipped_unchanged += 1;
            }
            let cache = &mut self.caches[r.band];
            cache.buf = Some(r.buf);
            cache.at_us = at_us;
            cache.valid = true;
            cache.empty_static = r.empty_static;
            self.band_dirty[r.band] = false;
        }
        let tc = Instant::now();
        let slice = out.as_mut_slice();
        for (s, cache) in self.caches.iter().enumerate() {
            let band = cache.buf.as_ref().expect("band buffer returned");
            let y0 = s * self.band_h;
            slice[y0 * w..y0 * w + band.len()].copy_from_slice(band.as_slice());
        }
        self.obs.record_composite(elapsed_us(tc));
        if stale {
            self.counters.stale_frames_served.inc();
        }
        self.stage_wall.snapshot_seconds += t0.elapsed().as_secs_f64();
        (out, stale)
    }

    /// The session counter block a checkpoint carries. Order is this
    /// module's contract with itself ([`Session::apply_counters`] is the
    /// inverse); unknown trailing entries are ignored on restore so the
    /// block can grow compatibly.
    fn counter_block(&self) -> Vec<u64> {
        vec![
            self.events_in,
            self.events_routed,
            self.dropped,
            self.frames_emitted,
            self.batches_shipped,
            self.snapshots_served,
            self.bands_skipped_unchanged,
            self.peak_batch_len as u64,
            self.rejected_batches,
            self.last_t,
            self.nonmonotonic,
        ]
    }

    /// Inverse of [`Session::counter_block`]; missing entries restore 0.
    fn apply_counters(&mut self, counters: &[u64]) {
        let g = |i: usize| counters.get(i).copied().unwrap_or(0);
        self.events_in = g(0);
        self.events_routed = g(1);
        self.dropped = g(2);
        self.frames_emitted = g(3);
        self.batches_shipped = g(4);
        self.snapshots_served = g(5);
        self.bands_skipped_unchanged = g(6);
        self.peak_batch_len = g(7) as usize;
        self.rejected_batches = g(8);
        self.last_t = g(9);
        self.nonmonotonic = g(10);
    }

    fn live_stats(&self) -> SessionStats {
        let (p50, p99) = latency_percentiles_us(&self.batch_latency_s);
        SessionStats {
            id: self.id.raw(),
            name: self.cfg.name.clone(),
            res: self.cfg.res,
            events_in: self.events_in,
            events_routed: self.events_routed,
            events_dropped_by_stcf: self.dropped,
            frames_emitted: self.frames_emitted,
            snapshots_served: self.snapshots_served,
            bands_skipped_unchanged: self.bands_skipped_unchanged,
            batches_shipped: self.batches_shipped,
            queue_depth: self.inflight.load(Ordering::SeqCst),
            peak_queue_depth: self.peak_queue_depth,
            rejected_batches: self.rejected_batches,
            ingest_ack_p50_us: p50,
            ingest_ack_p99_us: p99,
            batch_e2e_p50_us: self.obs.batch_e2e.percentile(50.0) as f64,
            batch_e2e_p99_us: self.obs.batch_e2e.percentile(99.0) as f64,
            resident_bytes: self.resident.load(Ordering::SeqCst),
        }
    }

    /// Refuse with [`Reject::Quarantined`] once any fault is filed.
    fn quarantine_gate(&self) -> Result<(), Reject> {
        if self.faults.is_quarantined() {
            return Err(Reject::Quarantined { id: self.id.raw(), faults: self.faults.count() });
        }
        Ok(())
    }
}

/// The multi-tenant session manager (see the module docs).
pub struct SessionManager {
    cfg: ServeConfig,
    pool: WorkerPool,
    sessions: BTreeMap<u64, Session>,
    next_id: u64,
    open_bands: Arc<AtomicUsize>,
    /// Fleet supervision counters (shared with every session and every
    /// worker slot). Registered on `obs.registry` so a scrape renders
    /// them without a snapshot round-trip.
    counters: Arc<SupervisorCounters>,
    /// Fleet observability: the metric registry plus fleet-level stage
    /// histograms every session double-records into (so the aggregates
    /// survive session close).
    obs: Arc<FleetObs>,
    /// Rejections + events of already-closed sessions (fleet totals).
    closed_rejected: u64,
    closed_events_in: u64,
}

impl SessionManager {
    /// Start a manager with a fresh fixed-size worker fleet (supervised:
    /// a dead worker respawns under the configured restart budget).
    pub fn new(cfg: ServeConfig) -> Self {
        let obs = Arc::new(FleetObs::new());
        Self {
            pool: WorkerPool::new(cfg.workers, cfg.supervisor.supervision),
            cfg,
            sessions: BTreeMap::new(),
            next_id: 0,
            open_bands: Arc::new(AtomicUsize::new(0)),
            counters: Arc::new(SupervisorCounters::registered(&obs.registry)),
            obs,
            closed_rejected: 0,
            closed_events_in: 0,
        }
    }

    /// Open a session: builds its band writers (and scorer bands when
    /// the STCF is sharded) as fleet actors. Rejects at the session
    /// ceiling, and sheds ([`Reject::Overloaded`]) when fleet pressure
    /// reaches [`SupervisorConfig::shed_pressure`].
    pub fn open(&mut self, cfg: SessionConfig) -> Result<SessionId, Reject> {
        self.open_with_fault(cfg, None)
    }

    /// [`SessionManager::open`] with a scheduler fault plan armed on the
    /// new session (chaos harness — see [`SchedFaultPlan`]). The plan
    /// fires at most once, on the session's `fire_on_job`-th job, and
    /// every firing is counted in the supervisor stats before it
    /// manifests.
    pub fn open_with_fault(
        &mut self,
        cfg: SessionConfig,
        plan: Option<SchedFaultPlan>,
    ) -> Result<SessionId, Reject> {
        if self.sessions.len() >= self.cfg.max_sessions {
            return Err(Reject::TooManySessions {
                open: self.sessions.len(),
                max: self.cfg.max_sessions,
            });
        }
        let p = pressure(self.pool.ready_depth(), self.total_resident());
        if self.cfg.supervisor.tier_for(p) >= DegradeTier::Shed {
            self.counters.sessions_shed_overloaded.inc();
            return Err(Reject::Overloaded { pressure: p });
        }
        let id = SessionId(self.next_id);
        self.next_id += 1;
        let obs = Arc::new(SessionObs::new(Arc::clone(&self.obs)));
        let inflight = Arc::new(AtomicUsize::new(0));
        let resident = Arc::new(AtomicUsize::new(0));
        let faults = Arc::new(FaultBoard::new());
        let armed = plan.map(|pl| Arc::new(ArmedFault::new(pl)));
        let height = cfg.res.height as usize;
        let (band_h, n_bands) = band_layout(height, cfg.pipeline.router.n_shards);
        let write_actors: Vec<Arc<BandActor>> = (0..n_bands)
            .map(|s| {
                // render_chunks = 1: the fleet's workers are the
                // parallelism; band renders must not spawn threads.
                let writer = BandWriter::for_band(cfg.res, &cfg.pipeline.router.isc, band_h, s, 1);
                self.pool.spawn_actor(BandSeed {
                    state: BandState::Writer(Box::new(writer)),
                    band: s as u16,
                    inflight: inflight.clone(),
                    open_bands: self.open_bands.clone(),
                    resident: resident.clone(),
                    faults: faults.clone(),
                    counters: self.counters.clone(),
                    armed: armed.clone(),
                    obs: obs.clone(),
                })
            })
            .collect();
        let sharded = cfg.pipeline.stcf.is_some() && cfg.pipeline.denoise_shards > 0;
        let (score_band_h, n_score) = if sharded {
            band_layout(height, cfg.pipeline.denoise_shards)
        } else {
            (height, 0)
        };
        let score_radius =
            cfg.pipeline.stcf.map(|prm| prm.radius as usize).unwrap_or(0);
        let score_actors: Vec<Arc<BandActor>> = (0..n_score)
            .map(|s| {
                let prm = cfg.pipeline.stcf.expect("sharded stage needs stcf");
                let backend = ShardBackend::Isc(cfg.pipeline.router.isc.clone());
                let scorer = BandScorer::for_band(cfg.res, &backend, prm, score_band_h, s);
                self.pool.spawn_actor(BandSeed {
                    state: BandState::Scorer(Box::new(scorer)),
                    // Combined band index: scorers follow the writers.
                    band: (n_bands + s) as u16,
                    inflight: inflight.clone(),
                    open_bands: self.open_bands.clone(),
                    resident: resident.clone(),
                    faults: faults.clone(),
                    counters: self.counters.clone(),
                    armed: armed.clone(),
                    obs: obs.clone(),
                })
            })
            .collect();
        let inline = match (&cfg.pipeline.stcf, sharded) {
            (Some(prm), false) => Some(InlineStage {
                backend: StcfBackend::isc(
                    cfg.res,
                    cfg.pipeline.router.isc.clone(),
                    prm.tau_tw_us,
                ),
                prm: *prm,
                tally: ShardTally::default(),
            }),
            _ => None,
        };
        let batch_size = cfg.pipeline.batch_size.max(1);
        let next_frame = cfg.pipeline.window_us;
        let session = Session {
            id,
            write_actors,
            score_actors,
            inline,
            band_h,
            score_band_h,
            score_radius,
            caches: (0..n_bands)
                .map(|_| BandCache {
                    buf: Some(Grid::new(1, 1, 0.0)),
                    at_us: 0,
                    valid: false,
                    empty_static: false,
                })
                .collect(),
            band_dirty: vec![false; n_bands],
            inflight,
            resident,
            faults,
            armed,
            counters: self.counters.clone(),
            deadline_us: self.cfg.supervisor.snapshot_deadline_us,
            obs,
            pre: Vec::with_capacity(batch_size),
            kept: Vec::with_capacity(batch_size),
            scores: Vec::new(),
            score_staging: (0..n_score).map(|_| Vec::new()).collect(),
            route_staging: (0..n_bands).map(|_| Vec::new()).collect(),
            next_frame,
            last_t: 0,
            events_in: 0,
            events_routed: 0,
            dropped: 0,
            nonmonotonic: 0,
            peak_batch_len: 0,
            batches_shipped: 0,
            snapshots_served: 0,
            bands_skipped_unchanged: 0,
            frames_emitted: 0,
            rejected_batches: 0,
            peak_queue_depth: 0,
            batch_latency_s: Vec::new(),
            latency_cursor: 0,
            stage_wall: StageWall::default(),
            opened: Instant::now(),
            cfg,
        };
        self.sessions.insert(id.raw(), session);
        Ok(id)
    }

    /// Ingest a time-sorted labeled batch, returning any window frames
    /// the stream crossed. Rejected in full (nothing ingested) while the
    /// session's queued write batches sit at the in-flight bound, or
    /// while the session is quarantined.
    pub fn ingest_batch(
        &mut self,
        sid: SessionId,
        events: &[LabeledEvent],
    ) -> Result<Vec<(u64, Grid<f64>)>, Reject> {
        let s = self.sessions.get_mut(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        if let Err(r) = s.quarantine_gate() {
            s.rejected_batches += 1;
            return Err(r);
        }
        let queued = s.inflight.load(Ordering::SeqCst);
        if queued >= self.cfg.max_inflight_batches {
            s.rejected_batches += 1;
            return Err(Reject::Backpressure { queued, max: self.cfg.max_inflight_batches });
        }
        let t0 = Instant::now();
        let mut frames = Vec::new();
        for le in events {
            s.push(&self.pool, *le, &mut frames);
        }
        let dt = t0.elapsed().as_secs_f64();
        if s.batch_latency_s.len() < LATENCY_SAMPLES {
            s.batch_latency_s.push(dt);
        } else {
            s.batch_latency_s[s.latency_cursor] = dt;
            s.latency_cursor = (s.latency_cursor + 1) % LATENCY_SAMPLES;
        }
        s.obs.record_ingest_ack((dt * 1e6) as u64);
        Ok(frames)
    }

    /// On-demand frame at `at_us` (flushes staged events first, like
    /// `Router::frame`). Must be causal — non-decreasing and ≥ the
    /// session's ingested event times — the same contract as every
    /// snapshot in the stack; causal on-demand snapshots never perturb
    /// the window frames.
    pub fn snapshot(&mut self, sid: SessionId, at_us: u64) -> Result<Grid<f64>, Reject> {
        self.snapshot_with_status(sid, at_us).map(|(frame, _)| frame)
    }

    /// [`SessionManager::snapshot`] plus the staleness flag: `true` when
    /// overload degradation ([`DegradeTier::ServeStale`]) served at
    /// least one dirty band from its last render instead of rendering.
    /// The net front door forwards the flag on the FRAME wire.
    pub fn snapshot_with_status(
        &mut self,
        sid: SessionId,
        at_us: u64,
    ) -> Result<(Grid<f64>, bool), Reject> {
        let tier = self.current_tier();
        let s = self.sessions.get_mut(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        s.quarantine_gate()?;
        s.flush(&self.pool);
        Ok(s.snapshot_frame(&self.pool, at_us, tier))
    }

    /// Flush staged events and emit every remaining window frame through
    /// `t_end_us` — the pipeline run's tail, so `ingest_batch` frames +
    /// `drain` frames together are exactly `pipeline::run`'s frame list.
    pub fn drain(&mut self, sid: SessionId) -> Result<Vec<(u64, Grid<f64>)>, Reject> {
        let s = self.sessions.get_mut(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        s.quarantine_gate()?;
        s.flush(&self.pool);
        let mut frames = Vec::new();
        while s.next_frame <= s.cfg.t_end_us {
            let at = s.next_frame;
            let (frame, _) = s.snapshot_frame(&self.pool, at, DegradeTier::Nominal);
            s.frames_emitted += 1;
            frames.push((at, frame));
            s.next_frame += s.cfg.pipeline.window_us;
        }
        Ok(frames)
    }

    /// Serialize the session's full state — band stamps, STCF backend,
    /// window clock, counters — into a compact versioned CRC-guarded
    /// blob. Staged events are flushed first (decision-identical: causal
    /// scoring means message boundaries never change band state), so the
    /// checkpoint captures every acknowledged event. The fan-out rides
    /// each band's own FIFO behind its pending writes: a consistent cut
    /// without stopping the fleet.
    pub fn checkpoint(&mut self, sid: SessionId) -> Result<Vec<u8>, Reject> {
        let s = self.sessions.get_mut(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        s.flush(&self.pool);
        let n_bands = s.write_actors.len();
        let n_actors = n_bands + s.score_actors.len();
        let (tx, rx) = bounded::<CheckpointDone>(n_actors.max(1));
        for (b, actor) in s.write_actors.iter().enumerate() {
            self.pool.enqueue(actor, Job::Checkpoint { band: b, reply: tx.clone() });
        }
        for (b, actor) in s.score_actors.iter().enumerate() {
            self.pool.enqueue(actor, Job::Checkpoint { band: n_bands + b, reply: tx.clone() });
        }
        drop(tx);
        // Quarantined (stateless) bands reply None and are omitted; the
        // restore treats a missing band as empty.
        let mut bands: Vec<BandCheckpoint> =
            rx.iter().take(n_actors).filter_map(|done| done.state).collect();
        if let Some(st) = &s.inline {
            let mut stamps = Vec::new();
            st.backend.for_each_stamp(|plane, x, y, t| stamps.push((plane, x, y, t)));
            bands.push(BandCheckpoint::Scorer {
                band: INLINE_BAND,
                tally: st.tally.clone(),
                stamps,
            });
        }
        bands.sort_by_key(BandCheckpoint::band);
        let ck = SessionCheckpoint {
            fingerprint: config_fingerprint(&s.cfg.pipeline, s.cfg.res, s.cfg.t_end_us),
            next_frame: s.next_frame,
            counters: s.counter_block(),
            bands,
        };
        let mut bytes = encode_checkpoint(&ck);
        if let Some(armed) = &s.armed {
            // Chaos hook: at most one seeded bit flip, which the restore
            // CRC guard must *detect* (tests/fleet_chaos.rs).
            armed.corrupt_checkpoint(&mut bytes, &s.counters);
        }
        self.counters.checkpoints_taken.inc();
        Ok(bytes)
    }

    /// Restore a session **in place** from a checkpoint it (or a
    /// config-identical twin) produced: rebuilds every band state from
    /// the blob's stamps, rewinds the window clock and counters to the
    /// checkpoint cut, and lifts the quarantine. After a successful
    /// restore the session renders bit-for-bit as if it had never
    /// crashed (position-stable stamp replay — see
    /// [`super::supervise`]).
    pub fn restore_in_place(&mut self, sid: SessionId, bytes: &[u8]) -> Result<(), RestoreError> {
        let ck = self.decode_guarded(bytes)?;
        let s = self
            .sessions
            .get_mut(&sid.raw())
            .ok_or(RestoreError::Reject(Reject::UnknownSession(sid.raw())))?;
        let expected = config_fingerprint(&s.cfg.pipeline, s.cfg.res, s.cfg.t_end_us);
        if ck.fingerprint != expected {
            return Err(RestoreError::Checkpoint(CheckpointError::ConfigMismatch {
                expected,
                found: ck.fingerprint,
            }));
        }
        Self::apply_checkpoint(&self.pool, s, &ck);
        s.faults.clear();
        self.counters.restores_completed.inc();
        Ok(())
    }

    /// Restore a checkpoint into a **new** session (migration): opens a
    /// session with `cfg` (which must fingerprint-match the blob) and
    /// applies the checkpointed state to it.
    pub fn restore(&mut self, cfg: SessionConfig, bytes: &[u8]) -> Result<SessionId, RestoreError> {
        let ck = self.decode_guarded(bytes)?;
        let expected = config_fingerprint(&cfg.pipeline, cfg.res, cfg.t_end_us);
        if ck.fingerprint != expected {
            return Err(RestoreError::Checkpoint(CheckpointError::ConfigMismatch {
                expected,
                found: ck.fingerprint,
            }));
        }
        let sid = self.open(cfg).map_err(RestoreError::Reject)?;
        if let Some(s) = self.sessions.get_mut(&sid.raw()) {
            Self::apply_checkpoint(&self.pool, s, &ck);
        }
        self.counters.restores_completed.inc();
        Ok(sid)
    }

    /// Decode + CRC-verify a checkpoint, counting detected corruption.
    fn decode_guarded(&self, bytes: &[u8]) -> Result<SessionCheckpoint, RestoreError> {
        decode_checkpoint(bytes).map_err(|e| {
            if e == CheckpointError::CrcMismatch {
                self.counters.checkpoint_corruptions_detected.inc();
            }
            RestoreError::Checkpoint(e)
        })
    }

    /// Rebuild every band state from the checkpoint on the caller
    /// thread, install each via its band FIFO ([`Job::Restore`] — which
    /// also revives quarantined bands), rebuild the inline STCF stage,
    /// and rewind the producer-side streaming state to the cut.
    fn apply_checkpoint(pool: &WorkerPool, s: &mut Session, ck: &SessionCheckpoint) {
        let n_bands = s.write_actors.len();
        let n_score = s.score_actors.len();
        let mut writer_ck: Vec<Option<&BandCheckpoint>> = vec![None; n_bands];
        let mut scorer_ck: Vec<Option<&BandCheckpoint>> = vec![None; n_score];
        let mut inline_ck: Option<&BandCheckpoint> = None;
        for b in &ck.bands {
            let band = b.band() as usize;
            if b.band() == INLINE_BAND {
                inline_ck = Some(b);
            } else if band < n_bands {
                writer_ck[band] = Some(b);
            } else if band < n_bands + n_score {
                scorer_ck[band - n_bands] = Some(b);
            }
        }
        let (tx, rx) = bounded::<RestoreDone>((n_bands + n_score).max(1));
        for (b, actor) in s.write_actors.iter().enumerate() {
            let mut writer =
                BandWriter::for_band(s.cfg.res, &s.cfg.pipeline.router.isc, s.band_h, b, 1);
            if let Some(BandCheckpoint::Writer { processed, stamps, .. }) = writer_ck[b] {
                writer.restore_state(*processed, stamps);
            }
            let state = Box::new(BandState::Writer(Box::new(writer)));
            pool.enqueue(actor, Job::Restore { state, band: b, reply: tx.clone() });
        }
        for (b, actor) in s.score_actors.iter().enumerate() {
            let prm = s.cfg.pipeline.stcf.expect("sharded stage needs stcf");
            let backend = ShardBackend::Isc(s.cfg.pipeline.router.isc.clone());
            let mut scorer = BandScorer::for_band(s.cfg.res, &backend, prm, s.score_band_h, b);
            if let Some(BandCheckpoint::Scorer { tally, stamps, .. }) = scorer_ck[b] {
                scorer.restore_state(tally.clone(), stamps);
            }
            let state = Box::new(BandState::Scorer(Box::new(scorer)));
            pool.enqueue(actor, Job::Restore { state, band: n_bands + b, reply: tx.clone() });
        }
        drop(tx);
        for _ in rx.iter().take(n_bands + n_score) {}
        if let Some(st) = &mut s.inline {
            let mut backend =
                StcfBackend::isc(s.cfg.res, s.cfg.pipeline.router.isc.clone(), st.prm.tau_tw_us);
            let mut tally = ShardTally::default();
            if let Some(BandCheckpoint::Scorer { tally: t, stamps, .. }) = inline_ck {
                // Replay in ascending stamp time: recency bitmask order
                // matters for bit-exactness (same law as restore_state).
                let mut ordered = stamps.clone();
                ordered.sort_unstable_by_key(|&(_, _, _, t)| t);
                for (plane, x, y, tt) in ordered {
                    backend.restore_stamp(plane, x, y, tt);
                }
                tally = t.clone();
            }
            st.backend = backend;
            st.tally = tally;
        }
        // Rewind the producer-side streaming state to the cut: staged
        // events after the checkpoint are discarded (the caller re-sends
        // from its own journal), caches invalidate (buffers kept for
        // reuse), and every band renders fully on the next frame.
        s.pre.clear();
        s.kept.clear();
        s.scores.clear();
        for v in &mut s.score_staging {
            v.clear();
        }
        for v in &mut s.route_staging {
            v.clear();
        }
        s.next_frame = ck.next_frame;
        s.apply_counters(&ck.counters);
        for cache in &mut s.caches {
            cache.valid = false;
            cache.empty_static = false;
            cache.at_us = 0;
        }
        for d in &mut s.band_dirty {
            *d = true;
        }
    }

    /// The faults filed on a session's quarantine board (empty while
    /// healthy).
    pub fn session_faults(&self, sid: SessionId) -> Result<Vec<SessionFault>, Reject> {
        let s = self.sessions.get(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        Ok(s.faults.faults())
    }

    /// Close a session: flushes its staged events, waits for its queued
    /// jobs, frees its bands on the fleet, and returns the final
    /// accounting (a full `PipelineStats` among it). Every event an
    /// `ingest_batch` call acknowledged is written before the final
    /// per-band counts are read: the flush ships staged events as write
    /// jobs and the `Close` jobs queue *behind* them on each band's FIFO
    /// mailbox, so in-flight writes are never silently discarded. (The
    /// remaining window frames through `t_end_us` are still only emitted
    /// by `drain` — call it first when the caller wants the frame tail.)
    /// Quarantined sessions close too — teardown never wedges — though
    /// their accounting reflects whatever bands survived the fault.
    pub fn close(&mut self, sid: SessionId) -> Result<SessionReport, Reject> {
        let mut s =
            self.sessions.remove(&sid.raw()).ok_or(Reject::UnknownSession(sid.raw()))?;
        s.flush(&self.pool);
        let n_actors = s.write_actors.len() + s.score_actors.len();
        let (tx, rx) = bounded::<CloseDone>(n_actors);
        for (b, actor) in s.write_actors.iter().enumerate() {
            self.pool.enqueue(actor, Job::Close { band: b, reply: tx.clone() });
        }
        for (b, actor) in s.score_actors.iter().enumerate() {
            let band = s.write_actors.len() + b;
            self.pool.enqueue(actor, Job::Close { band, reply: tx.clone() });
        }
        drop(tx);
        let mut per_shard = vec![0u64; s.write_actors.len()];
        let mut tallies: Vec<(usize, ShardTally)> = Vec::new();
        for done in rx.iter().take(n_actors) {
            if let Some(t) = done.tally {
                tallies.push((done.band, t));
            } else if done.band < per_shard.len() {
                per_shard[done.band] = done.written;
            }
        }
        tallies.sort_by_key(|(b, _)| *b);
        let denoise = match (&s.cfg.pipeline.stcf, s.inline.take()) {
            (Some(_), Some(st)) => {
                Some(DenoiseStats { inline_scoring: true, per_shard: vec![st.tally] })
            }
            (Some(_), None) => Some(DenoiseStats {
                inline_scoring: false,
                per_shard: tallies.into_iter().map(|(_, t)| t).collect(),
            }),
            _ => None,
        };
        let wall = s.opened.elapsed().as_secs_f64();
        let stats = s.live_stats();
        let pipeline = PipelineStats {
            events_in: s.events_in,
            events_written: per_shard.iter().sum(),
            events_dropped_by_stcf: s.dropped,
            events_nonmonotonic: s.nonmonotonic,
            frames_emitted: s.frames_emitted,
            peak_batch_len: s.peak_batch_len,
            wall_seconds: wall,
            stage_wall: s.stage_wall.clone(),
            denoise,
            router: RouterStats {
                events_routed: s.events_routed,
                per_shard,
                batches_shipped: s.batches_shipped,
                snapshots_served: s.snapshots_served,
                bands_skipped_unchanged: s.bands_skipped_unchanged,
            },
            events_per_second: if wall > 0.0 { s.events_in as f64 / wall } else { 0.0 },
        };
        self.closed_rejected += s.rejected_batches;
        self.closed_events_in += s.events_in;
        Ok(SessionReport { stats, pipeline })
    }

    /// Live band states on the fleet (drops to 0 once every session is
    /// closed — "close frees its bands").
    pub fn open_bands(&self) -> usize {
        self.open_bands.load(Ordering::SeqCst)
    }

    /// Open session count.
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Approximate resident bytes across every open session.
    fn total_resident(&self) -> usize {
        self.sessions.values().map(|s| s.resident.load(Ordering::SeqCst)).sum()
    }

    /// The fleet's active degradation tier right now (pressure = ready
    /// queue depth × resident footprint, mapped through the supervisor
    /// thresholds).
    pub fn current_tier(&self) -> DegradeTier {
        self.cfg.supervisor.tier_for(pressure(self.pool.ready_depth(), self.total_resident()))
    }

    /// Pause the worker fleet until the guard drops (maintenance drains,
    /// deterministic backpressure tests). While held, write jobs queue
    /// but nothing executes — so `snapshot`/`drain`/`close` and sharded
    /// scoring, which wait on job replies, will block until release.
    pub fn hold_workers(&self) -> HoldGuard {
        self.pool.hold()
    }

    /// Fleet-wide statistics snapshot. `net` is zeroed here — the fleet
    /// doesn't know about sockets; `serve::net::NetServer::stats` fills
    /// it for wire-driven fleets.
    pub fn stats(&self) -> ServeStats {
        let sessions: Vec<SessionStats> =
            self.sessions.values().map(Session::live_stats).collect();
        ServeStats {
            net: Default::default(),
            supervisor: self.counters.snapshot(
                self.pool.jobs_panicked(),
                self.pool.worker_respawns(),
                self.pool.degraded(),
            ),
            workers: self.pool.workers(),
            open_sessions: sessions.len(),
            open_bands: self.open_bands(),
            jobs_executed: self.pool.jobs_executed(),
            ready_depth: self.pool.ready_depth(),
            rejected_batches: self.closed_rejected
                + sessions.iter().map(|s| s.rejected_batches).sum::<u64>(),
            events_in: self.closed_events_in
                + sessions.iter().map(|s| s.events_in).sum::<u64>(),
            resident_bytes: sessions.iter().map(|s| s.resident_bytes).sum(),
            sessions,
        }
    }

    /// The fleet observability handle: metric registry + fleet-level
    /// stage histograms (see [`FleetObs`]). Callers that own long-lived
    /// references (metrics servers, JSON snapshot writers) clone the
    /// `Arc`.
    pub fn obs(&self) -> &Arc<FleetObs> {
        &self.obs
    }

    /// One Prometheus-style text scrape of everything the fleet knows:
    /// every registered counter (supervisor + any net front door
    /// registered on this fleet's registry), the fleet gauges and stage
    /// histograms, and per-session labeled counters + histograms. This
    /// is the body both the `STATS` wire reply and the `--metrics` HTTP
    /// endpoint serve.
    pub fn metrics_text(&self) -> String {
        let tier = match self.current_tier() {
            DegradeTier::Nominal => 0u8,
            DegradeTier::DeferCold => 1,
            DegradeTier::ServeStale => 2,
            DegradeTier::Shed => 3,
        };
        let pairs: Vec<(String, Arc<SessionObs>)> = self
            .sessions
            .values()
            .map(|s| (s.cfg.name.clone(), s.obs.clone()))
            .collect();
        render_fleet_text(&self.obs, &self.stats(), tier, &pairs)
    }

    /// Close every remaining session and stop the worker fleet,
    /// returning the final fleet statistics.
    pub fn shutdown(mut self) -> ServeStats {
        let ids: Vec<u64> = self.sessions.keys().copied().collect();
        for id in ids {
            let _ = self.close(SessionId(id));
        }
        let stats = self.stats();
        self.pool.shutdown();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;
    use crate::serve::supervise::SchedFaultKind;

    fn stream(n: u64, res: Resolution) -> Vec<LabeledEvent> {
        (0..n)
            .map(|k| LabeledEvent {
                ev: Event::new(
                    1 + k * 1_000,
                    (k % res.width as u64) as u16,
                    (k % res.height as u64) as u16,
                    Polarity::On,
                ),
                is_signal: true,
            })
            .collect()
    }

    fn session_cfg(res: Resolution, t_end_us: u64) -> SessionConfig {
        SessionConfig {
            name: "test".into(),
            res,
            t_end_us,
            pipeline: PipelineConfig::default(),
        }
    }

    fn frames_eq(a: &[(u64, Grid<f64>)], b: &[(u64, Grid<f64>)]) -> bool {
        a.len() == b.len()
            && a.iter()
                .zip(b)
                .all(|((ta, ga), (tb, gb))| ta == tb && ga.as_slice() == gb.as_slice())
    }

    #[test]
    fn open_ingest_drain_close_lifecycle() {
        let mut m = SessionManager::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let res = Resolution::new(16, 16);
        let sid = m.open(session_cfg(res, 100_000)).unwrap();
        assert_eq!(m.session_count(), 1);
        assert!(m.open_bands() > 0);
        let evs = stream(100, res); // covers 0..100 ms, 50 ms windows
        let mut frames = m.ingest_batch(sid, &evs).unwrap();
        frames.extend(m.drain(sid).unwrap());
        assert_eq!(frames.len(), 2);
        let report = m.close(sid).unwrap();
        assert_eq!(report.pipeline.events_in, 100);
        assert_eq!(report.pipeline.events_written, 100);
        assert_eq!(report.pipeline.frames_emitted, 2);
        assert_eq!(m.open_bands(), 0, "close must free every band");
        assert_eq!(m.session_count(), 0);
        assert!(matches!(m.ingest_batch(sid, &evs), Err(Reject::UnknownSession(_))));
        m.shutdown();
    }

    #[test]
    fn session_ceiling_rejects_with_reason() {
        let mut m = SessionManager::new(ServeConfig {
            workers: 1,
            max_sessions: 2,
            ..ServeConfig::default()
        });
        let res = Resolution::new(8, 8);
        m.open(session_cfg(res, 10_000)).unwrap();
        m.open(session_cfg(res, 10_000)).unwrap();
        match m.open(session_cfg(res, 10_000)) {
            Err(Reject::TooManySessions { open: 2, max: 2 }) => {}
            other => panic!("expected session-ceiling reject, got {other:?}"),
        }
        m.shutdown();
    }

    #[test]
    fn held_fleet_builds_bounded_queue_then_rejects() {
        let mut m = SessionManager::new(ServeConfig {
            workers: 2,
            max_sessions: 4,
            max_inflight_batches: 3,
            ..ServeConfig::default()
        });
        let res = Resolution::new(8, 8);
        let mut cfg = session_cfg(res, 10_000_000);
        cfg.pipeline.batch_size = 4; // every call flushes
        cfg.pipeline.window_us = 100_000_000; // no window crossing
        let sid = m.open(cfg).unwrap();
        let hold = m.hold_workers();
        let evs = stream(4, res);
        let mut rejected = 0u64;
        for _ in 0..20 {
            match m.ingest_batch(sid, &evs) {
                Ok(_) => {}
                Err(Reject::Backpressure { queued, max }) => {
                    assert_eq!(max, 3);
                    assert!(queued >= 3);
                    rejected += 1;
                }
                Err(other) => panic!("unexpected reject {other:?}"),
            }
        }
        assert!(rejected > 0, "a held fleet must reject past the in-flight bound");
        let st = m.stats();
        assert_eq!(st.rejected_batches, rejected);
        // Queue stayed bounded: at most the admission bound plus one
        // call's own flush (≤ touched bands) ever sat in flight.
        assert!(
            st.sessions[0].peak_queue_depth
                <= 3 + st.sessions[0].batches_shipped as usize,
        );
        drop(hold);
        // Released fleet drains and the session closes cleanly.
        let report = m.close(sid).unwrap();
        assert_eq!(report.stats.rejected_batches, rejected);
        assert_eq!(report.pipeline.events_in, report.pipeline.events_written);
        m.shutdown();
    }

    #[test]
    fn reject_is_a_coded_error_with_numbered_reasons() {
        let cases = [
            (Reject::TooManySessions { open: 7, max: 8 }, 1u16, ["7", "8"]),
            (Reject::Backpressure { queued: 5, max: 6 }, 2, ["5", "6"]),
            (Reject::UnknownSession(42), 3, ["42", "s42"]),
            (Reject::Overloaded { pressure: 97 }, 4, ["97", "overloaded"]),
            (Reject::Quarantined { id: 9, faults: 2 }, 5, ["s9", "2 fault"]),
        ];
        for (reject, code, needles) in cases {
            assert_eq!(reject.code(), code);
            let msg = reject.to_string();
            for n in needles {
                assert!(msg.contains(n), "Display {msg:?} must carry {n:?}");
            }
            // Usable as a boxed error (satellite: impl std::error::Error).
            let boxed: Box<dyn std::error::Error> = Box::new(reject);
            assert_eq!(boxed.to_string(), msg);
        }
    }

    #[test]
    fn close_flushes_staged_and_queued_batches() {
        // Regression: a session closed with events still staged in the
        // producer batcher AND write jobs still queued on the fleet must
        // account every acked event as written, not silently drop them.
        let mut m = SessionManager::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let res = Resolution::new(8, 8);
        let mut cfg = session_cfg(res, 10_000_000);
        cfg.pipeline.batch_size = 7; // 64 events: 9 flushed jobs + 1 staged
        cfg.pipeline.window_us = 100_000_000; // no window crossing
        let sid = m.open(cfg).unwrap();
        m.ingest_batch(sid, &stream(64, res)).unwrap();
        let report = m.close(sid).unwrap();
        assert_eq!(report.pipeline.events_in, 64);
        assert_eq!(report.pipeline.events_written, 64, "close must flush the staged tail");
        m.shutdown();
    }

    #[test]
    fn many_sessions_share_a_small_fixed_fleet() {
        // 6 sessions on 2 workers: everything completes, the fleet
        // reports 2 workers regardless of session count, and each
        // session's frames land independently.
        let mut m = SessionManager::new(ServeConfig {
            workers: 2,
            max_sessions: 8,
            ..ServeConfig::default()
        });
        let resolutions = [Resolution::new(16, 16), Resolution::new(8, 12)];
        let mut sids = Vec::new();
        for k in 0..6usize {
            let res = resolutions[k % 2];
            sids.push((m.open(session_cfg(res, 100_000)).unwrap(), res));
        }
        assert_eq!(m.stats().workers, 2);
        let mut emitted = vec![0usize; sids.len()];
        for (k, (sid, res)) in sids.iter().enumerate() {
            emitted[k] += m.ingest_batch(*sid, &stream(60, *res)).unwrap().len();
        }
        for (k, (sid, _)) in sids.iter().enumerate() {
            emitted[k] += m.drain(*sid).unwrap().len();
            assert_eq!(emitted[k], 2, "50 ms windows over 100 ms, session {k}");
        }
        let st = m.stats();
        assert_eq!(st.open_sessions, 6);
        assert!(st.jobs_executed > 0);
        let final_stats = m.shutdown();
        assert_eq!(final_stats.open_sessions, 0);
        assert_eq!(final_stats.open_bands, 0);
    }

    #[test]
    fn restore_in_place_resumes_bit_for_bit() {
        // Prefix → checkpoint → suffix (discarded) → restore → suffix
        // again: the replayed run's frames must equal a never-interrupted
        // reference, bit for bit, across no-STCF, inline-STCF and
        // sharded-STCF session shapes.
        let res = Resolution::new(16, 16);
        let shapes: [(Option<StcfParams>, usize); 3] = [
            (None, 4),
            (Some(StcfParams::default()), 0),
            (Some(StcfParams::default()), 2),
        ];
        for (stcf, shards) in shapes {
            let mut m =
                SessionManager::new(ServeConfig { workers: 2, ..ServeConfig::default() });
            let mut cfg = session_cfg(res, 100_000);
            cfg.pipeline.stcf = stcf;
            cfg.pipeline.denoise_shards = shards;
            cfg.pipeline.batch_size = 16;
            let evs = stream(100, res);
            let (head, tail) = evs.split_at(60);

            let sid_ref = m.open(cfg.clone()).unwrap();
            let mut want = m.ingest_batch(sid_ref, &evs).unwrap();
            want.extend(m.drain(sid_ref).unwrap());
            let want_report = m.close(sid_ref).unwrap();

            let sid = m.open(cfg).unwrap();
            let mut got = m.ingest_batch(sid, head).unwrap();
            let blob = m.checkpoint(sid).unwrap();
            // First pass past the cut, then rewind and replay it.
            let _ = m.ingest_batch(sid, tail).unwrap();
            m.restore_in_place(sid, &blob).unwrap();
            got.extend(m.ingest_batch(sid, tail).unwrap());
            got.extend(m.drain(sid).unwrap());
            assert!(
                frames_eq(&want, &got),
                "restored frames diverged (stcf={stcf:?}, shards={shards})"
            );
            let report = m.close(sid).unwrap();
            assert_eq!(report.pipeline.events_in, want_report.pipeline.events_in);
            assert_eq!(report.pipeline.events_written, want_report.pipeline.events_written);
            assert_eq!(
                report.pipeline.events_dropped_by_stcf,
                want_report.pipeline.events_dropped_by_stcf
            );
            let st = m.shutdown();
            assert_eq!(st.supervisor.checkpoints_taken, 1);
            assert_eq!(st.supervisor.restores_completed, 1);
        }
    }

    #[test]
    fn restore_migrates_into_a_new_session() {
        let res = Resolution::new(16, 16);
        let mut m = SessionManager::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let cfg = session_cfg(res, 100_000);
        let evs = stream(100, res);
        let (head, tail) = evs.split_at(50);

        let sid_ref = m.open(cfg.clone()).unwrap();
        let mut want = m.ingest_batch(sid_ref, &evs).unwrap();
        want.extend(m.drain(sid_ref).unwrap());
        m.close(sid_ref).unwrap();

        let sid_a = m.open(cfg.clone()).unwrap();
        let mut got = m.ingest_batch(sid_a, head).unwrap();
        let blob = m.checkpoint(sid_a).unwrap();
        m.close(sid_a).unwrap();

        let sid_b = m.restore(cfg.clone(), &blob).unwrap();
        assert_ne!(sid_a, sid_b);
        got.extend(m.ingest_batch(sid_b, tail).unwrap());
        got.extend(m.drain(sid_b).unwrap());
        assert!(frames_eq(&want, &got), "migrated session diverged");

        // Config mismatch is a typed refusal, not a silent misrestore.
        let mut other = cfg;
        other.pipeline.window_us += 1;
        match m.restore(other, &blob) {
            Err(RestoreError::Checkpoint(CheckpointError::ConfigMismatch { .. })) => {}
            r => panic!("expected ConfigMismatch, got {r:?}"),
        }
        m.shutdown();
    }

    #[test]
    fn corrupt_checkpoint_is_detected_and_counted() {
        let res = Resolution::new(8, 8);
        let mut m = SessionManager::new(ServeConfig { workers: 1, ..ServeConfig::default() });
        let sid = m.open(session_cfg(res, 10_000_000)).unwrap();
        m.ingest_batch(sid, &stream(30, res)).unwrap();
        let mut blob = m.checkpoint(sid).unwrap();
        blob[10] ^= 0x40;
        match m.restore_in_place(sid, &blob) {
            Err(RestoreError::Checkpoint(CheckpointError::CrcMismatch)) => {}
            r => panic!("expected CrcMismatch, got {r:?}"),
        }
        let st = m.shutdown();
        assert_eq!(st.supervisor.checkpoint_corruptions_detected, 1);
        assert_eq!(st.supervisor.restores_completed, 0);
    }

    #[test]
    fn injected_panic_quarantines_session_and_restore_lifts_it() {
        let res = Resolution::new(8, 8);
        let mut m = SessionManager::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let mut cfg = session_cfg(res, 10_000_000);
        cfg.pipeline.window_us = 100_000_000; // no window crossing
        let plan = SchedFaultPlan {
            kind: SchedFaultKind::JobPanic,
            fire_on_job: 1,
            stall_ms: 0,
            corrupt_salt: 0,
        };
        let sid = m.open_with_fault(cfg, Some(plan)).unwrap();
        // Checkpoint before the fault (checkpoint jobs don't tick the
        // armed ordinal, so this cannot fire it).
        let blob = m.checkpoint(sid).unwrap();
        m.ingest_batch(sid, &stream(20, res)).unwrap();
        // Snapshot flushes the staged batch; the first write job panics
        // on the worker, and the snapshot jobs queued behind it on the
        // band FIFOs synchronize: by the time the frame returns, the
        // quarantine is filed.
        let _ = m.snapshot(sid, 50_000).unwrap();
        match m.ingest_batch(sid, &stream(1, res)) {
            Err(Reject::Quarantined { id, faults }) => {
                assert_eq!(id, sid.raw());
                assert!(faults >= 1);
            }
            r => panic!("expected Quarantined, got {r:?}"),
        }
        assert!(matches!(m.snapshot(sid, 60_000), Err(Reject::Quarantined { .. })));
        assert!(matches!(m.drain(sid), Err(Reject::Quarantined { .. })));
        let faults = m.session_faults(sid).unwrap();
        assert!(!faults.is_empty());
        assert!(faults[0].detail.contains("injected fault"));
        let st = m.stats();
        assert_eq!(st.supervisor.quarantines, 1);
        assert_eq!(st.supervisor.injected_panics, 1);
        assert!(st.supervisor.worker_panics >= 1);
        // Restore lifts the quarantine; the session serves again.
        m.restore_in_place(sid, &blob).unwrap();
        m.ingest_batch(sid, &stream(20, res)).unwrap();
        let report = m.close(sid).unwrap();
        assert_eq!(report.pipeline.events_in, 20);
        m.shutdown();
    }

    #[test]
    fn clamp_policy_raises_backwards_timestamps_and_counts_them() {
        let res = Resolution::new(8, 8);
        let mut m = SessionManager::new(ServeConfig { workers: 1, ..ServeConfig::default() });
        let mut cfg = session_cfg(res, 10_000_000);
        cfg.pipeline.window_us = 100_000_000;
        assert_eq!(cfg.pipeline.clock_policy, ClockPolicy::Clamp, "Clamp is the default");
        let sid = m.open(cfg).unwrap();
        let mk = |t| LabeledEvent { ev: Event::new(t, 1, 1, Polarity::On), is_signal: true };
        // 1000, 500 (backwards → clamped to 1000), 1000 (duplicate:
        // passes untouched), 2000.
        m.ingest_batch(sid, &[mk(1_000), mk(500), mk(1_000), mk(2_000)]).unwrap();
        let report = m.close(sid).unwrap();
        assert_eq!(report.pipeline.events_in, 4, "clamped events are ingested");
        assert_eq!(report.pipeline.events_written, 4);
        assert_eq!(report.pipeline.events_nonmonotonic, 1);
        m.shutdown();
    }

    #[test]
    fn reject_policy_drops_backwards_timestamps_entirely() {
        let res = Resolution::new(8, 8);
        let mut m = SessionManager::new(ServeConfig { workers: 1, ..ServeConfig::default() });
        let mut cfg = session_cfg(res, 10_000_000);
        cfg.pipeline.window_us = 100_000_000;
        cfg.pipeline.clock_policy = ClockPolicy::Reject;
        let sid = m.open(cfg).unwrap();
        let mk = |t| LabeledEvent { ev: Event::new(t, 1, 1, Polarity::On), is_signal: true };
        m.ingest_batch(sid, &[mk(1_000), mk(500), mk(1_000), mk(2_000)]).unwrap();
        let report = m.close(sid).unwrap();
        // The backwards event is dropped *before* events_in, so the
        // accounting balance (in == written + dropped) still holds.
        assert_eq!(report.pipeline.events_in, 3);
        assert_eq!(report.pipeline.events_written, 3);
        assert_eq!(report.pipeline.events_nonmonotonic, 1);
        m.shutdown();
    }

    #[test]
    fn degradation_defers_cold_bands_and_serves_stale() {
        let res = Resolution::new(16, 16);
        let mut sc = ServeConfig { workers: 1, ..ServeConfig::default() };
        // Pressure 0 already reaches ServeStale (which includes
        // DeferCold); window frames must stay exact regardless.
        sc.supervisor.defer_cold_pressure = 0;
        sc.supervisor.serve_stale_pressure = 0;
        let mut m = SessionManager::new(sc);
        let mut cfg = session_cfg(res, 10_000_000);
        cfg.pipeline.window_us = 100_000_000;
        let sid = m.open(cfg).unwrap();
        assert_eq!(m.current_tier(), DegradeTier::ServeStale);
        // All bands cold: every render deferred, zero frame, not stale.
        let (f0, stale0) = m.snapshot_with_status(sid, 1_000).unwrap();
        assert!(!stale0);
        assert!(f0.as_slice().iter().all(|&v| v == 0.0));
        let n_bands = m.stats().open_bands as u64;
        assert_eq!(m.stats().supervisor.deferred_cold_snapshots, n_bands);
        // Dirty + never-rendered bands still render (only *cold* defers).
        let evs: Vec<LabeledEvent> = (0..8)
            .map(|k| LabeledEvent {
                ev: Event::new(2_000 + k, k as u16, 0, Polarity::On),
                is_signal: true,
            })
            .collect();
        m.ingest_batch(sid, &evs).unwrap();
        let (f1, stale1) = m.snapshot_with_status(sid, 3_000).unwrap();
        assert!(!stale1, "invalid+dirty bands render, they cannot serve stale");
        assert!(f1.as_slice().iter().any(|&v| v != 0.0));
        // Dirty + previously-rendered: served stale from the old cache.
        let evs2: Vec<LabeledEvent> = (0..8)
            .map(|k| LabeledEvent {
                ev: Event::new(4_000 + k, k as u16, 1, Polarity::On),
                is_signal: true,
            })
            .collect();
        m.ingest_batch(sid, &evs2).unwrap();
        let (f2, stale2) = m.snapshot_with_status(sid, 5_000).unwrap();
        assert!(stale2, "valid+dirty band must serve its cache under ServeStale");
        assert_eq!(f2.as_slice(), f1.as_slice(), "stale frame is the previous render");
        assert_eq!(m.stats().supervisor.stale_frames_served, 1);
        m.shutdown();
    }

    #[test]
    fn shed_tier_rejects_new_sessions() {
        let mut sc = ServeConfig { workers: 1, ..ServeConfig::default() };
        sc.supervisor.shed_pressure = 0;
        let mut m = SessionManager::new(sc);
        match m.open(session_cfg(Resolution::new(8, 8), 1_000)) {
            Err(Reject::Overloaded { .. }) => {}
            r => panic!("expected Overloaded, got {r:?}"),
        }
        let st = m.shutdown();
        assert_eq!(st.supervisor.sessions_shed_overloaded, 1);
    }
}
