//! Seeded fault injection for the wire protocol — the chaos test's
//! adversarial fleet.
//!
//! Every fault is deterministic given its seed: the injector draws
//! offsets, bit positions and event payloads from a [`Pcg64`] stream
//! keyed by `(seed, fault kind)`, so a failing chaos run replays
//! exactly from its printed seed. Each [`FaultKind`] is aimed at a
//! specific typed rejection bucket in
//! [`NetStats`](crate::serve::NetStats):
//!
//! | fault | wire behaviour | expected server accounting |
//! |---|---|---|
//! | `Truncate` | frame cut mid-payload, then close | `abrupt_disconnects`, session drained |
//! | `BitFlip` | payload bit flipped (past the seq prefix), repeated past the error budget | `checksum_errors`, `budget_disconnects` |
//! | `Stall` | silence mid-payload longer than the read deadline | `deadline_disconnects` |
//! | `Disconnect` | socket torn down between frames, no BYE | `abrupt_disconnects` |
//! | `Duplicate` | an already-acked seq resent verbatim, then clean BYE | `duplicate_batches`, `byes_completed` |

use super::deadline::DeadlineStream;
use super::frame::{self, kind, Header, Hello, HEADER_LEN};
use crate::events::{aer, Event, Polarity};
use crate::util::rng::Pcg64;
use std::io;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// The ways a faulty camera misbehaves on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Send a prefix of a BATCH frame, then close the socket.
    Truncate,
    /// Flip one payload bit per BATCH until the error budget trips.
    BitFlip,
    /// Go silent mid-frame for longer than the server's read deadline.
    Stall,
    /// Vanish between frames without a BYE.
    Disconnect,
    /// Resend an already-acknowledged seq, then finish cleanly.
    Duplicate,
}

impl FaultKind {
    /// All kinds, for chaos fleets that want one camera per fault.
    pub const ALL: [FaultKind; 5] = [
        FaultKind::Truncate,
        FaultKind::BitFlip,
        FaultKind::Stall,
        FaultKind::Disconnect,
        FaultKind::Duplicate,
    ];

    fn stream_key(self) -> u64 {
        match self {
            FaultKind::Truncate => 0xfa01,
            FaultKind::BitFlip => 0xfa02,
            FaultKind::Stall => 0xfa03,
            FaultKind::Disconnect => 0xfa04,
            FaultKind::Duplicate => 0xfa05,
        }
    }
}

/// Deterministic corruption of encoded frames.
pub struct FaultInjector {
    kind: FaultKind,
    rng: Pcg64,
}

impl FaultInjector {
    /// Build an injector whose draws depend on `(seed, kind)` only.
    pub fn new(kind: FaultKind, seed: u64) -> Self {
        Self { kind, rng: Pcg64::with_stream(seed, kind.stream_key()) }
    }

    /// The fault this injector drives.
    pub fn kind(&self) -> FaultKind {
        self.kind
    }

    /// Flip one bit inside a BATCH frame's AER body — past the header
    /// *and* the 4-byte seq prefix, so the damage is always caught by
    /// the CRC check rather than misread as a seq gap.
    pub fn flip_payload_bit(&mut self, frame_bytes: &mut [u8]) {
        let lo = HEADER_LEN + 4;
        debug_assert!(frame_bytes.len() > lo, "frame too short to corrupt safely");
        let span = (frame_bytes.len() - lo) as u64;
        let byte = lo + self.rng.below(span) as usize;
        let bit = self.rng.below(8) as u8;
        frame_bytes[byte] ^= 1 << bit;
    }

    /// A cut point strictly inside the payload (at least the header goes
    /// out, at least one payload byte stays behind).
    pub fn truncation_point(&mut self, frame_len: usize) -> usize {
        debug_assert!(frame_len > HEADER_LEN + 1);
        HEADER_LEN + 1 + self.rng.below((frame_len - HEADER_LEN - 1) as u64) as usize
    }

    /// Deterministic synthetic event batch: sorted times, in-bounds
    /// coordinates for a `width`×`height` sensor.
    pub fn gen_events(&mut self, t: &mut u64, n: usize, width: u16, height: u16) -> Vec<Event> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            *t += self.rng.range_u64(1, 40);
            out.push(Event {
                t: *t,
                x: self.rng.below(width as u64) as u16,
                y: self.rng.below(height as u64) as u16,
                p: if self.rng.bool(0.5) { Polarity::On } else { Polarity::Off },
            });
        }
        out
    }
}

/// Sensor geometry the faulty cameras announce.
const FAULT_W: u16 = 32;
const FAULT_H: u16 = 32;
/// Events per clean warm-up batch.
const BATCH_N: usize = 48;
/// How long the injector waits for any single reply.
const REPLY_TIMEOUT: Duration = Duration::from_millis(500);

/// Drive one faulty camera against a live server: clean HELLO, two
/// clean batches, then the configured fault. `stall_ms` is how long the
/// `Stall` fault holds the line (choose it above the server's read
/// timeout). All socket errors are tolerated — a faulted connection is
/// *expected* to die; the assertions live server-side in `NetStats`.
pub fn run_faulty_camera(addr: SocketAddr, fault: FaultKind, seed: u64, stall_ms: u64) {
    let _ = drive(addr, fault, seed, stall_ms);
}

fn drive(addr: SocketAddr, fault: FaultKind, seed: u64, stall_ms: u64) -> io::Result<()> {
    let mut inj = FaultInjector::new(fault, seed);
    let stream = TcpStream::connect(addr)?;
    let mut dl = DeadlineStream::new(stream, REPLY_TIMEOUT)?;

    // Clean HELLO: tiny sensor, huge window and t_end 0 so the session
    // produces no periodic FRAME traffic to get tangled with the fault.
    let hello = Hello {
        name: format!("faulty-{fault:?}-{seed}"),
        width: FAULT_W,
        height: FAULT_H,
        t_end_us: 0,
        window_us: 1_000_000_000,
        batch_size: 4_096,
        n_shards: 1,
        denoise_shards: 1,
        stcf: false,
    };
    let mut payload = Vec::new();
    hello.encode(&mut payload);
    let mut buf = Vec::new();
    frame::encode_frame_into(&mut buf, kind::HELLO, &payload);
    dl.write_all_within(&buf)?;
    match read_one(&mut dl)? {
        kind::ACK => {}
        // Shed or refused at admission — nothing more to inject.
        _ => return Ok(()),
    }

    let mut t = 0u64;
    let mut first_batch: Option<Vec<u8>> = None;
    for seq in 0..2u32 {
        let events = inj.gen_events(&mut t, BATCH_N, FAULT_W, FAULT_H);
        encode_batch(&mut payload, &mut buf, seq, &events);
        if seq == 0 {
            first_batch = Some(buf.clone());
        }
        dl.write_all_within(&buf)?;
        read_until_ack(&mut dl)?;
    }

    match fault {
        FaultKind::Truncate => {
            let events = inj.gen_events(&mut t, BATCH_N, FAULT_W, FAULT_H);
            encode_batch(&mut payload, &mut buf, 2, &events);
            let cut = inj.truncation_point(buf.len());
            dl.write_all_within(&buf[..cut])?;
            dl.shutdown_now()?;
        }
        FaultKind::BitFlip => {
            // One flipped batch per strike until the budget NACK lands
            // and the server hangs up (subsequent writes then fail, which
            // is the success condition here).
            for seq in 2..10u32 {
                let events = inj.gen_events(&mut t, BATCH_N, FAULT_W, FAULT_H);
                encode_batch(&mut payload, &mut buf, seq, &events);
                inj.flip_payload_bit(&mut buf);
                if dl.write_all_within(&buf).is_err() {
                    break;
                }
                match read_one(&mut dl) {
                    Ok(kind::NACK) => continue,
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
        }
        FaultKind::Stall => {
            let events = inj.gen_events(&mut t, BATCH_N, FAULT_W, FAULT_H);
            encode_batch(&mut payload, &mut buf, 2, &events);
            let cut = inj.truncation_point(buf.len());
            dl.write_all_within(&buf[..cut])?;
            std::thread::sleep(Duration::from_millis(stall_ms));
            dl.shutdown_now()?;
        }
        FaultKind::Disconnect => {
            dl.shutdown_now()?;
        }
        FaultKind::Duplicate => {
            let dup = first_batch.take().unwrap_or_default();
            dl.write_all_within(&dup)?;
            // Expect the DUPLICATE nack, then leave cleanly.
            let _ = read_one(&mut dl)?;
            frame::encode_frame_into(&mut buf, kind::BYE, &[]);
            dl.write_all_within(&buf)?;
            read_until(&mut dl, kind::BYE_OK)?;
        }
    }
    Ok(())
}

/// Frame a BATCH: 4-byte seq prefix + AER body.
fn encode_batch(payload: &mut Vec<u8>, out: &mut Vec<u8>, seq: u32, events: &[Event]) {
    payload.clear();
    payload.extend_from_slice(&seq.to_le_bytes());
    payload.extend_from_slice(&aer::encode(events));
    frame::encode_frame_into(out, kind::BATCH, payload);
}

/// Read one reply frame (header + payload), returning its kind.
fn read_one(dl: &mut DeadlineStream) -> io::Result<u8> {
    let mut hdr_bytes = [0u8; HEADER_LEN];
    dl.read_exact_within(&mut hdr_bytes, REPLY_TIMEOUT)?;
    let hdr = Header::parse(&hdr_bytes);
    let mut payload = vec![0u8; hdr.len as usize];
    dl.read_exact_within(&mut payload, REPLY_TIMEOUT)?;
    Ok(hdr.kind)
}

/// Swallow replies (FRAMEs, NACKs) until an ACK arrives.
fn read_until_ack(dl: &mut DeadlineStream) -> io::Result<()> {
    read_until(dl, kind::ACK)
}

/// Swallow replies until a frame of `want` arrives (bounded, so a
/// misbehaving server cannot wedge the injector).
fn read_until(dl: &mut DeadlineStream, want: u8) -> io::Result<()> {
    for _ in 0..64 {
        if read_one(dl)? == want {
            return Ok(());
        }
    }
    Err(io::Error::new(io::ErrorKind::InvalidData, "expected reply never arrived"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn injector_is_deterministic_per_seed_and_kind() {
        let mk = |kind, seed| {
            let mut inj = FaultInjector::new(kind, seed);
            let mut t = 0;
            let evs = inj.gen_events(&mut t, 16, FAULT_W, FAULT_H);
            let mut frame_bytes = vec![0u8; 256];
            inj.flip_payload_bit(&mut frame_bytes);
            let cut = inj.truncation_point(256);
            (evs, frame_bytes, cut)
        };
        assert_eq!(mk(FaultKind::BitFlip, 7), mk(FaultKind::BitFlip, 7));
        // Different kinds draw from different streams even at one seed.
        assert_ne!(mk(FaultKind::BitFlip, 7).0, mk(FaultKind::Truncate, 7).0);
    }

    #[test]
    fn bit_flip_lands_past_the_seq_prefix() {
        let mut inj = FaultInjector::new(FaultKind::BitFlip, 3);
        for _ in 0..200 {
            let mut frame_bytes = vec![0u8; HEADER_LEN + 4 + 32];
            inj.flip_payload_bit(&mut frame_bytes);
            let changed = frame_bytes.iter().position(|&b| b != 0).expect("one bit flipped");
            assert!(changed >= HEADER_LEN + 4, "flip at {changed} could masquerade as a seq gap");
        }
    }

    #[test]
    fn truncation_point_is_strictly_inside_the_payload() {
        let mut inj = FaultInjector::new(FaultKind::Truncate, 11);
        for _ in 0..200 {
            let cut = inj.truncation_point(100);
            assert!(cut > HEADER_LEN && cut < 100);
        }
    }

    #[test]
    fn gen_events_are_sorted_and_in_bounds() {
        let mut inj = FaultInjector::new(FaultKind::Stall, 5);
        let mut t = 0;
        let evs = inj.gen_events(&mut t, 500, FAULT_W, FAULT_H);
        for w in evs.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
        assert!(evs.iter().all(|e| e.x < FAULT_W && e.y < FAULT_H));
    }
}
