//! Per-connection protocol engine: one thread, one camera, one session.
//!
//! Maps the connection lifecycle onto the [`SessionManager`] lifecycle —
//! HELLO → `open`, BATCH → `ingest_batch`, SNAPSHOT_REQ → `snapshot`,
//! BYE → `drain` + `close` — and makes every way a connection can go
//! wrong a *typed, counted, bounded* event:
//!
//! * **Deadlines.** The frame header is awaited under the idle deadline,
//!   payload bytes under the read deadline (both overall bounds via
//!   [`DeadlineStream`]). A miss NACKs `DEADLINE` and tears down.
//! * **Error budget.** Recoverable protocol faults (checksum mismatch,
//!   `AerError`, unknown frame kind, seq gaps) each cost a strike; at
//!   [`NetConfig::error_budget`] strikes the connection is NACKed
//!   `BUDGET` and torn down. Unrecoverable faults (garbage header —
//!   framing itself untrusted) tear down immediately.
//! * **Drained, not dropped.** Teardown of a live session *always* runs
//!   `drain` then `close`, so every event an ACK acknowledged reaches
//!   the band writers; the final accounting is balance-checked and any
//!   discrepancy counted in `NetStats::drain_accounting_mismatches`.
//! * **Duplicates.** BATCH frames carry a client seq; a seq already
//!   acknowledged is NACKed `DUPLICATE` and *not* re-ingested, so a
//!   retry after a lost ACK cannot double-write events.
//!
//! BATCH payloads are consumed streaming: each socket chunk goes through
//! the incremental [`AerDecoder`] and the running [`Crc32`] in one pass
//! — a frame split across reads is never copied into a contiguous
//! buffer, never re-parsed.

use super::deadline::{DeadlineStream, PolledRead};
use super::frame::{self, code, kind, Crc32, Header, Hello, Nack, HEADER_LEN};
use super::server::{NetConfig, NetCounters};
use crate::events::aer::{AerDecoder, AerError};
use crate::events::{Event, LabeledEvent};
use crate::serve::obs::{elapsed_us, FleetObs};
use crate::serve::session::{SessionConfig, SessionId, SessionManager};
use crate::util::grid::Grid;
use crate::util::sync::{Arc, AtomicUsize, Mutex, Ordering};
use crate::util::telemetry::Counter;
use std::io;
use std::net::TcpStream;
use std::time::Instant;

/// The manager handle every connection thread shares.
pub(crate) type SharedManager = Arc<Mutex<SessionManager>>;

/// Everything a connection handler needs from the server.
pub(crate) struct ConnCtx {
    pub(crate) manager: SharedManager,
    pub(crate) cfg: NetConfig,
    pub(crate) counters: Arc<NetCounters>,
    /// Fleet observability root — handlers record the decode stage here
    /// without taking the manager lock.
    pub(crate) obs: Arc<FleetObs>,
    pub(crate) shutdown: Arc<AtomicUsize>,
}

/// Why the connection loop ended.
enum ConnEnd {
    /// Clean BYE handshake (session already drained + closed).
    Bye,
    /// HELLO refused by admission control (no session ever opened).
    Refused,
    /// Peer vanished (EOF / reset).
    PeerGone,
    /// A read/idle deadline expired.
    Deadline,
    /// The decode-error budget is exhausted.
    Budget,
    /// Unrecoverable framing fault (header can't be trusted to resync).
    Fatal,
    /// The server is shutting down.
    Shutdown,
    /// Unclassified socket error.
    Io,
}

/// Size of the streaming read window for BATCH payloads.
const CHUNK: usize = 4096;

#[inline]
fn bump(c: &Counter) {
    c.inc();
}

/// Run one connection to completion. Never panics outward by design;
/// the server still counts a panicking handler via its join results.
pub(crate) fn handle(stream: TcpStream, ctx: ConnCtx) {
    let dl = match DeadlineStream::new(stream, ctx.cfg.write_timeout) {
        Ok(dl) => dl,
        Err(_) => return,
    };
    let mut conn = Conn {
        dl,
        ctx,
        session: None,
        decoder: None,
        strikes: 0,
        evbuf: Vec::new(),
        lebuf: Vec::new(),
        payload_buf: Vec::new(),
        send_buf: Vec::new(),
        frame_buf: Vec::new(),
    };
    let end = conn.run();
    conn.teardown(end);
}

/// Wire-session state for an admitted camera.
struct OpenSession {
    sid: SessionId,
    /// Next unacknowledged BATCH seq (everything below is a duplicate).
    expected_seq: u32,
    /// Largest ingested event time — the causality floor for BATCH
    /// ordering and SNAPSHOT_REQ times.
    last_t: u64,
}

struct Conn {
    dl: DeadlineStream,
    ctx: ConnCtx,
    session: Option<OpenSession>,
    decoder: Option<AerDecoder>,
    strikes: u32,
    evbuf: Vec<Event>,
    lebuf: Vec<LabeledEvent>,
    /// Scratch for small whole payloads (HELLO, SNAPSHOT_REQ).
    payload_buf: Vec<u8>,
    /// Reusable frame serialization buffer.
    send_buf: Vec<u8>,
    /// Reusable reply-payload buffer.
    frame_buf: Vec<u8>,
}

impl Conn {
    fn run(&mut self) -> ConnEnd {
        loop {
            // Await the next header under the idle deadline, waking every
            // 50 ms so server shutdown is noticed promptly; a header that
            // started arriving is always finished (or deadlined), never
            // abandoned mid-frame.
            let mut hdr_bytes = [0u8; HEADER_LEN];
            let shutdown_flag = &self.ctx.shutdown;
            match self.dl.read_exact_polled(
                &mut hdr_bytes,
                self.ctx.cfg.idle_timeout,
                std::time::Duration::from_millis(50),
                || shutdown_flag.load(Ordering::SeqCst) != 0,
            ) {
                Ok(PolledRead::Filled) => {}
                Ok(PolledRead::Stopped) => return ConnEnd::Shutdown,
                Err(e) => return classify_io(&e),
            }
            let hdr = Header::parse(&hdr_bytes);
            if hdr.len as usize > self.ctx.cfg.max_frame_bytes {
                // An implausible length means we cannot trust the byte
                // stream to contain a next frame boundary: fatal.
                bump(&self.ctx.counters.bad_frames);
                let _ = self.send_nack(code::BAD_FRAME, 0, 0, "oversized or garbage frame header");
                return ConnEnd::Fatal;
            }
            let step = match hdr.kind {
                kind::HELLO => self.on_hello(&hdr),
                kind::BATCH => self.on_batch(&hdr),
                kind::SNAPSHOT_REQ => self.on_snapshot(&hdr),
                kind::STATS_REQ => self.on_stats(&hdr),
                kind::BYE => return self.on_bye(),
                _ => self.on_unknown(&hdr),
            };
            if let Err(end) = step {
                return end;
            }
        }
    }

    // ---- frame handlers -------------------------------------------------

    fn on_hello(&mut self, hdr: &Header) -> Result<(), ConnEnd> {
        self.read_small_payload(hdr)?;
        if !self.checksum_ok(hdr) {
            return self.recoverable(code::BAD_CHECKSUM, 0, "HELLO checksum mismatch");
        }
        if self.session.is_some() {
            bump(&self.ctx.counters.protocol_errors);
            return self.recoverable(code::PROTOCOL, 0, "duplicate HELLO on an open session");
        }
        let hello = match Hello::decode(&self.payload_buf) {
            Ok(h) => h,
            Err(e) => {
                bump(&self.ctx.counters.bad_frames);
                return self.recoverable(code::BAD_FRAME, 0, &format!("bad HELLO payload: {e}"));
            }
        };
        let res = hello.resolution();
        let session_cfg = SessionConfig {
            name: hello.name.clone(),
            res,
            t_end_us: hello.t_end_us,
            pipeline: hello.pipeline_config(),
        };
        let opened = {
            let mut mgr = self.lock_manager();
            mgr.open(session_cfg)
        };
        match opened {
            Ok(sid) => {
                bump(&self.ctx.counters.sessions_opened);
                self.decoder = Some(AerDecoder::new(res));
                self.session = Some(OpenSession { sid, expected_seq: 0, last_t: 0 });
                self.send_ack(0).map_err(|e| classify_io(&e))
            }
            Err(reject) => {
                bump(&self.ctx.counters.hellos_rejected);
                let _ = self.send_nack(
                    reject.code(),
                    self.ctx.cfg.retry_after_ms,
                    0,
                    &reject.to_string(),
                );
                Err(ConnEnd::Refused)
            }
        }
    }

    fn on_batch(&mut self, hdr: &Header) -> Result<(), ConnEnd> {
        if hdr.len < 4 {
            self.discard_payload(hdr.len as usize)?;
            bump(&self.ctx.counters.bad_frames);
            return self.recoverable(code::BAD_FRAME, 0, "BATCH payload shorter than its seq");
        }
        if self.session.is_none() {
            self.discard_payload(hdr.len as usize)?;
            bump(&self.ctx.counters.protocol_errors);
            return self.recoverable(code::PROTOCOL, 0, "BATCH before HELLO");
        }
        let mut crc = Crc32::new();
        let mut seq_bytes = [0u8; 4];
        self.dl
            .read_exact_within(&mut seq_bytes, self.ctx.cfg.read_timeout)
            .map_err(|e| classify_io(&e))?;
        crc.update(&seq_bytes);
        let seq = u32::from_le_bytes(seq_bytes);
        let body_len = hdr.len as usize - 4;
        let expected_seq = self.session.as_ref().map(|s| s.expected_seq).unwrap_or(0);
        if seq != expected_seq {
            // Consume the body so framing stays in sync, then classify.
            self.discard_payload(body_len)?;
            return if seq < expected_seq {
                // A retry of an already-acked batch (e.g. our ACK was
                // lost): refuse idempotently, no strike, no re-ingest.
                bump(&self.ctx.counters.duplicate_batches);
                self.send_nack(code::DUPLICATE, 0, seq, "batch seq already acknowledged")
                    .map_err(|e| classify_io(&e))
            } else {
                bump(&self.ctx.counters.protocol_errors);
                self.recoverable(code::PROTOCOL, seq, "batch seq gap (batches lost?)")
            };
        }
        // Stream the AER body: every chunk feeds the running CRC and the
        // incremental decoder in one pass. The whole streaming window is
        // the decode stage span (includes the socket reads — that is the
        // real cost of getting a batch off the wire into events).
        let t_decode = Instant::now();
        self.evbuf.clear();
        let mut decode_err: Option<AerError> = None;
        {
            let decoder = self.decoder.as_mut().expect("decoder exists for open session");
            decoder.reset();
            let mut left = body_len;
            let mut chunk = [0u8; CHUNK];
            while left > 0 {
                let take = left.min(CHUNK);
                self.dl
                    .read_exact_within(&mut chunk[..take], self.ctx.cfg.read_timeout)
                    .map_err(|e| classify_io(&e))?;
                crc.update(&chunk[..take]);
                if decode_err.is_none() {
                    if let Err(e) = decoder.push(&chunk[..take], &mut self.evbuf) {
                        decode_err = Some(e);
                    }
                }
                left -= take;
            }
            if decode_err.is_none() {
                if let Err(e) = decoder.finish() {
                    decode_err = Some(e);
                }
            }
        }
        self.ctx.obs.stage_decode.record(elapsed_us(t_decode));
        if crc.finish() != hdr.crc {
            bump(&self.ctx.counters.checksum_errors);
            return self.recoverable(code::BAD_CHECKSUM, seq, "BATCH checksum mismatch");
        }
        if let Some(e) = decode_err {
            bump(&self.ctx.counters.decode_errors);
            return self.recoverable(code::DECODE, seq, &e.to_string());
        }
        let last_t = self.session.as_ref().map(|s| s.last_t).unwrap_or(0);
        if self.evbuf.first().is_some_and(|e| e.t < last_t) {
            bump(&self.ctx.counters.protocol_errors);
            return self.recoverable(
                code::OUT_OF_ORDER,
                seq,
                "batch timestamps precede the session stream",
            );
        }
        self.lebuf.clear();
        self.lebuf.extend(self.evbuf.iter().map(|&ev| LabeledEvent { ev, is_signal: true }));
        let sid = self.session.as_ref().map(|s| s.sid).expect("session checked above");
        let ingested = {
            let mut mgr = self.lock_manager();
            mgr.ingest_batch(sid, &self.lebuf)
        };
        match ingested {
            Ok(frames) => {
                for (at, g) in &frames {
                    self.send_frame_reply(*at, g, 0).map_err(|e| classify_io(&e))?;
                }
                if let Some(s) = self.session.as_mut() {
                    s.expected_seq = expected_seq.wrapping_add(1);
                    if let Some(last) = self.evbuf.last() {
                        s.last_t = last.t;
                    }
                }
                bump(&self.ctx.counters.batches_acked);
                self.ctx.counters.events_ingested.add(self.evbuf.len() as u64);
                self.send_ack(seq).map_err(|e| classify_io(&e))
            }
            Err(reject) => {
                // Backpressure: the batch was NOT ingested; the client
                // retries the same seq after the hinted backoff. Not a
                // strike — a correct client under load hits this path.
                bump(&self.ctx.counters.backpressure_nacks);
                self.send_nack(
                    reject.code(),
                    self.ctx.cfg.retry_after_ms,
                    seq,
                    &reject.to_string(),
                )
                .map_err(|e| classify_io(&e))
            }
        }
    }

    fn on_snapshot(&mut self, hdr: &Header) -> Result<(), ConnEnd> {
        self.read_small_payload(hdr)?;
        if !self.checksum_ok(hdr) {
            return self.recoverable(code::BAD_CHECKSUM, 0, "SNAPSHOT_REQ checksum mismatch");
        }
        let (sid, last_t) = match self.session.as_ref() {
            Some(s) => (s.sid, s.last_t),
            None => {
                bump(&self.ctx.counters.protocol_errors);
                return self.recoverable(code::PROTOCOL, 0, "SNAPSHOT_REQ before HELLO");
            }
        };
        if self.payload_buf.len() != 8 {
            bump(&self.ctx.counters.bad_frames);
            return self.recoverable(code::BAD_FRAME, 0, "SNAPSHOT_REQ payload must be 8 bytes");
        }
        let mut at = [0u8; 8];
        at.copy_from_slice(&self.payload_buf);
        let at_us = u64::from_le_bytes(at);
        if at_us < last_t {
            bump(&self.ctx.counters.protocol_errors);
            return self.recoverable(
                code::OUT_OF_ORDER,
                0,
                "snapshot time precedes ingested events (snapshots must be causal)",
            );
        }
        let snap = {
            let mut mgr = self.lock_manager();
            mgr.snapshot_with_status(sid, at_us)
        };
        match snap {
            Ok((g, stale)) => {
                let flags = if stale { frame::flag::STALE } else { 0 };
                self.send_frame_reply(at_us, &g, flags).map_err(|e| classify_io(&e))
            }
            Err(reject) => {
                bump(&self.ctx.counters.protocol_errors);
                self.recoverable(reject.code(), 0, &reject.to_string())
            }
        }
    }

    /// STATS_REQ → one Prometheus-style scrape as a `STATS` frame.
    /// Deliberately allowed before HELLO: operators scrape the fleet
    /// without opening a session (or holding one open).
    fn on_stats(&mut self, hdr: &Header) -> Result<(), ConnEnd> {
        self.read_small_payload(hdr)?;
        if !self.checksum_ok(hdr) {
            return self.recoverable(code::BAD_CHECKSUM, 0, "STATS_REQ checksum mismatch");
        }
        let text = {
            let mgr = self.lock_manager();
            mgr.metrics_text()
        };
        self.frame_buf.clear();
        self.frame_buf.extend_from_slice(text.as_bytes());
        frame::encode_frame_into(&mut self.send_buf, kind::STATS, &self.frame_buf);
        self.send_raw().map_err(|e| classify_io(&e))
    }

    fn on_bye(&mut self) -> ConnEnd {
        let frames_total = match self.session.take() {
            Some(sess) => {
                let drained = {
                    let mut mgr = self.lock_manager();
                    mgr.drain(sess.sid)
                };
                if let Ok(frames) = &drained {
                    for (at, g) in frames {
                        if self.send_frame_reply(*at, g, 0).is_err() {
                            break;
                        }
                    }
                }
                let report = {
                    let mut mgr = self.lock_manager();
                    mgr.close(sess.sid)
                };
                match report {
                    Ok(r) => {
                        self.check_balance(&r.pipeline);
                        r.pipeline.frames_emitted
                    }
                    Err(_) => 0,
                }
            }
            None => 0,
        };
        bump(&self.ctx.counters.byes_completed);
        self.frame_buf.clear();
        self.frame_buf.extend_from_slice(&frames_total.to_le_bytes());
        frame::encode_frame_into(&mut self.send_buf, kind::BYE_OK, &self.frame_buf);
        let _ = self.send_raw();
        ConnEnd::Bye
    }

    fn on_unknown(&mut self, hdr: &Header) -> Result<(), ConnEnd> {
        // The length is plausible, so skip the payload and resync on the
        // next header — one flipped kind bit must not kill the stream.
        self.discard_payload(hdr.len as usize)?;
        bump(&self.ctx.counters.bad_frames);
        self.recoverable(code::BAD_FRAME, 0, "unknown frame kind")
    }

    // ---- teardown -------------------------------------------------------

    /// Always leave the fleet consistent: a live session is drained then
    /// closed no matter how the connection ended, and its accounting is
    /// balance-checked (acked events must all have reached the writers).
    fn teardown(&mut self, end: ConnEnd) {
        match end {
            ConnEnd::Bye | ConnEnd::Refused => {}
            ConnEnd::Shutdown => {
                // Server-initiated graceful end: drain, hand the client
                // its tail frames and a BYE_OK, then close.
                self.drain_close_session(true);
            }
            ConnEnd::Deadline => {
                bump(&self.ctx.counters.deadline_disconnects);
                let _ = self.send_nack(code::DEADLINE, 0, 0, "read deadline missed");
                self.fault_drain();
            }
            ConnEnd::PeerGone | ConnEnd::Io => {
                bump(&self.ctx.counters.abrupt_disconnects);
                self.fault_drain();
            }
            ConnEnd::Budget => {
                bump(&self.ctx.counters.budget_disconnects);
                self.fault_drain();
            }
            ConnEnd::Fatal => {
                self.fault_drain();
            }
        }
        let _ = self.dl.shutdown_now();
    }

    /// Drain + close after a fault, counting the session as
    /// drained-on-error (the "drained, not dropped" guarantee).
    fn fault_drain(&mut self) {
        if self.session.is_some() {
            bump(&self.ctx.counters.sessions_drained_on_error);
            self.drain_close_session(false);
        }
    }

    fn drain_close_session(&mut self, send_tail: bool) {
        let Some(sess) = self.session.take() else { return };
        let drained = {
            let mut mgr = self.lock_manager();
            mgr.drain(sess.sid)
        };
        if send_tail {
            if let Ok(frames) = &drained {
                for (at, g) in frames {
                    if self.send_frame_reply(*at, g, 0).is_err() {
                        break;
                    }
                }
            }
        }
        let report = {
            let mut mgr = self.lock_manager();
            mgr.close(sess.sid)
        };
        if let Ok(r) = report {
            self.check_balance(&r.pipeline);
            if send_tail {
                self.frame_buf.clear();
                self.frame_buf.extend_from_slice(&r.pipeline.frames_emitted.to_le_bytes());
                frame::encode_frame_into(&mut self.send_buf, kind::BYE_OK, &self.frame_buf);
                let _ = self.send_raw();
            }
        }
    }

    fn check_balance(&self, p: &crate::coordinator::PipelineStats) {
        if p.events_in != p.events_written + p.events_dropped_by_stcf {
            bump(&self.ctx.counters.drain_accounting_mismatches);
        }
    }

    // ---- plumbing -------------------------------------------------------

    fn lock_manager(&self) -> crate::util::sync::MutexGuard<'_, SessionManager> {
        self.ctx.manager.lock().expect("session manager lock poisoned")
    }

    /// A recoverable fault: NACK it, take a strike, and keep the
    /// connection unless the budget is spent.
    fn recoverable(&mut self, code_: u16, seq: u32, reason: &str) -> Result<(), ConnEnd> {
        self.send_nack(code_, 0, seq, reason).map_err(|e| classify_io(&e))?;
        self.strikes += 1;
        if self.strikes >= self.ctx.cfg.error_budget {
            let _ = self.send_nack(
                code::BUDGET,
                0,
                seq,
                &format!("error budget exhausted ({} strikes)", self.strikes),
            );
            return Err(ConnEnd::Budget);
        }
        Ok(())
    }

    fn read_small_payload(&mut self, hdr: &Header) -> Result<(), ConnEnd> {
        self.payload_buf.resize(hdr.len as usize, 0);
        self.dl
            .read_exact_within(&mut self.payload_buf, self.ctx.cfg.read_timeout)
            .map_err(|e| classify_io(&e))
    }

    fn checksum_ok(&mut self, hdr: &Header) -> bool {
        let ok = frame::crc32(&self.payload_buf) == hdr.crc;
        if !ok {
            bump(&self.ctx.counters.checksum_errors);
        }
        ok
    }

    fn discard_payload(&mut self, mut len: usize) -> Result<(), ConnEnd> {
        let mut chunk = [0u8; CHUNK];
        while len > 0 {
            let take = len.min(CHUNK);
            self.dl
                .read_exact_within(&mut chunk[..take], self.ctx.cfg.read_timeout)
                .map_err(|e| classify_io(&e))?;
            len -= take;
        }
        Ok(())
    }

    fn send_ack(&mut self, seq: u32) -> io::Result<()> {
        self.frame_buf.clear();
        self.frame_buf.extend_from_slice(&seq.to_le_bytes());
        frame::encode_frame_into(&mut self.send_buf, kind::ACK, &self.frame_buf);
        self.send_raw()
    }

    fn send_nack(
        &mut self,
        code_: u16,
        retry_after_ms: u32,
        seq: u32,
        reason: &str,
    ) -> io::Result<()> {
        bump(&self.ctx.counters.nacks_sent);
        let nack = Nack { code: code_, retry_after_ms, seq, reason: reason.to_string() };
        nack.encode(&mut self.frame_buf);
        frame::encode_frame_into(&mut self.send_buf, kind::NACK, &self.frame_buf);
        self.send_raw()
    }

    /// Send one FRAME. `flags` carries the [`frame::flag`] bits (window
    /// frames always pass 0 — they are never degraded).
    fn send_frame_reply(&mut self, at_us: u64, g: &Grid<f64>, flags: u8) -> io::Result<()> {
        bump(&self.ctx.counters.frames_sent);
        frame::encode_frame_payload(&mut self.frame_buf, at_us, g, flags);
        frame::encode_frame_into(&mut self.send_buf, kind::FRAME, &self.frame_buf);
        self.send_raw()
    }

    fn send_raw(&mut self) -> io::Result<()> {
        self.dl.write_all_within(&self.send_buf)
    }
}

fn classify_io(e: &io::Error) -> ConnEnd {
    match e.kind() {
        io::ErrorKind::TimedOut => ConnEnd::Deadline,
        io::ErrorKind::UnexpectedEof
        | io::ErrorKind::ConnectionReset
        | io::ErrorKind::ConnectionAborted
        | io::ErrorKind::BrokenPipe => ConnEnd::PeerGone,
        _ => ConnEnd::Io,
    }
}
