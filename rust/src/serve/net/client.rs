//! Loopback wire client: the reference implementation of correct camera
//! behaviour, used by the benches, the chaos test's clean fleet, and the
//! `tsisc camera` subcommand.
//!
//! The client is deliberately strict — it verifies reply CRCs, tracks
//! its own batch seq, and on a `BACKPRESSURE` NACK retries the *same*
//! seq after a capped exponential backoff with seeded jitter (never
//! below the server's retry-after hint). Any other NACK is surfaced as
//! a typed [`NetError::Nacked`].

use super::deadline::DeadlineStream;
use super::frame::{self, kind, Header, Hello, Nack, HEADER_LEN};
use crate::events::{aer, Event};
use crate::util::grid::Grid;
use crate::util::rng::Pcg64;
use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Client-side knobs: reply deadlines and the backpressure retry policy.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Overall deadline for each reply read.
    pub read_timeout: Duration,
    /// Deadline for socket writes.
    pub write_timeout: Duration,
    /// Backpressure retries per batch before giving up.
    pub max_retries: u32,
    /// First backoff step, milliseconds (doubles per retry).
    pub backoff_base_ms: u64,
    /// Ceiling on one backoff step, milliseconds.
    pub backoff_cap_ms: u64,
    /// Seed for the jitter generator — retries stay reproducible.
    pub seed: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            max_retries: 10,
            backoff_base_ms: 1,
            backoff_cap_ms: 50,
            seed: 0x5eed_cafe,
        }
    }
}

/// Why a client call failed.
#[derive(Debug)]
pub enum NetError {
    /// Socket-level failure (includes reply deadlines).
    Io(io::Error),
    /// The server refused the request with a typed NACK.
    Nacked {
        /// Stable reject code (`frame::code::*` / `Reject::code`).
        code: u16,
        /// Batch seq the NACK refers to (0 when not batch-scoped).
        seq: u32,
        /// Server's retry-after hint, milliseconds (0 = don't retry).
        retry_after_ms: u32,
        /// Human-readable reason from the server.
        reason: String,
    },
    /// The reply stream itself was malformed (bad CRC, wrong kind).
    Protocol(String),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Io(e) => write!(f, "socket error: {e}"),
            NetError::Nacked { code, seq, retry_after_ms, reason } => write!(
                f,
                "server NACK code {code} (seq {seq}, retry after {retry_after_ms} ms): {reason}"
            ),
            NetError::Protocol(msg) => write!(f, "malformed reply: {msg}"),
        }
    }
}

impl std::error::Error for NetError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NetError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for NetError {
    fn from(e: io::Error) -> Self {
        NetError::Io(e)
    }
}

/// Ceiling on reply payloads the client will buffer (a full FRAME for
/// the largest supported sensor fits comfortably under this).
const MAX_REPLY_BYTES: usize = 64 << 20;

/// One wire connection to a [`super::NetServer`].
pub struct NetClient {
    dl: DeadlineStream,
    cfg: ClientConfig,
    rng: Pcg64,
    next_seq: u32,
    frames: Vec<(u64, Grid<f64>)>,
    payload_buf: Vec<u8>,
    send_buf: Vec<u8>,
    reply_buf: Vec<u8>,
}

impl NetClient {
    /// Connect to `addr` (no HELLO yet — call [`NetClient::hello`]).
    pub fn connect<A: ToSocketAddrs>(addr: A, cfg: ClientConfig) -> Result<NetClient, NetError> {
        let stream = TcpStream::connect(addr)?;
        let rng = Pcg64::new(cfg.seed);
        let dl = DeadlineStream::new(stream, cfg.write_timeout)?;
        Ok(NetClient {
            dl,
            cfg,
            rng,
            next_seq: 0,
            frames: Vec::new(),
            payload_buf: Vec::new(),
            send_buf: Vec::new(),
            reply_buf: Vec::new(),
        })
    }

    /// Open the session: send HELLO, await the ACK. A NACK (admission
    /// refused, fleet shed) comes back as [`NetError::Nacked`].
    pub fn hello(&mut self, hello: &Hello) -> Result<(), NetError> {
        hello.encode(&mut self.payload_buf);
        self.send(kind::HELLO)?;
        match self.read_reply()? {
            kind::ACK => Ok(()),
            kind::NACK => Err(self.take_nack()),
            k => Err(NetError::Protocol(format!("unexpected reply kind {k:#x} to HELLO"))),
        }
    }

    /// Ship one time-sorted batch and wait for its ACK. Window frames
    /// the server emits on the way are collected into
    /// [`NetClient::frames`]. On a backpressure NACK the same seq is
    /// retried after a capped, jittered exponential backoff (never
    /// sooner than the server's retry-after hint), up to
    /// [`ClientConfig::max_retries`] times.
    pub fn send_batch(&mut self, events: &[Event]) -> Result<(), NetError> {
        let seq = self.next_seq;
        let body = aer::encode(events);
        let mut attempt = 0u32;
        loop {
            self.payload_buf.clear();
            self.payload_buf.extend_from_slice(&seq.to_le_bytes());
            self.payload_buf.extend_from_slice(&body);
            self.send(kind::BATCH)?;
            loop {
                match self.read_reply()? {
                    kind::FRAME => self.collect_frame()?,
                    kind::ACK => {
                        let got = ack_seq(&self.reply_buf)?;
                        if got != seq {
                            return Err(NetError::Protocol(format!(
                                "ACK for seq {got}, expected {seq}"
                            )));
                        }
                        self.next_seq = self.next_seq.wrapping_add(1);
                        return Ok(());
                    }
                    kind::NACK => {
                        let nack = self.take_nack();
                        let NetError::Nacked { code, retry_after_ms, .. } = &nack else {
                            return Err(nack);
                        };
                        if *code == frame::code::BACKPRESSURE && attempt < self.cfg.max_retries {
                            let wait = self.backoff_ms(attempt, *retry_after_ms);
                            std::thread::sleep(Duration::from_millis(wait));
                            attempt += 1;
                            break; // resend the same seq
                        }
                        return Err(nack);
                    }
                    k => {
                        return Err(NetError::Protocol(format!(
                            "unexpected reply kind {k:#x} to BATCH"
                        )));
                    }
                }
            }
        }
    }

    /// Request an on-demand time-surface snapshot at `at_us` (must not
    /// precede already-sent events). Discards the FRAME flags; use
    /// [`NetClient::snapshot_with_status`] to observe the overload
    /// staleness marker.
    pub fn snapshot(&mut self, at_us: u64) -> Result<(u64, Grid<f64>), NetError> {
        self.snapshot_with_status(at_us).map(|(at, g, _)| (at, g))
    }

    /// [`NetClient::snapshot`] plus the server's staleness marker: true
    /// when overload degradation served at least one band from a stale
    /// cache ([`frame::flag::STALE`] on the wire).
    pub fn snapshot_with_status(
        &mut self,
        at_us: u64,
    ) -> Result<(u64, Grid<f64>, bool), NetError> {
        self.payload_buf.clear();
        self.payload_buf.extend_from_slice(&at_us.to_le_bytes());
        self.send(kind::SNAPSHOT_REQ)?;
        match self.read_reply()? {
            kind::FRAME => frame::decode_frame_payload(&self.reply_buf)
                .map(|(at, g, flags)| (at, g, flags & frame::flag::STALE != 0))
                .map_err(|e| NetError::Protocol(format!("bad FRAME payload: {e}"))),
            kind::NACK => Err(self.take_nack()),
            k => {
                Err(NetError::Protocol(format!("unexpected reply kind {k:#x} to SNAPSHOT_REQ")))
            }
        }
    }

    /// Close the session: send BYE, collect the drained tail frames, and
    /// return `(window frames received over the whole session, server's
    /// total emitted-frame count)` — the caller can check the two agree.
    pub fn bye(mut self) -> Result<(Vec<(u64, Grid<f64>)>, u64), NetError> {
        self.payload_buf.clear();
        self.send(kind::BYE)?;
        loop {
            match self.read_reply()? {
                kind::FRAME => self.collect_frame()?,
                kind::BYE_OK => {
                    if self.reply_buf.len() != 8 {
                        return Err(NetError::Protocol("BYE_OK payload must be 8 bytes".into()));
                    }
                    let mut n = [0u8; 8];
                    n.copy_from_slice(&self.reply_buf);
                    return Ok((self.frames, u64::from_le_bytes(n)));
                }
                kind::NACK => return Err(self.take_nack()),
                k => {
                    return Err(NetError::Protocol(format!(
                        "unexpected reply kind {k:#x} to BYE"
                    )));
                }
            }
        }
    }

    /// One metrics scrape: send STATS_REQ, return the Prometheus-style
    /// text body. Works before HELLO — `tsisc top` connects, scrapes,
    /// and disconnects without ever opening a session.
    pub fn stats(&mut self) -> Result<String, NetError> {
        self.payload_buf.clear();
        self.send(kind::STATS_REQ)?;
        match self.read_reply()? {
            kind::STATS => String::from_utf8(self.reply_buf.clone())
                .map_err(|_| NetError::Protocol("STATS payload is not UTF-8".into())),
            kind::NACK => Err(self.take_nack()),
            k => Err(NetError::Protocol(format!("unexpected reply kind {k:#x} to STATS_REQ"))),
        }
    }

    /// Window frames received so far (in emission order).
    pub fn frames(&self) -> &[(u64, Grid<f64>)] {
        &self.frames
    }

    // ---- plumbing -------------------------------------------------------

    /// Frame `payload_buf` under `kind` and write it out.
    fn send(&mut self, kind: u8) -> Result<(), NetError> {
        frame::encode_frame_into(&mut self.send_buf, kind, &self.payload_buf);
        self.dl.write_all_within(&self.send_buf)?;
        Ok(())
    }

    /// Read one reply frame into `reply_buf`, verifying its CRC, and
    /// return its kind (the payload stays in `self.reply_buf`).
    fn read_reply(&mut self) -> Result<u8, NetError> {
        let mut hdr_bytes = [0u8; HEADER_LEN];
        self.dl.read_exact_within(&mut hdr_bytes, self.cfg.read_timeout)?;
        let hdr = Header::parse(&hdr_bytes);
        if hdr.len as usize > MAX_REPLY_BYTES {
            return Err(NetError::Protocol(format!("oversized reply ({} bytes)", hdr.len)));
        }
        self.reply_buf.resize(hdr.len as usize, 0);
        self.dl.read_exact_within(&mut self.reply_buf, self.cfg.read_timeout)?;
        if frame::crc32(&self.reply_buf) != hdr.crc {
            return Err(NetError::Protocol("reply checksum mismatch".into()));
        }
        Ok(hdr.kind)
    }

    /// Decode the NACK sitting in `reply_buf` into a typed error.
    fn take_nack(&mut self) -> NetError {
        match Nack::decode(&self.reply_buf) {
            Ok(n) => NetError::Nacked {
                code: n.code,
                seq: n.seq,
                retry_after_ms: n.retry_after_ms,
                reason: n.reason,
            },
            Err(e) => NetError::Protocol(format!("undecodable NACK: {e}")),
        }
    }

    /// Decode the FRAME sitting in `reply_buf` into the frame log.
    /// Window frames are never degraded, so the flags are ignored here.
    fn collect_frame(&mut self) -> Result<(), NetError> {
        let (at, g, _flags) = frame::decode_frame_payload(&self.reply_buf)
            .map_err(|e| NetError::Protocol(format!("bad FRAME payload: {e}")))?;
        self.frames.push((at, g));
        Ok(())
    }

    /// Capped exponential backoff with jitter: the wait for retry
    /// `attempt` is uniform in [step/2, step] where step doubles from
    /// the base, and never under the server's retry-after hint.
    fn backoff_ms(&mut self, attempt: u32, retry_after_ms: u32) -> u64 {
        let base = self.cfg.backoff_base_ms.max(1);
        let cap = self.cfg.backoff_cap_ms.max(1);
        let step = base.saturating_mul(1u64 << attempt.min(20)).min(cap);
        let jittered = step / 2 + self.rng.below(step - step / 2 + 1);
        jittered.max(retry_after_ms as u64)
    }
}

/// Parse an ACK payload (the 4-byte LE seq it acknowledges).
fn ack_seq(p: &[u8]) -> Result<u32, NetError> {
    if p.len() != 4 {
        return Err(NetError::Protocol("ACK payload must be 4 bytes".into()));
    }
    let mut b = [0u8; 4];
    b.copy_from_slice(p);
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_caps_and_honors_hint() {
        // No live socket needed: poke the policy directly through a
        // client built around a loopback pair.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let mut c = NetClient::connect(
            addr,
            ClientConfig {
                backoff_base_ms: 2,
                backoff_cap_ms: 16,
                seed: 7,
                ..ClientConfig::default()
            },
        )
        .expect("connect");
        let _server_side = listener.accept().expect("accept");
        for attempt in 0..8 {
            let step = (2u64 << attempt).min(16);
            let w = c.backoff_ms(attempt, 0);
            let lo = step / 2;
            assert!(w >= lo && w <= step, "attempt {attempt}: {w} not in [{lo}, {step}]");
        }
        // The server's hint is a floor even when the computed step is tiny.
        assert!(c.backoff_ms(0, 40) >= 40);
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let mk = || {
            NetClient::connect(addr, ClientConfig { seed: 99, ..ClientConfig::default() })
                .expect("connect")
        };
        let mut a = mk();
        let _sa = listener.accept().expect("accept");
        let mut b = mk();
        let _sb = listener.accept().expect("accept");
        let seq_a: Vec<u64> = (0..6).map(|i| a.backoff_ms(i, 0)).collect();
        let seq_b: Vec<u64> = (0..6).map(|i| b.backoff_ms(i, 0)).collect();
        assert_eq!(seq_a, seq_b);
    }
}
