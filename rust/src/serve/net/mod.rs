//! TCP ingest front-end: the paper's AER bus stretched over a socket.
//!
//! A camera connects, announces itself, streams AER batches, and the
//! server maps that connection lifecycle 1:1 onto the
//! [`SessionManager`](crate::serve::SessionManager) lifecycle:
//!
//! ```text
//!   connect ──► HELLO ──► open          BATCH ──► ingest_batch
//!   SNAPSHOT_REQ ──► snapshot           BYE / any fault ──► drain + close
//! ```
//!
//! ## Wire format
//!
//! Every frame is `kind (u8) | len (u32 LE) | crc32 (u32 LE) | payload`,
//! where `len` counts payload bytes and the CRC (CRC-32/ISO-HDLC)
//! covers the payload only. Client→server kinds sit below `0x80`,
//! server→client kinds at or above it:
//!
//! | kind | dir | payload |
//! |---|---|---|
//! | `HELLO` `0x01` | → | `w u16 \| h u16 \| t_end u64 \| window u64 \| batch u32 \| n_shards u32 \| denoise u32 \| stcf u8 \| name utf8` |
//! | `BATCH` `0x02` | → | `seq u32 \| AER records` ([`crate::events::aer`]: varint Δt, `x u16`, `y u16`, `p u8`; Δ-base resets to 0 per frame, so each BATCH carries absolute times) |
//! | `SNAPSHOT_REQ` `0x03` | → | `at_us u64` |
//! | `BYE` `0x04` | → | empty |
//! | `STATS_REQ` `0x05` | → | empty (allowed before HELLO — operators scrape sessionless) |
//! | `ACK` `0x81` | ← | `seq u32` (HELLO is acked with seq 0) |
//! | `NACK` `0x82` | ← | `code u16 \| retry_after_ms u32 \| seq u32 \| reason utf8` |
//! | `FRAME` `0x83` | ← | `at_us u64 \| w u16 \| h u16 \| flags u8 \| w·h f64 LE` (bit-lossless; [`frame::flag::STALE`] marks a degraded snapshot) |
//! | `BYE_OK` `0x84` | ← | `frames_emitted u64` |
//! | `STATS` `0x85` | ← | Prometheus-style text scrape, UTF-8 (the same body `--metrics` serves over HTTP — see [`crate::serve::obs`]) |
//!
//! NACK codes 1–9 are [`Reject::code`](crate::serve::Reject::code)
//! values straight from admission control (1–3 classic admission, 4
//! overloaded-shed, 5 quarantined); codes ≥ 10 are net-layer faults
//! ([`frame::code`]). BATCH payloads are decoded *incrementally*
//! ([`crate::events::aer::AerDecoder`]): a frame split across socket
//! reads feeds the running CRC and decoder chunk by chunk — never
//! copied into a contiguous buffer, never re-parsed.
//!
//! ## Robustness contract
//!
//! * Every read and write is deadline-bounded ([`deadline`]); the
//!   `net-deadline` xtask lint keeps it that way.
//! * Recoverable faults cost a strike against a per-connection error
//!   budget; the budget trips into a `BUDGET` NACK and teardown.
//! * Overload sheds whole connections (accept cap, `TooManySessions` at
//!   HELLO) before degrading any admitted session.
//! * Teardown — graceful or not — always `drain`s then `close`s a live
//!   session, so every acked batch reaches the band writers. The chaos
//!   test (`tests/net_chaos.rs`, seeded via `TSISC_CHAOS_SEED`) holds a
//!   mixed clean+faulty fleet to exactly this contract, and
//!   [`NetStats`](crate::serve::NetStats) counts every fault by type.

mod client;
mod conn;
mod deadline;
pub mod faults;
pub mod frame;
mod server;

pub use client::{ClientConfig, NetClient, NetError};
pub use deadline::{DeadlineStream, PolledRead};
pub use frame::Hello;
pub use server::{NetConfig, NetServer};
