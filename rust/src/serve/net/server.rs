//! The TCP listener: accept, shed, spawn, and drain-on-shutdown.
//!
//! Graceful degradation is strictly outside-in: when the fleet is busy
//! the listener sheds *whole connections* at accept time (a `SHED` NACK
//! before the client even says HELLO) and session admission refuses
//! HELLOs with `TooManySessions` — admitted sessions are never degraded
//! to make room. [`NetServer::shutdown`] reverses the order: stop
//! accepting, signal every live handler, and let each drain its session
//! through `drain`/`close` so no acknowledged batch is ever lost.

use super::conn::{self, ConnCtx, SharedManager};
use super::deadline::DeadlineStream;
use super::frame::{self, code, kind, Nack};
use crate::serve::session::{ServeConfig, SessionManager};
use crate::serve::stats::{NetStats, ServeStats};
use crate::util::sync::thread::{spawn, JoinHandle};
use crate::util::sync::{Arc, AtomicU64, AtomicUsize, Mutex, Ordering};
use std::io;
use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

/// Front-door configuration (wraps the fleet's [`ServeConfig`]).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// The fleet the listener fronts.
    pub serve: ServeConfig,
    /// Overall deadline for one payload read window. A peer that stalls
    /// mid-frame longer than this is disconnected (and drained).
    pub read_timeout: Duration,
    /// Deadline for the *next frame header* to arrive — how long a
    /// connection may sit idle between frames.
    pub idle_timeout: Duration,
    /// Deadline for socket writes (a reply-ignoring peer cannot wedge a
    /// handler thread).
    pub write_timeout: Duration,
    /// Recoverable protocol faults tolerated per connection before a
    /// `BUDGET` NACK and teardown.
    pub error_budget: u32,
    /// Connection cap: accepts past this are shed whole (before HELLO).
    pub max_connections: usize,
    /// Largest acceptable frame payload; bigger headers are treated as
    /// garbage (unrecoverable).
    pub max_frame_bytes: usize,
    /// Retry-after hint attached to backpressure/admission NACKs, ms.
    pub retry_after_ms: u32,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            serve: ServeConfig::default(),
            read_timeout: Duration::from_secs(2),
            idle_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(2),
            error_budget: 3,
            max_connections: 64,
            max_frame_bytes: 16 << 20,
            retry_after_ms: 2,
        }
    }
}

/// Live counters shared by the listener and every connection handler.
/// Snapshot with [`NetCounters::snapshot`]; field meanings mirror
/// [`NetStats`] one-to-one.
#[derive(Default)]
pub(crate) struct NetCounters {
    pub(crate) connections_accepted: AtomicU64,
    pub(crate) connections_shed: AtomicU64,
    pub(crate) hellos_rejected: AtomicU64,
    pub(crate) sessions_opened: AtomicU64,
    pub(crate) batches_acked: AtomicU64,
    pub(crate) events_ingested: AtomicU64,
    pub(crate) frames_sent: AtomicU64,
    pub(crate) nacks_sent: AtomicU64,
    pub(crate) bad_frames: AtomicU64,
    pub(crate) checksum_errors: AtomicU64,
    pub(crate) decode_errors: AtomicU64,
    pub(crate) protocol_errors: AtomicU64,
    pub(crate) duplicate_batches: AtomicU64,
    pub(crate) backpressure_nacks: AtomicU64,
    pub(crate) deadline_disconnects: AtomicU64,
    pub(crate) budget_disconnects: AtomicU64,
    pub(crate) abrupt_disconnects: AtomicU64,
    pub(crate) sessions_drained_on_error: AtomicU64,
    pub(crate) drain_accounting_mismatches: AtomicU64,
    pub(crate) handler_panics: AtomicU64,
    pub(crate) byes_completed: AtomicU64,
}

impl NetCounters {
    pub(crate) fn snapshot(&self) -> NetStats {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        NetStats {
            connections_accepted: g(&self.connections_accepted),
            connections_shed: g(&self.connections_shed),
            hellos_rejected: g(&self.hellos_rejected),
            sessions_opened: g(&self.sessions_opened),
            batches_acked: g(&self.batches_acked),
            events_ingested: g(&self.events_ingested),
            frames_sent: g(&self.frames_sent),
            nacks_sent: g(&self.nacks_sent),
            bad_frames: g(&self.bad_frames),
            checksum_errors: g(&self.checksum_errors),
            decode_errors: g(&self.decode_errors),
            protocol_errors: g(&self.protocol_errors),
            duplicate_batches: g(&self.duplicate_batches),
            backpressure_nacks: g(&self.backpressure_nacks),
            deadline_disconnects: g(&self.deadline_disconnects),
            budget_disconnects: g(&self.budget_disconnects),
            abrupt_disconnects: g(&self.abrupt_disconnects),
            sessions_drained_on_error: g(&self.sessions_drained_on_error),
            drain_accounting_mismatches: g(&self.drain_accounting_mismatches),
            handler_panics: g(&self.handler_panics),
            byes_completed: g(&self.byes_completed),
        }
    }
}

/// A running TCP front door over one [`SessionManager`] fleet.
pub struct NetServer {
    local_addr: SocketAddr,
    manager: SharedManager,
    counters: Arc<NetCounters>,
    shutdown: Arc<AtomicUsize>,
    accept_handle: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral loopback port)
    /// and start accepting connections over a fresh fleet.
    pub fn bind(addr: &str, cfg: NetConfig) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        // The accept loop polls so a shutdown flag can stop it; handlers
        // use blocking reads with deadlines.
        listener.set_nonblocking(true)?;
        let manager: SharedManager =
            Arc::new(Mutex::new(SessionManager::new(cfg.serve.clone())));
        let counters = Arc::new(NetCounters::default());
        let shutdown = Arc::new(AtomicUsize::new(0));
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let live = Arc::new(AtomicUsize::new(0));

        let accept_handle = {
            let manager = manager.clone();
            let counters = counters.clone();
            let shutdown = shutdown.clone();
            let handlers = handlers.clone();
            spawn(move || {
                accept_loop(listener, cfg, manager, counters, shutdown, handlers, live)
            })
        };
        Ok(NetServer {
            local_addr,
            manager,
            counters,
            shutdown,
            accept_handle: Some(accept_handle),
            handlers,
        })
    }

    /// The bound address (resolves ephemeral ports for loopback tests).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Fleet statistics with the net counters filled in.
    pub fn stats(&self) -> ServeStats {
        let mut stats =
            self.manager.lock().expect("session manager lock poisoned").stats();
        stats.net = self.counters.snapshot();
        stats
    }

    /// Graceful shutdown: stop accepting, signal every handler, wait for
    /// each to drain + close its session, then shut the fleet down.
    /// Returns the final statistics (net counters included).
    pub fn shutdown(mut self) -> ServeStats {
        self.shutdown.store(1, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            if h.join().is_err() {
                self.counters.handler_panics.fetch_add(1, Ordering::Relaxed);
            }
        }
        let handlers = {
            let mut guard = self.handlers.lock().expect("handler list lock poisoned");
            std::mem::take(&mut *guard)
        };
        for h in handlers {
            if h.join().is_err() {
                self.counters.handler_panics.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Every handler has drained its own session; anything left (a
        // refused or panicked handler's session) is closed by the fleet
        // shutdown. All Arc clones live in the joined threads, so the
        // unwrap succeeds; the fallback degrades to a live snapshot.
        let mut stats = match Arc::try_unwrap(self.manager) {
            Ok(m) => m.into_inner().expect("session manager lock poisoned").shutdown(),
            Err(arc) => arc.lock().expect("session manager lock poisoned").stats(),
        };
        stats.net = self.counters.snapshot();
        stats
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    cfg: NetConfig,
    manager: SharedManager,
    counters: Arc<NetCounters>,
    shutdown: Arc<AtomicUsize>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    live: Arc<AtomicUsize>,
) {
    loop {
        if shutdown.load(Ordering::SeqCst) != 0 {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if live.load(Ordering::SeqCst) >= cfg.max_connections {
                    counters.connections_shed.fetch_add(1, Ordering::Relaxed);
                    counters.nacks_sent.fetch_add(1, Ordering::Relaxed);
                    shed(stream, &cfg);
                    continue;
                }
                counters.connections_accepted.fetch_add(1, Ordering::Relaxed);
                live.fetch_add(1, Ordering::SeqCst);
                let ctx = ConnCtx {
                    manager: manager.clone(),
                    cfg: cfg.clone(),
                    counters: counters.clone(),
                    shutdown: shutdown.clone(),
                };
                let live = live.clone();
                let handle = spawn(move || {
                    let _guard = LiveGuard(live);
                    conn::handle(stream, ctx);
                });
                handlers.lock().expect("handler list lock poisoned").push(handle);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Decrements the live-connection gauge even if the handler panics.
struct LiveGuard(Arc<AtomicUsize>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Shed a connection before HELLO: best-effort `SHED` NACK, then close.
/// Whole-connection shedding is the overload policy — admitted sessions
/// keep their service level; newcomers are turned away at the door.
fn shed(stream: std::net::TcpStream, cfg: &NetConfig) {
    let Ok(mut dl) = DeadlineStream::new(stream, cfg.write_timeout) else { return };
    let nack = Nack {
        code: code::SHED,
        retry_after_ms: cfg.retry_after_ms,
        seq: 0,
        reason: format!("listener at connection cap {}; retry later", cfg.max_connections),
    };
    let mut payload = Vec::new();
    nack.encode(&mut payload);
    let mut buf = Vec::new();
    frame::encode_frame_into(&mut buf, kind::NACK, &payload);
    let _ = dl.write_all_within(&buf);
    let _ = dl.shutdown_now();
}
