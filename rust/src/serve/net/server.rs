//! The TCP listener: accept, shed, spawn, and drain-on-shutdown.
//!
//! Graceful degradation is strictly outside-in: when the fleet is busy
//! the listener sheds *whole connections* at accept time (a `SHED` NACK
//! before the client even says HELLO) and session admission refuses
//! HELLOs with `TooManySessions` — admitted sessions are never degraded
//! to make room. [`NetServer::shutdown`] reverses the order: stop
//! accepting, signal every live handler, and let each drain its session
//! through `drain`/`close` so no acknowledged batch is ever lost.

use super::conn::{self, ConnCtx, SharedManager};
use super::deadline::DeadlineStream;
use super::frame::{self, code, kind, Nack};
use crate::serve::obs::{FleetObs, MetricsServer, ObsJsonWriter};
use crate::serve::session::{ServeConfig, SessionManager};
use crate::serve::stats::{NetStats, ServeStats};
use crate::util::sync::thread::{spawn, JoinHandle};
use crate::util::sync::{Arc, AtomicUsize, Mutex, Ordering};
use crate::util::telemetry::{Counter, Registry};
use std::io;
use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

/// Front-door configuration (wraps the fleet's [`ServeConfig`]).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// The fleet the listener fronts.
    pub serve: ServeConfig,
    /// Overall deadline for one payload read window. A peer that stalls
    /// mid-frame longer than this is disconnected (and drained).
    pub read_timeout: Duration,
    /// Deadline for the *next frame header* to arrive — how long a
    /// connection may sit idle between frames.
    pub idle_timeout: Duration,
    /// Deadline for socket writes (a reply-ignoring peer cannot wedge a
    /// handler thread).
    pub write_timeout: Duration,
    /// Recoverable protocol faults tolerated per connection before a
    /// `BUDGET` NACK and teardown.
    pub error_budget: u32,
    /// Connection cap: accepts past this are shed whole (before HELLO).
    pub max_connections: usize,
    /// Largest acceptable frame payload; bigger headers are treated as
    /// garbage (unrecoverable).
    pub max_frame_bytes: usize,
    /// Retry-after hint attached to backpressure/admission NACKs, ms.
    pub retry_after_ms: u32,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            serve: ServeConfig::default(),
            read_timeout: Duration::from_secs(2),
            idle_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(2),
            error_budget: 3,
            max_connections: 64,
            max_frame_bytes: 16 << 20,
            retry_after_ms: 2,
        }
    }
}

/// Live counters shared by the listener and every connection handler.
/// Snapshot with [`NetCounters::snapshot`]; field meanings mirror
/// [`NetStats`] one-to-one. Registered on the fleet's metric registry
/// (as `net_*_total`) so one scrape covers the front door too; the
/// counters stay functional state (error budgets, chaos accounting)
/// and are never compiled out.
pub(crate) struct NetCounters {
    pub(crate) connections_accepted: Arc<Counter>,
    pub(crate) connections_shed: Arc<Counter>,
    pub(crate) hellos_rejected: Arc<Counter>,
    pub(crate) sessions_opened: Arc<Counter>,
    pub(crate) batches_acked: Arc<Counter>,
    pub(crate) events_ingested: Arc<Counter>,
    pub(crate) frames_sent: Arc<Counter>,
    pub(crate) nacks_sent: Arc<Counter>,
    pub(crate) bad_frames: Arc<Counter>,
    pub(crate) checksum_errors: Arc<Counter>,
    pub(crate) decode_errors: Arc<Counter>,
    pub(crate) protocol_errors: Arc<Counter>,
    pub(crate) duplicate_batches: Arc<Counter>,
    pub(crate) backpressure_nacks: Arc<Counter>,
    pub(crate) deadline_disconnects: Arc<Counter>,
    pub(crate) budget_disconnects: Arc<Counter>,
    pub(crate) abrupt_disconnects: Arc<Counter>,
    pub(crate) sessions_drained_on_error: Arc<Counter>,
    pub(crate) drain_accounting_mismatches: Arc<Counter>,
    pub(crate) handler_panics: Arc<Counter>,
    pub(crate) byes_completed: Arc<Counter>,
}

impl NetCounters {
    /// Register every front-door counter on `reg` (idempotent by name).
    pub(crate) fn registered(reg: &Registry) -> Self {
        Self {
            connections_accepted: reg.counter("net_connections_accepted_total"),
            connections_shed: reg.counter("net_connections_shed_total"),
            hellos_rejected: reg.counter("net_hellos_rejected_total"),
            sessions_opened: reg.counter("net_sessions_opened_total"),
            batches_acked: reg.counter("net_batches_acked_total"),
            events_ingested: reg.counter("net_events_ingested_total"),
            frames_sent: reg.counter("net_frames_sent_total"),
            nacks_sent: reg.counter("net_nacks_sent_total"),
            bad_frames: reg.counter("net_bad_frames_total"),
            checksum_errors: reg.counter("net_checksum_errors_total"),
            decode_errors: reg.counter("net_decode_errors_total"),
            protocol_errors: reg.counter("net_protocol_errors_total"),
            duplicate_batches: reg.counter("net_duplicate_batches_total"),
            backpressure_nacks: reg.counter("net_backpressure_nacks_total"),
            deadline_disconnects: reg.counter("net_deadline_disconnects_total"),
            budget_disconnects: reg.counter("net_budget_disconnects_total"),
            abrupt_disconnects: reg.counter("net_abrupt_disconnects_total"),
            sessions_drained_on_error: reg.counter("net_sessions_drained_on_error_total"),
            drain_accounting_mismatches: reg.counter("net_drain_accounting_mismatches_total"),
            handler_panics: reg.counter("net_handler_panics_total"),
            byes_completed: reg.counter("net_byes_completed_total"),
        }
    }

    pub(crate) fn snapshot(&self) -> NetStats {
        let g = |c: &Counter| c.get();
        NetStats {
            connections_accepted: g(&self.connections_accepted),
            connections_shed: g(&self.connections_shed),
            hellos_rejected: g(&self.hellos_rejected),
            sessions_opened: g(&self.sessions_opened),
            batches_acked: g(&self.batches_acked),
            events_ingested: g(&self.events_ingested),
            frames_sent: g(&self.frames_sent),
            nacks_sent: g(&self.nacks_sent),
            bad_frames: g(&self.bad_frames),
            checksum_errors: g(&self.checksum_errors),
            decode_errors: g(&self.decode_errors),
            protocol_errors: g(&self.protocol_errors),
            duplicate_batches: g(&self.duplicate_batches),
            backpressure_nacks: g(&self.backpressure_nacks),
            deadline_disconnects: g(&self.deadline_disconnects),
            budget_disconnects: g(&self.budget_disconnects),
            abrupt_disconnects: g(&self.abrupt_disconnects),
            sessions_drained_on_error: g(&self.sessions_drained_on_error),
            drain_accounting_mismatches: g(&self.drain_accounting_mismatches),
            handler_panics: g(&self.handler_panics),
            byes_completed: g(&self.byes_completed),
        }
    }
}

/// A running TCP front door over one [`SessionManager`] fleet.
pub struct NetServer {
    local_addr: SocketAddr,
    manager: SharedManager,
    counters: Arc<NetCounters>,
    shutdown: Arc<AtomicUsize>,
    accept_handle: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral loopback port)
    /// and start accepting connections over a fresh fleet.
    pub fn bind(addr: &str, cfg: NetConfig) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        // The accept loop polls so a shutdown flag can stop it; handlers
        // use blocking reads with deadlines.
        listener.set_nonblocking(true)?;
        let sm = SessionManager::new(cfg.serve.clone());
        // Front-door counters live on the fleet's registry so one
        // scrape covers the whole stack.
        let counters = Arc::new(NetCounters::registered(&sm.obs().registry));
        let obs = sm.obs().clone();
        let manager: SharedManager = Arc::new(Mutex::new(sm));
        let shutdown = Arc::new(AtomicUsize::new(0));
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let live = Arc::new(AtomicUsize::new(0));

        let accept_handle = {
            let manager = manager.clone();
            let counters = counters.clone();
            let shutdown = shutdown.clone();
            let handlers = handlers.clone();
            spawn(move || {
                accept_loop(listener, cfg, manager, obs, counters, shutdown, handlers, live)
            })
        };
        Ok(NetServer {
            local_addr,
            manager,
            counters,
            shutdown,
            accept_handle: Some(accept_handle),
            handlers,
        })
    }

    /// The bound address (resolves ephemeral ports for loopback tests).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Fleet statistics with the net counters filled in.
    pub fn stats(&self) -> ServeStats {
        let mut stats =
            self.manager.lock().expect("session manager lock poisoned").stats();
        stats.net = self.counters.snapshot();
        stats
    }

    /// The fleet scrape body (Prometheus-style text) — the same string
    /// the wire `STATS` reply carries. Net counters are included: they
    /// are registered on the fleet's registry at bind.
    pub fn metrics_text(&self) -> String {
        self.manager.lock().expect("session manager lock poisoned").metrics_text()
    }

    /// Serve the fleet scrape over HTTP at `addr` (`tsisc serve
    /// --metrics ADDR`). The returned [`MetricsServer`] stops serving
    /// when dropped; scrapes lock the manager only long enough to
    /// render.
    pub fn spawn_metrics(&self, addr: &str) -> io::Result<MetricsServer> {
        let manager = self.manager.clone();
        MetricsServer::spawn(addr, move || {
            manager.lock().expect("session manager lock poisoned").metrics_text()
        })
    }

    /// The fleet's observability plane (stage histograms + the metric
    /// registry the scrape renders from).
    pub fn obs(&self) -> Arc<FleetObs> {
        self.manager.lock().expect("session manager lock poisoned").obs().clone()
    }

    /// Tick the periodic JSON snapshot writer (`tsisc serve
    /// --json-stats PATH`) against the live fleet; returns whether a
    /// snapshot was actually written this tick.
    pub fn tick_json(&self, writer: &mut ObsJsonWriter) -> bool {
        let stats = self.stats();
        let obs =
            self.manager.lock().expect("session manager lock poisoned").obs().clone();
        writer.maybe_write(&obs, &stats)
    }

    /// Graceful shutdown: stop accepting, signal every handler, wait for
    /// each to drain + close its session, then shut the fleet down.
    /// Returns the final statistics (net counters included).
    pub fn shutdown(mut self) -> ServeStats {
        self.shutdown.store(1, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            if h.join().is_err() {
                self.counters.handler_panics.inc();
            }
        }
        let handlers = {
            let mut guard = self.handlers.lock().expect("handler list lock poisoned");
            std::mem::take(&mut *guard)
        };
        for h in handlers {
            if h.join().is_err() {
                self.counters.handler_panics.inc();
            }
        }
        // Every handler has drained its own session; anything left (a
        // refused or panicked handler's session) is closed by the fleet
        // shutdown. All Arc clones live in the joined threads, so the
        // unwrap succeeds; the fallback degrades to a live snapshot.
        let mut stats = match Arc::try_unwrap(self.manager) {
            Ok(m) => m.into_inner().expect("session manager lock poisoned").shutdown(),
            Err(arc) => arc.lock().expect("session manager lock poisoned").stats(),
        };
        stats.net = self.counters.snapshot();
        stats
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    cfg: NetConfig,
    manager: SharedManager,
    obs: Arc<FleetObs>,
    counters: Arc<NetCounters>,
    shutdown: Arc<AtomicUsize>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    live: Arc<AtomicUsize>,
) {
    loop {
        if shutdown.load(Ordering::SeqCst) != 0 {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if live.load(Ordering::SeqCst) >= cfg.max_connections {
                    counters.connections_shed.inc();
                    counters.nacks_sent.inc();
                    shed(stream, &cfg);
                    continue;
                }
                counters.connections_accepted.inc();
                live.fetch_add(1, Ordering::SeqCst);
                let ctx = ConnCtx {
                    manager: manager.clone(),
                    cfg: cfg.clone(),
                    counters: counters.clone(),
                    obs: obs.clone(),
                    shutdown: shutdown.clone(),
                };
                let live = live.clone();
                let handle = spawn(move || {
                    let _guard = LiveGuard(live);
                    conn::handle(stream, ctx);
                });
                handlers.lock().expect("handler list lock poisoned").push(handle);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Decrements the live-connection gauge even if the handler panics.
struct LiveGuard(Arc<AtomicUsize>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Shed a connection before HELLO: best-effort `SHED` NACK, then close.
/// Whole-connection shedding is the overload policy — admitted sessions
/// keep their service level; newcomers are turned away at the door.
fn shed(stream: std::net::TcpStream, cfg: &NetConfig) {
    let Ok(mut dl) = DeadlineStream::new(stream, cfg.write_timeout) else { return };
    let nack = Nack {
        code: code::SHED,
        retry_after_ms: cfg.retry_after_ms,
        seq: 0,
        reason: format!("listener at connection cap {}; retry later", cfg.max_connections),
    };
    let mut payload = Vec::new();
    nack.encode(&mut payload);
    let mut buf = Vec::new();
    frame::encode_frame_into(&mut buf, kind::NACK, &payload);
    let _ = dl.write_all_within(&buf);
    let _ = dl.shutdown_now();
}
