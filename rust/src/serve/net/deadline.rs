//! Deadline-bounded socket I/O — the *only* place in `serve::net` that
//! touches a raw stream.
//!
//! Every read and write in the front door happens under a configured
//! timeout: [`DeadlineStream`] wraps a `TcpStream`, forces it blocking,
//! installs `SO_RCVTIMEO`/`SO_SNDTIMEO`, and exposes
//! [`read_exact_within`](DeadlineStream::read_exact_within) /
//! [`write_all_within`](DeadlineStream::write_all_within), which enforce
//! an *overall* per-call deadline (a peer trickling one byte per
//! timeout-minus-ε cannot hold a connection open indefinitely — the
//! classic slow-loris hole a bare per-`read` timeout leaves). The
//! `net-deadline` invariant lint (`cargo xtask lint-invariants`) rejects
//! any bare `.read_exact(` / `.write_all(` / … call elsewhere under
//! `serve/net/`, so new code cannot reintroduce an unbounded wait.

use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// Floor for socket timeouts: `set_read_timeout(Some(0))` is an error
/// and a sub-millisecond timeout is indistinguishable from busy-wait.
const MIN_TIMEOUT: Duration = Duration::from_millis(1);

/// How a [`DeadlineStream::read_exact_polled`] call ended short of an
/// error: buffer filled, or the stop predicate fired before any byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolledRead {
    /// The buffer was filled completely.
    Filled,
    /// `should_stop` returned true before the first byte arrived.
    Stopped,
}

/// A `TcpStream` whose every operation carries a deadline.
#[derive(Debug)]
pub struct DeadlineStream {
    stream: TcpStream,
    write_timeout: Duration,
    /// Last timeout installed via `SO_RCVTIMEO`, to skip redundant
    /// setsockopt syscalls on the hot read path.
    last_read_timeout: Option<Duration>,
}

impl DeadlineStream {
    /// Wrap `stream`, forcing blocking mode and installing the write
    /// timeout. Reads take their budget per call.
    pub fn new(stream: TcpStream, write_timeout: Duration) -> io::Result<Self> {
        let write_timeout = write_timeout.max(MIN_TIMEOUT);
        stream.set_nonblocking(false)?;
        stream.set_write_timeout(Some(write_timeout))?;
        stream.set_nodelay(true)?;
        Ok(Self { stream, write_timeout, last_read_timeout: None })
    }

    /// The peer's address.
    pub fn peer_addr(&self) -> io::Result<SocketAddr> {
        self.stream.peer_addr()
    }

    /// Fill `buf` completely, or fail within `timeout` overall.
    ///
    /// The remaining budget is re-installed as the socket timeout before
    /// each underlying read, so total wall time is bounded by `timeout`
    /// no matter how the peer paces its bytes. `TimedOut` means the
    /// deadline expired; `UnexpectedEof` means the peer closed mid-buffer
    /// (EOF before the first byte is also `UnexpectedEof` with an empty
    /// `buf` position — callers distinguish idle-EOF by asking for the
    /// header first).
    pub fn read_exact_within(&mut self, buf: &mut [u8], timeout: Duration) -> io::Result<()> {
        let deadline = Instant::now() + timeout.max(MIN_TIMEOUT);
        let mut filled = 0usize;
        while filled < buf.len() {
            let now = Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| *d > Duration::ZERO)
            else {
                return Err(io::Error::new(io::ErrorKind::TimedOut, "read deadline expired"));
            };
            self.set_read_window(left.max(MIN_TIMEOUT))?;
            match self.stream.read(&mut buf[filled..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "peer closed the connection",
                    ));
                }
                Ok(n) => filled += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    return Err(io::Error::new(io::ErrorKind::TimedOut, "read deadline expired"));
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Like [`read_exact_within`](Self::read_exact_within), but wakes
    /// every `tick` to consult `should_stop` — partial progress is kept
    /// across ticks, so a frame header split over several wake-ups still
    /// reassembles. Once the first byte has arrived the stop predicate
    /// is ignored (the peer is mid-frame; the overall deadline still
    /// bounds the wait). This is how connection handlers notice server
    /// shutdown without abandoning a half-read frame.
    pub fn read_exact_polled(
        &mut self,
        buf: &mut [u8],
        timeout: Duration,
        tick: Duration,
        mut should_stop: impl FnMut() -> bool,
    ) -> io::Result<PolledRead> {
        let deadline = Instant::now() + timeout.max(MIN_TIMEOUT);
        let tick = tick.max(MIN_TIMEOUT);
        let mut filled = 0usize;
        while filled < buf.len() {
            let now = Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| *d > Duration::ZERO)
            else {
                return Err(io::Error::new(io::ErrorKind::TimedOut, "read deadline expired"));
            };
            self.set_read_window(left.min(tick).max(MIN_TIMEOUT))?;
            match self.stream.read(&mut buf[filled..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "peer closed the connection",
                    ));
                }
                Ok(n) => filled += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    // One tick with no data: the predicate is consulted
                    // only here, so bytes already buffered are never
                    // abandoned in favor of stopping.
                    if filled == 0 && should_stop() {
                        return Ok(PolledRead::Stopped);
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Ok(PolledRead::Filled)
    }

    /// Write all of `buf` under the configured write timeout (installed
    /// at construction; a stalled peer surfaces as `TimedOut`, never an
    /// indefinite block).
    pub fn write_all_within(&mut self, buf: &[u8]) -> io::Result<()> {
        let deadline = Instant::now() + self.write_timeout;
        let mut written = 0usize;
        while written < buf.len() {
            if Instant::now() >= deadline {
                return Err(io::Error::new(io::ErrorKind::TimedOut, "write deadline expired"));
            }
            match self.stream.write(&buf[written..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "peer stopped accepting bytes",
                    ));
                }
                Ok(n) => written += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    return Err(io::Error::new(io::ErrorKind::TimedOut, "write deadline expired"));
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Half-close both directions — the fault injector's abrupt
    /// disconnect, and the server's final word to a shed connection.
    pub fn shutdown_now(&self) -> io::Result<()> {
        self.stream.shutdown(Shutdown::Both)
    }

    fn set_read_window(&mut self, timeout: Duration) -> io::Result<()> {
        // Re-arming SO_RCVTIMEO only when the remaining budget moved by
        // ≥ 1/8 keeps the syscall off the per-chunk fast path.
        if let Some(last) = self.last_read_timeout {
            let delta = if last > timeout { last - timeout } else { timeout - last };
            if delta * 8 < last {
                return Ok(());
            }
        }
        self.stream.set_read_timeout(Some(timeout))?;
        self.last_read_timeout = Some(timeout);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (DeadlineStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr");
        let client = TcpStream::connect(addr).expect("connect");
        let (server, _) = listener.accept().expect("accept");
        (DeadlineStream::new(server, Duration::from_millis(500)).expect("wrap"), client)
    }

    #[test]
    fn read_times_out_on_silent_peer() {
        let (mut dl, _client) = pair();
        let mut buf = [0u8; 4];
        let t0 = Instant::now();
        let err = dl.read_exact_within(&mut buf, Duration::from_millis(60)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline must actually bound the wait");
    }

    #[test]
    fn read_times_out_on_trickling_peer() {
        // One byte up front, then silence: the overall deadline still
        // fires even though the first read made progress.
        let (mut dl, mut client) = pair();
        client.write_all(&[1]).unwrap();
        let mut buf = [0u8; 8];
        let err = dl.read_exact_within(&mut buf, Duration::from_millis(80)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
    }

    #[test]
    fn read_reports_eof_as_unexpected_eof() {
        let (mut dl, client) = pair();
        drop(client);
        let mut buf = [0u8; 4];
        let err = dl.read_exact_within(&mut buf, Duration::from_millis(200)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn polled_read_stops_fast_when_idle_but_finishes_a_started_header() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        // Idle stream + raised stop flag: returns Stopped well before the
        // overall deadline.
        let (mut dl, _client) = pair();
        let mut buf = [0u8; 9];
        let t0 = Instant::now();
        let got = dl
            .read_exact_polled(&mut buf, Duration::from_secs(30), Duration::from_millis(10), || {
                true
            })
            .unwrap();
        assert_eq!(got, PolledRead::Stopped);
        assert!(t0.elapsed() < Duration::from_secs(5));

        // Once bytes start flowing the predicate no longer aborts: the
        // header reassembles even though the flag flips mid-read.
        let (mut dl, mut client) = pair();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = stop.clone();
        let t = std::thread::spawn(move || {
            client.write_all(b"abcd").unwrap();
            stop_t.store(true, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(30));
            client.write_all(b"efghi").unwrap();
        });
        let mut buf = [0u8; 9];
        let got = dl
            .read_exact_polled(&mut buf, Duration::from_secs(5), Duration::from_millis(10), || {
                stop.load(Ordering::SeqCst)
            })
            .unwrap();
        assert_eq!(got, PolledRead::Filled);
        assert_eq!(&buf, b"abcdefghi");
        t.join().unwrap();
    }

    #[test]
    fn split_reads_reassemble() {
        let (mut dl, mut client) = pair();
        let t = std::thread::spawn(move || {
            client.write_all(b"abc").unwrap();
            std::thread::sleep(Duration::from_millis(20));
            client.write_all(b"defgh").unwrap();
        });
        let mut buf = [0u8; 8];
        dl.read_exact_within(&mut buf, Duration::from_secs(2)).unwrap();
        assert_eq!(&buf, b"abcdefgh");
        t.join().unwrap();
    }
}
