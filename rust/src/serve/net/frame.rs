//! Wire protocol: length-prefixed, checksummed frames.
//!
//! Every message on the socket is one frame — a fixed 9-byte header
//! (`kind: u8 | payload_len: u32 LE | crc32(payload): u32 LE`) followed
//! by the payload. The CRC makes *any* payload corruption land as a
//! typed [`code::BAD_CHECKSUM`] rejection even when the corrupted bytes
//! would still parse (a flipped coordinate bit is a valid coordinate);
//! the length prefix lets the server skip an unknown frame and resync.
//! The full frame table lives in the [`super`] module docs.
//!
//! BATCH payloads are `u32 seq` + AER records ([`crate::events::aer`],
//! timestamps absolute per frame — each BATCH encodes from Δ-base 0), so
//! the server can deduplicate client retries and decode incrementally
//! straight off the socket.

use crate::events::Resolution;
use crate::util::grid::Grid;

/// Frame header size on the wire: kind + payload length + payload CRC.
pub const HEADER_LEN: usize = 9;

/// Frame kinds. Client→server kinds have the top bit clear,
/// server→client kinds have it set.
pub mod kind {
    /// Client→server: open a session (payload: [`super::Hello`]).
    pub const HELLO: u8 = 0x01;
    /// Client→server: one event batch (`u32 seq` + AER records).
    pub const BATCH: u8 = 0x02;
    /// Client→server: on-demand frame request (`u64 at_us`).
    pub const SNAPSHOT_REQ: u8 = 0x03;
    /// Client→server: end of stream; drain and close my session.
    pub const BYE: u8 = 0x04;
    /// Client→server: one metrics scrape, please (empty payload).
    /// Allowed before HELLO — operators scrape without opening a
    /// session.
    pub const STATS_REQ: u8 = 0x05;
    /// Server→client: request `u32 seq` succeeded.
    pub const ACK: u8 = 0x81;
    /// Server→client: typed rejection (payload: [`super::Nack`]).
    pub const NACK: u8 = 0x82;
    /// Server→client: one rendered frame (`u64 at_us | u16 w | u16 h |
    /// u8 flags | w·h f64 LE pixels` — lossless, for bit-for-bit
    /// equivalence; see [`super::flag`] for the flags bits).
    pub const FRAME: u8 = 0x83;
    /// Server→client: BYE honored (`u64 frames_emitted` lifetime total).
    pub const BYE_OK: u8 = 0x84;
    /// Server→client: one Prometheus-style text scrape (UTF-8 payload —
    /// the same body `--metrics` serves over HTTP), answering
    /// [`STATS_REQ`].
    pub const STATS: u8 = 0x85;
}

/// Stable NACK codes. 1–9 mirror [`crate::serve::Reject::code`] (session
/// admission); 10+ are net-layer rejections. Wire-stable: never
/// renumber, only append.
pub mod code {
    /// [`crate::serve::Reject::TooManySessions`].
    pub const TOO_MANY_SESSIONS: u16 = 1;
    /// [`crate::serve::Reject::Backpressure`] — retry-after hint attached.
    pub const BACKPRESSURE: u16 = 2;
    /// [`crate::serve::Reject::UnknownSession`].
    pub const UNKNOWN_SESSION: u16 = 3;
    /// Malformed or oversized frame header.
    pub const BAD_FRAME: u16 = 10;
    /// Payload CRC mismatch.
    pub const BAD_CHECKSUM: u16 = 11;
    /// BATCH payload failed AER decoding (typed `AerError`).
    pub const DECODE: u16 = 12;
    /// Protocol-order violation (BATCH before HELLO, seq gap, …).
    pub const PROTOCOL: u16 = 13;
    /// Duplicate BATCH (seq already acknowledged); not re-ingested.
    pub const DUPLICATE: u16 = 14;
    /// A read/idle deadline expired; the connection is being dropped.
    pub const DEADLINE: u16 = 15;
    /// Listener at its connection cap — shed before HELLO.
    pub const SHED: u16 = 16;
    /// Decode-error budget exhausted; the connection is being dropped.
    pub const BUDGET: u16 = 17;
    /// [`crate::serve::Reject::Overloaded`] — fleet at the shed tier.
    pub const OVERLOADED: u16 = 4;
    /// [`crate::serve::Reject::Quarantined`] — session faulted; restore
    /// from a checkpoint to resume.
    pub const QUARANTINED: u16 = 5;
    /// BATCH timestamps went backwards relative to the session stream.
    pub const OUT_OF_ORDER: u16 = 18;
}

/// FRAME flag bits (the `u8 flags` field of a FRAME payload).
pub mod flag {
    /// At least one band of this frame was served from a stale cache
    /// under overload degradation (`DegradeTier::ServeStale`) instead of
    /// being rendered at `at_us`. Consumers choosing exactness over
    /// latency should re-request once the fleet pressure drops.
    pub const STALE: u8 = 0x01;
}

/// Errors raised while parsing a frame *payload* (the header and CRC
/// were already validated).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Payload shorter than its fixed fields require.
    Short,
    /// A string field was not valid UTF-8.
    BadUtf8,
    /// Fields are internally inconsistent (e.g. pixel count ≠ w·h).
    Inconsistent,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Short => write!(f, "frame payload too short"),
            WireError::BadUtf8 => write!(f, "frame string field is not UTF-8"),
            WireError::Inconsistent => write!(f, "frame payload fields inconsistent"),
        }
    }
}

impl std::error::Error for WireError {}

/// Parsed frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    /// Frame kind byte (see [`kind`]).
    pub kind: u8,
    /// Payload length in bytes.
    pub len: u32,
    /// CRC-32 (IEEE) of the payload.
    pub crc: u32,
}

impl Header {
    /// Parse the 9 wire bytes.
    pub fn parse(b: &[u8; HEADER_LEN]) -> Header {
        Header {
            kind: b[0],
            len: u32::from_le_bytes([b[1], b[2], b[3], b[4]]),
            crc: u32::from_le_bytes([b[5], b[6], b[7], b[8]]),
        }
    }
}

/// Serialize one frame (header + payload) into `out`, clearing it first
/// — callers keep one send buffer per connection, so the hot path does
/// no per-frame allocation once warm.
pub fn encode_frame_into(out: &mut Vec<u8>, kind: u8, payload: &[u8]) {
    out.clear();
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

// CRC-32/ISO-HDLC (the zlib/Ethernet polynomial), nibble-table variant:
// 16 entries keep the table in a cache line while still processing four
// bits per step.
const CRC_TABLE: [u32; 16] = [
    0x0000_0000, 0x1db7_1064, 0x3b6e_20c8, 0x26d9_30ac,
    0x76dc_4190, 0x6b6b_51f4, 0x4db2_6158, 0x5005_713c,
    0xedb8_8320, 0xf00f_9344, 0xd6d6_a3e8, 0xcb61_b38c,
    0x9b64_c2b0, 0x86d3_d2d4, 0xa00a_e278, 0xbdbd_f21c,
];

/// One-shot CRC-32 (IEEE reflected polynomial) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Streaming CRC-32, so the server can checksum a BATCH payload chunk by
/// chunk while the incremental AER decoder consumes the same chunks —
/// the payload is never materialized whole.
#[derive(Debug, Clone)]
pub struct Crc32(u32);

impl Crc32 {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Crc32(!0)
    }

    /// Fold `bytes` into the accumulator.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.0;
        for &b in bytes {
            c = (c >> 4) ^ CRC_TABLE[((c ^ b as u32) & 0xf) as usize];
            c = (c >> 4) ^ CRC_TABLE[((c ^ (b as u32 >> 4)) & 0xf) as usize];
        }
        self.0 = c;
    }

    /// Final CRC value.
    pub fn finish(&self) -> u32 {
        !self.0
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// HELLO payload: everything the server needs to build a
/// [`crate::serve::SessionConfig`]. The pipeline mapping lives in
/// [`Hello::pipeline_config`] and is shared by the server and the
/// equivalence tests, so "what the wire opens" and "what the test
/// compares against" can never drift apart.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    /// Session display name.
    pub name: String,
    /// Sensor geometry.
    pub width: u16,
    /// Sensor geometry.
    pub height: u16,
    /// Stream end time (window frames emitted through this).
    pub t_end_us: u64,
    /// Window period, µs.
    pub window_us: u64,
    /// Producer staging batch size.
    pub batch_size: u32,
    /// Router write shards.
    pub n_shards: u32,
    /// STCF shard count (0 = inline) — meaningful only with `stcf`.
    pub denoise_shards: u32,
    /// Enable the STCF denoise stage with default parameters.
    pub stcf: bool,
}

impl Hello {
    /// Serialize into `out` (cleared first).
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&self.width.to_le_bytes());
        out.extend_from_slice(&self.height.to_le_bytes());
        out.extend_from_slice(&self.t_end_us.to_le_bytes());
        out.extend_from_slice(&self.window_us.to_le_bytes());
        out.extend_from_slice(&self.batch_size.to_le_bytes());
        out.extend_from_slice(&self.n_shards.to_le_bytes());
        out.extend_from_slice(&self.denoise_shards.to_le_bytes());
        out.push(self.stcf as u8);
        out.extend_from_slice(self.name.as_bytes());
    }

    /// Parse a HELLO payload.
    pub fn decode(p: &[u8]) -> Result<Hello, WireError> {
        let mut r = Reader::new(p);
        let width = r.u16()?;
        let height = r.u16()?;
        let t_end_us = r.u64()?;
        let window_us = r.u64()?;
        let batch_size = r.u32()?;
        let n_shards = r.u32()?;
        let denoise_shards = r.u32()?;
        let stcf = r.u8()? != 0;
        let name = std::str::from_utf8(r.rest()).map_err(|_| WireError::BadUtf8)?.to_string();
        if width == 0 || height == 0 || window_us == 0 {
            return Err(WireError::Inconsistent);
        }
        Ok(Hello {
            name,
            width,
            height,
            t_end_us,
            window_us,
            batch_size,
            n_shards,
            denoise_shards,
            stcf,
        })
    }

    /// Sensor geometry as a [`Resolution`].
    pub fn resolution(&self) -> Resolution {
        Resolution::new(self.width, self.height)
    }

    /// The pipeline shape this HELLO opens — the *single* mapping used
    /// by both the server (to build the session) and the chaos test (to
    /// build the `pipeline::run` reference).
    pub fn pipeline_config(&self) -> crate::coordinator::PipelineConfig {
        crate::coordinator::PipelineConfig {
            window_us: self.window_us,
            stcf: self.stcf.then(crate::denoise::StcfParams::default),
            denoise_shards: self.denoise_shards as usize,
            batch_size: (self.batch_size as usize).max(1),
            clock_policy: crate::events::ClockPolicy::default(),
            router: crate::coordinator::RouterConfig {
                n_shards: (self.n_shards as usize).max(1),
                ..Default::default()
            },
        }
    }
}

/// NACK payload: a typed, coded rejection plus an operator-readable
/// reason (the `Display` of the underlying `Reject`/`AerError`, numbers
/// and all).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Nack {
    /// Stable rejection code (see [`code`]).
    pub code: u16,
    /// Backoff floor for retryable rejections (0 = not retryable or no
    /// hint).
    pub retry_after_ms: u32,
    /// The request seq this NACK answers (0 when not seq-addressed).
    pub seq: u32,
    /// Human-readable cause.
    pub reason: String,
}

impl Nack {
    /// Serialize into `out` (cleared first).
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&self.code.to_le_bytes());
        out.extend_from_slice(&self.retry_after_ms.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(self.reason.as_bytes());
    }

    /// Parse a NACK payload.
    pub fn decode(p: &[u8]) -> Result<Nack, WireError> {
        let mut r = Reader::new(p);
        let code = r.u16()?;
        let retry_after_ms = r.u32()?;
        let seq = r.u32()?;
        let reason = std::str::from_utf8(r.rest()).map_err(|_| WireError::BadUtf8)?.to_string();
        Ok(Nack { code, retry_after_ms, seq, reason })
    }
}

/// Serialize a FRAME payload (`at_us | w | h | flags | pixels`) into
/// `out` (cleared first). f64 bits go over verbatim — the wire is
/// lossless so clean sessions stay bit-for-bit ≡ the in-process
/// pipeline. `flags` carries the [`flag`] bits (window frames and
/// un-degraded snapshots send 0).
pub fn encode_frame_payload(out: &mut Vec<u8>, at_us: u64, frame: &Grid<f64>, flags: u8) {
    out.clear();
    out.extend_from_slice(&at_us.to_le_bytes());
    out.extend_from_slice(&(frame.width() as u16).to_le_bytes());
    out.extend_from_slice(&(frame.height() as u16).to_le_bytes());
    out.push(flags);
    for v in frame.as_slice() {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Parse a FRAME payload back into `(at_us, frame, flags)`.
pub fn decode_frame_payload(p: &[u8]) -> Result<(u64, Grid<f64>, u8), WireError> {
    let mut r = Reader::new(p);
    let at_us = r.u64()?;
    let w = r.u16()? as usize;
    let h = r.u16()? as usize;
    let flags = r.u8()?;
    let rest = r.rest();
    if rest.len() != w * h * 8 {
        return Err(WireError::Inconsistent);
    }
    let data: Vec<f64> = rest
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect();
    Ok((at_us, Grid::from_vec(w, h, data), flags))
}

/// Little-endian field reader over a payload slice.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Reader { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.b.len() {
            return Err(WireError::Short);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.b[self.pos..];
        self.pos = self.b.len();
        s
    }
}

/// Read a `u32` request seq off the front of a BATCH payload.
pub fn batch_seq(p: &[u8]) -> Result<u32, WireError> {
    Reader::new(p).u32()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::Reject;

    #[test]
    fn crc32_matches_reference_vector() {
        // The universal CRC-32/ISO-HDLC check value.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_streaming_equals_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        for split in [0usize, 1, 7, 500, 999, 1000] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), crc32(&data));
        }
    }

    #[test]
    fn header_roundtrip() {
        let payload = b"hello";
        let mut buf = Vec::new();
        encode_frame_into(&mut buf, kind::BATCH, payload);
        assert_eq!(buf.len(), HEADER_LEN + payload.len());
        let mut hdr = [0u8; HEADER_LEN];
        hdr.copy_from_slice(&buf[..HEADER_LEN]);
        let h = Header::parse(&hdr);
        assert_eq!(h.kind, kind::BATCH);
        assert_eq!(h.len as usize, payload.len());
        assert_eq!(h.crc, crc32(payload));
    }

    #[test]
    fn hello_roundtrip() {
        let hello = Hello {
            name: "cam-θ".into(),
            width: 320,
            height: 240,
            t_end_us: 1_000_000,
            window_us: 50_000,
            batch_size: 256,
            n_shards: 4,
            denoise_shards: 2,
            stcf: true,
        };
        let mut buf = Vec::new();
        hello.encode(&mut buf);
        assert_eq!(Hello::decode(&buf).unwrap(), hello);
        let cfg = hello.pipeline_config();
        assert_eq!(cfg.window_us, 50_000);
        assert!(cfg.stcf.is_some());
        assert_eq!(cfg.denoise_shards, 2);
        assert_eq!(cfg.router.n_shards, 4);
    }

    #[test]
    fn hello_rejects_degenerate_geometry() {
        let mut h = Hello {
            name: String::new(),
            width: 0,
            height: 4,
            t_end_us: 0,
            window_us: 1,
            batch_size: 1,
            n_shards: 1,
            denoise_shards: 0,
            stcf: false,
        };
        let mut buf = Vec::new();
        h.encode(&mut buf);
        assert_eq!(Hello::decode(&buf), Err(WireError::Inconsistent));
        h.width = 4;
        h.encode(&mut buf);
        assert!(Hello::decode(&buf).is_ok());
    }

    #[test]
    fn nack_roundtrips_reject_codes_and_numbers() {
        // Satellite: code → Reject → Display survives the wire intact,
        // including the depth/cap numbers PR 7 put in the messages.
        let rejects = [
            Reject::TooManySessions { open: 9, max: 16 },
            Reject::Backpressure { queued: 64, max: 64 },
            Reject::UnknownSession(5),
        ];
        for reject in rejects {
            let nack =
                Nack { code: reject.code(), retry_after_ms: 3, seq: 7, reason: reject.to_string() };
            let mut buf = Vec::new();
            nack.encode(&mut buf);
            let back = Nack::decode(&buf).unwrap();
            assert_eq!(back, nack);
            assert_eq!(back.code, reject.code());
            assert_eq!(back.reason, reject.to_string());
        }
        // The numbers really are in the reasons.
        let n = Nack {
            code: Reject::Backpressure { queued: 64, max: 64 }.code(),
            retry_after_ms: 0,
            seq: 0,
            reason: Reject::Backpressure { queued: 64, max: 64 }.to_string(),
        };
        assert_eq!(n.code, code::BACKPRESSURE);
        assert!(n.reason.contains("64 of 64"));
    }

    #[test]
    fn wire_frame_payload_roundtrip_is_lossless() {
        let mut g = Grid::new(3, 2, 0.0f64);
        for (i, v) in g.as_mut_slice().iter_mut().enumerate() {
            *v = (i as f64) * 0.731 + f64::EPSILON;
        }
        let mut buf = Vec::new();
        encode_frame_payload(&mut buf, 123_456, &g, 0);
        let (at, back, flags) = decode_frame_payload(&buf).unwrap();
        assert_eq!(at, 123_456);
        assert_eq!(back, g);
        assert_eq!(flags, 0);
        // Truncated pixel data is Inconsistent, not a panic.
        assert_eq!(decode_frame_payload(&buf[..buf.len() - 1]), Err(WireError::Inconsistent));
        // The staleness marker survives the wire.
        encode_frame_payload(&mut buf, 9, &g, flag::STALE);
        let (_, stale_back, stale_flags) = decode_frame_payload(&buf).unwrap();
        assert_eq!(stale_back, g);
        assert_eq!(stale_flags, flag::STALE);
    }

    #[test]
    fn batch_seq_reads_prefix() {
        let mut p = 77u32.to_le_bytes().to_vec();
        p.extend_from_slice(&[1, 2, 3]);
        assert_eq!(batch_seq(&p).unwrap(), 77);
        assert_eq!(batch_seq(&p[..3]), Err(WireError::Short));
    }
}
