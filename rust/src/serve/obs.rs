//! The fleet's observability plane: per-session stage tracing, the
//! quarantine flight recorder, and every export surface.
//!
//! Built on [`crate::util::telemetry`] (counters/gauges/histograms +
//! [`Registry`]); this module adds the serve-specific structure:
//!
//! * [`SessionObs`] — one per session, shared `Arc` between the session
//!   front half and its band actors on the worker pool. Holds the
//!   per-stage latency histograms (µs, log2 buckets) and the bounded
//!   [`FlightRecorder`]. Every sample double-records into the matching
//!   fleet-level histogram on [`FleetObs`], so fleet aggregates survive
//!   session close and need no merge walk at scrape time.
//! * [`FleetObs`] — one per `SessionManager`: the metric [`Registry`]
//!   (supervisor + net counters register here) plus the fleet-level
//!   stage histograms and the serving start time behind
//!   `worker_busy_ratio`.
//! * [`render_fleet_text`] — the Prometheus-style text body served by
//!   both export surfaces: the `STATS_REQ`/`STATS` wire message and the
//!   [`MetricsServer`] behind `tsisc serve --metrics ADDR`.
//! * [`ObsJsonWriter`] — the periodic JSON snapshot writer reusing
//!   `util::bench::dump_json`, so fleet snapshots land in the same
//!   `{"benchmarks": [...]}` shape CI already parses.
//!
//! ## The two batch-latency metrics
//!
//! The fleet reports batch latency twice, on purpose:
//!
//! * **`ingest_ack_us`** — producer-side wall time of one
//!   `ingest_batch` call: clock/admission checks, STCF staging and job
//!   *enqueue*. This is what a wire client experiences as time-to-ACK.
//!   It does **not** include queue wait or band-writer service — a
//!   backlogged fleet still ACKs quickly.
//! * **`batch_e2e_us`** — end-to-end: enqueue → band writer finished
//!   applying the batch (`queue_wait_us` + write service). This is the
//!   number that grows under load, and the one capacity planning reads.
//!
//! The historical `SessionStats.batch_latency_p50_ms/_p99_ms` measured
//! ingest-ack only; its µs-backed successors keep that meaning (see
//! `serve::stats`).
//!
//! Everything purely observational here — histograms, spans, the flight
//! recorder — compiles to a no-op under the `telemetry-off` feature;
//! frames are bit-for-bit identical either way
//! (`tests/telemetry_equiv.rs`).

use super::stats::ServeStats;
use super::supervise::FaultJobKind;
use crate::util::sync::{Arc, Mutex};
use crate::util::telemetry::{render_histogram, Histogram, Registry};
use std::time::Instant;

/// Elapsed wall time since `t0` in microseconds — the repo's one
/// duration unit (saturating; `u64` µs spans ~585k years).
#[inline]
pub fn elapsed_us(t0: Instant) -> u64 {
    t0.elapsed().as_micros() as u64
}

/// RAII stage span: records its lifetime into a histogram (µs) on drop.
/// Under `telemetry-off` it is a zero-sized no-op — not even the clock
/// is read.
#[cfg(not(feature = "telemetry-off"))]
pub struct Span<'a> {
    h: &'a Histogram,
    t0: Instant,
}

#[cfg(not(feature = "telemetry-off"))]
impl<'a> Span<'a> {
    #[inline]
    pub fn enter(h: &'a Histogram) -> Self {
        Self { h, t0: Instant::now() }
    }
}

#[cfg(not(feature = "telemetry-off"))]
impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.h.record(elapsed_us(self.t0));
    }
}

/// The `telemetry-off` span: zero-sized, no clock read, no record.
#[cfg(feature = "telemetry-off")]
pub struct Span<'a>(std::marker::PhantomData<&'a ()>);

#[cfg(feature = "telemetry-off")]
impl<'a> Span<'a> {
    #[inline]
    pub fn enter(_h: &'a Histogram) -> Self {
        Span(std::marker::PhantomData)
    }
}

/// One flight-recorder record: a completed scheduler job with its
/// queue-wait and service time. This is what a quarantined session's
/// `SessionFault::recent` carries — the last [`FLIGHT_CAPACITY`] jobs
/// before the panic, oldest first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlightSample {
    /// Per-session monotone sequence number (1-based).
    pub seq: u64,
    /// Band the job ran on.
    pub band: u16,
    /// Job kind, in the supervision taxonomy.
    pub job: FaultJobKind,
    /// Time spent in the ready queue before a worker picked it up (µs).
    pub queue_wait_us: u64,
    /// Time spent executing (µs).
    pub service_us: u64,
}

/// Bound of the per-session flight-recorder ring. Sized so a
/// `SessionFault` dump stays a screenful while still covering the
/// handful of batches that precede a typical panic.
pub const FLIGHT_CAPACITY: usize = 64;

/// A bounded ring of the session's most recent job records. Recording
/// takes a short per-session lock (never the registry's, never another
/// session's); the ring is preallocated once, so the hot path does not
/// allocate. Under `telemetry-off` this is a zero-sized no-op and
/// `tail()` is always empty.
#[cfg(not(feature = "telemetry-off"))]
#[derive(Debug, Default)]
pub struct FlightRecorder {
    inner: Mutex<FlightRing>,
}

#[cfg(not(feature = "telemetry-off"))]
#[derive(Debug, Default)]
struct FlightRing {
    seq: u64,
    ring: Vec<FlightSample>,
    /// Overwrite cursor once the ring is full.
    head: usize,
}

#[cfg(not(feature = "telemetry-off"))]
impl FlightRecorder {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(FlightRing {
                seq: 0,
                ring: Vec::with_capacity(FLIGHT_CAPACITY),
                head: 0,
            }),
        }
    }

    /// Append one job record, evicting the oldest past [`FLIGHT_CAPACITY`].
    pub fn record(&self, band: u16, job: FaultJobKind, queue_wait_us: u64, service_us: u64) {
        let mut g = self.inner.lock().expect("flight recorder lock");
        g.seq += 1;
        let sample = FlightSample { seq: g.seq, band, job, queue_wait_us, service_us };
        if g.ring.len() < FLIGHT_CAPACITY {
            g.ring.push(sample);
        } else {
            let head = g.head;
            g.ring[head] = sample;
            g.head = (head + 1) % FLIGHT_CAPACITY;
        }
    }

    /// Snapshot of the ring, oldest → newest. At most
    /// [`FLIGHT_CAPACITY`] records, always.
    pub fn tail(&self) -> Vec<FlightSample> {
        let g = self.inner.lock().expect("flight recorder lock");
        let mut out = Vec::with_capacity(g.ring.len());
        if g.ring.len() < FLIGHT_CAPACITY {
            out.extend_from_slice(&g.ring);
        } else {
            out.extend_from_slice(&g.ring[g.head..]);
            out.extend_from_slice(&g.ring[..g.head]);
        }
        out
    }
}

/// The `telemetry-off` flight recorder: zero-sized, records nothing.
#[cfg(feature = "telemetry-off")]
#[derive(Debug, Default)]
pub struct FlightRecorder;

#[cfg(feature = "telemetry-off")]
impl FlightRecorder {
    pub fn new() -> Self {
        FlightRecorder
    }

    #[inline]
    pub fn record(&self, _band: u16, _job: FaultJobKind, _queue_wait_us: u64, _service_us: u64) {}

    pub fn tail(&self) -> Vec<FlightSample> {
        Vec::new()
    }
}

/// Fleet-level observability root: the metric [`Registry`] every serve
/// counter registers into, the fleet-wide stage histograms, and the
/// serving start time. One per `SessionManager`, shared by `Arc`.
pub struct FleetObs {
    /// Named registry: supervisor counters, net counters, and the fleet
    /// histograms below all live here, so one [`Registry::render`]
    /// covers every registered metric.
    pub registry: Registry,
    /// Queue wait of every scheduler job (enqueue → a worker dequeues).
    pub queue_wait: Arc<Histogram>,
    /// Wire BATCH payload decode (connection-scoped; sessions driven
    /// in-process never touch a decode stage, so this one has no
    /// per-session twin).
    pub stage_decode: Arc<Histogram>,
    /// STCF score job service time.
    pub stage_score: Arc<Histogram>,
    /// Band-write (route/apply) job service time.
    pub stage_route: Arc<Histogram>,
    /// Snapshot render job service time.
    pub stage_render: Arc<Histogram>,
    /// Frame composite (band gather on the session thread).
    pub stage_composite: Arc<Histogram>,
    /// Producer-side `ingest_batch` wall time (time-to-ACK; module docs).
    pub ingest_ack: Arc<Histogram>,
    /// End-to-end batch latency: enqueue → band writer applied it.
    pub batch_e2e: Arc<Histogram>,
    started: Instant,
}

impl FleetObs {
    pub fn new() -> Self {
        let registry = Registry::new();
        let queue_wait = registry.histogram("queue_wait_us");
        let stage_decode = registry.histogram("stage_decode_us");
        let stage_score = registry.histogram("stage_score_us");
        let stage_route = registry.histogram("stage_route_us");
        let stage_render = registry.histogram("stage_render_us");
        let stage_composite = registry.histogram("stage_composite_us");
        let ingest_ack = registry.histogram("ingest_ack_us");
        let batch_e2e = registry.histogram("batch_e2e_us");
        Self {
            registry,
            queue_wait,
            stage_decode,
            stage_score,
            stage_route,
            stage_render,
            stage_composite,
            ingest_ack,
            batch_e2e,
            started: Instant::now(),
        }
    }

    /// Wall time since the manager was built (µs, ≥ 1 so ratios are
    /// division-safe).
    pub fn uptime_us(&self) -> u64 {
        elapsed_us(self.started).max(1)
    }

    /// Fraction of the worker pool's wall-clock capacity spent in job
    /// service since start: Σ service-time sums ÷ (workers × uptime).
    /// An approximation — checkpoint/restore/close service is not staged
    /// — and 0 under `telemetry-off` (histogram sums read 0).
    pub fn worker_busy_ratio(&self, workers: usize) -> f64 {
        if workers == 0 {
            return 0.0;
        }
        let busy_us = self.stage_score.sum() + self.stage_route.sum() + self.stage_render.sum();
        (busy_us as f64 / (workers as f64 * self.uptime_us() as f64)).min(1.0)
    }
}

impl Default for FleetObs {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-session observability: stage histograms plus the flight
/// recorder. Shared `Arc` between the session front half (ingest-ack,
/// composite) and its band slots on the worker pool (queue wait, job
/// service). Every sample double-records into the fleet twin so the
/// fleet view needs no merge at scrape time and outlives the session.
pub struct SessionObs {
    fleet: Arc<FleetObs>,
    pub queue_wait: Histogram,
    pub stage_score: Histogram,
    pub stage_route: Histogram,
    pub stage_render: Histogram,
    pub stage_composite: Histogram,
    pub ingest_ack: Histogram,
    pub batch_e2e: Histogram,
    pub flight: FlightRecorder,
}

impl SessionObs {
    pub fn new(fleet: Arc<FleetObs>) -> Self {
        Self {
            fleet,
            queue_wait: Histogram::new(),
            stage_score: Histogram::new(),
            stage_route: Histogram::new(),
            stage_render: Histogram::new(),
            stage_composite: Histogram::new(),
            ingest_ack: Histogram::new(),
            batch_e2e: Histogram::new(),
            flight: FlightRecorder::new(),
        }
    }

    /// The fleet root this session double-records into.
    pub fn fleet(&self) -> &Arc<FleetObs> {
        &self.fleet
    }

    /// Record one completed scheduler job: flight-record it, count its
    /// queue wait, and file its service time under the job's stage
    /// (write → route, score → score, snapshot → render; the
    /// lifecycle jobs have no stage histogram and only flight-record).
    /// A completed write job also closes the end-to-end batch span:
    /// `batch_e2e_us = queue_wait + service`.
    pub fn record_job(&self, band: u16, job: FaultJobKind, queue_wait_us: u64, service_us: u64) {
        self.flight.record(band, job, queue_wait_us, service_us);
        self.queue_wait.record(queue_wait_us);
        self.fleet.queue_wait.record(queue_wait_us);
        match job {
            FaultJobKind::Write => {
                self.stage_route.record(service_us);
                self.fleet.stage_route.record(service_us);
                let e2e = queue_wait_us.saturating_add(service_us);
                self.batch_e2e.record(e2e);
                self.fleet.batch_e2e.record(e2e);
            }
            FaultJobKind::Score => {
                self.stage_score.record(service_us);
                self.fleet.stage_score.record(service_us);
            }
            FaultJobKind::Snapshot => {
                self.stage_render.record(service_us);
                self.fleet.stage_render.record(service_us);
            }
            FaultJobKind::Checkpoint | FaultJobKind::Restore | FaultJobKind::Close => {}
        }
    }

    /// Record one frame-composite span (µs).
    pub fn record_composite(&self, us: u64) {
        self.stage_composite.record(us);
        self.fleet.stage_composite.record(us);
    }

    /// Record one producer-side `ingest_batch` wall time (µs).
    pub fn record_ingest_ack(&self, us: u64) {
        self.ingest_ack.record(us);
        self.fleet.ingest_ack.record(us);
    }
}

fn push_gauge(out: &mut String, name: &str, v: u64) {
    out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
}

/// Render the full fleet scrape body: scrape-time gauges derived from
/// [`ServeStats`], everything in the registry (fleet histograms +
/// supervisor/net counters), then per-session labeled sections. This is
/// the one text both export surfaces serve (wire `STATS` and
/// `--metrics` HTTP).
pub fn render_fleet_text(
    fleet: &FleetObs,
    stats: &ServeStats,
    degrade_tier: u8,
    sessions: &[(String, Arc<SessionObs>)],
) -> String {
    let mut out = String::new();
    // Scrape-time fleet gauges (levels sampled from the manager, not
    // registered: they are owned by functional state elsewhere).
    push_gauge(&mut out, "uptime_us", fleet.uptime_us());
    push_gauge(&mut out, "workers_total", stats.workers as u64);
    push_gauge(&mut out, "open_sessions_total", stats.open_sessions as u64);
    push_gauge(&mut out, "open_bands_total", stats.open_bands as u64);
    push_gauge(&mut out, "ready_depth_total", stats.ready_depth as u64);
    push_gauge(&mut out, "jobs_executed_total", stats.jobs_executed);
    push_gauge(&mut out, "events_in_total", stats.events_in);
    push_gauge(&mut out, "rejected_batches_total", stats.rejected_batches);
    push_gauge(&mut out, "resident_bytes", stats.resident_bytes as u64);
    push_gauge(&mut out, "degrade_tier_total", degrade_tier as u64);
    out.push_str(&format!(
        "# TYPE worker_busy_ratio gauge\nworker_busy_ratio {:.6}\n",
        fleet.worker_busy_ratio(stats.workers)
    ));
    // Every registered metric: fleet stage histograms, supervisor and
    // net counters.
    out.push_str(&fleet.registry.render());
    // Per-session sections, labeled by session name.
    for s in &stats.sessions {
        let labels = format!(",session=\"{}\"", s.name);
        let block = format!("{{session=\"{}\"}}", s.name);
        out.push_str(&format!("session_events_in_total{block} {}\n", s.events_in));
        out.push_str(&format!("session_events_routed_total{block} {}\n", s.events_routed));
        out.push_str(&format!(
            "session_events_dropped_by_stcf_total{block} {}\n",
            s.events_dropped_by_stcf
        ));
        out.push_str(&format!("session_snapshots_served_total{block} {}\n", s.snapshots_served));
        out.push_str(&format!("session_resident_bytes{block} {}\n", s.resident_bytes));
        if let Some((_, obs)) = sessions.iter().find(|(name, _)| *name == s.name) {
            render_histogram(&mut out, "session_queue_wait_us", &labels, &obs.queue_wait);
            render_histogram(&mut out, "session_stage_score_us", &labels, &obs.stage_score);
            render_histogram(&mut out, "session_stage_route_us", &labels, &obs.stage_route);
            render_histogram(&mut out, "session_stage_render_us", &labels, &obs.stage_render);
            render_histogram(
                &mut out,
                "session_stage_composite_us",
                &labels,
                &obs.stage_composite,
            );
            render_histogram(&mut out, "session_ingest_ack_us", &labels, &obs.ingest_ack);
            render_histogram(&mut out, "session_batch_e2e_us", &labels, &obs.batch_e2e);
        }
    }
    out
}

/// Periodic JSON snapshot writer: serializes the fleet's headline
/// numbers through `util::bench::dump_json`, so operational snapshots
/// share the `{"benchmarks": [...]}` shape (and tooling) of the bench
/// artifacts. Keys are the fixed set below — `bench-compare` can diff
/// two snapshots the same way it diffs two bench runs.
pub struct ObsJsonWriter {
    path: String,
    every_us: u64,
    last: Option<Instant>,
}

impl ObsJsonWriter {
    pub fn new(path: &str, every_secs: u64) -> Self {
        Self { path: path.to_string(), every_us: every_secs.saturating_mul(1_000_000), last: None }
    }

    /// Write a snapshot if the interval elapsed (or none was written
    /// yet). Returns whether a write happened.
    pub fn maybe_write(&mut self, fleet: &FleetObs, stats: &ServeStats) -> bool {
        let due = match self.last {
            None => true,
            Some(t0) => elapsed_us(t0) >= self.every_us,
        };
        if due {
            self.write_now(fleet, stats);
            self.last = Some(Instant::now());
        }
        due
    }

    /// Write one snapshot unconditionally.
    pub fn write_now(&self, fleet: &FleetObs, stats: &ServeStats) {
        let result = crate::util::bench::BenchResult {
            name: "serve_obs_snapshot".to_string(),
            iters: 1,
            mean_ns: fleet.uptime_us() as f64 * 1e3,
            stddev_ns: 0.0,
            min_ns: fleet.uptime_us() as f64 * 1e3,
            items_per_iter: stats.events_in as f64,
        };
        let entry = crate::util::bench::JsonEntry {
            result,
            extra: vec![
                ("events_in_total", stats.events_in as f64),
                ("jobs_executed_total", stats.jobs_executed as f64),
                ("open_sessions_total", stats.open_sessions as f64),
                ("resident_bytes", stats.resident_bytes as f64),
                ("queue_wait_p99_us", fleet.queue_wait.percentile(99.0) as f64),
                ("stage_decode_p99_us", fleet.stage_decode.percentile(99.0) as f64),
                ("stage_score_p99_us", fleet.stage_score.percentile(99.0) as f64),
                ("stage_route_p99_us", fleet.stage_route.percentile(99.0) as f64),
                ("stage_render_p99_us", fleet.stage_render.percentile(99.0) as f64),
                ("stage_composite_p99_us", fleet.stage_composite.percentile(99.0) as f64),
                ("ingest_ack_p99_us", fleet.ingest_ack.percentile(99.0) as f64),
                ("batch_e2e_p99_us", fleet.batch_e2e.percentile(99.0) as f64),
                ("worker_busy_ratio", fleet.worker_busy_ratio(stats.workers)),
            ],
        };
        crate::util::bench::dump_json(&[entry], &self.path);
    }
}

/// A minimal HTTP/1.1 exposition endpoint for `tsisc serve --metrics
/// ADDR`: every request gets a fresh scrape body from the `source`
/// closure, regardless of method or path. Runs on one OS thread with a
/// nonblocking accept loop so [`MetricsServer::stop`] can interrupt it.
/// Deliberately uses `std` primitives directly (this is plain OS I/O,
/// never loom-modeled, and lives outside `serve/net/`'s
/// deadline-stream discipline — scrapes are read-once/write-once with
/// socket timeouts).
pub struct MetricsServer {
    local_addr: std::net::SocketAddr,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve scrapes of `source()`
    /// until stopped.
    pub fn spawn<F>(addr: &str, source: F) -> std::io::Result<Self>
    where
        F: Fn() -> String + Send + 'static,
    {
        let listener = std::net::TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = std::sync::Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("tsisc-metrics".to_string())
            .spawn(move || accept_scrapes(listener, stop2, source))?;
        Ok(Self { local_addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves `:0` ephemeral ports for tests).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Stop accepting and join the serving thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_scrapes<F: Fn() -> String>(
    listener: std::net::TcpListener,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    source: F,
) {
    while !stop.load(std::sync::atomic::Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = serve_scrape(stream, &source);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(_) => break,
        }
    }
}

fn serve_scrape<F: Fn() -> String>(
    mut stream: std::net::TcpStream,
    source: &F,
) -> std::io::Result<()> {
    use std::io::{Read, Write};
    stream.set_read_timeout(Some(std::time::Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(std::time::Duration::from_secs(2)))?;
    // Best-effort drain of the request head; the body served does not
    // depend on method or path, so one read is enough for any scraper.
    let mut req = [0u8; 1024];
    let _ = stream.read(&mut req);
    let body = source();
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::serve::stats::{NetStats, SupervisorStats};

    fn empty_serve_stats() -> ServeStats {
        ServeStats {
            workers: 2,
            open_sessions: 0,
            open_bands: 0,
            jobs_executed: 7,
            ready_depth: 0,
            rejected_batches: 0,
            events_in: 11,
            resident_bytes: 4096,
            sessions: Vec::new(),
            net: NetStats::default(),
            supervisor: SupervisorStats::default(),
        }
    }

    #[test]
    fn flight_recorder_is_bounded_and_ordered() {
        let fr = FlightRecorder::new();
        for i in 0..(FLIGHT_CAPACITY as u64 + 10) {
            fr.record(3, FaultJobKind::Write, i, i * 2);
        }
        let tail = fr.tail();
        if cfg!(feature = "telemetry-off") {
            assert!(tail.is_empty());
            return;
        }
        assert_eq!(tail.len(), FLIGHT_CAPACITY, "ring never exceeds its bound");
        // Oldest → newest, consecutive seq, ending at the last record.
        for w in tail.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1);
        }
        assert_eq!(tail.last().unwrap().seq, FLIGHT_CAPACITY as u64 + 10);
        assert_eq!(tail.last().unwrap().queue_wait_us, FLIGHT_CAPACITY as u64 + 9);
    }

    #[test]
    fn session_obs_double_records_into_fleet() {
        let fleet = Arc::new(FleetObs::new());
        let a = SessionObs::new(Arc::clone(&fleet));
        let b = SessionObs::new(Arc::clone(&fleet));
        a.record_job(0, FaultJobKind::Write, 10, 90);
        b.record_job(1, FaultJobKind::Score, 5, 20);
        a.record_job(2, FaultJobKind::Snapshot, 1, 300);
        a.record_ingest_ack(42);
        a.record_composite(17);
        if cfg!(feature = "telemetry-off") {
            assert_eq!(fleet.queue_wait.count(), 0);
            return;
        }
        assert_eq!(fleet.queue_wait.count(), 3, "both sessions feed the fleet twin");
        assert_eq!(a.queue_wait.count(), 2);
        assert_eq!(b.queue_wait.count(), 1);
        assert_eq!(fleet.stage_route.count(), 1);
        assert_eq!(fleet.stage_score.count(), 1);
        assert_eq!(fleet.stage_render.count(), 1);
        assert_eq!(fleet.batch_e2e.sum(), 100, "e2e = queue wait + service");
        assert_eq!(fleet.ingest_ack.sum(), 42);
        assert_eq!(fleet.stage_composite.sum(), 17);
        assert_eq!(a.flight.tail().len(), 2, "only a's jobs in a's flight ring");
    }

    #[test]
    fn lifecycle_jobs_flight_record_without_stage_histograms() {
        let fleet = Arc::new(FleetObs::new());
        let s = SessionObs::new(Arc::clone(&fleet));
        s.record_job(0, FaultJobKind::Checkpoint, 4, 8);
        s.record_job(0, FaultJobKind::Close, 2, 1);
        if cfg!(feature = "telemetry-off") {
            return;
        }
        assert_eq!(s.flight.tail().len(), 2);
        assert_eq!(s.queue_wait.count(), 2);
        assert_eq!(s.stage_route.count() + s.stage_score.count() + s.stage_render.count(), 0);
    }

    #[test]
    fn fleet_text_carries_gauges_registry_and_session_sections() {
        let fleet = Arc::new(FleetObs::new());
        let obs = Arc::new(SessionObs::new(Arc::clone(&fleet)));
        obs.record_job(0, FaultJobKind::Write, 10, 90);
        let mut stats = empty_serve_stats();
        stats.open_sessions = 1;
        stats.sessions.push(crate::serve::stats::SessionStats {
            id: 0,
            name: "cam0".to_string(),
            res: crate::events::Resolution { width: 8, height: 8 },
            events_in: 5,
            events_routed: 4,
            events_dropped_by_stcf: 1,
            frames_emitted: 0,
            snapshots_served: 2,
            bands_skipped_unchanged: 0,
            batches_shipped: 1,
            queue_depth: 0,
            peak_queue_depth: 1,
            rejected_batches: 0,
            ingest_ack_p50_us: 100.0,
            ingest_ack_p99_us: 200.0,
            batch_e2e_p50_us: 0.0,
            batch_e2e_p99_us: 0.0,
            resident_bytes: 128,
        });
        let text = render_fleet_text(&fleet, &stats, 1, &[("cam0".to_string(), obs)]);
        assert!(text.contains("workers_total 2"));
        assert!(text.contains("degrade_tier_total 1"));
        assert!(text.contains("worker_busy_ratio "));
        assert!(text.contains("# TYPE queue_wait_us summary"));
        assert!(text.contains("session_events_in_total{session=\"cam0\"} 5"));
        assert!(text.contains("session_queue_wait_us{quantile=\"0.5\",session=\"cam0\"}"));
        // Every non-comment line is `name[{labels}] value`, and every
        // metric name obeys the repo name law.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let name = line.split(['{', ' ']).next().unwrap();
            let stem = name
                .strip_suffix("_count")
                .or_else(|| name.strip_suffix("_sum"))
                .unwrap_or(name);
            assert!(
                crate::util::telemetry::valid_metric_name(stem),
                "exported name breaks the law: {line}"
            );
        }
    }

    #[test]
    fn json_snapshot_writer_emits_stage_keys() {
        let fleet = FleetObs::new();
        fleet.stage_render.record(500);
        let path = std::env::temp_dir().join("tsisc_obs_snapshot_test.json");
        let path = path.to_str().unwrap();
        let mut w = ObsJsonWriter::new(path, 3600);
        assert!(w.maybe_write(&fleet, &empty_serve_stats()), "first write is immediate");
        assert!(!w.maybe_write(&fleet, &empty_serve_stats()), "interval not yet elapsed");
        let s = std::fs::read_to_string(path).unwrap();
        for key in [
            "queue_wait_p99_us",
            "stage_decode_p99_us",
            "stage_score_p99_us",
            "stage_route_p99_us",
            "stage_render_p99_us",
            "batch_e2e_p99_us",
            "worker_busy_ratio",
        ] {
            assert!(s.contains(key), "snapshot missing {key}");
        }
        if !cfg!(feature = "telemetry-off") {
            assert!(s.contains("\"stage_render_p99_us\": 511.0"), "bucket upper of 500: {s}");
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn metrics_server_serves_one_scrape() {
        use std::io::{Read, Write};
        let srv = MetricsServer::spawn("127.0.0.1:0", || "fleet_up_total 1\n".to_string())
            .expect("bind ephemeral");
        let mut c = std::net::TcpStream::connect(srv.local_addr()).expect("connect");
        c.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        c.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        c.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("text/plain"));
        assert!(resp.ends_with("fleet_up_total 1\n"), "{resp}");
        srv.stop();
    }
}
