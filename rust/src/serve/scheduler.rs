//! The shared worker fleet: band semantics layered on the generic
//! [`crate::util::actor::ActorPool`].
//!
//! Every band of every session is a [`BandActor`]: a job queue plus the
//! band's state ([`crate::coordinator::router::BandWriter`] or
//! [`crate::denoise::sharded::BandScorer`]). The scheduling invariants —
//! each actor in the global ready queue at most once, strict per-band
//! FIFO job order, one job per turn with round-robin re-queueing,
//! hold-gated drain quiescence, worker respawn with at-most-once
//! death handoff — live in the generic pool, where the loom models in
//! `tests/loom_sched.rs` check them exhaustively. This module
//! contributes only what is band-specific: the [`Job`] grammar, panic
//! *quarantine* confined to one session, checkpoint export/restore
//! jobs, the in-flight / open-band fleet gauges, and the telemetry
//! tap: every job is enqueued as a [`TimedJob`] so the worker records
//! queue-wait vs service time per stage into the session's
//! [`SessionObs`] (and its flight recorder) as the job completes.
//!
//! ## Supervision boundary
//!
//! Every job body runs under [`crate::util::sync::catch_boundary`]. A
//! panic inside a band operation drops that band's state and files a
//! typed [`SessionFault`] on the owning session's [`FaultBoard`] — the
//! session is quarantined, the worker thread survives, and every other
//! session keeps its exactness guarantees. The job bodies themselves
//! are panic-free by construction (`cargo xtask lint-invariants` rule
//! `panic-boundary` bans `unwrap`/`expect`/`panic!`/bare indexing in
//! them); the only sanctioned panic site on this path is the injected
//! [`ArmedFault::before_job`], which exists to prove the boundary
//! works.
//!
//! Jobs on one band execute strictly in enqueue order — writes land
//! before the snapshot that must observe them — while different bands
//! (of the same or different sessions) run concurrently on however many
//! workers the pool owns. A hot camera flooding its own bands cannot
//! starve the others; it only lengthens its own turnaround. Thread count
//! is fixed at pool construction: sessions spawn no threads of their own
//! (band renders run with `render_chunks = 1`), so the whole fleet is
//! bounded by `workers`, not by session count.

use crate::coordinator::router::{BandSnapshot, BandWriter};
use crate::denoise::sharded::{BandScorer, ScoreItem, ShardTally};
use crate::events::Event;
use crate::serve::obs::{elapsed_us, SessionObs};
use crate::serve::supervise::{
    ArmedFault, BandCheckpoint, FaultBoard, FaultJobKind, SessionFault, SupervisorCounters,
};
use crate::util::actor::{Actor, ActorPool, Hold, SupervisionConfig};
use crate::util::grid::Grid;
use crate::util::sync::chan::Sender;
use crate::util::sync::{catch_boundary, Arc, AtomicUsize, Ordering};

/// Band-local state a job operates on (boxed: actors are long-lived,
/// the enum is moved in and out of the actor on every job turn).
pub(crate) enum BandState {
    Writer(Box<BandWriter>),
    Scorer(Box<BandScorer>),
}

impl BandState {
    /// Approximate resident bytes of the band's state (lazy writer
    /// bands report only their struct size while cold).
    fn approx_bytes(&self) -> usize {
        match self {
            BandState::Writer(w) => w.approx_bytes(),
            BandState::Scorer(s) => s.approx_bytes(),
        }
    }
}

/// Reply to [`Job::Score`].
pub(crate) struct ScoreDone {
    pub scores: Vec<(u32, u32)>,
}

/// Reply to [`Job::Snapshot`].
pub(crate) struct SnapDone {
    pub band: usize,
    pub buf: Grid<f64>,
    pub rendered: bool,
    pub empty_static: bool,
}

/// Reply to [`Job::Close`].
pub(crate) struct CloseDone {
    pub band: usize,
    /// Events the band writer absorbed (0 for scorer bands).
    pub written: u64,
    /// The scorer band's tallies (None for writer bands).
    pub tally: Option<ShardTally>,
}

/// Reply to [`Job::Checkpoint`]: the exported band state, or None when
/// the band is already freed/quarantined (the checkpoint then simply
/// omits it).
pub(crate) struct CheckpointDone {
    pub band: usize,
    pub state: Option<BandCheckpoint>,
}

/// Reply to [`Job::Restore`].
pub(crate) struct RestoreDone {
    pub band: usize,
}

/// One queued unit of work, tagged by its (session, band) actor.
pub(crate) enum Job {
    /// Apply a write batch (sensor-coordinate events) to the band array.
    /// Fire-and-forget; counted against the session's in-flight bound
    /// (incremented by the session *before* enqueue, decremented by the
    /// worker as the job completes).
    Write(Vec<Event>),
    /// Score a time-ordered item list causally and reply.
    Score { items: Vec<ScoreItem>, reply: Sender<ScoreDone> },
    /// Render (or certify unchanged) the band at `at_us` and reply with
    /// the recycled buffer — the dirty-band snapshot protocol, verbatim
    /// from the router. Carries its enqueue instant so the worker can
    /// count soft-deadline misses (`deadline_us == 0` disables).
    Snapshot {
        at_us: u64,
        buf: Grid<f64>,
        cache_valid: bool,
        band: usize,
        enqueued: std::time::Instant,
        deadline_us: u64,
        reply: Sender<SnapDone>,
    },
    /// Export the band's state for a session checkpoint and reply.
    /// Runs on the band's own FIFO, so it observes exactly the writes
    /// enqueued before it — a consistent cut without stopping the fleet.
    Checkpoint { band: usize, reply: Sender<CheckpointDone> },
    /// Install a rebuilt band state (restore-in-place or migrate). The
    /// state was reconstructed on the session thread; installing via the
    /// band FIFO keeps the open-band/resident gauges worker-maintained
    /// and serializes against any jobs still draining on the old state.
    Restore { state: Box<BandState>, band: usize, reply: Sender<RestoreDone> },
    /// Drop the band state (freeing its arrays), report the final
    /// counters, and acknowledge.
    Close { band: usize, reply: Sender<CloseDone> },
}

impl Job {
    /// The job's kind in the supervision/observability taxonomy.
    fn kind(&self) -> FaultJobKind {
        match self {
            Job::Write(_) => FaultJobKind::Write,
            Job::Score { .. } => FaultJobKind::Score,
            Job::Snapshot { .. } => FaultJobKind::Snapshot,
            Job::Checkpoint { .. } => FaultJobKind::Checkpoint,
            Job::Restore { .. } => FaultJobKind::Restore,
            Job::Close { .. } => FaultJobKind::Close,
        }
    }
}

/// Every queued job wrapped with its enqueue instant, so the worker can
/// split observed latency into queue wait (enqueue → dequeue) and
/// service time (the `execute_inner` body) — the two numbers the
/// telemetry plane files per stage and the flight recorder keeps per
/// job. The instant is captured unconditionally (one clock read; the
/// `telemetry-off` guarantee is about observable frames, not about
/// skipping a register-sized timestamp).
pub(crate) struct TimedJob {
    enqueued: std::time::Instant,
    job: Job,
}

/// The per-actor slot handed to the job runner: the band state plus the
/// fleet gauges and supervision hooks the runner maintains as jobs
/// complete.
pub(crate) struct BandSlot {
    /// None after [`Job::Close`] ran or a job panicked (band is freed).
    state: Option<BandState>,
    /// Band index, for fault attribution.
    band: u16,
    /// The owning session's in-flight write-batch gauge (admission
    /// control reads it; workers decrement it as write jobs complete).
    inflight: Arc<AtomicUsize>,
    /// Fleet gauge of live band states (decremented by [`Job::Close`]
    /// and by quarantine).
    open_bands: Arc<AtomicUsize>,
    /// The owning session's resident-bytes gauge: after every job the
    /// runner re-measures the band state and applies the delta, so the
    /// gauge tracks materialization, demotion, growth and teardown
    /// without any producer-side round-trip.
    resident: Arc<AtomicUsize>,
    /// This band's last reported contribution to `resident`.
    last_bytes: usize,
    /// The owning session's quarantine board.
    faults: Arc<FaultBoard>,
    /// Fleet supervision counters.
    counters: Arc<SupervisorCounters>,
    /// The owning session's observability handle: stage histograms +
    /// flight recorder (shared with the session front half).
    obs: Arc<SessionObs>,
    /// Chaos-injection plan armed on this session (None in production).
    armed: Option<Arc<ArmedFault>>,
}

/// Everything needed to register one band actor — bundled so
/// [`WorkerPool::spawn_actor`] stays a one-argument call as the
/// supervision hooks grow.
pub(crate) struct BandSeed {
    pub state: BandState,
    pub band: u16,
    pub inflight: Arc<AtomicUsize>,
    pub open_bands: Arc<AtomicUsize>,
    pub resident: Arc<AtomicUsize>,
    pub faults: Arc<FaultBoard>,
    pub counters: Arc<SupervisorCounters>,
    pub obs: Arc<SessionObs>,
    pub armed: Option<Arc<ArmedFault>>,
}

/// Re-measure the slot's band state and fold the delta into the
/// session's resident-bytes gauge.
fn sync_resident(slot: &mut BandSlot) {
    let now = slot.state.as_ref().map_or(0, BandState::approx_bytes);
    if now >= slot.last_bytes {
        slot.resident.fetch_add(now - slot.last_bytes, Ordering::SeqCst);
    } else {
        slot.resident.fetch_sub(slot.last_bytes - now, Ordering::SeqCst);
    }
    slot.last_bytes = now;
}

/// One (session, band) actor on the generic pool.
pub(crate) type BandActor = Actor<BandSlot, TimedJob>;

/// The fixed worker fleet (a band-typed [`ActorPool`] with worker
/// supervision: a dead worker thread is respawned under the restart
/// budget, and budget exhaustion flags the fleet degraded).
pub(crate) struct WorkerPool {
    pool: ActorPool<BandSlot, TimedJob>,
}

/// Pauses the worker fleet while alive (workers finish their current
/// job, then idle). Returned by `SessionManager::hold_workers`; dropping
/// it resumes draining. Used to stage deterministic backpressure and
/// for maintenance drains.
pub struct HoldGuard {
    _hold: Hold<BandSlot, TimedJob>,
}

impl WorkerPool {
    pub(crate) fn new(workers: usize, supervision: SupervisionConfig) -> Self {
        Self { pool: ActorPool::with_supervision(workers, supervision, execute) }
    }

    pub(crate) fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Register a new band actor with the fleet gauges. The band's
    /// initial footprint lands on the session's resident gauge
    /// immediately (lazy writer bands contribute only their struct).
    pub(crate) fn spawn_actor(&self, seed: BandSeed) -> Arc<BandActor> {
        seed.open_bands.fetch_add(1, Ordering::SeqCst);
        let mut slot = BandSlot {
            state: Some(seed.state),
            band: seed.band,
            inflight: seed.inflight,
            open_bands: seed.open_bands,
            resident: seed.resident,
            last_bytes: 0,
            faults: seed.faults,
            counters: seed.counters,
            obs: seed.obs,
            armed: seed.armed,
        };
        sync_resident(&mut slot);
        self.pool.spawn_actor(slot)
    }

    /// Enqueue `job` on `actor`'s FIFO; schedules the actor if idle.
    /// Never blocks on job execution — backpressure is the session
    /// layer's admission check against the in-flight gauge (which the
    /// session bumps *before* enqueueing a [`Job::Write`]).
    pub(crate) fn enqueue(&self, actor: &Arc<BandActor>, job: Job) {
        self.pool.enqueue(actor, TimedJob { enqueued: std::time::Instant::now(), job });
    }

    /// Jobs executed fleet-wide since construction.
    pub(crate) fn jobs_executed(&self) -> u64 {
        self.pool.jobs_executed()
    }

    /// Panics that escaped a job body to the worker loop (normally 0 —
    /// job bodies carry their own boundary).
    pub(crate) fn jobs_panicked(&self) -> u64 {
        self.pool.jobs_panicked()
    }

    /// Worker threads respawned by the pool supervisor.
    pub(crate) fn worker_respawns(&self) -> u64 {
        self.pool.worker_respawns()
    }

    /// True once the respawn budget was exhausted inside its window.
    pub(crate) fn degraded(&self) -> bool {
        self.pool.degraded()
    }

    /// Actors currently waiting in the global ready queue.
    pub(crate) fn ready_depth(&self) -> usize {
        self.pool.ready_depth()
    }

    /// Pause draining until the guard drops (see [`HoldGuard`]).
    pub(crate) fn hold(&self) -> HoldGuard {
        HoldGuard { _hold: self.pool.hold() }
    }

    /// Stop the fleet: workers drain every queued job, then exit.
    pub(crate) fn shutdown(self) {
        self.pool.shutdown();
    }
}

/// Quarantine the slot's session after a caught job panic: drop the
/// band's state (the band is dead, but the actor keeps draining — later
/// jobs take the stateless paths, so a waiting `snapshot`/`drain`/
/// `close` completes instead of wedging the session) and file a typed
/// [`SessionFault`] so the front door refuses further traffic until a
/// restore. The panic message still lands on stderr via the default
/// hook; the fault detail carries it to the operator.
fn quarantine(slot: &mut BandSlot, job: FaultJobKind, detail: String) {
    if slot.state.take().is_some() {
        slot.open_bands.fetch_sub(1, Ordering::SeqCst);
    }
    slot.counters.job_panics.inc();
    // Dump the session's flight-recorder tail into the fault so the
    // jobs leading up to the panic are preserved post-mortem (the
    // panicking job itself never completed, so it is not in the ring).
    let recent = slot.obs.flight.tail();
    let prior_faults = slot.faults.file(SessionFault { band: slot.band, job, detail, recent });
    if prior_faults == 0 {
        // Count sessions entering quarantine, not individual faults.
        slot.counters.quarantines.inc();
    }
}

fn execute(tj: TimedJob, slot: &mut BandSlot) {
    let queue_wait_us = elapsed_us(tj.enqueued);
    let kind = tj.job.kind();
    let t0 = std::time::Instant::now();
    execute_inner(tj.job, slot);
    let service_us = elapsed_us(t0);
    // File the completed job with the telemetry plane: queue wait +
    // per-stage service histograms (session and fleet) + flight ring.
    slot.obs.record_job(slot.band, kind, queue_wait_us, service_us);
    // One re-measure per job keeps the session's resident gauge honest
    // across materialization (first write), demotion (expiry snapshot),
    // active-set growth, quarantine and close — all of which change the
    // band's footprint on the worker side.
    sync_resident(slot);
}

/// Export the band's state as a checkpoint record (runs inside the
/// supervision boundary).
fn export_band(state: &BandState, band: u16) -> BandCheckpoint {
    match state {
        BandState::Writer(w) => {
            let mut stamps = Vec::new();
            let processed = w.export_state(&mut stamps);
            BandCheckpoint::Writer { band, processed, stamps }
        }
        BandState::Scorer(s) => {
            let mut stamps = Vec::new();
            let tally = s.export_state(&mut stamps);
            BandCheckpoint::Scorer { band, tally, stamps }
        }
    }
}

fn execute_inner(job: Job, slot: &mut BandSlot) {
    match job {
        Job::Write(mut batch) => {
            let mut failed = None;
            if let Some(BandState::Writer(w)) = &mut slot.state {
                let armed = slot.armed.clone();
                let counters = Arc::clone(&slot.counters);
                if let Err(msg) = catch_boundary(|| {
                    if let Some(f) = &armed {
                        f.before_job(&counters);
                    }
                    w.apply_batch(&mut batch);
                }) {
                    failed = Some(msg);
                }
            }
            if let Some(msg) = failed {
                quarantine(slot, FaultJobKind::Write, msg);
            }
            slot.inflight.fetch_sub(1, Ordering::SeqCst);
        }
        Job::Score { items, reply } => {
            let mut scores = Vec::new();
            let mut failed = None;
            if let Some(BandState::Scorer(s)) = &mut slot.state {
                let armed = slot.armed.clone();
                let counters = Arc::clone(&slot.counters);
                if let Err(msg) = catch_boundary(|| {
                    if let Some(f) = &armed {
                        f.before_job(&counters);
                    }
                    s.process(&items, &mut scores);
                }) {
                    failed = Some(msg);
                }
            }
            if let Some(msg) = failed {
                quarantine(slot, FaultJobKind::Score, msg);
            }
            let _ = reply.send(ScoreDone { scores });
        }
        Job::Snapshot { at_us, mut buf, cache_valid, band, enqueued, deadline_us, reply } => {
            let mut out = BandSnapshot { rendered: false, empty_static: false };
            let mut failed = None;
            if let Some(BandState::Writer(w)) = &mut slot.state {
                let armed = slot.armed.clone();
                let counters = Arc::clone(&slot.counters);
                match catch_boundary(|| {
                    if let Some(f) = &armed {
                        f.before_job(&counters);
                    }
                    w.snapshot_into(&mut buf, at_us, cache_valid)
                }) {
                    Ok(o) => out = o,
                    Err(msg) => failed = Some(msg),
                }
            }
            if let Some(msg) = failed {
                quarantine(slot, FaultJobKind::Snapshot, msg);
            }
            if deadline_us > 0 && enqueued.elapsed().as_micros() as u64 > deadline_us {
                slot.counters.deadline_misses.inc();
            }
            let rendered = out.rendered;
            let empty_static = out.empty_static;
            let _ = reply.send(SnapDone { band, buf, rendered, empty_static });
        }
        Job::Checkpoint { band, reply } => {
            let mut exported = None;
            let mut failed = None;
            if let Some(state) = &slot.state {
                let band_ix = slot.band;
                match catch_boundary(|| export_band(state, band_ix)) {
                    Ok(ck) => exported = Some(ck),
                    Err(msg) => failed = Some(msg),
                }
            }
            if let Some(msg) = failed {
                quarantine(slot, FaultJobKind::Checkpoint, msg);
            }
            let _ = reply.send(CheckpointDone { band, state: exported });
        }
        Job::Restore { state, band, reply } => {
            // Installing counts the band open again if quarantine or
            // close had freed it; replacing live state keeps the gauge.
            if slot.state.replace(*state).is_none() {
                slot.open_bands.fetch_add(1, Ordering::SeqCst);
            }
            let _ = reply.send(RestoreDone { band });
        }
        Job::Close { band, reply } => {
            let (written, tally) = match slot.state.take() {
                Some(BandState::Writer(w)) => {
                    let n = w.events_written();
                    // Dropping `w` here frees the band's arrays — the
                    // fleet gauge reflects it before the ack lands.
                    drop(w);
                    slot.open_bands.fetch_sub(1, Ordering::SeqCst);
                    (n, None)
                }
                Some(BandState::Scorer(s)) => {
                    let tally = s.tally().clone();
                    drop(s);
                    slot.open_bands.fetch_sub(1, Ordering::SeqCst);
                    (0, Some(tally))
                }
                None => (0, None),
            };
            let _ = reply.send(CloseDone { band, written, tally });
        }
    }
}
