//! The shared worker fleet: band semantics layered on the generic
//! [`crate::util::actor::ActorPool`].
//!
//! Every band of every session is a [`BandActor`]: a job queue plus the
//! band's state ([`crate::coordinator::router::BandWriter`] or
//! [`crate::denoise::sharded::BandScorer`]). The scheduling invariants —
//! each actor in the global ready queue at most once, strict per-band
//! FIFO job order, one job per turn with round-robin re-queueing,
//! hold-gated drain quiescence — live in the generic pool, where the
//! loom models in `tests/loom_sched.rs` check them exhaustively. This
//! module contributes only what is band-specific: the [`Job`] grammar,
//! panic poisoning confined to one band, and the in-flight / open-band
//! fleet gauges.
//!
//! Jobs on one band execute strictly in enqueue order — writes land
//! before the snapshot that must observe them — while different bands
//! (of the same or different sessions) run concurrently on however many
//! workers the pool owns. A hot camera flooding its own bands cannot
//! starve the others; it only lengthens its own turnaround. Thread count
//! is fixed at pool construction: sessions spawn no threads of their own
//! (band renders run with `render_chunks = 1`), so the whole fleet is
//! bounded by `workers`, not by session count.

use crate::coordinator::router::{BandSnapshot, BandWriter};
use crate::denoise::sharded::{BandScorer, ScoreItem, ShardTally};
use crate::events::Event;
use crate::util::actor::{Actor, ActorPool, Hold};
use crate::util::grid::Grid;
use crate::util::sync::chan::Sender;
use crate::util::sync::{Arc, AtomicUsize, Ordering};

/// Band-local state a job operates on (boxed: actors are long-lived,
/// the enum is moved in and out of the actor on every job turn).
pub(crate) enum BandState {
    Writer(Box<BandWriter>),
    Scorer(Box<BandScorer>),
}

impl BandState {
    /// Approximate resident bytes of the band's state (lazy writer
    /// bands report only their struct size while cold).
    fn approx_bytes(&self) -> usize {
        match self {
            BandState::Writer(w) => w.approx_bytes(),
            BandState::Scorer(s) => s.approx_bytes(),
        }
    }
}

/// Reply to [`Job::Score`].
pub(crate) struct ScoreDone {
    pub scores: Vec<(u32, u32)>,
}

/// Reply to [`Job::Snapshot`].
pub(crate) struct SnapDone {
    pub band: usize,
    pub buf: Grid<f64>,
    pub rendered: bool,
    pub empty_static: bool,
}

/// Reply to [`Job::Close`].
pub(crate) struct CloseDone {
    pub band: usize,
    /// Events the band writer absorbed (0 for scorer bands).
    pub written: u64,
    /// The scorer band's tallies (None for writer bands).
    pub tally: Option<ShardTally>,
}

/// One queued unit of work, tagged by its (session, band) actor.
pub(crate) enum Job {
    /// Apply a write batch (sensor-coordinate events) to the band array.
    /// Fire-and-forget; counted against the session's in-flight bound
    /// (incremented by the session *before* enqueue, decremented by the
    /// worker as the job completes).
    Write(Vec<Event>),
    /// Score a time-ordered item list causally and reply.
    Score { items: Vec<ScoreItem>, reply: Sender<ScoreDone> },
    /// Render (or certify unchanged) the band at `at_us` and reply with
    /// the recycled buffer — the dirty-band snapshot protocol, verbatim
    /// from the router.
    Snapshot {
        at_us: u64,
        buf: Grid<f64>,
        cache_valid: bool,
        band: usize,
        reply: Sender<SnapDone>,
    },
    /// Drop the band state (freeing its arrays), report the final
    /// counters, and acknowledge.
    Close { band: usize, reply: Sender<CloseDone> },
}

/// The per-actor slot handed to the job runner: the band state plus the
/// two fleet gauges the runner maintains as jobs complete.
pub(crate) struct BandSlot {
    /// None after [`Job::Close`] ran or a job panicked (band is freed).
    state: Option<BandState>,
    /// The owning session's in-flight write-batch gauge (admission
    /// control reads it; workers decrement it as write jobs complete).
    inflight: Arc<AtomicUsize>,
    /// Fleet gauge of live band states (decremented by [`Job::Close`]
    /// and by panic poisoning).
    open_bands: Arc<AtomicUsize>,
    /// The owning session's resident-bytes gauge: after every job the
    /// runner re-measures the band state and applies the delta, so the
    /// gauge tracks materialization, demotion, growth and teardown
    /// without any producer-side round-trip.
    resident: Arc<AtomicUsize>,
    /// This band's last reported contribution to `resident`.
    last_bytes: usize,
}

/// Re-measure the slot's band state and fold the delta into the
/// session's resident-bytes gauge.
fn sync_resident(slot: &mut BandSlot) {
    let now = slot.state.as_ref().map_or(0, BandState::approx_bytes);
    if now >= slot.last_bytes {
        slot.resident.fetch_add(now - slot.last_bytes, Ordering::SeqCst);
    } else {
        slot.resident.fetch_sub(slot.last_bytes - now, Ordering::SeqCst);
    }
    slot.last_bytes = now;
}

/// One (session, band) actor on the generic pool.
pub(crate) type BandActor = Actor<BandSlot, Job>;

/// The fixed worker fleet (a band-typed [`ActorPool`]).
pub(crate) struct WorkerPool {
    pool: ActorPool<BandSlot, Job>,
}

/// Pauses the worker fleet while alive (workers finish their current
/// job, then idle). Returned by `SessionManager::hold_workers`; dropping
/// it resumes draining. Used to stage deterministic backpressure and
/// for maintenance drains.
pub struct HoldGuard {
    _hold: Hold<BandSlot, Job>,
}

impl WorkerPool {
    pub(crate) fn new(workers: usize) -> Self {
        Self { pool: ActorPool::new(workers, execute) }
    }

    pub(crate) fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Register a new band actor with the fleet gauges. The band's
    /// initial footprint lands on the session's resident gauge
    /// immediately (lazy writer bands contribute only their struct).
    pub(crate) fn spawn_actor(
        &self,
        state: BandState,
        inflight: Arc<AtomicUsize>,
        open_bands: Arc<AtomicUsize>,
        resident: Arc<AtomicUsize>,
    ) -> Arc<BandActor> {
        open_bands.fetch_add(1, Ordering::SeqCst);
        let mut slot =
            BandSlot { state: Some(state), inflight, open_bands, resident, last_bytes: 0 };
        sync_resident(&mut slot);
        self.pool.spawn_actor(slot)
    }

    /// Enqueue `job` on `actor`'s FIFO; schedules the actor if idle.
    /// Never blocks on job execution — backpressure is the session
    /// layer's admission check against the in-flight gauge (which the
    /// session bumps *before* enqueueing a [`Job::Write`]).
    pub(crate) fn enqueue(&self, actor: &Arc<BandActor>, job: Job) {
        self.pool.enqueue(actor, job);
    }

    /// Jobs executed fleet-wide since construction.
    pub(crate) fn jobs_executed(&self) -> u64 {
        self.pool.jobs_executed()
    }

    /// Actors currently waiting in the global ready queue.
    pub(crate) fn ready_depth(&self) -> usize {
        self.pool.ready_depth()
    }

    /// Pause draining until the guard drops (see [`HoldGuard`]).
    pub(crate) fn hold(&self) -> HoldGuard {
        HoldGuard { _hold: self.pool.hold() }
    }

    /// Stop the fleet: workers drain every queued job, then exit.
    pub(crate) fn shutdown(self) {
        self.pool.shutdown();
    }
}

/// Drop a band's state after a job panicked on it. The band is dead,
/// but the actor keeps draining: later jobs take the stateless paths
/// below (no-op + reply), so a waiting `snapshot`/`drain`/`close`
/// completes instead of wedging the whole session. This mirrors the
/// dedicated router's failure visibility (`expect("shard died")`) in
/// queue form — the panic message still lands on stderr via the
/// default hook.
fn poison(slot: &mut BandSlot) {
    if slot.state.take().is_some() {
        slot.open_bands.fetch_sub(1, Ordering::SeqCst);
    }
}

fn execute(job: Job, slot: &mut BandSlot) {
    execute_inner(job, slot);
    // One re-measure per job keeps the session's resident gauge honest
    // across materialization (first write), demotion (expiry snapshot),
    // active-set growth, poisoning and close — all of which change the
    // band's footprint on the worker side.
    sync_resident(slot);
}

fn execute_inner(job: Job, slot: &mut BandSlot) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    match job {
        Job::Write(mut batch) => {
            if let Some(BandState::Writer(w)) = &mut slot.state {
                if catch_unwind(AssertUnwindSafe(|| w.apply_batch(&mut batch))).is_err() {
                    poison(slot);
                }
            }
            slot.inflight.fetch_sub(1, Ordering::SeqCst);
        }
        Job::Score { items, reply } => {
            let mut scores = Vec::new();
            if let Some(BandState::Scorer(s)) = &mut slot.state {
                if catch_unwind(AssertUnwindSafe(|| s.process(&items, &mut scores))).is_err() {
                    poison(slot);
                }
            }
            let _ = reply.send(ScoreDone { scores });
        }
        Job::Snapshot { at_us, mut buf, cache_valid, band, reply } => {
            let mut out = BandSnapshot { rendered: false, empty_static: false };
            if let Some(BandState::Writer(w)) = &mut slot.state {
                let render = catch_unwind(AssertUnwindSafe(|| {
                    w.snapshot_into(&mut buf, at_us, cache_valid)
                }));
                match render {
                    Ok(o) => out = o,
                    Err(_) => poison(slot),
                }
            }
            let rendered = out.rendered;
            let empty_static = out.empty_static;
            let _ = reply.send(SnapDone { band, buf, rendered, empty_static });
        }
        Job::Close { band, reply } => {
            let (written, tally) = match slot.state.take() {
                Some(BandState::Writer(w)) => {
                    let n = w.events_written();
                    // Dropping `w` here frees the band's arrays — the
                    // fleet gauge reflects it before the ack lands.
                    drop(w);
                    slot.open_bands.fetch_sub(1, Ordering::SeqCst);
                    (n, None)
                }
                Some(BandState::Scorer(s)) => {
                    let tally = s.tally().clone();
                    drop(s);
                    slot.open_bands.fetch_sub(1, Ordering::SeqCst);
                    (0, Some(tally))
                }
                None => (0, None),
            };
            let _ = reply.send(CloseDone { band, written, tally });
        }
    }
}
